//! End-to-end XLA runtime integration: load the AOT artifacts produced by
//! `make artifacts`, execute the Pallas-authored ELL SpMV through PJRT,
//! and check numerics against the native rust kernels.
//!
//! Tests are skipped (not failed) when `artifacts/manifest.tsv` is absent,
//! so `cargo test` works before the first `make artifacts`.

use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::matrixgen::{banded_circulant, random_csr};
use spmv_at::rng::Rng;
use spmv_at::runtime::{EllXlaKernel, XlaRuntime, XlaService};
use spmv_at::transform::crs_to_ell;
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn assert_close(a: &[f64], b: &[f64]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= 1e-9 * (1.0 + x.abs()),
            "index {i}: {x} vs {y}"
        );
    }
}

#[test]
fn xla_ell_spmv_matches_native_exact_bucket() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    // Exact bucket: 256 rows, bandwidth 4 (circulant band).
    let mut rng = Rng::new(1);
    let a = banded_circulant(&mut rng, 256, &[-1, 0, 1, 2]);
    let ell = crs_to_ell(&a).unwrap();
    assert_eq!(ell.bandwidth, 4);
    let x: Vec<f64> = (0..256).map(|i| (i as f64 * 0.37).sin()).collect();
    let mut want = vec![0.0; 256];
    a.spmv(&x, &mut want);
    let k = EllXlaKernel::new(&rt, ell).unwrap();
    let mut got = vec![0.0; 256];
    k.spmv(&x, &mut got).unwrap();
    assert_close(&got, &want);
    assert_eq!(rt.compiled_count(), 1);
}

#[test]
fn xla_ell_spmv_pads_into_larger_bucket() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    // 300 rows, bandwidth ~13: needs the 1024x16 bucket with padding on
    // both axes.
    let mut rng = Rng::new(2);
    let a = random_csr(&mut rng, 300, 300, 0.02);
    let ell = crs_to_ell(&a).unwrap();
    assert!(ell.bandwidth <= 16, "bandwidth {} too wide for test", ell.bandwidth);
    let x: Vec<f64> = (0..300).map(|i| 1.0 + (i % 5) as f64).collect();
    let mut want = vec![0.0; 300];
    a.spmv(&x, &mut want);
    let k = EllXlaKernel::new(&rt, ell).unwrap();
    let mut got = vec![0.0; 300];
    k.spmv(&x, &mut got).unwrap();
    assert_close(&got, &want);
}

#[test]
fn xla_executable_cache_reused_across_calls() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let mut rng = Rng::new(3);
    let a = banded_circulant(&mut rng, 256, &[0, 1]);
    let ell = crs_to_ell(&a).unwrap();
    let k = EllXlaKernel::new(&rt, ell).unwrap();
    let x = vec![1.0; 256];
    let mut y = vec![0.0; 256];
    for _ in 0..5 {
        k.spmv(&x, &mut y).unwrap();
    }
    assert_eq!(rt.compiled_count(), 1, "one executable per bucket, compiled once");
}

#[test]
fn xla_rejects_oversized_matrix() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    // Bandwidth 100 exceeds every bucket at 16384 rows.
    let t: Vec<(usize, usize, f64)> = (0..100).map(|j| (0, j * 163, 1.0)).collect();
    let a = Csr::from_triplets(16_384, 16_384, &t).unwrap();
    let ell = crs_to_ell(&a).unwrap();
    assert!(EllXlaKernel::new(&rt, ell).is_err());
}

#[test]
fn xla_service_thread_roundtrip() {
    let Some(dir) = artifact_dir() else { return };
    let (_svc, handle) = XlaService::spawn(dir).expect("service");
    assert!(handle.platform().unwrap().to_lowercase().contains("cpu")
        || handle.platform().unwrap().to_lowercase().contains("host"));
    assert!(handle.has_bucket(256, 4));
    assert!(!handle.has_bucket(1 << 20, 4));

    let mut rng = Rng::new(4);
    let a = banded_circulant(&mut rng, 200, &[-1, 0, 1]);
    let ell = crs_to_ell(&a).unwrap();
    let cols: Vec<i32> = ell.col_idx.iter().map(|&c| c as i32).collect();
    let x: Vec<f64> = (0..200).map(|i| (i as f64).cos()).collect();
    let mut want = vec![0.0; 200];
    a.spmv(&x, &mut want);
    let got = handle
        .ell_spmv(200, ell.bandwidth, &ell.values, &cols, &x)
        .unwrap();
    assert_close(&got, &want);

    // Handle is Send + Sync: exercise from two threads.
    let h2 = handle.clone();
    let t = std::thread::spawn(move || h2.has_bucket(256, 4));
    assert!(t.join().unwrap());
}

#[test]
fn coordinator_serves_through_xla_artifact() {
    use spmv_at::autotune::online::TuningData;
    use spmv_at::coordinator::{Coordinator, CoordinatorConfig, EllExec};
    use spmv_at::formats::FormatKind;
    use spmv_at::spmv::Implementation;

    let Some(dir) = artifact_dir() else { return };
    let (_svc, handle) = XlaService::spawn(dir).expect("service");
    let tuning = TuningData {
        backend: "sim:ES2".into(),
        imp: Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    let mut cfg = CoordinatorConfig::new(tuning);
    cfg.ell_exec = EllExec::XlaPreferred;
    let mut coord = Coordinator::new(cfg).with_xla(handle);

    let mut rng = Rng::new(5);
    let a = banded_circulant(&mut rng, 256, &[-2, 0, 3]);
    let x: Vec<f64> = (0..256).map(|i| (i as f64 * 0.11).sin()).collect();
    let mut want = vec![0.0; 256];
    a.spmv(&x, &mut want);

    coord.register("band", a).unwrap();
    let got = coord.spmv("band", &x).unwrap();
    assert_close(&got, &want);
    assert_eq!(coord.serving_format("band"), Some(FormatKind::Ell));
}
