//! Adaptive-loop invariants, end to end through the coordinator and the
//! sharded server:
//!
//! * exploration (shadow measurement) never changes served results —
//!   bitwise against the `csr_seq` reference, across thread counts;
//! * a wrong offline decision is re-planned to the measured-faster
//!   implementation within K controller windows, observably via the
//!   replan counters, with results bitwise-stable across the flip;
//! * hysteresis suppresses flip-flapping under alternating synthetic
//!   timings;
//! * with the flag off, the pipeline is the decide-once one (no
//!   telemetry hooks, no flips, injection rejected);
//! * the `spmv-at-tuning` v1/v2 formats round-trip and cross-load the
//!   way the forward-compat contract promises.
//!
//! The tuning candidates exercised are ELL-Row *inner* and SELL-Row
//! inner: both keep each row's accumulation order equal to sequential
//! CRS exactly (row-partitioned, band-ordered, no cross-chunk
//! reduction; SELL additionally never touches padding and scatters
//! through its row permutation), so "bitwise vs `csr_seq`" holds for
//! every serving choice the controller can make.

mod common;

use common::{band, reference};
use spmv_at::autotune::adaptive::LearnedTuning;
use spmv_at::autotune::online::TuningData;
use spmv_at::coordinator::{Coordinator, CoordinatorConfig, Server};
use spmv_at::formats::FormatKind;
use spmv_at::spmv::Implementation;
use spmv_at::Value;

fn tuning(d_star: Option<f64>) -> TuningData {
    common::tuning(Implementation::EllRowInner, d_star)
}

fn cfg_for(
    imp: Implementation,
    d_star: Option<f64>,
    threads: usize,
    adaptive: bool,
) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::new(common::tuning(imp, d_star));
    cfg.threads = threads;
    cfg.adaptive.enabled = adaptive;
    // Deterministic tests: no wall-clock-driven exploration by default.
    cfg.adaptive.epsilon = 0.0;
    cfg
}

fn cfg(d_star: Option<f64>, threads: usize, adaptive: bool) -> CoordinatorConfig {
    cfg_for(Implementation::EllRowInner, d_star, threads, adaptive)
}

fn k_windows() -> u64 {
    let cfg = spmv_at::autotune::adaptive::AdaptiveConfig::default();
    cfg.window * cfg.flip_windows as u64
}

#[test]
fn exploration_never_changes_results_bitwise() {
    for arm in [Implementation::EllRowInner, Implementation::SellRowInner] {
        for threads in [1usize, 2, 7] {
            let a = band(160, 3);
            let xs: Vec<Vec<Value>> = (0..6)
                .map(|k| (0..160).map(|i| ((i * 3 + k) as f64 * 0.29).sin() - 0.4).collect())
                .collect();

            // Plain decide-once pipeline.
            let mut plain = Coordinator::new(cfg_for(arm, Some(3.1), threads, false));
            plain.register("m", a.clone()).unwrap();

            // Adaptive with exploration forced on every call, flips disabled so
            // only the shadow machinery differs from the plain run.
            let mut c = cfg_for(arm, Some(3.1), threads, true);
            c.adaptive.epsilon = 1.0;
            c.adaptive.explore_warmup = 0;
            c.adaptive.flip_windows = u32::MAX;
            let mut explored = Coordinator::new(c);
            explored.register("m", a.clone()).unwrap();

            for x in &xs {
                let want = reference(&a, x);
                let yp = plain.spmv("m", x).unwrap();
                let ye = explored.spmv("m", x).unwrap();
                assert_eq!(yp, ye, "exploration must be invisible ({arm}, {threads} threads)");
                assert_eq!(ye, want, "bitwise vs csr_seq ({arm}, {threads} threads)");
            }
            // Batched serving explores too (the whole batch is shadowed
            // through the rival's tiled SpMM, keeping per-call means
            // comparable across arms).
            let yb = explored.spmv_batch("m", &xs).unwrap();
            for (x, y) in xs.iter().zip(&yb) {
                assert_eq!(*y, reference(&a, x), "batch bitwise vs csr_seq ({arm})");
            }
            let s = &explored.stats()[0];
            assert!(s.explored > 0, "shadow calls must have happened");
            assert_eq!(s.replans, 0, "flips were disabled");
            assert!(s.samples_imp > 0 || s.samples_crs > 0, "telemetry must fill");
            // The plain run never explores and never builds telemetry.
            let sp = &plain.stats()[0];
            assert_eq!((sp.explored, sp.samples_crs, sp.samples_imp), (0, 0, 0));
        }
    }
}

#[test]
fn wrong_keep_crs_decision_is_replanned_within_k_windows() {
    // Offline table says "never transform" (no D*), but injected
    // measurements (the synthetic stand-in for MeasuredBackend timings)
    // show the candidate is far faster than any wall-clock serve.
    let a = band(128, 5);
    let mut c = Coordinator::new(cfg(None, 2, true));
    c.register("m", a.clone()).unwrap();
    assert_eq!(c.serving_format("m"), Some(FormatKind::Csr));

    let k_windows = {
        let cfg = spmv_at::autotune::adaptive::AdaptiveConfig::default();
        cfg.window * cfg.flip_windows as u64
    };
    c.inject_sample("m", Implementation::EllRowInner, 1e-12, 16).unwrap();

    let x: Vec<Value> = (0..128).map(|i| (i as f64 * 0.41).cos()).collect();
    let want = reference(&a, &x);
    for call in 0..k_windows {
        let y = c.spmv("m", &x).unwrap();
        assert_eq!(y, want, "bitwise vs csr_seq at call {call}, across the flip");
    }
    assert_eq!(
        c.serving_format("m"),
        Some(FormatKind::Ell),
        "the wrong decision must be corrected within K windows"
    );
    let s = &c.stats()[0];
    assert_eq!(s.replans, 1, "the flip is observable in the counters");
    assert_eq!(s.serving, Implementation::EllRowInner);
    assert!(s.samples_crs > 0, "serving arm was measured");
    // The flip was folded into the learned table for this D_mat bucket.
    assert!(c.learned().correction(s.d_mat).is_some());
    // And serving continues bitwise-stable after the flip.
    assert_eq!(c.spmv("m", &x).unwrap(), want);
}

#[test]
fn wrong_transform_decision_is_replanned_back_to_crs() {
    // Offline table says "transform"; injected measurements say CRS wins.
    let a = band(96, 6);
    let mut c = Coordinator::new(cfg(Some(3.1), 2, true));
    c.register("m", a.clone()).unwrap();
    let x = vec![1.0; 96];
    let want = reference(&a, &x);
    assert_eq!(c.spmv("m", &x).unwrap(), want);
    assert_eq!(c.serving_format("m"), Some(FormatKind::Ell), "transformed on first call");

    // Rival arm (the CRS baseline plan) measured much faster. The
    // baseline kernel follows the partition pick (row-parallel here;
    // merge-path under SPMV_AT_PARTITION=merge or heavy skew), so feed
    // both CRS arms — only the one serving as baseline is consulted.
    c.inject_sample("m", Implementation::CsrRowPar, 1e-12, 16).unwrap();
    c.inject_sample("m", Implementation::CsrMergePar, 1e-12, 16).unwrap();
    let k_windows = {
        let cfg = spmv_at::autotune::adaptive::AdaptiveConfig::default();
        cfg.window * cfg.flip_windows as u64
    };
    for _ in 0..k_windows {
        assert_eq!(c.spmv("m", &x).unwrap(), want, "bitwise across the flip back");
    }
    assert_eq!(c.serving_format("m"), Some(FormatKind::Csr));
    let s = &c.stats()[0];
    assert_eq!(s.replans, 1);
    // The transformed plan is parked for a cheap flip forward, still
    // accounted as held memory.
    assert!(s.extra_bytes > 0, "parked shadow plan keeps its bytes");
    // No immediate re-transform: the decision was updated with the flip.
    for _ in 0..8 {
        c.spmv("m", &x).unwrap();
    }
    assert_eq!(c.serving_format("m"), Some(FormatKind::Csr));
}

#[test]
fn hysteresis_prevents_flip_flap_on_alternating_timings() {
    for arm in [Implementation::EllRowInner, Implementation::SellRowInner] {
        let a = band(64, 7);
        let mut conf = cfg_for(arm, None, 1, true);
        conf.adaptive.window = 4;
        conf.adaptive.flip_windows = 2;
        conf.adaptive.ewma_alpha = 1.0; // telemetry = last injected sample
        let mut c = Coordinator::new(conf);
        c.register("m", a.clone()).unwrap();
        let x = vec![1.0; 64];
        // 20 windows of alternating synthetic rival timings: far faster on
        // even windows, far slower on odd ones. Consecutive-window voting
        // must never reach 2, so no flip ever fires.
        for w in 0..20u64 {
            let rival = if w % 2 == 0 { 1e-12 } else { 1e3 };
            c.inject_sample("m", arm, rival, 1).unwrap();
            for _ in 0..4 {
                c.spmv("m", &x).unwrap();
            }
        }
        assert_eq!(c.serving_format("m"), Some(FormatKind::Csr));
        assert_eq!(c.stats()[0].replans, 0, "alternating evidence must not flip ({arm})");
    }
}

/// ISSUE-6: the explorer shadow-measures SELL as the rival arm and flips
/// *to* it within K windows when the measurements favour it — same
/// contract as the ELL flip test above, exercised through the new
/// format/kernel/plan path end to end, bitwise across the flip.
#[test]
fn wrong_keep_crs_decision_is_replanned_to_sell_within_k_windows() {
    let a = band(128, 5);
    let mut c = Coordinator::new(cfg_for(Implementation::SellRowInner, None, 2, true));
    c.register("m", a.clone()).unwrap();
    assert_eq!(c.serving_format("m"), Some(FormatKind::Csr));

    c.inject_sample("m", Implementation::SellRowInner, 1e-12, 16).unwrap();
    let x: Vec<Value> = (0..128).map(|i| (i as f64 * 0.41).cos()).collect();
    let want = reference(&a, &x);
    for call in 0..k_windows() {
        let y = c.spmv("m", &x).unwrap();
        assert_eq!(y, want, "bitwise vs csr_seq at call {call}, across the SELL flip");
    }
    assert_eq!(
        c.serving_format("m"),
        Some(FormatKind::Sell),
        "the wrong keep-CRS decision must be corrected to SELL within K windows"
    );
    let s = &c.stats()[0];
    assert_eq!(s.replans, 1, "the flip is observable in the counters");
    assert_eq!(s.serving, Implementation::SellRowInner);
    assert!(c.learned().correction(s.d_mat).is_some());
    assert_eq!(c.spmv("m", &x).unwrap(), want, "bitwise-stable after the flip");
}

/// ISSUE-6: and the reverse direction — a decide-once transform *to*
/// SELL is flipped back to CRS when the measured rival (the CRS baseline
/// plan) wins, with the SELL plan parked, not dropped.
#[test]
fn wrong_sell_transform_decision_is_replanned_back_to_crs() {
    let a = band(96, 6);
    let mut c = Coordinator::new(cfg_for(Implementation::SellRowInner, Some(3.1), 2, true));
    c.register("m", a.clone()).unwrap();
    let x = vec![1.0; 96];
    let want = reference(&a, &x);
    assert_eq!(c.spmv("m", &x).unwrap(), want);
    assert_eq!(c.serving_format("m"), Some(FormatKind::Sell), "transformed on first call");

    // Both CRS arms, as above: the baseline kernel follows the
    // partition pick, and only the baseline's telemetry key is read.
    c.inject_sample("m", Implementation::CsrRowPar, 1e-12, 16).unwrap();
    c.inject_sample("m", Implementation::CsrMergePar, 1e-12, 16).unwrap();
    for _ in 0..k_windows() {
        assert_eq!(c.spmv("m", &x).unwrap(), want, "bitwise across the flip back");
    }
    assert_eq!(c.serving_format("m"), Some(FormatKind::Csr));
    let s = &c.stats()[0];
    assert_eq!(s.replans, 1);
    assert!(s.extra_bytes > 0, "parked SELL shadow plan keeps its bytes");
    for _ in 0..8 {
        c.spmv("m", &x).unwrap();
    }
    assert_eq!(c.serving_format("m"), Some(FormatKind::Csr), "no immediate re-transform");
}

#[test]
fn flag_off_is_the_decide_once_pipeline() {
    let a = band(80, 9);
    let mut c = Coordinator::new(cfg(None, 2, false));
    c.register("m", a.clone()).unwrap();
    assert!(!c.adaptive_enabled());
    assert!(
        c.inject_sample("m", Implementation::EllRowInner, 1e-12, 100).is_err(),
        "telemetry injection is rejected when the loop is off"
    );
    let x = vec![1.0; 80];
    let want = reference(&a, &x);
    for _ in 0..64 {
        assert_eq!(c.spmv("m", &x).unwrap(), want);
    }
    let s = &c.stats()[0];
    assert_eq!(c.serving_format("m"), Some(FormatKind::Csr), "decision never moves");
    assert_eq!((s.replans, s.explored, s.samples_crs, s.samples_imp), (0, 0, 0, 0));
}

#[test]
fn replan_flows_through_the_sharded_server() {
    let mut conf = cfg(Some(3.1), 2, true);
    conf.shards = 2;
    let (srv, client) = Server::spawn_sharded(conf, 16);
    let a = band(72, 11);
    client.register("m", a.clone()).unwrap();
    let x = vec![1.0; 72];
    let want = reference(&a, &x);
    assert_eq!(client.spmv("m", x.clone()).unwrap(), want);
    let before = client.stats().unwrap();
    assert_eq!(before[0].serving, Implementation::EllRowInner);
    // Forced replan with an unchanged decision rebuilds + swaps in place.
    let after = client.replan("m").unwrap();
    assert_eq!(after.serving, Implementation::EllRowInner);
    assert_eq!(after.replans, before[0].replans + 1);
    assert_eq!(client.spmv("m", x).unwrap(), want, "swap is bitwise-invisible");
    drop(srv);
}

#[test]
fn tuning_v1_v2_forward_compat_contract() {
    let dir = std::env::temp_dir().join("spmv_at_adaptive_it");
    std::fs::create_dir_all(&dir).unwrap();
    let v1 = dir.join("it1.tsv");
    let v2 = dir.join("it2.tsv");

    for d_star in [Some(0.5), None] {
        // v1 roundtrip (including d_star = none).
        let t = tuning(d_star);
        t.save(&v1).unwrap();
        assert_eq!(TuningData::load(&v1).unwrap(), t);
        // v2 roundtrip with corrections.
        let mut lt = LearnedTuning::new(t.clone());
        lt.record(0.07, 3.5);
        lt.save(&v2).unwrap();
        assert_eq!(LearnedTuning::load(&v2).unwrap(), lt);
        // Forward compat: the v2 loader reads v1 files…
        let up = LearnedTuning::load(&v1).unwrap();
        assert_eq!(up.base, t);
        assert_eq!(up.corrected_buckets(), 0);
        // …and the v1 loader rejects v2 files with a clear error.
        let err = TuningData::load(&v2).unwrap_err().to_string();
        assert!(err.contains("v2") && err.contains("LearnedTuning"), "{err}");
    }
    // Rejected-header path, both loaders.
    let bad = dir.join("bad.tsv");
    std::fs::write(&bad, "spmv-at-tuning v99\nbackend\tx\n").unwrap();
    assert!(TuningData::load(&bad).is_err());
    assert!(LearnedTuning::load(&bad).is_err());
    for p in [v1, v2, bad] {
        std::fs::remove_file(p).ok();
    }
}
