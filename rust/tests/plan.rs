//! Execution-engine tests: `SpmvPlan` correctness against the sequential
//! CRS baseline for every implementation across thread counts, bitwise
//! stability of repeated executions, and pool reuse across consecutive
//! plans (no stale `YY`/partition state).

mod common;

use common::{assert_close, small_suite as cases, tuning};
use spmv_at::autotune::MemoryPolicy;
use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::matrixgen::{banded_circulant, random_csr};
use spmv_at::rng::Rng;
use spmv_at::solver::{cg, SolverOptions};
use spmv_at::spmv::pool::ParPool;
use spmv_at::spmv::{Implementation, Planner, SpmvPlan};
use std::sync::Arc;

/// The headline property: for every implementation and every pool width
/// in {1, 2, 7, 16}, `SpmvPlan::execute` matches `csr_seq` within 1e-9
/// relative tolerance, and repeated executions of one plan are bitwise
/// identical (fixed partition + fixed reduction order).
#[test]
fn plan_execute_matches_csr_seq_for_every_implementation_and_thread_count() {
    for threads in [1usize, 2, 7, 16] {
        let pool = Arc::new(ParPool::new(threads));
        for a in cases() {
            let x: Vec<f64> = (0..a.n_cols()).map(|i| ((i * 3 + 1) as f64).recip()).collect();
            let mut want = vec![0.0; a.n_rows()];
            spmv_at::spmv::csr_seq(&a, &x, &mut want);
            for imp in Implementation::ALL {
                let tag = format!("{imp} t={threads} n={}", a.n_rows());
                let mut plan = SpmvPlan::build(&a, imp, None, pool.clone())
                    .unwrap_or_else(|e| panic!("{tag}: build failed: {e}"));
                let mut y1 = vec![0.0; a.n_rows()];
                plan.execute(&x, &mut y1).unwrap();
                assert_close(&tag, &y1, &want);
                // Bitwise stability across repeated executes.
                for _ in 0..3 {
                    let mut y2 = vec![0.0; a.n_rows()];
                    plan.execute(&x, &mut y2).unwrap();
                    assert_eq!(y1, y2, "{tag}: repeated execute must be bitwise stable");
                }
            }
        }
    }
}

/// ISSUE-6 satellite: SELL-C-σ execution is **bitwise** equal to
/// `csr_seq` — through a built plan (env-default C/σ) and through the
/// raw kernel across the full C × σ property matrix (explicit-parameter
/// builder, no env mutation) — at pool widths {1, 2, 7}. SELL stores
/// each row's entries in CSR order, never accumulates padding, and
/// scatters through the permutation, so not even the last ulp may move.
#[test]
fn sell_plans_are_bitwise_identical_to_csr_seq_across_threads() {
    use spmv_at::spmv::partition::split_even;
    use spmv_at::spmv::sell_row_inner_on;
    use spmv_at::transform::crs_to_sell_with;
    for threads in [1usize, 2, 7] {
        let pool = Arc::new(ParPool::new(threads));
        for a in cases() {
            let x: Vec<f64> =
                (0..a.n_cols()).map(|i| ((i * 7 + 3) as f64 * 0.83).sin()).collect();
            let mut want = vec![0.0; a.n_rows()];
            spmv_at::spmv::csr_seq(&a, &x, &mut want);
            let mut plan =
                SpmvPlan::build(&a, Implementation::SellRowInner, None, pool.clone()).unwrap();
            let mut y = vec![0.0; a.n_rows()];
            plan.execute(&x, &mut y).unwrap();
            assert_eq!(y, want, "plan t={threads} n={}", a.n_rows());
            let n = a.n_rows().max(1);
            for c in [1usize, 4, 32] {
                for sigma in [1usize, c, 4 * c, n] {
                    let s = crs_to_sell_with(&a, c, sigma).unwrap();
                    let ranges = split_even(s.n_chunks(), threads);
                    let mut y = vec![0.0; a.n_rows()];
                    sell_row_inner_on(&s, &x, &mut y, &pool, &ranges);
                    assert_eq!(y, want, "kernel t={threads} C={c} sigma={sigma}");
                }
            }
        }
    }
}

/// One shared pool, ≥3 consecutive plans of different shapes and
/// implementations: later plans must not observe stale `YY` or partition
/// state from earlier ones, and earlier plans must stay correct after
/// later ones ran.
#[test]
fn consecutive_plans_share_one_pool_without_stale_state() {
    let pool = Arc::new(ParPool::new(4));
    let mut rng = Rng::new(7);

    let a1 = Arc::new(random_csr(&mut rng, 64, 64, 0.1));
    let a2 = Arc::new(banded_circulant(&mut rng, 200, &[-2, -1, 0, 1, 2]));
    let a3 = Arc::new(random_csr(&mut rng, 33, 47, 0.2));

    let specs: Vec<(&Arc<Csr>, Implementation)> = vec![
        (&a1, Implementation::CooRowOuter),
        (&a2, Implementation::EllRowOuter),
        (&a3, Implementation::CsrRowPar),
        (&a1, Implementation::EllRowInner),
        (&a2, Implementation::CooColOuter),
    ];

    let mut plans = Vec::new();
    let mut wants = Vec::new();
    let mut xs = Vec::new();
    for (k, (a, imp)) in specs.iter().enumerate() {
        let x: Vec<f64> = (0..a.n_cols()).map(|i| ((i + k) as f64 * 0.29).sin()).collect();
        let mut want = vec![0.0; a.n_rows()];
        a.spmv(&x, &mut want);
        let mut plan = SpmvPlan::build(a, *imp, None, pool.clone()).unwrap();
        let mut y = vec![0.0; a.n_rows()];
        plan.execute(&x, &mut y).unwrap();
        assert_close(&format!("plan {k} ({imp}) fresh"), &y, &want);
        plans.push(plan);
        wants.push(want);
        xs.push(x);
    }
    // Re-run every plan after all the others executed, twice.
    for round in 0..2 {
        for (k, plan) in plans.iter_mut().enumerate() {
            let mut y = vec![0.0; wants[k].len()];
            plan.execute(&xs[k], &mut y).unwrap();
            assert_close(&format!("plan {k} round {round}"), &y, &wants[k]);
        }
    }
}

/// Planner auto-decision: a low-D matrix transforms to the tuning-table
/// candidate; the plan is the operator the solvers iterate with.
#[test]
fn solver_iterates_through_a_cached_plan() {
    let mut rng = Rng::new(13);
    let a = Arc::new(spmv_at::matrixgen::make_spd(&banded_circulant(&mut rng, 120, &[-1, 0, 1])));
    let x_true: Vec<f64> = (0..120).map(|i| ((i + 1) as f64 * 0.37).sin()).collect();
    let mut b = vec![0.0; 120];
    a.spmv(&x_true, &mut b);

    let td = tuning(Implementation::EllRowOuter, Some(3.1));
    let planner = Planner::new(td, MemoryPolicy::unlimited(), Arc::new(ParPool::new(3)));
    let mut plan = planner.plan(&a).unwrap();
    assert_eq!(plan.implementation(), Implementation::EllRowOuter);
    let mut x = vec![0.0; 120];
    let stats = cg(&mut plan, &b, &mut x, &SolverOptions::default()).unwrap();
    assert!(stats.converged, "residual {}", stats.residual);
    let err: f64 = x
        .iter()
        .zip(&x_true)
        .map(|(p, q)| (p - q) * (p - q))
        .sum::<f64>()
        .sqrt();
    assert!(err < 1e-6, "err {err}");
    assert_eq!(plan.calls() as usize, stats.spmv_calls, "plan served every SpMV");
    assert!(plan.transform_seconds() > 0.0, "transformation accounted once");
}

/// `execute_many` batches multiple right-hand sides under one plan.
#[test]
fn execute_many_batches_under_one_plan() {
    let mut rng = Rng::new(17);
    let a = Arc::new(random_csr(&mut rng, 48, 48, 0.15));
    let mut plan =
        SpmvPlan::build(&a, Implementation::CsrRowPar, None, Arc::new(ParPool::new(2))).unwrap();
    let xs: Vec<Vec<f64>> = (0..6)
        .map(|k| (0..48).map(|i| ((i * 5 + k) as f64 * 0.11).cos()).collect())
        .collect();
    let mut ys = vec![vec![0.0; 48]; 6];
    plan.execute_many(&xs, &mut ys).unwrap();
    for (k, (x, y)) in xs.iter().zip(&ys).enumerate() {
        let mut want = vec![0.0; 48];
        a.spmv(x, &mut want);
        assert_close(&format!("rhs {k}"), y, &want);
    }
    assert_eq!(plan.calls(), 6);
}
