//! Property tests for the preconditioner subsystem (ISSUE 8):
//! level-scheduled SpTRSV bitwise identity across thread counts and
//! matrix suites, exact `split_triangular` recomposition, and the
//! SymGS-vs-Jacobi PCG iteration-count ordering the HPCG workload
//! shape depends on.

mod common;

use spmv_at::autotune::adaptive::AdaptiveConfig;
use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::matrixgen::{assemble_from_row_lens, make_spd, rowlen, Placement};
use spmv_at::precond::{
    sptrsv, Jacobi, LevelSchedule, Preconditioner, SymGs, TrsvPar,
};
use spmv_at::rng::Rng;
use spmv_at::solver::{pcg_with, SolverOptions};
use spmv_at::spmv::ParPool;
use spmv_at::Value;
use std::sync::Arc;

/// The three suites the bitwise sweep runs: banded (regular levels),
/// uniform random (irregular DAG), and power-law row lengths (wildly
/// uneven intra-level work — the nnz-balanced partitions' stress case).
/// `make_spd` guarantees the non-zero diagonal the `(D+L)`/`(D+U)`
/// solves divide by.
fn suites() -> Vec<(&'static str, Csr)> {
    let band = make_spd(&common::band(160, 31));
    let rand = make_spd(&common::rand_csr(140, 140, 0.06, 32));
    let power = {
        let mut rng = Rng::new(33);
        let lens = rowlen::synthesize(&mut rng, 150, 1800, 20.0, 150);
        make_spd(&assemble_from_row_lens(&mut rng, 150, &lens, Placement::Uniform))
    };
    vec![("band", band), ("random", rand), ("powerlaw", power)]
}

fn rhs(n: usize) -> Vec<Value> {
    // Exact binary fractions so bitwise comparisons are meaningful.
    (0..n).map(|i| 1.0 + ((i * 7) % 13) as f64 * 0.0625).collect()
}

#[test]
fn level_scheduled_sptrsv_is_bitwise_identical_across_threads_and_suites() {
    for (tag, a) in suites() {
        let n = a.n_rows();
        let tri = a.split_triangular().unwrap();
        let d = Some(tri.diag.as_slice());
        let b = rhs(n);

        let mut want_lo = vec![0.0; n];
        sptrsv::solve_lower_seq(&tri.lower, d, &b, &mut want_lo);
        let mut want_up = vec![0.0; n];
        sptrsv::solve_upper_seq(&tri.upper, d, &b, &mut want_up);
        // Unit-diagonal views run the same sweep without the divide.
        let mut want_unit = vec![0.0; n];
        sptrsv::solve_lower_seq(&tri.lower, None, &b, &mut want_unit);

        for threads in [1usize, 2, 7] {
            let pool = ParPool::new(threads);
            let lo = LevelSchedule::build_lower(&tri.lower, threads);
            let up = LevelSchedule::build_upper(&tri.upper, threads);
            // The schedule covers every row exactly once.
            let mut seen = vec![false; n];
            for &i in lo.rows() {
                assert!(!seen[i], "{tag}: row {i} scheduled twice");
                seen[i] = true;
            }
            assert!(seen.iter().all(|&s| s), "{tag}: row missing from schedule");

            let mut got = vec![0.0; n];
            sptrsv::solve_lower_levels(&tri.lower, d, &lo, &pool, &b, &mut got);
            assert_eq!(got, want_lo, "{tag}/{threads}t: forward SpTRSV not bitwise");

            got.fill(0.0);
            sptrsv::solve_upper_levels(&tri.upper, d, &up, &pool, &b, &mut got);
            assert_eq!(got, want_up, "{tag}/{threads}t: backward SpTRSV not bitwise");

            got.fill(0.0);
            sptrsv::solve_lower_levels(&tri.lower, None, &lo, &pool, &b, &mut got);
            assert_eq!(got, want_unit, "{tag}/{threads}t: unit-diag SpTRSV not bitwise");
        }
    }
}

#[test]
fn symgs_is_bitwise_identical_across_threads_and_suites() {
    let cfg = AdaptiveConfig::default();
    for (tag, a) in suites() {
        let n = a.n_rows();
        let b = rhs(n);
        let mut want = vec![0.0; n];
        let serial_pool = Arc::new(ParPool::new(1));
        let mut serial = SymGs::build(&a, serial_pool, TrsvPar::Never, &cfg).unwrap();
        serial.apply(&b, &mut want);
        for threads in [1usize, 2, 7] {
            let pool = Arc::new(ParPool::new(threads));
            let mut par = SymGs::build(&a, pool, TrsvPar::Always, &cfg).unwrap();
            let mut got = vec![0.0; n];
            par.apply(&b, &mut got);
            assert_eq!(got, want, "{tag}/{threads}t: SymGS not bitwise");
        }
    }
}

#[test]
fn split_triangular_recomposes_exactly_on_the_suites() {
    for (tag, a) in suites() {
        let tri = a.split_triangular().unwrap();
        assert_eq!(tri.recompose(), a, "{tag}: recomposition not exact");
        // Strictness: no diagonal entries inside the triangles.
        for i in 0..a.n_rows() {
            assert!(tri.lower.row(i).all(|(c, _)| (c as usize) < i), "{tag}");
            assert!(tri.upper.row(i).all(|(c, _)| (c as usize) > i), "{tag}");
        }
    }
}

#[test]
fn split_triangular_handles_zero_diagonals_and_empty_rows() {
    // Row 0: stored zero diagonal. Row 1: entirely empty. Row 2: only
    // off-diagonal entries (absent diagonal). Row 3: full row.
    let a = Csr::from_triplets(
        4,
        4,
        &[
            (0, 0, 0.0),
            (0, 2, 2.0),
            (2, 0, 3.0),
            (2, 3, 4.0),
            (3, 0, 5.0),
            (3, 3, 6.0),
        ],
    )
    .unwrap();
    let tri = a.split_triangular().unwrap();
    assert_eq!(tri.diag_stored, vec![true, false, false, true]);
    assert_eq!(tri.diag, vec![0.0, 0.0, 0.0, 6.0]);
    assert!(!tri.diag_nonzero());
    let back = tri.recompose();
    assert_eq!(back, a, "stored-zero diagonal and empty rows must survive");
    assert_eq!(back.nnz(), a.nnz());
    // An all-empty square matrix round-trips too.
    let empty = Csr::from_triplets(6, 6, &[]).unwrap();
    assert_eq!(empty.split_triangular().unwrap().recompose(), empty);
}

/// The badly-scaled SPD suite from the solver tests: an SPD base plus a
/// wildly varying extra diagonal (condition number driven by 10^0..10^6
/// scale spread).
fn badly_scaled(seed: u64, n: usize) -> (Csr, Vec<Value>, Vec<Value>) {
    let mut rng = Rng::new(seed);
    let base = make_spd(&spmv_at::matrixgen::random_csr(&mut rng, n, n, 0.05));
    let mut t = base.to_triplets();
    for i in 0..n {
        let s = 10f64.powi((i % 4) as i32 * 2);
        t.push((i, i, s));
    }
    let a = Csr::from_triplets(n, n, &t).unwrap();
    let x_true: Vec<Value> = (0..n).map(|i| ((i + 1) as f64 * 0.07).sin()).collect();
    let mut b = vec![0.0; n];
    a.spmv(&x_true, &mut b);
    (a, b, x_true)
}

#[test]
fn symgs_pcg_beats_jacobi_pcg_on_the_badly_scaled_suite() {
    let opts = SolverOptions { tol: 1e-10, max_iters: 3000 };
    let cfg = AdaptiveConfig::default();
    for seed in [52u64, 61, 77] {
        let (a, b, x_true) = badly_scaled(seed, 150);
        let n = a.n_rows();

        let mut a_j = a.clone();
        let mut jac = Jacobi::build(&a_j).unwrap();
        let mut x_j = vec![0.0; n];
        let jstats = pcg_with(&mut a_j, &mut jac, &b, &mut x_j, &opts).unwrap();
        assert!(jstats.converged, "seed {seed}: Jacobi-PCG failed to converge");

        let mut a_s = a.clone();
        let pool = Arc::new(ParPool::new(2));
        let mut sym = SymGs::build(&a, pool, TrsvPar::Auto, &cfg).unwrap();
        let mut x_s = vec![0.0; n];
        let sstats = pcg_with(&mut a_s, &mut sym, &b, &mut x_s, &opts).unwrap();
        assert!(sstats.converged, "seed {seed}: SymGS-PCG failed to converge");

        common::assert_close("jacobi-pcg solution", &x_j, &x_true);
        common::assert_close("symgs-pcg solution", &x_s, &x_true);
        assert!(
            sstats.iterations < jstats.iterations,
            "seed {seed}: SymGS-PCG ({}) must beat Jacobi-PCG ({}) iterations",
            sstats.iterations,
            jstats.iterations
        );
        // Both counted their preconditioner work.
        assert_eq!(jstats.precond_calls, jstats.iterations + 1);
        assert_eq!(sstats.precond_calls, sstats.iterations + 1);
        assert!(sstats.precond_setup_seconds > 0.0);
    }
}

#[test]
fn level_stats_feed_the_width_threshold_decision() {
    // The banded suite has wide levels; the width policy must pick
    // LevelPar on a wide pool and Serial on a 1-thread pool.
    let a = make_spd(&common::band(400, 41));
    let tri = a.split_triangular().unwrap();
    let sched = LevelSchedule::build_lower(&tri.lower, 4);
    let stats = sched.stats();
    assert_eq!(stats.rows, 400);
    assert!(stats.levels >= 1);
    assert!(stats.avg_width >= 1.0);
    assert!(stats.max_width >= stats.avg_width as usize);
    assert!(sched.analysis_seconds() >= 0.0);
    let wide_decision = TrsvPar::MinWidthPerThread(1.0).choose(stats, 2);
    let serial_decision = TrsvPar::Auto.choose(stats, 1);
    assert_eq!(serial_decision, spmv_at::precond::TrsvMode::Serial);
    // Banded circulant lower triangles level like a short chain of wide
    // levels, so a tiny width factor on few threads goes parallel.
    if stats.avg_width >= 2.0 {
        assert_eq!(wide_decision, spmv_at::precond::TrsvMode::LevelPar);
    }
}
