//! Concurrent cross-socket split execution — the ISSUE-5 acceptance
//! surface:
//!
//! * the bitwise property: concurrent `execute_split_many` (and the new
//!   single-vector `execute_split`) equals the unsplit tiled SpMM across
//!   splits {1, 2, 3, 5} × pool widths {1, 2, 7} × the split-stable
//!   kernels {CsrRowPar, EllRowInner} × batch sizes k ∈ {1, 4, 17};
//! * overlap: ≥ 2 row blocks demonstrably in flight at once
//!   (`SplitPlan::max_concurrent_blocks`, fed by the `PoolGroup` join
//!   primitive) when splits ≥ 2 and threads ≥ 4;
//! * panic containment: a panicking block neither deadlocks the join nor
//!   poisons the pools for the next call;
//! * the `matrix_passes` regression: split pass counts pin to the
//!   unsplit ⌈k/tile⌉ semantics instead of summing per block;
//! * automatic routing: matrices past `SplitThreshold` serve through a
//!   *cached* `SplitPlan` (observable via `EntryStats`), adaptive mode
//!   composes without double-building, and threshold-off / single-shard
//!   setups reproduce the pre-split serving byte for byte.
//!
//! No test here mutates environment variables; thresholds are set
//! through `CoordinatorConfig::split` (the `SPMV_AT_SPLIT_ROWS` parser
//! has its own unit tests in `coordinator::shards`).

mod common;

use spmv_at::autotune::MemoryPolicy;
use spmv_at::coordinator::{
    Coordinator, CoordinatorConfig, PlanShards, ShardedPlanner, SplitThreshold,
};
use spmv_at::formats::{Csr, FormatKind, SparseMatrix};
use spmv_at::spmv::pool::PoolGroup;
use spmv_at::spmv::Implementation;
use spmv_at::Value;
use std::sync::Arc;

fn planner(shards: usize, threads: usize) -> ShardedPlanner {
    ShardedPlanner::new(
        common::tuning(Implementation::EllRowInner, Some(3.1)),
        MemoryPolicy::unlimited(),
        PlanShards::new(shards, threads),
    )
}

#[test]
fn concurrent_split_is_bitwise_identical_to_unsplit() {
    let matrices: Vec<Csr> = vec![
        common::rand_csr(160, 160, 0.06, 101),
        common::band(128, 102),
    ];
    for threads in [1usize, 2, 7] {
        let sp = planner(3, threads);
        for a in &matrices {
            let a = Arc::new(a.clone());
            let n = a.n_rows();
            for imp in [Implementation::CsrRowPar, Implementation::EllRowInner] {
                let mut full = sp.planner(0).plan_for(&a, imp).unwrap();
                for splits in [1usize, 2, 3, 5] {
                    let mut split = sp.plan_split(&a, imp, splits).unwrap();
                    for k in [1usize, 4, 17] {
                        let tag = format!("t={threads} imp={imp} splits={splits} k={k}");
                        let xs = common::xs_batch(a.n_cols(), k);
                        let mut want = vec![vec![0.0; n]; k];
                        full.execute_many(&xs, &mut want).unwrap();
                        let mut got = vec![vec![0.0; n]; k];
                        sp.execute_split_many(&mut split, &xs, &mut got).unwrap();
                        assert_eq!(got, want, "{tag}: concurrent split must be bitwise");
                        // Stable on reuse of the same cached split plan.
                        sp.execute_split_many(&mut split, &xs, &mut got).unwrap();
                        assert_eq!(got, want, "{tag}: rerun");
                        // The single-vector path agrees with the batch.
                        let mut y1 = vec![0.0; n];
                        sp.execute_split(&mut split, &xs[0], &mut y1).unwrap();
                        assert_eq!(y1, want[0], "{tag}: execute_split");
                    }
                    // split_by_nnz yields at most `splits` blocks; these
                    // near-uniform matrices always get at least 2 when
                    // asked for 2+.
                    assert!(split.parts() <= splits, "splits={splits}");
                    assert!(split.parts() >= splits.min(2), "splits={splits}");
                }
            }
        }
    }
}

#[test]
fn at_least_two_blocks_are_in_flight_concurrently() {
    // The acceptance overlap assertion: splits >= 2, threads >= 4.
    let sp = planner(2, 4);
    let a = Arc::new(common::rand_csr(200, 200, 0.05, 7));
    for splits in [2usize, 3] {
        let mut split = sp.plan_split(&a, Implementation::CsrRowPar, splits).unwrap();
        assert_eq!(split.max_concurrent_blocks(), 0, "fresh plan has not joined yet");
        let xs = common::xs_batch(200, 4);
        let mut ys = vec![vec![0.0; 200]; 4];
        sp.execute_split_many(&mut split, &xs, &mut ys).unwrap();
        assert!(
            split.max_concurrent_blocks() >= 2,
            "splits={splits}: >=2 blocks must be in flight simultaneously, saw {}",
            split.max_concurrent_blocks()
        );
        assert_eq!(split.join_count(), 1);
        // The single-vector path joins through the same group.
        let mut y = vec![0.0; 200];
        sp.execute_split(&mut split, &xs[0], &mut y).unwrap();
        assert_eq!(split.join_count(), 2);
    }
}

#[test]
fn panic_in_one_block_joins_cleanly_and_pools_survive() {
    let sp = planner(2, 2);
    let pools = [sp.shards().pool(0).clone(), sp.shards().pool(1).clone()];
    let group = PoolGroup::new();
    let mut marks = vec![0u32; 2];
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        group.join_all(&pools, &mut marks, |i, m| {
            if i == 1 {
                panic!("injected block failure");
            }
            *m = 1;
        });
    }));
    assert!(err.is_err(), "the block panic must re-raise after the join");
    assert_eq!(marks[0], 1, "the surviving block still completed");

    // The same pools serve a real split correctly afterwards — the join
    // neither deadlocked nor poisoned them.
    let a = Arc::new(common::band(96, 5));
    let xs = common::xs_batch(96, 3);
    let mut want = vec![vec![0.0; 96]; 3];
    let mut full = sp.planner(0).plan_for(&a, Implementation::CsrRowPar).unwrap();
    full.execute_many(&xs, &mut want).unwrap();
    let mut split = sp.plan_split(&a, Implementation::CsrRowPar, 2).unwrap();
    let mut got = vec![vec![0.0; 96]; 3];
    sp.execute_split_many(&mut split, &xs, &mut got).unwrap();
    assert_eq!(got, want, "pools must stay fully usable after a block panic");
}

#[test]
fn split_matrix_passes_pin_to_unsplit_semantics() {
    // Regression (ISSUE 5): SplitPlan::matrix_passes summed the per-block
    // counters, over-counting by a factor of `parts` relative to the
    // unsplit plan's ceil(k/tile) semantics.
    let sp = planner(3, 2);
    let a = Arc::new(common::rand_csr(120, 120, 0.08, 23));
    let k = 7usize;
    let xs = common::xs_batch(120, k);
    let mut ys = vec![vec![0.0; 120]; k];
    for tile in [1usize, 3] {
        let mut full = sp.planner(0).plan_for(&a, Implementation::CsrRowPar).unwrap();
        let mut split = sp.plan_split(&a, Implementation::CsrRowPar, 3).unwrap();
        full.set_batch_tile(tile);
        split.set_batch_tile(tile);
        full.execute_many(&xs, &mut ys).unwrap();
        sp.execute_split_many(&mut split, &xs, &mut ys).unwrap();
        assert_eq!(
            split.matrix_passes(),
            full.matrix_passes(),
            "tile={tile}: split passes must equal the unsplit ceil(k/tile)"
        );
        assert_eq!(split.matrix_passes(), (k as u64).div_ceil(tile as u64));
    }
    // Default (uniform) tile: still the ceil(k/tile) of the plan's own
    // tile, counted once per call — never multiplied by the block count.
    let mut split = sp.plan_split(&a, Implementation::CsrRowPar, 3).unwrap();
    let before = split.matrix_passes();
    sp.execute_split_many(&mut split, &xs, &mut ys).unwrap();
    assert_eq!(
        split.matrix_passes() - before,
        (k as u64).div_ceil(split.batch_tile() as u64)
    );
    let mut y = vec![0.0; 120];
    sp.execute_split(&mut split, &xs[0], &mut y).unwrap();
    assert_eq!(split.matrix_passes() - before, (k as u64).div_ceil(split.batch_tile() as u64) + 1);
}

fn coord(threads: usize, shards: usize, split: SplitThreshold, adaptive: bool) -> Coordinator {
    let mut cfg = CoordinatorConfig::new(common::tuning(Implementation::EllRowInner, Some(3.1)));
    cfg.threads = threads;
    cfg.shards = shards;
    cfg.split = split;
    cfg.adaptive.enabled = adaptive;
    cfg.adaptive.epsilon = 0.0;
    Coordinator::new(cfg)
}

#[test]
fn oversized_matrix_auto_routes_through_a_cached_split_plan() {
    let mut c = coord(2, 2, SplitThreshold::Rows(64), false);
    let a = common::band(128, 31);
    c.register("big", a.clone()).unwrap();
    assert_eq!(c.stats()[0].split_parts, 0, "the split builds lazily, like the transform");

    let x: Vec<Value> = (0..128).map(|i| 1.0 + (i % 9) as f64 * 0.125).collect();
    let want = common::reference(&a, &x);
    let y = c.spmv("big", &x).unwrap();
    assert_eq!(y, want, "split serving must stay bitwise vs csr_seq (EllRowInner order)");
    let s = &c.stats()[0];
    assert_eq!(s.split_parts, 2, "the decided kernel serves through a 2-block split");
    assert_eq!(s.split_calls, 1);
    assert_eq!(s.serving, Implementation::EllRowInner);
    assert_eq!(c.serving_format("big"), Some(FormatKind::Ell));
    assert!(s.extra_bytes > 0, "the split blocks are accounted");
    assert!(s.t_trans > 0.0, "block transforms are accounted once");

    // The split plan is cached: further serving builds nothing new.
    let inits: Vec<u64> = (0..2).map(|i| c.planner().shards().pool(i).init_count()).collect();
    assert_eq!(c.spmv("big", &x).unwrap(), want);
    let xs = common::xs_batch(128, 4);
    let ys = c.spmv_batch("big", &xs).unwrap();
    for (xi, yi) in xs.iter().zip(&ys) {
        assert_eq!(*yi, common::reference(&a, xi), "batched split serving");
    }
    for (i, before) in inits.iter().enumerate() {
        assert_eq!(
            c.planner().shards().pool(i).init_count(),
            *before,
            "pool {i}: cached split must not rebuild on later serves"
        );
    }
    let s = &c.stats()[0];
    assert_eq!(s.split_calls, 6);
    assert_eq!(s.calls, 6);

    // Below the threshold nothing splits.
    c.register("small", common::band(32, 33)).unwrap();
    let xs32: Vec<Value> = vec![1.0; 32];
    c.spmv("small", &xs32).unwrap();
    let small = c.stats().into_iter().find(|s| s.name == "small").unwrap();
    assert_eq!((small.split_parts, small.split_calls), (0, 0));
}

#[test]
fn adaptive_and_split_routing_compose_without_double_building() {
    // Exploration forced on every call: if split serving consulted the
    // explorer it would build a full-matrix shadow plan immediately.
    let mut cfg = CoordinatorConfig::new(common::tuning(Implementation::EllRowInner, Some(3.1)));
    cfg.threads = 2;
    cfg.shards = 2;
    cfg.split = SplitThreshold::Rows(64);
    cfg.adaptive.enabled = true;
    cfg.adaptive.epsilon = 1.0;
    cfg.adaptive.explore_warmup = 0;
    let mut c = Coordinator::new(cfg);
    let a = common::band(128, 41);
    c.register("m", a.clone()).unwrap();
    let x: Vec<Value> = (0..128).map(|i| 1.0 + (i % 7) as f64 * 0.25).collect();
    let want = common::reference(&a, &x);
    assert_eq!(c.spmv("m", &x).unwrap(), want);
    assert_eq!(c.stats()[0].split_parts, 2);

    // Adaptive serving over a split entry never builds the full-size
    // shadow/transformed plans (that would be the double build): the
    // init counters stay flat over sustained traffic.
    let inits: Vec<u64> = (0..2).map(|i| c.planner().shards().pool(i).init_count()).collect();
    for _ in 0..10 {
        assert_eq!(c.spmv("m", &x).unwrap(), want, "bitwise-stable under adaptive");
    }
    for (i, before) in inits.iter().enumerate() {
        assert_eq!(
            c.planner().shards().pool(i).init_count(),
            *before,
            "pool {i}: no shadow or transform build behind split serving"
        );
    }
    let s = &c.stats()[0];
    assert_eq!(s.explored, 0, "split-served entries skip exploration");
    assert_eq!(s.replans, 0);
    assert_eq!(s.split_calls, 11);

    // A forced replan re-decides and rebuilds the split exactly once.
    let s = c.replan("m").unwrap();
    assert_eq!(s.replans, 1);
    assert_eq!(s.split_parts, 2, "the rebuilt split keeps serving");
    let after: Vec<u64> = (0..2).map(|i| c.planner().shards().pool(i).init_count()).collect();
    assert!(
        after.iter().zip(&inits).all(|(a, b)| a > b),
        "the replan rebuilt one block per shard ({inits:?} -> {after:?})"
    );
    assert_eq!(c.spmv("m", &x).unwrap(), want, "bitwise across the replan");
    assert_eq!(
        (0..2).map(|i| c.planner().shards().pool(i).init_count()).collect::<Vec<_>>(),
        after,
        "exactly one rebuild, then cached again"
    );
}

#[test]
fn threshold_off_and_single_shard_reproduce_unsplit_serving() {
    let a = common::band(96, 51);
    let x: Vec<Value> = (0..96).map(|i| 1.0 + (i % 5) as f64 * 0.5).collect();
    let xs = common::xs_batch(96, 3);

    // SPMV_AT_SPLIT_ROWS=0 semantics: identical bytes, no split built.
    let mut on = coord(2, 2, SplitThreshold::Rows(16), false);
    let mut off = coord(2, 2, SplitThreshold::Off, false);
    on.register("m", a.clone()).unwrap();
    off.register("m", a.clone()).unwrap();
    let (y_on, y_off) = (on.spmv("m", &x).unwrap(), off.spmv("m", &x).unwrap());
    assert_eq!(y_on, y_off, "split and unsplit serving must agree byte for byte");
    assert_eq!(on.spmv_batch("m", &xs).unwrap(), off.spmv_batch("m", &xs).unwrap());
    assert_eq!(on.stats()[0].split_parts, 2);
    assert_eq!(off.stats()[0].split_parts, 0, "threshold off = the pre-split path");
    assert_eq!(off.serving_format("m"), Some(FormatKind::Ell), "plain transform still runs");

    // Single-shard planners (the single-socket topology case — shard
    // count defaults to the socket count) never split, whatever the
    // threshold says.
    let mut single = coord(2, 1, SplitThreshold::Rows(1), false);
    single.register("m", a.clone()).unwrap();
    assert_eq!(single.spmv("m", &x).unwrap(), y_off);
    assert_eq!(single.stats()[0].split_parts, 0, "single shard: never split");
}
