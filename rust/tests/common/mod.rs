//! Shared fixtures for the integration-test binaries.
//!
//! Every `rust/tests/*.rs` target is its own crate; before this module
//! existed each of them carried private copies of the same tuning-table
//! helper, matrix-generator suite, deterministic RHS batches and fixture
//! `/sys` topology trees. Declare it from a test file with `mod common;`
//! — each binary compiles its own copy, so only the items it uses are
//! linked (hence the file-wide `dead_code` allow).

#![allow(dead_code)]

use spmv_at::autotune::online::TuningData;
use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::matrixgen::{banded_circulant, random_csr};
use spmv_at::rng::Rng;
use spmv_at::spmv::Implementation;
use spmv_at::Value;
use std::path::PathBuf;
use std::sync::Arc;

/// A minimal tuning table naming `imp` as the transform candidate.
pub fn tuning(imp: Implementation, d_star: Option<f64>) -> TuningData {
    TuningData { backend: "sim:ES2".into(), imp, threads: 1, c: 1.0, d_star }
}

/// The standard small correctness suite: degenerate 1×1, rectangular,
/// larger sparse square, banded, and all-zero matrices (seed 2024 — the
/// shapes the plan/SpMM property tests have always swept).
pub fn small_suite() -> Vec<Arc<Csr>> {
    let mut rng = Rng::new(2024);
    vec![
        Arc::new(random_csr(&mut rng, 1, 1, 1.0)),
        Arc::new(random_csr(&mut rng, 23, 19, 0.25)),
        Arc::new(random_csr(&mut rng, 150, 150, 0.04)),
        Arc::new(banded_circulant(&mut rng, 97, &[-1, 0, 1, 3])),
        Arc::new(Csr::from_triplets(11, 11, &[]).unwrap()),
    ]
}

/// A banded circulant (bands −2..=2) — the adaptive/coordinator tests'
/// well-conditioned ELL-friendly shape.
pub fn band(n: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    banded_circulant(&mut rng, n, &[-2, -1, 0, 1, 2])
}

/// A seeded uniform random CSR.
pub fn rand_csr(n_rows: usize, n_cols: usize, density: f64, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    random_csr(&mut rng, n_rows, n_cols, density)
}

/// `k` deterministic right-hand sides of width `n_cols` (exact binary
/// fractions, so bitwise assertions are meaningful).
pub fn xs_batch(n_cols: usize, k: usize) -> Vec<Vec<Value>> {
    (0..k)
        .map(|j| (0..n_cols).map(|i| 1.0 + ((i * 5 + j * 3) % 11) as f64 * 0.0625).collect())
        .collect()
}

/// Sweep every [`Implementation`] × thread count {1, 2, 7} × partition
/// strategy (the planner's own pick plus each explicit
/// [`PartitionStrategy`][spmv_at::spmv::partition::PartitionStrategy]),
/// building one plan per combination through
/// [`SpmvPlan::build_with`][spmv_at::spmv::SpmvPlan::build_with] — no
/// environment mutation, so parallel test binaries never race a getenv —
/// and handing each to `f` with a diagnostic tag. The differential
/// oracle drives every kernel in the crate through this single sweep.
pub fn for_all_impls<F>(csr: &Arc<Csr>, mut f: F)
where
    F: FnMut(&str, &mut spmv_at::spmv::SpmvPlan),
{
    use spmv_at::spmv::partition::PartitionStrategy;
    use spmv_at::spmv::pool::ParPool;
    use spmv_at::spmv::SpmvPlan;
    for threads in [1usize, 2, 7] {
        let pool = Arc::new(ParPool::new(threads));
        for imp in Implementation::ALL {
            let mut strategies: Vec<Option<PartitionStrategy>> = vec![None];
            strategies.extend(PartitionStrategy::ALL.map(Some));
            for strategy in strategies {
                let tag = format!(
                    "{imp} threads={threads} partition={}",
                    strategy.map_or("auto", PartitionStrategy::name)
                );
                let mut plan =
                    match SpmvPlan::build_with(csr, imp, None, pool.clone(), strategy) {
                        Ok(p) => p,
                        Err(e) => panic!("{tag}: plan build failed: {e}"),
                    };
                f(&tag, &mut plan);
            }
        }
    }
}

/// The sequential CRS reference `y = A·x`.
pub fn reference(a: &Csr, x: &[Value]) -> Vec<Value> {
    let mut y = vec![0.0; a.n_rows()];
    a.spmv(x, &mut y);
    y
}

/// Relative-tolerance comparison for the non-bitwise-stable kernels.
pub fn assert_close(tag: &str, got: &[Value], want: &[Value]) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-9 * (1.0 + w.abs()),
            "{tag}: index {i}: {g} vs {w}"
        );
    }
}

/// Build a fixture `/sys` tree under a unique temp dir; returns its
/// root. `nodes` maps node index → `cpulist` contents; `online` is the
/// optional `devices/system/cpu/online` contents. Remove it with
/// [`remove_sys_fixture`] when done.
pub fn sys_fixture(tag: &str, nodes: &[(usize, &str)], online: Option<&str>) -> PathBuf {
    let root = std::env::temp_dir().join(format!("spmv-at-sys-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    for (idx, cpulist) in nodes {
        let d = root.join(format!("devices/system/node/node{idx}"));
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(d.join("cpulist"), cpulist).unwrap();
    }
    if let Some(online) = online {
        let d = root.join("devices/system/cpu");
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(d.join("online"), online).unwrap();
    } else {
        // The node dir must exist even with zero nodes so read_dir works.
        std::fs::create_dir_all(root.join("devices/system/node")).unwrap();
    }
    root
}

/// Tear down a [`sys_fixture`] tree (best-effort).
pub fn remove_sys_fixture(root: &std::path::Path) {
    let _ = std::fs::remove_dir_all(root);
}
