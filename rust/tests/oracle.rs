//! The differential oracle: every `Implementation`, at every thread
//! count and under every partition strategy, against the sequential CRS
//! reference on an adversarial matrix suite.
//!
//! Inputs and stored values are exact binary fractions, so "equal"
//! means *bitwise* equal wherever the kernel contract promises CRS
//! accumulation order (the CRS family, ELL-Row inner, SELL — the same
//! set the adaptive tests rely on), and every kernel must be bitwise
//! *self*-stable: re-executing a plan, and serving a batch through the
//! tiled SpMM instead of looped single calls, may never change a bit.
//!
//! The suite is chosen to break partitioners, not kernels:
//!
//! * a giant row holding more than half of all non-zeros (no row-aligned
//!   split can balance it — the merge-path motivation);
//! * power-law row lengths (heavy head, long tail);
//! * leading and trailing empty-row runs (boundary drain order);
//! * an all-empty matrix and a single-column matrix (degenerate merge
//!   lists);
//! * explicit stored zeros (padding-confusable entries).

mod common;

use common::{assert_close, for_all_impls, reference, xs_batch};
use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::spmv::partition::{merge_path_split, split_by_nnz};
use spmv_at::spmv::Implementation;
use spmv_at::Value;
use std::sync::Arc;

/// Exact binary fraction, never zero.
fn frac(k: usize) -> Value {
    1.0 + (k % 13) as Value * 0.0625
}

/// One row owns >50% of the non-zeros: 24×40 with row 7 fully dense
/// (40 entries) over 23 single-entry rows.
fn giant_row() -> Csr {
    let mut t: Vec<(usize, usize, Value)> = Vec::new();
    for r in 0..24 {
        if r == 7 {
            for c in 0..40 {
                t.push((r, c, frac(3 * c + 1)));
            }
        } else {
            t.push((r, (r * 5) % 40, frac(r)));
        }
    }
    Csr::from_triplets(24, 40, &t).unwrap()
}

/// Power-law row lengths: row `r` gets `60 / (r + 1)` entries.
fn power_law() -> Csr {
    let mut t: Vec<(usize, usize, Value)> = Vec::new();
    for r in 0..60 {
        for c in 0..(60 / (r + 1)).max(1) {
            t.push((r, c, frac(r * 7 + c)));
        }
    }
    Csr::from_triplets(60, 60, &t).unwrap()
}

/// Rows 0..13 completely empty, data only below them.
fn leading_empties() -> Csr {
    let mut t: Vec<(usize, usize, Value)> = Vec::new();
    for r in 13..40 {
        t.push((r, r % 20, frac(r)));
        t.push((r, (r + 9) % 20, frac(r + 5)));
    }
    Csr::from_triplets(40, 20, &t).unwrap()
}

/// Data only in rows 0..25; rows 25..40 empty (the run the *last* merge
/// chunk must own).
fn trailing_empties() -> Csr {
    let mut t: Vec<(usize, usize, Value)> = Vec::new();
    for r in 0..25 {
        t.push((r, (r * 3) % 20, frac(r)));
    }
    Csr::from_triplets(40, 20, &t).unwrap()
}

/// No entries at all.
fn all_empty() -> Csr {
    Csr::from_triplets(17, 9, &[]).unwrap()
}

/// One column; alternating filled and empty rows.
fn single_column() -> Csr {
    let t: Vec<(usize, usize, Value)> =
        (0..30).step_by(2).map(|r| (r, 0, frac(r))).collect();
    Csr::from_triplets(30, 1, &t).unwrap()
}

/// Explicit stored zeros interleaved with real entries (`from_triplets`
/// keeps them — a kernel that confuses stored zeros with padding would
/// still compute the right values, so the shape also skews row lengths
/// to catch partition miscounts).
fn stored_zeros() -> Csr {
    let mut t: Vec<(usize, usize, Value)> = Vec::new();
    for r in 0..16 {
        t.push((r, r, frac(r)));
        t.push((r, (r + 1) % 16, 0.0));
        if r % 3 == 0 {
            for c in 0..8 {
                t.push((r, (r + 2 + c) % 16, if c % 2 == 0 { 0.0 } else { frac(c) }));
            }
        }
    }
    Csr::from_triplets(16, 16, &t).unwrap()
}

fn adversarial_suite() -> Vec<(&'static str, Csr)> {
    vec![
        ("giant-row", giant_row()),
        ("power-law", power_law()),
        ("leading-empties", leading_empties()),
        ("trailing-empties", trailing_empties()),
        ("all-empty", all_empty()),
        ("single-column", single_column()),
        ("stored-zeros", stored_zeros()),
    ]
}

/// The kernels whose per-row accumulation order equals sequential CRS —
/// where the oracle demands bitwise identity, not closeness (the same
/// contract `rust/tests/adaptive.rs` serves flips under).
fn bitwise_vs_seq(imp: Implementation) -> bool {
    matches!(
        imp,
        Implementation::CsrSeq
            | Implementation::CsrRowPar
            | Implementation::CsrMergePar
            | Implementation::EllRowInner
            | Implementation::SellRowInner
    )
}

#[test]
fn every_kernel_matches_csr_seq_on_adversarial_shapes() {
    for (name, a) in adversarial_suite() {
        let a = Arc::new(a);
        let x = xs_batch(a.n_cols(), 1).remove(0);
        let want = reference(&a, &x);
        for_all_impls(&a, |tag, plan| {
            let mut y = vec![0.0; a.n_rows()];
            plan.execute(&x, &mut y).unwrap();
            if bitwise_vs_seq(plan.implementation()) {
                assert_eq!(y, want, "{name} {tag}: bitwise vs csr_seq");
            } else {
                assert_close(&format!("{name} {tag}"), &y, &want);
            }
            // Rerun stability: the same plan must reproduce itself
            // bitwise — partitions, carries and fixups are deterministic.
            let mut y2 = vec![0.0; a.n_rows()];
            plan.execute(&x, &mut y2).unwrap();
            assert_eq!(y, y2, "{name} {tag}: rerun must be bitwise-stable");
        });
    }
}

#[test]
fn batched_execution_matches_looped_bitwise_on_adversarial_shapes() {
    for (name, a) in adversarial_suite() {
        let a = Arc::new(a);
        let xs = xs_batch(a.n_cols(), 4);
        for_all_impls(&a, |tag, plan| {
            let looped: Vec<Vec<Value>> = xs
                .iter()
                .map(|x| {
                    let mut y = vec![0.0; a.n_rows()];
                    plan.execute(x, &mut y).unwrap();
                    y
                })
                .collect();
            let mut ys = vec![vec![0.0; a.n_rows()]; xs.len()];
            plan.execute_many(&xs, &mut ys).unwrap();
            assert_eq!(ys, looped, "{name} {tag}: tiled SpMM must match looped calls");
        });
    }
}

/// The acceptance criterion behind the whole PR: on the giant-row
/// fixture, merge-path chunks stay within 2× the mean non-zero weight,
/// while the best row-aligned nnz split cannot — the giant row lands
/// whole in one chunk and dwarfs the mean.
#[test]
fn merge_path_balances_the_giant_row_where_row_aligned_splits_cannot() {
    let a = giant_row();
    let k = 7;
    let mp = merge_path_split(&a.row_ptr, k);
    assert_eq!(mp.n_chunks(), k);
    let mean = a.nnz() as f64 / k as f64;
    assert!(
        (mp.max_nnz_weight() as f64) <= 2.0 * mean,
        "merge-path max nnz weight {} must stay within 2x the mean {mean:.2}",
        mp.max_nnz_weight()
    );
    let ranges = split_by_nnz(&a.row_ptr, k);
    let max_row_aligned = ranges
        .iter()
        .map(|r| a.row_ptr[r.end] - a.row_ptr[r.start])
        .max()
        .unwrap();
    assert!(
        (max_row_aligned as f64) > 2.0 * mean,
        "a row-aligned split cannot cut the giant row ({max_row_aligned} vs mean {mean:.2})"
    );
}
