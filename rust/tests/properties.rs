//! Property-based tests (seeded randomised invariants — the environment
//! carries no proptest crate, so `for_seeds` plays its role with explicit
//! deterministic seeds and shrink-friendly failure messages).

use spmv_at::autotune::dmat::RowStats;
use spmv_at::autotune::{MemoryPolicy, Ratios};
use spmv_at::formats::{Csr, FormatKind, SparseMatrix};
use spmv_at::machine::MatrixShape;
use spmv_at::matrixgen::{assemble_from_row_lens, random_csr, rowlen, Placement};
use spmv_at::rng::Rng;
use spmv_at::spmv::partition::{imbalance, split_by_nnz, split_even};
use spmv_at::spmv::pool::ParPool;
use spmv_at::spmv::{Implementation, SpmvPlan};
use spmv_at::transform;
use std::sync::Arc;

/// Run `f` for a batch of deterministic seeds; failures report the seed.
fn for_seeds(n: u64, mut f: impl FnMut(u64, &mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xABCD_0000 + seed);
        f(seed, &mut rng);
    }
}

/// Random matrix with diverse shapes: rectangular, empty rows, varying
/// density.
fn arbitrary_matrix(rng: &mut Rng) -> Csr {
    let n_rows = rng.range(1, 120);
    let n_cols = rng.range(1, 120);
    let density = rng.range_f64(0.0, 0.3);
    random_csr(rng, n_rows, n_cols, density)
}

#[test]
fn prop_every_transform_roundtrips_losslessly() {
    for_seeds(40, |seed, rng| {
        let a = arbitrary_matrix(rng);
        let r1 = transform::coo_to_crs(&transform::crs_to_coo_row(&a));
        assert_eq!(a, r1, "COO-Row roundtrip, seed {seed}");
        let r2 = transform::coo_to_crs(&transform::crs_to_coo_col(&a));
        assert_eq!(a, r2, "COO-Col roundtrip, seed {seed}");
        let r3 = transform::csc_to_crs(&transform::crs_to_ccs(&a));
        assert_eq!(a, r3, "CCS roundtrip, seed {seed}");
        let r4 = transform::ell_to_crs(&transform::crs_to_ell(&a).unwrap());
        assert_eq!(a, r4, "ELL roundtrip, seed {seed}");
    });
}

#[test]
fn prop_transforms_preserve_nnz_and_shape() {
    for_seeds(40, |seed, rng| {
        let a = arbitrary_matrix(rng);
        for kind in FormatKind::ALL {
            let m = transform::transform_to(&a, kind, None).unwrap();
            assert_eq!(m.nnz(), a.nnz(), "{kind} nnz, seed {seed}");
            assert_eq!(m.n_rows(), a.n_rows(), "{kind} rows, seed {seed}");
            assert_eq!(m.n_cols(), a.n_cols(), "{kind} cols, seed {seed}");
        }
    });
}

#[test]
fn prop_all_kernels_agree_with_csr_at_random_thread_counts() {
    for_seeds(25, |seed, rng| {
        let a = Arc::new(arbitrary_matrix(rng));
        let x: Vec<f64> = (0..a.n_cols()).map(|_| rng.range_f64(-2.0, 2.0)).collect();
        let mut want = vec![0.0; a.n_rows()];
        a.spmv(&x, &mut want);
        let threads = rng.range(1, 9);
        let pool = Arc::new(ParPool::new(threads));
        for imp in Implementation::ALL {
            let mut plan = SpmvPlan::build(&a, imp, None, pool.clone()).unwrap();
            let mut y = vec![0.0; a.n_rows()];
            plan.execute(&x, &mut y).unwrap();
            for (i, (g, w)) in y.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-9 * (1.0 + w.abs()),
                    "{imp} row {i}: {g} vs {w}, seed {seed}, threads {threads}"
                );
            }
        }
    });
}

#[test]
fn prop_partitions_cover_without_overlap() {
    for_seeds(50, |seed, rng| {
        let n = rng.range(0, 200);
        let k = rng.range(1, 20);
        // Random row_ptr.
        let mut row_ptr = vec![0usize];
        for _ in 0..n {
            let len = if rng.next_bool(0.2) { rng.range(0, 50) } else { rng.range(0, 5) };
            row_ptr.push(row_ptr.last().unwrap() + len);
        }
        for ranges in [split_even(n, k), split_by_nnz(&row_ptr, k)] {
            let mut pos = 0;
            for r in &ranges {
                assert_eq!(r.start, pos, "gap/overlap, seed {seed}");
                assert!(r.end > r.start, "empty range, seed {seed}");
                pos = r.end;
            }
            assert_eq!(pos, n, "coverage, seed {seed}");
            assert!(ranges.len() <= k, "too many ranges, seed {seed}");
        }
        // nnz balancing never does worse than even splitting (on imbalance).
        if n > 0 && row_ptr[n] > 0 {
            let ie = imbalance(&row_ptr, &split_even(n, k));
            let ib = imbalance(&row_ptr, &split_by_nnz(&row_ptr, k));
            // Greedy quantile placement can lose a little on near-uniform
            // inputs (boundary rounding) but must never be much worse.
            assert!(
                ib <= ie * 1.2 + 1e-9,
                "by_nnz {ib} much worse than even {ie}, seed {seed}"
            );
        }
    });
}

#[test]
fn prop_dmat_invariances() {
    for_seeds(30, |seed, rng| {
        let a = arbitrary_matrix(rng);
        let d = RowStats::of_csr(&a).d_mat();
        assert!(d >= 0.0 && d.is_finite(), "seed {seed}");
        // Column permutation leaves the row-length distribution unchanged.
        let mut perm: Vec<usize> = (0..a.n_cols()).collect();
        rng.shuffle(&mut perm);
        let permuted: Vec<(usize, usize, f64)> = a
            .to_triplets()
            .into_iter()
            .map(|(r, c, v)| (r, perm[c], v))
            .collect();
        let b = Csr::from_triplets(a.n_rows(), a.n_cols(), &permuted).unwrap();
        let d2 = RowStats::of_csr(&b).d_mat();
        assert!((d - d2).abs() < 1e-12, "column permutation changed D_mat, seed {seed}");
        // Scaling values leaves D_mat unchanged (it never reads values).
        let scaled: Vec<(usize, usize, f64)> =
            a.to_triplets().into_iter().map(|(r, c, v)| (r, c, v * 7.5)).collect();
        let c = Csr::from_triplets(a.n_rows(), a.n_cols(), &scaled).unwrap();
        assert_eq!(d, RowStats::of_csr(&c).d_mat(), "seed {seed}");
    });
}

#[test]
fn prop_memory_predictions_match_materialized_formats() {
    for_seeds(25, |seed, rng| {
        let a = arbitrary_matrix(rng);
        let shape = MatrixShape::of(&a);
        for kind in [FormatKind::CooRow, FormatKind::CooCol, FormatKind::Ell] {
            let m = transform::transform_to(&a, kind, None).unwrap();
            let predicted = MemoryPolicy::predicted_bytes(&shape, kind);
            assert_eq!(predicted, m.memory_bytes(), "{kind}, seed {seed}");
        }
    });
}

#[test]
fn prop_ratios_consistency() {
    for_seeds(200, |seed, rng| {
        let t_crs = rng.range_f64(1e-6, 1e-2);
        let t_imp = rng.range_f64(1e-7, 1e-2);
        let t_trans = rng.range_f64(0.0, 1e-1);
        let r = Ratios::from_times(t_crs, t_imp, t_trans);
        // Definitional identities.
        assert!((r.sp - t_crs / t_imp).abs() < 1e-12 * r.sp, "seed {seed}");
        if t_trans > 0.0 {
            assert!((r.r - r.sp / r.tt).abs() <= 1e-9 * r.r.abs(), "seed {seed}");
        }
        // Break-even: at the break-even iteration count, transformed total
        // cost equals the CRS-only cost (within fp tolerance).
        let be = r.break_even_iterations();
        if be.is_finite() && be > 0.0 {
            let iters = be.ceil() as usize + 1;
            let transformed = r.total_cost(iters);
            let baseline = iters as f64;
            assert!(
                transformed <= baseline + 1e-9,
                "past break-even but still losing: {transformed} > {baseline}, seed {seed}"
            );
        }
    });
}

#[test]
fn prop_rowlen_synthesis_hits_sum_exactly() {
    for_seeds(40, |seed, rng| {
        let n = rng.range(1, 3000);
        let mu = rng.range_f64(1.0, 40.0);
        let nnz = ((n as f64 * mu) as usize).min(n * n).max(1);
        let sigma = rng.range_f64(0.0, mu * 4.0);
        let lens = rowlen::synthesize(rng, n, nnz, sigma, n);
        let s = rowlen::stats(&lens);
        assert_eq!(s.sum, nnz, "sum, seed {seed} (n={n}, mu={mu}, sigma={sigma})");
        assert!(s.max <= n, "cap, seed {seed}");
    });
}

#[test]
fn prop_assembled_matrices_are_valid_with_exact_row_lens() {
    for_seeds(30, |seed, rng| {
        let n = rng.range(1, 150);
        let n_cols = rng.range(1, 150);
        let lens: Vec<usize> = (0..n).map(|_| rng.range(0, 12)).collect();
        for placement in [Placement::Banded, Placement::Uniform] {
            let a = assemble_from_row_lens(rng, n_cols, &lens, placement);
            a.validate().expect("valid CSR");
            for (i, &l) in lens.iter().enumerate() {
                assert_eq!(a.row_len(i), l.min(n_cols), "row {i}, seed {seed} {placement:?}");
            }
        }
    });
}

#[test]
fn prop_ell_fill_ratio_bounds() {
    for_seeds(30, |seed, rng| {
        let a = arbitrary_matrix(rng);
        if a.nnz() == 0 {
            return;
        }
        let e = transform::crs_to_ell(&a).unwrap();
        assert!(e.fill_ratio() >= 1.0, "seed {seed}");
        // fill == 1 iff every row has the same length.
        let s = RowStats::of_csr(&a);
        if s.max_row == s.min_row {
            assert!((e.fill_ratio() - 1.0).abs() < 1e-12, "seed {seed}");
        } else {
            assert!(e.fill_ratio() > 1.0, "seed {seed}");
        }
        // Padding accounting is exact.
        assert_eq!(e.padding() + e.nnz(), a.n_rows() * e.bandwidth, "seed {seed}");
    });
}

#[test]
fn prop_coordinator_random_op_sequences_stay_consistent() {
    use spmv_at::autotune::online::TuningData;
    use spmv_at::coordinator::{Coordinator, CoordinatorConfig};
    for_seeds(10, |seed, rng| {
        let tuning = TuningData {
            backend: "t".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(rng.range_f64(0.0, 4.0)),
        };
        let mut c = Coordinator::new(CoordinatorConfig::new(tuning));
        let mut live: Vec<(String, usize, u64)> = Vec::new(); // (name, n_cols, calls)
        for step in 0..40 {
            match rng.range(0, 4) {
                0 => {
                    let name = format!("m{seed}_{step}");
                    let a = arbitrary_matrix(rng);
                    let nc = a.n_cols();
                    c.register(&name, a).unwrap();
                    live.push((name, nc, 0));
                }
                1 if !live.is_empty() => {
                    let k = rng.range(0, live.len());
                    let (name, nc, calls) = &mut live[k];
                    let x = vec![1.0; *nc];
                    c.spmv(name, &x).unwrap();
                    *calls += 1;
                }
                2 if !live.is_empty() => {
                    let k = rng.range(0, live.len());
                    let (name, _, _) = live.remove(k);
                    assert!(c.evict(&name), "seed {seed} step {step}");
                }
                _ => {
                    // Stats must match our book-keeping exactly.
                    let stats = c.stats();
                    assert_eq!(stats.len(), live.len(), "seed {seed} step {step}");
                    for (name, _, calls) in &live {
                        let row = stats.iter().find(|s| &s.name == name).unwrap();
                        assert_eq!(row.calls, *calls, "seed {seed} step {step} {name}");
                    }
                }
            }
        }
    });
}

#[test]
fn prop_spmv_linearity() {
    // SpMV is linear: A(αx + βz) = αAx + βAz — catches padding slots that
    // read uninitialised columns.
    let pool = Arc::new(ParPool::new(2));
    for_seeds(20, |seed, rng| {
        let a = Arc::new(arbitrary_matrix(rng));
        let (nr, nc) = (a.n_rows(), a.n_cols());
        let x: Vec<f64> = (0..nc).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let z: Vec<f64> = (0..nc).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let (alpha, beta) = (rng.range_f64(-2.0, 2.0), rng.range_f64(-2.0, 2.0));
        let combo: Vec<f64> = x.iter().zip(&z).map(|(a, b)| alpha * a + beta * b).collect();
        for imp in [Implementation::EllRowInner, Implementation::CooRowOuter] {
            let mut plan = SpmvPlan::build(&a, imp, None, pool.clone()).unwrap();
            let mut yx = vec![0.0; nr];
            let mut yz = vec![0.0; nr];
            let mut yc = vec![0.0; nr];
            plan.execute(&x, &mut yx).unwrap();
            plan.execute(&z, &mut yz).unwrap();
            plan.execute(&combo, &mut yc).unwrap();
            for i in 0..nr {
                let want = alpha * yx[i] + beta * yz[i];
                assert!(
                    (yc[i] - want).abs() <= 1e-8 * (1.0 + want.abs()),
                    "{imp} linearity row {i}, seed {seed}"
                );
            }
        }
    });
}

#[test]
fn prop_cost_models_are_sane() {
    // Structural invariants of the machine models, fuzzed over shapes:
    // positive times, monotone in nnz, ELL monotone in fill, CRS-par
    // non-increasing in threads.
    use spmv_at::machine::scalar::ScalarMachine;
    use spmv_at::machine::vector::VectorMachine;
    use spmv_at::machine::CostModel;
    let models: [Box<dyn CostModel>; 2] = [
        Box::new(VectorMachine::default()),
        Box::new(ScalarMachine::default()),
    ];
    for_seeds(40, |seed, rng| {
        let n = rng.range(64, 300_000);
        let mu = rng.range_f64(1.0, 80.0);
        let nnz = (n as f64 * mu) as usize;
        let bw = ((mu * rng.range_f64(1.0, 20.0)).ceil() as usize).max(1).min(n);
        let shape = MatrixShape {
            n,
            n_cols: n,
            nnz,
            mu,
            sigma: rng.range_f64(0.0, mu * 3.0),
            bandwidth: bw,
            fill_ratio: (n * bw) as f64 / nnz as f64,
        };
        for m in &models {
            for imp in Implementation::ALL {
                let t = m.spmv_seconds(&shape, imp, 1);
                assert!(t > 0.0 && t.is_finite(), "{} {imp} t={t}, seed {seed}", m.name());
            }
            // More nnz at fixed n must not be faster (CRS baseline).
            let bigger = MatrixShape { nnz: nnz * 2, mu: mu * 2.0, ..shape };
            assert!(
                m.spmv_seconds(&bigger, Implementation::CsrSeq, 1)
                    >= m.spmv_seconds(&shape, Implementation::CsrSeq, 1),
                "{}: CRS not monotone in nnz, seed {seed}",
                m.name()
            );
            // Wider band (same nnz) must not make ELL faster.
            if bw * 2 <= n {
                let wider = MatrixShape {
                    bandwidth: bw * 2,
                    fill_ratio: (n * bw * 2) as f64 / nnz as f64,
                    ..shape
                };
                assert!(
                    m.spmv_seconds(&wider, Implementation::EllRowInner, 1)
                        >= m.spmv_seconds(&shape, Implementation::EllRowInner, 1) * 0.999,
                    "{}: ELL not monotone in fill, seed {seed}",
                    m.name()
                );
            }
            // Threads never hurt the parallel CRS baseline (weak check).
            let t1 = m.spmv_seconds(&shape, Implementation::CsrRowPar, 1);
            let t8 = m.spmv_seconds(&shape, Implementation::CsrRowPar, 8);
            assert!(t8 <= t1 * 1.6, "{}: 8 threads much slower than 1, seed {seed}", m.name());
            // Transform times positive for every non-CRS target.
            for kind in spmv_at::formats::FormatKind::ALL {
                if kind != spmv_at::formats::FormatKind::Csr {
                    let tt = m.transform_seconds(&shape, kind);
                    assert!(tt > 0.0 && tt.is_finite(), "{} {kind}, seed {seed}", m.name());
                }
            }
        }
    });
}
