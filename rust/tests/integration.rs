//! Cross-module integration tests: the full offline→online→serve pipeline
//! glued together the way a downstream application would use it, plus
//! failure-injection cases (memory-policy vetoes, transformation failures,
//! dimension errors crossing the server boundary).

use spmv_at::autotune::atlib::{switches, Durmv};
use spmv_at::autotune::online::TuningData;
use spmv_at::autotune::{decide, run_offline, MemoryPolicy, OfflineConfig};
use spmv_at::coordinator::{Coordinator, CoordinatorConfig, Server, SolverKind};
use spmv_at::formats::{Csr, FormatKind, SparseMatrix};
use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{MeasuredBackend, SimulatedBackend};
use spmv_at::matrixgen::{banded_circulant, generate, make_spd, spec_by_name, table1_specs};
use spmv_at::rng::Rng;
use spmv_at::solver::{bicgstab, cg, gmres, jacobi, SolverOptions};
use spmv_at::spmv::Implementation;

fn small_suite(scale: f64) -> Vec<(String, Csr)> {
    table1_specs()
        .iter()
        .filter(|s| s.no != 3)
        .map(|s| (s.name.to_string(), generate(s, 7, scale)))
        .collect()
}

#[test]
fn offline_to_online_to_serving_full_pipeline() {
    // 1. Offline install on the vector machine.
    let backend = SimulatedBackend::new(VectorMachine::default());
    let offline = run_offline(&backend, &small_suite(0.02), &OfflineConfig::default()).unwrap();
    let d_star = offline.d_star.expect("vector machine must accept matrices");
    assert!(d_star > 1.0, "ES2 D* = {d_star} (paper: 3.10)");

    // 2. Persist + reload the tuning table (the install artifact).
    let dir = std::env::temp_dir().join("spmv_at_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tuning.tsv");
    offline.tuning_data().save(&path).unwrap();
    let tuning = TuningData::load(&path).unwrap();
    assert_eq!(tuning, offline.tuning_data());

    // 3. Serve matrices through a coordinator configured with it.
    let mut cfg = CoordinatorConfig::new(tuning);
    cfg.threads = 2;
    let (_srv, client) = Server::spawn(Coordinator::new(cfg), 8);
    let mut rng = Rng::new(5);
    let band = banded_circulant(&mut rng, 500, &[-1, 0, 1]);
    let mut want = vec![0.0; 500];
    let x: Vec<f64> = (0..500).map(|i| (i as f64 * 0.2).sin()).collect();
    band.spmv(&x, &mut want);
    client.register("band", band).unwrap();
    let y = client.spmv("band", x).unwrap();
    for (g, w) in y.iter().zip(&want) {
        assert!((g - w).abs() < 1e-9);
    }
    // The decision actually transformed (D=0 < D*).
    let rows = client.stats().unwrap();
    assert_ne!(rows[0].serving, Implementation::CsrSeq);
    std::fs::remove_file(&path).ok();
}

#[test]
fn machine_dependence_of_decisions() {
    // The same matrix set produces different D* per machine — the paper's
    // core observation (R depends on the architecture, D_mat does not).
    let suite = small_suite(0.02);
    let cfg = OfflineConfig::default();
    let es2 = run_offline(
        &SimulatedBackend::new(VectorMachine::default()),
        &suite,
        &cfg,
    )
    .unwrap();
    let sr = run_offline(
        &SimulatedBackend::new(ScalarMachine::default()),
        &suite,
        &cfg,
    )
    .unwrap();
    let (d_es2, d_sr) = (es2.d_star.unwrap(), sr.d_star.unwrap());
    assert!(d_es2 > 1.0 && d_sr < 0.5 && d_sr < d_es2);

    // epb2 (D ~= 0.92) transforms under the ES2 table but not under the
    // SR table — the machine-dependent middle of the D range.
    let epb2 = generate(&spec_by_name("epb2").unwrap(), 3, 0.05);
    assert!(decide(&epb2, &es2.tuning_data()).transform);
    assert!(!decide(&epb2, &sr.tuning_data()).transform);
}

#[test]
fn durmv_numbered_switches_agree_with_coordinator() {
    let mut rng = Rng::new(9);
    let a = spmv_at::matrixgen::random_csr(&mut rng, 80, 80, 0.1);
    let x: Vec<f64> = (0..80).map(|i| (i as f64).cos()).collect();
    let mut want = vec![0.0; 80];
    a.spmv(&x, &mut want);

    let tuning = TuningData {
        backend: "t".into(),
        imp: Implementation::EllRowInner,
        threads: 1,
        c: 1.0,
        d_star: Some(10.0),
    };
    // Durmv path.
    let mut h = Durmv::new(a.clone(), tuning.clone(), MemoryPolicy::unlimited(), 2);
    let mut y1 = vec![0.0; 80];
    h.durmv(switches::AUTO, &x, &mut y1).unwrap();
    // Coordinator path.
    let mut c = Coordinator::new(CoordinatorConfig::new(tuning));
    c.register("m", a).unwrap();
    let y2 = c.spmv("m", &x).unwrap();
    for ((a, b), w) in y1.iter().zip(&y2).zip(&want) {
        assert!((a - w).abs() < 1e-9 && (b - w).abs() < 1e-9);
    }
}

#[test]
fn all_solvers_converge_through_at_routed_operator() {
    let mut rng = Rng::new(11);
    let a = make_spd(&banded_circulant(&mut rng, 400, &[-2, -1, 0, 1, 2]));
    let x_true: Vec<f64> = (0..400).map(|i| ((i + 1) as f64 * 0.113).sin()).collect();
    let mut b = vec![0.0; 400];
    a.spmv(&x_true, &mut b);

    let tuning = TuningData {
        backend: "t".into(),
        imp: Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    let opts = SolverOptions { tol: 1e-9, max_iters: 4000 };
    for solver in ["cg", "bicgstab", "gmres", "jacobi"] {
        let mut h = Durmv::new(a.clone(), tuning.clone(), MemoryPolicy::unlimited(), 1);
        let mut x = vec![0.0; 400];
        let stats = match solver {
            "cg" => cg(&mut h, &b, &mut x, &opts).unwrap(),
            "bicgstab" => bicgstab(&mut h, &b, &mut x, &opts).unwrap(),
            "gmres" => gmres(&mut h, &b, &mut x, 30, &opts).unwrap(),
            _ => jacobi(&mut h, &b, &mut x, 1.0, &opts).unwrap(),
        };
        assert!(stats.converged, "{solver} residual {}", stats.residual);
        let err: f64 = x
            .iter()
            .zip(&x_true)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-5, "{solver} err {err}");
        // The AT handle transformed exactly once and served every SpMV.
        assert!(h.transform_seconds > 0.0, "{solver} never transformed");
        assert_eq!(h.calls as usize, stats.spmv_calls, "{solver}");
    }
}

#[test]
fn failure_injection_memory_policy_and_bad_requests() {
    // ELL blow-up matrix with a tight budget: decision must fall back.
    let spec = spec_by_name("torso1").unwrap();
    let a = generate(&spec, 3, 0.01);
    let n = a.n_rows();
    let tuning = TuningData {
        backend: "t".into(),
        imp: Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(100.0), // would always transform
    };
    let mut cfg = CoordinatorConfig::new(tuning);
    cfg.policy = MemoryPolicy::with_budget(1 << 20); // 1 MiB
    let (_srv, client) = Server::spawn(Coordinator::new(cfg), 8);
    client.register("torso1", a).unwrap();
    let y = client.spmv("torso1", vec![1.0; n]).unwrap();
    assert_eq!(y.len(), n);
    let rows = client.stats().unwrap();
    assert_eq!(rows[0].serving, Implementation::CsrSeq, "policy must veto ELL");
    assert_eq!(rows[0].extra_bytes, 0);

    // Bad requests error across the channel without killing the server.
    assert!(client.spmv("torso1", vec![1.0; n + 1]).is_err());
    assert!(client.spmv("ghost", vec![1.0]).is_err());
    assert!(client
        .solve("torso1", vec![1.0; 3], SolverKind::Cg, SolverOptions::default())
        .is_err());
    // Server still alive afterwards.
    assert_eq!(client.stats().unwrap().len(), 1);
}

#[test]
fn measured_backend_offline_phase_runs_end_to_end() {
    // Tiny suite on the host backend: real wallclock, real transforms.
    let suite: Vec<(String, Csr)> = table1_specs()
        .iter()
        .filter(|s| [2u32, 6, 14].contains(&s.no))
        .map(|s| (s.name.to_string(), generate(s, 5, 0.02)))
        .collect();
    let backend = MeasuredBackend::new(0, 3);
    let r = run_offline(&backend, &suite, &OfflineConfig::default()).unwrap();
    assert_eq!(r.samples.len(), 3);
    for s in &r.samples {
        assert!(s.t_crs > 0.0, "{}", s.name);
        assert!(s.ratios.is_some(), "{} excluded unexpectedly", s.name);
    }
}

#[test]
fn mtx_file_to_coordinator_roundtrip() {
    // MatrixMarket in -> registered -> served: the external-data path.
    let mut rng = Rng::new(21);
    let a = spmv_at::matrixgen::random_csr(&mut rng, 40, 40, 0.15);
    let dir = std::env::temp_dir().join("spmv_at_integration_mtx");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("m.mtx");
    spmv_at::io::write_matrix_market_file(&a, &p).unwrap();
    let back = spmv_at::io::read_matrix_market_file(&p).unwrap();
    assert_eq!(a, back);

    let tuning = TuningData {
        backend: "t".into(),
        imp: Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    let mut c = Coordinator::new(CoordinatorConfig::new(tuning));
    c.register("mtx", back).unwrap();
    let x = vec![1.0; 40];
    let mut want = vec![0.0; 40];
    a.spmv(&x, &mut want);
    let y = c.spmv("mtx", &x).unwrap();
    for (g, w) in y.iter().zip(&want) {
        assert!((g - w).abs() < 1e-9);
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn serving_format_tracks_decision_lifecycle() {
    let tuning = TuningData {
        backend: "t".into(),
        imp: Implementation::CooRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(0.5),
    };
    let mut c = Coordinator::new(CoordinatorConfig::new(tuning));
    let mut rng = Rng::new(30);
    // Low-D matrix: transforms to COO-Row per the tuning table.
    let band = banded_circulant(&mut rng, 64, &[0, 1]);
    c.register("low", band).unwrap();
    assert_eq!(c.serving_format("low"), Some(FormatKind::Csr));
    c.spmv("low", &vec![1.0; 64]).unwrap();
    assert_eq!(c.serving_format("low"), Some(FormatKind::CooRow));
    // High-D matrix stays CRS forever.
    let spiky = generate(&spec_by_name("memplus").unwrap(), 1, 0.02);
    let n = spiky.n_rows();
    c.register("high", spiky).unwrap();
    c.spmv("high", &vec![1.0; n]).unwrap();
    assert_eq!(c.serving_format("high"), Some(FormatKind::Csr));
    // Evict and the registry reflects it.
    assert!(c.evict("low"));
    assert_eq!(c.serving_format("low"), None);
}

#[test]
fn break_even_accounting_matches_ratios_module() {
    // Coordinator amortisation must agree with the Ratios::break_even math.
    let mut rng = Rng::new(40);
    let a = banded_circulant(&mut rng, 2000, &[-1, 0, 1, 2, 3]);
    let tuning = TuningData {
        backend: "t".into(),
        imp: Implementation::EllRowInner,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    // This test is about decide-once amortisation accounting: pin the
    // adaptive loop off so a measured re-plan (legitimate under
    // SPMV_AT_ADAPTIVE=1) cannot divert calls from the transformed plan.
    let mut cfg = CoordinatorConfig::new(tuning);
    cfg.adaptive.enabled = false;
    let mut c = Coordinator::new(cfg);
    c.register("m", a).unwrap();
    let x = vec![1.0; 2000];
    for _ in 0..50 {
        c.spmv("m", &x).unwrap();
    }
    let s = &c.stats()[0];
    assert_eq!(s.calls, 50);
    assert_eq!(s.transformed_calls, 50, "all calls after decision use ELL");
    assert!(s.t_trans > 0.0);
}

#[test]
fn batched_spmv_serves_multiple_rhs_under_one_decision() {
    let tuning = TuningData {
        backend: "t".into(),
        imp: Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    let (_srv, client) = Server::spawn(
        Coordinator::new(CoordinatorConfig::new(tuning)),
        8,
    );
    let mut rng = Rng::new(77);
    let a = banded_circulant(&mut rng, 200, &[-1, 0, 1]);
    let reference = a.clone();
    client.register("band", a).unwrap();
    let xs: Vec<Vec<f64>> = (0..5)
        .map(|k| (0..200).map(|i| ((i + k) as f64 * 0.13).sin()).collect())
        .collect();
    let ys = client.spmv_batch("band", xs.clone()).unwrap();
    assert_eq!(ys.len(), 5);
    for (x, y) in xs.iter().zip(&ys) {
        let mut want = vec![0.0; 200];
        reference.spmv(x, &mut want);
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }
    // One transformation served the whole batch.
    let s = &client.stats().unwrap()[0];
    assert_eq!(s.calls, 5);
    assert_eq!(s.transformed_calls, 5);
    assert!(s.t_trans > 0.0);
}
