//! End-to-end tests for the network serving front end: coalescing is
//! bitwise-invisible and observably cheaper, broken clients cannot take
//! the server down, a full ingress queue (or a spent session quota)
//! answers `Busy`, deadlines shed instead of serving stale work, v1
//! clients are served byte-for-byte per the v1 spec, and the decision
//! log fetched over the wire replays to the registry's final state.

mod common;

use spmv_at::coordinator::{decision_log, CoordinatorConfig, DecisionLog, Server};
use spmv_at::net::proto::{self, Message};
use spmv_at::net::{ListenAddr, NetClient, NetConfig, NetServer};
use std::io::Write;
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// An explicit front-end config — tests never read the environment.
fn net_cfg(queue_depth: usize, coalesce_wait: Duration) -> NetConfig {
    NetConfig {
        queue_depth,
        coalesce_wait,
        auth_token: None,
        quota_requests: 0,
        quota_bytes: 0,
        decision_log: None,
    }
}

/// A TCP front end on an ephemeral port over a fresh sharded server,
/// optionally wired to a decision log on the coordinator side. The
/// adaptive loop is off so `matrix_passes` counts serving streams only
/// (exploration would add shadow streams and blur the pass arithmetic).
fn start_with(cfg: NetConfig, log: Option<DecisionLog>) -> NetServer {
    let mut ccfg = CoordinatorConfig::new(common::tuning(
        spmv_at::spmv::Implementation::EllRowOuter,
        Some(3.1),
    ));
    ccfg.threads = 2;
    ccfg.adaptive.enabled = false;
    ccfg.decision_log = log;
    let (server, client) = Server::spawn_sharded(ccfg, 64);
    NetServer::start(server, client, &ListenAddr::Tcp("127.0.0.1:0".into()), cfg)
        .expect("bind an ephemeral port")
}

fn start(cfg: NetConfig) -> NetServer {
    start_with(cfg, None)
}

fn passes_of(c: &mut NetClient, name: &str) -> u64 {
    c.stats()
        .unwrap()
        .into_iter()
        .find(|r| r.name == name)
        .expect("registered matrix has a stats row")
        .matrix_passes
}

/// The acceptance scenario: `k` concurrent single-vector requests are
/// served bitwise-identically to `k` sequential ones, while the matrix
/// is streamed ⌈k/tile⌉-ish times instead of `k`.
#[test]
fn concurrent_requests_coalesce_bitwise_identically_and_stream_less() {
    const K: usize = 8;
    // A generous coalescing window so all K barrier-released requests
    // land in one drain with near-certainty.
    let net = start(net_cfg(64, Duration::from_millis(200)));
    let addr = net.local_addr().clone();

    let a = common::band(96, 7);
    let mut c = NetClient::connect(&addr).unwrap();
    c.register("m", &a).unwrap();
    let xs = common::xs_batch(96, K);

    // Sequential phase: each request waits for its reply, so every drain
    // holds exactly one request — K singleton batches, K matrix passes.
    let before_seq = passes_of(&mut c, "m");
    let seq: Vec<Vec<f64>> = xs.iter().map(|x| c.spmv("m", x.clone()).unwrap()).collect();
    let seq_passes = passes_of(&mut c, "m") - before_seq;
    assert_eq!(seq_passes, K as u64, "sequential requests stream the matrix once each");
    for (x, y) in xs.iter().zip(&seq) {
        assert_eq!(y, &common::reference(&a, x), "served result matches the CRS reference");
    }

    // Concurrent phase: K connections handshake first, then release
    // their requests together.
    let before_conc = passes_of(&mut c, "m");
    let barrier = Arc::new(Barrier::new(K));
    let handles: Vec<_> = xs
        .iter()
        .map(|x| {
            let addr = addr.clone();
            let x = x.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = NetClient::connect(&addr).unwrap();
                barrier.wait();
                c.spmv("m", x).unwrap()
            })
        })
        .collect();
    let conc: Vec<Vec<f64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let conc_passes = passes_of(&mut c, "m") - before_conc;

    assert_eq!(conc, seq, "coalesced serving is bitwise identical to sequential serving");
    assert!(
        conc_passes < seq_passes,
        "coalescing must cut matrix passes: {conc_passes} concurrent vs {seq_passes} sequential"
    );
    let ns = c.net_stats().unwrap();
    assert!(ns.coalesced_batches >= 1, "at least one drain coalesced: {ns:?}");
    assert!(ns.coalesced_requests >= 2, "coalesced drains held ≥ 2 requests: {ns:?}");
    assert!(ns.max_batch >= 2, "a multi-request batch was dispatched: {ns:?}");

    net.shutdown();
}

#[test]
fn malformed_frames_and_abrupt_disconnects_leave_the_server_serving() {
    let net = start(net_cfg(16, Duration::ZERO));
    let addr = net.local_addr().clone();
    let ListenAddr::Tcp(tcp) = addr.clone() else { unreachable!() };

    let mut c = NetClient::connect(&addr).unwrap();
    c.register("id", &spmv_at::formats::Csr::identity(4)).unwrap();

    // A raw connection that handshakes, then misbehaves.
    let mut raw = TcpStream::connect(&tcp).unwrap();
    let hello = Message::Hello { version: proto::VERSION, auth: String::new() };
    proto::write_frame(&mut raw, &proto::encode(1, &hello)).unwrap();
    let (_, ack) = proto::decode(&proto::read_frame(&mut raw).unwrap().unwrap()).unwrap();
    assert_eq!(
        ack,
        Message::HelloAck {
            version: proto::VERSION,
            min: proto::MIN_VERSION,
            max: proto::VERSION
        }
    );

    // Unknown opcode: Error reply with the right code, session survives.
    proto::write_frame(&mut raw, &[0x55, 9, 0, 0, 0]).unwrap();
    let (id, reply) = proto::decode(&proto::read_frame(&mut raw).unwrap().unwrap()).unwrap();
    assert_eq!(id, 9, "the request id is echoed even on undecodable frames");
    assert!(matches!(reply, Message::Error { code, .. } if code == proto::ERR_UNKNOWN_OPCODE));

    // Truncated body of a known opcode: malformed, session still survives.
    proto::write_frame(&mut raw, &[proto::OP_SPMV, 2, 0, 0, 0, 200]).unwrap();
    let (_, reply) = proto::decode(&proto::read_frame(&mut raw).unwrap().unwrap()).unwrap();
    assert!(matches!(reply, Message::Error { code, .. } if code == proto::ERR_MALFORMED));

    // The same session still serves real requests after both errors.
    proto::write_frame(&mut raw, &proto::encode(3, &Message::Stats)).unwrap();
    let (_, reply) = proto::decode(&proto::read_frame(&mut raw).unwrap().unwrap()).unwrap();
    assert!(matches!(reply, Message::StatsRows { .. }));

    // Abrupt mid-frame disconnect: write half a frame and vanish.
    let mut half = TcpStream::connect(&tcp).unwrap();
    proto::write_frame(&mut half, &proto::encode(1, &hello)).unwrap();
    let _ = proto::read_frame(&mut half).unwrap().unwrap();
    half.write_all(&[200, 0, 0, 0, 1, 2, 3]).unwrap(); // promises 200 bytes, sends 3
    drop(half);

    // A pre-handshake request instead of Hello: rejected, connection closed.
    let mut rude = TcpStream::connect(&tcp).unwrap();
    proto::write_frame(&mut rude, &proto::encode(1, &Message::Stats)).unwrap();
    let (_, reply) = proto::decode(&proto::read_frame(&mut rude).unwrap().unwrap()).unwrap();
    assert!(matches!(reply, Message::Error { code, .. } if code == proto::ERR_MALFORMED));
    assert!(proto::read_frame(&mut rude).unwrap().is_none(), "server closes after a bad handshake");

    // After all of that, fresh connections serve normally.
    let mut c2 = NetClient::connect(&addr).unwrap();
    assert_eq!(c2.spmv("id", vec![1.0, 2.0, 3.0, 4.0]).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);

    net.shutdown();
}

#[test]
fn oversized_length_prefix_hard_closes_only_that_session() {
    let net = start(net_cfg(16, Duration::ZERO));
    let addr = net.local_addr().clone();
    let ListenAddr::Tcp(tcp) = addr.clone() else { unreachable!() };

    let mut c = NetClient::connect(&addr).unwrap();
    c.register("id", &spmv_at::formats::Csr::identity(4)).unwrap();

    // A handshaken session that promises a 100 MiB frame — past
    // MAX_FRAME. Unlike a merely malformed body (delimited by its length
    // prefix, answered with an Error), an oversized prefix leaves the
    // stream unframed: any reply would interleave with unread request
    // bytes. The server must hard-close without replying.
    let mut big = TcpStream::connect(&tcp).unwrap();
    let hello = Message::Hello { version: proto::VERSION, auth: String::new() };
    proto::write_frame(&mut big, &proto::encode(1, &hello)).unwrap();
    let _ = proto::read_frame(&mut big).unwrap().unwrap();
    big.write_all(&(100u32 * 1024 * 1024).to_le_bytes()).unwrap();
    assert!(
        proto::read_frame(&mut big).unwrap().is_none(),
        "hard close with no reply: the stream after an oversized prefix is unframed"
    );

    // Other sessions are untouched: the established client still serves,
    // and so does a fresh one.
    let x = vec![1.0, 2.0, 3.0, 4.0];
    assert_eq!(c.spmv("id", x.clone()).unwrap(), x);
    let mut c2 = NetClient::connect(&addr).unwrap();
    assert_eq!(c2.spmv("id", x.clone()).unwrap(), x);

    net.shutdown();
}

#[test]
fn full_ingress_queue_answers_busy_and_recovers() {
    // Depth-1 queue and a long drain wait: the first request is consumed
    // by the sleeping coalescer, the second fills the queue slot, the
    // third must be refused.
    let net = start(net_cfg(1, Duration::from_millis(500)));
    let addr = net.local_addr().clone();

    let mut c = NetClient::connect(&addr).unwrap();
    c.register("id", &spmv_at::formats::Csr::identity(3)).unwrap();
    let x = vec![1.0, 2.0, 3.0];

    let spawn_spmv = |x: Vec<f64>| {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = NetClient::connect(&addr).unwrap();
            c.spmv("id", x)
        })
    };
    let t1 = spawn_spmv(x.clone());
    std::thread::sleep(Duration::from_millis(150)); // coalescer takes it, starts its wait
    let t2 = spawn_spmv(x.clone());
    std::thread::sleep(Duration::from_millis(100)); // t2 occupies the single queue slot

    let err = c.spmv("id", x.clone()).expect_err("third concurrent request is refused");
    assert!(err.to_string().contains("busy"), "busy reply surfaces as such: {err}");

    // The two admitted requests complete correctly...
    assert_eq!(t1.join().unwrap().unwrap(), x);
    assert_eq!(t2.join().unwrap().unwrap(), x);
    // ...the reject was counted, and the same connection serves again.
    assert!(c.net_stats().unwrap().admission_rejects >= 1);
    assert_eq!(c.spmv("id", x.clone()).unwrap(), x);

    net.shutdown();
}

#[test]
fn expired_deadlines_are_shed_without_executing_the_batch() {
    // A 60 ms coalesce window: a 1 µs deadline is long expired by the
    // time the coalescer drains, deterministically.
    let net = start(net_cfg(16, Duration::from_millis(60)));
    let addr = net.local_addr().clone();
    let mut c = NetClient::connect_with(&addr, proto::VERSION, None).unwrap();
    c.register("id", &spmv_at::formats::Csr::identity(3)).unwrap();
    let x = vec![1.0, 2.0, 3.0];

    let before = passes_of(&mut c, "id");
    let err = c.spmv_deadline("id", x.clone(), 1).expect_err("expired deadline must shed");
    assert!(err.to_string().contains("deadline exceeded"), "{err}");
    let ns = c.net_stats().unwrap();
    assert_eq!(ns.deadline_sheds, 1, "the shed was counted: {ns:?}");
    assert_eq!(ns.requests, 0, "the shed request was never served: {ns:?}");
    assert_eq!(ns.batches, 0, "the coalescer executed no batch for it: {ns:?}");
    assert_eq!(passes_of(&mut c, "id"), before, "the matrix was never streamed");

    // The same session still serves live requests, and an ample deadline
    // passes the drain-time check.
    assert_eq!(c.spmv("id", x.clone()).unwrap(), x);
    assert_eq!(c.spmv_deadline("id", x.clone(), 60_000_000).unwrap(), x);
    let ns = c.net_stats().unwrap();
    assert_eq!((ns.requests, ns.deadline_sheds), (2, 1), "{ns:?}");

    net.shutdown();
}

#[test]
fn session_quotas_answer_busy_and_reset_on_reconnect() {
    // Request quota: three requests per session, then Busy for everything.
    let net = start(NetConfig { quota_requests: 3, ..net_cfg(16, Duration::ZERO) });
    let addr = net.local_addr().clone();
    let x = vec![1.0, 2.0, 3.0];

    let mut c = NetClient::connect(&addr).unwrap();
    c.register("id", &spmv_at::formats::Csr::identity(3)).unwrap(); // 1
    assert_eq!(c.spmv("id", x.clone()).unwrap(), x); // 2
    assert_eq!(c.spmv("id", x.clone()).unwrap(), x); // 3
    let err = c.spmv("id", x.clone()).expect_err("budget spent");
    assert!(err.to_string().contains("busy"), "{err}");
    // Once spent, every request on the session is refused — not just SpMV.
    assert!(c.stats().is_err(), "a spent session refuses everything");

    // The budget is session identity: a reconnect starts fresh.
    let mut c2 = NetClient::connect(&addr).unwrap();
    assert_eq!(c2.spmv("id", x.clone()).unwrap(), x);
    net.shutdown();

    // Byte quota: some serving prefix fits in the budget, then Busy.
    let net = start(NetConfig { quota_bytes: 100, ..net_cfg(16, Duration::ZERO) });
    let addr = net.local_addr().clone();
    let mut reg = NetClient::connect(&addr).unwrap();
    reg.register("id", &spmv_at::formats::Csr::identity(3)).unwrap();
    let mut q = NetClient::connect(&addr).unwrap();
    let mut served = 0;
    let err = loop {
        match q.spmv("id", x.clone()) {
            Ok(y) => {
                assert_eq!(y, x);
                served += 1;
                assert!(served < 10, "the byte budget never bit");
            }
            Err(e) => break e,
        }
    };
    assert!(err.to_string().contains("busy"), "{err}");
    assert!(served >= 1, "at least one request fit the byte budget");
    // The register session spent its own budget separately; a fresh
    // session serves again.
    let mut q2 = NetClient::connect(&addr).unwrap();
    assert_eq!(q2.spmv("id", x.clone()).unwrap(), x);
    net.shutdown();
}

#[test]
fn auth_tokens_gate_sessions_and_refuse_v1() {
    let net =
        start(NetConfig { auth_token: Some("sesame".into()), ..net_cfg(16, Duration::ZERO) });
    let addr = net.local_addr().clone();

    // The right token serves normally.
    let mut ok = NetClient::connect_with(&addr, proto::VERSION, Some("sesame".into())).unwrap();
    ok.register("id", &spmv_at::formats::Csr::identity(2)).unwrap();
    assert_eq!(ok.spmv("id", vec![5.0, 6.0]).unwrap(), vec![5.0, 6.0]);

    // Wrong or missing tokens are refused with the unauthorized code.
    let err = NetClient::connect_with(&addr, proto::VERSION, Some("open".into()))
        .expect_err("wrong token refused")
        .to_string();
    assert!(err.contains(&format!("error {}", proto::ERR_UNAUTHORIZED)), "{err}");
    assert!(NetClient::connect_with(&addr, proto::VERSION, None).is_err());

    // A v1 Hello cannot carry a token, so a token-requiring server
    // refuses v1 clients outright.
    let err = NetClient::connect_with(&addr, 1, Some("sesame".into()))
        .expect_err("v1 refused on an auth-requiring server")
        .to_string();
    assert!(err.contains("v1"), "{err}");

    // The refusals did not poison the listener.
    assert_eq!(ok.spmv("id", vec![1.0, 2.0]).unwrap(), vec![1.0, 2.0]);
    net.shutdown();
}

/// The v1-compat acceptance scenario, with every byte written and
/// checked by hand against the v1 spec: handshake, Spmv, NetStats, quit.
#[test]
fn a_v1_client_is_served_byte_for_byte_per_the_v1_spec() {
    let net = start(net_cfg(16, Duration::ZERO));
    let addr = net.local_addr().clone();
    let ListenAddr::Tcp(tcp) = addr.clone() else { unreachable!() };

    // Register through a v2 session; the v1 client serves against it.
    let mut reg = NetClient::connect_with(&addr, proto::VERSION, None).unwrap();
    reg.register("id", &spmv_at::formats::Csr::identity(3)).unwrap();

    let mut raw = TcpStream::connect(&tcp).unwrap();
    // v1 Hello: opcode, id 1, magic "SPAT", version 1 — no auth field.
    let mut hello = vec![proto::OP_HELLO, 1, 0, 0, 0];
    hello.extend_from_slice(&proto::MAGIC);
    hello.extend_from_slice(&[1, 0]);
    proto::write_frame(&mut raw, &hello).unwrap();
    // v1 HelloAck: exactly opcode + id + u16 version, no window bytes.
    let ack = proto::read_frame(&mut raw).unwrap().unwrap();
    assert_eq!(ack, [proto::OP_HELLO_ACK, 1, 0, 0, 0, 1, 0]);

    // v1 Spmv "id", x = [1, 2, 3]: no deadline bytes in the body.
    let mut spmv = vec![proto::OP_SPMV, 2, 0, 0, 0, 2, 0, b'i', b'd', 3, 0, 0, 0];
    for v in [1.0f64, 2.0, 3.0] {
        spmv.extend_from_slice(&v.to_le_bytes());
    }
    proto::write_frame(&mut raw, &spmv).unwrap();
    // v1 Vector reply: opcode, echoed id, count, three f64 — nothing else.
    let reply = proto::read_frame(&mut raw).unwrap().unwrap();
    let mut want = vec![proto::OP_VECTOR, 2, 0, 0, 0, 3, 0, 0, 0];
    for v in [1.0f64, 2.0, 3.0] {
        want.extend_from_slice(&v.to_le_bytes());
    }
    assert_eq!(reply, want, "the identity serve echoes x, in the v1 layout");

    // v1 NetStats reply: exactly the eight v1 counters (69 payload
    // bytes) — no deadline_sheds on the v1 wire.
    proto::write_frame(&mut raw, &[proto::OP_NET_STATS, 3, 0, 0, 0]).unwrap();
    let reply = proto::read_frame(&mut raw).unwrap().unwrap();
    assert_eq!(reply.len(), 5 + 8 * 8, "v1 NetStatsReply payload size");
    assert_eq!(reply[..5], [proto::OP_NET_STATS_REPLY, 3, 0, 0, 0]);

    // Quit is a clean close; the server keeps serving other sessions.
    drop(raw);
    let x = vec![1.0, 2.0, 3.0];
    assert_eq!(reg.spmv("id", x.clone()).unwrap(), x);
    net.shutdown();
}

#[test]
fn the_whole_client_api_works_over_an_explicit_v1_session() {
    let net = start(net_cfg(16, Duration::ZERO));
    let addr = net.local_addr().clone();
    let mut c = NetClient::connect_with(&addr, 1, None).unwrap();
    assert_eq!(c.version(), 1);

    let a = common::band(32, 11);
    let row = c.register("m", &a).unwrap();
    assert_eq!(row.n, 32);
    let xs = common::xs_batch(32, 3);
    for x in &xs {
        assert_eq!(c.spmv("m", x.clone()).unwrap(), common::reference(&a, x));
    }
    assert_eq!(c.spmv_batch("m", xs.clone()).unwrap().len(), 3);
    assert_eq!(c.stats().unwrap().len(), 1);
    let ns = c.net_stats().unwrap();
    assert_eq!(ns.deadline_sheds, 0, "always 0 as decoded from the v1 wire");
    c.replan("m").unwrap();
    assert!(c.evict("m").unwrap());
    net.shutdown();
}

/// The decision-log acceptance scenario: register, serve, and replan
/// over the wire; fetch the log over the wire; replaying it must
/// reproduce the final serving decision (kernel + partition + split
/// state) of every matrix in the registry.
#[test]
fn the_decision_log_replays_to_the_final_serving_decision_for_every_matrix() {
    let log = DecisionLog::in_memory();
    let net = start_with(
        NetConfig { decision_log: Some(log.clone()), ..net_cfg(32, Duration::ZERO) },
        Some(log),
    );
    let addr = net.local_addr().clone();
    let mut c = NetClient::connect_with(&addr, proto::VERSION, None).unwrap();

    // A transformable band, a degenerate identity, and a forced replan.
    let band = common::band(96, 7);
    c.register("band", &band).unwrap();
    c.register("id", &spmv_at::formats::Csr::identity(16)).unwrap();
    for x in common::xs_batch(96, 3) {
        assert_eq!(c.spmv("band", x.clone()).unwrap(), common::reference(&band, &x));
    }
    c.spmv("id", vec![1.0; 16]).unwrap();
    c.replan("id").unwrap();

    // The log travels the wire...
    let lines = c.decision_log().unwrap();
    assert!(lines.iter().any(|l| l.contains("\"event\":\"register\"")), "{lines:?}");
    assert!(lines.iter().any(|l| l.contains("\"event\":\"transform\"")), "{lines:?}");
    assert!(lines.iter().any(|l| l.contains("\"event\":\"replan\"")), "{lines:?}");

    // ...and replays, by the last-record-per-matrix fold, to exactly the
    // serving state the registry ended in.
    let replayed = decision_log::replay(lines.iter().map(String::as_str));
    drop(c);
    let coords = net.shutdown();
    let mut rows = 0;
    for coord in &coords {
        for s in coord.stats() {
            let r = replayed.get(&s.name).expect("every matrix has a final decision");
            assert_eq!(r.kernel, s.serving.name(), "{}: replayed kernel", s.name);
            assert_eq!(r.partition, s.partition, "{}: replayed partition", s.name);
            assert_eq!(r.split_parts as usize, s.split_parts, "{}: replayed split state", s.name);
            assert!(!r.split_vetoed, "{}: no split veto happened", s.name);
            rows += 1;
        }
    }
    assert_eq!(rows, 2, "both matrices ended in the registry");
}
