//! End-to-end tests for the network serving front end: coalescing is
//! bitwise-invisible and observably cheaper, broken clients cannot take
//! the server down, and a full ingress queue answers `Busy`.

mod common;

use spmv_at::coordinator::{CoordinatorConfig, Server};
use spmv_at::net::proto::{self, Message};
use spmv_at::net::{ListenAddr, NetClient, NetConfig, NetServer};
use std::io::Write;
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// A TCP front end on an ephemeral port over a fresh sharded server. The
/// adaptive loop is off so `matrix_passes` counts serving streams only
/// (exploration would add shadow streams and blur the pass arithmetic).
fn start(cfg: NetConfig) -> NetServer {
    let mut ccfg = CoordinatorConfig::new(common::tuning(
        spmv_at::spmv::Implementation::EllRowOuter,
        Some(3.1),
    ));
    ccfg.threads = 2;
    ccfg.adaptive.enabled = false;
    let (server, client) = Server::spawn_sharded(ccfg, 64);
    NetServer::start(server, client, &ListenAddr::Tcp("127.0.0.1:0".into()), cfg)
        .expect("bind an ephemeral port")
}

fn passes_of(c: &mut NetClient, name: &str) -> u64 {
    c.stats()
        .unwrap()
        .into_iter()
        .find(|r| r.name == name)
        .expect("registered matrix has a stats row")
        .matrix_passes
}

/// The acceptance scenario: `k` concurrent single-vector requests are
/// served bitwise-identically to `k` sequential ones, while the matrix
/// is streamed ⌈k/tile⌉-ish times instead of `k`.
#[test]
fn concurrent_requests_coalesce_bitwise_identically_and_stream_less() {
    const K: usize = 8;
    // A generous coalescing window so all K barrier-released requests
    // land in one drain with near-certainty.
    let net = start(NetConfig { queue_depth: 64, coalesce_wait: Duration::from_millis(200) });
    let addr = net.local_addr().clone();

    let a = common::band(96, 7);
    let mut c = NetClient::connect(&addr).unwrap();
    c.register("m", &a).unwrap();
    let xs = common::xs_batch(96, K);

    // Sequential phase: each request waits for its reply, so every drain
    // holds exactly one request — K singleton batches, K matrix passes.
    let before_seq = passes_of(&mut c, "m");
    let seq: Vec<Vec<f64>> = xs.iter().map(|x| c.spmv("m", x.clone()).unwrap()).collect();
    let seq_passes = passes_of(&mut c, "m") - before_seq;
    assert_eq!(seq_passes, K as u64, "sequential requests stream the matrix once each");
    for (x, y) in xs.iter().zip(&seq) {
        assert_eq!(y, &common::reference(&a, x), "served result matches the CRS reference");
    }

    // Concurrent phase: K connections handshake first, then release
    // their requests together.
    let before_conc = passes_of(&mut c, "m");
    let barrier = Arc::new(Barrier::new(K));
    let handles: Vec<_> = xs
        .iter()
        .map(|x| {
            let addr = addr.clone();
            let x = x.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = NetClient::connect(&addr).unwrap();
                barrier.wait();
                c.spmv("m", x).unwrap()
            })
        })
        .collect();
    let conc: Vec<Vec<f64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let conc_passes = passes_of(&mut c, "m") - before_conc;

    assert_eq!(conc, seq, "coalesced serving is bitwise identical to sequential serving");
    assert!(
        conc_passes < seq_passes,
        "coalescing must cut matrix passes: {conc_passes} concurrent vs {seq_passes} sequential"
    );
    let ns = c.net_stats().unwrap();
    assert!(ns.coalesced_batches >= 1, "at least one drain coalesced: {ns:?}");
    assert!(ns.coalesced_requests >= 2, "coalesced drains held ≥ 2 requests: {ns:?}");
    assert!(ns.max_batch >= 2, "a multi-request batch was dispatched: {ns:?}");

    net.shutdown();
}

#[test]
fn malformed_frames_and_abrupt_disconnects_leave_the_server_serving() {
    let net = start(NetConfig { queue_depth: 16, coalesce_wait: Duration::ZERO });
    let addr = net.local_addr().clone();
    let ListenAddr::Tcp(tcp) = addr.clone() else { unreachable!() };

    let mut c = NetClient::connect(&addr).unwrap();
    c.register("id", &spmv_at::formats::Csr::identity(4)).unwrap();

    // A raw connection that handshakes, then misbehaves.
    let mut raw = TcpStream::connect(&tcp).unwrap();
    proto::write_frame(&mut raw, &proto::encode(1, &Message::Hello { version: proto::VERSION }))
        .unwrap();
    let (_, ack) = proto::decode(&proto::read_frame(&mut raw).unwrap().unwrap()).unwrap();
    assert_eq!(ack, Message::HelloAck { version: proto::VERSION });

    // Unknown opcode: Error reply with the right code, session survives.
    proto::write_frame(&mut raw, &[0x55, 9, 0, 0, 0]).unwrap();
    let (id, reply) = proto::decode(&proto::read_frame(&mut raw).unwrap().unwrap()).unwrap();
    assert_eq!(id, 9, "the request id is echoed even on undecodable frames");
    assert!(matches!(reply, Message::Error { code, .. } if code == proto::ERR_UNKNOWN_OPCODE));

    // Truncated body of a known opcode: malformed, session still survives.
    proto::write_frame(&mut raw, &[proto::OP_SPMV, 2, 0, 0, 0, 200]).unwrap();
    let (_, reply) = proto::decode(&proto::read_frame(&mut raw).unwrap().unwrap()).unwrap();
    assert!(matches!(reply, Message::Error { code, .. } if code == proto::ERR_MALFORMED));

    // The same session still serves real requests after both errors.
    proto::write_frame(&mut raw, &proto::encode(3, &Message::Stats)).unwrap();
    let (_, reply) = proto::decode(&proto::read_frame(&mut raw).unwrap().unwrap()).unwrap();
    assert!(matches!(reply, Message::StatsRows { .. }));

    // Abrupt mid-frame disconnect: write half a frame and vanish.
    let mut half = TcpStream::connect(&tcp).unwrap();
    proto::write_frame(&mut half, &proto::encode(1, &Message::Hello { version: proto::VERSION }))
        .unwrap();
    let _ = proto::read_frame(&mut half).unwrap().unwrap();
    half.write_all(&[200, 0, 0, 0, 1, 2, 3]).unwrap(); // promises 200 bytes, sends 3
    drop(half);

    // A pre-handshake request instead of Hello: rejected, connection closed.
    let mut rude = TcpStream::connect(&tcp).unwrap();
    proto::write_frame(&mut rude, &proto::encode(1, &Message::Stats)).unwrap();
    let (_, reply) = proto::decode(&proto::read_frame(&mut rude).unwrap().unwrap()).unwrap();
    assert!(matches!(reply, Message::Error { code, .. } if code == proto::ERR_MALFORMED));
    assert!(proto::read_frame(&mut rude).unwrap().is_none(), "server closes after a bad handshake");

    // After all of that, fresh connections serve normally.
    let mut c2 = NetClient::connect(&addr).unwrap();
    assert_eq!(c2.spmv("id", vec![1.0, 2.0, 3.0, 4.0]).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);

    net.shutdown();
}

#[test]
fn full_ingress_queue_answers_busy_and_recovers() {
    // Depth-1 queue and a long drain wait: the first request is consumed
    // by the sleeping coalescer, the second fills the queue slot, the
    // third must be refused.
    let net = start(NetConfig { queue_depth: 1, coalesce_wait: Duration::from_millis(500) });
    let addr = net.local_addr().clone();

    let mut c = NetClient::connect(&addr).unwrap();
    c.register("id", &spmv_at::formats::Csr::identity(3)).unwrap();
    let x = vec![1.0, 2.0, 3.0];

    let spawn_spmv = |x: Vec<f64>| {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = NetClient::connect(&addr).unwrap();
            c.spmv("id", x)
        })
    };
    let t1 = spawn_spmv(x.clone());
    std::thread::sleep(Duration::from_millis(150)); // coalescer takes it, starts its wait
    let t2 = spawn_spmv(x.clone());
    std::thread::sleep(Duration::from_millis(100)); // t2 occupies the single queue slot

    let err = c.spmv("id", x.clone()).expect_err("third concurrent request is refused");
    assert!(err.to_string().contains("busy"), "busy reply surfaces as such: {err}");

    // The two admitted requests complete correctly...
    assert_eq!(t1.join().unwrap().unwrap(), x);
    assert_eq!(t2.join().unwrap().unwrap(), x);
    // ...the reject was counted, and the same connection serves again.
    assert!(c.net_stats().unwrap().admission_rejects >= 1);
    assert_eq!(c.spmv("id", x.clone()).unwrap(), x);

    net.shutdown();
}
