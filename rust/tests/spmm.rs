//! Blocked SpMM + sharded serving tests: tiled `execute_many` must be
//! bitwise-identical to looped single-RHS `execute` for every
//! implementation, thread count and tile width (the tile is a pure
//! blocking transformation — it may never change a result), performing
//! exactly ⌈k/tile⌉ passes over the matrix; and shard routing must place
//! different matrices on distinct pools that serve concurrently.

mod common;

use common::{small_suite as cases, tuning};
use spmv_at::coordinator::{shards, CoordinatorConfig, Server};
use spmv_at::formats::SparseMatrix;
use spmv_at::matrixgen::{banded_circulant, random_csr};
use spmv_at::rng::Rng;
use spmv_at::spmv::pool::ParPool;
use spmv_at::spmv::{Implementation, SpmvPlan};
use std::sync::Arc;

/// The headline SpMM property: for every implementation × pool width
/// {1, 2, 7} × tile width {1, 3, k}, `execute_many` over a batch of k
/// right-hand sides is **bitwise** identical to k individual `execute`
/// calls on the same plan, and streams the matrix exactly ⌈k/tile⌉
/// times.
#[test]
fn execute_many_is_bitwise_identical_to_looped_execute_everywhere() {
    let k = 6usize;
    for threads in [1usize, 2, 7] {
        let pool = Arc::new(ParPool::new(threads));
        for a in cases() {
            let (nr, nc) = (a.n_rows(), a.n_cols());
            let xs: Vec<Vec<f64>> = (0..k)
                .map(|j| (0..nc).map(|i| ((i * 7 + j * 3 + 1) as f64 * 0.17).sin()).collect())
                .collect();
            for imp in Implementation::ALL {
                let tag = format!("{imp} t={threads} n={nr}");
                let mut plan = SpmvPlan::build(&a, imp, None, pool.clone())
                    .unwrap_or_else(|e| panic!("{tag}: build failed: {e}"));
                // Reference: k looped single-RHS executes on the same plan.
                let mut want = vec![vec![0.0; nr]; k];
                for (x, y) in xs.iter().zip(want.iter_mut()) {
                    plan.execute(x, y).unwrap();
                }
                for tile in [1usize, 3, k] {
                    plan.set_batch_tile(tile);
                    let passes_before = plan.matrix_passes();
                    let mut got = vec![vec![0.0; nr]; k];
                    plan.execute_many(&xs, &mut got).unwrap();
                    assert_eq!(got, want, "{tag} tile={tile}: tiled SpMM must be bitwise");
                    assert_eq!(
                        plan.matrix_passes() - passes_before,
                        k.div_ceil(tile) as u64,
                        "{tag} tile={tile}: ceil(k/tile) matrix passes"
                    );
                }
            }
        }
    }
}

/// The pool dispatch counter exposes the single-pass-per-tile behaviour
/// end to end: a row-parallel CRS SpMM of k RHS at tile width t is
/// exactly ⌈k/t⌉ pool dispatches (the looped equivalent is k).
#[test]
fn tiled_spmm_dispatches_once_per_tile() {
    let mut rng = Rng::new(77);
    let a = Arc::new(random_csr(&mut rng, 200, 200, 0.05));
    let pool = Arc::new(ParPool::new(4));
    let mut plan = SpmvPlan::build(&a, Implementation::CsrRowPar, None, pool.clone()).unwrap();
    let k = 12usize;
    let xs: Vec<Vec<f64>> = (0..k)
        .map(|j| (0..200).map(|i| ((i + j) as f64 * 0.05).cos()).collect())
        .collect();
    let mut ys = vec![vec![0.0; 200]; k];

    plan.set_batch_tile(4);
    let before = pool.dispatch_count();
    plan.execute_many(&xs, &mut ys).unwrap();
    assert_eq!(pool.dispatch_count() - before, 3, "12 RHS / tile 4 = 3 passes");

    let before = pool.dispatch_count();
    for (x, y) in xs.iter().zip(ys.iter_mut()) {
        plan.execute(x, y).unwrap();
    }
    assert_eq!(pool.dispatch_count() - before, 12, "looped executes pass per RHS");
}

/// Shard routing: two matrices whose keys hash to different shards land
/// on distinct pools, and concurrent batched clients against both get
/// correct results.
#[test]
fn sharded_serving_routes_to_distinct_pools_and_stays_correct() {
    let td = tuning(Implementation::EllRowOuter, Some(3.1));
    let mut cfg = CoordinatorConfig::new(td);
    cfg.threads = 4;
    cfg.shards = 2;

    // Routing is deterministic and the two keys below differ in shard.
    let names: Vec<String> = (0..32).map(|i| format!("mat-{i}")).collect();
    let a_name = names
        .iter()
        .find(|n| shards::route_key(n, 2) == 0)
        .expect("32 keys cover shard 0")
        .clone();
    let b_name = names
        .iter()
        .find(|n| shards::route_key(n, 2) == 1)
        .expect("32 keys cover shard 1")
        .clone();

    // Coordinator-level: distinct pools per shard.
    let coord = spmv_at::coordinator::Coordinator::new(cfg.clone());
    assert_ne!(coord.shard_of(&a_name), coord.shard_of(&b_name));
    assert!(!Arc::ptr_eq(
        coord.planner().planner_for(&a_name).pool(),
        coord.planner().planner_for(&b_name).pool(),
    ));

    // Server-level: one loop per shard, concurrent batched clients.
    let (srv, client) = Server::spawn_sharded(cfg, 32);
    let mut rng = Rng::new(21);
    let ma = banded_circulant(&mut rng, 64, &[-1, 0, 1]);
    let mb = random_csr(&mut rng, 64, 64, 0.15);
    client.register(&a_name, ma.clone()).unwrap();
    client.register(&b_name, mb.clone()).unwrap();

    let mut handles = Vec::new();
    for (name, m) in [(a_name.clone(), ma), (b_name.clone(), mb)] {
        let c = client.clone();
        handles.push(std::thread::spawn(move || {
            let xs: Vec<Vec<f64>> = (0..6)
                .map(|j| (0..64).map(|i| ((i * 2 + j) as f64 * 0.11).sin()).collect())
                .collect();
            let mut want = Vec::new();
            for x in &xs {
                let mut y = vec![0.0; 64];
                m.spmv(x, &mut y);
                want.push(y);
            }
            for _ in 0..8 {
                let ys = c.spmv_batch(&name, xs.clone()).unwrap();
                for (got, w) in ys.iter().zip(&want) {
                    for (g, v) in got.iter().zip(w) {
                        assert!((g - v).abs() < 1e-9, "{name}: {g} vs {v}");
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let rows = client.stats().unwrap();
    assert_eq!(rows.len(), 2);
    assert!(rows.iter().all(|r| r.calls == 48));
    let coords = srv.shutdown_all();
    assert_eq!(coords.len(), 2);
    assert!(coords.iter().all(|c| c.names().len() == 1), "one matrix per shard");
}
