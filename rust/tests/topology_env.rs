//! The `SPMV_AT_TOPOLOGY` environment-override acceptance test, isolated
//! in its own test binary.
//!
//! This is the ONLY test in the workspace that mutates topology-related
//! environment variables. It lives alone because `std::env::set_var`
//! racing `getenv` on another thread is undefined behaviour on glibc,
//! and other tests (any `Coordinator::new`, `PlanShards::spread`,
//! `Server::spawn_sharded`) read these variables through
//! `Topology::detect`. Cargo runs test binaries sequentially and this
//! binary holds a single `#[test]`, so no reader can race the writes.

mod common;

use spmv_at::coordinator::shards::configured_shards;
use spmv_at::coordinator::{Coordinator, CoordinatorConfig};
use spmv_at::machine::topology::{Topology, TopologySource};
use spmv_at::spmv::Implementation;

/// The acceptance-criteria scenario: `SPMV_AT_TOPOLOGY=2:4` on a
/// single-node machine makes shards default to 2.
#[test]
fn topology_env_override_defaults_shards_to_sockets() {
    std::env::remove_var("SPMV_AT_SHARDS");
    std::env::set_var("SPMV_AT_TOPOLOGY", "2:4");
    let t = Topology::detect();
    assert_eq!(t.n_sockets(), 2);
    assert_eq!(t.n_cpus(), 8);
    assert_eq!(t.source(), TopologySource::Override);
    assert_eq!(configured_shards(), 2, "shards default to the socket count");

    // A coordinator built under the override really gets 2 shard pools
    // (given enough threads for both after clamping).
    let mut cfg =
        CoordinatorConfig::new(common::tuning(Implementation::EllRowInner, Some(3.1)));
    cfg.threads = 2;
    cfg.shards = configured_shards();
    let c = Coordinator::new(cfg);
    assert_eq!(c.planner().len(), 2);

    // Invalid overrides fall back to detection, not a panic.
    std::env::set_var("SPMV_AT_TOPOLOGY", "banana");
    let t = Topology::detect();
    assert!(t.n_sockets() >= 1);
    std::env::remove_var("SPMV_AT_TOPOLOGY");
}
