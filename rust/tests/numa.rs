//! NUMA topology + cross-socket split integration tests.
//!
//! Covers the ISSUE-4 acceptance surface:
//!
//! * sysfs fixture parsing (single-node, 2-socket, offline CPUs);
//! * the zero-thread-shard regression (`shard_thread_counts` clamps);
//! * first-touch observability: every plan build and adaptive re-plan is
//!   a `ParPool::run_init` fan-out on the owning shard's pool;
//! * the bitwise property: `execute_split_many` equals `execute_many`
//!   across splits {1, 2, shards} and thread counts {1, 2, 7}.
//!
//! No test here mutates environment variables (tests share the process
//! and `set_var` racing `getenv` is UB on glibc): the
//! `SPMV_AT_TOPOLOGY` override acceptance test lives alone in
//! `rust/tests/topology_env.rs`, its own sequentially-run binary.

mod common;

use common::{sys_fixture, tuning};
use spmv_at::autotune::MemoryPolicy;
use spmv_at::coordinator::shards::shard_thread_counts;
use spmv_at::coordinator::{Coordinator, CoordinatorConfig, PlanShards, ShardedPlanner};
use spmv_at::formats::{Csr, FormatKind, SparseMatrix};
use spmv_at::machine::topology::{parse_cpu_list, Topology, TopologySource};
use spmv_at::matrixgen::{banded_circulant, random_csr};
use spmv_at::rng::Rng;
use spmv_at::spmv::Implementation;
use spmv_at::Value;
use std::sync::Arc;

#[test]
fn sysfs_single_node_fixture() {
    let root = sys_fixture("single", &[(0, "0-3\n")], None);
    let t = Topology::from_sys_root(&root).unwrap();
    assert_eq!(t.n_sockets(), 1);
    assert_eq!(t.cpus(0), &[0, 1, 2, 3]);
    assert_eq!(t.source(), TopologySource::Sysfs);
    assert!(t.shard_cpus(0).is_none(), "one socket: no pinning");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn sysfs_two_socket_fixture() {
    let root = sys_fixture("dual", &[(0, "0-3\n"), (1, "4-7\n")], None);
    let t = Topology::from_sys_root(&root).unwrap();
    assert_eq!(t.n_sockets(), 2);
    assert_eq!(t.cpus(0), &[0, 1, 2, 3]);
    assert_eq!(t.cpus(1), &[4, 5, 6, 7]);
    assert_eq!(t.shard_cpus(1), Some(vec![4, 5, 6, 7]));
    assert_eq!(t.shard_cpus(3), Some(vec![4, 5, 6, 7]), "wraps modulo sockets");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn sysfs_offline_cpus_are_dropped() {
    // CPUs 6-7 of node1 are offline; node2 is entirely offline and must
    // disappear rather than become an unpinnable empty socket.
    let root = sys_fixture(
        "offline",
        &[(0, "0-3\n"), (1, "4-7\n"), (2, "8-11\n")],
        Some("0-5\n"),
    );
    let t = Topology::from_sys_root(&root).unwrap();
    assert_eq!(t.n_sockets(), 2, "the all-offline node vanishes");
    assert_eq!(t.cpus(0), &[0, 1, 2, 3]);
    assert_eq!(t.cpus(1), &[4, 5], "offline CPUs never get pinned to");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn sysfs_memory_only_and_empty_trees() {
    // A memory-only node (empty cpulist) is skipped.
    let root = sys_fixture("memnode", &[(0, "0-1\n"), (1, "\n")], None);
    let t = Topology::from_sys_root(&root).unwrap();
    assert_eq!(t.n_sockets(), 1);
    let _ = std::fs::remove_dir_all(&root);
    // No node directories at all -> None (caller falls back to flat).
    let root = sys_fixture("empty", &[], None);
    assert!(Topology::from_sys_root(&root).is_none());
    let _ = std::fs::remove_dir_all(&root);
    // Missing tree entirely -> None.
    assert!(Topology::from_sys_root(std::path::Path::new("/nonexistent-spmv-at")).is_none());
}

#[test]
fn cpu_list_roundtrip_kernel_shapes() {
    assert_eq!(parse_cpu_list("0-63\n").len(), 64);
    assert_eq!(parse_cpu_list("0,32,1,33"), vec![0, 1, 32, 33]);
    assert!(parse_cpu_list("\n").is_empty());
}

#[test]
fn shard_thread_counts_never_returns_a_zero_thread_shard() {
    // Regression (ISSUE 4): SPMV_AT_THREADS < shard count used to spawn
    // width-1 pools oversubscribing the budget; now the shard count
    // clamps. Exhaustive small-space sweep: no zero widths, sums match,
    // length = min(shards, threads) clamped to >= 1.
    for threads in 0..=9usize {
        for shards in 0..=9usize {
            let counts = shard_thread_counts(threads, shards);
            assert!(!counts.is_empty(), "({threads},{shards})");
            assert!(
                counts.iter().all(|&c| c >= 1),
                "({threads},{shards}): zero-thread shard in {counts:?}"
            );
            assert_eq!(counts.iter().sum::<usize>(), threads.max(1), "({threads},{shards})");
            assert_eq!(counts.len(), shards.max(1).min(threads.max(1)), "({threads},{shards})");
        }
    }
}

#[test]
fn plan_builds_run_init_on_the_owning_pool() {
    // Acceptance: every plan build runs its array initialization through
    // the owning shard's ParPool::run_init, observable via init_count.
    let sp = ShardedPlanner::new(
        tuning(Implementation::EllRowInner, Some(3.1)),
        MemoryPolicy::unlimited(),
        PlanShards::new(2, 2),
    );
    let mut rng = Rng::new(31);
    let a = Arc::new(banded_circulant(&mut rng, 64, &[-1, 0, 1]));
    for shard in 0..2 {
        let before = sp.shards().pool(shard).init_count();
        let other = sp.shards().pool(1 - shard).init_count();
        sp.planner(shard).plan_for(&a, Implementation::EllRowInner).unwrap();
        assert!(
            sp.shards().pool(shard).init_count() > before,
            "build must init on shard {shard}"
        );
        assert_eq!(
            sp.shards().pool(1 - shard).init_count(),
            other,
            "build must not touch the other shard"
        );
        // CRS plans (zero-copy) still warm through run_init.
        let before = sp.shards().pool(shard).init_count();
        sp.planner(shard).plan_for(&a, Implementation::CsrRowPar).unwrap();
        assert!(sp.shards().pool(shard).init_count() > before);
    }
}

#[test]
fn replans_and_adaptive_flips_first_touch_on_the_owning_shard() {
    // A forced replan (the adaptive loop's re-decision path) rebuilds the
    // serving plan through the owning shard's run_init fan-out.
    let mut cfg = CoordinatorConfig::new(tuning(Implementation::EllRowInner, Some(3.1)));
    cfg.threads = 2;
    cfg.shards = 1;
    cfg.adaptive.enabled = true;
    cfg.adaptive.epsilon = 0.0;
    let mut c = Coordinator::new(cfg);
    let mut rng = Rng::new(7);
    let a = banded_circulant(&mut rng, 96, &[-1, 0, 1]);
    c.register("band", a).unwrap();
    let x = vec![1.0; 96];
    c.spmv("band", &x).unwrap();
    assert_eq!(c.serving_format("band"), Some(FormatKind::Ell));

    let before = c.planner().shards().pool(0).init_count();
    c.replan("band").unwrap(); // same decision -> rebuild + swap_executable
    let after = c.planner().shards().pool(0).init_count();
    assert!(after > before, "a re-plan is a first-touch rebuild");
}

#[test]
fn execute_split_many_is_bitwise_identical_across_splits_and_threads() {
    // The ISSUE-4 property test: splits {1, 2, shards} x threads
    // {1, 2, 7}, row-oriented kernels, bitwise equality with the unsplit
    // tiled SpMM.
    let shards = 3usize;
    let mut rng = Rng::new(101);
    let matrices: Vec<Csr> = vec![
        random_csr(&mut rng, 150, 150, 0.06),
        banded_circulant(&mut rng, 128, &[-2, -1, 0, 1, 2]),
    ];
    let xs_for = |n: usize| -> Vec<Vec<Value>> {
        (0..4)
            .map(|j| (0..n).map(|i| 1.0 + ((i * 5 + j * 3) % 11) as f64 * 0.0625).collect())
            .collect()
    };
    for threads in [1usize, 2, 7] {
        let sp = ShardedPlanner::new(
            tuning(Implementation::EllRowInner, Some(3.1)),
            MemoryPolicy::unlimited(),
            PlanShards::new(shards, threads),
        );
        for a in &matrices {
            let a = Arc::new(a.clone());
            let n = a.n_rows();
            let xs = xs_for(a.n_cols());
            for imp in [Implementation::CsrRowPar, Implementation::EllRowInner] {
                let mut want = vec![vec![0.0; n]; xs.len()];
                let mut full = sp.planner(0).plan_for(&a, imp).unwrap();
                full.execute_many(&xs, &mut want).unwrap();
                for splits in [1usize, 2, shards] {
                    let mut split = sp.plan_split(&a, imp, splits).unwrap();
                    let mut got = vec![vec![0.0; n]; xs.len()];
                    sp.execute_split_many(&mut split, &xs, &mut got).unwrap();
                    assert_eq!(
                        got, want,
                        "threads={threads} imp={imp} splits={splits}: split SpMM \
                         must be bitwise-identical"
                    );
                    // Repeat on the same split plan: stable and still equal.
                    sp.execute_split_many(&mut split, &xs, &mut got).unwrap();
                    assert_eq!(got, want, "threads={threads} imp={imp} splits={splits} (rerun)");
                }
            }
        }
    }
}

#[test]
fn split_pass_counters_expose_the_split() {
    // matrix_passes on a split plan follows the unsplit ceil(k/tile)
    // semantics (ISSUE-5 regression fix: it used to sum per-block
    // counters, over-counting by a factor of `parts`); per-block
    // activity stays visible through the shard pools' dispatch counters.
    let sp = ShardedPlanner::new(
        tuning(Implementation::EllRowInner, Some(3.1)),
        MemoryPolicy::unlimited(),
        PlanShards::new(2, 2),
    );
    let mut rng = Rng::new(55);
    let a = Arc::new(random_csr(&mut rng, 90, 90, 0.1));
    let mut split = sp.plan_split(&a, Implementation::CsrRowPar, 2).unwrap();
    split.set_batch_tile(3);
    let k = 7usize;
    let xs: Vec<Vec<Value>> = (0..k)
        .map(|j| (0..90).map(|i| ((i + j) as f64 * 0.21).cos()).collect())
        .collect();
    let mut ys = vec![vec![0.0; 90]; k];
    let before = split.matrix_passes();
    let dispatch_before: Vec<u64> =
        (0..2).map(|i| sp.shards().pool(i).dispatch_count()).collect();
    sp.execute_split_many(&mut split, &xs, &mut ys).unwrap();
    assert_eq!(
        split.matrix_passes() - before,
        3, // ceil(7/3), once per split call — NOT multiplied by parts
        "pass counter must match the unsplit ceil(k/tile) semantics"
    );
    for i in 0..2 {
        assert!(
            sp.shards().pool(i).dispatch_count() > dispatch_before[i],
            "block {i} still observable on its own pool"
        );
    }
    assert_eq!(split.part_shard(0), 0);
    assert_eq!(split.part_shard(1), 1);
    // Blocks tile the row range contiguously.
    assert_eq!(split.part_rows(0).start, 0);
    assert_eq!(split.part_rows(0).end, split.part_rows(1).start);
    assert_eq!(split.part_rows(1).end, 90);
}

#[test]
fn sharded_server_still_serves_under_clamped_shards() {
    // shards > threads now clamps the loop count instead of spawning
    // thread-starved pools; the client transparently routes over the
    // effective count.
    let mut cfg = CoordinatorConfig::new(tuning(Implementation::EllRowInner, Some(3.1)));
    cfg.threads = 1;
    cfg.shards = 4; // clamps to 1
    let (srv, client) = spmv_at::coordinator::Server::spawn_sharded(cfg, 8);
    assert_eq!(client.shards(), 1, "loops follow the clamped count");
    client.register("m", Csr::identity(8)).unwrap();
    let y = client.spmv("m", vec![2.0; 8]).unwrap();
    assert_eq!(y, vec![2.0; 8]);
    let stats = client.stats().unwrap();
    assert_eq!(stats.len(), 1);
    assert_eq!(stats[0].shard, 0);
    srv.shutdown_all();
}
