//! Vector-machine cost model — the Earth Simulator 2 stand-in.
//!
//! One ES2 node: 8 × NEC SX-9/E cores at 3.2 GHz, 256-element vector
//! registers, no data cache (memory is flat and extremely high-bandwidth
//! for *vector* accesses; scalar accesses eat full memory latency).
//!
//! Mechanisms modelled, following the paper's §4.5 reasoning:
//!
//! * **CRS runs scalar.** The OpenATLib CRS kernel's inner loop (indirect
//!   load + accumulation, trip count ≈ μ ≈ 5–70) does not vectorise, so
//!   every element pays the scalar unit's memory-latency-bound cost. This
//!   is what makes 100×+ ELL speedups possible at all.
//! * **ELL runs vector.** Band-major storage turns SpMV into `nz` sweeps
//!   of unit-stride length-`n` vector operations (strip-mined at 256), at
//!   gather-limited throughput, paying the padding waste `fill_ratio`.
//! * **COO runs vector with scatter hazard.** `YY(KK) += …` needs the
//!   list-vector (conflict-resolving scatter) path, an order of magnitude
//!   slower than clean gathers — memplus's 2.75× COO-Row win against 151×
//!   ELL wins elsewhere falls out of this.
//! * **Transformation vectorises.** Zero-fill and copy streams run at
//!   vector store bandwidth, which is why the paper sees only 0.01–0.51
//!   CRS-SpMV-times of overhead on the ES2.

use super::{transform_bytes, CostModel, MatrixShape};
use crate::formats::FormatKind;
use crate::spmv::Implementation;

/// Tunable parameters of the vector model (cycles unless noted).
#[derive(Clone, Debug)]
pub struct VectorParams {
    /// Core clock in Hz (SX-9/E: 3.2 GHz).
    pub clock_hz: f64,
    /// Cores per node (ES2: 8).
    pub cores: usize,
    /// Scalar-unit cost per element of a non-vectorised loop body with an
    /// indirect load (memory-latency bound; the SX has no cache).
    pub scalar_elem: f64,
    /// Scalar loop bookkeeping per CRS row.
    pub row_overhead: f64,
    /// Vector instruction startup (issue + pipe fill) per 256-strip.
    pub vec_startup: f64,
    /// Per-element cost of a vector gather (`x[icol]`), cycles/element.
    pub gather: f64,
    /// Per-element cost of a unit-stride vector load/FMA stream.
    pub stream: f64,
    /// Per-element cost of the conflict-resolving list-vector scatter the
    /// COO kernels need for `YY(KK) +=`.
    pub scatter: f64,
    /// Thread (microtask) fork/join overhead per parallel region, cycles.
    pub fork: f64,
    /// Vector memory bandwidth per core, bytes/second (SX-9: 256 GB/s).
    pub mem_bw: f64,
    /// Parallel efficiency exponent: work scales as `threads^eff`.
    pub par_eff: f64,
}

impl Default for VectorParams {
    fn default() -> Self {
        Self {
            clock_hz: 3.2e9,
            cores: 8,
            scalar_elem: 110.0,
            row_overhead: 50.0,
            vec_startup: 70.0,
            gather: 0.28,
            stream: 0.17,
            scatter: 28.0,
            fork: 12_000.0,
            mem_bw: 256e9,
            par_eff: 0.92,
        }
    }
}

/// The ES2 stand-in. See module docs for the modelled mechanisms.
pub struct VectorMachine {
    /// Model parameters (public so ablation benches can perturb them).
    pub p: VectorParams,
}

impl Default for VectorMachine {
    fn default() -> Self {
        Self { p: VectorParams::default() }
    }
}

impl VectorMachine {
    /// Model with explicit parameters.
    pub fn new(p: VectorParams) -> Self {
        Self { p }
    }

    /// Effective speedup of spreading vector work over `t` threads.
    fn par(&self, t: usize) -> f64 {
        (t.max(1) as f64).powf(self.p.par_eff)
    }

    fn strips(&self, len: usize) -> f64 {
        (len as f64 / 256.0).ceil().max(1.0)
    }

    /// CRS baseline: scalar per-element cost + per-row bookkeeping,
    /// row-parallelised across threads.
    fn crs_cycles(&self, m: &MatrixShape, threads: usize) -> f64 {
        let work = m.nnz as f64 * self.p.scalar_elem + m.n as f64 * self.p.row_overhead;
        work / self.par(threads) + if threads > 1 { self.p.fork } else { 0.0 }
    }
}

impl CostModel for VectorMachine {
    fn name(&self) -> &'static str {
        "ES2"
    }

    fn max_threads(&self) -> usize {
        self.p.cores
    }

    fn spmv_seconds(&self, m: &MatrixShape, imp: Implementation, threads: usize) -> f64 {
        let t = threads.clamp(1, self.p.cores);
        let n = m.n as f64;
        let nnz = m.nnz as f64;
        let nz = m.bandwidth as f64;
        let cycles = match imp {
            Implementation::CsrSeq => self.crs_cycles(m, 1),
            Implementation::CsrRowPar => self.crs_cycles(m, t),
            Implementation::CsrMergePar => {
                // Same balanced CRS stream, plus the serial carry fixup:
                // two slots per chunk folded after the parallel sweep.
                self.crs_cycles(m, t) + 2.0 * t as f64 * self.p.scalar_elem
            }
            Implementation::EllRowInner => {
                // Fig. 3: rows split across threads; each band is a
                // unit-stride gather-FMA sweep of length n/t.
                let rows = n / t as f64;
                let per_band = self.strips(rows.ceil() as usize) * self.p.vec_startup
                    + rows * (self.p.gather + self.p.stream);
                nz * per_band / 1.0 + if t > 1 { self.p.fork } else { 0.0 }
            }
            Implementation::EllRowOuter => {
                // Fig. 4: bands split across threads (parallelism ≤ nz);
                // each thread sweeps full-length rows into private YY,
                // then a serial vector reduction over t copies.
                let t_eff = (t as f64).min(nz.max(1.0));
                let bands_per_thread = (nz / t_eff).ceil();
                let per_band =
                    self.strips(m.n) * self.p.vec_startup + n * (self.p.gather + self.p.stream);
                let reduce = if t > 1 {
                    t as f64 * (n * self.p.stream + self.strips(m.n) * self.p.vec_startup)
                } else {
                    0.0
                };
                bands_per_thread * per_band + reduce + if t > 1 { self.p.fork } else { 0.0 }
            }
            Implementation::CooRowOuter | Implementation::CooColOuter => {
                // Figs. 1–2: entry stream split across threads; the scatter
                // into YY pays the list-vector penalty; serial reduction.
                let per_elem = self.p.gather + self.p.scatter;
                let chunk = nnz / t as f64;
                let reduce = if t > 1 {
                    t as f64 * (n * self.p.stream + self.strips(m.n) * self.p.vec_startup)
                } else {
                    0.0
                };
                chunk * per_elem
                    + self.strips(chunk.ceil() as usize) * self.p.vec_startup
                    + reduce
                    + if t > 1 { self.p.fork } else { 0.0 }
            }
            Implementation::BcsrSeq => {
                // Small dense blocks vectorise poorly at 2x2: treat as
                // scalar with halved bookkeeping.
                nnz * self.p.scalar_elem * 0.6 + n * self.p.row_overhead * 0.5
            }
            Implementation::JdsSeq => {
                // Extension: each jagged diagonal is a dense vector op of
                // shrinking length — nnz total elements, no fill, plus a
                // final permutation scatter on y (conflict-free, so it
                // runs at gather speed) and per-diagonal startups.
                let n_diags = m.bandwidth.max(1) as f64;
                nnz * (self.p.gather + self.p.stream)
                    + n_diags * self.strips(m.n) * self.p.vec_startup / 2.0
                    + n * (self.p.gather + self.p.stream)
            }
            Implementation::SellRowInner => {
                // Extension: like Fig. 3 but the σ-sort shrinks the padded
                // slot count towards nnz (85% of ELL's waste removed — the
                // transform_bytes estimate), chunk bands sweep at
                // gather-FMA speed with one strip-startup per 256 slots
                // (C is chosen ≤ the vector length), and the finished
                // rows scatter back through the permutation conflict-free
                // (gather-speed, like JDS's final permutation).
                let slots = nnz * (1.0 + 0.15 * (m.fill_ratio - 1.0).max(0.0));
                let sweep = slots * (self.p.gather + self.p.stream)
                    + self.strips(slots.ceil() as usize) * self.p.vec_startup;
                let perm = n * (self.p.gather + self.p.stream);
                (sweep + perm) / self.par(t) + if t > 1 { self.p.fork } else { 0.0 }
            }
            Implementation::HybSeq => {
                // Extension: ELL body at ~1.5μ bandwidth + COO spill tail
                // through the list-vector scatter (~10% of nnz worst case).
                let body_bw = (m.mu * 1.5).ceil().min(m.bandwidth as f64).max(1.0);
                let body = body_bw
                    * (self.strips(m.n) * self.p.vec_startup
                        + n * (self.p.gather + self.p.stream));
                // Spill fraction estimated from the fill ratio: no tail at
                // all when the band is already tight.
                let tail_frac = (0.12 * (1.0 - 1.5 / m.fill_ratio)).max(0.0);
                let tail = tail_frac * nnz * (self.p.gather + self.p.scatter);
                body + tail
            }
        };
        cycles / self.p.clock_hz
    }

    fn transform_seconds(&self, m: &MatrixShape, target: FormatKind) -> f64 {
        // Transform streams vectorise: cost = byte traffic at vector
        // bandwidth + a vector-startup term per pass.
        let bytes = transform_bytes(m, target);
        let passes = match target {
            FormatKind::Csr => 0.0,
            FormatKind::CooRow => 2.0,
            FormatKind::Ell => 3.0,
            FormatKind::Csc | FormatKind::CooCol => {
                // The §2.1 counting transform's scatter phase is indirect —
                // it pays the scatter penalty per nnz instead of streaming.
                return (m.nnz as f64 * self.p.scatter
                    + bytes / self.p.mem_bw * self.p.clock_hz * 0.3)
                    / self.p.clock_hz;
            }
            FormatKind::Bcsr => 4.0,
            FormatKind::Jds => 3.0,
            FormatKind::Hyb => 3.0,
            // SELL-C-σ: length pass + σ-window sort + scatter + pad pass.
            FormatKind::Sell => 4.0,
        };
        (bytes / self.p.mem_bw) + passes * self.strips(m.n) * self.p.vec_startup / self.p.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MatrixShape;

    /// chem_master1's published shape (μ=4.98, σ=0.14, D=0.02).
    fn chem_master() -> MatrixShape {
        MatrixShape {
            n: 40_401, n_cols: 40_401, nnz: 201_201,
            mu: 4.98, sigma: 0.14, bandwidth: 6,
            fill_ratio: 40_401.0 * 6.0 / 201_201.0,
        }
    }

    /// memplus's published shape (μ=7.10, σ=22.03, D=3.10); bandwidth from
    /// the real matrix is 574.
    fn memplus() -> MatrixShape {
        MatrixShape {
            n: 17_758, n_cols: 17_758, nnz: 126_150,
            mu: 7.10, sigma: 22.03, bandwidth: 574,
            fill_ratio: 17_758.0 * 574.0 / 126_150.0,
        }
    }

    #[test]
    fn ell_speedup_exceeds_100x_for_small_dmat() {
        let mch = VectorMachine::default();
        let m = chem_master();
        let t_crs = mch.spmv_seconds(&m, Implementation::CsrSeq, 1);
        let t_ell = mch.spmv_seconds(&m, Implementation::EllRowInner, 1);
        let sp = t_crs / t_ell;
        // Paper: 151x for chem_master1 (ELL-Row inner). Require the right
        // magnitude band.
        assert!((100.0..260.0).contains(&sp), "SP_crs/ell = {sp}");
    }

    #[test]
    fn memplus_prefers_coo_row_over_ell() {
        let mch = VectorMachine::default();
        let m = memplus();
        let t_crs = mch.spmv_seconds(&m, Implementation::CsrSeq, 1);
        let t_ell = mch.spmv_seconds(&m, Implementation::EllRowInner, 1);
        let t_coo = mch.spmv_seconds(&m, Implementation::CooRowOuter, 1);
        let sp_ell = t_crs / t_ell;
        let sp_coo = t_crs / t_coo;
        assert!(sp_coo > sp_ell, "COO {sp_coo} should beat ELL {sp_ell} on memplus");
        // Paper: COO-Row gives 2.75x on memplus.
        assert!((1.5..6.0).contains(&sp_coo), "SP_crs/coo = {sp_coo}");
    }

    #[test]
    fn transform_overhead_below_one_crs_spmv() {
        let mch = VectorMachine::default();
        for m in [chem_master(), memplus()] {
            let t_crs = mch.spmv_seconds(&m, Implementation::CsrSeq, 1);
            let t_tr = mch.transform_seconds(&m, FormatKind::Ell);
            let ratio = t_tr / t_crs;
            // Paper Fig. 7: ES2 ELL overheads are 0.01x–0.51x.
            assert!(ratio < 1.0, "t_trans/t_crs = {ratio}");
            assert!(ratio > 0.0);
        }
    }

    #[test]
    fn thread_scaling_monotone() {
        let mch = VectorMachine::default();
        let m = chem_master();
        for imp in [Implementation::CsrRowPar, Implementation::EllRowInner] {
            let t1 = mch.spmv_seconds(&m, imp, 1);
            let t8 = mch.spmv_seconds(&m, imp, 8);
            assert!(t8 < t1, "{imp}: t8 {t8} !< t1 {t1}");
        }
    }

    #[test]
    fn ell_outer_parallelism_capped_by_bandwidth() {
        let mch = VectorMachine::default();
        let m = chem_master(); // bandwidth 6
        let t6 = mch.spmv_seconds(&m, Implementation::EllRowOuter, 6);
        let t8 = mch.spmv_seconds(&m, Implementation::EllRowOuter, 8);
        // No additional speedup beyond nz threads (reduction even grows).
        assert!(t8 >= t6 * 0.95, "outer should not scale past nz: {t8} vs {t6}");
    }
}
