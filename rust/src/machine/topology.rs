//! Socket/core topology detection and thread pinning — the NUMA layer's
//! ground truth.
//!
//! The paper's headline speedups depend on the transformed arrays living
//! close to the cores that stream them (on the Earth Simulator that is
//! vector-pipe locality; on commodity multi-socket boxes it is NUMA
//! locality). This module answers the one question the shard layer needs:
//! *how many sockets does this machine have, and which CPUs belong to
//! each?* [`Topology::detect`] resolves it from three sources, in order:
//!
//! 1. the `SPMV_AT_TOPOLOGY=<sockets>:<cores>` environment override
//!    (synthetic contiguous CPU blocks — the test/bench/CI hook, and the
//!    way to *pretend* a topology on a single-node dev box);
//! 2. the Linux sysfs NUMA tree (`/sys/devices/system/node/node*/cpulist`,
//!    intersected with `/sys/devices/system/cpu/online` so offline CPUs
//!    are never pinned to);
//! 3. a flat single-node fallback (one socket holding every hardware
//!    thread) everywhere else.
//!
//! [`pin_current_thread`] is the affinity shim: on Linux it calls
//! `sched_setaffinity` directly through the C ABI (no `libc` crate in
//! this environment); on other targets it is a no-op returning `false`.
//! Pinning is always best-effort — a synthetic override naming CPUs the
//! machine does not have simply fails the syscall and the pool runs
//! unpinned.
//!
//! [`crate::coordinator::shards`] consumes this: the shard count defaults
//! to the socket count, shard `i`'s [`crate::spmv::pool::ParPool`] is
//! pinned to socket `i mod sockets`, and every plan build first-touches
//! its arrays from those pinned workers (see
//! [`crate::spmv::pool::ParPool::run_init`]).

use std::path::Path;

/// How a [`Topology`] was obtained (reported by `spmv-at topology` and
/// the serve banner; pinning itself only depends on the socket count).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologySource {
    /// The `SPMV_AT_TOPOLOGY=<sockets>:<cores>` override.
    Override,
    /// Parsed from the sysfs NUMA tree.
    Sysfs,
    /// Flat single-node fallback (no NUMA information available).
    Flat,
}

/// The machine's socket/core layout: one CPU-id list per socket.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    sockets: Vec<Vec<usize>>,
    source: TopologySource,
}

impl Topology {
    /// The topology this process should plan against: the
    /// `SPMV_AT_TOPOLOGY` override when set and valid, the sysfs NUMA
    /// tree on Linux, a flat single-node layout otherwise.
    pub fn detect() -> Self {
        if let Ok(s) = std::env::var("SPMV_AT_TOPOLOGY") {
            if let Some(t) = Self::parse_override(&s) {
                return t;
            }
        }
        #[cfg(target_os = "linux")]
        if let Some(t) = Self::from_sys_root(Path::new("/sys")) {
            return t;
        }
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::single_node(cores)
    }

    /// A flat single-node topology: one socket holding CPUs `0..cores`.
    pub fn single_node(cores: usize) -> Self {
        Self {
            sockets: vec![(0..cores.max(1)).collect()],
            source: TopologySource::Flat,
        }
    }

    /// Parse the `<sockets>:<cores>` override (e.g. `2:4` = two sockets
    /// of four cores each, CPUs numbered contiguously per socket).
    /// Returns `None` for anything malformed or non-positive.
    pub fn parse_override(s: &str) -> Option<Self> {
        let (sockets, cores) = s.trim().split_once(':')?;
        let sockets: usize = sockets.trim().parse().ok().filter(|&n| n >= 1)?;
        let cores: usize = cores.trim().parse().ok().filter(|&n| n >= 1)?;
        Some(Self {
            sockets: (0..sockets)
                .map(|i| (i * cores..(i + 1) * cores).collect())
                .collect(),
            source: TopologySource::Override,
        })
    }

    /// Parse a sysfs tree rooted at `root` (`/sys` in production, a
    /// fixture directory in tests): one socket per
    /// `devices/system/node/node<k>` directory, CPUs from its `cpulist`,
    /// intersected with `devices/system/cpu/online` when present.
    /// Memory-only nodes (no online CPUs) are dropped. Returns `None`
    /// when no node directory with CPUs exists.
    pub fn from_sys_root(root: &Path) -> Option<Self> {
        let node_dir = root.join("devices/system/node");
        let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
        for entry in std::fs::read_dir(&node_dir).ok()? {
            let entry = entry.ok()?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(idx) = name.strip_prefix("node").and_then(|r| r.parse::<usize>().ok())
            else {
                continue;
            };
            let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else {
                continue;
            };
            nodes.push((idx, parse_cpu_list(&list)));
        }
        nodes.sort_by_key(|(idx, _)| *idx);
        // Offline CPUs must never be pinned to: intersect with the online
        // mask when the tree carries one.
        if let Ok(online) = std::fs::read_to_string(root.join("devices/system/cpu/online")) {
            let online = parse_cpu_list(&online);
            for (_, cpus) in &mut nodes {
                cpus.retain(|c| online.binary_search(c).is_ok());
            }
        }
        let sockets: Vec<Vec<usize>> =
            nodes.into_iter().map(|(_, cpus)| cpus).filter(|c| !c.is_empty()).collect();
        if sockets.is_empty() {
            return None;
        }
        Some(Self { sockets, source: TopologySource::Sysfs })
    }

    /// Number of sockets (always ≥ 1).
    pub fn n_sockets(&self) -> usize {
        self.sockets.len()
    }

    /// Total CPUs across all sockets.
    pub fn n_cpus(&self) -> usize {
        self.sockets.iter().map(Vec::len).sum()
    }

    /// The CPU ids of socket `i`.
    ///
    /// # Panics
    /// Panics if `i >= n_sockets()`.
    pub fn cpus(&self, i: usize) -> &[usize] {
        &self.sockets[i]
    }

    /// Where this topology came from.
    pub fn source(&self) -> TopologySource {
        self.source
    }

    /// The CPU set pool shard `i` should pin to (socket `i mod sockets`),
    /// or `None` on single-socket machines where pinning buys nothing.
    pub fn shard_cpus(&self, shard: usize) -> Option<Vec<usize>> {
        if self.n_sockets() <= 1 {
            return None;
        }
        Some(self.sockets[shard % self.sockets.len()].clone())
    }
}

/// Parse a kernel CPU-list string (`"0-3,8,10-11"`) into a sorted,
/// deduplicated id list. Malformed tokens are skipped (the kernel never
/// emits them; fixtures should not be able to panic production detect).
pub fn parse_cpu_list(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for token in s.trim().split(',') {
        let token = token.trim();
        if token.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = token.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>()) {
                if lo <= hi {
                    out.extend(lo..=hi);
                }
            }
        } else if let Ok(c) = token.parse::<usize>() {
            out.push(c);
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// The raw `sched_{set,get}affinity` shim (Linux only). glibc `cpu_set_t`
/// is a fixed 1024-bit mask of unsigned longs; the symbols are declared
/// directly against the C ABI because this environment carries no `libc`
/// crate.
#[cfg(target_os = "linux")]
mod sys {
    pub const SETSIZE: usize = 1024;
    pub const WORD: usize = 8 * std::mem::size_of::<usize>();

    #[repr(C)]
    pub struct CpuSet {
        pub bits: [usize; SETSIZE / WORD],
    }

    extern "C" {
        // int sched_setaffinity(pid_t pid, size_t cpusetsize, const cpu_set_t *mask);
        pub fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
        // int sched_getaffinity(pid_t pid, size_t cpusetsize, cpu_set_t *mask);
        pub fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut CpuSet) -> i32;
    }
}

/// Pin the calling thread to `cpus` via `sched_setaffinity`. Returns
/// whether the kernel accepted the mask. Best-effort by design: an empty
/// or entirely-invalid CPU set (e.g. a synthetic `SPMV_AT_TOPOLOGY`
/// override naming CPUs this machine lacks) returns `false` and leaves
/// the thread's affinity unchanged.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    use sys::{CpuSet, SETSIZE, WORD};
    let mut set = CpuSet { bits: [0; SETSIZE / WORD] };
    let mut any = false;
    for &c in cpus {
        if c < SETSIZE {
            set.bits[c / WORD] |= 1 << (c % WORD);
            any = true;
        }
    }
    if !any {
        return false;
    }
    // SAFETY: `set` is a valid, fully initialised mask of the size passed;
    // pid 0 targets the calling thread.
    unsafe { sys::sched_setaffinity(0, std::mem::size_of::<CpuSet>(), &set) == 0 }
}

/// The calling thread's current affinity mask as a CPU-id list, or `None`
/// when it cannot be read.
#[cfg(target_os = "linux")]
pub fn current_affinity() -> Option<Vec<usize>> {
    use sys::{CpuSet, SETSIZE, WORD};
    let mut set = CpuSet { bits: [0; SETSIZE / WORD] };
    // SAFETY: `set` is a writable mask of the size passed; pid 0 targets
    // the calling thread.
    if unsafe { sys::sched_getaffinity(0, std::mem::size_of::<CpuSet>(), &mut set) } != 0 {
        return None;
    }
    let mut cpus = Vec::new();
    for c in 0..SETSIZE {
        if set.bits[c / WORD] & (1 << (c % WORD)) != 0 {
            cpus.push(c);
        }
    }
    Some(cpus)
}

/// Run `f` with the calling thread pinned to `cpus`, restoring the
/// thread's previous affinity afterwards. If the previous mask cannot be
/// read (so it could not be restored), `f` runs unpinned rather than
/// permanently hijacking the caller's placement. This is what
/// [`crate::spmv::pool::ParPool::run_init`] wraps initialization
/// fan-outs in: the *caller* participates in chunk claiming (and runs
/// everything on width-1 pools), so the first-touch guarantee needs the
/// calling thread on the pool's socket too, not just the parked workers.
pub fn with_affinity<R>(cpus: &[usize], f: impl FnOnce() -> R) -> R {
    #[cfg(target_os = "linux")]
    {
        if let Some(saved) = current_affinity() {
            let pinned = pin_current_thread(cpus);
            let out = f();
            if pinned {
                pin_current_thread(&saved);
            }
            return out;
        }
    }
    let _ = cpus;
    f()
}

/// Non-Linux stub: affinity is not supported, nothing happens.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_cpus: &[usize]) -> bool {
    false
}

/// Non-Linux stub: the affinity mask is not readable.
#[cfg(not(target_os = "linux"))]
pub fn current_affinity() -> Option<Vec<usize>> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_list_parsing() {
        assert_eq!(parse_cpu_list("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpu_list("0-1,4,6-7\n"), vec![0, 1, 4, 6, 7]);
        assert_eq!(parse_cpu_list(" 2 , 0 "), vec![0, 2]);
        assert_eq!(parse_cpu_list("3,3,1-3"), vec![1, 2, 3]);
        assert!(parse_cpu_list("").is_empty());
        assert!(parse_cpu_list("garbage,5-2").is_empty());
    }

    #[test]
    fn override_parsing() {
        let t = Topology::parse_override("2:4").unwrap();
        assert_eq!(t.n_sockets(), 2);
        assert_eq!(t.cpus(0), &[0, 1, 2, 3]);
        assert_eq!(t.cpus(1), &[4, 5, 6, 7]);
        assert_eq!(t.source(), TopologySource::Override);
        assert_eq!(Topology::parse_override(" 1:2 ").unwrap().n_cpus(), 2);
        for bad in ["", "2", "0:4", "2:0", "a:b", "2:4:8", "-1:4"] {
            assert!(Topology::parse_override(bad).is_none(), "{bad:?}");
        }
    }

    #[test]
    fn single_node_fallback_shape() {
        let t = Topology::single_node(6);
        assert_eq!(t.n_sockets(), 1);
        assert_eq!(t.n_cpus(), 6);
        assert_eq!(t.source(), TopologySource::Flat);
        assert!(t.shard_cpus(0).is_none(), "single socket never pins");
        assert_eq!(Topology::single_node(0).n_cpus(), 1, "degenerate clamps to one CPU");
    }

    #[test]
    fn shard_cpus_wrap_around_sockets() {
        let t = Topology::parse_override("2:2").unwrap();
        assert_eq!(t.shard_cpus(0), Some(vec![0, 1]));
        assert_eq!(t.shard_cpus(1), Some(vec![2, 3]));
        assert_eq!(t.shard_cpus(2), Some(vec![0, 1]), "shard 2 wraps to socket 0");
    }

    #[test]
    fn detect_is_always_usable() {
        // Whatever the host looks like, detect() must produce a pinnable,
        // non-empty layout.
        let t = Topology::detect();
        assert!(t.n_sockets() >= 1);
        assert!(t.n_cpus() >= 1);
        for i in 0..t.n_sockets() {
            assert!(!t.cpus(i).is_empty());
        }
    }

    #[test]
    fn pinning_is_best_effort() {
        // An empty set must be rejected without touching affinity.
        assert!(!pin_current_thread(&[]));
        // CPUs beyond the mask width are ignored rather than UB.
        assert!(!pin_current_thread(&[usize::MAX]));
    }

    #[test]
    fn with_affinity_restores_the_callers_mask() {
        let before = current_affinity();
        let ran = std::cell::Cell::new(false);
        // Whatever CPU 0's validity on this host, the closure must run
        // and the caller's mask must come back unchanged.
        with_affinity(&[0], || ran.set(true));
        assert!(ran.get());
        assert_eq!(current_affinity(), before, "caller affinity must be restored");
        // An unpinnable set still runs the closure.
        let out = with_affinity(&[usize::MAX], || 42);
        assert_eq!(out, 42);
        assert_eq!(current_affinity(), before);
    }
}
