//! Machine backends: where `t_crs`, `t_ell`, `t_trans` come from.
//!
//! The paper measures on two machines we cannot obtain — the Earth
//! Simulator 2 (NEC SX-9/E vector processor) and a HITACHI SR16000/VL1
//! (POWER6 SMP). Per the substitution rule, both are replaced by
//! *calibrated analytic cost models* ([`vector::VectorMachine`],
//! [`scalar::ScalarMachine`]) that simulate the execution-time mechanisms
//! the paper's §4.5 discussion attributes the results to:
//!
//! * on the vector machine, CRS's short rows serialise onto the slow
//!   scalar unit while ELL's band-major layout feeds full-length vector
//!   pipes — hence the 100×+ speedups;
//! * on the scalar machine, both formats are cache/bandwidth-bound, so
//!   ELL only wins its loop-overhead margin and loses it to zero-fill as
//!   `D_mat` grows.
//!
//! [`MeasuredBackend`] is the third backend: real wall-clock measurements
//! of this library's kernels on the host CPU. The AT engine is generic
//! over [`Backend`], so every experiment can run on all three.
//!
//! [`topology`] describes the *host* machine itself — socket/core layout
//! from sysfs (or the `SPMV_AT_TOPOLOGY` override) plus the
//! `sched_setaffinity` shim — so the shard layer can turn key-routing
//! into socket-routing.

pub mod scalar;
pub mod simd;
pub mod topology;
pub mod vector;

pub use topology::Topology;

use crate::formats::{Csr, FormatKind, SparseMatrix};
use crate::spmv::pool::{self, ParPool};
use crate::spmv::{Implementation, SpmvPlan};
use crate::{Result, Value};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// The size/shape summary a cost model consumes. Everything the paper's
/// analysis depends on: dimension, nnz, row-length moments, the ELL
/// bandwidth and fill ratio.
#[derive(Clone, Copy, Debug)]
pub struct MatrixShape {
    /// Rows.
    pub n: usize,
    /// Columns.
    pub n_cols: usize,
    /// Stored non-zeros.
    pub nnz: usize,
    /// Mean non-zeros per row (μ).
    pub mu: f64,
    /// Std of non-zeros per row (σ).
    pub sigma: f64,
    /// Max row length = ELL bandwidth `nz`.
    pub bandwidth: usize,
    /// `n·nz / nnz` — ELL padding waste (≥ 1).
    pub fill_ratio: f64,
}

impl MatrixShape {
    /// Compute the shape summary of a CSR matrix (one O(n) pass).
    pub fn of(a: &Csr) -> Self {
        let n = a.n_rows();
        let nnz = a.nnz();
        let mut bw = 0usize;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for i in 0..n {
            let l = a.row_len(i);
            bw = bw.max(l);
            sum += l as f64;
            sum2 += (l * l) as f64;
        }
        let mu = if n == 0 { 0.0 } else { sum / n as f64 };
        let var = if n == 0 { 0.0 } else { (sum2 / n as f64) - mu * mu };
        MatrixShape {
            n,
            n_cols: a.n_cols(),
            nnz,
            mu,
            sigma: var.max(0.0).sqrt(),
            bandwidth: bw,
            fill_ratio: if nnz == 0 { 1.0 } else { (n * bw) as f64 / nnz as f64 },
        }
    }

    /// `D_mat = σ/μ` (paper Eq. 4).
    pub fn d_mat(&self) -> f64 {
        if self.mu > 0.0 {
            self.sigma / self.mu
        } else {
            0.0
        }
    }
}

/// An analytic per-machine cost model (pure function of [`MatrixShape`]).
pub trait CostModel: Send + Sync {
    /// Machine name for reports ("ES2", "SR16000", …).
    fn name(&self) -> &'static str;
    /// Hardware thread count of one node.
    fn max_threads(&self) -> usize;
    /// Predicted SpMV seconds for `imp` on a matrix of this shape.
    fn spmv_seconds(&self, m: &MatrixShape, imp: Implementation, threads: usize) -> f64;
    /// Predicted seconds to transform CRS into `target`.
    fn transform_seconds(&self, m: &MatrixShape, target: FormatKind) -> f64;
}

/// A source of `t_crs` / `t_imp` / `t_trans` numbers — either simulated
/// ([`SimulatedBackend`]) or measured on the host ([`MeasuredBackend`]).
pub trait Backend {
    /// Backend name for reports.
    fn name(&self) -> String;
    /// Max threads this backend can evaluate.
    fn max_threads(&self) -> usize;
    /// SpMV seconds for implementation `imp` at `threads`.
    fn spmv_seconds(&self, a: &Csr, imp: Implementation, threads: usize) -> Result<f64>;
    /// Seconds to transform CRS to the format `imp` needs (0 for CRS itself).
    fn transform_seconds(&self, a: &Csr, imp: Implementation) -> Result<f64>;
}

/// Backend wrapping an analytic [`CostModel`].
pub struct SimulatedBackend<M: CostModel> {
    model: M,
}

impl<M: CostModel> SimulatedBackend<M> {
    /// Wrap a cost model.
    pub fn new(model: M) -> Self {
        Self { model }
    }

    /// Access the inner model.
    pub fn model(&self) -> &M {
        &self.model
    }
}

impl<M: CostModel> Backend for SimulatedBackend<M> {
    fn name(&self) -> String {
        format!("sim:{}", self.model.name())
    }

    fn max_threads(&self) -> usize {
        self.model.max_threads()
    }

    fn spmv_seconds(&self, a: &Csr, imp: Implementation, threads: usize) -> Result<f64> {
        anyhow::ensure!(threads >= 1, "threads must be >= 1");
        let shape = MatrixShape::of(a);
        Ok(self.model.spmv_seconds(&shape, imp, threads.min(self.model.max_threads())))
    }

    fn transform_seconds(&self, a: &Csr, imp: Implementation) -> Result<f64> {
        let shape = MatrixShape::of(a);
        Ok(if imp.needs_transform() {
            self.model.transform_seconds(&shape, imp.required_format())
        } else {
            0.0
        })
    }
}

/// Backend measuring the library's real kernels on the host CPU. Kernel
/// runs execute through a cached [`SpmvPlan`] on a persistent pool of the
/// requested width (pools are cached per thread count so repeated
/// offline-phase measurements never re-spawn workers).
pub struct MeasuredBackend {
    /// Unmeasured warmup repetitions.
    pub warmup: usize,
    /// Measured repetitions (median taken).
    pub reps: usize,
    pools: Mutex<HashMap<usize, Arc<ParPool>>>,
}

impl Default for MeasuredBackend {
    fn default() -> Self {
        Self::new(1, 5)
    }
}

impl MeasuredBackend {
    /// Backend with explicit repetition counts.
    pub fn new(warmup: usize, reps: usize) -> Self {
        Self { warmup, reps, pools: Mutex::new(HashMap::new()) }
    }

    fn pool(&self, threads: usize) -> Arc<ParPool> {
        if threads == pool::configured_threads() {
            return pool::global();
        }
        self.pools
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .entry(threads)
            .or_insert_with(|| Arc::new(ParPool::new(threads)))
            .clone()
    }

    /// Measured **per-SpMV** seconds of a tiled `execute_many` batch of
    /// `batch` right-hand sides (optionally at a forced tile width) —
    /// the SpMM counterpart of [`Backend::spmv_seconds`], used by the
    /// amortisation bench's tile sweep. Dividing the batch wall time by
    /// `batch` makes the number directly comparable to the single-RHS
    /// measurement.
    pub fn spmm_seconds_per_rhs(
        &self,
        a: &Csr,
        imp: Implementation,
        threads: usize,
        batch: usize,
        tile: Option<usize>,
    ) -> Result<f64> {
        anyhow::ensure!(threads >= 1, "threads must be >= 1");
        anyhow::ensure!(batch >= 1, "batch must be >= 1");
        let mut plan = SpmvPlan::build_ref(a, imp, None, self.pool(threads))?;
        if let Some(t) = tile {
            plan.set_batch_tile(t);
        }
        let xs: Vec<Vec<Value>> = (0..batch)
            .map(|j| (0..a.n_cols()).map(|i| 1.0 + ((i + j) % 7) as f64 * 0.125).collect())
            .collect();
        let mut ys = vec![vec![0.0; a.n_rows()]; batch];
        // Prime the workspace outside the timed region.
        plan.execute_many(&xs, &mut ys)?;
        let t = crate::metrics::time_median(self.warmup, self.reps, || {
            plan.execute_many(&xs, &mut ys).expect("kernel run");
        });
        std::hint::black_box(&ys);
        Ok(t / batch as f64)
    }
}

impl Backend for MeasuredBackend {
    fn name(&self) -> String {
        format!("host:{}t", pool::configured_threads())
    }

    fn max_threads(&self) -> usize {
        pool::configured_threads()
    }

    fn spmv_seconds(&self, a: &Csr, imp: Implementation, threads: usize) -> Result<f64> {
        anyhow::ensure!(threads >= 1, "threads must be >= 1");
        let mut plan = SpmvPlan::build_ref(a, imp, None, self.pool(threads))?;
        let x: Vec<Value> = (0..a.n_cols()).map(|i| 1.0 + (i % 7) as f64 * 0.125).collect();
        let mut y = vec![0.0; a.n_rows()];
        // Prime the workspace outside the timed region.
        plan.execute(&x, &mut y)?;
        let t = crate::metrics::time_median(self.warmup, self.reps, || {
            plan.execute(&x, &mut y).expect("kernel run");
        });
        std::hint::black_box(&y);
        Ok(t)
    }

    fn transform_seconds(&self, a: &Csr, imp: Implementation) -> Result<f64> {
        if !imp.needs_transform() {
            return Ok(0.0);
        }
        let target = imp.required_format();
        // Time the same pool-parallel pipeline `SpmvPlan::build` pays, so
        // break-even accounting reflects the cost actually incurred.
        let pool = pool::global();
        let t = crate::metrics::time_median(self.warmup.min(1), self.reps.min(3), || {
            let m = crate::transform::par::transform_to_on(a, target, None, &pool)
                .expect("transform");
            std::hint::black_box(&m);
        });
        Ok(t)
    }
}

/// Helper shared by cost models: transformation byte traffic from CRS into
/// `target` (reads of the CRS arrays + writes of the target arrays).
pub(crate) fn transform_bytes(m: &MatrixShape, target: FormatKind) -> f64 {
    let vb = std::mem::size_of::<Value>() as f64;
    let ib = std::mem::size_of::<crate::Index>() as f64;
    let nnz = m.nnz as f64;
    let n = m.n as f64;
    let read_crs = nnz * (vb + ib) + n * 8.0;
    match target {
        FormatKind::Csr => 0.0,
        // COO-Row: copy VAL/ICOL, write IROW.
        FormatKind::CooRow => read_crs + nnz * (vb + 2.0 * ib),
        // CCS: counting pass reads ICOL, then scatter writes VAL/IROW with
        // random access; Phase II adds the ICOL expansion for COO-Col.
        FormatKind::Csc => 2.0 * read_crs + nnz * (vb + ib) + n * 8.0,
        FormatKind::CooCol => 2.0 * read_crs + nnz * (2.0 * vb + 3.0 * ib) + n * 8.0,
        // ELL: read CRS once, write (and first zero) n*bw slots.
        FormatKind::Ell => {
            let slots = n * m.bandwidth as f64;
            read_crs + 1.5 * slots * (vb + ib)
        }
        // BCSR: block discovery (two passes) + block fill.
        FormatKind::Bcsr => 2.0 * read_crs + nnz * (vb + ib) * m.fill_ratio.min(4.0),
        // JDS: counting sort by length (two O(n) passes) + diagonal gather.
        FormatKind::Jds => 2.0 * read_crs + nnz * (vb + ib) + n * 16.0,
        // HYB: histogram pass + body fill (capped slots) + tail copy.
        FormatKind::Hyb => {
            let body_slots = n * (m.mu * 1.5).ceil().min(m.bandwidth as f64);
            1.5 * read_crs + 1.5 * body_slots * (vb + ib) + 0.1 * nnz * (vb + 2.0 * ib)
        }
        // SELL-C-σ: σ-window length sort (row-length pass) + scatter into
        // per-chunk-padded slots. The sort shrinks padding towards zero,
        // so the slot estimate keeps only a fraction of ELL's waste (the
        // memory policy uses the same retention factor), plus the
        // perm/row_len side arrays.
        FormatKind::Sell => {
            let slots = nnz * (1.0 + 0.15 * (m.fill_ratio - 1.0).max(0.0));
            1.5 * read_crs + 1.5 * slots * (vb + ib) + n * 2.0 * ib
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;

    #[test]
    fn shape_of_matches_direct_stats() {
        let mut rng = Rng::new(3);
        let a = random_csr(&mut rng, 60, 60, 0.1);
        let s = MatrixShape::of(&a);
        assert_eq!(s.n, 60);
        assert_eq!(s.nnz, a.nnz());
        let m = crate::matrixgen::suite::measure(&a);
        assert!((s.mu - m.mu).abs() < 1e-12);
        assert!((s.sigma - m.sigma).abs() < 1e-9);
        assert_eq!(s.bandwidth, m.max_row);
        assert!(s.fill_ratio >= 1.0);
    }

    #[test]
    fn measured_backend_times_are_positive_and_ordered() {
        let mut rng = Rng::new(4);
        let a = random_csr(&mut rng, 300, 300, 0.05);
        let b = MeasuredBackend::new(0, 3);
        let t_crs = b.spmv_seconds(&a, Implementation::CsrSeq, 1).unwrap();
        assert!(t_crs > 0.0);
        let t_spmm = b
            .spmm_seconds_per_rhs(&a, Implementation::CsrSeq, 1, 4, Some(4))
            .unwrap();
        assert!(t_spmm > 0.0);
        assert!(b.spmm_seconds_per_rhs(&a, Implementation::CsrSeq, 1, 0, None).is_err());
        let t_tr = b.transform_seconds(&a, Implementation::EllRowInner).unwrap();
        assert!(t_tr > 0.0);
        assert_eq!(
            b.transform_seconds(&a, Implementation::CsrSeq).unwrap(),
            0.0,
            "CRS needs no transform"
        );
    }

    #[test]
    fn transform_bytes_monotone_in_fill() {
        let lo = MatrixShape {
            n: 1000, n_cols: 1000, nnz: 5000, mu: 5.0, sigma: 0.0,
            bandwidth: 5, fill_ratio: 1.0,
        };
        let hi = MatrixShape { bandwidth: 50, fill_ratio: 10.0, ..lo };
        assert!(
            transform_bytes(&hi, FormatKind::Ell) > transform_bytes(&lo, FormatKind::Ell),
            "more padding must cost more"
        );
        assert_eq!(transform_bytes(&lo, FormatKind::Csr), 0.0);
    }
}
