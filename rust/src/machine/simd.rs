//! Host SIMD capability detection for the SELL-C-σ kernel layer.
//!
//! SELL-C-σ's chunk height C is a *storage* parameter: picking C equal to
//! (a small multiple of) the hardware vector width keeps every full band
//! a whole number of vector registers. This module answers "what is that
//! width here?" so `docs/TUNING.md`'s C guidance and the benches can
//! report it, and exposes whether the crate was built with the `simd`
//! cargo feature (which swaps the SELL band loop for explicitly unrolled
//! lane blocks; see `spmv::sell_row_inner_on`).
//!
//! Everything here is stable Rust: detection uses
//! `is_x86_feature_detected!` where available and falls back to scalar
//! (1 lane) elsewhere. No nightly `std::simd` is required — on targets
//! without detection the unrolled loops still compile and simply rely on
//! autovectorization.

/// Whether the `simd` cargo feature (explicitly unrolled SELL band
/// loops) is compiled in.
pub fn simd_enabled() -> bool {
    cfg!(feature = "simd")
}

/// Best-effort f64 lanes per vector register on the host CPU: 8 under
/// AVX-512, 4 under AVX2, 2 under SSE2, 1 when nothing is detectable.
/// Chunk heights that are a multiple of this (the
/// `crate::transform::DEFAULT_SELL_C` default of 8 covers all of them)
/// keep SELL's full bands register-aligned.
pub fn simd_lanes() -> usize {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            8
        } else if std::arch::is_x86_feature_detected!("avx2") {
            4
        } else if std::arch::is_x86_feature_detected!("sse2") {
            2
        } else {
            1
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is baseline on aarch64: 128-bit registers, 2 × f64.
        2
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_is_a_sane_power_of_two() {
        let l = simd_lanes();
        assert!(l.is_power_of_two(), "{l}");
        assert!(l <= 8, "{l}");
    }

    #[test]
    fn default_sell_c_is_lane_aligned() {
        assert_eq!(crate::transform::DEFAULT_SELL_C % simd_lanes(), 0);
    }

    #[test]
    fn feature_flag_is_consistent() {
        assert_eq!(simd_enabled(), cfg!(feature = "simd"));
    }
}
