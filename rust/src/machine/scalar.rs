//! Scalar/cache-machine cost model — the HITACHI SR16000/VL1 stand-in.
//!
//! One node: 64 × IBM POWER6 at 5.0 GHz (128 SMT threads), 64 KB L1 +
//! 4 MB L2 per core, 32 MB L3 per core pair, big but finite memory
//! bandwidth.
//!
//! Mechanisms modelled (the paper's Fig. 5 behaviour):
//!
//! * Both CRS and ELL stream their value/index arrays; per-element compute
//!   cost is a few cycles with out-of-order overlap. CRS additionally pays
//!   per-row loop/branch bookkeeping — the only margin ELL can win
//!   (≤ 2.45× at 1 thread, and only when μ is small so the bookkeeping
//!   share is large).
//! * ELL's zero padding multiplies its element count by `fill_ratio`; as
//!   `D_mat` grows the padding swallows the bookkeeping win — matrices
//!   with `D_mat ≳ 0.1` stop benefiting (the paper's Fig. 8 SR16000 rule).
//! * Thread scaling is compute-bound at first, then saturates on the
//!   node's memory bandwidth — by 64–128 threads every format is
//!   bandwidth-bound and "there is no advantage of ELL".
//! * The CRS→ELL transformation is latency/allocation-bound on a cache
//!   machine: zeroing + scattering `n·nz` padded slots costs 20–50 CRS
//!   SpMVs for high-fill matrices (Fig. 7, memplus & sme3D*).

use super::{transform_bytes, CostModel, MatrixShape};
use crate::formats::FormatKind;
use crate::spmv::Implementation;

/// Tunable parameters of the scalar model (cycles unless noted).
#[derive(Clone, Debug)]
pub struct ScalarParams {
    /// Core clock in Hz (POWER6: 5.0 GHz).
    pub clock_hz: f64,
    /// Hardware threads per node (64 cores × 2 SMT).
    pub threads: usize,
    /// Per-element cost of the CRS inner loop (load val/icol, gather x, fma).
    pub crs_elem: f64,
    /// Per-row loop/branch/store bookkeeping of CRS.
    pub row_overhead: f64,
    /// Per-element cost of the ELL band sweep (better pipelined: no branch,
    /// unit-stride val/icol).
    pub ell_elem: f64,
    /// Per-element cost of the COO stream (extra irow load + indirect YY add).
    pub coo_elem: f64,
    /// Per-element cost of the serial YY reduction.
    pub reduce_elem: f64,
    /// Thread fork/join overhead per parallel region, cycles.
    pub fork: f64,
    /// Single-thread sustainable memory bandwidth, bytes/s.
    pub mem_bw_1t: f64,
    /// Node-level saturated memory bandwidth, bytes/s.
    pub mem_bw_node: f64,
    /// Threads at which bandwidth saturates.
    pub bw_knee: f64,
    /// Gather miss penalty (cycles) applied per element for matrices whose
    /// x-vector spills L2 (scaled by a locality factor).
    pub miss_penalty: f64,
    /// L2 capacity per core, bytes.
    pub l2_bytes: f64,
}

impl Default for ScalarParams {
    fn default() -> Self {
        Self {
            clock_hz: 5.0e9,
            threads: 128,
            crs_elem: 3.0,
            row_overhead: 40.0,
            ell_elem: 2.4,
            coo_elem: 5.0,
            reduce_elem: 1.5,
            fork: 40_000.0,
            mem_bw_1t: 20e9,
            mem_bw_node: 160e9,
            bw_knee: 8.0,
            miss_penalty: 90.0,
            l2_bytes: 4.0 * 1024.0 * 1024.0,
        }
    }
}

/// The SR16000/VL1 stand-in. See module docs for the modelled mechanisms.
pub struct ScalarMachine {
    /// Model parameters (public so ablation benches can perturb them).
    pub p: ScalarParams,
}

impl Default for ScalarMachine {
    fn default() -> Self {
        Self { p: ScalarParams::default() }
    }
}

impl ScalarMachine {
    /// Model with explicit parameters.
    pub fn new(p: ScalarParams) -> Self {
        Self { p }
    }

    /// Aggregate memory bandwidth available to `t` threads: linear up to
    /// the knee, flat at the node ceiling after.
    fn bw(&self, t: usize) -> f64 {
        let t = (t.max(1) as f64).min(self.p.bw_knee);
        (self.p.mem_bw_1t * t).min(self.p.mem_bw_node)
    }

    /// Probability-weighted gather penalty per element: 0 when x fits in
    /// L2, growing with the x footprint (random column access pattern).
    fn gather_penalty(&self, m: &MatrixShape) -> f64 {
        let x_bytes = m.n_cols as f64 * 8.0;
        if x_bytes <= self.p.l2_bytes {
            0.0
        } else {
            // Fraction of x accesses that miss; saturates at 35%.
            let over = 1.0 - self.p.l2_bytes / x_bytes;
            self.p.miss_penalty * 0.35 * over
        }
    }

    /// Roofline combine: max of compute time and memory-traffic time.
    fn roofline(&self, cycles: f64, bytes: f64, t: usize) -> f64 {
        let compute = cycles / self.p.clock_hz;
        let memory = bytes / self.bw(t);
        compute.max(memory)
    }

    /// Parallel compute scaling (linear to core count, weak SMT gain after 64).
    fn par(&self, t: usize) -> f64 {
        let t = t.max(1) as f64;
        if t <= 64.0 {
            t
        } else {
            64.0 * (t / 64.0).powf(0.3)
        }
    }
}

impl CostModel for ScalarMachine {
    fn name(&self) -> &'static str {
        "SR16000"
    }

    fn max_threads(&self) -> usize {
        self.p.threads
    }

    fn spmv_seconds(&self, m: &MatrixShape, imp: Implementation, threads: usize) -> f64 {
        let t = threads.clamp(1, self.p.threads);
        let n = m.n as f64;
        let nnz = m.nnz as f64;
        let slots = n * m.bandwidth as f64;
        let gp = self.gather_penalty(m);
        let fork = if t > 1 { self.p.fork / self.p.clock_hz } else { 0.0 };
        match imp {
            Implementation::CsrSeq => {
                let cycles = nnz * (self.p.crs_elem + gp) + n * self.p.row_overhead;
                let bytes = nnz * 12.0 + n * 24.0;
                self.roofline(cycles, bytes, 1)
            }
            Implementation::CsrRowPar => {
                let cycles = (nnz * (self.p.crs_elem + gp) + n * self.p.row_overhead) / self.par(t);
                let bytes = nnz * 12.0 + n * 24.0;
                self.roofline(cycles, bytes, t) + fork
            }
            Implementation::CsrMergePar => {
                // Row-parallel CRS work, perfectly nnz-balanced by the
                // merge split, plus the serial per-chunk carry fixup
                // (O(t) adds) — negligible next to the fork cost.
                let cycles = (nnz * (self.p.crs_elem + gp) + n * self.p.row_overhead) / self.par(t)
                    + 2.0 * t as f64 * self.p.reduce_elem;
                let bytes = nnz * 12.0 + n * 24.0;
                self.roofline(cycles, bytes, t) + fork
            }
            Implementation::EllRowInner => {
                let cycles = slots * (self.p.ell_elem + gp) / self.par(t);
                let bytes = slots * 12.0 + n * 16.0;
                self.roofline(cycles, bytes, t) + fork
            }
            Implementation::EllRowOuter => {
                let t_eff = (t as f64).min(m.bandwidth.max(1) as f64);
                let sweep = slots * (self.p.ell_elem + gp) / t_eff;
                let reduce = if t > 1 { t as f64 * n * self.p.reduce_elem } else { 0.0 };
                let bytes = slots * 12.0 + (1.0 + t as f64) * n * 8.0;
                self.roofline(sweep + reduce, bytes, t) + fork
            }
            Implementation::CooRowOuter | Implementation::CooColOuter => {
                let stream = nnz * (self.p.coo_elem + gp) / self.par(t);
                let reduce = if t > 1 { t as f64 * n * self.p.reduce_elem } else { 0.0 };
                let bytes = nnz * 16.0 + (1.0 + t as f64) * n * 8.0;
                self.roofline(stream + reduce, bytes, t) + fork
            }
            Implementation::BcsrSeq => {
                // 2x2 blocks: fewer index loads, some zero fill (~fill-capped).
                let eff = nnz * m.fill_ratio.min(2.0);
                let cycles = eff * (self.p.crs_elem * 0.7 + gp) + n * self.p.row_overhead * 0.5;
                let bytes = eff * 9.0 + n * 24.0;
                self.roofline(cycles, bytes, 1)
            }
            Implementation::JdsSeq => {
                // Extension: no fill, but the permuted y access costs an
                // extra indirection per element on a cache machine.
                let cycles = nnz * (self.p.crs_elem + 1.0 + gp) + n * 6.0;
                let bytes = nnz * 12.0 + n * 28.0;
                self.roofline(cycles, bytes, 1)
            }
            Implementation::SellRowInner => {
                // Extension: ELL's branch-free band sweep, but the σ-sort
                // removes ~85% of the padding (slots shrink towards nnz);
                // the price is a permuted y store plus per-chunk tail
                // bookkeeping, a few extra cycles per row.
                let sell_slots = nnz * (1.0 + 0.15 * (m.fill_ratio - 1.0).max(0.0));
                let cycles = (sell_slots * (self.p.ell_elem + gp) + n * 4.0) / self.par(t);
                let bytes = sell_slots * 12.0 + n * 24.0;
                self.roofline(cycles, bytes, t) + fork
            }
            Implementation::HybSeq => {
                // Extension: ELL body at ~1.5μ bandwidth + COO tail.
                let body_slots = n * (m.mu * 1.5).ceil().min(m.bandwidth as f64).max(1.0);
                let tail_frac = (0.12 * (1.0 - 1.5 / m.fill_ratio)).max(0.0);
                let cycles = body_slots * (self.p.ell_elem + gp)
                    + tail_frac * nnz * (self.p.coo_elem + gp);
                let bytes = body_slots * 12.0 + tail_frac * nnz * 16.0 + n * 16.0;
                self.roofline(cycles, bytes, 1)
            }
        }
    }

    fn transform_seconds(&self, m: &MatrixShape, target: FormatKind) -> f64 {
        let bytes = transform_bytes(m, target);
        // Cache-machine transforms are latency-bound scatters plus
        // allocation/zeroing; effective bandwidth is a fraction of stream
        // bandwidth, and the counting transform pays per-element latency.
        let (eff_bw, extra_cycles) = match target {
            FormatKind::Csr => (self.p.mem_bw_1t, 0.0),
            FormatKind::CooRow => (self.p.mem_bw_1t * 0.6, m.nnz as f64 * 1.0),
            FormatKind::Csc | FormatKind::CooCol => {
                (self.p.mem_bw_1t * 0.35, m.nnz as f64 * (4.0 + self.gather_penalty(m)))
            }
            FormatKind::Ell => {
                // malloc + zero + scatter of n*nz slots.
                (self.p.mem_bw_1t * 0.6, m.nnz as f64 * 2.0)
            }
            FormatKind::Bcsr => (self.p.mem_bw_1t * 0.35, m.nnz as f64 * 6.0),
            FormatKind::Jds => (self.p.mem_bw_1t * 0.5, m.nnz as f64 * 3.0),
            FormatKind::Hyb => (self.p.mem_bw_1t * 0.5, m.nnz as f64 * 2.5),
            // SELL-C-σ: σ-window sort (cheap, window-local) + scatter into
            // chunk-padded slots — close to JDS's sort-and-gather profile.
            FormatKind::Sell => (self.p.mem_bw_1t * 0.55, m.nnz as f64 * 2.5),
        };
        bytes / eff_bw + extra_cycles / self.p.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chem_master() -> MatrixShape {
        MatrixShape {
            n: 40_401, n_cols: 40_401, nnz: 201_201,
            mu: 4.98, sigma: 0.14, bandwidth: 6,
            fill_ratio: 40_401.0 * 6.0 / 201_201.0,
        }
    }

    fn memplus() -> MatrixShape {
        MatrixShape {
            n: 17_758, n_cols: 17_758, nnz: 126_150,
            mu: 7.10, sigma: 22.03, bandwidth: 574,
            fill_ratio: 17_758.0 * 574.0 / 126_150.0,
        }
    }

    fn sme3da() -> MatrixShape {
        MatrixShape {
            n: 12_504, n_cols: 12_504, nnz: 874_887,
            mu: 69.96, sigma: 34.92, bandwidth: 345,
            fill_ratio: 12_504.0 * 345.0 / 874_887.0,
        }
    }

    #[test]
    fn small_dmat_gets_modest_ell_win_at_one_thread() {
        let mch = ScalarMachine::default();
        let m = chem_master();
        let sp = mch.spmv_seconds(&m, Implementation::CsrSeq, 1)
            / mch.spmv_seconds(&m, Implementation::EllRowInner, 1);
        // Paper: max 2.45x on SR16000 (chem_master1, 1 thread).
        assert!((1.3..3.5).contains(&sp), "SP = {sp}");
    }

    #[test]
    fn high_dmat_loses_on_scalar_machine() {
        let mch = ScalarMachine::default();
        let m = memplus();
        let sp = mch.spmv_seconds(&m, Implementation::CsrSeq, 1)
            / mch.spmv_seconds(&m, Implementation::EllRowInner, 1);
        assert!(sp < 1.0, "memplus ELL should lose: SP = {sp}");
    }

    #[test]
    fn advantage_dies_at_high_thread_count() {
        let mch = ScalarMachine::default();
        let m = chem_master();
        let sp128 = mch.spmv_seconds(&m, Implementation::CsrRowPar, 128)
            / mch.spmv_seconds(&m, Implementation::EllRowInner, 128);
        // Paper: "there is no advantage of ELL for 64 and 128 threads".
        assert!(sp128 < 1.4, "SP at 128 threads = {sp128}");
    }

    #[test]
    fn transform_overhead_tens_of_spmvs_for_high_fill() {
        let mch = ScalarMachine::default();
        for (m, lo, hi) in [(memplus(), 10.0, 150.0), (sme3da(), 3.0, 80.0)] {
            let ratio = mch.transform_seconds(&m, FormatKind::Ell)
                / mch.spmv_seconds(&m, Implementation::CsrSeq, 1);
            // Paper Fig. 7: 20x–50x for these matrices.
            assert!((lo..hi).contains(&ratio), "t_trans/t_crs = {ratio}");
        }
    }

    #[test]
    fn transform_overhead_small_for_low_fill() {
        let mch = ScalarMachine::default();
        let m = chem_master();
        let ratio = mch.transform_seconds(&m, FormatKind::Ell)
            / mch.spmv_seconds(&m, Implementation::CsrSeq, 1);
        assert!(ratio < 10.0, "t_trans/t_crs = {ratio}");
    }

    #[test]
    fn thread_scaling_saturates() {
        let mch = ScalarMachine::default();
        let m = sme3da();
        let t1 = mch.spmv_seconds(&m, Implementation::CsrRowPar, 1);
        let t16 = mch.spmv_seconds(&m, Implementation::CsrRowPar, 16);
        let t128 = mch.spmv_seconds(&m, Implementation::CsrRowPar, 128);
        assert!(t16 < t1);
        // Saturation: 128t is not 8x faster than 16t.
        assert!(t128 > t16 / 8.0, "t128 {t128} vs t16 {t16}");
    }
}
