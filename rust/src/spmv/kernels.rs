//! Kernel registry: named SpMV implementations over owned format data.
//!
//! [`Implementation`] enumerates the paper's five parallel codes plus the
//! sequential baseline and the BCSR extension; [`AnyMatrix`] owns a matrix
//! in whichever format an implementation needs, so the plan layer can hold
//! "the chosen representation" as a single value. [`run_on`] is the single
//! dispatch point from `(Implementation, AnyMatrix)` to a kernel: it takes
//! a [`ParPool`] plus precomputed partitions ([`partition_for`]) so a
//! cached [`super::plan::SpmvPlan`] pays no partitioning cost per call;
//! [`run`] is the compatibility wrapper that partitions on the fly and
//! executes on the global pool.

use super::pool::{self, ParPool};
use super::Workspace;
use crate::formats::{
    Bcsr, Coo, CooOrder, Csc, Csr, Ell, FormatKind, Hyb, Jds, SellCSigma, SparseMatrix,
};
use crate::spmv::partition::{
    merge_path_split, merge_row_aligned, split_by_nnz, split_even, Partition, PartitionStrategy,
};
use crate::transform;
use crate::{Index, Result, Value};
use std::sync::Arc;

/// A named SpMV implementation (paper §3 + baseline + extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Implementation {
    /// OpenATLib `OpenATI_DURMV` switch 11: sequential CRS.
    CsrSeq,
    /// Row-parallel CRS (nnz-balanced) — the multi-thread baseline.
    CsrRowPar,
    /// Fig. 1: COO-Column, outer-parallelised entry stream.
    CooColOuter,
    /// Fig. 2: COO-Row, outer-parallelised entry stream.
    CooRowOuter,
    /// Fig. 3: ELL-Row, inner `N`-loop parallelised.
    EllRowInner,
    /// Fig. 4: ELL-Row, outer band-loop parallelised (parallelism ≤ NE).
    EllRowOuter,
    /// BCSR 2×2 register-blocked (paper future work; sequential kernel).
    BcsrSeq,
    /// JDS diagonal-sweep (extension; sequential, vectorisable).
    JdsSeq,
    /// HYB body+tail (extension; sequential).
    HybSeq,
    /// SELL-C-σ chunk-parallel kernel (extension): lane-width-C chunks,
    /// σ-window sorted rows, output merged through the row permutation.
    SellRowInner,
    /// Merge-path parallel CRS (extension): 2-D merge chunks that may cut
    /// rows, carry slots + deterministic serial fixup. Runs on CRS data
    /// directly (no transform), so it is a zero-setup-cost rival to
    /// [`Implementation::CsrRowPar`] on skewed row-length distributions.
    CsrMergePar,
}

impl Implementation {
    /// Every implementation, in the order the paper's figures report them.
    pub const ALL: [Implementation; 11] = [
        Implementation::CsrSeq,
        Implementation::CsrRowPar,
        Implementation::CooColOuter,
        Implementation::CooRowOuter,
        Implementation::EllRowInner,
        Implementation::EllRowOuter,
        Implementation::BcsrSeq,
        Implementation::JdsSeq,
        Implementation::HybSeq,
        Implementation::SellRowInner,
        Implementation::CsrMergePar,
    ];

    /// The candidates the paper's AT method chooses between at run time
    /// (its figures 5–8 series, excluding the baseline itself).
    pub const AT_CANDIDATES: [Implementation; 4] = [
        Implementation::CooColOuter,
        Implementation::CooRowOuter,
        Implementation::EllRowInner,
        Implementation::EllRowOuter,
    ];

    /// Stable display name (matches the paper's legend strings).
    pub fn name(self) -> &'static str {
        match self {
            Implementation::CsrSeq => "CRS",
            Implementation::CsrRowPar => "CRS-Par",
            Implementation::CooColOuter => "COO-Col Outer",
            Implementation::CooRowOuter => "COO-Row Outer",
            Implementation::EllRowInner => "ELL-Row Inner",
            Implementation::EllRowOuter => "ELL-Row Outer",
            Implementation::BcsrSeq => "BCSR",
            Implementation::JdsSeq => "JDS",
            Implementation::HybSeq => "HYB",
            Implementation::SellRowInner => "SELL-Row Inner",
            Implementation::CsrMergePar => "CRS-Merge",
        }
    }

    /// Parse a CLI/report name. Bare `"ell"` means the paper's headline
    /// ELL-Row *inner* kernel (Fig. 3); the outer variant must be named
    /// explicitly (`"ellouter"` / `"ell-row-outer"`).
    pub fn parse(s: &str) -> Option<Self> {
        let norm: String = s
            .to_ascii_lowercase()
            .chars()
            .filter(|c| c.is_ascii_alphanumeric())
            .collect();
        Some(match norm.as_str() {
            "crs" | "csr" | "crsseq" | "csrseq" => Implementation::CsrSeq,
            "crspar" | "csrpar" | "csrrowpar" => Implementation::CsrRowPar,
            "coocolouter" | "coocol" => Implementation::CooColOuter,
            "coorowouter" | "coorow" => Implementation::CooRowOuter,
            "ellrowinner" | "ellinner" | "ell" => Implementation::EllRowInner,
            "ellrowouter" | "ellouter" => Implementation::EllRowOuter,
            "bcsr" | "bcsrseq" => Implementation::BcsrSeq,
            "jds" | "jdsseq" => Implementation::JdsSeq,
            "hyb" | "hybseq" => Implementation::HybSeq,
            "sellrowinner" | "sellinner" | "sellcsigma" | "sell" => Implementation::SellRowInner,
            "crsmerge" | "csrmerge" | "merge" | "crsmergepar" | "csrmergepar" => {
                Implementation::CsrMergePar
            }
            _ => return None,
        })
    }

    /// The storage format this implementation runs on.
    pub fn required_format(self) -> FormatKind {
        match self {
            Implementation::CsrSeq | Implementation::CsrRowPar | Implementation::CsrMergePar => {
                FormatKind::Csr
            }
            Implementation::CooColOuter => FormatKind::CooCol,
            Implementation::CooRowOuter => FormatKind::CooRow,
            Implementation::EllRowInner | Implementation::EllRowOuter => FormatKind::Ell,
            Implementation::BcsrSeq => FormatKind::Bcsr,
            Implementation::JdsSeq => FormatKind::Jds,
            Implementation::HybSeq => FormatKind::Hyb,
            Implementation::SellRowInner => FormatKind::Sell,
        }
    }

    /// Whether the implementation needs a data transformation away from CRS.
    pub fn needs_transform(self) -> bool {
        self.required_format() != FormatKind::Csr
    }

    /// Whether a row split of the operator leaves this kernel's results
    /// bitwise-identical to the unsplit execution: every output row must
    /// be produced by exactly one row block with unchanged per-row
    /// accumulation order. True for the row-oriented kernels (the set
    /// [`crate::coordinator::shards::ShardedPlanner::plan_split`]
    /// supports); the COO column-major kernels reorder entries *across*
    /// rows of the whole matrix and are not split-stable, and the
    /// sequential extension formats (BCSR/JDS/HYB) resequence rows or
    /// entries globally too. SELL-C-σ *permutes* rows but accumulates
    /// each one in unchanged CSR entry order and scatters it back through
    /// the permutation, so a row split stays bitwise-identical. CRS-Merge
    /// cuts rows into chunk segments, but every row is still finalised by
    /// exactly one deterministic serial fixup that folds its segments in
    /// CSR element order, and each row block carries its own precomputed
    /// merge coordinates — re-running a split plan is reproducible and
    /// row-owned, which is what the coordinator's split machinery needs.
    pub fn split_stable(self) -> bool {
        matches!(
            self,
            Implementation::CsrSeq
                | Implementation::CsrRowPar
                | Implementation::CsrMergePar
                | Implementation::EllRowInner
                | Implementation::EllRowOuter
                | Implementation::SellRowInner
        )
    }
}

impl std::fmt::Display for Implementation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A matrix owned in any of the library's formats.
///
/// The CRS arm shares the original through an [`Arc`]: a CRS plan (the
/// baseline every registered matrix keeps) is a zero-copy view of the
/// registry's matrix rather than a private clone.
#[derive(Clone, Debug)]
pub enum AnyMatrix {
    /// CRS/CSR, shared with whoever registered the matrix.
    Csr(Arc<Csr>),
    /// CCS/CSC.
    Csc(Csc),
    /// COO (either order; see [`Coo::order`]).
    Coo(Coo),
    /// ELL.
    Ell(Ell),
    /// BCSR.
    Bcsr(Bcsr),
    /// JDS.
    Jds(Jds),
    /// HYB.
    Hyb(Hyb),
    /// SELL-C-σ.
    Sell(SellCSigma),
}

impl AnyMatrix {
    /// Transform a CRS source into whatever `imp` requires, using the
    /// sequential transformations. The CRS case copies `a`; plan
    /// construction goes through [`AnyMatrix::prepare_on`] with a shared
    /// handle instead.
    pub fn prepare(a: &Csr, imp: Implementation, max_bytes: Option<usize>) -> Result<Self> {
        Ok(match imp.required_format() {
            FormatKind::Csr => AnyMatrix::Csr(Arc::new(a.clone())),
            FormatKind::Csc => AnyMatrix::Csc(transform::crs_to_ccs(a)),
            FormatKind::CooRow => AnyMatrix::Coo(transform::crs_to_coo_row(a)),
            FormatKind::CooCol => AnyMatrix::Coo(transform::crs_to_coo_col(a)),
            FormatKind::Ell => AnyMatrix::Ell(transform::crs_to_ell_bounded(a, max_bytes)?),
            FormatKind::Bcsr => AnyMatrix::Bcsr(transform::crs_to_bcsr(a, 2, 2)?),
            FormatKind::Jds => AnyMatrix::Jds(transform::crs_to_jds(a)),
            FormatKind::Hyb => AnyMatrix::Hyb(transform::crs_to_hyb(a)?),
            FormatKind::Sell => AnyMatrix::Sell(transform::crs_to_sell_bounded(a, max_bytes)?),
        })
    }

    /// Transform a CRS source into whatever `imp` requires, running the
    /// parallel transformation pipelines (paper §5 future work) on `pool`
    /// where one exists. This is the plan-construction path; the CRS case
    /// is zero-copy (it clones the `Arc`, not the matrix).
    pub fn prepare_on(
        a: &Arc<Csr>,
        imp: Implementation,
        max_bytes: Option<usize>,
        pool: &ParPool,
    ) -> Result<Self> {
        match imp.required_format() {
            FormatKind::Csr => Ok(AnyMatrix::Csr(Arc::clone(a))),
            _ => Self::transform_on(a, imp, max_bytes, pool),
        }
    }

    /// Like [`AnyMatrix::prepare_on`] for a borrowed CRS nobody shares:
    /// the CRS case copies `a` (pre-`Arc` behaviour), the transformed
    /// cases never copy the source at all. Throwaway measurement plans
    /// build through this.
    pub fn prepare_ref_on(
        a: &Csr,
        imp: Implementation,
        max_bytes: Option<usize>,
        pool: &ParPool,
    ) -> Result<Self> {
        match imp.required_format() {
            FormatKind::Csr => Ok(AnyMatrix::Csr(Arc::new(a.clone()))),
            _ => Self::transform_on(a, imp, max_bytes, pool),
        }
    }

    /// The non-CRS arms shared by [`AnyMatrix::prepare_on`] and
    /// [`AnyMatrix::prepare_ref_on`].
    fn transform_on(
        a: &Csr,
        imp: Implementation,
        max_bytes: Option<usize>,
        pool: &ParPool,
    ) -> Result<Self> {
        Ok(match imp.required_format() {
            FormatKind::Csr => AnyMatrix::Csr(Arc::new(a.clone())),
            FormatKind::Csc => AnyMatrix::Csc(transform::par::crs_to_ccs_on(a, pool)),
            FormatKind::CooRow => AnyMatrix::Coo(transform::par::crs_to_coo_row_on(a, pool)),
            FormatKind::CooCol => AnyMatrix::Coo(transform::par::crs_to_coo_col_on(a, pool)),
            FormatKind::Ell => {
                AnyMatrix::Ell(transform::par::crs_to_ell_bounded_on(a, max_bytes, pool)?)
            }
            FormatKind::Bcsr => AnyMatrix::Bcsr(transform::crs_to_bcsr(a, 2, 2)?),
            FormatKind::Jds => AnyMatrix::Jds(transform::crs_to_jds(a)),
            FormatKind::Hyb => AnyMatrix::Hyb(transform::crs_to_hyb(a)?),
            FormatKind::Sell => {
                AnyMatrix::Sell(transform::par::crs_to_sell_bounded_on(a, max_bytes, pool)?)
            }
        })
    }

    /// Fault the owned arrays into memory from `pool`'s workers via one
    /// [`ParPool::run_init`] fan-out. On a socket-pinned pool this is the
    /// NUMA first-touch/warm pass every plan build pays: freshly
    /// transformed arrays were already written (first-touched) on these
    /// workers by [`crate::transform::par`], and this pass additionally
    /// walks the value/index streams so shared or pre-existing pages
    /// (e.g. the zero-copy CRS original) are faulted and cache-warmed on
    /// the socket that will stream them. Formats without exposed raw
    /// arrays (BCSR/JDS/HYB) still count one init fan-out so a build is
    /// always observable through [`ParPool::init_count`].
    pub fn first_touch_on(&self, pool: &ParPool) {
        let (vals, idx): (&[Value], Option<&[Index]>) = match self {
            AnyMatrix::Csr(m) => (&m.values, Some(&m.col_idx)),
            AnyMatrix::Csc(m) => (&m.values, Some(&m.row_idx)),
            AnyMatrix::Coo(m) => (&m.values, Some(&m.col_idx)),
            AnyMatrix::Ell(m) => (&m.values, Some(&m.col_idx)),
            AnyMatrix::Sell(m) => (&m.values, Some(&m.col_idx)),
            AnyMatrix::Bcsr(_) | AnyMatrix::Jds(_) | AnyMatrix::Hyb(_) => (&[], None),
        };
        let ranges = split_even(vals.len(), pool.size());
        pool.run_init(&ranges, |_tid, r| {
            let mut acc = 0.0f64;
            for &v in &vals[r.clone()] {
                acc += v;
            }
            let mut ci = 0u64;
            if let Some(idx) = idx {
                for &c in &idx[r] {
                    ci = ci.wrapping_add(u64::from(c));
                }
            }
            std::hint::black_box((acc, ci));
        });
    }

    /// View as the dynamic [`SparseMatrix`] trait.
    pub fn as_sparse(&self) -> &dyn SparseMatrix {
        match self {
            AnyMatrix::Csr(m) => m.as_ref(),
            AnyMatrix::Csc(m) => m,
            AnyMatrix::Coo(m) => m,
            AnyMatrix::Ell(m) => m,
            AnyMatrix::Bcsr(m) => m,
            AnyMatrix::Jds(m) => m,
            AnyMatrix::Hyb(m) => m,
            AnyMatrix::Sell(m) => m,
        }
    }

    /// The stored format tag.
    pub fn kind(&self) -> FormatKind {
        self.as_sparse().kind()
    }

    /// Storage footprint in bytes.
    pub fn memory_bytes(&self) -> usize {
        self.as_sparse().memory_bytes()
    }
}

/// Compute the work [`Partition`] `imp` wants over `m` at `n_chunks`-way
/// parallelism. Row-parallel CRS honours the picked [`PartitionStrategy`]
/// (nnz-balanced rows by default, even rows, or the row-aligned
/// projection of the merge boundaries); `CRS-Merge` always computes full
/// 2-D merge coordinates. The remaining kernels keep their natural unit
/// split regardless of strategy — even entry ranges for the COO outer
/// kernels, even row ranges for ELL-inner, band ranges (capped at the
/// bandwidth) for ELL-outer and even **chunk** ranges for SELL (a chunk
/// owns a contiguous storage span and C output rows, so chunk granularity
/// is both false-sharing-free and load-balanced after the σ sort).
/// Sequential implementations get an empty partition. A
/// [`super::plan::SpmvPlan`] computes this once and replays it every
/// call; `strategy = None` means "kernel default" (`ByNnz` for
/// row-parallel CRS).
pub fn partition_for(
    imp: Implementation,
    m: &AnyMatrix,
    n_chunks: usize,
    strategy: Option<PartitionStrategy>,
) -> Partition {
    match (imp, m) {
        (Implementation::CsrRowPar, AnyMatrix::Csr(a)) => {
            let s = strategy.unwrap_or(PartitionStrategy::ByNnz);
            let ranges = match s {
                PartitionStrategy::Even => split_even(a.n_rows(), n_chunks),
                PartitionStrategy::ByNnz => split_by_nnz(&a.row_ptr, n_chunks),
                PartitionStrategy::MergePath => merge_row_aligned(&a.row_ptr, n_chunks),
            };
            Partition::aligned(s, ranges)
        }
        (Implementation::CsrMergePar, AnyMatrix::Csr(a)) => {
            Partition::merged(merge_path_split(&a.row_ptr, n_chunks))
        }
        (Implementation::CooColOuter | Implementation::CooRowOuter, AnyMatrix::Coo(c)) => {
            Partition::aligned(PartitionStrategy::Even, split_even(c.nnz(), n_chunks))
        }
        (Implementation::EllRowInner, AnyMatrix::Ell(e)) => {
            Partition::aligned(PartitionStrategy::Even, split_even(e.n_rows(), n_chunks))
        }
        (Implementation::EllRowOuter, AnyMatrix::Ell(e)) => {
            Partition::aligned(PartitionStrategy::Even, split_even(e.bandwidth, n_chunks))
        }
        (Implementation::SellRowInner, AnyMatrix::Sell(s)) => {
            Partition::aligned(PartitionStrategy::Even, split_even(s.n_chunks(), n_chunks))
        }
        _ => Partition::none(),
    }
}

/// Execute implementation `imp` on `m` over `pool` with the precomputed
/// partition `part` (see [`partition_for`]).
///
/// # Errors
/// Returns an error if `m`'s format does not match `imp`'s requirement.
pub fn run_on(
    imp: Implementation,
    m: &AnyMatrix,
    x: &[Value],
    y: &mut [Value],
    pool: &ParPool,
    part: &Partition,
    ws: &mut Workspace,
) -> Result<()> {
    let ranges = part.ranges.as_slice();
    match (imp, m) {
        (Implementation::CsrSeq, AnyMatrix::Csr(a)) => super::csr_seq(a, x, y),
        (Implementation::CsrRowPar, AnyMatrix::Csr(a)) => {
            super::csr_row_par_on(a, x, y, pool, ranges)
        }
        (Implementation::CsrMergePar, AnyMatrix::Csr(a)) => match &part.merge {
            Some(mp) => super::csr_merge_par_on(a, x, y, pool, mp, ranges, ws),
            // No merge coordinates (degenerate partition): serial path.
            None => super::csr_seq(a, x, y),
        },
        (Implementation::CooColOuter, AnyMatrix::Coo(c)) if c.order() == CooOrder::ColMajor => {
            super::coo_col_outer_on(c, x, y, pool, ranges, ws)
        }
        (Implementation::CooRowOuter, AnyMatrix::Coo(c)) if c.order() == CooOrder::RowMajor => {
            super::coo_row_outer_on(c, x, y, pool, ranges, ws)
        }
        (Implementation::EllRowInner, AnyMatrix::Ell(e)) => {
            super::ell_row_inner_on(e, x, y, pool, ranges)
        }
        (Implementation::EllRowOuter, AnyMatrix::Ell(e)) => {
            super::ell_row_outer_on(e, x, y, pool, ranges, ws)
        }
        (Implementation::SellRowInner, AnyMatrix::Sell(s)) => {
            super::sell_row_inner_on(s, x, y, pool, ranges)
        }
        (Implementation::BcsrSeq, AnyMatrix::Bcsr(b)) => b.spmv(x, y),
        (Implementation::JdsSeq, AnyMatrix::Jds(j)) => {
            let yp = ws.yy(j.n_rows(), 1);
            j.spmv_into(x, y, yp)
        }
        (Implementation::HybSeq, AnyMatrix::Hyb(h)) => h.spmv(x, y),
        _ => anyhow::bail!(
            "implementation {imp} requires {} data but matrix is {}",
            imp.required_format(),
            m.kind()
        ),
    }
    Ok(())
}

/// Execute implementation `imp` on `m` for a whole **tile** of right-hand
/// sides (`ys[j] = A·xs[j]`), streaming the matrix arrays once for the
/// entire tile through the blocked SpMM kernels
/// ([`super::csr_seq_many`], [`super::csr_row_par_many_on`],
/// [`super::coo_col_outer_many_on`], [`super::coo_row_outer_many_on`],
/// [`super::ell_row_inner_many_on`], [`super::ell_row_outer_many_on`],
/// [`super::sell_row_inner_many_on`]).
/// The sequential extension formats (BCSR/JDS/HYB) have no blocked kernel
/// and degrade to one [`run_on`] per right-hand side.
///
/// Per right-hand side the accumulation order matches the single-RHS
/// kernel, so results are bitwise-identical to looped [`run_on`] calls.
///
/// # Errors
/// Returns an error if `m`'s format does not match `imp`'s requirement or
/// the tile widths differ.
pub fn run_many_on(
    imp: Implementation,
    m: &AnyMatrix,
    xs: &[&[Value]],
    ys: &mut [&mut [Value]],
    pool: &ParPool,
    part: &Partition,
    ws: &mut Workspace,
) -> Result<()> {
    anyhow::ensure!(
        xs.len() == ys.len(),
        "tile mismatch: {} inputs vs {} outputs",
        xs.len(),
        ys.len()
    );
    if xs.is_empty() {
        return Ok(());
    }
    let ranges = part.ranges.as_slice();
    match (imp, m) {
        (Implementation::CsrSeq, AnyMatrix::Csr(a)) => super::csr_seq_many(a, xs, ys),
        (Implementation::CsrRowPar, AnyMatrix::Csr(a)) => {
            super::csr_row_par_many_on(a, xs, ys, pool, ranges)
        }
        (Implementation::CsrMergePar, AnyMatrix::Csr(a)) => match &part.merge {
            Some(mp) => super::csr_merge_par_many_on(a, xs, ys, pool, mp, ranges, ws),
            None => {
                for (x, y) in xs.iter().zip(ys.iter_mut()) {
                    super::csr_seq(a, x, y);
                }
            }
        },
        (Implementation::CooColOuter, AnyMatrix::Coo(c)) if c.order() == CooOrder::ColMajor => {
            super::coo_col_outer_many_on(c, xs, ys, pool, ranges, ws)
        }
        (Implementation::CooRowOuter, AnyMatrix::Coo(c)) if c.order() == CooOrder::RowMajor => {
            super::coo_row_outer_many_on(c, xs, ys, pool, ranges, ws)
        }
        (Implementation::EllRowInner, AnyMatrix::Ell(e)) => {
            super::ell_row_inner_many_on(e, xs, ys, pool, ranges)
        }
        (Implementation::EllRowOuter, AnyMatrix::Ell(e)) => {
            super::ell_row_outer_many_on(e, xs, ys, pool, ranges, ws)
        }
        (Implementation::SellRowInner, AnyMatrix::Sell(s)) => {
            super::sell_row_inner_many_on(s, xs, ys, pool, ranges)
        }
        // No blocked kernel: stream the matrix once per right-hand side.
        _ => {
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                run_on(imp, m, x, y, pool, part, ws)?;
            }
        }
    }
    Ok(())
}

/// Execute implementation `imp` on `m` at `n_threads`-way parallelism,
/// partitioning on the fly and running on the global pool (compatibility
/// wrapper around [`run_on`]).
///
/// # Errors
/// Returns an error if `m`'s format does not match `imp`'s requirement.
pub fn run(
    imp: Implementation,
    m: &AnyMatrix,
    x: &[Value],
    y: &mut [Value],
    n_threads: usize,
    ws: &mut Workspace,
) -> Result<()> {
    let part = partition_for(imp, m, n_threads, None);
    run_on(imp, m, x, y, &pool::global(), &part, ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;

    #[test]
    fn names_roundtrip() {
        for imp in Implementation::ALL {
            assert_eq!(Implementation::parse(imp.name()), Some(imp), "{imp}");
        }
        assert_eq!(Implementation::parse("garbage"), None);
    }

    #[test]
    fn bare_ell_parses_to_the_inner_kernel() {
        assert_eq!(Implementation::parse("ell"), Some(Implementation::EllRowInner));
        assert_eq!(Implementation::parse("ellinner"), Some(Implementation::EllRowInner));
        assert_eq!(Implementation::parse("ellouter"), Some(Implementation::EllRowOuter));
        assert_eq!(
            Implementation::parse("ell-row-outer"),
            Some(Implementation::EllRowOuter)
        );
    }

    #[test]
    fn prepare_and_run_all_implementations() {
        let mut rng = Rng::new(5);
        let a = random_csr(&mut rng, 40, 40, 0.1);
        let x: Vec<Value> = (0..40).map(|i| (i as f64).cos()).collect();
        let mut want = vec![0.0; 40];
        a.spmv(&x, &mut want);
        let mut ws = Workspace::new();
        for imp in Implementation::ALL {
            let m = AnyMatrix::prepare(&a, imp, None).unwrap();
            assert_eq!(m.kind(), imp.required_format(), "{imp}");
            let mut y = vec![0.0; 40];
            run(imp, &m, &x, &mut y, 3, &mut ws).unwrap();
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "{imp}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn prepare_on_matches_sequential_prepare() {
        let mut rng = Rng::new(6);
        let a = Arc::new(random_csr(&mut rng, 50, 50, 0.12));
        let pool = ParPool::new(3);
        let x: Vec<Value> = (0..50).map(|i| (i as f64 * 0.7).sin()).collect();
        let mut want = vec![0.0; 50];
        a.spmv(&x, &mut want);
        let mut ws = Workspace::new();
        for imp in Implementation::ALL {
            let m = AnyMatrix::prepare_on(&a, imp, None, &pool).unwrap();
            assert_eq!(m.kind(), imp.required_format(), "{imp}");
            let part = partition_for(imp, &m, pool.size(), None);
            let mut y = vec![0.0; 50];
            run_on(imp, &m, &x, &mut y, &pool, &part, &mut ws).unwrap();
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "{imp}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn run_rejects_format_mismatch() {
        let a = Csr::identity(4);
        let m = AnyMatrix::Csr(Arc::new(a));
        let x = vec![1.0; 4];
        let mut y = vec![0.0; 4];
        let mut ws = Workspace::new();
        assert!(run(Implementation::EllRowInner, &m, &x, &mut y, 1, &mut ws).is_err());
        let xs = [x.as_slice()];
        let mut y2 = vec![0.0; 4];
        let mut ys = [y2.as_mut_slice()];
        let pool = ParPool::new(1);
        let imp = Implementation::EllRowInner;
        let r = run_many_on(imp, &m, &xs, &mut ys, &pool, &Partition::none(), &mut ws);
        assert!(r.is_err());
    }

    #[test]
    fn partition_for_honours_the_strategy() {
        let mut rng = Rng::new(7);
        let a = random_csr(&mut rng, 60, 60, 0.08);
        let m = AnyMatrix::Csr(Arc::new(a.clone()));
        for s in PartitionStrategy::ALL {
            let part = partition_for(Implementation::CsrRowPar, &m, 4, Some(s));
            assert_eq!(part.strategy, Some(s));
            assert!(part.merge.is_none(), "row-par stays row-aligned under {s}");
            let rows: usize = part.ranges.iter().map(|r| r.len()).sum();
            assert_eq!(rows, 60, "strategy {s} must cover all rows");
        }
        // CRS-Merge always carries full merge coordinates.
        let part = partition_for(Implementation::CsrMergePar, &m, 4, None);
        assert_eq!(part.strategy, Some(PartitionStrategy::MergePath));
        let mp = part.merge.as_ref().expect("merge coordinates");
        assert_eq!(part.ranges.len(), mp.n_chunks());
        // Non-CRS kernels ignore the strategy (natural unit split).
        let e = AnyMatrix::prepare(&a, Implementation::EllRowInner, None).unwrap();
        let part = partition_for(
            Implementation::EllRowInner,
            &e,
            4,
            Some(PartitionStrategy::MergePath),
        );
        assert_eq!(part.strategy, Some(PartitionStrategy::Even));
    }

    #[test]
    fn merge_arm_needs_no_transform_and_is_split_stable() {
        assert!(!Implementation::CsrMergePar.needs_transform());
        assert!(Implementation::CsrMergePar.split_stable());
        assert_eq!(Implementation::parse("merge"), Some(Implementation::CsrMergePar));
        assert_eq!(Implementation::parse("CRS-Merge"), Some(Implementation::CsrMergePar));
    }

    #[test]
    fn prepare_on_shares_the_crs_original() {
        let a = Arc::new(Csr::identity(16));
        let pool = ParPool::new(1);
        let m = AnyMatrix::prepare_on(&a, Implementation::CsrRowPar, None, &pool).unwrap();
        match &m {
            AnyMatrix::Csr(shared) => {
                assert!(Arc::ptr_eq(shared, &a), "CRS plans must be zero-copy");
            }
            other => panic!("expected CRS, got {:?}", other.kind()),
        }
    }

    #[test]
    fn needs_transform_flags() {
        assert!(!Implementation::CsrSeq.needs_transform());
        assert!(!Implementation::CsrRowPar.needs_transform());
        for imp in Implementation::AT_CANDIDATES {
            assert!(imp.needs_transform(), "{imp}");
        }
    }
}
