//! Kernel registry: named SpMV implementations over owned format data.
//!
//! [`Implementation`] enumerates the paper's five parallel codes plus the
//! sequential baseline and the BCSR extension; [`AnyMatrix`] owns a matrix
//! in whichever format an implementation needs, so the auto-tuner and the
//! coordinator can hold "the chosen representation" as a single value.

use super::Workspace;
use crate::formats::{Bcsr, Coo, CooOrder, Csc, Csr, Ell, FormatKind, Hyb, Jds, SparseMatrix};
use crate::transform;
use crate::{Result, Value};

/// A named SpMV implementation (paper §3 + baseline + extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Implementation {
    /// OpenATLib `OpenATI_DURMV` switch 11: sequential CRS.
    CsrSeq,
    /// Row-parallel CRS (nnz-balanced) — the multi-thread baseline.
    CsrRowPar,
    /// Fig. 1: COO-Column, outer-parallelised entry stream.
    CooColOuter,
    /// Fig. 2: COO-Row, outer-parallelised entry stream.
    CooRowOuter,
    /// Fig. 3: ELL-Row, inner `N`-loop parallelised.
    EllRowInner,
    /// Fig. 4: ELL-Row, outer band-loop parallelised (parallelism ≤ NE).
    EllRowOuter,
    /// BCSR 2×2 register-blocked (paper future work; sequential kernel).
    BcsrSeq,
    /// JDS diagonal-sweep (extension; sequential, vectorisable).
    JdsSeq,
    /// HYB body+tail (extension; sequential).
    HybSeq,
}

impl Implementation {
    /// Every implementation, in the order the paper's figures report them.
    pub const ALL: [Implementation; 9] = [
        Implementation::CsrSeq,
        Implementation::CsrRowPar,
        Implementation::CooColOuter,
        Implementation::CooRowOuter,
        Implementation::EllRowInner,
        Implementation::EllRowOuter,
        Implementation::BcsrSeq,
        Implementation::JdsSeq,
        Implementation::HybSeq,
    ];

    /// The candidates the paper's AT method chooses between at run time
    /// (its figures 5–8 series, excluding the baseline itself).
    pub const AT_CANDIDATES: [Implementation; 4] = [
        Implementation::CooColOuter,
        Implementation::CooRowOuter,
        Implementation::EllRowInner,
        Implementation::EllRowOuter,
    ];

    /// Stable display name (matches the paper's legend strings).
    pub fn name(self) -> &'static str {
        match self {
            Implementation::CsrSeq => "CRS",
            Implementation::CsrRowPar => "CRS-Par",
            Implementation::CooColOuter => "COO-Col Outer",
            Implementation::CooRowOuter => "COO-Row Outer",
            Implementation::EllRowInner => "ELL-Row Inner",
            Implementation::EllRowOuter => "ELL-Row Outer",
            Implementation::BcsrSeq => "BCSR",
            Implementation::JdsSeq => "JDS",
            Implementation::HybSeq => "HYB",
        }
    }

    /// Parse a CLI/report name.
    pub fn parse(s: &str) -> Option<Self> {
        let norm: String = s
            .to_ascii_lowercase()
            .chars()
            .filter(|c| c.is_ascii_alphanumeric())
            .collect();
        Some(match norm.as_str() {
            "crs" | "csr" | "crsseq" | "csrseq" => Implementation::CsrSeq,
            "crspar" | "csrpar" | "csrrowpar" => Implementation::CsrRowPar,
            "coocolouter" | "coocol" => Implementation::CooColOuter,
            "coorowouter" | "coorow" => Implementation::CooRowOuter,
            "ellrowinner" | "ellinner" => Implementation::EllRowInner,
            "ellrowouter" | "ellouter" | "ell" => Implementation::EllRowOuter,
            "bcsr" | "bcsrseq" => Implementation::BcsrSeq,
            "jds" | "jdsseq" => Implementation::JdsSeq,
            "hyb" | "hybseq" => Implementation::HybSeq,
            _ => return None,
        })
    }

    /// The storage format this implementation runs on.
    pub fn required_format(self) -> FormatKind {
        match self {
            Implementation::CsrSeq | Implementation::CsrRowPar => FormatKind::Csr,
            Implementation::CooColOuter => FormatKind::CooCol,
            Implementation::CooRowOuter => FormatKind::CooRow,
            Implementation::EllRowInner | Implementation::EllRowOuter => FormatKind::Ell,
            Implementation::BcsrSeq => FormatKind::Bcsr,
            Implementation::JdsSeq => FormatKind::Jds,
            Implementation::HybSeq => FormatKind::Hyb,
        }
    }

    /// Whether the implementation needs a data transformation away from CRS.
    pub fn needs_transform(self) -> bool {
        self.required_format() != FormatKind::Csr
    }
}

impl std::fmt::Display for Implementation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A matrix owned in any of the library's formats.
#[derive(Clone, Debug)]
pub enum AnyMatrix {
    /// CRS/CSR.
    Csr(Csr),
    /// CCS/CSC.
    Csc(Csc),
    /// COO (either order; see [`Coo::order`]).
    Coo(Coo),
    /// ELL.
    Ell(Ell),
    /// BCSR.
    Bcsr(Bcsr),
    /// JDS.
    Jds(Jds),
    /// HYB.
    Hyb(Hyb),
}

impl AnyMatrix {
    /// Transform a CRS source into whatever `imp` requires.
    pub fn prepare(a: &Csr, imp: Implementation, max_bytes: Option<usize>) -> Result<Self> {
        Ok(match imp.required_format() {
            FormatKind::Csr => AnyMatrix::Csr(a.clone()),
            FormatKind::Csc => AnyMatrix::Csc(transform::crs_to_ccs(a)),
            FormatKind::CooRow => AnyMatrix::Coo(transform::crs_to_coo_row(a)),
            FormatKind::CooCol => AnyMatrix::Coo(transform::crs_to_coo_col(a)),
            FormatKind::Ell => AnyMatrix::Ell(transform::crs_to_ell_bounded(a, max_bytes)?),
            FormatKind::Bcsr => AnyMatrix::Bcsr(transform::crs_to_bcsr(a, 2, 2)?),
            FormatKind::Jds => AnyMatrix::Jds(transform::crs_to_jds(a)),
            FormatKind::Hyb => AnyMatrix::Hyb(transform::crs_to_hyb(a)?),
        })
    }

    /// View as the dynamic [`SparseMatrix`] trait.
    pub fn as_sparse(&self) -> &dyn SparseMatrix {
        match self {
            AnyMatrix::Csr(m) => m,
            AnyMatrix::Csc(m) => m,
            AnyMatrix::Coo(m) => m,
            AnyMatrix::Ell(m) => m,
            AnyMatrix::Bcsr(m) => m,
            AnyMatrix::Jds(m) => m,
            AnyMatrix::Hyb(m) => m,
        }
    }

    /// The stored format tag.
    pub fn kind(&self) -> FormatKind {
        self.as_sparse().kind()
    }

    /// Storage footprint in bytes.
    pub fn memory_bytes(&self) -> usize {
        self.as_sparse().memory_bytes()
    }
}

/// Execute implementation `imp` on `m` with `n_threads` threads.
///
/// # Errors
/// Returns an error if `m`'s format does not match `imp`'s requirement.
pub fn run(
    imp: Implementation,
    m: &AnyMatrix,
    x: &[Value],
    y: &mut [Value],
    n_threads: usize,
    ws: &mut Workspace,
) -> Result<()> {
    match (imp, m) {
        (Implementation::CsrSeq, AnyMatrix::Csr(a)) => super::csr_seq(a, x, y),
        (Implementation::CsrRowPar, AnyMatrix::Csr(a)) => super::csr_row_par(a, x, y, n_threads),
        (Implementation::CooColOuter, AnyMatrix::Coo(c)) if c.order() == CooOrder::ColMajor => {
            super::coo_col_outer(c, x, y, n_threads, ws)
        }
        (Implementation::CooRowOuter, AnyMatrix::Coo(c)) if c.order() == CooOrder::RowMajor => {
            super::coo_row_outer(c, x, y, n_threads, ws)
        }
        (Implementation::EllRowInner, AnyMatrix::Ell(e)) => {
            super::ell_row_inner(e, x, y, n_threads)
        }
        (Implementation::EllRowOuter, AnyMatrix::Ell(e)) => {
            super::ell_row_outer(e, x, y, n_threads, ws)
        }
        (Implementation::BcsrSeq, AnyMatrix::Bcsr(b)) => b.spmv(x, y),
        (Implementation::JdsSeq, AnyMatrix::Jds(j)) => {
            let yp = ws.yy(j.n_rows(), 1);
            j.spmv_into(x, y, yp)
        }
        (Implementation::HybSeq, AnyMatrix::Hyb(h)) => h.spmv(x, y),
        _ => anyhow::bail!(
            "implementation {imp} requires {} data but matrix is {}",
            imp.required_format(),
            m.kind()
        ),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;

    #[test]
    fn names_roundtrip() {
        for imp in Implementation::ALL {
            assert_eq!(Implementation::parse(imp.name()), Some(imp), "{imp}");
        }
        assert_eq!(Implementation::parse("garbage"), None);
    }

    #[test]
    fn prepare_and_run_all_implementations() {
        let mut rng = Rng::new(5);
        let a = random_csr(&mut rng, 40, 40, 0.1);
        let x: Vec<Value> = (0..40).map(|i| (i as f64).cos()).collect();
        let mut want = vec![0.0; 40];
        a.spmv(&x, &mut want);
        let mut ws = Workspace::new();
        for imp in Implementation::ALL {
            let m = AnyMatrix::prepare(&a, imp, None).unwrap();
            assert_eq!(m.kind(), imp.required_format(), "{imp}");
            let mut y = vec![0.0; 40];
            run(imp, &m, &x, &mut y, 3, &mut ws).unwrap();
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "{imp}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn run_rejects_format_mismatch() {
        let a = Csr::identity(4);
        let m = AnyMatrix::Csr(a);
        let x = vec![1.0; 4];
        let mut y = vec![0.0; 4];
        let mut ws = Workspace::new();
        assert!(run(Implementation::EllRowInner, &m, &x, &mut y, 1, &mut ws).is_err());
    }

    #[test]
    fn needs_transform_flags() {
        assert!(!Implementation::CsrSeq.needs_transform());
        assert!(!Implementation::CsrRowPar.needs_transform());
        for imp in Implementation::AT_CANDIDATES {
            assert!(imp.needs_transform(), "{imp}");
        }
    }
}
