//! Parallel SpMV implementations (paper §3, Figs. 1–4).
//!
//! Each OpenMP listing in the paper maps to one function here, with the
//! same work decomposition:
//!
//! | Paper | Function | Decomposition |
//! |---|---|---|
//! | Fig. 1 | [`coo_col_outer`] | entry stream split per thread, private `YY`, serial reduction |
//! | Fig. 2 | [`coo_row_outer`] | same, over the row-major stream |
//! | Fig. 3 | [`ell_row_inner`] | parallel `N`-loop inside the band loop, no reduction |
//! | Fig. 4 | [`ell_row_outer`] | band range split per thread, private `YY`, serial reduction |
//! | switch 11 | [`csr_seq`] / [`csr_row_par`] | OpenATLib CRS baseline (+ row-parallel variant) |
//!
//! The per-thread accumulation buffers (`YY(1:n, 1:threads)` in the paper)
//! live in a reusable [`Workspace`] so the hot path performs no allocation
//! after the first call.

pub mod kernels;
pub mod partition;

pub use kernels::{AnyMatrix, Implementation};

use crate::formats::{Coo, CooOrder, Csr, Ell, SparseMatrix};
use crate::Value;
use partition::{split_by_nnz, split_even};

/// Reusable per-call scratch: the paper's `YY(1:N, 1:NUM_SMP)` private
/// accumulation buffers plus the padded `y` staging area.
#[derive(Default, Debug)]
pub struct Workspace {
    yy: Vec<Value>,
}

impl Workspace {
    /// Fresh, empty workspace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Borrow a zeroed `n × k` buffer, growing the backing storage if needed.
    pub(crate) fn yy(&mut self, n: usize, k: usize) -> &mut [Value] {
        let need = n * k;
        if self.yy.len() < need {
            self.yy.resize(need, 0.0);
        }
        let buf = &mut self.yy[..need];
        buf.fill(0.0);
        buf
    }

    /// Bytes currently held.
    pub fn capacity_bytes(&self) -> usize {
        self.yy.capacity() * std::mem::size_of::<Value>()
    }
}

/// Sequential CRS SpMV — the paper's baseline (OpenATLib `OpenATI_DURMV`
/// switch no. 11). `t_crs` in every ratio is measured on this kernel.
pub fn csr_seq(a: &Csr, x: &[Value], y: &mut [Value]) {
    a.spmv(x, y);
}

/// Row-parallel CRS SpMV with nnz-balanced row ranges; each thread writes a
/// disjoint `y` slice, so no reduction is needed.
pub fn csr_row_par(a: &Csr, x: &[Value], y: &mut [Value], n_threads: usize) {
    assert_eq!(x.len(), a.n_cols(), "x length");
    assert_eq!(y.len(), a.n_rows(), "y length");
    let ranges = split_by_nnz(&a.row_ptr, n_threads);
    if ranges.len() <= 1 {
        return csr_seq(a, x, y);
    }
    std::thread::scope(|s| {
        let mut rest: &mut [Value] = y;
        let mut pos = 0usize;
        for r in &ranges {
            let (chunk, tail) = rest.split_at_mut(r.end - pos);
            rest = tail;
            pos = r.end;
            let (lo, hi) = (r.start, r.end);
            s.spawn(move || {
                for i in lo..hi {
                    let mut acc = 0.0;
                    for k in a.row_ptr[i]..a.row_ptr[i + 1] {
                        acc += a.values[k] * x[a.col_idx[k] as usize];
                    }
                    chunk[i - lo] = acc;
                }
            });
        }
    });
}

/// Shared body of Figs. 1 and 2: split the COO entry stream into
/// `ISTART(K)..IEND(K)` chunks, accumulate into private `YY(:,K)`, then do
/// the serial reduction of lines 12–16 ("the overhead of the thread fork is
/// high if N is small. Hence, we do not parallelize this part").
fn coo_outer(c: &Coo, x: &[Value], y: &mut [Value], n_threads: usize, ws: &mut Workspace) {
    assert_eq!(x.len(), c.n_cols(), "x length");
    assert_eq!(y.len(), c.n_rows(), "y length");
    let nnz = c.nnz();
    let n = c.n_rows();
    let ranges = split_even(nnz, n_threads);
    if ranges.len() <= 1 {
        return c.spmv(x, y);
    }
    let k = ranges.len();
    let yy = ws.yy(n, k);
    std::thread::scope(|s| {
        let mut rest: &mut [Value] = yy;
        for r in &ranges {
            let (slice, tail) = rest.split_at_mut(n);
            rest = tail;
            let (lo, hi) = (r.start, r.end);
            s.spawn(move || {
                for j in lo..hi {
                    // <5> II = ICOL(J_PTR); <6> KK = row; <7> accumulate.
                    let row = c.row_idx[j] as usize;
                    let col = c.col_idx[j] as usize;
                    slice[row] += c.values[j] * x[col];
                }
            });
        }
    });
    // Lines <12>-<16>: serial reduction over thread-private copies.
    y.fill(0.0);
    for t in 0..k {
        let slice = &yy[t * n..(t + 1) * n];
        for i in 0..n {
            y[i] += slice[i];
        }
    }
}

/// Fig. 1 — outer-loop parallel SpMV over the **column-major** COO stream.
///
/// # Panics
/// Panics if `c` is not column-major ordered.
pub fn coo_col_outer(c: &Coo, x: &[Value], y: &mut [Value], n_threads: usize, ws: &mut Workspace) {
    assert_eq!(c.order(), CooOrder::ColMajor, "Fig. 1 requires COO-Column data");
    coo_outer(c, x, y, n_threads, ws);
}

/// Fig. 2 — outer-loop parallel SpMV over the **row-major** COO stream.
///
/// # Panics
/// Panics if `c` is not row-major ordered.
pub fn coo_row_outer(c: &Coo, x: &[Value], y: &mut [Value], n_threads: usize, ws: &mut Workspace) {
    assert_eq!(c.order(), CooOrder::RowMajor, "Fig. 2 requires COO-Row data");
    coo_outer(c, x, y, n_threads, ws);
}

/// Fig. 3 — ELL-Row with the **inner `N`-loop parallelised**: each thread
/// owns a contiguous row range and streams every band over it with unit
/// stride. "There is no reduction loop, which is an advantage of this
/// format."
pub fn ell_row_inner(e: &Ell, x: &[Value], y: &mut [Value], n_threads: usize) {
    assert_eq!(x.len(), e.n_cols(), "x length");
    assert_eq!(y.len(), e.n_rows(), "y length");
    let n = e.n_rows();
    let ranges = split_even(n, n_threads);
    if ranges.len() <= 1 {
        return e.spmv(x, y);
    }
    std::thread::scope(|s| {
        let mut rest: &mut [Value] = y;
        let mut pos = 0usize;
        for r in &ranges {
            let (chunk, tail) = rest.split_at_mut(r.end - pos);
            rest = tail;
            pos = r.end;
            let (lo, hi) = (r.start, r.end);
            s.spawn(move || {
                chunk.fill(0.0);
                for k in 0..e.bandwidth {
                    let base = k * n;
                    let vals = &e.values[base + lo..base + hi];
                    let cols = &e.col_idx[base + lo..base + hi];
                    for i in 0..hi - lo {
                        // <8> Y(I) = Y(I) + VAL(J_PTR) * X(II)
                        chunk[i] += vals[i] * x[cols[i] as usize];
                    }
                }
            });
        }
    });
}

/// Fig. 4 — ELL-Row with the **outer band loop parallelised**: the band
/// range `K = 1..NE` is split across threads (`ISTART(J)..IEND(J)`), each
/// thread accumulates into its private `YY(:,J)`, then the serial
/// reduction runs. Parallelism is capped at the bandwidth `NE` — the
/// paper's point that "if NE = 2, the parallelism is only 2".
pub fn ell_row_outer(e: &Ell, x: &[Value], y: &mut [Value], n_threads: usize, ws: &mut Workspace) {
    assert_eq!(x.len(), e.n_cols(), "x length");
    assert_eq!(y.len(), e.n_rows(), "y length");
    let n = e.n_rows();
    let ranges = split_even(e.bandwidth, n_threads); // capped at NE chunks
    if ranges.len() <= 1 {
        return e.spmv(x, y);
    }
    let k = ranges.len();
    let yy = ws.yy(n, k);
    std::thread::scope(|s| {
        let mut rest: &mut [Value] = yy;
        for r in &ranges {
            let (slice, tail) = rest.split_at_mut(n);
            rest = tail;
            let (lo, hi) = (r.start, r.end);
            s.spawn(move || {
                for band in lo..hi {
                    let base = band * n;
                    let vals = &e.values[base..base + n];
                    let cols = &e.col_idx[base..base + n];
                    for i in 0..n {
                        slice[i] += vals[i] * x[cols[i] as usize];
                    }
                }
            });
        }
    });
    y.fill(0.0);
    for t in 0..k {
        let slice = &yy[t * n..(t + 1) * n];
        for i in 0..n {
            y[i] += slice[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;
    use crate::transform::{crs_to_coo_col, crs_to_coo_row, crs_to_ell};

    fn assert_close(a: &[Value], b: &[Value]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-9 * (1.0 + x.abs()),
                "index {i}: {x} vs {y}"
            );
        }
    }

    fn cases() -> Vec<Csr> {
        let mut rng = Rng::new(31);
        vec![
            random_csr(&mut rng, 1, 1, 1.0),
            random_csr(&mut rng, 17, 17, 0.3),
            random_csr(&mut rng, 128, 96, 0.06),
            random_csr(&mut rng, 200, 200, 0.02),
            Csr::from_triplets(9, 9, &[]).unwrap(),
        ]
    }

    #[test]
    fn all_kernels_match_baseline_across_threads() {
        let mut ws = Workspace::new();
        for a in cases() {
            let x: Vec<Value> = (0..a.n_cols()).map(|i| ((i * 7 + 1) as f64).recip()).collect();
            let mut want = vec![0.0; a.n_rows()];
            csr_seq(&a, &x, &mut want);
            let ell = crs_to_ell(&a).unwrap();
            let coo_r = crs_to_coo_row(&a);
            let coo_c = crs_to_coo_col(&a);
            for t in [1usize, 2, 3, 4, 9] {
                let mut y = vec![0.0; a.n_rows()];
                csr_row_par(&a, &x, &mut y, t);
                assert_close(&y, &want);
                coo_col_outer(&coo_c, &x, &mut y, t, &mut ws);
                assert_close(&y, &want);
                coo_row_outer(&coo_r, &x, &mut y, t, &mut ws);
                assert_close(&y, &want);
                ell_row_inner(&ell, &x, &mut y, t);
                assert_close(&y, &want);
                ell_row_outer(&ell, &x, &mut y, t, &mut ws);
                assert_close(&y, &want);
            }
        }
    }

    #[test]
    fn fig1_rejects_wrong_order() {
        let a = cases()[1].clone();
        let coo_r = crs_to_coo_row(&a);
        let x = vec![1.0; a.n_cols()];
        let mut y = vec![0.0; a.n_rows()];
        let mut ws = Workspace::new();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            coo_col_outer(&coo_r, &x, &mut y, 2, &mut ws);
        }));
        assert!(err.is_err());
    }

    #[test]
    fn ell_outer_parallelism_capped_at_bandwidth() {
        // bandwidth 2, 8 threads -> must still be correct (only 2 chunks used).
        let a = Csr::from_triplets(
            4,
            4,
            &[(0, 0, 1.0), (0, 1, 2.0), (1, 1, 3.0), (2, 2, 4.0), (3, 0, 5.0), (3, 3, 6.0)],
        )
        .unwrap();
        let ell = crs_to_ell(&a).unwrap();
        assert_eq!(ell.bandwidth, 2);
        let x = [1.0, 1.0, 1.0, 1.0];
        let mut want = vec![0.0; 4];
        csr_seq(&a, &x, &mut want);
        let mut y = vec![0.0; 4];
        let mut ws = Workspace::new();
        ell_row_outer(&ell, &x, &mut y, 8, &mut ws);
        assert_close(&y, &want);
    }

    #[test]
    fn workspace_reuse_does_not_leak_state() {
        let mut ws = Workspace::new();
        let a = cases()[2].clone();
        let coo = crs_to_coo_row(&a);
        let x = vec![1.0; a.n_cols()];
        let mut want = vec![0.0; a.n_rows()];
        csr_seq(&a, &x, &mut want);
        for _ in 0..3 {
            let mut y = vec![0.0; a.n_rows()];
            coo_row_outer(&coo, &x, &mut y, 4, &mut ws);
            assert_close(&y, &want);
        }
        assert!(ws.capacity_bytes() > 0);
    }
}
