//! Parallel SpMV implementations (paper §3, Figs. 1–4) on the persistent
//! execution engine.
//!
//! Each OpenMP listing in the paper maps to one function here, with the
//! same work decomposition:
//!
//! | Paper | Function | Decomposition |
//! |---|---|---|
//! | Fig. 1 | [`coo_col_outer`] | entry stream split per chunk, private `YY`, tree reduction |
//! | Fig. 2 | [`coo_row_outer`] | same, over the row-major stream |
//! | Fig. 3 | [`ell_row_inner`] | parallel `N`-loop inside the band loop, no reduction |
//! | Fig. 4 | [`ell_row_outer`] | band range split per chunk, private `YY`, tree reduction |
//! | switch 11 | [`csr_seq`] / [`csr_row_par`] | OpenATLib CRS baseline (+ row-parallel variant) |
//! | extension | [`sell_row_inner`] | SELL-C-σ chunk ranges, lane-width-C bands, no reduction |
//! | extension | [`csr_merge_par`] | merge-path 2-D chunks (may cut rows), carry slots + serial row-order fixup |
//!
//! Two layers sit underneath and above these kernels:
//!
//! * [`pool`] — the crate-wide persistent worker pool ([`pool::ParPool`]).
//!   No kernel (and no parallel transform) spawns OS threads per call any
//!   more: each `*_on` kernel takes `(&ParPool, &[Range])` and executes its
//!   pre-partitioned chunks on parked workers. The `n_threads`-taking
//!   entry points below are compatibility wrappers that partition on the
//!   fly and run on the [`pool::global`] pool.
//! * [`plan`] — [`plan::SpmvPlan`], an executable plan owning the chosen
//!   [`AnyMatrix`], its partitions (computed once, not per call), and its
//!   [`Workspace`]; and [`plan::Planner`], which turns a CSR matrix plus
//!   the online AT decision into such a plan. The auto-tuner handle, the
//!   coordinator, the solvers and the CLI all execute through cached
//!   plans.
//!
//! Every kernel also has a **blocked multi-RHS (SpMM) variant**
//! (`*_many_on`, dispatched through [`kernels::run_many_on`]): a tile of
//! right-hand sides is served by a single pass over the matrix arrays,
//! with the per-RHS accumulation order unchanged — so a tiled batch is
//! bitwise-identical to looped single executes while streaming the
//! matrix ⌈k/tile⌉ times instead of k. [`plan::SpmvPlan::execute_many`]
//! does the tiling (`SPMV_AT_BATCH_TILE`); the coordinator's batch
//! requests and the `Durmv` handle's `durmv_many` ride on it.
//!
//! The per-thread accumulation buffers (`YY(1:n, 1:threads)` in the paper,
//! widened to `n × tile` blocks for SpMM) live in a reusable [`Workspace`]
//! so the hot path performs no allocation after the first call. The
//! serial reduction of the paper's listings ("we do not parallelize this
//! part") is replaced by a pairwise tree reduction over the pool,
//! parallel across row ranges.

pub mod kernels;
pub mod partition;
pub mod plan;
pub mod pool;

pub use kernels::{AnyMatrix, Implementation};
pub use plan::{Planner, SpmvPlan};
pub use pool::ParPool;

use crate::formats::{Coo, CooOrder, Csr, Ell, SellCSigma, SparseMatrix, MAX_C};
use crate::{Index, Value};
use partition::{merge_path_split, split_by_nnz, split_even, MergePartition};
use pool::SendPtr;
use std::ops::Range;

/// Reusable per-call scratch: the paper's `YY(1:N, 1:NUM_SMP)` private
/// accumulation buffers plus the padded `y` staging area.
#[derive(Default, Debug)]
pub struct Workspace {
    yy: Vec<Value>,
}

impl Workspace {
    /// Fresh, empty workspace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Borrow a zeroed `n × k` buffer, growing the backing storage if needed.
    pub(crate) fn yy(&mut self, n: usize, k: usize) -> &mut [Value] {
        let need = n * k;
        if self.yy.len() < need {
            self.yy.resize(need, 0.0);
        }
        let buf = &mut self.yy[..need];
        buf.fill(0.0);
        buf
    }

    /// Bytes currently held.
    pub fn capacity_bytes(&self) -> usize {
        self.yy.capacity() * std::mem::size_of::<Value>()
    }
}

/// Sequential CRS SpMV — the paper's baseline (OpenATLib `OpenATI_DURMV`
/// switch no. 11). `t_crs` in every ratio is measured on this kernel.
pub fn csr_seq(a: &Csr, x: &[Value], y: &mut [Value]) {
    a.spmv(x, y);
}

/// Row-parallel CRS SpMV over precomputed nnz-balanced row ranges; each
/// chunk writes a disjoint `y` slice, so no reduction is needed.
pub fn csr_row_par_on(
    a: &Csr,
    x: &[Value],
    y: &mut [Value],
    pool: &ParPool,
    ranges: &[Range<usize>],
) {
    assert_eq!(x.len(), a.n_cols(), "x length");
    assert_eq!(y.len(), a.n_rows(), "y length");
    if ranges.len() <= 1 {
        return csr_seq(a, x, y);
    }
    let yp = SendPtr(y.as_mut_ptr());
    pool.run_chunks(ranges, |_tid, r| {
        for i in r {
            let mut acc = 0.0;
            for k in a.row_ptr[i]..a.row_ptr[i + 1] {
                acc += a.values[k] * x[a.col_idx[k] as usize];
            }
            // Row ranges are disjoint: each y[i] has exactly one writer.
            unsafe { *yp.get().add(i) = acc };
        }
    });
}

/// Row-parallel CRS SpMV, partitioning on the fly and executing on the
/// [`pool::global`] pool (compatibility entry point; plans precompute the
/// partition instead).
pub fn csr_row_par(a: &Csr, x: &[Value], y: &mut [Value], n_threads: usize) {
    let ranges = split_by_nnz(&a.row_ptr, n_threads);
    csr_row_par_on(a, x, y, &pool::global(), &ranges);
}

/// Merge-path parallel CRS SpMV over a precomputed [`MergePartition`]:
/// every chunk owns ⌈(n+nnz)/k⌉ merge items — row boundaries *plus*
/// non-zeros — so a single giant row is cut across workers instead of
/// serialising one of them.
///
/// Rows a chunk both starts and finishes are written to `y` directly
/// (each such row has exactly one writer). The partial segments at a
/// chunk's edges — a leading segment that *completes* a row an earlier
/// chunk began, and a trailing segment that *starts* the next row — go
/// into two per-chunk carry slots in the workspace; [`merge_fixup`] then
/// sums them serially in ascending chunk order, which **is** row order
/// and stored-element order. Each row's result is therefore the sum of
/// its left-associated segment sums combined left-to-right: the same
/// global element order as [`csr_seq`], re-associated only at the
/// ≤ k−1 chunk boundaries that actually cut a row. On inputs whose
/// partial products and sums are exactly representable (the oracle
/// harness's binary-fraction fixtures) the result is bit-for-bit equal
/// to `csr_seq`; re-running a plan always reproduces the identical
/// result (fixed coordinates, fixed fixup order).
///
/// `ranges` are the unit chunk-id ranges of
/// [`partition::Partition::merged`] — the pool claims chunk indices, not
/// rows.
pub fn csr_merge_par_on(
    a: &Csr,
    x: &[Value],
    y: &mut [Value],
    pool: &ParPool,
    mp: &MergePartition,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_eq!(x.len(), a.n_cols(), "x length");
    assert_eq!(y.len(), a.n_rows(), "y length");
    let kc = mp.n_chunks();
    if kc <= 1 || ranges.len() <= 1 {
        return csr_seq(a, x, y);
    }
    debug_assert_eq!(ranges.len(), kc, "one unit range per merge chunk");
    // Two carry slots per chunk (head, tail), zeroed by the workspace.
    let carry = ws.yy(2 * kc, 1);
    let yp = SendPtr(y.as_mut_ptr());
    let cp = SendPtr(carry.as_mut_ptr());
    pool.run_chunks(ranges, |_tid, ts| {
        for t in ts {
            let (r0, v0) = mp.bounds[t];
            let (r1, v1) = mp.bounds[t + 1];
            let mut v = v0;
            for r in r0..r1 {
                let end = a.row_ptr[r + 1];
                let mut acc = 0.0;
                for e in v..end {
                    acc += a.values[e] * x[a.col_idx[e] as usize];
                }
                if r == r0 && v0 > a.row_ptr[r0] {
                    // Head segment: completes a row an earlier chunk began.
                    unsafe { *cp.get().add(2 * t) = acc };
                } else {
                    // Fully-owned row (empty rows write 0); one writer.
                    unsafe { *yp.get().add(r) = acc };
                }
                v = end;
            }
            if v1 > v {
                // Trailing partial segment of row r1 (the whole chunk,
                // when r0 == r1 and the chunk sits inside one row).
                let mut acc = 0.0;
                for e in v..v1 {
                    acc += a.values[e] * x[a.col_idx[e] as usize];
                }
                unsafe { *cp.get().add(2 * t + 1) = acc };
            }
        }
    });
    merge_fixup(&a.row_ptr, mp, carry, 1, 0, y);
}

/// Merge-path compatibility wrapper (global pool, on-the-fly partition).
pub fn csr_merge_par(a: &Csr, x: &[Value], y: &mut [Value], n_threads: usize, ws: &mut Workspace) {
    let mp = merge_path_split(&a.row_ptr, n_threads);
    let ranges: Vec<Range<usize>> = (0..mp.n_chunks()).map(|t| t..t + 1).collect();
    csr_merge_par_on(a, x, y, &pool::global(), &mp, &ranges, ws);
}

/// The deterministic caller-side fixup of the merge-path kernels: walk
/// the chunks in ascending order (= row order = element order) and fold
/// each chunk's carried partial segments into `y`. A chunk's **head**
/// slot finalises the row left open by the previous chunks; its **tail**
/// slot opens (or extends, for chunks entirely inside one row) the
/// partial sum of its last row. Serial and identical on every run.
///
/// `b`/`j` address the carry layout of the multi-RHS kernel
/// (slot `2·(t·b + j)` + head/tail offset); the single-RHS kernel passes
/// `b = 1, j = 0`.
fn merge_fixup(
    row_ptr: &[usize],
    mp: &MergePartition,
    carry: &[Value],
    b: usize,
    j: usize,
    y: &mut [Value],
) {
    let mut open: Option<(usize, Value)> = None;
    for t in 0..mp.n_chunks() {
        let (r0, v0) = mp.bounds[t];
        let (r1, v1) = mp.bounds[t + 1];
        if r0 < r1 && v0 > row_ptr[r0] {
            // Head: the last segment of row r0 — close it out.
            let s = carry[2 * (t * b + j)];
            y[r0] = match open.take() {
                Some((or, os)) if or == r0 => os + s,
                _ => s,
            };
        }
        let tail_from = if r1 > r0 { row_ptr[r1] } else { v0 };
        if v1 > tail_from {
            // Tail: a partial segment of row r1 stays open for later
            // chunks (middle chunks of a very long row extend it here).
            let s = carry[2 * (t * b + j) + 1];
            open = Some(match open.take() {
                Some((or, os)) if or == r1 => (r1, os + s),
                _ => (r1, s),
            });
        }
    }
    if let Some((or, os)) = open {
        y[or] = os;
    }
}

/// Shared body of Figs. 1 and 2 over precomputed entry-stream ranges:
/// each chunk accumulates into its private `YY(:,K)` slice, then the
/// reduction of lines 12–16 runs. The paper keeps that reduction serial
/// ("the overhead of the thread fork is high if N is small"); with parked
/// workers the fork is free, so it runs as a pairwise tree over the pool,
/// parallel across row ranges.
fn coo_outer_on(
    c: &Coo,
    x: &[Value],
    y: &mut [Value],
    pool: &ParPool,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_eq!(x.len(), c.n_cols(), "x length");
    assert_eq!(y.len(), c.n_rows(), "y length");
    let n = c.n_rows();
    if ranges.len() <= 1 {
        return c.spmv(x, y);
    }
    let k = ranges.len();
    let yy = ws.yy(n, k);
    let yyp = SendPtr(yy.as_mut_ptr());
    pool.run_chunks(ranges, |tid, r| {
        // Chunk `tid` owns the disjoint column yy[tid*n .. (tid+1)*n].
        let slice = unsafe { std::slice::from_raw_parts_mut(yyp.get().add(tid * n), n) };
        for j in r {
            // <5> II = ICOL(J_PTR); <6> KK = row; <7> accumulate.
            let row = c.row_idx[j] as usize;
            let col = c.col_idx[j] as usize;
            slice[row] += c.values[j] * x[col];
        }
    });
    // Lines <12>-<16>, parallelised: tree reduction over thread-private copies.
    reduce_yy_tree(pool, yy, y, n, k);
}

/// Reduce `k` private copies `yy[t*n..(t+1)*n]` into `y`, as a pairwise
/// tree (`stride = 1, 2, 4, …`) executed over the pool, parallel across
/// disjoint row ranges. Overwrites `y` entirely. This is exactly the
/// single-RHS case of [`reduce_yy_tree_many`] (`b = 1` makes the block
/// offsets `t*n*b + 0*n` collapse to `t*n`), so it delegates — one copy
/// of the raw-pointer tree to keep correct.
pub(crate) fn reduce_yy_tree(
    pool: &ParPool,
    yy: &mut [Value],
    y: &mut [Value],
    n: usize,
    k: usize,
) {
    reduce_yy_tree_many(pool, yy, &mut [y], n, 1, k);
}

/// Fig. 1 — outer-loop parallel SpMV over the **column-major** COO stream,
/// on precomputed entry ranges.
///
/// # Panics
/// Panics if `c` is not column-major ordered.
pub fn coo_col_outer_on(
    c: &Coo,
    x: &[Value],
    y: &mut [Value],
    pool: &ParPool,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_eq!(c.order(), CooOrder::ColMajor, "Fig. 1 requires COO-Column data");
    coo_outer_on(c, x, y, pool, ranges, ws);
}

/// Fig. 1 compatibility wrapper (global pool, on-the-fly partition).
pub fn coo_col_outer(c: &Coo, x: &[Value], y: &mut [Value], n_threads: usize, ws: &mut Workspace) {
    let ranges = split_even(c.nnz(), n_threads);
    coo_col_outer_on(c, x, y, &pool::global(), &ranges, ws);
}

/// Fig. 2 — outer-loop parallel SpMV over the **row-major** COO stream,
/// on precomputed entry ranges.
///
/// # Panics
/// Panics if `c` is not row-major ordered.
pub fn coo_row_outer_on(
    c: &Coo,
    x: &[Value],
    y: &mut [Value],
    pool: &ParPool,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_eq!(c.order(), CooOrder::RowMajor, "Fig. 2 requires COO-Row data");
    coo_outer_on(c, x, y, pool, ranges, ws);
}

/// Fig. 2 compatibility wrapper (global pool, on-the-fly partition).
pub fn coo_row_outer(c: &Coo, x: &[Value], y: &mut [Value], n_threads: usize, ws: &mut Workspace) {
    let ranges = split_even(c.nnz(), n_threads);
    coo_row_outer_on(c, x, y, &pool::global(), &ranges, ws);
}

/// Fig. 3 — ELL-Row with the **inner `N`-loop parallelised** over
/// precomputed row ranges: each chunk owns a contiguous row range and
/// streams every band over it with unit stride. "There is no reduction
/// loop, which is an advantage of this format."
pub fn ell_row_inner_on(
    e: &Ell,
    x: &[Value],
    y: &mut [Value],
    pool: &ParPool,
    ranges: &[Range<usize>],
) {
    assert_eq!(x.len(), e.n_cols(), "x length");
    assert_eq!(y.len(), e.n_rows(), "y length");
    let n = e.n_rows();
    if ranges.len() <= 1 {
        return e.spmv(x, y);
    }
    let yp = SendPtr(y.as_mut_ptr());
    pool.run_chunks(ranges, |_tid, r| {
        let (lo, hi) = (r.start, r.end);
        // Row ranges are disjoint: this chunk is y[lo..hi]'s only writer.
        let chunk = unsafe { std::slice::from_raw_parts_mut(yp.get().add(lo), hi - lo) };
        chunk.fill(0.0);
        for k in 0..e.bandwidth {
            let base = k * n;
            let vals = &e.values[base + lo..base + hi];
            let cols = &e.col_idx[base + lo..base + hi];
            for i in 0..hi - lo {
                // <8> Y(I) = Y(I) + VAL(J_PTR) * X(II)
                chunk[i] += vals[i] * x[cols[i] as usize];
            }
        }
    });
}

/// Fig. 3 compatibility wrapper (global pool, on-the-fly partition).
pub fn ell_row_inner(e: &Ell, x: &[Value], y: &mut [Value], n_threads: usize) {
    let ranges = split_even(e.n_rows(), n_threads);
    ell_row_inner_on(e, x, y, &pool::global(), &ranges);
}

/// Fig. 4 — ELL-Row with the **outer band loop parallelised** over
/// precomputed band ranges (`ISTART(J)..IEND(J)`), each chunk accumulating
/// into its private `YY(:,J)`, followed by the tree reduction. Parallelism
/// is capped at the bandwidth `NE` — the paper's point that "if NE = 2,
/// the parallelism is only 2".
pub fn ell_row_outer_on(
    e: &Ell,
    x: &[Value],
    y: &mut [Value],
    pool: &ParPool,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_eq!(x.len(), e.n_cols(), "x length");
    assert_eq!(y.len(), e.n_rows(), "y length");
    let n = e.n_rows();
    if ranges.len() <= 1 {
        return e.spmv(x, y);
    }
    let k = ranges.len();
    let yy = ws.yy(n, k);
    let yyp = SendPtr(yy.as_mut_ptr());
    pool.run_chunks(ranges, |tid, r| {
        let slice = unsafe { std::slice::from_raw_parts_mut(yyp.get().add(tid * n), n) };
        for band in r {
            let base = band * n;
            let vals = &e.values[base..base + n];
            let cols = &e.col_idx[base..base + n];
            for i in 0..n {
                slice[i] += vals[i] * x[cols[i] as usize];
            }
        }
    });
    reduce_yy_tree(pool, yy, y, n, k);
}

/// Fig. 4 compatibility wrapper (global pool, on-the-fly partition).
pub fn ell_row_outer(e: &Ell, x: &[Value], y: &mut [Value], n_threads: usize, ws: &mut Workspace) {
    let ranges = split_even(e.bandwidth, n_threads); // capped at NE chunks
    ell_row_outer_on(e, x, y, &pool::global(), &ranges, ws);
}

/// Accumulate one **full** SELL band (`rows` active lanes, every lane
/// populated) into the per-lane accumulators: `acc[i] += vals[i] *
/// x[cols[i]]`. The band is a contiguous unit-stride slice, which is what
/// makes this loop the format's vector payoff.
///
/// With the `simd` cargo feature the lane loop is unrolled into explicit
/// 4-wide blocks — the shape the compiler turns into packed
/// mul-add/gather sequences on stable Rust (no nightly `std::simd`
/// needed). Per-lane sums are independent and each lane still sees its
/// bands in ascending-`k` order, so both paths are bitwise-identical.
#[inline]
fn sell_band_accumulate(acc: &mut [Value], vals: &[Value], cols: &[Index], x: &[Value]) {
    debug_assert_eq!(acc.len(), vals.len());
    debug_assert_eq!(acc.len(), cols.len());
    #[cfg(feature = "simd")]
    {
        let rows = acc.len();
        let mut i = 0usize;
        while i + 4 <= rows {
            acc[i] += vals[i] * x[cols[i] as usize];
            acc[i + 1] += vals[i + 1] * x[cols[i + 1] as usize];
            acc[i + 2] += vals[i + 2] * x[cols[i + 2] as usize];
            acc[i + 3] += vals[i + 3] * x[cols[i + 3] as usize];
            i += 4;
        }
        while i < rows {
            acc[i] += vals[i] * x[cols[i] as usize];
            i += 1;
        }
    }
    #[cfg(not(feature = "simd"))]
    for i in 0..acc.len() {
        acc[i] += vals[i] * x[cols[i] as usize];
    }
}

/// Compute chunk `q` of a SELL-C-σ operator into the stack accumulators
/// `acc[..rows]`: full bands first (`k < min_len`, every lane active — the
/// unit-stride [`sell_band_accumulate`] fast path), then the ragged tail
/// with a per-lane length guard. Padding slots are **never** accumulated
/// (the guard stops at the stored logical row length), so each sorted
/// row's sum is exactly its CSR left-to-right sum — bitwise, even when
/// `x` holds `-0.0`/`inf`/`NaN` that a `0.0 * x[pad]` term would perturb.
/// Returns the number of active lanes.
#[inline]
fn sell_chunk_into(s: &SellCSigma, x: &[Value], q: usize, acc: &mut [Value; MAX_C]) -> usize {
    let rows = s.chunk_rows(q);
    let base = q * s.c;
    let off = s.chunk_off[q];
    let width = s.chunk_width[q];
    let lens = &s.row_len[base..base + rows];
    let min_len = lens.iter().copied().min().unwrap_or(0) as usize;
    acc[..rows].fill(0.0);
    for k in 0..min_len {
        let p = off + k * rows;
        sell_band_accumulate(&mut acc[..rows], &s.values[p..p + rows], &s.col_idx[p..p + rows], x);
    }
    for k in min_len..width {
        let p = off + k * rows;
        let vals = &s.values[p..p + rows];
        let cols = &s.col_idx[p..p + rows];
        for i in 0..rows {
            if (k as Index) < lens[i] {
                acc[i] += vals[i] * x[cols[i] as usize];
            }
        }
    }
    rows
}

/// SELL-C-σ chunk-parallel SpMV (extension) over precomputed **chunk**
/// ranges: each worker owns a contiguous run of C-row chunks, keeps the
/// C partial sums in stack registers and scatters the finished chunk
/// through the row permutation. Like Fig. 3 there is no reduction — the
/// permutation is a bijection, so every output row has exactly one
/// writer — but unlike ELL the bands are only C lanes tall and padded to
/// the *chunk* width, so the σ-window sort keeps the wasted lanes near
/// zero on irregular row-length distributions.
pub fn sell_row_inner_on(
    s: &SellCSigma,
    x: &[Value],
    y: &mut [Value],
    pool: &ParPool,
    ranges: &[Range<usize>],
) {
    assert_eq!(x.len(), s.n_cols(), "x length");
    assert_eq!(y.len(), s.n_rows(), "y length");
    if ranges.len() <= 1 {
        return s.spmv(x, y);
    }
    let yp = SendPtr(y.as_mut_ptr());
    pool.run_chunks(ranges, |_tid, qs| {
        let mut acc = [0.0 as Value; MAX_C];
        for q in qs {
            let rows = sell_chunk_into(s, x, q, &mut acc);
            let base = q * s.c;
            for i in 0..rows {
                // perm is a bijection and each sorted slot belongs to
                // exactly one chunk: y[perm[...]] has exactly one writer.
                unsafe { *yp.get().add(s.perm[base + i] as usize) = acc[i] };
            }
        }
    });
}

/// SELL-C-σ compatibility wrapper (global pool, on-the-fly partition).
pub fn sell_row_inner(s: &SellCSigma, x: &[Value], y: &mut [Value], n_threads: usize) {
    let ranges = split_even(s.n_chunks(), n_threads);
    sell_row_inner_on(s, x, y, &pool::global(), &ranges);
}

// ---- Blocked multi-RHS (SpMM) kernels ----
//
// Each `*_many_on` kernel computes `ys[j] = A·xs[j]` for a whole tile of
// right-hand sides while streaming the matrix arrays **once**: the outer
// loops walk the matrix exactly as the single-RHS kernel does, and only
// the innermost accumulation fans out over the tile. Per right-hand side
// the floating-point accumulation order is identical to the single-RHS
// kernel, so a tiled batch is bitwise-identical to looped single
// executes. When the precomputed partition is degenerate
// (`ranges.len() <= 1`) each kernel falls back to the same serial path
// the single-RHS kernel uses, per right-hand side, preserving that
// bitwise identity.

fn assert_tile(xs: &[&[Value]], ys: &[&mut [Value]], n_cols: usize, n_rows: usize) {
    assert_eq!(xs.len(), ys.len(), "tile width");
    for x in xs {
        assert_eq!(x.len(), n_cols, "x length");
    }
    for y in ys.iter() {
        assert_eq!(y.len(), n_rows, "y length");
    }
}

/// Sequential CRS SpMM: one pass over the CRS arrays serves every
/// right-hand side in the tile (the multi-RHS form of [`csr_seq`]).
pub fn csr_seq_many(a: &Csr, xs: &[&[Value]], ys: &mut [&mut [Value]]) {
    assert_tile(xs, ys, a.n_cols(), a.n_rows());
    for i in 0..a.n_rows() {
        for y in ys.iter_mut() {
            y[i] = 0.0;
        }
        for k in a.row_ptr[i]..a.row_ptr[i + 1] {
            let v = a.values[k];
            let c = a.col_idx[k] as usize;
            for (y, x) in ys.iter_mut().zip(xs) {
                y[i] += v * x[c];
            }
        }
    }
}

/// Row-parallel CRS SpMM over precomputed nnz-balanced row ranges: each
/// chunk streams its rows once and writes the same disjoint row slice of
/// every output in the tile.
pub fn csr_row_par_many_on(
    a: &Csr,
    xs: &[&[Value]],
    ys: &mut [&mut [Value]],
    pool: &ParPool,
    ranges: &[Range<usize>],
) {
    assert_tile(xs, ys, a.n_cols(), a.n_rows());
    if ranges.len() <= 1 {
        // Same serial path as the single-RHS kernel, per right-hand side.
        for (y, x) in ys.iter_mut().zip(xs) {
            csr_seq(a, x, y);
        }
        return;
    }
    let yps: Vec<SendPtr<Value>> = ys.iter_mut().map(|y| SendPtr(y.as_mut_ptr())).collect();
    pool.run_chunks(ranges, |_tid, r| {
        for i in r {
            // Row ranges are disjoint: each ys[j][i] has exactly one writer.
            for yp in &yps {
                unsafe { *yp.get().add(i) = 0.0 };
            }
            for k in a.row_ptr[i]..a.row_ptr[i + 1] {
                let v = a.values[k];
                let c = a.col_idx[k] as usize;
                for (yp, x) in yps.iter().zip(xs) {
                    unsafe { *yp.get().add(i) += v * x[c] };
                }
            }
        }
    });
}

/// Merge-path parallel CRS SpMM over a precomputed [`MergePartition`]:
/// one pass over each chunk's merge span serves the whole tile, fanning
/// every stored element out to all right-hand sides (the multi-RHS form
/// of [`csr_merge_par_on`]). Carry slots widen to `2·k·tile` — head and
/// tail per (chunk, RHS) — and the serial [`merge_fixup`] runs once per
/// right-hand side, so each output's accumulation order matches the
/// single-RHS kernel bitwise.
pub fn csr_merge_par_many_on(
    a: &Csr,
    xs: &[&[Value]],
    ys: &mut [&mut [Value]],
    pool: &ParPool,
    mp: &MergePartition,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_tile(xs, ys, a.n_cols(), a.n_rows());
    let kc = mp.n_chunks();
    if kc <= 1 || ranges.len() <= 1 {
        // Same serial path as the single-RHS kernel, per right-hand side.
        for (y, x) in ys.iter_mut().zip(xs) {
            csr_seq(a, x, y);
        }
        return;
    }
    let b = xs.len();
    if b == 0 {
        return;
    }
    debug_assert_eq!(ranges.len(), kc, "one unit range per merge chunk");
    let carry = ws.yy(2 * kc * b, 1);
    let yps: Vec<SendPtr<Value>> = ys.iter_mut().map(|y| SendPtr(y.as_mut_ptr())).collect();
    let cp = SendPtr(carry.as_mut_ptr());
    pool.run_chunks(ranges, |_tid, ts| {
        for t in ts {
            let (r0, v0) = mp.bounds[t];
            let (r1, v1) = mp.bounds[t + 1];
            let mut v = v0;
            for r in r0..r1 {
                let end = a.row_ptr[r + 1];
                if r == r0 && v0 > a.row_ptr[r0] {
                    // Head segments accumulate into the pre-zeroed
                    // carry slots 2·(t·b + j); one writer each.
                    for e in v..end {
                        let val = a.values[e];
                        let c = a.col_idx[e] as usize;
                        for (j, x) in xs.iter().enumerate() {
                            unsafe { *cp.get().add(2 * (t * b + j)) += val * x[c] };
                        }
                    }
                } else {
                    for yp in &yps {
                        unsafe { *yp.get().add(r) = 0.0 };
                    }
                    for e in v..end {
                        let val = a.values[e];
                        let c = a.col_idx[e] as usize;
                        for (yp, x) in yps.iter().zip(xs) {
                            unsafe { *yp.get().add(r) += val * x[c] };
                        }
                    }
                }
                v = end;
            }
            for e in v..v1 {
                let val = a.values[e];
                let c = a.col_idx[e] as usize;
                for (j, x) in xs.iter().enumerate() {
                    unsafe { *cp.get().add(2 * (t * b + j) + 1) += val * x[c] };
                }
            }
        }
    });
    for (j, y) in ys.iter_mut().enumerate() {
        merge_fixup(&a.row_ptr, mp, carry, b, j, y);
    }
}

/// Shared multi-RHS body of Figs. 1 and 2: each chunk streams its entry
/// range once, accumulating into a private `n × tile` block of `YY`, then
/// the pairwise tree reduction runs per right-hand side.
fn coo_outer_many_on(
    c: &Coo,
    xs: &[&[Value]],
    ys: &mut [&mut [Value]],
    pool: &ParPool,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_tile(xs, ys, c.n_cols(), c.n_rows());
    let n = c.n_rows();
    let b = xs.len();
    if ranges.len() <= 1 {
        for (y, x) in ys.iter_mut().zip(xs) {
            c.spmv(x, y);
        }
        return;
    }
    let k = ranges.len();
    let yy = ws.yy(n * b, k);
    let yyp = SendPtr(yy.as_mut_ptr());
    pool.run_chunks(ranges, |tid, r| {
        // Chunk `tid` owns the disjoint block yy[tid*n*b .. (tid+1)*n*b];
        // right-hand side `j` lives at offset j*n inside it.
        let block = unsafe { std::slice::from_raw_parts_mut(yyp.get().add(tid * n * b), n * b) };
        for e in r {
            let row = c.row_idx[e] as usize;
            let col = c.col_idx[e] as usize;
            let v = c.values[e];
            for (j, x) in xs.iter().enumerate() {
                block[j * n + row] += v * x[col];
            }
        }
    });
    reduce_yy_tree_many(pool, yy, ys, n, b, k);
}

/// Reduce `k` private `n × b` blocks `yy[t*n*b..(t+1)*n*b]` into the `b`
/// outputs, as the same pairwise tree [`reduce_yy_tree`] runs — per
/// right-hand side, so each output's summation order matches the
/// single-RHS reduction bitwise. Overwrites every `ys[j]` entirely.
pub(crate) fn reduce_yy_tree_many(
    pool: &ParPool,
    yy: &mut [Value],
    ys: &mut [&mut [Value]],
    n: usize,
    b: usize,
    k: usize,
) {
    debug_assert!(yy.len() >= n * b * k);
    debug_assert_eq!(ys.len(), b);
    if n == 0 || b == 0 {
        return;
    }
    let row_ranges = split_even(n, pool.size());
    let yyp = SendPtr(yy.as_mut_ptr());
    let yps: Vec<SendPtr<Value>> = ys.iter_mut().map(|y| SendPtr(y.as_mut_ptr())).collect();
    pool.run_chunks(&row_ranges, |_tid, r| {
        for (j, yp) in yps.iter().enumerate() {
            let mut stride = 1usize;
            while stride < k {
                let mut t = 0usize;
                while t + stride < k {
                    unsafe {
                        let dst = yyp.get().add(t * n * b + j * n);
                        let src = yyp.get().add((t + stride) * n * b + j * n) as *const Value;
                        for i in r.clone() {
                            *dst.add(i) += *src.add(i);
                        }
                    }
                    t += 2 * stride;
                }
                stride *= 2;
            }
            unsafe {
                let src = yyp.get().add(j * n) as *const Value;
                for i in r.clone() {
                    *yp.get().add(i) = *src.add(i);
                }
            }
        }
    });
}

/// Fig. 1, blocked: multi-RHS SpMM over the **column-major** COO stream.
///
/// # Panics
/// Panics if `c` is not column-major ordered.
pub fn coo_col_outer_many_on(
    c: &Coo,
    xs: &[&[Value]],
    ys: &mut [&mut [Value]],
    pool: &ParPool,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_eq!(c.order(), CooOrder::ColMajor, "Fig. 1 requires COO-Column data");
    coo_outer_many_on(c, xs, ys, pool, ranges, ws);
}

/// Fig. 2, blocked: multi-RHS SpMM over the **row-major** COO stream.
///
/// # Panics
/// Panics if `c` is not row-major ordered.
pub fn coo_row_outer_many_on(
    c: &Coo,
    xs: &[&[Value]],
    ys: &mut [&mut [Value]],
    pool: &ParPool,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_eq!(c.order(), CooOrder::RowMajor, "Fig. 2 requires COO-Row data");
    coo_outer_many_on(c, xs, ys, pool, ranges, ws);
}

/// Fig. 3, blocked: each chunk owns a contiguous row range and streams
/// every band over it once, fanning the padded entry out to the whole
/// tile of right-hand sides.
pub fn ell_row_inner_many_on(
    e: &Ell,
    xs: &[&[Value]],
    ys: &mut [&mut [Value]],
    pool: &ParPool,
    ranges: &[Range<usize>],
) {
    assert_tile(xs, ys, e.n_cols(), e.n_rows());
    let n = e.n_rows();
    if ranges.len() <= 1 {
        for (y, x) in ys.iter_mut().zip(xs) {
            e.spmv(x, y);
        }
        return;
    }
    let yps: Vec<SendPtr<Value>> = ys.iter_mut().map(|y| SendPtr(y.as_mut_ptr())).collect();
    pool.run_chunks(ranges, |_tid, r| {
        let (lo, hi) = (r.start, r.end);
        // Row ranges are disjoint: this chunk is rows lo..hi's only writer.
        for yp in &yps {
            let chunk = unsafe { std::slice::from_raw_parts_mut(yp.get().add(lo), hi - lo) };
            chunk.fill(0.0);
        }
        for k in 0..e.bandwidth {
            let base = k * n;
            let vals = &e.values[base + lo..base + hi];
            let cols = &e.col_idx[base + lo..base + hi];
            for i in 0..hi - lo {
                let v = vals[i];
                let c = cols[i] as usize;
                for (yp, x) in yps.iter().zip(xs) {
                    unsafe { *yp.get().add(lo + i) += v * x[c] };
                }
            }
        }
    });
}

/// Fig. 4, blocked: each chunk streams its band range once into a private
/// `n × tile` block of `YY`, followed by the per-RHS tree reduction.
pub fn ell_row_outer_many_on(
    e: &Ell,
    xs: &[&[Value]],
    ys: &mut [&mut [Value]],
    pool: &ParPool,
    ranges: &[Range<usize>],
    ws: &mut Workspace,
) {
    assert_tile(xs, ys, e.n_cols(), e.n_rows());
    let n = e.n_rows();
    let b = xs.len();
    if ranges.len() <= 1 {
        for (y, x) in ys.iter_mut().zip(xs) {
            e.spmv(x, y);
        }
        return;
    }
    let k = ranges.len();
    let yy = ws.yy(n * b, k);
    let yyp = SendPtr(yy.as_mut_ptr());
    pool.run_chunks(ranges, |tid, r| {
        let block = unsafe { std::slice::from_raw_parts_mut(yyp.get().add(tid * n * b), n * b) };
        for band in r {
            let base = band * n;
            let vals = &e.values[base..base + n];
            let cols = &e.col_idx[base..base + n];
            for i in 0..n {
                let v = vals[i];
                let c = cols[i] as usize;
                for (j, x) in xs.iter().enumerate() {
                    block[j * n + i] += v * x[c];
                }
            }
        }
    });
    reduce_yy_tree_many(pool, yy, ys, n, b, k);
}

/// SELL-C-σ, blocked: each worker walks its chunk range once per
/// right-hand side. A chunk (C lanes × chunk width) is small enough to
/// stay cache-resident across the tile, so DRAM sees roughly one matrix
/// stream per tile even though the walk is per-RHS; keeping the per-RHS
/// walk identical to [`sell_row_inner_on`] preserves the bitwise
/// contract of [`kernels::run_many_on`] for free.
pub fn sell_row_inner_many_on(
    s: &SellCSigma,
    xs: &[&[Value]],
    ys: &mut [&mut [Value]],
    pool: &ParPool,
    ranges: &[Range<usize>],
) {
    assert_tile(xs, ys, s.n_cols(), s.n_rows());
    if ranges.len() <= 1 {
        for (y, x) in ys.iter_mut().zip(xs) {
            s.spmv(x, y);
        }
        return;
    }
    let yps: Vec<SendPtr<Value>> = ys.iter_mut().map(|y| SendPtr(y.as_mut_ptr())).collect();
    pool.run_chunks(ranges, |_tid, qs| {
        let mut acc = [0.0 as Value; MAX_C];
        for q in qs {
            let base = q * s.c;
            for (yp, x) in yps.iter().zip(xs) {
                let rows = sell_chunk_into(s, x, q, &mut acc);
                for i in 0..rows {
                    unsafe { *yp.get().add(s.perm[base + i] as usize) = acc[i] };
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;
    use crate::transform::{crs_to_coo_col, crs_to_coo_row, crs_to_ell, crs_to_sell_with};

    fn assert_close(a: &[Value], b: &[Value]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-9 * (1.0 + x.abs()),
                "index {i}: {x} vs {y}"
            );
        }
    }

    fn cases() -> Vec<Csr> {
        let mut rng = Rng::new(31);
        vec![
            random_csr(&mut rng, 1, 1, 1.0),
            random_csr(&mut rng, 17, 17, 0.3),
            random_csr(&mut rng, 128, 96, 0.06),
            random_csr(&mut rng, 200, 200, 0.02),
            Csr::from_triplets(9, 9, &[]).unwrap(),
        ]
    }

    #[test]
    fn all_kernels_match_baseline_across_threads() {
        let mut ws = Workspace::new();
        for a in cases() {
            let x: Vec<Value> = (0..a.n_cols()).map(|i| ((i * 7 + 1) as f64).recip()).collect();
            let mut want = vec![0.0; a.n_rows()];
            csr_seq(&a, &x, &mut want);
            let ell = crs_to_ell(&a).unwrap();
            let coo_r = crs_to_coo_row(&a);
            let coo_c = crs_to_coo_col(&a);
            for t in [1usize, 2, 3, 4, 9] {
                let mut y = vec![0.0; a.n_rows()];
                csr_row_par(&a, &x, &mut y, t);
                assert_close(&y, &want);
                csr_merge_par(&a, &x, &mut y, t, &mut ws);
                assert_close(&y, &want);
                coo_col_outer(&coo_c, &x, &mut y, t, &mut ws);
                assert_close(&y, &want);
                coo_row_outer(&coo_r, &x, &mut y, t, &mut ws);
                assert_close(&y, &want);
                ell_row_inner(&ell, &x, &mut y, t);
                assert_close(&y, &want);
                ell_row_outer(&ell, &x, &mut y, t, &mut ws);
                assert_close(&y, &want);
                for (c, sigma) in [(1, 1), (4, 8), (32, a.n_rows().max(1))] {
                    let sell = crs_to_sell_with(&a, c, sigma).unwrap();
                    sell_row_inner(&sell, &x, &mut y, t);
                    // SELL never touches padding and keeps per-row CSR
                    // order, so it is *bitwise* equal to the baseline.
                    assert_eq!(y, want, "sell C={c} sigma={sigma} t={t}");
                }
            }
        }
    }

    #[test]
    fn explicit_pool_kernels_match_baseline() {
        // The `_on` entry points with a dedicated (non-global) pool and
        // hand-built partitions must agree with the baseline too.
        let pool = ParPool::new(3);
        let mut ws = Workspace::new();
        let a = cases()[2].clone();
        let x: Vec<Value> = (0..a.n_cols()).map(|i| (i as f64 * 0.3).cos()).collect();
        let mut want = vec![0.0; a.n_rows()];
        csr_seq(&a, &x, &mut want);

        let mut y = vec![0.0; a.n_rows()];
        csr_row_par_on(&a, &x, &mut y, &pool, &split_by_nnz(&a.row_ptr, 5));
        assert_close(&y, &want);

        let mp = merge_path_split(&a.row_ptr, 5);
        let unit: Vec<Range<usize>> = (0..mp.n_chunks()).map(|t| t..t + 1).collect();
        csr_merge_par_on(&a, &x, &mut y, &pool, &mp, &unit, &mut ws);
        assert_close(&y, &want);

        let ell = crs_to_ell(&a).unwrap();
        ell_row_inner_on(&ell, &x, &mut y, &pool, &split_even(ell.n_rows(), 5));
        assert_close(&y, &want);
        ell_row_outer_on(&ell, &x, &mut y, &pool, &split_even(ell.bandwidth, 5), &mut ws);
        assert_close(&y, &want);

        let coo_r = crs_to_coo_row(&a);
        coo_row_outer_on(&coo_r, &x, &mut y, &pool, &split_even(coo_r.nnz(), 5), &mut ws);
        assert_close(&y, &want);

        let sell = crs_to_sell_with(&a, 8, 32).unwrap();
        sell_row_inner_on(&sell, &x, &mut y, &pool, &split_even(sell.n_chunks(), 5));
        assert_eq!(y, want, "sell_row_inner_on is bitwise");
    }

    #[test]
    fn tree_reduction_matches_serial_sum() {
        let pool = ParPool::new(4);
        let (n, k) = (101usize, 7usize);
        let mut yy: Vec<Value> = (0..n * k).map(|i| (i as f64 * 0.01).sin()).collect();
        let want: Vec<Value> = (0..n)
            .map(|i| (0..k).map(|t| yy[t * n + i]).sum())
            .collect();
        let mut y = vec![0.0; n];
        reduce_yy_tree(&pool, &mut yy, &mut y, n, k);
        assert_close(&y, &want);
    }

    #[test]
    fn fig1_rejects_wrong_order() {
        let a = cases()[1].clone();
        let coo_r = crs_to_coo_row(&a);
        let x = vec![1.0; a.n_cols()];
        let mut y = vec![0.0; a.n_rows()];
        let mut ws = Workspace::new();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            coo_col_outer(&coo_r, &x, &mut y, 2, &mut ws);
        }));
        assert!(err.is_err());
    }

    #[test]
    fn ell_outer_parallelism_capped_at_bandwidth() {
        // bandwidth 2, 8 threads -> must still be correct (only 2 chunks used).
        let a = Csr::from_triplets(
            4,
            4,
            &[(0, 0, 1.0), (0, 1, 2.0), (1, 1, 3.0), (2, 2, 4.0), (3, 0, 5.0), (3, 3, 6.0)],
        )
        .unwrap();
        let ell = crs_to_ell(&a).unwrap();
        assert_eq!(ell.bandwidth, 2);
        let x = [1.0, 1.0, 1.0, 1.0];
        let mut want = vec![0.0; 4];
        csr_seq(&a, &x, &mut want);
        let mut y = vec![0.0; 4];
        let mut ws = Workspace::new();
        ell_row_outer(&ell, &x, &mut y, 8, &mut ws);
        assert_close(&y, &want);
    }

    #[test]
    fn blocked_kernels_match_looped_single_rhs_bitwise() {
        let pool = ParPool::new(3);
        let mut ws = Workspace::new();
        for a in cases() {
            let (nr, nc) = (a.n_rows(), a.n_cols());
            let b = 3usize;
            let xs_own: Vec<Vec<Value>> = (0..b)
                .map(|j| (0..nc).map(|i| ((i * 3 + j + 1) as f64 * 0.41).sin()).collect())
                .collect();
            let xs: Vec<&[Value]> = xs_own.iter().map(|v| v.as_slice()).collect();
            let ell = crs_to_ell(&a).unwrap();
            let coo_r = crs_to_coo_row(&a);
            let coo_c = crs_to_coo_col(&a);

            // Reference: looped single-RHS kernels with the same partitions.
            let run_single = |f: &mut dyn FnMut(&[Value], &mut [Value])| -> Vec<Vec<Value>> {
                xs_own
                    .iter()
                    .map(|x| {
                        let mut y = vec![0.0; nr];
                        f(x, &mut y);
                        y
                    })
                    .collect()
            };
            let run_many =
                |f: &mut dyn FnMut(&[&[Value]], &mut [&mut [Value]])| -> Vec<Vec<Value>> {
                    let mut ys_own = vec![vec![0.0; nr]; b];
                    let mut ys: Vec<&mut [Value]> =
                        ys_own.iter_mut().map(|v| v.as_mut_slice()).collect();
                    f(&xs, &mut ys);
                    ys_own
                };

            let got = run_many(&mut |xs, ys| csr_seq_many(&a, xs, ys));
            assert_eq!(got, run_single(&mut |x, y| csr_seq(&a, x, y)), "csr_seq_many");

            let r_csr = split_by_nnz(&a.row_ptr, 3);
            let got = run_many(&mut |xs, ys| csr_row_par_many_on(&a, xs, ys, &pool, &r_csr));
            assert_eq!(
                got,
                run_single(&mut |x, y| csr_row_par_on(&a, x, y, &pool, &r_csr)),
                "csr_row_par_many_on"
            );

            let mp = merge_path_split(&a.row_ptr, 3);
            let r_merge: Vec<Range<usize>> = (0..mp.n_chunks()).map(|t| t..t + 1).collect();
            let got = run_many(&mut |xs, ys| {
                csr_merge_par_many_on(&a, xs, ys, &pool, &mp, &r_merge, &mut ws)
            });
            assert_eq!(
                got,
                run_single(&mut |x, y| csr_merge_par_on(
                    &a, x, y, &pool, &mp, &r_merge, &mut ws
                )),
                "csr_merge_par_many_on"
            );

            let r_ell_in = split_even(ell.n_rows(), 3);
            let got =
                run_many(&mut |xs, ys| ell_row_inner_many_on(&ell, xs, ys, &pool, &r_ell_in));
            assert_eq!(
                got,
                run_single(&mut |x, y| ell_row_inner_on(&ell, x, y, &pool, &r_ell_in)),
                "ell_row_inner_many_on"
            );

            let r_ell_out = split_even(ell.bandwidth, 3);
            let got = run_many(&mut |xs, ys| {
                ell_row_outer_many_on(&ell, xs, ys, &pool, &r_ell_out, &mut ws)
            });
            assert_eq!(
                got,
                run_single(&mut |x, y| ell_row_outer_on(&ell, x, y, &pool, &r_ell_out, &mut ws)),
                "ell_row_outer_many_on"
            );

            let r_coo = split_even(coo_r.nnz(), 3);
            let got = run_many(&mut |xs, ys| {
                coo_row_outer_many_on(&coo_r, xs, ys, &pool, &r_coo, &mut ws)
            });
            assert_eq!(
                got,
                run_single(&mut |x, y| coo_row_outer_on(&coo_r, x, y, &pool, &r_coo, &mut ws)),
                "coo_row_outer_many_on"
            );

            let got = run_many(&mut |xs, ys| {
                coo_col_outer_many_on(&coo_c, xs, ys, &pool, &r_coo, &mut ws)
            });
            assert_eq!(
                got,
                run_single(&mut |x, y| coo_col_outer_on(&coo_c, x, y, &pool, &r_coo, &mut ws)),
                "coo_col_outer_many_on"
            );

            let sell = crs_to_sell_with(&a, 4, 8).unwrap();
            let r_sell = split_even(sell.n_chunks(), 3);
            let got =
                run_many(&mut |xs, ys| sell_row_inner_many_on(&sell, xs, ys, &pool, &r_sell));
            assert_eq!(
                got,
                run_single(&mut |x, y| sell_row_inner_on(&sell, x, y, &pool, &r_sell)),
                "sell_row_inner_many_on"
            );
        }
    }

    #[test]
    fn merge_kernel_bitwise_on_exact_giant_row_fixture() {
        // One row holds 16 of 22 nnz; every value and x entry is an exact
        // binary fraction, so partial sums are exactly representable and
        // the merge kernel's chunk-boundary re-association is invisible:
        // the result must be bit-for-bit equal to csr_seq on every thread
        // count, and identical across reruns of the same partition.
        let (n, nc) = (8usize, 16usize);
        let mut trips: Vec<(usize, usize, Value)> = Vec::new();
        for c in 0..nc {
            trips.push((3, c, 0.25 + c as Value * 0.125));
        }
        for (r, c) in [(0usize, 1usize), (1, 0), (5, 5), (6, 2), (6, 7), (7, 0)] {
            trips.push((r, c, 0.5 + (r + c) as Value * 0.0625));
        }
        let a = Csr::from_triplets(n, nc, &trips).unwrap();
        let x: Vec<Value> = (0..nc).map(|i| 1.0 + i as Value * 0.125).collect();
        let mut want = vec![0.0; n];
        csr_seq(&a, &x, &mut want);
        let mut ws = Workspace::new();
        for t in [1usize, 2, 3, 5, 9] {
            let mp = merge_path_split(&a.row_ptr, t);
            let unit: Vec<Range<usize>> = (0..mp.n_chunks()).map(|q| q..q + 1).collect();
            let pool = ParPool::new(t);
            let mut y = vec![0.0; n];
            csr_merge_par_on(&a, &x, &mut y, &pool, &mp, &unit, &mut ws);
            assert_eq!(y, want, "t={t}");
            let mut y2 = vec![0.0; n];
            csr_merge_par_on(&a, &x, &mut y2, &pool, &mp, &unit, &mut ws);
            assert_eq!(y2, y, "rerun stability t={t}");
        }
    }

    #[test]
    fn workspace_reuse_does_not_leak_state() {
        let mut ws = Workspace::new();
        let a = cases()[2].clone();
        let coo = crs_to_coo_row(&a);
        let x = vec![1.0; a.n_cols()];
        let mut want = vec![0.0; a.n_rows()];
        csr_seq(&a, &x, &mut want);
        for _ in 0..3 {
            let mut y = vec![0.0; a.n_rows()];
            coo_row_outer(&coo, &x, &mut y, 4, &mut ws);
            assert_close(&y, &want);
        }
        assert!(ws.capacity_bytes() > 0);
    }
}
