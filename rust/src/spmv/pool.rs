//! Persistent worker pool — the crate's single thread-spawning site.
//!
//! The paper's premise is that run-time transformation cost is amortised
//! over many SpMV calls, but a fork/join of fresh OS threads on *every*
//! call (the "thread fork overhead" its §3 listings warn about) eats the
//! amortised win back. [`ParPool`] keeps a fixed set of parked workers
//! alive for the life of the process (or of a coordinator / `Durmv`
//! handle) and hands them pre-partitioned chunk ranges through
//! [`ParPool::run_chunks`]; the hot path performs no `spawn`, no
//! allocation, and no channel traffic — one mutex/condvar handshake per
//! call.
//!
//! Invariants:
//!
//! * `std::thread::scope`/`std::thread::spawn` for kernel or transform
//!   work exist **only in this file**; every parallel code path in the
//!   crate executes through a pool.
//! * `run_chunks` blocks until every chunk has finished, so borrowed
//!   closures and range slices never escape the call (the lifetime
//!   erasure below is sound for exactly this reason).
//! * The caller participates in chunk execution instead of idling, so a
//!   pool of size `k` uses `k-1` parked workers plus the calling thread.
//! * Nested `run_chunks` calls (a chunk body re-entering the pool) fall
//!   back to serial execution instead of deadlocking.
//!
//! The pool size defaults to [`configured_threads`]: the `SPMV_AT_THREADS`
//! environment variable when set, otherwise the hardware parallelism.
//! That function is the crate-wide single source of thread-count truth.
//!
//! **Cross-pool join.** [`PoolGroup::join_all`] is the fan-out *across*
//! pools: it runs one closure per pool concurrently (each on its own
//! fan-out thread, pinned to its pool's CPU set) and blocks until all
//! complete, with per-call overlap counters ([`PoolGroup::max_in_flight`],
//! [`PoolGroup::join_count`]). The cross-socket split plan executes its
//! row blocks through it, so blocks on different sockets are genuinely in
//! flight simultaneously. Being a thread-spawning primitive, it lives in
//! this file like everything else that spawns.
//!
//! **NUMA affinity.** A pool built with [`ParPool::new_pinned`] pins every
//! worker to a CPU set (one socket, in the shard layer's usage) via the
//! [`crate::machine::topology::pin_current_thread`] shim — best-effort,
//! no-op off Linux. [`ParPool::run_init`] is the *initialization* fan-out:
//! identical to [`ParPool::run_chunks`] but counted separately
//! ([`ParPool::init_count`]), it is what plan construction and the
//! parallel transforms run their array-materialising writes through, so
//! on a pinned pool every transformed page is first-touched on the owning
//! socket — and the counter makes that routing observable to tests.
//!
//! # Example
//!
//! Fan a reduction out over a pool, then build and execute a plan on it:
//!
//! ```
//! use spmv_at::spmv::pool::ParPool;
//! use spmv_at::spmv::{Implementation, SpmvPlan};
//! use spmv_at::formats::Csr;
//! use std::sync::Arc;
//! use std::sync::atomic::{AtomicUsize, Ordering};
//!
//! let pool = Arc::new(ParPool::new(2));
//! let total = AtomicUsize::new(0);
//! pool.run_chunks(&[0..50, 50..100], |_chunk, r| {
//!     total.fetch_add(r.sum::<usize>(), Ordering::Relaxed);
//! });
//! assert_eq!(total.into_inner(), 4950);
//!
//! // Plans execute on the same persistent workers (see `spmv::plan`).
//! let a = Arc::new(Csr::identity(4));
//! let before = pool.init_count();
//! let mut plan = SpmvPlan::build(&a, Implementation::CsrRowPar, None, pool.clone()).unwrap();
//! assert!(pool.init_count() > before, "builds first-touch through run_init");
//! let mut y = vec![0.0; 4];
//! plan.execute(&[1.0, 2.0, 3.0, 4.0], &mut y).unwrap();
//! assert_eq!(y, vec![1.0, 2.0, 3.0, 4.0]);
//! ```

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::thread::JoinHandle;

/// The crate-wide thread-count: `SPMV_AT_THREADS` when set to a positive
/// integer, else the hardware's available parallelism.
pub fn configured_threads() -> usize {
    match std::env::var("SPMV_AT_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => default_threads(),
        },
        Err(_) => default_threads(),
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

static GLOBAL: OnceLock<Arc<ParPool>> = OnceLock::new();

/// The process-wide shared pool, sized by [`configured_threads`] on first
/// use. Library entry points that take a plain `n_threads` count execute
/// on this pool (`n_threads` becomes the chunk count, so any request is
/// served correctly even when it exceeds the pool size).
pub fn global() -> Arc<ParPool> {
    GLOBAL
        .get_or_init(|| Arc::new(ParPool::new(configured_threads())))
        .clone()
}

/// Send/Sync wrapper for a raw pointer into a buffer that chunk bodies
/// write through at provably disjoint indices (each chunk owns its range).
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub(crate) fn get(self) -> *mut T {
        self.0
    }
}

/// A published unit of work: the chunk body plus the range table, with
/// borrow lifetimes erased (sound because `run_chunks` blocks until
/// `pending == 0`, keeping both borrows alive past the last use).
struct Job {
    f: *const (dyn Fn(usize, Range<usize>) + Sync),
    ranges: *const [Range<usize>],
}

unsafe impl Send for Job {}

struct PoolState {
    job: Option<Job>,
    /// Bumped once per published job so parked workers can tell a new job
    /// from the one they already drained.
    epoch: u64,
    /// Next chunk index to claim.
    next_chunk: usize,
    /// Chunks claimed-or-unclaimed that have not finished executing.
    pending: usize,
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// Callers park here while a job drains (and while waiting for the
    /// job slot when several callers share one pool).
    done: Condvar,
}

impl PoolShared {
    fn lock(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

thread_local! {
    /// Set while this thread is executing a chunk body; a nested
    /// `run_chunks` from such a context runs serially instead of
    /// deadlocking on the single job slot.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// A persistent worker pool with a scoped fork/join primitive.
pub struct ParPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    /// The CPU set every worker pinned itself to at spawn (`None` =
    /// unpinned). The sharded server reads this back to pin its request
    /// loop onto the same socket.
    affinity: Option<Arc<Vec<usize>>>,
    /// Chunked jobs dispatched over the pool's lifetime (including serial
    /// fallbacks) — the observability counter the SpMM pass-count tests
    /// read to prove a tiled batch streams the matrix once per tile.
    dispatches: AtomicU64,
    /// Initialization fan-outs ([`ParPool::run_init`]) over the pool's
    /// lifetime — the observability counter proving plan builds and
    /// re-plans first-touch their arrays on this pool's workers.
    inits: AtomicU64,
}

impl ParPool {
    /// Pool of logical size `size` (`size - 1` parked workers; the caller
    /// of [`ParPool::run_chunks`] is the remaining thread). `size == 1`
    /// spawns nothing and runs everything serially.
    pub fn new(size: usize) -> Self {
        Self::new_pinned(size, None)
    }

    /// Pool of logical size `size` whose workers pin themselves to `cpus`
    /// at spawn (the whole set, so the OS can still balance within the
    /// socket). Pinning is best-effort — see
    /// [`crate::machine::topology::pin_current_thread`] — and `None` (or
    /// an empty set) spawns an ordinary unpinned pool.
    pub fn new_pinned(size: usize, cpus: Option<Vec<usize>>) -> Self {
        let size = size.max(1);
        let affinity = cpus.filter(|c| !c.is_empty()).map(Arc::new);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                next_chunk: 0,
                pending: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(size - 1);
        for id in 1..size {
            let sh = Arc::clone(&shared);
            let aff = affinity.clone();
            let h = std::thread::Builder::new()
                .name(format!("spmv-pool-{id}"))
                .spawn(move || {
                    if let Some(cpus) = &aff {
                        crate::machine::topology::pin_current_thread(cpus);
                    }
                    worker_loop(&sh)
                })
                .expect("spawn pool worker");
            workers.push(h);
        }
        Self {
            shared,
            workers,
            size,
            affinity,
            dispatches: AtomicU64::new(0),
            inits: AtomicU64::new(0),
        }
    }

    /// Pool sized by [`configured_threads`].
    pub fn with_configured_size() -> Self {
        Self::new(configured_threads())
    }

    /// Logical size (workers + caller).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Chunked jobs dispatched so far (monotonic; serial fallbacks count
    /// too). A blocked SpMM kernel performs a fixed number of dispatches
    /// per matrix pass, so the delta of this counter across an
    /// `execute_many` call exposes the ⌈k/tile⌉ pass count.
    pub fn dispatch_count(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Initialization fan-outs so far (monotonic). Every
    /// [`ParPool::run_init`] call counts — including degenerate ones whose
    /// range table is empty — so a plan build on this pool is always
    /// visible as a positive delta.
    pub fn init_count(&self) -> u64 {
        self.inits.load(Ordering::Relaxed)
    }

    /// The CPU set this pool's workers pinned to, if any.
    pub fn affinity(&self) -> Option<&[usize]> {
        self.affinity.as_ref().map(|a| a.as_slice())
    }

    /// [`ParPool::run_chunks`], counted as an **initialization** fan-out:
    /// the entry point for work that *materialises* arrays (parallel
    /// CRS→COO/ELL/CCS transforms, plan-build first-touch passes) rather
    /// than consuming them. On a pinned pool every chunk body executes on
    /// the pool's socket — the parked workers are pinned at spawn, and
    /// the **calling thread** (which claims chunks too, and runs
    /// everything on width-1 pools) is temporarily moved onto the same
    /// CPU set for the duration of the fan-out
    /// ([`crate::machine::topology::with_affinity`], original mask
    /// restored after) — so pages written here are first-touched —
    /// physically allocated — on that socket's memory regardless of where
    /// the build was driven from. [`ParPool::init_count`] exposes how
    /// many such fan-outs ran.
    pub fn run_init(&self, ranges: &[Range<usize>], f: impl Fn(usize, Range<usize>) + Sync) {
        self.inits.fetch_add(1, Ordering::Relaxed);
        match &self.affinity {
            Some(cpus) => crate::machine::topology::with_affinity(cpus, || {
                self.run_chunks(ranges, f);
            }),
            None => self.run_chunks(ranges, f),
        }
    }

    /// Execute `f(chunk_index, range)` once per range, in parallel across
    /// the pool, blocking until every chunk has finished. Chunk indices
    /// are the positions in `ranges`, so a body indexing a per-chunk
    /// buffer by `tid` gets a disjoint slot per chunk.
    ///
    /// Chunks are claimed dynamically (a fast worker takes more), so
    /// passing more ranges than the pool size is correct — parallelism is
    /// simply capped at `self.size()`.
    ///
    /// # Panics
    /// Re-raises (as a single panic) if any chunk body panicked; the pool
    /// itself stays usable afterwards.
    #[allow(clippy::useless_transmute)] // lifetime-erasing transmute below
    pub fn run_chunks(&self, ranges: &[Range<usize>], f: impl Fn(usize, Range<usize>) + Sync) {
        let n = ranges.len();
        if n == 0 {
            return;
        }
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        let nested = IN_POOL.with(|c| c.get());
        if n == 1 || self.workers.is_empty() || nested {
            for (i, r) in ranges.iter().enumerate() {
                f(i, r.clone());
            }
            return;
        }
        let f_ref: &(dyn Fn(usize, Range<usize>) + Sync) = &f;
        // Erase the borrow lifetimes. Sound: this function does not return
        // until `pending == 0`, i.e. until no thread can touch the job.
        let f_static: &'static (dyn Fn(usize, Range<usize>) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize, Range<usize>) + Sync),
                &'static (dyn Fn(usize, Range<usize>) + Sync),
            >(f_ref)
        };
        let job = Job { f: f_static as *const _, ranges: ranges as *const [Range<usize>] };
        {
            let mut st = self.shared.lock();
            // One job slot: if another caller's job is in flight, queue
            // behind it (its owner clears the slot and signals `done`).
            while st.job.is_some() {
                st = self.shared.done.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            st.job = Some(job);
            st.epoch = st.epoch.wrapping_add(1);
            st.next_chunk = 0;
            st.pending = n;
            st.panicked = false;
        }
        self.shared.work.notify_all();
        // The caller participates instead of idling.
        IN_POOL.with(|c| c.set(true));
        claim_chunks(&self.shared);
        IN_POOL.with(|c| c.set(false));
        // Wait for straggler workers, then release the job slot.
        let panicked;
        {
            let mut st = self.shared.lock();
            while st.pending > 0 {
                st = self.shared.done.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            st.job = None;
            panicked = st.panicked;
            st.panicked = false;
        }
        // Wake callers queued on the job slot.
        self.shared.done.notify_all();
        if panicked {
            panic!("ParPool chunk body panicked");
        }
    }
}

/// Cross-pool fork/join — the primitive behind concurrent split
/// execution ([`crate::coordinator::shards::SplitPlan`]).
///
/// [`ParPool::run_chunks`] parallelises *within* one pool, but it blocks
/// the calling thread, so a caller looping over N pools (one per socket)
/// runs them one after another — the cross-socket wall-clock win of a
/// row-split plan never materialises. [`PoolGroup::join_all`] dispatches
/// one closure per pool onto its own fan-out thread (task 0 runs on the
/// caller), each pinned to its pool's CPU set so the chunk claiming the
/// fan-out thread participates in stays on the pool's socket, and blocks
/// until every task has completed.
///
/// **Overlap observability.** Every task counts as *in flight* from the
/// moment the group dispatches it until it completes; the high-water mark
/// is exposed through [`PoolGroup::max_in_flight`] the same way
/// [`ParPool::dispatch_count`] / [`ParPool::init_count`] expose pass and
/// build activity. Because the whole batch is dispatched before the join
/// waits, a call with `n` tasks always drives the mark to at least `n` —
/// while a sequential caller running blocks one at a time through the
/// same group can never push it past 1. Tests assert against this
/// counter instead of timing. Note the deliberate division of labour:
/// the counter measures *dispatch* concurrency (deterministic, so CI can
/// gate on it even on one core), while *execution* concurrency — that
/// the runners really proceed simultaneously — is guarded by the
/// rendezvous unit test (`pool_group_tasks_truly_execute_concurrently`),
/// which deadlock-times-out if `join_all` ever serialises its tasks.
///
/// **Panic containment.** A panicking task is caught on its own runner,
/// the join still completes (no deadlock, no abandoned threads), the
/// pools stay usable, and a single `"PoolGroup task panicked"` panic is
/// re-raised to the caller afterwards — mirroring the
/// [`ParPool::run_chunks`] contract.
///
/// # Example
///
/// ```
/// use spmv_at::spmv::pool::{ParPool, PoolGroup};
/// use std::sync::Arc;
///
/// let pools = vec![Arc::new(ParPool::new(1)), Arc::new(ParPool::new(1))];
/// let group = PoolGroup::new();
/// let mut sums = vec![0usize; 2];
/// group.join_all(&pools, &mut sums, |i, s| {
///     pools[i].run_chunks(&[0..50, 50..100], |_c, _r| {});
///     *s = i + 1;
/// });
/// assert_eq!(sums, vec![1, 2]);
/// assert!(group.max_in_flight() >= 2, "both tasks were in flight together");
/// assert_eq!(group.join_count(), 1);
/// ```
#[derive(Default)]
pub struct PoolGroup {
    joins: AtomicU64,
    in_flight: AtomicU64,
    max_in_flight: AtomicU64,
}

impl PoolGroup {
    /// A fresh group with zeroed counters.
    pub const fn new() -> Self {
        Self {
            joins: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            max_in_flight: AtomicU64::new(0),
        }
    }

    /// `join_all` calls so far (monotonic; empty batches do not count).
    pub fn join_count(&self) -> u64 {
        self.joins.load(Ordering::Relaxed)
    }

    /// High-water mark of tasks simultaneously in flight (dispatched and
    /// not yet completed) across this group's lifetime. ≥ the largest
    /// batch ever joined; stays at 1 if blocks were only ever run one at
    /// a time.
    pub fn max_in_flight(&self) -> u64 {
        self.max_in_flight.load(Ordering::SeqCst)
    }

    /// Run `f(i, &mut items[i])` for every task concurrently — task `i`
    /// on its own fan-out thread pinned (best-effort) to `pools[i]`'s CPU
    /// set, task 0 on the calling thread (temporarily joining `pools[0]`'s
    /// set, original mask restored) — and block until all complete.
    /// Distinct pools have independent job slots, so the tasks' inner
    /// `run_chunks` calls proceed without contending on one slot.
    ///
    /// # Panics
    /// Panics if `pools` and `items` differ in length, and re-raises (as
    /// a single panic, after every task has finished) if any task body
    /// panicked; the pools stay usable afterwards.
    pub fn join_all<T: Send>(
        &self,
        pools: &[Arc<ParPool>],
        items: &mut [T],
        f: impl Fn(usize, &mut T) + Sync,
    ) {
        assert_eq!(pools.len(), items.len(), "join_all needs one pool per task");
        let n = items.len();
        if n == 0 {
            return;
        }
        self.joins.fetch_add(1, Ordering::Relaxed);
        // The whole batch is in flight from here: the scope below waits
        // for every task, and no task is queued behind another.
        let was = self.in_flight.fetch_add(n as u64, Ordering::SeqCst);
        self.max_in_flight.fetch_max(was + n as u64, Ordering::SeqCst);
        let panicked = std::sync::atomic::AtomicBool::new(false);
        let run = |i: usize, item: &mut T| {
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, item))).is_ok();
            if !ok {
                panicked.store(true, Ordering::SeqCst);
            }
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
        };
        let mut iter = items.iter_mut().enumerate();
        let (_, first) = iter.next().expect("n >= 1");
        // Task 0 always runs on the caller, temporarily joining its
        // pool's socket (mask restored after) — including the
        // single-task degenerate case, which must keep the same
        // first-touch behaviour as a fan-out.
        let run_first = |first: &mut T| match pools[0].affinity() {
            Some(cpus) => crate::machine::topology::with_affinity(cpus, || run(0, first)),
            None => run(0, first),
        };
        if n == 1 {
            run_first(first);
        } else {
            std::thread::scope(|s| {
                for (i, item) in iter {
                    let cpus = pools[i].affinity().map(<[usize]>::to_vec);
                    let run = &run;
                    s.spawn(move || {
                        if let Some(cpus) = &cpus {
                            crate::machine::topology::pin_current_thread(cpus);
                        }
                        run(i, item);
                    });
                }
                run_first(first);
            });
        }
        if panicked.load(Ordering::SeqCst) {
            panic!("PoolGroup task panicked");
        }
    }
}

impl std::fmt::Debug for PoolGroup {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolGroup")
            .field("joins", &self.join_count())
            .field("max_in_flight", &self.max_in_flight())
            .finish()
    }
}

impl Drop for ParPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ParPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParPool")
            .field("size", &self.size)
            .field("affinity", &self.affinity)
            .finish()
    }
}

/// Claim and execute chunks of the current job until none remain. Shared
/// by workers and the publishing caller.
fn claim_chunks(shared: &PoolShared) {
    loop {
        let (f, ranges, i) = {
            let mut st = shared.lock();
            // Copy the raw pointers out so the `&Job` borrow of the guard
            // ends before `next_chunk` is mutated.
            let (f_ptr, ranges_ptr) = match st.job.as_ref() {
                Some(job) => (job.f, job.ranges),
                None => return,
            };
            // SAFETY: the job owner blocks until pending == 0, so both
            // pointers are live for as long as this chunk executes.
            let ranges = unsafe { &*ranges_ptr };
            if st.next_chunk >= ranges.len() {
                return;
            }
            let i = st.next_chunk;
            st.next_chunk += 1;
            (unsafe { &*f_ptr }, ranges, i)
        };
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(i, ranges[i].clone());
        }))
        .is_ok();
        let mut st = shared.lock();
        if !ok {
            st.panicked = true;
        }
        st.pending -= 1;
        if st.pending == 0 {
            drop(st);
            shared.done.notify_all();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    // Workers always run chunk bodies, so nested pool entry from a body
    // on this thread must serialise.
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        {
            let mut st = shared.lock();
            loop {
                if st.shutdown {
                    return;
                }
                if st.job.is_some() && st.epoch != seen {
                    seen = st.epoch;
                    break;
                }
                st = shared.work.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        }
        claim_chunks(shared);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::partition::split_even;

    #[test]
    fn chunks_cover_iteration_space_once() {
        let pool = ParPool::new(4);
        let n = 10_000usize;
        let mut hits = vec![0u8; n];
        let ranges = split_even(n, 7);
        let p = SendPtr(hits.as_mut_ptr());
        pool.run_chunks(&ranges, |_tid, r| {
            for i in r {
                // Disjoint ranges: each index written by exactly one chunk.
                unsafe { *p.get().add(i) += 1 };
            }
        });
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn pool_is_reusable_across_many_calls() {
        let pool = ParPool::new(3);
        let n = 512usize;
        let ranges = split_even(n, 3);
        let mut out = vec![0.0f64; n];
        for round in 1..=10u32 {
            let p = SendPtr(out.as_mut_ptr());
            pool.run_chunks(&ranges, |_tid, r| {
                for i in r {
                    unsafe { *p.get().add(i) = (round as f64) * (i as f64) };
                }
            });
            assert_eq!(out[17], round as f64 * 17.0, "round {round}");
            assert_eq!(out[n - 1], round as f64 * (n - 1) as f64);
        }
    }

    #[test]
    fn size_one_pool_runs_serially() {
        let pool = ParPool::new(1);
        assert_eq!(pool.size(), 1);
        let mut sum = 0usize;
        let p = SendPtr(&mut sum as *mut usize);
        pool.run_chunks(&split_even(100, 4), |_tid, r| {
            // Serial execution: unsynchronised accumulation is safe.
            for i in r {
                unsafe { *p.get() += i };
            }
        });
        assert_eq!(sum, 99 * 100 / 2);
    }

    #[test]
    fn nested_run_chunks_degrades_to_serial() {
        let pool = ParPool::new(4);
        let n = 64usize;
        let mut out = vec![0usize; n];
        let outer = split_even(n, 4);
        let p = SendPtr(out.as_mut_ptr());
        pool.run_chunks(&outer, |_tid, r| {
            // Nested entry must not deadlock on the single job slot.
            let inner = split_even(r.end - r.start, 2);
            let base = r.start;
            pool.run_chunks(&inner, |_t2, r2| {
                for i in r2 {
                    unsafe { *p.get().add(base + i) = base + i };
                }
            });
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn concurrent_callers_share_one_pool() {
        let pool = Arc::new(ParPool::new(4));
        let mut handles = Vec::new();
        for t in 0..4usize {
            let pool = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let n = 2048usize;
                let ranges = split_even(n, 4);
                let mut out = vec![0.0f64; n];
                for _ in 0..20 {
                    let p = SendPtr(out.as_mut_ptr());
                    pool.run_chunks(&ranges, |_tid, r| {
                        for i in r {
                            unsafe { *p.get().add(i) = (t * n + i) as f64 };
                        }
                    });
                }
                (0..n).all(|i| out[i] == (t * n + i) as f64)
            }));
        }
        for h in handles {
            assert!(h.join().unwrap(), "a caller observed torn results");
        }
    }

    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        let pool = ParPool::new(2);
        let ranges = split_even(8, 2);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_chunks(&ranges, |tid, _r| {
                if tid == 1 {
                    panic!("injected");
                }
            });
        }));
        assert!(err.is_err(), "panic must propagate to the caller");
        // The pool must still be usable.
        let mut sum = vec![0usize; 2];
        let p = SendPtr(sum.as_mut_ptr());
        pool.run_chunks(&ranges, |tid, r| unsafe {
            *p.get().add(tid) = r.end - r.start;
        });
        assert_eq!(sum[0] + sum[1], 8);
    }

    #[test]
    fn dispatch_count_is_monotonic_per_job() {
        let pool = ParPool::new(2);
        let before = pool.dispatch_count();
        let ranges = split_even(64, 2);
        pool.run_chunks(&ranges, |_tid, _r| {});
        pool.run_chunks(&ranges, |_tid, _r| {});
        assert_eq!(pool.dispatch_count() - before, 2);
        pool.run_chunks(&[], |_tid, _r| {});
        assert_eq!(pool.dispatch_count() - before, 2, "empty jobs are not dispatches");
    }

    #[test]
    fn configured_threads_is_positive() {
        assert!(configured_threads() >= 1);
        assert!(global().size() >= 1);
    }

    #[test]
    fn run_init_counts_separately_from_plain_dispatches() {
        let pool = ParPool::new(2);
        let ranges = split_even(64, 2);
        let (d0, i0) = (pool.dispatch_count(), pool.init_count());
        pool.run_chunks(&ranges, |_tid, _r| {});
        assert_eq!(pool.init_count() - i0, 0, "plain chunks are not inits");
        pool.run_init(&ranges, |_tid, _r| {});
        assert_eq!(pool.init_count() - i0, 1);
        assert_eq!(pool.dispatch_count() - d0, 2, "an init fan-out is also a dispatch");
        // Degenerate init fan-outs still count (a CRS plan with nothing to
        // materialise must stay observable).
        pool.run_init(&[], |_tid, _r| {});
        assert_eq!(pool.init_count() - i0, 2);
    }

    #[test]
    fn pool_group_joins_tasks_and_counts_overlap() {
        let pools: Vec<Arc<ParPool>> =
            (0..3).map(|_| Arc::new(ParPool::new(2))).collect();
        let group = PoolGroup::new();
        assert_eq!((group.join_count(), group.max_in_flight()), (0, 0));
        let mut out = vec![0usize; 3];
        group.join_all(&pools, &mut out, |i, o| {
            // 2 disjoint chunks, each summed into its own slot.
            let mut slots = [0usize; 2];
            let p = SendPtr(slots.as_mut_ptr());
            pools[i].run_chunks(&split_even(100, 2), |tid, r| {
                let s: usize = r.sum();
                unsafe { *p.get().add(tid) = s };
            });
            *o = slots[0] + slots[1] + i;
        });
        assert_eq!(out, vec![4950, 4951, 4952]);
        assert_eq!(group.join_count(), 1);
        assert_eq!(group.max_in_flight(), 3, "all 3 tasks dispatched before the join");
        // Empty batches are a no-op, not a join.
        group.join_all(&pools[..0], &mut out[..0], |_i, _o| {});
        assert_eq!(group.join_count(), 1);
        // A single-task batch runs on the caller and never raises the mark.
        group.join_all(&pools[..1], &mut out[..1], |_i, o| *o = 7);
        assert_eq!(out[0], 7);
        assert_eq!(group.max_in_flight(), 3);
    }

    #[test]
    fn pool_group_tasks_truly_execute_concurrently() {
        // Rendezvous: each task spins until the other has started. If the
        // group ran tasks sequentially, the first would spin to timeout
        // and the assert below would fail.
        let pools: Vec<Arc<ParPool>> =
            (0..2).map(|_| Arc::new(ParPool::new(1))).collect();
        let group = PoolGroup::new();
        let started = AtomicU64::new(0);
        let mut met = vec![false; 2];
        group.join_all(&pools, &mut met, |_i, m| {
            started.fetch_add(1, Ordering::SeqCst);
            let t0 = std::time::Instant::now();
            while started.load(Ordering::SeqCst) < 2 {
                if t0.elapsed().as_secs() > 10 {
                    return; // leaves *m == false -> assert fails below
                }
                std::thread::yield_now();
            }
            *m = true;
        });
        assert_eq!(met, vec![true, true], "both tasks must be in flight at once");
        assert!(group.max_in_flight() >= 2);
    }

    #[test]
    fn pool_group_panic_joins_without_poisoning() {
        let pools: Vec<Arc<ParPool>> =
            (0..3).map(|_| Arc::new(ParPool::new(2))).collect();
        let group = PoolGroup::new();
        let mut out = vec![0usize; 3];
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            group.join_all(&pools, &mut out, |i, o| {
                if i == 1 {
                    panic!("injected");
                }
                *o = i + 1;
            });
        }));
        assert!(err.is_err(), "the task panic must re-raise on the caller");
        assert_eq!(out[0], 1, "non-panicking tasks still completed");
        assert_eq!(out[2], 3);
        // The group and every pool stay usable for the next call.
        group.join_all(&pools, &mut out, |i, o| {
            let mut slots = [0usize; 2];
            let p = SendPtr(slots.as_mut_ptr());
            pools[i].run_chunks(&split_even(64, 2), |tid, r| {
                let n = r.len();
                unsafe { *p.get().add(tid) = n };
            });
            *o = slots[0] + slots[1];
        });
        assert_eq!(out, vec![64, 64, 64]);
        assert_eq!(group.join_count(), 2);
    }

    #[test]
    fn pinned_pool_executes_correctly_whatever_the_host() {
        // Pinning is best-effort: whether or not the mask applies on this
        // machine, the pool must stay a correct executor.
        let pool = ParPool::new_pinned(3, Some(vec![0, 1]));
        assert_eq!(pool.affinity(), Some(&[0usize, 1][..]));
        let n = 1024usize;
        let ranges = split_even(n, 3);
        let mut out = vec![0.0f64; n];
        let p = SendPtr(out.as_mut_ptr());
        pool.run_init(&ranges, |_tid, r| {
            for i in r {
                unsafe { *p.get().add(i) = i as f64 };
            }
        });
        assert!((0..n).all(|i| out[i] == i as f64));
        // Empty CPU sets degrade to an unpinned pool.
        assert!(ParPool::new_pinned(2, Some(Vec::new())).affinity().is_none());
        assert!(ParPool::new_pinned(2, None).affinity().is_none());
    }
}
