//! Work partitioning — the paper's `ISTART(K)`/`IEND(K)` arrays.
//!
//! The OpenMP codes in Figs. 1–4 pre-split their iteration space into one
//! contiguous chunk per thread. Three policies are provided, named by
//! [`PartitionStrategy`] and picked by the planner (or forced with
//! `SPMV_AT_PARTITION`):
//!
//! * [`split_even`] — equal iteration counts (what a static OpenMP schedule
//!   over the entry stream gives);
//! * [`split_by_nnz`] — row ranges balanced by non-zero count, which is the
//!   right policy for row-wise kernels on skewed matrices (memplus-like
//!   dense rows would otherwise serialise one thread);
//! * [`merge_path_split`] — 2-D merge coordinates over (row boundaries,
//!   non-zeros), so a chunk may start and end *mid-row*. No chunk ever owns
//!   more than ⌈(n + nnz)/k⌉ merge items, which bounds its non-zero count
//!   even when one giant row holds most of the matrix — the regime where
//!   row-aligned splitting degenerates to one serialised worker (Bergmans
//!   et al., arxiv 2502.19284; Merrill & Garland's merge-based SpMV).

use std::ops::Range;

/// How a kernel's iteration space is split across pool workers.
///
/// `Even` and `ByNnz` produce row-aligned ranges; `MergePath` produces
/// [`MergePartition`] coordinates that may cut rows (honoured in full by
/// the `CRS-Merge` kernel; row-aligned kernels under `MergePath` use the
/// merge boundaries rounded to row starts).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Equal unit counts per chunk.
    Even,
    /// Row chunks balanced by non-zero count (row-aligned).
    ByNnz,
    /// 2-D merge coordinates over (row_ptr, nnz); chunks may split rows.
    MergePath,
}

impl PartitionStrategy {
    /// Every strategy, in planner preference order.
    pub const ALL: [PartitionStrategy; 3] =
        [PartitionStrategy::ByNnz, PartitionStrategy::MergePath, PartitionStrategy::Even];

    /// Canonical name (accepted back by [`PartitionStrategy::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            PartitionStrategy::Even => "even",
            PartitionStrategy::ByNnz => "nnz",
            PartitionStrategy::MergePath => "merge",
        }
    }

    /// Parse a strategy name (case-insensitive; `None` for unknown).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "even" => Some(PartitionStrategy::Even),
            "nnz" | "bynnz" | "by-nnz" => Some(PartitionStrategy::ByNnz),
            "merge" | "mergepath" | "merge-path" => Some(PartitionStrategy::MergePath),
            _ => None,
        }
    }
}

impl std::fmt::Display for PartitionStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Truth for the partition-strategy override: the `SPMV_AT_PARTITION`
/// environment variable. Unset, empty, or `auto` means "planner's pick"
/// ([`pick_strategy`]); unknown values also fall through to the planner
/// (same quiet-fallback contract as `SPMV_AT_TOPOLOGY`).
pub fn configured_partition() -> Option<PartitionStrategy> {
    match std::env::var("SPMV_AT_PARTITION") {
        Ok(v) if !v.trim().is_empty() && v.trim().to_ascii_lowercase() != "auto" => {
            PartitionStrategy::parse(&v)
        }
        _ => None,
    }
}

/// Skew ratio `max_row / mean_row` at which the planner prefers
/// merge-path partitioning: one row this far above the mean serialises a
/// worker under any row-aligned split of `k ≤ skew` chunks.
pub const MERGE_SKEW_THRESHOLD: f64 = 8.0;

/// The planner's strategy pick for a CSR row partition: the
/// `SPMV_AT_PARTITION` override when set, otherwise merge-path iff the
/// row-length skew `max_row / mean_row` reaches
/// [`MERGE_SKEW_THRESHOLD`], else nnz-balanced row chunks.
pub fn pick_strategy(row_ptr: &[usize]) -> PartitionStrategy {
    if let Some(s) = configured_partition() {
        return s;
    }
    pick_strategy_auto(row_ptr)
}

/// The environment-independent half of [`pick_strategy`]: the pure skew
/// heuristic (callers that already resolved an override use this), read
/// off the same [`crate::matrixgen::rowlen::LenStats`] the generator and
/// the offline model already compute.
pub fn pick_strategy_auto(row_ptr: &[usize]) -> PartitionStrategy {
    let s = crate::matrixgen::rowlen::stats_of_row_ptr(row_ptr);
    if s.sum == 0 {
        return PartitionStrategy::ByNnz;
    }
    if s.max as f64 >= MERGE_SKEW_THRESHOLD * s.mean {
        PartitionStrategy::MergePath
    } else {
        PartitionStrategy::ByNnz
    }
}

/// Split `0..n` into at most `k` contiguous ranges of near-equal length.
/// Returns fewer than `k` ranges when `n < k`; never returns empty ranges
/// (except that `n == 0` yields no ranges).
pub fn split_even(n: usize, k: usize) -> Vec<Range<usize>> {
    let k = k.max(1);
    if n == 0 {
        return Vec::new();
    }
    let k = k.min(n);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split rows `0..row_ptr.len()-1` into at most `k` contiguous ranges with
/// near-equal non-zero counts, using the CSR row pointers as the prefix-sum
/// of work. Greedy boundary placement at the ideal quantiles.
///
/// Boundary canonicalisation: when the prefix array has a run of equal
/// values (empty rows), the boundary is the **last** index of the run — a
/// chunk end never precedes a run of empty rows, so the empty rows ride
/// with the chunk that did the work before them. `binary_search` alone
/// leaves the position within a duplicate run unspecified, which made the
/// partition (and everything cached from it) depend on the search's
/// internal probe order.
pub fn split_by_nnz(row_ptr: &[usize], k: usize) -> Vec<Range<usize>> {
    let n = row_ptr.len().saturating_sub(1);
    let k = k.max(1);
    if n == 0 {
        return Vec::new();
    }
    let nnz = row_ptr[n];
    if nnz == 0 {
        return split_even(n, k);
    }
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for i in 0..k {
        if start >= n {
            break;
        }
        // Ideal cumulative work at the end of chunk i.
        let target = ((i + 1) as u128 * nnz as u128 / k as u128) as usize;
        // First row boundary whose prefix ≥ target, but always advance.
        let mut end = match row_ptr[start + 1..=n].binary_search(&target) {
            Ok(p) => start + 1 + p,
            Err(p) => start + 1 + p,
        };
        end = end.clamp(start + 1, n);
        // Canonicalise to the last index of an equal-prefix run: the
        // trailing empty rows belong to this chunk, not the next.
        while end < n && row_ptr[end + 1] == row_ptr[end] {
            end += 1;
        }
        if i == k - 1 {
            end = n;
        }
        out.push(start..end);
        start = end;
    }
    if let Some(last) = out.last_mut() {
        if last.end < n {
            last.end = n;
        }
    }
    out
}

/// Imbalance factor of a partition under a per-row cost prefix: max chunk
/// work / ideal work. 1.0 is perfect.
pub fn imbalance(row_ptr: &[usize], ranges: &[Range<usize>]) -> f64 {
    let n = row_ptr.len().saturating_sub(1);
    if ranges.is_empty() || row_ptr[n] == 0 {
        return 1.0;
    }
    let ideal = row_ptr[n] as f64 / ranges.len() as f64;
    ranges
        .iter()
        .map(|r| (row_ptr[r.end] - row_ptr[r.start]) as f64 / ideal)
        .fold(1.0, f64::max)
}

/// A merge-path partition: `k+1` (row, nnz) coordinates on the 2-D merge
/// of the row-boundary list `row_ptr[1..=n]` with the element list
/// `0..nnz`. Chunk `t` spans `bounds[t] .. bounds[t+1]`; it owns the row
/// boundaries `rows(t)` (writing those rows' results, empty rows
/// included) and the elements `elems(t)` — which may begin after its
/// first row's start and end before its last row's end, the partial
/// segments the `CRS-Merge` kernel routes through carry slots.
///
/// Invariant per coordinate: `row_ptr[r] ≤ v ≤ row_ptr[r+1]` (a valid
/// state of the merge), with `bounds[0] = (0, 0)` and
/// `bounds[k] = (n, nnz)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergePartition {
    /// The `k+1` (row, element) chunk boundaries.
    pub bounds: Vec<(usize, usize)>,
}

impl MergePartition {
    /// Number of chunks.
    pub fn n_chunks(&self) -> usize {
        self.bounds.len().saturating_sub(1)
    }

    /// Row boundaries chunk `t` consumes (it writes these rows).
    pub fn rows(&self, t: usize) -> Range<usize> {
        self.bounds[t].0..self.bounds[t + 1].0
    }

    /// Elements chunk `t` consumes.
    pub fn elems(&self, t: usize) -> Range<usize> {
        self.bounds[t].1..self.bounds[t + 1].1
    }

    /// Non-zeros chunk `t` owns (its share of the multiply work).
    pub fn nnz_weight(&self, t: usize) -> usize {
        self.bounds[t + 1].1 - self.bounds[t].1
    }

    /// The heaviest chunk's non-zero count.
    pub fn max_nnz_weight(&self) -> usize {
        (0..self.n_chunks()).map(|t| self.nnz_weight(t)).max().unwrap_or(0)
    }

    /// Heap bytes held (the cached coordinates).
    pub fn memory_bytes(&self) -> usize {
        self.bounds.len() * std::mem::size_of::<(usize, usize)>()
    }
}

/// Compute the merge-path partition of a CSR row structure into at most
/// `k` chunks. Diagonal `d_t = ⌊t·(n+nnz)/k⌋` is resolved to the unique
/// valid merge state `(r, v)` with `r + v = d_t` by binary search on the
/// row boundaries; consecutive diagonals differ, so no chunk is empty of
/// merge items (`k` is clamped to `n + nnz`). `n = 0` yields zero
/// chunks.
pub fn merge_path_split(row_ptr: &[usize], k: usize) -> MergePartition {
    let n = row_ptr.len().saturating_sub(1);
    if n == 0 {
        return MergePartition { bounds: vec![(0, 0)] };
    }
    let nnz = row_ptr[n];
    let total = n + nnz;
    let k = k.max(1).min(total);
    let mut bounds = Vec::with_capacity(k + 1);
    bounds.push((0usize, 0usize));
    for t in 1..k {
        let d = (t as u128 * total as u128 / k as u128) as usize;
        bounds.push(merge_search(row_ptr, n, nnz, d));
    }
    bounds.push((n, nnz));
    MergePartition { bounds }
}

/// Find the merge state `(r, v)` with `r + v = d` on the merge of the
/// row-boundary list `A[i] = row_ptr[i+1]` with the element list
/// `B[j] = j`: the smallest `r` such that `A[r] > B[d-1-r]`, i.e. the
/// boundary count consumed when boundary values ≤ the facing element
/// index go first (empty-row boundaries drain eagerly).
fn merge_search(row_ptr: &[usize], n: usize, nnz: usize, d: usize) -> (usize, usize) {
    let mut lo = d.saturating_sub(nnz);
    let mut hi = d.min(n);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if row_ptr[mid + 1] <= d - mid - 1 {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    (lo, d - lo)
}

/// Row-aligned projection of a merge-path partition: the chunk
/// boundaries' row components, deduplicated into non-empty row ranges.
/// This is what row-aligned kernels run when the picked strategy is
/// [`PartitionStrategy::MergePath`] — balanced by rows *plus* nnz, but
/// never cutting a row.
pub fn merge_row_aligned(row_ptr: &[usize], k: usize) -> Vec<Range<usize>> {
    let mp = merge_path_split(row_ptr, k);
    let n = row_ptr.len().saturating_sub(1);
    let mut out = Vec::new();
    let mut start = 0usize;
    for t in 0..mp.n_chunks() {
        let end = mp.bounds[t + 1].0;
        if end > start {
            out.push(start..end);
            start = end;
        }
    }
    if start < n {
        out.push(start..n);
    }
    out
}

/// A computed work partition: the strategy that produced it, the chunk
/// ranges the pool claims, and — for merge-path partitions — the 2-D
/// merge coordinates. For row-aligned partitions `ranges` are row
/// ranges and `merge` is `None`; for a [`MergePartition`] the ranges are
/// unit chunk-index ranges (`t..t+1`) so the pool's dynamic claiming
/// works unchanged, and the coordinates live in `merge`.
#[derive(Clone, Debug, Default)]
pub struct Partition {
    /// Strategy that produced this partition (reported in stats).
    pub strategy: Option<PartitionStrategy>,
    /// Chunk ranges for the pool (rows, entries, bands, or chunk ids —
    /// per the kernel's unit).
    pub ranges: Vec<Range<usize>>,
    /// Merge coordinates when `strategy` is `MergePath` and the kernel
    /// honours mid-row chunks.
    pub merge: Option<MergePartition>,
}

impl Partition {
    /// An unpartitioned (sequential) plan.
    pub fn none() -> Self {
        Self::default()
    }

    /// A row-/unit-aligned partition.
    pub fn aligned(strategy: PartitionStrategy, ranges: Vec<Range<usize>>) -> Self {
        Partition { strategy: Some(strategy), ranges, merge: None }
    }

    /// A merge-path partition: unit chunk-id ranges plus the coordinates.
    pub fn merged(mp: MergePartition) -> Self {
        let ranges = (0..mp.n_chunks()).map(|t| t..t + 1).collect();
        Partition { strategy: Some(PartitionStrategy::MergePath), ranges, merge: Some(mp) }
    }

    /// Number of chunks the pool will claim.
    pub fn n_chunks(&self) -> usize {
        self.ranges.len()
    }

    /// Stats label: the strategy name, `-` when unpartitioned.
    pub fn strategy_name(&self) -> &'static str {
        self.strategy.map_or("-", PartitionStrategy::name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_covers(ranges: &[Range<usize>], n: usize) {
        let mut pos = 0;
        for r in ranges {
            assert_eq!(r.start, pos, "gap/overlap at {pos}");
            assert!(r.end > r.start, "empty range {r:?}");
            pos = r.end;
        }
        assert_eq!(pos, n, "does not cover 0..{n}");
    }

    /// Structural invariants of a merge partition: monotone valid merge
    /// states from (0,0) to (n,nnz), no chunk empty of merge items.
    fn assert_valid_merge(row_ptr: &[usize], mp: &MergePartition) {
        let n = row_ptr.len().saturating_sub(1);
        let nnz = if n == 0 { 0 } else { row_ptr[n] };
        assert_eq!(mp.bounds.first(), Some(&(0, 0)));
        assert_eq!(mp.bounds.last(), Some(&(n, nnz)));
        for w in mp.bounds.windows(2) {
            let ((r0, v0), (r1, v1)) = (w[0], w[1]);
            assert!(r1 >= r0 && v1 >= v0, "non-monotone: {w:?}");
            assert!(r1 + v1 > r0 + v0, "empty chunk: {w:?}");
        }
        for &(r, v) in &mp.bounds {
            assert!(r <= n && v <= nnz);
            if r < n {
                assert!(row_ptr[r] <= v && v <= row_ptr[r + 1], "invalid state ({r},{v})");
            }
        }
    }

    #[test]
    fn split_even_basic() {
        assert_covers(&split_even(10, 3), 10);
        assert_eq!(split_even(10, 3), vec![0..4, 4..7, 7..10]);
        assert_eq!(split_even(2, 8).len(), 2);
        assert!(split_even(0, 4).is_empty());
        assert_eq!(split_even(5, 1), vec![0..5]);
    }

    #[test]
    fn split_by_nnz_balances_skew() {
        // Row 0 has 97 nnz, rows 1..=3 have 1 each.
        let row_ptr = vec![0, 97, 98, 99, 100];
        let r = split_by_nnz(&row_ptr, 4);
        assert_covers(&r, 4);
        // The heavy row must sit alone in its chunk.
        assert_eq!(r[0], 0..1);
    }

    #[test]
    fn split_by_nnz_uniform_matches_even() {
        let row_ptr: Vec<usize> = (0..=100).map(|i| i * 5).collect();
        let r = split_by_nnz(&row_ptr, 4);
        assert_covers(&r, 100);
        let imb = imbalance(&row_ptr, &r);
        assert!(imb < 1.05, "imbalance {imb}");
    }

    #[test]
    fn split_by_nnz_more_threads_than_rows() {
        let row_ptr = vec![0, 3, 6];
        let r = split_by_nnz(&row_ptr, 16);
        assert_covers(&r, 2);
    }

    #[test]
    fn split_by_nnz_empty_matrix() {
        let row_ptr = vec![0, 0, 0];
        let r = split_by_nnz(&row_ptr, 2);
        assert_covers(&r, 2);
    }

    #[test]
    fn split_by_nnz_boundary_is_last_of_duplicate_run() {
        // Rows: 4 nnz, then a run of 3 empty rows, then 4 nnz. The ideal
        // first-of-two boundary (target 4) hits the duplicate run
        // [4,4,4,4]; the canonical boundary is its LAST index, so the
        // empty rows ride with chunk 0.
        let row_ptr = vec![0, 4, 4, 4, 4, 8];
        let r = split_by_nnz(&row_ptr, 2);
        assert_covers(&r, 5);
        assert_eq!(r, vec![0..4, 4..5], "chunk end must not precede the empty-row run");

        // Same with the work before the run spread over two rows and a
        // trailing empty run, at k=3.
        let row_ptr = vec![0, 2, 4, 4, 4, 6, 6, 6];
        let r = split_by_nnz(&row_ptr, 3);
        assert_covers(&r, 7);
        for w in r.windows(2) {
            let boundary = w[0].end;
            assert!(
                row_ptr[boundary + 1] > row_ptr[boundary],
                "boundary {boundary} precedes an empty-row run: {r:?}"
            );
        }
    }

    #[test]
    fn imbalance_of_even_partition() {
        let row_ptr: Vec<usize> = (0..=8).map(|i| i * 2).collect();
        let r = split_even(8, 4);
        assert!((imbalance(&row_ptr, &r) - 1.0).abs() < 1e-12);
    }

    // ---- merge-path coordinate search ----

    #[test]
    fn merge_spans_sum_to_totals() {
        let cases: Vec<Vec<usize>> = vec![
            vec![0, 97, 98, 99, 100],            // one giant row
            vec![0, 0, 0, 5],                    // leading empty rows
            vec![0, 5, 5, 5],                    // trailing empty rows
            vec![0, 0, 0, 0],                    // all empty
            (0..=64).map(|i| i * 3).collect(),   // uniform
            vec![0, 1, 1, 2, 50, 50, 51, 60],    // mixed skew + empties
        ];
        for row_ptr in &cases {
            let n = row_ptr.len() - 1;
            for k in [1usize, 2, 3, 4, 7, 16, 1000] {
                let mp = merge_path_split(row_ptr, k);
                assert_valid_merge(row_ptr, &mp);
                let rows: usize = (0..mp.n_chunks()).map(|t| mp.rows(t).len()).sum();
                let elems: usize = (0..mp.n_chunks()).map(|t| mp.elems(t).len()).sum();
                assert_eq!(rows, n, "rows, k={k}, {row_ptr:?}");
                assert_eq!(elems, row_ptr[n], "elems, k={k}, {row_ptr:?}");
            }
        }
    }

    #[test]
    fn merge_balances_single_giant_row() {
        // split_by_nnz degenerates to one chunk here; merge-path gives
        // nnz weights that differ by ≤ 1.
        let row_ptr = vec![0, 100];
        assert_eq!(split_by_nnz(&row_ptr, 4).len(), 1);
        let mp = merge_path_split(&row_ptr, 4);
        assert_eq!(mp.n_chunks(), 4);
        let weights: Vec<usize> = (0..4).map(|t| mp.nnz_weight(t)).collect();
        let (mn, mx) = (weights.iter().min().unwrap(), weights.iter().max().unwrap());
        assert!(mx - mn <= 1, "weights {weights:?}");
        assert_eq!(weights.iter().sum::<usize>(), 100);
    }

    #[test]
    fn merge_balances_giant_row_among_small_rows() {
        // 50 one-nnz rows around one 150-nnz row: every chunk's weight
        // stays within ⌈(n+nnz)/k⌉ even though one row is 75% of nnz.
        let mut row_ptr = vec![0usize];
        for i in 0..51 {
            let len = if i == 25 { 150 } else { 1 };
            row_ptr.push(row_ptr.last().unwrap() + len);
        }
        let (n, nnz) = (51, 200);
        for k in [2usize, 4, 7] {
            let mp = merge_path_split(&row_ptr, k);
            assert_valid_merge(&row_ptr, &mp);
            let cap = (n + nnz + k - 1) / k;
            assert!(
                mp.max_nnz_weight() <= cap,
                "k={k}: max weight {} > cap {cap}",
                mp.max_nnz_weight()
            );
        }
    }

    #[test]
    fn merge_edge_cases() {
        // k > n + nnz clamps: no empty chunks.
        let row_ptr = vec![0, 1, 2];
        let mp = merge_path_split(&row_ptr, 100);
        assert_valid_merge(&row_ptr, &mp);
        assert!(mp.n_chunks() <= 4);
        // n_rows = 0.
        let mp = merge_path_split(&[0], 4);
        assert_eq!(mp.n_chunks(), 0);
        // k = 1 is the trivial whole-matrix chunk.
        let row_ptr = vec![0, 3, 6];
        let mp = merge_path_split(&row_ptr, 1);
        assert_eq!(mp.bounds, vec![(0, 0), (2, 6)]);
    }

    #[test]
    fn merge_row_aligned_covers_rows() {
        let row_ptr = vec![0, 1, 1, 2, 50, 50, 51, 60];
        for k in [1usize, 2, 3, 8] {
            let r = merge_row_aligned(&row_ptr, k);
            assert_covers(&r, 7);
        }
        assert!(merge_row_aligned(&[0], 4).is_empty());
    }

    // ---- strategy naming / picking ----

    #[test]
    fn strategy_names_roundtrip() {
        for s in PartitionStrategy::ALL {
            assert_eq!(PartitionStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(PartitionStrategy::parse("MERGE-PATH"), Some(PartitionStrategy::MergePath));
        assert_eq!(PartitionStrategy::parse("by-nnz"), Some(PartitionStrategy::ByNnz));
        assert_eq!(PartitionStrategy::parse("bogus"), None);
        assert_eq!(PartitionStrategy::parse("auto"), None);
    }

    #[test]
    fn skew_heuristic_picks_merge_only_on_skew() {
        // Uniform rows: ByNnz.
        let uniform: Vec<usize> = (0..=50).map(|i| i * 4).collect();
        assert_eq!(pick_strategy_auto(&uniform), PartitionStrategy::ByNnz);
        // One row at 97/100 nnz over 4 rows: max/mean = 97/25 < 8 → still
        // ByNnz at tiny n…
        assert_eq!(pick_strategy_auto(&[0, 97, 98, 99, 100]), PartitionStrategy::ByNnz);
        // …but a memplus-style giant row across many short rows crosses
        // the threshold.
        let mut skewed = vec![0usize];
        for i in 0..100 {
            let len = if i == 50 { 200 } else { 2 };
            skewed.push(skewed.last().unwrap() + len);
        }
        assert_eq!(pick_strategy_auto(&skewed), PartitionStrategy::MergePath);
        // Degenerate inputs default to ByNnz.
        assert_eq!(pick_strategy_auto(&[0]), PartitionStrategy::ByNnz);
        assert_eq!(pick_strategy_auto(&[0, 0, 0]), PartitionStrategy::ByNnz);
    }

    #[test]
    fn env_override_defaults_off() {
        if std::env::var("SPMV_AT_PARTITION").is_err() {
            assert_eq!(configured_partition(), None);
        }
    }

    #[test]
    fn partition_struct_shapes() {
        let p = Partition::none();
        assert_eq!(p.n_chunks(), 0);
        assert_eq!(p.strategy_name(), "-");
        let p = Partition::aligned(PartitionStrategy::ByNnz, vec![0..2, 2..4]);
        assert_eq!(p.n_chunks(), 2);
        assert_eq!(p.strategy_name(), "nnz");
        assert!(p.merge.is_none());
        let p = Partition::merged(merge_path_split(&[0, 100], 4));
        assert_eq!(p.n_chunks(), 4);
        assert_eq!(p.ranges, vec![0..1, 1..2, 2..3, 3..4]);
        assert_eq!(p.strategy_name(), "merge");
    }
}
