//! Work partitioning — the paper's `ISTART(K)`/`IEND(K)` arrays.
//!
//! The OpenMP codes in Figs. 1–4 pre-split their iteration space into one
//! contiguous chunk per thread. Two policies are provided:
//!
//! * [`split_even`] — equal iteration counts (what a static OpenMP schedule
//!   over the entry stream gives);
//! * [`split_by_nnz`] — row ranges balanced by non-zero count, which is the
//!   right policy for row-wise kernels on skewed matrices (memplus-like
//!   dense rows would otherwise serialise one thread).

use std::ops::Range;

/// Split `0..n` into at most `k` contiguous ranges of near-equal length.
/// Returns fewer than `k` ranges when `n < k`; never returns empty ranges
/// (except that `n == 0` yields no ranges).
pub fn split_even(n: usize, k: usize) -> Vec<Range<usize>> {
    let k = k.max(1);
    if n == 0 {
        return Vec::new();
    }
    let k = k.min(n);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split rows `0..row_ptr.len()-1` into at most `k` contiguous ranges with
/// near-equal non-zero counts, using the CSR row pointers as the prefix-sum
/// of work. Greedy boundary placement at the ideal quantiles.
pub fn split_by_nnz(row_ptr: &[usize], k: usize) -> Vec<Range<usize>> {
    let n = row_ptr.len().saturating_sub(1);
    let k = k.max(1);
    if n == 0 {
        return Vec::new();
    }
    let nnz = row_ptr[n];
    if nnz == 0 {
        return split_even(n, k);
    }
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for i in 0..k {
        if start >= n {
            break;
        }
        // Ideal cumulative work at the end of chunk i.
        let target = ((i + 1) as u128 * nnz as u128 / k as u128) as usize;
        // First row boundary whose prefix ≥ target, but always advance.
        let mut end = match row_ptr[start + 1..=n].binary_search(&target) {
            Ok(p) => start + 1 + p,
            Err(p) => start + 1 + p,
        };
        end = end.clamp(start + 1, n);
        if i == k - 1 {
            end = n;
        }
        out.push(start..end);
        start = end;
    }
    if let Some(last) = out.last_mut() {
        if last.end < n {
            last.end = n;
        }
    }
    out
}

/// Imbalance factor of a partition under a per-row cost prefix: max chunk
/// work / ideal work. 1.0 is perfect.
pub fn imbalance(row_ptr: &[usize], ranges: &[Range<usize>]) -> f64 {
    let n = row_ptr.len().saturating_sub(1);
    if ranges.is_empty() || row_ptr[n] == 0 {
        return 1.0;
    }
    let ideal = row_ptr[n] as f64 / ranges.len() as f64;
    ranges
        .iter()
        .map(|r| (row_ptr[r.end] - row_ptr[r.start]) as f64 / ideal)
        .fold(1.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_covers(ranges: &[Range<usize>], n: usize) {
        let mut pos = 0;
        for r in ranges {
            assert_eq!(r.start, pos, "gap/overlap at {pos}");
            assert!(r.end > r.start, "empty range {r:?}");
            pos = r.end;
        }
        assert_eq!(pos, n, "does not cover 0..{n}");
    }

    #[test]
    fn split_even_basic() {
        assert_covers(&split_even(10, 3), 10);
        assert_eq!(split_even(10, 3), vec![0..4, 4..7, 7..10]);
        assert_eq!(split_even(2, 8).len(), 2);
        assert!(split_even(0, 4).is_empty());
        assert_eq!(split_even(5, 1), vec![0..5]);
    }

    #[test]
    fn split_by_nnz_balances_skew() {
        // Row 0 has 97 nnz, rows 1..=3 have 1 each.
        let row_ptr = vec![0, 97, 98, 99, 100];
        let r = split_by_nnz(&row_ptr, 4);
        assert_covers(&r, 4);
        // The heavy row must sit alone in its chunk.
        assert_eq!(r[0], 0..1);
    }

    #[test]
    fn split_by_nnz_uniform_matches_even() {
        let row_ptr: Vec<usize> = (0..=100).map(|i| i * 5).collect();
        let r = split_by_nnz(&row_ptr, 4);
        assert_covers(&r, 100);
        let imb = imbalance(&row_ptr, &r);
        assert!(imb < 1.05, "imbalance {imb}");
    }

    #[test]
    fn split_by_nnz_more_threads_than_rows() {
        let row_ptr = vec![0, 3, 6];
        let r = split_by_nnz(&row_ptr, 16);
        assert_covers(&r, 2);
    }

    #[test]
    fn split_by_nnz_empty_matrix() {
        let row_ptr = vec![0, 0, 0];
        let r = split_by_nnz(&row_ptr, 2);
        assert_covers(&r, 2);
    }

    #[test]
    fn imbalance_of_even_partition() {
        let row_ptr: Vec<usize> = (0..=8).map(|i| i * 2).collect();
        let r = split_even(8, 4);
        assert!((imbalance(&row_ptr, &r) - 1.0).abs() < 1e-12);
    }
}
