//! Reusable SpMV execution plans.
//!
//! The paper's run-time auto-tuning amortises one transformation over many
//! SpMV calls. A [`SpmvPlan`] widens that idea to *everything* the hot
//! path would otherwise recompute per call: it owns the chosen
//! representation ([`AnyMatrix`]), the work partition for the chosen
//! kernel (computed once via [`kernels::partition_for`]), the reusable
//! [`Workspace`], and a handle to the persistent [`ParPool`] it executes
//! on. After construction, [`SpmvPlan::execute`] performs no allocation,
//! no partitioning and no thread spawning.
//!
//! [`Planner`] is the factory: it carries the installed tuning table, the
//! memory policy and the pool, and turns a CSR matrix into a plan either
//! through the §2.2 online AT decision ([`Planner::plan`]) or for an
//! explicitly requested implementation ([`Planner::plan_for`]). The
//! `Durmv` handle, the coordinator registry, the solvers and the CLI all
//! build and cache plans here instead of hand-rolling the
//! decide→transform→kernel→workspace pipeline.
//!
//! [`SpmvPlan::execute_many`] is a true blocked SpMM: the batch of
//! right-hand sides is tiled into column blocks (`SPMV_AT_BATCH_TILE`,
//! defaulting to a width whose `x`/`y` columns — plus, for the
//! YY-reduction kernels, their per-chunk private copies — fit in the
//! last-level cache) and each tile streams the matrix **once** through the
//! multi-RHS kernels — ⌈k/tile⌉ matrix passes for a batch of `k` instead
//! of `k`, observable through [`SpmvPlan::matrix_passes`] and the pool's
//! dispatch counters. Plans share the CRS original by `Arc`, so the CRS
//! baseline plan every registered matrix keeps is zero-copy.
//!
//! A plan is also the **per-block unit of cross-socket split serving**:
//! [`crate::coordinator::shards::SplitPlan`] owns one `SpmvPlan` per
//! nnz-balanced row block (each on its own shard pool) and runs them
//! concurrently through [`crate::spmv::pool::PoolGroup::join_all`],
//! forcing one uniform [`SpmvPlan::batch_tile`] across the blocks so the
//! split's ⌈k/tile⌉ pass accounting stays comparable to an unsplit plan.
//!
//! Construction is **first-touch aware**: the transformation writes its
//! arrays through [`ParPool::run_init`] on the plan's pool, and every
//! build ends with an [`AnyMatrix::first_touch_on`] pass over the chosen
//! representation — so on a socket-pinned shard pool (see
//! [`crate::coordinator::shards`] and [`crate::machine::topology`]) the
//! data a plan will stream lives on the socket whose workers stream it,
//! and each build/re-plan is observable as a
//! [`ParPool::init_count`] delta.

use super::kernels::{self, AnyMatrix};
use super::partition::{self, Partition, PartitionStrategy};
use super::pool::{self, ParPool};
use super::{Implementation, Workspace};
use crate::autotune::online::{decide, TuningData};
use crate::autotune::MemoryPolicy;
use crate::formats::{Csr, Ell, FormatKind, SparseMatrix};
use crate::machine::MatrixShape;
use crate::{Result, Value};
use std::sync::Arc;

/// The batch-tile width for blocked SpMM: the `SPMV_AT_BATCH_TILE`
/// environment variable when set to a positive integer, else a width
/// chosen so one tile's per-RHS working set (`rows_per_rhs` output/
/// scratch rows plus an `x` column) fits in a conservative
/// last-level-cache budget (the matrix stream then misses cache at most
/// once per tile, which is the whole point of blocking). For the
/// direct-output kernels `rows_per_rhs` is just `n_rows`; the
/// YY-reduction kernels pass their private-copy footprint so the
/// workspace the tile allocates is counted too.
pub fn configured_batch_tile(rows_per_rhs: usize, n_cols: usize) -> usize {
    if let Ok(s) = std::env::var("SPMV_AT_BATCH_TILE") {
        if let Ok(t) = s.trim().parse::<usize>() {
            if t >= 1 {
                return t;
            }
        }
    }
    default_batch_tile(rows_per_rhs, n_cols)
}

/// Default tile width: as many RHS columns as fit in half of an assumed
/// 32 MiB LLC, clamped to [1, 32]. The `.max(1)` divisor guard keeps the
/// empty-matrix degenerate case (0 rows, 0 cols) from dividing by zero —
/// it clamps to the top of the range.
fn default_batch_tile(rows_per_rhs: usize, n_cols: usize) -> usize {
    const LLC_BUDGET_BYTES: usize = 16 << 20;
    let bytes_per_rhs = (rows_per_rhs + n_cols).max(1) * std::mem::size_of::<Value>();
    (LLC_BUDGET_BYTES / bytes_per_rhs).clamp(1, 32)
}

/// Rows of output/scratch one right-hand side costs `imp` per tile: the
/// YY-reduction kernels (COO outer, ELL outer) keep one private `y` copy
/// per chunk on top of the output itself, so their tile must shrink with
/// the partition width or one `execute_many` call grows the workspace to
/// `n_rows × tile × chunks` values — past any cache budget and retained
/// for the plan's lifetime.
fn rows_per_rhs_for(imp: Implementation, n_rows: usize, n_chunks: usize) -> usize {
    match imp {
        Implementation::CooColOuter
        | Implementation::CooRowOuter
        | Implementation::EllRowOuter => n_rows * (n_chunks.max(1) + 1),
        _ => n_rows,
    }
}

/// An executable SpMV plan: chosen representation + partition + workspace
/// + pool, built once and replayed per call.
pub struct SpmvPlan {
    imp: Implementation,
    matrix: AnyMatrix,
    part: Partition,
    ws: Workspace,
    pool: Arc<ParPool>,
    n_rows: usize,
    n_cols: usize,
    transform_seconds: f64,
    calls: u64,
    batch_tile: usize,
    matrix_passes: u64,
}

impl SpmvPlan {
    /// Build a plan executing `imp` for `csr` on `pool`. The (possibly
    /// parallel) transformation runs here, once; `max_bytes` bounds ELL
    /// storage (the §2.2 memory-policy hook). CRS plans share `csr`
    /// zero-copy; transformed plans own their converted data.
    pub fn build(
        csr: &Arc<Csr>,
        imp: Implementation,
        max_bytes: Option<usize>,
        pool: Arc<ParPool>,
    ) -> Result<Self> {
        Self::build_with(csr, imp, max_bytes, pool, None)
    }

    /// Like [`SpmvPlan::build`], with an explicit [`PartitionStrategy`]
    /// instead of the planner's env-override + skew pick. The oracle
    /// harness sweeps strategies through this without mutating the
    /// process environment.
    pub fn build_with(
        csr: &Arc<Csr>,
        imp: Implementation,
        max_bytes: Option<usize>,
        pool: Arc<ParPool>,
        strategy: Option<PartitionStrategy>,
    ) -> Result<Self> {
        let t0 = std::time::Instant::now();
        let matrix = AnyMatrix::prepare_on(csr, imp, max_bytes, &pool)?;
        Ok(Self::assemble(csr, imp, matrix, t0, pool, strategy))
    }

    /// Like [`SpmvPlan::build`] for a borrowed CRS nobody shares: the CRS
    /// case clones it, the transformed cases never copy the source. The
    /// measurement backend builds its throwaway plans here so sweeping
    /// t_imp across implementations does not pay a matrix copy per cell.
    pub fn build_ref(
        csr: &Csr,
        imp: Implementation,
        max_bytes: Option<usize>,
        pool: Arc<ParPool>,
    ) -> Result<Self> {
        let t0 = std::time::Instant::now();
        let matrix = AnyMatrix::prepare_ref_on(csr, imp, max_bytes, &pool)?;
        Ok(Self::assemble(csr, imp, matrix, t0, pool, None))
    }

    fn assemble(
        csr: &Csr,
        imp: Implementation,
        matrix: AnyMatrix,
        t0: std::time::Instant,
        pool: Arc<ParPool>,
        strategy: Option<PartitionStrategy>,
    ) -> Self {
        let transform_seconds = if imp.needs_transform() {
            t0.elapsed().as_secs_f64()
        } else {
            0.0
        };
        // First-touch/warm the chosen representation from this pool's
        // (possibly socket-pinned) workers — every build is observable as
        // a `ParPool::init_count` delta, and on a NUMA shard the arrays
        // end up faulted on the socket that will stream them.
        matrix.first_touch_on(&pool);
        // Partition-strategy decision point: an explicit caller request
        // wins, then the `SPMV_AT_PARTITION` override, then the row-skew
        // pick off the matrixgen row-length stats. Cached in the plan —
        // merge coordinates included — and replayed every call.
        let strategy = strategy
            .or_else(partition::configured_partition)
            .unwrap_or_else(|| partition::pick_strategy_auto(&csr.row_ptr));
        let part = kernels::partition_for(imp, &matrix, pool.size(), Some(strategy));
        let rows_per_rhs = rows_per_rhs_for(imp, csr.n_rows(), part.n_chunks());
        Self {
            imp,
            matrix,
            part,
            ws: Workspace::new(),
            pool,
            n_rows: csr.n_rows(),
            n_cols: csr.n_cols(),
            transform_seconds,
            calls: 0,
            batch_tile: configured_batch_tile(rows_per_rhs, csr.n_cols()),
            matrix_passes: 0,
        }
    }

    /// `y = A·x` through the planned kernel.
    ///
    /// # Errors
    /// Fails on dimension mismatch.
    pub fn execute(&mut self, x: &[Value], y: &mut [Value]) -> Result<()> {
        anyhow::ensure!(
            x.len() == self.n_cols,
            "x length {} != n_cols {}",
            x.len(),
            self.n_cols
        );
        anyhow::ensure!(
            y.len() == self.n_rows,
            "y length {} != n_rows {}",
            y.len(),
            self.n_rows
        );
        self.calls += 1;
        self.matrix_passes += 1;
        kernels::run_on(self.imp, &self.matrix, x, y, &self.pool, &self.part, &mut self.ws)
    }

    /// Batched `Y = A·X` as a **tiled SpMM**: the batch is cut into column
    /// tiles of [`SpmvPlan::batch_tile`] right-hand sides and each tile is
    /// served by one pass of the blocked multi-RHS kernels over the
    /// matrix — ⌈k/tile⌉ matrix passes total instead of the k passes
    /// looped [`SpmvPlan::execute`] calls would make, with bitwise-identical
    /// results. All served by this plan's single transformation and
    /// partition.
    ///
    /// # Errors
    /// Fails if `xs` and `ys` differ in length or any vector mismatches.
    pub fn execute_many(&mut self, xs: &[Vec<Value>], ys: &mut [Vec<Value>]) -> Result<()> {
        anyhow::ensure!(
            xs.len() == ys.len(),
            "batch mismatch: {} inputs vs {} outputs",
            xs.len(),
            ys.len()
        );
        for x in xs {
            anyhow::ensure!(
                x.len() == self.n_cols,
                "x length {} != n_cols {}",
                x.len(),
                self.n_cols
            );
        }
        for y in ys.iter() {
            anyhow::ensure!(
                y.len() == self.n_rows,
                "y length {} != n_rows {}",
                y.len(),
                self.n_rows
            );
        }
        let tile = self.batch_tile.max(1);
        for (txs, tys) in xs.chunks(tile).zip(ys.chunks_mut(tile)) {
            let xrefs: Vec<&[Value]> = txs.iter().map(|v| v.as_slice()).collect();
            let mut yrefs: Vec<&mut [Value]> = tys.iter_mut().map(|v| v.as_mut_slice()).collect();
            kernels::run_many_on(
                self.imp,
                &self.matrix,
                &xrefs,
                &mut yrefs,
                &self.pool,
                &self.part,
                &mut self.ws,
            )?;
            self.matrix_passes += 1;
        }
        self.calls += xs.len() as u64;
        Ok(())
    }

    /// The batch-tile width `execute_many` blocks on (see
    /// [`configured_batch_tile`]).
    pub fn batch_tile(&self) -> usize {
        self.batch_tile
    }

    /// Override the batch-tile width (tests and tuning sweeps).
    pub fn set_batch_tile(&mut self, tile: usize) {
        self.batch_tile = tile.max(1);
    }

    /// Passes over the matrix data so far: one per `execute`, ⌈k/tile⌉
    /// per `execute_many` of k right-hand sides (the SpMM amortisation
    /// probe; for the sequential extension formats without a blocked
    /// kernel a "pass" is one tile dispatch).
    pub fn matrix_passes(&self) -> u64 {
        self.matrix_passes
    }

    /// The implementation this plan executes.
    pub fn implementation(&self) -> Implementation {
        self.imp
    }

    /// The cached work partition (strategy + chunk ranges + merge
    /// coordinates, when any).
    pub fn partition(&self) -> &Partition {
        &self.part
    }

    /// Stats label of the partition strategy (`-` when unpartitioned).
    pub fn partition_strategy(&self) -> &'static str {
        self.part.strategy_name()
    }

    /// The stored format tag.
    pub fn kind(&self) -> FormatKind {
        self.matrix.kind()
    }

    /// The owned representation.
    pub fn matrix(&self) -> &AnyMatrix {
        &self.matrix
    }

    /// The ELL data when this plan serves an ELL kernel (the XLA runtime
    /// path inspects this without reaching into [`AnyMatrix`]).
    pub fn ell(&self) -> Option<&Ell> {
        match &self.matrix {
            AnyMatrix::Ell(e) => Some(e),
            _ => None,
        }
    }

    /// Rows of the operator.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Columns of the operator.
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Seconds the transformation took at build time (0 for CRS plans).
    pub fn transform_seconds(&self) -> f64 {
        self.transform_seconds
    }

    /// Calls served so far (the amortisation denominator).
    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// Storage footprint of the owned representation, bytes.
    pub fn memory_bytes(&self) -> usize {
        self.matrix.memory_bytes()
    }

    /// Extra bytes relative to serving from the CRS original: 0 for CRS
    /// plans, the full copy size otherwise.
    pub fn extra_bytes(&self) -> usize {
        if self.kind() == FormatKind::Csr {
            0
        } else {
            self.memory_bytes()
        }
    }

    /// The pool this plan executes on.
    pub fn pool(&self) -> &Arc<ParPool> {
        &self.pool
    }

    /// Swap this plan's executable state — implementation, representation,
    /// partition, batch tile, transform accounting — for `new`'s, while
    /// keeping the accumulated `calls`/`matrix_passes` counters and
    /// whichever workspace allocation is larger. The worker pool is an
    /// `Arc` handle either way, so nothing is torn down or respawned: the
    /// adaptive controller uses this to re-point a serving slot at a
    /// re-decided plan in O(1) under load.
    ///
    /// # Panics
    /// Panics if `new` is a plan for a different operator shape.
    pub fn swap_executable(&mut self, new: SpmvPlan) {
        assert_eq!(
            (new.n_rows, new.n_cols),
            (self.n_rows, self.n_cols),
            "swap_executable requires plans over the same operator"
        );
        let SpmvPlan { imp, matrix, part, ws, pool, transform_seconds, batch_tile, .. } = new;
        self.imp = imp;
        self.matrix = matrix;
        self.part = part;
        self.pool = pool;
        self.transform_seconds = transform_seconds;
        self.batch_tile = batch_tile;
        if ws.capacity_bytes() > self.ws.capacity_bytes() {
            self.ws = ws;
        }
    }
}

impl std::fmt::Debug for SpmvPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpmvPlan")
            .field("imp", &self.imp)
            .field("kind", &self.kind())
            .field("partition", &self.part.strategy_name())
            .field("chunks", &self.part.n_chunks())
            .field("pool", &self.pool.size())
            .field("calls", &self.calls)
            .finish()
    }
}

/// Plan factory: tuning table + memory policy + pool.
pub struct Planner {
    tuning: TuningData,
    policy: MemoryPolicy,
    pool: Arc<ParPool>,
}

impl Planner {
    /// Planner over an explicit pool.
    pub fn new(tuning: TuningData, policy: MemoryPolicy, pool: Arc<ParPool>) -> Self {
        Self { tuning, policy, pool }
    }

    /// Planner over the process-wide [`pool::global`] pool.
    pub fn with_global_pool(tuning: TuningData, policy: MemoryPolicy) -> Self {
        Self::new(tuning, policy, pool::global())
    }

    /// The installed tuning table.
    pub fn tuning(&self) -> &TuningData {
        &self.tuning
    }

    /// The memory policy bounding transformed copies.
    pub fn policy(&self) -> &MemoryPolicy {
        &self.policy
    }

    /// The pool plans will execute on.
    pub fn pool(&self) -> &Arc<ParPool> {
        &self.pool
    }

    /// The implementation the §2.2 online phase chooses for `csr` right
    /// now: the tuning table's candidate when `D_mat < D*` *and* the
    /// memory policy admits the target format, CRS otherwise.
    pub fn auto_choice(&self, csr: &Csr) -> Implementation {
        let d = decide(csr, &self.tuning);
        if !d.transform {
            return Implementation::CsrSeq;
        }
        let shape = MatrixShape::of(csr);
        if self.policy.admits(&shape, d.chosen.required_format()) {
            d.chosen
        } else {
            Implementation::CsrSeq
        }
    }

    /// The parallel-CRS baseline implementation for `csr`: `CRS-Merge`
    /// when the partition pick (env override or row-skew heuristic) says
    /// merge-path — a single giant row would serialise one worker of any
    /// row-aligned split — and plain row-parallel CRS otherwise. The
    /// coordinator's zero-transform serving plan builds through this, so
    /// skewed matrices get merge-path balance without any format change.
    pub fn baseline_impl(&self, csr: &Csr) -> Implementation {
        match partition::pick_strategy(&csr.row_ptr) {
            PartitionStrategy::MergePath => Implementation::CsrMergePar,
            _ => Implementation::CsrRowPar,
        }
    }

    /// Build the plan the online AT decision selects, falling back to the
    /// CRS baseline if the selected transformation fails at run time
    /// (e.g. an ELL blow-up the size predictor underestimated).
    pub fn plan(&self, csr: &Arc<Csr>) -> Result<SpmvPlan> {
        let imp = self.auto_choice(csr);
        match self.plan_for(csr, imp) {
            Ok(p) => Ok(p),
            Err(_) if imp != Implementation::CsrSeq => {
                self.plan_for(csr, Implementation::CsrSeq)
            }
            Err(e) => Err(e),
        }
    }

    /// Build a plan for an explicitly requested implementation. CRS plans
    /// share `csr` instead of cloning it.
    pub fn plan_for(&self, csr: &Arc<Csr>, imp: Implementation) -> Result<SpmvPlan> {
        SpmvPlan::build(csr, imp, self.policy.ell_budget(), self.pool.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::{banded_circulant, generate, random_csr, spec_by_name};
    use crate::rng::Rng;

    fn tuning(d_star: Option<f64>, imp: Implementation) -> TuningData {
        TuningData { backend: "sim:ES2".into(), imp, threads: 1, c: 1.0, d_star }
    }

    #[test]
    fn plan_matches_baseline_for_every_implementation() {
        let mut rng = Rng::new(41);
        let a = Arc::new(random_csr(&mut rng, 60, 60, 0.1));
        let x: Vec<Value> = (0..60).map(|i| (i as f64 * 0.21).cos()).collect();
        let mut want = vec![0.0; 60];
        a.spmv(&x, &mut want);
        let pool = Arc::new(ParPool::new(4));
        for imp in Implementation::ALL {
            let mut plan = SpmvPlan::build(&a, imp, None, pool.clone()).unwrap();
            assert_eq!(plan.kind(), imp.required_format());
            let mut y = vec![0.0; 60];
            plan.execute(&x, &mut y).unwrap();
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "{imp}: {g} vs {w}");
            }
            assert_eq!(plan.calls(), 1);
        }
    }

    #[test]
    fn auto_plan_transforms_banded_and_vetoes_on_policy() {
        let mut rng = Rng::new(42);
        let band = Arc::new(banded_circulant(&mut rng, 128, &[-1, 0, 1]));
        let planner = Planner::new(
            tuning(Some(3.1), Implementation::EllRowOuter),
            MemoryPolicy::unlimited(),
            Arc::new(ParPool::new(2)),
        );
        assert_eq!(planner.auto_choice(&band), Implementation::EllRowOuter);
        let plan = planner.plan(&band).unwrap();
        assert_eq!(plan.implementation(), Implementation::EllRowOuter);
        assert!(plan.transform_seconds() > 0.0);
        assert!(plan.extra_bytes() > 0);

        // Tail-heavy matrix + tight budget: the policy vetoes ELL.
        let spiky = Arc::new(generate(&spec_by_name("memplus").unwrap(), 3, 0.03));
        let vetoed = Planner::new(
            tuning(Some(10.0), Implementation::EllRowOuter),
            MemoryPolicy::with_budget(64 * 1024),
            Arc::new(ParPool::new(2)),
        );
        assert_eq!(vetoed.auto_choice(&spiky), Implementation::CsrSeq);
        let plan = vetoed.plan(&spiky).unwrap();
        assert_eq!(plan.implementation(), Implementation::CsrSeq);
        assert_eq!(plan.transform_seconds(), 0.0);
        assert_eq!(plan.extra_bytes(), 0);
    }

    #[test]
    fn execute_many_matches_individual_executes() {
        let mut rng = Rng::new(43);
        let a = Arc::new(random_csr(&mut rng, 32, 32, 0.2));
        let pool = Arc::new(ParPool::new(2));
        let mut plan = SpmvPlan::build(&a, Implementation::CsrRowPar, None, pool).unwrap();
        let xs: Vec<Vec<Value>> = (0..4)
            .map(|k| (0..32).map(|i| ((i + k) as f64 * 0.31).sin()).collect())
            .collect();
        let mut ys = vec![vec![0.0; 32]; 4];
        plan.execute_many(&xs, &mut ys).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let mut want = vec![0.0; 32];
            a.spmv(x, &mut want);
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12);
            }
        }
        assert_eq!(plan.calls(), 4);
        // Length mismatches are rejected.
        let mut short = vec![vec![0.0; 32]; 3];
        assert!(plan.execute_many(&xs, &mut short).is_err());
    }

    #[test]
    fn execute_many_streams_the_matrix_once_per_tile() {
        let mut rng = Rng::new(44);
        let a = Arc::new(random_csr(&mut rng, 40, 40, 0.15));
        let pool = Arc::new(ParPool::new(3));
        let mut plan = SpmvPlan::build(&a, Implementation::CsrRowPar, None, pool.clone()).unwrap();
        let k = 7usize;
        let xs: Vec<Vec<Value>> = (0..k)
            .map(|j| (0..40).map(|i| ((i * 2 + j) as f64 * 0.19).cos()).collect())
            .collect();
        let mut ys = vec![vec![0.0; 40]; k];
        for (tile, want_passes) in [(3usize, 3u64), (1, 7), (7, 1), (100, 1)] {
            plan.set_batch_tile(tile);
            assert_eq!(plan.batch_tile(), tile.max(1));
            let before_passes = plan.matrix_passes();
            let before_dispatch = pool.dispatch_count();
            plan.execute_many(&xs, &mut ys).unwrap();
            assert_eq!(
                plan.matrix_passes() - before_passes,
                want_passes,
                "tile {tile}: ceil(k/tile) matrix passes"
            );
            // Row-parallel CRS SpMM is exactly one pool dispatch per pass.
            assert_eq!(
                pool.dispatch_count() - before_dispatch,
                want_passes,
                "tile {tile}: one dispatch per pass"
            );
        }
    }

    #[test]
    fn build_with_pins_the_partition_strategy() {
        let mut rng = Rng::new(46);
        let a = Arc::new(random_csr(&mut rng, 48, 48, 0.1));
        let pool = Arc::new(ParPool::new(3));
        let x: Vec<Value> = (0..48).map(|i| (i as f64 * 0.13).sin()).collect();
        let mut want = vec![0.0; 48];
        a.spmv(&x, &mut want);
        for s in PartitionStrategy::ALL {
            let mut plan =
                SpmvPlan::build_with(&a, Implementation::CsrRowPar, None, pool.clone(), Some(s))
                    .unwrap();
            assert_eq!(plan.partition_strategy(), s.name());
            let mut y = vec![0.0; 48];
            plan.execute(&x, &mut y).unwrap();
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12, "{s}");
            }
        }
        // CRS-Merge plans cache the merge coordinates.
        let plan =
            SpmvPlan::build(&a, Implementation::CsrMergePar, None, pool.clone()).unwrap();
        assert_eq!(plan.partition_strategy(), "merge");
        assert!(plan.partition().merge.is_some());
        // Default builds still resolve to the skew pick (nnz here).
        let plan = SpmvPlan::build(&a, Implementation::CsrRowPar, None, pool).unwrap();
        if std::env::var("SPMV_AT_PARTITION").is_err() {
            assert!(plan.partition_strategy() == "nnz" || plan.partition_strategy() == "merge");
        }
    }

    #[test]
    fn baseline_impl_follows_the_skew_pick() {
        if std::env::var("SPMV_AT_PARTITION").is_ok() {
            return; // pick is env-forced; the auto heuristic is not observable
        }
        let planner = Planner::new(
            tuning(None, Implementation::CsrSeq),
            MemoryPolicy::unlimited(),
            Arc::new(ParPool::new(2)),
        );
        let mut rng = Rng::new(47);
        let uniform = banded_circulant(&mut rng, 64, &[-1, 0, 1]);
        assert_eq!(planner.baseline_impl(&uniform), Implementation::CsrRowPar);
        // memplus-style skew: one giant row among short rows.
        let mut trips: Vec<(usize, usize, Value)> = (0..100).map(|c| (50, c, 1.0)).collect();
        for r in 0..100 {
            trips.push((r, r, 1.0));
        }
        let skewed = Csr::from_triplets(100, 100, &trips).unwrap();
        assert_eq!(planner.baseline_impl(&skewed), Implementation::CsrMergePar);
    }

    #[test]
    fn plan_rejects_dimension_mismatch() {
        let a = Arc::new(Csr::identity(8));
        let mut plan =
            SpmvPlan::build(&a, Implementation::CsrSeq, None, Arc::new(ParPool::new(1))).unwrap();
        let mut y = vec![0.0; 8];
        assert!(plan.execute(&[1.0; 7], &mut y).is_err());
        assert!(plan.execute(&[1.0; 8], &mut vec![0.0; 9]).is_err());
        // Batched dimension mismatches are rejected up front too.
        let bad_x = vec![vec![0.0; 7]; 2];
        let mut ys = vec![vec![0.0; 8]; 2];
        assert!(plan.execute_many(&bad_x, &mut ys).is_err());
        let good_x = vec![vec![0.0; 8]; 2];
        let mut bad_y = vec![vec![0.0; 9]; 2];
        assert!(plan.execute_many(&good_x, &mut bad_y).is_err());
    }

    #[test]
    fn swap_executable_keeps_counters_and_pool() {
        let mut rng = Rng::new(45);
        let a = Arc::new(banded_circulant(&mut rng, 64, &[-1, 0, 1]));
        let pool = Arc::new(ParPool::new(2));
        let mut plan = SpmvPlan::build(&a, Implementation::CsrRowPar, None, pool.clone()).unwrap();
        let x: Vec<Value> = (0..64).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut want = vec![0.0; 64];
        a.spmv(&x, &mut want);
        let mut y = vec![0.0; 64];
        plan.execute(&x, &mut y).unwrap();
        let (calls, passes) = (plan.calls(), plan.matrix_passes());

        // Re-point the slot at an ELL plan built on the same pool.
        let ell = SpmvPlan::build(&a, Implementation::EllRowInner, None, pool.clone()).unwrap();
        plan.swap_executable(ell);
        assert_eq!(plan.implementation(), Implementation::EllRowInner);
        assert_eq!(plan.kind(), FormatKind::Ell);
        assert!(Arc::ptr_eq(plan.pool(), &pool), "no pool teardown across the swap");
        assert_eq!(plan.calls(), calls, "cumulative counters survive");
        assert_eq!(plan.matrix_passes(), passes);
        plan.execute(&x, &mut y).unwrap();
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
        assert_eq!(plan.calls(), calls + 1);

        // Shape mismatches are rejected loudly.
        let other = Arc::new(Csr::identity(8));
        let wrong =
            SpmvPlan::build(&other, Implementation::CsrSeq, None, Arc::new(ParPool::new(1)))
                .unwrap();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.swap_executable(wrong);
        }));
        assert!(err.is_err());
    }

    #[test]
    fn reduction_kernels_get_smaller_default_tiles() {
        let direct = super::rows_per_rhs_for(Implementation::CsrRowPar, 1000, 8);
        let reduced = super::rows_per_rhs_for(Implementation::EllRowOuter, 1000, 8);
        assert_eq!(direct, 1000);
        assert_eq!(reduced, 9000, "8 private chunk copies + the output itself");
        // At sizes where the budget binds, the YY footprint shrinks the tile.
        assert!(
            super::default_batch_tile(200_000 * 9, 200_000)
                < super::default_batch_tile(200_000, 200_000)
        );
    }

    #[test]
    fn default_tile_respects_llc_budget_and_clamps() {
        assert_eq!(super::default_batch_tile(0, 0), 32, "degenerate clamps high");
        assert_eq!(super::default_batch_tile(10_000_000, 10_000_000), 1, "huge clamps low");
        let t = super::default_batch_tile(100_000, 100_000);
        assert!((1..=32).contains(&t));
        // Half of 32 MiB over (n_rows + n_cols) * 8 bytes, clamped.
        assert_eq!(t, ((16usize << 20) / (200_000 * 8)).clamp(1, 32));
    }
}
