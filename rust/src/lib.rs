//! # spmv-at — run-time sparse data transformation auto-tuning for SpMV
//!
//! A reproduction of *"An Auto-tuning Method for Run-time Data Transformation
//! for Sparse Matrix-Vector Multiplication"* (Katagiri & Sato).
//!
//! The library is organised in four layers (plus a network front end):
//!
//! ```text
//!   network      net — framed wire protocol (unix/tcp), per-connection
//!                sessions, bounded ingress queues with Busy backpressure,
//!                cross-request batch coalescing → Client
//!   serving      coordinator ── registry of MatrixEntry{ decision, plans }
//!                coordinator::shards — socket-pinned pools (one/socket),
//!                key-routed matrices, cross-socket SplitPlan SpMM,
//!                runtime (XLA/PJRT artifacts)     │  one server loop/shard
//!   autotune     offline/online AT phases, D_mat, │D*, memory policy
//!                autotune::adaptive — telemetry (EWMA/imp) · ε-explore ·
//!                hysteresis controller · learned v2 table; per-shard
//!                controllers re-plan serving entries under load
//!                        │ decision (re-decidable) │ cached SpmvPlan
//!   execution    spmv::plan  Planner ──▶ SpmvPlan{ AnyMatrix, partition,
//!   engine                                         Workspace, pool, tile }
//!                execute (SpMV) · execute_many (tiled SpMM: one matrix
//!                pass per SPMV_AT_BATCH_TILE right-hand sides) ·
//!                swap_executable (O(1) plan swap, no pool teardown)
//!                spmv::pool  ParPool — persistent parked workers;
//!                            the crate's only thread-spawning site
//!   substrates   formats · transform · spmv kernels · matrixgen · io
//!                machine cost models + topology/affinity · solvers
//!                precond — level-scheduled SpTRSV/SymGS kernels
//! ```
//!
//! * **Substrates** — sparse formats ([`formats`]), run-time transformations
//!   ([`transform`]), parallel SpMV implementations ([`spmv`]), synthetic
//!   matrix generators ([`matrixgen`]), Matrix Market I/O ([`io`]), machine
//!   cost models ([`machine`]), iterative solvers ([`solver`]) and
//!   preconditioner kernels ([`precond`]: level-scheduled sparse
//!   triangular solves and symmetric Gauss-Seidel, with their own
//!   serial-vs-parallel autotuned decision).
//! * **The execution engine** — a persistent worker pool
//!   ([`spmv::pool::ParPool`]: parked workers, no per-call spawning) and
//!   reusable plans ([`spmv::plan`]): a [`spmv::SpmvPlan`] owns the chosen
//!   representation (sharing the CRS original by `Arc`, so baseline plans
//!   are zero-copy), its work partition (computed once) and its workspace,
//!   so the hot path is allocation- and fork-free. Batches execute as a
//!   **tiled SpMM** ([`spmv::SpmvPlan::execute_many`]): every kernel has a
//!   blocked multi-RHS variant that streams the matrix once per column
//!   tile, bitwise-identical to looped single executes. Every layer
//!   above — the `Durmv` handle, the coordinator, the solvers, the CLI —
//!   executes through cached plans.
//! * **The paper's contribution** — the auto-tuning engine ([`autotune`]):
//!   the `D_mat` statistic, the `R_ell` cost ratio, the `D_mat`–`R_ell`
//!   graph with its `D*` threshold, and the offline/online AT phases —
//!   extended by the **adaptive runtime loop** ([`autotune::adaptive`],
//!   `SPMV_AT_ADAPTIVE`): per-implementation EWMA telemetry on served
//!   traffic, budgeted epsilon-greedy shadow measurement of the rival
//!   kernel, a dead-band + K-window hysteresis controller that re-plans a
//!   matrix when the measured ratio contradicts the offline table, and a
//!   `spmv-at-tuning v2` table persisting the learned per-`D_mat`-bucket
//!   corrections. Exploration and re-planning never change served
//!   results; with the flag off the pipeline is the decide-once one.
//! * **The serving layer** — a PJRT-backed runtime ([`runtime`]) that
//!   executes AOT-compiled JAX/Pallas SpMV artifacts, and a coordinator
//!   ([`coordinator`]) that owns matrix lifecycles, routes SpMV requests
//!   through the online AT decision, and shards plans across independent
//!   pools ([`coordinator::shards`], `SPMV_AT_SHARDS`) with one server
//!   loop per shard so batches against different matrices run
//!   concurrently.
//! * **The network front end** — [`net`]: a compact length-prefixed
//!   binary protocol ([`net::proto`], `docs/PROTOCOL.md`) served over
//!   Unix sockets or TCP (`spmv-at serve --listen …`), with per-shard
//!   bounded ingress queues (explicit `Busy` backpressure) and a
//!   coalescer ([`net::ingress`]) that folds concurrent single-vector
//!   requests against the same matrix into one tiled batch call —
//!   bitwise-identical results, ⌈k/tile⌉ matrix passes instead of `k`.
//!
//! Thread-count truth lives in one place:
//! [`spmv::pool::configured_threads`] (the `SPMV_AT_THREADS` environment
//! variable when set, hardware parallelism otherwise) sizes the global
//! pool, `CoordinatorConfig::new`, and the CLI defaults; shard-count truth
//! likewise in [`coordinator::shards::configured_shards`]
//! (`SPMV_AT_SHARDS` when set, else the socket count from
//! [`machine::Topology::detect`] — overridable with
//! `SPMV_AT_TOPOLOGY=<sockets>:<cores>`), batch-tile truth in
//! [`spmv::plan::configured_batch_tile`] (`SPMV_AT_BATCH_TILE`, default
//! sized to the last-level cache), and adaptive-loop truth in
//! [`autotune::adaptive::configured_adaptive`] (`SPMV_AT_ADAPTIVE`,
//! default off). The full knob reference lives in `docs/TUNING.md`; the
//! request-path walkthrough in `docs/ARCHITECTURE.md`.
//!
//! Quick start:
//!
//! ```
//! use spmv_at::formats::{Csr, SparseMatrix};
//! use spmv_at::autotune::dmat::RowStats;
//!
//! // 2x2 identity in CSR.
//! let a = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (1, 1, 1.0)]).unwrap();
//! let mut y = vec![0.0; 2];
//! a.spmv(&[3.0, 4.0], &mut y);
//! assert_eq!(y, vec![3.0, 4.0]);
//! let stats = RowStats::of_csr(&a);
//! assert_eq!(stats.mean, 1.0);
//! assert_eq!(stats.d_mat(), 0.0);
//! ```

pub mod autotune;
pub mod coordinator;
pub mod formats;
pub mod io;
pub mod machine;
pub mod matrixgen;
pub mod metrics;
pub mod net;
pub mod precond;
pub mod rng;
pub mod runtime;
pub mod solver;
pub mod spmv;
pub mod transform;

/// Scalar element type used throughout the library (the paper uses
/// double-precision Fortran REAL*8).
pub type Value = f64;

/// Column/row index type. `u32` matches the 32-bit Fortran `INTEGER`s of the
/// paper's kernels and halves index-array memory traffic relative to `usize`.
pub type Index = u32;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
