//! Timing, statistics and report-table utilities shared by the AT engine,
//! the benches and the coordinator.

use std::time::{Duration, Instant};

/// Measure the median wall-clock time of `f` over `reps` runs after
/// `warmup` unmeasured runs. Returns seconds. The paper's ratios (`SP`,
/// `TT`, `R_ell`) are all built from such measurements.
pub fn time_median<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    median(&mut samples)
}

/// Measure a single run of `f` in seconds.
pub fn time_once<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// In-place median (sorts the slice).
pub fn median(xs: &mut [f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty slice");
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

/// Online mean/min/max/stddev accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Sample count.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0 for empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    /// Minimum (NaN-free; +inf when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-column ASCII table writer for bench reports (the repo's analogue
/// of the paper's tables/figure series).
#[derive(Clone, Debug)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i].saturating_sub(cells[i].len());
                line.push_str(&cells[i]);
                line.push_str(&" ".repeat(pad));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Minimal JSON value emitter (the environment has no serde); enough for
/// the bench harness to dump machine-readable results next to the tables.
#[derive(Clone, Debug)]
pub enum Json {
    /// JSON null.
    Null,
    /// Boolean.
    Bool(bool),
    /// Number (serialized via `{:?}` for round-trip fidelity).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (insertion-ordered).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    out.push_str(&format!("{x:?}"))
                } else {
                    out.push_str("null")
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience: duration as human-readable string.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn stats_welford() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["name", "val"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["longer", "2.5"]);
        let r = t.render();
        assert!(r.contains("name"));
        assert!(r.lines().count() == 4);
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn json_escaping_and_shapes() {
        let j = Json::Obj(vec![
            ("s".into(), Json::Str("a\"b\\c\nd".into())),
            ("n".into(), Json::Num(1.5)),
            ("arr".into(), Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("inf".into(), Json::Num(f64::INFINITY)),
        ]);
        let s = j.render();
        assert_eq!(
            s,
            r#"{"s":"a\"b\\c\nd","n":1.5,"arr":[true,null],"inf":null}"#
        );
    }

    #[test]
    fn time_median_measures_something() {
        let t = time_median(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0 && t < 1.0);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("µs"));
    }
}
