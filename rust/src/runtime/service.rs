//! XLA runtime service: pins the (non-`Send`) PJRT client to one dedicated
//! thread and serves SpMV executions over a channel.
//!
//! The `xla` crate's client and executables hold `Rc` internals, so they
//! must never cross threads. The coordinator therefore talks to
//! [`XlaHandle`] — a cheap, cloneable, `Send + Sync` front — while the
//! actual `XlaRuntime` lives inside the service thread for its whole life.
//!
//! The thread itself runs through
//! [`crate::coordinator::server::spawn_dispatch`] — the same dispatch
//! primitive behind the request loops — whose in-thread `init` closure is
//! exactly the hook a non-`Send` runtime needs: the `XlaRuntime` is
//! constructed inside the service thread, the init result is reported
//! back synchronously, and the state never crosses a thread boundary.

use super::XlaRuntime;
use crate::coordinator::server::spawn_dispatch;
use crate::{Result, Value};
use std::path::PathBuf;
use std::sync::mpsc;

enum Msg {
    EllSpmv {
        n_rows: usize,
        bandwidth: usize,
        values: Vec<Value>,
        col_idx_i32: Vec<i32>,
        x: Vec<Value>,
        resp: mpsc::Sender<Result<Vec<Value>>>,
    },
    /// Does any bucket fit (rows, bandwidth)?
    HasBucket {
        rows: usize,
        bandwidth: usize,
        resp: mpsc::Sender<bool>,
    },
    Platform {
        resp: mpsc::Sender<String>,
    },
    Shutdown,
}

/// Cloneable, thread-safe handle to the XLA service.
#[derive(Clone)]
pub struct XlaHandle {
    tx: mpsc::SyncSender<Msg>,
}

impl XlaHandle {
    /// Whether an artifact bucket fits the given ELL shape.
    pub fn has_bucket(&self, rows: usize, bandwidth: usize) -> bool {
        let (resp, rx) = mpsc::channel();
        if self.tx.send(Msg::HasBucket { rows, bandwidth, resp }).is_err() {
            return false;
        }
        rx.recv().unwrap_or(false)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> Result<String> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Msg::Platform { resp })
            .map_err(|_| anyhow::anyhow!("xla service stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("xla service dropped response"))
    }

    /// Execute ELL SpMV on the service thread (band-major inputs, like
    /// [`crate::formats::Ell`]).
    pub fn ell_spmv(
        &self,
        n_rows: usize,
        bandwidth: usize,
        values: &[Value],
        col_idx_i32: &[i32],
        x: &[Value],
    ) -> Result<Vec<Value>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Msg::EllSpmv {
                n_rows,
                bandwidth,
                values: values.to_vec(),
                col_idx_i32: col_idx_i32.to_vec(),
                x: x.to_vec(),
                resp,
            })
            .map_err(|_| anyhow::anyhow!("xla service stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("xla service dropped response"))?
    }
}

/// The service thread owner. Dropping it shuts the thread down.
pub struct XlaService {
    tx: mpsc::SyncSender<Msg>,
    handle: Option<std::thread::JoinHandle<Option<()>>>,
}

impl XlaService {
    /// Spawn the service over an artifact directory. Fails (synchronously)
    /// if the manifest cannot be loaded or the PJRT client cannot start.
    pub fn spawn(artifact_dir: PathBuf) -> Result<(Self, XlaHandle)> {
        let (tx, handle) = spawn_dispatch(
            "spmv-xla",
            32,
            move || XlaRuntime::new(&artifact_dir),
            |rt, msg| match msg {
                Msg::EllSpmv { n_rows, bandwidth, values, col_idx_i32, x, resp } => {
                    let mut y = vec![0.0; n_rows];
                    let r = rt
                        .ell_spmv(n_rows, bandwidth, &values, &col_idx_i32, &x, &mut y)
                        .map(|()| y);
                    let _ = resp.send(r);
                    true
                }
                Msg::HasBucket { rows, bandwidth, resp } => {
                    let _ =
                        resp.send(rt.manifest().bucket_for("ell_spmv", rows, bandwidth).is_some());
                    true
                }
                Msg::Platform { resp } => {
                    let _ = resp.send(rt.platform());
                    true
                }
                Msg::Shutdown => false,
            },
            // The runtime is non-`Send`: it is dropped inside its thread.
            |_rt| (),
        )?;
        let client = XlaHandle { tx: tx.clone() };
        Ok((Self { tx, handle: Some(handle) }, client))
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_fails_cleanly_without_manifest() {
        let dir = std::env::temp_dir().join("spmv_at_no_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.tsv"));
        assert!(XlaService::spawn(dir).is_err());
    }

    // Execution tests require real artifacts; see rust/tests/runtime_xla.rs.
}
