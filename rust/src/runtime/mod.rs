//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas SpMV
//! artifacts from the rust request path.
//!
//! The build-time Python side (`python/compile/aot.py`) lowers the L2 JAX
//! model (which calls the L1 Pallas ELL kernel) to **HLO text** — the
//! interchange format this image's xla_extension 0.5.1 accepts — for a
//! fixed set of `(rows, bandwidth)` shape buckets, and writes
//! `artifacts/manifest.tsv`. This module:
//!
//! * parses the manifest ([`Manifest`]);
//! * compiles artifacts on the PJRT CPU client lazily and caches the
//!   executables ([`XlaRuntime`]) — one compiled executable per model
//!   variant, compiled at most once;
//! * exposes [`EllXlaKernel`], an ELL SpMV that pads a matrix into its
//!   bucket and executes on XLA, so the coordinator can route SpMV
//!   requests to the Pallas-authored kernel with Python long gone.
//!
//! **The `xla` cargo feature.** The `xla` crate is a git-only dependency
//! (not on crates.io), so the PJRT-typed code here is gated behind the
//! no-dependency `xla` feature: enabling it requires patching the
//! dependency in by hand. With the feature **off** (the default, and
//! every CI leg) the same public surface compiles against stubs whose
//! constructors return a descriptive error — [`Manifest`], the
//! [`XlaService`] clean-failure path, and every caller keep building and
//! testing without the artifact toolchain present.

pub mod service;

pub use service::{XlaHandle, XlaService};

use crate::formats::Ell;
#[cfg(feature = "xla")]
use crate::formats::SparseMatrix;
use crate::{Result, Value};
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "xla")]
use std::sync::Mutex;

/// One artifact entry: an HLO module computing ELL SpMV for a shape bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// Kernel kind (currently `ell_spmv`).
    pub kind: String,
    /// Bucket row count.
    pub rows: usize,
    /// Bucket bandwidth.
    pub bandwidth: usize,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
}

/// The parsed `artifacts/manifest.tsv`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Directory the manifest lives in (file paths are relative to it).
    pub dir: PathBuf,
    /// Entries in file order.
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = t.split('\t').collect();
            anyhow::ensure!(
                cols.len() == 4,
                "manifest line {}: expected 4 tab-separated fields, got {}",
                lineno + 1,
                cols.len()
            );
            entries.push(ArtifactEntry {
                kind: cols[0].to_string(),
                rows: cols[1].parse()?,
                bandwidth: cols[2].parse()?,
                file: cols[3].to_string(),
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest {} is empty", path.display());
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    /// The smallest bucket that fits `(rows, bandwidth)`, or `None` if the
    /// matrix exceeds every bucket.
    pub fn bucket_for(&self, kind: &str, rows: usize, bandwidth: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && e.rows >= rows && e.bandwidth >= bandwidth)
            .min_by_key(|e| (e.rows, e.bandwidth))
    }

    /// All bucketed shapes for a kind (used by reports/tests).
    pub fn buckets(&self, kind: &str) -> Vec<(usize, usize)> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| (e.rows, e.bandwidth))
            .collect()
    }
}

/// Lazily-compiling PJRT executable cache, one per artifact.
#[cfg(feature = "xla")]
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<(usize, usize), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Create a CPU PJRT client over the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu: {e:?}"))?;
        Ok(Self { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the executable for a bucket.
    fn executable(
        &self,
        entry: &ArtifactEntry,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = (entry.rows, entry.bandwidth);
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-UTF8 path {}", path.display()))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute ELL SpMV through the bucketed artifact: pads
    /// `(values, col_idx, x)` to the bucket shape, runs, truncates `y`.
    ///
    /// Inputs are band-major exactly like [`Ell`]: `values[k*n + i]`.
    pub fn ell_spmv(
        &self,
        n_rows: usize,
        bandwidth: usize,
        values: &[Value],
        col_idx_i32: &[i32],
        x: &[Value],
        y: &mut [Value],
    ) -> Result<()> {
        let entry = self
            .manifest
            .bucket_for("ell_spmv", n_rows, bandwidth)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact bucket fits rows={n_rows} bandwidth={bandwidth} \
                     (available: {:?})",
                    self.manifest.buckets("ell_spmv")
                )
            })?
            .clone();
        let exe = self.executable(&entry)?;
        let (br, bk) = (entry.rows, entry.bandwidth);

        // Pad band-major arrays into the bucket. Padding values are 0.0
        // with column 0 — contributes 0.0 * x[0].
        let mut pv = vec![0.0f64; br * bk];
        let mut pc = vec![0i32; br * bk];
        for k in 0..bandwidth {
            pv[k * br..k * br + n_rows].copy_from_slice(&values[k * n_rows..(k + 1) * n_rows]);
            pc[k * br..k * br + n_rows]
                .copy_from_slice(&col_idx_i32[k * n_rows..(k + 1) * n_rows]);
        }
        let mut px = vec![0.0f64; br];
        px[..x.len().min(br)].copy_from_slice(&x[..x.len().min(br)]);

        let lv = xla::Literal::vec1(&pv)
            .reshape(&[bk as i64, br as i64])
            .map_err(|e| anyhow::anyhow!("reshape values: {e:?}"))?;
        let lc = xla::Literal::vec1(&pc)
            .reshape(&[bk as i64, br as i64])
            .map_err(|e| anyhow::anyhow!("reshape col_idx: {e:?}"))?;
        let lx = xla::Literal::vec1(&px);
        let result = exe
            .execute::<xla::Literal>(&[lv, lc, lx])
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let full: Vec<f64> = out
            .to_vec()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        anyhow::ensure!(full.len() == br, "bucket output length {} != {br}", full.len());
        y.copy_from_slice(&full[..y.len()]);
        Ok(())
    }
}

/// ELL SpMV kernel backed by the XLA runtime — the coordinator's
/// "serve through the Pallas artifact" path.
#[cfg(feature = "xla")]
pub struct EllXlaKernel<'rt> {
    rt: &'rt XlaRuntime,
    ell: Ell,
    col_idx_i32: Vec<i32>,
}

#[cfg(feature = "xla")]
impl<'rt> EllXlaKernel<'rt> {
    /// Wrap an ELL matrix for execution on `rt`. Fails early if no bucket
    /// fits.
    pub fn new(rt: &'rt XlaRuntime, ell: Ell) -> Result<Self> {
        anyhow::ensure!(
            rt.manifest
                .bucket_for("ell_spmv", ell.n_rows(), ell.bandwidth)
                .is_some(),
            "no artifact bucket for rows={} bandwidth={}",
            ell.n_rows(),
            ell.bandwidth
        );
        let col_idx_i32: Vec<i32> = ell.col_idx.iter().map(|&c| c as i32).collect();
        Ok(Self { rt, ell, col_idx_i32 })
    }

    /// The wrapped matrix.
    pub fn ell(&self) -> &Ell {
        &self.ell
    }

    /// `y = A·x` on the XLA executable.
    pub fn spmv(&self, x: &[Value], y: &mut [Value]) -> Result<()> {
        assert_eq!(x.len(), self.ell.n_cols(), "x length");
        assert_eq!(y.len(), self.ell.n_rows(), "y length");
        self.rt.ell_spmv(
            self.ell.n_rows(),
            self.ell.bandwidth,
            &self.ell.values,
            &self.col_idx_i32,
            x,
            y,
        )
    }
}

/// Feature-off stub of the PJRT executable cache: the same public
/// surface, but [`XlaRuntime::new`] fails with a build-configuration
/// error after validating the manifest, so every caller (the XLA
/// service, the artifact tests) degrades to its manifest-missing /
/// runtime-unavailable path instead of failing to compile.
#[cfg(not(feature = "xla"))]
pub struct XlaRuntime {
    manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    /// Validate the artifact directory, then fail: executing artifacts
    /// requires building with the `xla` cargo feature (and its git
    /// dependency).
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let _ = Manifest::load(artifact_dir)?;
        anyhow::bail!(
            "artifacts present at {} but spmv-at was built without the `xla` cargo \
             feature; rebuild with `--features xla` (requires the git-only `xla` crate — \
             see docs/ARCHITECTURE.md) to execute them",
            artifact_dir.display()
        )
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (built without the `xla` feature)".into()
    }

    /// Number of compiled executables currently cached (always 0 here).
    pub fn compiled_count(&self) -> usize {
        0
    }

    /// Unavailable without the `xla` feature.
    pub fn ell_spmv(
        &self,
        _n_rows: usize,
        _bandwidth: usize,
        _values: &[Value],
        _col_idx_i32: &[i32],
        _x: &[Value],
        _y: &mut [Value],
    ) -> Result<()> {
        anyhow::bail!("built without the `xla` feature")
    }
}

/// Feature-off stub of the XLA-backed ELL kernel; construction fails.
#[cfg(not(feature = "xla"))]
pub struct EllXlaKernel<'rt> {
    #[allow(dead_code)]
    rt: &'rt XlaRuntime,
    ell: Ell,
}

#[cfg(not(feature = "xla"))]
impl<'rt> EllXlaKernel<'rt> {
    /// Unavailable without the `xla` feature.
    pub fn new(rt: &'rt XlaRuntime, ell: Ell) -> Result<Self> {
        let _ = (rt, &ell);
        anyhow::bail!("built without the `xla` feature")
    }

    /// The wrapped matrix.
    pub fn ell(&self) -> &Ell {
        &self.ell
    }

    /// Unavailable without the `xla` feature.
    pub fn spmv(&self, _x: &[Value], _y: &mut [Value]) -> Result<()> {
        anyhow::bail!("built without the `xla` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, lines: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), lines).unwrap();
    }

    #[test]
    fn manifest_parse_and_bucket_selection() {
        let dir = std::env::temp_dir().join("spmv_at_manifest_test");
        write_manifest(
            &dir,
            "# comment\nell_spmv\t1024\t8\ta.hlo.txt\nell_spmv\t1024\t32\tb.hlo.txt\nell_spmv\t8192\t8\tc.hlo.txt\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 3);
        let b = m.bucket_for("ell_spmv", 1000, 6).unwrap();
        assert_eq!((b.rows, b.bandwidth), (1024, 8));
        let b = m.bucket_for("ell_spmv", 1000, 20).unwrap();
        assert_eq!((b.rows, b.bandwidth), (1024, 32));
        let b = m.bucket_for("ell_spmv", 5000, 8).unwrap();
        assert_eq!((b.rows, b.bandwidth), (8192, 8));
        assert!(m.bucket_for("ell_spmv", 100_000, 8).is_none());
        assert!(m.bucket_for("coo_spmv", 10, 1).is_none());
        assert_eq!(m.buckets("ell_spmv").len(), 3);
    }

    #[test]
    fn manifest_rejects_bad_lines() {
        let dir = std::env::temp_dir().join("spmv_at_manifest_bad");
        write_manifest(&dir, "ell_spmv\t1024\n");
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, "");
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, "ell_spmv\tx\t8\ta.hlo.txt\n");
        assert!(Manifest::load(&dir).is_err());
    }

    // End-to-end XLA execution tests live in rust/tests/runtime_xla.rs and
    // run only when `make artifacts` has produced real HLO files.
}
