//! Matrix I/O: MatrixMarket text format and a fast binary cache.
//!
//! MatrixMarket (`.mtx`) is the interchange format of the UF collection the
//! paper draws its suite from; supporting it means real downloaded matrices
//! drop straight into the auto-tuner. The binary cache exists because
//! re-parsing multi-million-entry text files dominates bench startup.

use crate::formats::{Csr, SparseMatrix};
use crate::{Result, Value};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Symmetry field of a MatrixMarket header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MmSymmetry {
    /// `general` — entries stored as-is.
    General,
    /// `symmetric` — lower triangle stored; mirror on read.
    Symmetric,
    /// `skew-symmetric` — mirror with negation.
    SkewSymmetric,
}

/// Parse a MatrixMarket coordinate file into CSR.
///
/// Supports `matrix coordinate real/integer/pattern` with
/// `general/symmetric/skew-symmetric` symmetry. Pattern entries get value
/// 1.0. Complex matrices are rejected.
pub fn read_matrix_market<R: Read>(reader: R) -> Result<Csr> {
    let mut lines = BufReader::new(reader).lines();
    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty MatrixMarket file"))??;
    let h: Vec<String> = header.split_whitespace().map(|s| s.to_ascii_lowercase()).collect();
    anyhow::ensure!(
        h.len() >= 5 && h[0] == "%%matrixmarket" && h[1] == "matrix",
        "bad MatrixMarket header: {header}"
    );
    anyhow::ensure!(h[2] == "coordinate", "only coordinate format supported, got {}", h[2]);
    let pattern = match h[3].as_str() {
        "real" | "integer" => false,
        "pattern" => true,
        other => anyhow::bail!("unsupported field type: {other}"),
    };
    let symmetry = match h[4].as_str() {
        "general" => MmSymmetry::General,
        "symmetric" => MmSymmetry::Symmetric,
        "skew-symmetric" => MmSymmetry::SkewSymmetric,
        other => anyhow::bail!("unsupported symmetry: {other}"),
    };

    // Skip comments, read size line.
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(line);
        break;
    }
    let size_line = size_line.ok_or_else(|| anyhow::anyhow!("missing size line"))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("bad size line '{size_line}': {e}"))?;
    anyhow::ensure!(dims.len() == 3, "size line must be 'rows cols nnz', got '{size_line}'");
    let (n_rows, n_cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut triplets: Vec<(usize, usize, Value)> = Vec::with_capacity(nnz * 2);
    let mut seen = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("short entry line"))?
            .parse()?;
        let c: usize = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("short entry line"))?
            .parse()?;
        let v: Value = if pattern {
            1.0
        } else {
            it.next()
                .ok_or_else(|| anyhow::anyhow!("missing value on entry line"))?
                .parse()?
        };
        anyhow::ensure!(
            (1..=n_rows).contains(&r) && (1..=n_cols).contains(&c),
            "entry ({r},{c}) out of bounds {n_rows}x{n_cols}"
        );
        let (r, c) = (r - 1, c - 1); // 1-based -> 0-based
        triplets.push((r, c, v));
        match symmetry {
            MmSymmetry::General => {}
            MmSymmetry::Symmetric if r != c => triplets.push((c, r, v)),
            MmSymmetry::SkewSymmetric if r != c => triplets.push((c, r, -v)),
            _ => {}
        }
        seen += 1;
    }
    anyhow::ensure!(seen == nnz, "expected {nnz} entries, found {seen}");
    Csr::from_triplets(n_rows, n_cols, &triplets)
}

/// Read a `.mtx` file from disk.
pub fn read_matrix_market_file(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    read_matrix_market(f)
}

/// Write CSR as MatrixMarket `coordinate real general`.
pub fn write_matrix_market<W: Write>(a: &Csr, writer: W) -> Result<()> {
    let mut w = BufWriter::new(writer);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by spmv-at")?;
    writeln!(w, "{} {} {}", a.n_rows(), a.n_cols(), a.nnz())?;
    for i in 0..a.n_rows() {
        for (c, v) in a.row(i) {
            writeln!(w, "{} {} {:.17e}", i + 1, c as usize + 1, v)?;
        }
    }
    Ok(())
}

/// Write a `.mtx` file to disk.
pub fn write_matrix_market_file(a: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("create {}: {e}", path.display()))?;
    write_matrix_market(a, f)
}

const BIN_MAGIC: &[u8; 8] = b"SPMVATB1";

/// Serialize CSR to the fast binary cache format (little-endian).
pub fn write_binary<W: Write>(a: &Csr, writer: W) -> Result<()> {
    let mut w = BufWriter::new(writer);
    w.write_all(BIN_MAGIC)?;
    for v in [a.n_rows() as u64, a.n_cols() as u64, a.nnz() as u64] {
        w.write_all(&v.to_le_bytes())?;
    }
    for &p in &a.row_ptr {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &c in &a.col_idx {
        w.write_all(&c.to_le_bytes())?;
    }
    for &v in &a.values {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Deserialize CSR from the binary cache format.
pub fn read_binary<R: Read>(reader: R) -> Result<Csr> {
    let mut r = BufReader::new(reader);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == BIN_MAGIC, "bad magic: not an spmv-at binary matrix");
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<R>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let n_rows = read_u64(&mut r)? as usize;
    let n_cols = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;
    let mut row_ptr = Vec::with_capacity(n_rows + 1);
    let mut b8 = [0u8; 8];
    for _ in 0..=n_rows {
        r.read_exact(&mut b8)?;
        row_ptr.push(u64::from_le_bytes(b8) as usize);
    }
    let mut col_idx = Vec::with_capacity(nnz);
    let mut b4 = [0u8; 4];
    for _ in 0..nnz {
        r.read_exact(&mut b4)?;
        col_idx.push(u32::from_le_bytes(b4));
    }
    let mut values = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        r.read_exact(&mut b8)?;
        values.push(f64::from_le_bytes(b8));
    }
    Csr::new(n_rows, n_cols, row_ptr, col_idx, values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;

    #[test]
    fn mtx_roundtrip_general() {
        let mut rng = Rng::new(1);
        let a = random_csr(&mut rng, 20, 15, 0.15);
        let mut buf = Vec::new();
        write_matrix_market(&a, &mut buf).unwrap();
        let b = read_matrix_market(&buf[..]).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn mtx_symmetric_mirrors() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n3 3 3\n1 1 2.0\n2 1 -1.0\n3 3 4.0\n";
        let a = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(a.nnz(), 4); // (0,0),(1,0),(0,1),(2,2)
        let t = a.to_triplets();
        assert!(t.contains(&(0, 1, -1.0)));
        assert!(t.contains(&(1, 0, -1.0)));
    }

    #[test]
    fn mtx_skew_symmetric_negates() {
        let text =
            "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n2 1 3.0\n";
        let a = read_matrix_market(text.as_bytes()).unwrap();
        let t = a.to_triplets();
        assert!(t.contains(&(1, 0, 3.0)));
        assert!(t.contains(&(0, 1, -3.0)));
    }

    #[test]
    fn mtx_pattern_gets_unit_values() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 1\n2 2\n";
        let a = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(a.values, vec![1.0, 1.0]);
    }

    #[test]
    fn mtx_rejects_garbage() {
        assert!(read_matrix_market("not a header\n".as_bytes()).is_err());
        assert!(read_matrix_market(
            "%%MatrixMarket matrix array real general\n2 2\n".as_bytes()
        )
        .is_err());
        assert!(read_matrix_market(
            "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1 0\n".as_bytes()
        )
        .is_err());
        // Entry count mismatch.
        assert!(read_matrix_market(
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n".as_bytes()
        )
        .is_err());
        // Out of bounds entry.
        assert!(read_matrix_market(
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n".as_bytes()
        )
        .is_err());
    }

    #[test]
    fn binary_roundtrip() {
        let mut rng = Rng::new(2);
        let a = random_csr(&mut rng, 33, 47, 0.1);
        let mut buf = Vec::new();
        write_binary(&a, &mut buf).unwrap();
        let b = read_binary(&buf[..]).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn binary_rejects_bad_magic() {
        assert!(read_binary(&b"XXXXXXXXrest"[..]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(3);
        let a = random_csr(&mut rng, 10, 10, 0.3);
        let dir = std::env::temp_dir().join("spmv_at_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.mtx");
        write_matrix_market_file(&a, &p).unwrap();
        let b = read_matrix_market_file(&p).unwrap();
        assert_eq!(a, b);
        std::fs::remove_file(&p).ok();
    }
}
