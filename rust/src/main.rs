//! `spmv-at` — CLI entry point for the run-time sparse-transformation
//! auto-tuning library.
//!
//! Subcommands:
//!
//! * `suite` — print the Table-1 synthetic matrix suite (spec vs generated).
//! * `offline` — run the offline AT phase on a backend, write the tuning
//!   table (the "library install" step).
//! * `decide` — run the online phase for one matrix against a tuning table.
//! * `spmv` — run SpMV through an `OpenATI_DURMV`-style switch.
//! * `solve` — solve a generated system through the AT-routed coordinator.
//! * `serve` — line-oriented REPL over the coordinator server; with
//!   `--listen` also a network front end (Unix socket or TCP) speaking
//!   the framed binary protocol of `docs/PROTOCOL.md`, with
//!   cross-request batch coalescing.
//! * `topology` — print the detected socket/core layout and the shard
//!   plan derived from it (NUMA observability).
//!
//! The CLI is dependency-free (no clap in the offline environment): flags
//! are `--key value` pairs parsed by [`Args`].

use anyhow::{anyhow, bail, ensure, Result};
use spmv_at::autotune::adaptive::LearnedTuning;
use spmv_at::autotune::atlib::{switches, Durmv};
use spmv_at::autotune::online::TuningData;
use spmv_at::autotune::{run_offline, MemoryPolicy, OfflineConfig};
use spmv_at::coordinator::{Coordinator, CoordinatorConfig, Server, SolverKind, SplitThreshold};
use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{Backend, MeasuredBackend, SimulatedBackend};
use spmv_at::matrixgen::{generate, measure, spec_by_name, table1_specs};
use spmv_at::metrics::Table;
use spmv_at::solver::SolverOptions;
use spmv_at::spmv::pool::configured_threads;
use spmv_at::spmv::Implementation;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Tiny `--key value` flag parser.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{a}'"))?;
            let val = it
                .next()
                .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn parse_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    fn parse_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    /// `--key 0|1|true|false|on|off`; `None` when the flag is absent (so
    /// the environment default applies).
    fn parse_bool(&self, key: &str) -> Result<Option<bool>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "on" | "yes" => Ok(Some(true)),
                "0" | "false" | "off" | "no" => Ok(Some(false)),
                other => Err(anyhow!("--{key}: expected 0/1, got '{other}'")),
            },
        }
    }
}

/// Apply `--split-rows` (overriding `SPMV_AT_SPLIT_ROWS`) to the config.
/// Since every serving loop sees all the shards, the threshold engages
/// in whatever serving shape runs it — no shape opt-in involved.
fn apply_split_flag(args: &Args, cfg: &mut CoordinatorConfig) -> Result<()> {
    if let Some(v) = args.get("split-rows") {
        cfg.split = SplitThreshold::parse(v)
            .ok_or_else(|| anyhow!("--split-rows: expected 0, a positive integer, or 'auto'"))?;
    }
    Ok(())
}

/// Apply `--precond` (overriding `SPMV_AT_PRECOND`) to the config — the
/// preconditioner `solve` requests build and cache per served entry.
fn apply_precond_flag(args: &Args, cfg: &mut CoordinatorConfig) -> Result<()> {
    if let Some(v) = args.get("precond") {
        cfg.precond = spmv_at::precond::PrecondKind::parse(v)
            .ok_or_else(|| anyhow!("--precond: expected none, jacobi, or symgs"))?;
    }
    Ok(())
}

/// Apply `--partition` (overriding `SPMV_AT_PARTITION`): the intra-pool
/// work-partition strategy CRS plans split their rows (or, for
/// merge-path, their row+nnz merge list) with — `even`, `nnz`, `merge`,
/// or `auto` (the row-length-skew pick). Routed through the environment
/// variable that plan assembly reads, so every serving shape — Durmv
/// handles, coordinators, shard planners — honours it.
fn apply_partition_flag(args: &Args) -> Result<()> {
    if let Some(v) = args.get("partition") {
        let canon = match v.to_ascii_lowercase().as_str() {
            "auto" => "auto",
            other => spmv_at::spmv::partition::PartitionStrategy::parse(other)
                .ok_or_else(|| anyhow!("--partition: expected even, nnz, merge, or auto"))?
                .name(),
        };
        // Single-threaded at flag-parse time, so setenv cannot race a getenv.
        std::env::set_var("SPMV_AT_PARTITION", canon);
    }
    Ok(())
}

fn make_backend(name: &str) -> Result<Box<dyn Backend>> {
    Ok(match name {
        "es2" => Box::new(SimulatedBackend::new(VectorMachine::default())),
        "sr16000" => Box::new(SimulatedBackend::new(ScalarMachine::default())),
        "host" => Box::new(MeasuredBackend::default()),
        other => bail!("unknown backend '{other}' (es2 | sr16000 | host)"),
    })
}

/// Load a matrix: `--matrix <table1-name>` (generated) or `--mtx <file>`.
fn load_matrix(args: &Args, seed: u64, scale: f64) -> Result<(String, Csr)> {
    if let Some(name) = args.get("matrix") {
        let spec = spec_by_name(name)
            .ok_or_else(|| anyhow!("'{name}' is not a Table-1 matrix name"))?;
        Ok((name.to_string(), generate(&spec, seed, scale)))
    } else if let Some(path) = args.get("mtx") {
        let csr = spmv_at::io::read_matrix_market_file(Path::new(path))?;
        Ok((path.to_string(), csr))
    } else {
        bail!("need --matrix <table1-name> or --mtx <file.mtx>")
    }
}

fn cmd_suite(args: &Args) -> Result<()> {
    let scale = args.parse_f64("scale", 0.05)?;
    let seed = args.parse_usize("seed", 42)? as u64;
    let mut t = Table::new(vec![
        "no", "name", "N", "NNZ", "mu", "sigma", "D_mat", "gen_mu", "gen_sigma", "gen_D",
    ]);
    for spec in table1_specs() {
        let a = generate(&spec, seed, scale);
        let m = measure(&a);
        t.row(vec![
            spec.no.to_string(),
            spec.name.to_string(),
            m.n.to_string(),
            m.nnz.to_string(),
            format!("{:.2}", spec.mu),
            format!("{:.2}", spec.sigma),
            format!("{:.2}", spec.d_mat),
            format!("{:.2}", m.mu),
            format!("{:.2}", m.sigma),
            format!("{:.2}", m.d_mat),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_offline(args: &Args) -> Result<()> {
    let backend = make_backend(&args.get_or("backend", "es2"))?;
    let scale = args.parse_f64("scale", 0.05)?;
    let seed = args.parse_usize("seed", 42)? as u64;
    let imp = Implementation::parse(&args.get_or("imp", "ell-row-outer"))
        .ok_or_else(|| anyhow!("bad --imp"))?;
    let cfg = OfflineConfig {
        imp,
        threads: args.parse_usize("threads", 1)?,
        c: args.parse_f64("c", 1.0)?,
    };
    let suite: Vec<(String, Csr)> = table1_specs()
        .iter()
        .map(|s| (s.name.to_string(), generate(s, seed, scale)))
        .collect();
    let result = run_offline(backend.as_ref(), &suite, &cfg)?;
    print!("{}", result.graph.render(cfg.c));
    if let Some(fit) = result.graph.fit_power_law() {
        println!(
            "power-law fit: R ~= {:.3} * D^{:.3} (R2 = {:.3}), model threshold {:.3}",
            fit.a,
            fit.b,
            fit.r2,
            fit.threshold(cfg.c)
        );
    }
    if let Some(out) = args.get("out") {
        result.tuning_data().save(Path::new(out))?;
        println!("tuning table written to {out}");
    }
    if let Some(json) = args.get("json") {
        std::fs::write(json, result.to_json().render())?;
        println!("json written to {json}");
    }
    Ok(())
}

fn load_tuning(args: &Args) -> Result<TuningData> {
    match args.get("tuning") {
        Some(path) => TuningData::load(Path::new(path)),
        None => Ok(TuningData {
            backend: "default:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        }),
    }
}

fn cmd_decide(args: &Args) -> Result<()> {
    let tuning = load_tuning(args)?;
    let scale = args.parse_f64("scale", 0.05)?;
    let (name, a) = load_matrix(args, args.parse_usize("seed", 42)? as u64, scale)?;
    let d = spmv_at::autotune::decide(&a, &tuning);
    println!(
        "matrix={name} n={} nnz={} D_mat={:.4} D*={:.4} -> {} ({})",
        a.n_rows(),
        a.nnz(),
        d.d_mat,
        d.d_star,
        if d.transform { "TRANSFORM" } else { "keep CRS" },
        d.chosen
    );
    Ok(())
}

fn cmd_spmv(args: &Args) -> Result<()> {
    let tuning = load_tuning(args)?;
    let scale = args.parse_f64("scale", 0.05)?;
    let (name, a) = load_matrix(args, args.parse_usize("seed", 42)? as u64, scale)?;
    let switch: u32 = args.get_or("switch", "0").parse()?;
    // SPMV_AT_PARTITION (default: skew pick) unless --partition overrides.
    apply_partition_flag(args)?;
    let iters = args.parse_usize("iters", 10)?;
    // Batch width: >1 serves each iteration as one tiled SpMM.
    let batch = args.parse_usize("batch", 1)?.max(1);
    // SPMV_AT_THREADS (or hardware parallelism) unless --threads overrides.
    let threads = args.parse_usize("threads", configured_threads())?;
    let n = a.n_rows();
    let ncols = a.n_cols();
    let mut h = Durmv::new(a, tuning, MemoryPolicy::unlimited(), threads);
    if switch == switches::AUTO {
        println!("AUTO choice: {}", h.auto_choice());
    }
    let checksum;
    let dt;
    if batch > 1 {
        let xs: Vec<Vec<f64>> = (0..batch)
            .map(|j| (0..ncols).map(|i| 1.0 + ((i + j) % 5) as f64 * 0.25).collect())
            .collect();
        let mut ys = vec![vec![0.0; n]; batch];
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            h.durmv_many(switch, &xs, &mut ys)?;
        }
        dt = t0.elapsed().as_secs_f64();
        checksum = ys.iter().flatten().sum::<f64>();
    } else {
        let x = vec![1.0; ncols];
        let mut y = vec![0.0; n];
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            h.durmv(switch, &x, &mut y)?;
        }
        dt = t0.elapsed().as_secs_f64();
        checksum = y.iter().sum::<f64>();
    }
    println!(
        "matrix={name} switch={switch} iters={iters} batch={batch} total={:.4}s per-spmv={:.6}s transform={:.6}s checksum={:.6e}",
        dt,
        dt / (iters * batch) as f64,
        h.transform_seconds,
        checksum
    );
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let tuning = load_tuning(args)?;
    let scale = args.parse_f64("scale", 0.05)?;
    let (name, a0) = load_matrix(args, args.parse_usize("seed", 42)? as u64, scale)?;
    ensure!(a0.n_rows() == a0.n_cols(), "solve needs a square matrix");
    // Make the system solvable: SPD for cg/jacobi, dominant for the rest.
    let a = spmv_at::matrixgen::make_spd(&a0);
    let n = a.n_rows();
    let solver = SolverKind::parse(&args.get_or("solver", "cg"))
        .ok_or_else(|| anyhow!("bad --solver"))?;
    let mut cfg = CoordinatorConfig::new(tuning);
    cfg.threads = args.parse_usize("threads", configured_threads())?;
    // SPMV_AT_SHARDS (default: detected socket count) unless --shards overrides.
    cfg.shards = args.parse_usize("shards", cfg.shards)?;
    // SPMV_AT_ADAPTIVE (default off) unless --adaptive overrides.
    if let Some(on) = args.parse_bool("adaptive")? {
        cfg.adaptive.enabled = on;
    }
    // SPMV_AT_SPLIT_ROWS unless --split-rows overrides.
    apply_split_flag(args, &mut cfg)?;
    // SPMV_AT_PRECOND (default jacobi) unless --precond overrides.
    apply_precond_flag(args, &mut cfg)?;
    // SPMV_AT_PARTITION (default: skew pick) unless --partition overrides.
    apply_partition_flag(args)?;
    let (_srv, client) = Server::spawn_sharded(cfg, 32);
    client.register(&name, a)?;
    let b = vec![1.0; n];
    let opts = SolverOptions {
        tol: args.parse_f64("tol", 1e-8)?,
        max_iters: args.parse_usize("max-iters", 2000)?,
    };
    let t0 = std::time::Instant::now();
    let (x, stats) = client.solve(&name, b, solver, opts)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "matrix={name} solver={solver:?} iters={} converged={} residual={:.3e} spmv_calls={} precond_calls={} precond_setup={:.6}s wall={:.4}s |x|={:.6e}",
        stats.iterations,
        stats.converged,
        stats.residual,
        stats.spmv_calls,
        stats.precond_calls,
        stats.precond_setup_seconds,
        dt,
        x.iter().map(|v| v * v).sum::<f64>().sqrt()
    );
    for row in client.stats()? {
        let split = if row.split_parts > 0 {
            format!(" split=blocks:{}/calls:{}", row.split_parts, row.split_calls)
        } else {
            String::new()
        };
        let precond = match row.precond {
            Some(p) => {
                format!(
                    " precond={p}/calls:{}/setup:{:.6}s",
                    row.precond_calls, row.precond_setup_seconds
                )
            }
            None => String::new(),
        };
        println!(
            "  serving={} partition={} calls={} transformed_calls={} t_trans={:.6}s \
             amortized={} explored={} replans={}{precond}{split}",
            row.serving,
            row.partition,
            row.calls,
            row.transformed_calls,
            row.t_trans,
            row.amortized,
            row.explored,
            row.replans
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::io::BufRead;
    let mut tuning = load_tuning(args)?;
    // --learned <path>: start from a learned v2 table (reads v1 too) and
    // save the corrections back on quit, closing the persistence loop.
    let learned_path = args.get("learned").map(PathBuf::from);
    let mut preloaded = None;
    if let Some(p) = &learned_path {
        if p.exists() {
            let lt = LearnedTuning::load(p)?;
            println!(
                "# learned table loaded from {} ({} corrected bucket(s))",
                p.display(),
                lt.corrected_buckets()
            );
            tuning = lt.base.clone();
            preloaded = Some(lt);
        }
    }
    let mut cfg = CoordinatorConfig::new(tuning);
    // Every shard coordinator starts from the same snapshot; the quit-time
    // merge folds in only each shard's delta beyond it.
    let preload_snapshot = preloaded.clone();
    cfg.learned = preloaded;
    cfg.threads = args.parse_usize("threads", configured_threads())?;
    // SPMV_AT_SHARDS (default: detected socket count) unless --shards overrides.
    cfg.shards = args.parse_usize("shards", cfg.shards)?;
    // SPMV_AT_ADAPTIVE (default off) unless --adaptive overrides.
    if let Some(on) = args.parse_bool("adaptive")? {
        cfg.adaptive.enabled = on;
    }
    // SPMV_AT_SPLIT_ROWS unless --split-rows overrides.
    apply_split_flag(args, &mut cfg)?;
    // SPMV_AT_PRECOND (default jacobi) unless --precond overrides.
    apply_precond_flag(args, &mut cfg)?;
    // SPMV_AT_PARTITION (default: skew pick) unless --partition overrides.
    apply_partition_flag(args)?;
    // --decision-log <path>: append every serving decision (register,
    // transform, flip, replan, split, split veto) as JSONL. The
    // in-memory ring — and with it the DecisionLog wire request — is
    // always on; the flag only adds the append-only file.
    let decision_log = match args.get("decision-log") {
        Some(p) => spmv_at::coordinator::DecisionLog::to_path(Path::new(p))?,
        None => spmv_at::coordinator::DecisionLog::in_memory(),
    };
    cfg.decision_log = Some(decision_log.clone());
    if let Some(p) = decision_log.path() {
        println!("# decision log appending to {}", p.display());
    }
    // Attach XLA runtime if artifacts exist (XLA serving is single-loop:
    // the artifact handle is not shared across shard coordinators).
    let art = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut _xla_service = None;
    let adaptive_on = cfg.adaptive.enabled;
    let effective =
        spmv_at::coordinator::shards::shard_thread_counts(cfg.threads, cfg.shards).len();
    let (srv, client) = if art.join("manifest.tsv").exists() {
        let mut coord = Coordinator::new(cfg);
        match spmv_at::runtime::XlaService::spawn(art) {
            Ok((svc, handle)) => {
                println!(
                    "# XLA runtime attached ({})",
                    handle.platform().unwrap_or_else(|_| "?".into())
                );
                coord = coord.with_xla(handle);
                _xla_service = Some(svc);
            }
            Err(e) => println!("# XLA runtime unavailable: {e}"),
        }
        Server::spawn(coord, 64)
    } else {
        let topo = spmv_at::machine::Topology::detect();
        println!(
            "# serving {} shard(s) over {} socket(s), {} thread(s), adaptive={}, split-rows {}",
            effective,
            topo.n_sockets(),
            cfg.threads,
            if adaptive_on { "on" } else { "off" },
            cfg.split
        );
        Server::spawn_sharded(cfg, 64)
    };
    // --listen (or SPMV_AT_LISTEN): put the network front end in front of
    // the serving loops. The REPL keeps running alongside it; on stdin
    // EOF a listening server keeps serving until killed.
    let listen_spec = args
        .get("listen")
        .map(str::to_string)
        .or_else(|| std::env::var("SPMV_AT_LISTEN").ok());
    enum Serving {
        Local(Server),
        Net(spmv_at::net::NetServer),
    }
    let serving = match &listen_spec {
        None => Serving::Local(srv),
        Some(spec) => {
            let addr = spmv_at::net::parse_listen(spec)?;
            let net_cfg = spmv_at::net::NetConfig {
                decision_log: Some(decision_log.clone()),
                ..spmv_at::net::NetConfig::default()
            };
            let net = spmv_at::net::NetServer::start(srv, client.clone(), &addr, net_cfg)?;
            println!(
                "# listening on {} (protocol v{}..v{}, docs/PROTOCOL.md)",
                net.local_addr(),
                spmv_at::net::proto::MIN_VERSION,
                spmv_at::net::proto::VERSION
            );
            Serving::Net(net)
        }
    };
    println!("# commands: register <name> <table1-name> [scale] | spmv <name> | spmm <name> <batch> | stats | netstats | decisions [n] | replan <name> | evict <name> | quit");
    let stdin = std::io::stdin();
    let mut explicit_quit = false;
    for line in stdin.lock().lines() {
        let line = line?;
        let parts: Vec<&str> = line.split_whitespace().collect();
        match parts.as_slice() {
            [] => {}
            ["quit"] | ["exit"] => {
                explicit_quit = true;
                break;
            }
            ["register", name, spec_name, rest @ ..] => {
                let scale: f64 = rest.first().unwrap_or(&"0.05").parse().unwrap_or(0.05);
                match spec_by_name(spec_name) {
                    None => println!("! unknown spec {spec_name}"),
                    Some(spec) => {
                        let a = generate(&spec, 42, scale);
                        match client.register(name, a) {
                            Ok(s) => println!("ok n={} nnz={} D_mat={:.4}", s.n, s.nnz, s.d_mat),
                            Err(e) => println!("! {e}"),
                        }
                    }
                }
            }
            ["spmv", name] => {
                match client.stats()?.iter().find(|s| &s.name == name) {
                    None => println!("! unknown matrix {name}"),
                    Some(s) => {
                        let x = vec![1.0; s.n];
                        match client.spmv(name, x) {
                            Ok(y) => println!("ok checksum={:.6e}", y.iter().sum::<f64>()),
                            Err(e) => println!("! {e}"),
                        }
                    }
                }
            }
            ["spmm", name, batch] => {
                let k: usize = batch.parse().unwrap_or(0);
                match client.stats()?.iter().find(|s| &s.name == name) {
                    None => println!("! unknown matrix {name}"),
                    Some(_) if k == 0 => println!("! batch must be a positive integer"),
                    Some(s) => {
                        let xs: Vec<Vec<f64>> = (0..k)
                            .map(|j| {
                                (0..s.n).map(|i| 1.0 + ((i + j) % 5) as f64 * 0.25).collect()
                            })
                            .collect();
                        match client.spmv_batch(name, xs) {
                            Ok(ys) => println!(
                                "ok batch={k} checksum={:.6e}",
                                ys.iter().flatten().sum::<f64>()
                            ),
                            Err(e) => println!("! {e}"),
                        }
                    }
                }
            }
            ["stats"] => {
                for s in client.stats()? {
                    // Split-served entries show their block count and how
                    // many calls the split served.
                    let split = if s.split_parts > 0 {
                        format!(" split=blocks:{}/calls:{}", s.split_parts, s.split_calls)
                    } else {
                        String::new()
                    };
                    // Solver traffic shows its cached preconditioner and
                    // how much work it amortised.
                    let precond = match s.precond {
                        Some(p) => format!(
                            " precond={p}/calls:{}/setup:{:.6}s",
                            s.precond_calls, s.precond_setup_seconds
                        ),
                        None => String::new(),
                    };
                    // Every loop sees all the shards, so the entry's own
                    // shard field is the serving route in every shape.
                    println!(
                        "{}: n={} nnz={} D={:.3} shard={} serving={} partition={} calls={} \
                         passes={} amortized={} samples=crs:{}/imp:{} explored={} \
                         replans={}{precond}{split}",
                        s.name,
                        s.n,
                        s.nnz,
                        s.d_mat,
                        s.shard,
                        s.serving,
                        s.partition,
                        s.calls,
                        s.matrix_passes,
                        s.amortized,
                        s.samples_crs,
                        s.samples_imp,
                        s.explored,
                        s.replans
                    );
                }
            }
            ["netstats"] => match &serving {
                Serving::Local(_) => println!("! no network front end (start with --listen)"),
                Serving::Net(net) => {
                    let s = net.counters().snapshot();
                    println!(
                        "sessions={}/{} batches={} requests={} coalesced={}/{} rejects={} \
                         sheds={} max_batch={} factor={:.2}",
                        s.sessions_open,
                        s.sessions_total,
                        s.batches,
                        s.requests,
                        s.coalesced_batches,
                        s.coalesced_requests,
                        s.admission_rejects,
                        s.deadline_sheds,
                        s.max_batch,
                        net.counters().coalescing_factor()
                    );
                }
            },
            ["decisions", rest @ ..] => {
                let n: usize = rest.first().and_then(|v| v.parse().ok()).unwrap_or(20);
                let lines = decision_log.tail(n);
                if lines.is_empty() {
                    println!("# no serving decisions recorded yet");
                }
                for l in lines {
                    println!("{l}");
                }
            }
            ["replan", name] => match client.replan(name) {
                Ok(s) => println!("ok serving={} replans={}", s.serving, s.replans),
                Err(e) => println!("! {e}"),
            },
            ["evict", name] => {
                println!("{}", if client.evict(name)? { "ok" } else { "! not found" });
            }
            other => println!("! unknown command {other:?}"),
        }
    }
    let coords = match serving {
        Serving::Local(srv) => srv.shutdown_all(),
        Serving::Net(net) => {
            if !explicit_quit {
                // stdin closed without a quit: a listening server is a
                // daemon, so keep serving until the process is killed.
                println!("# stdin closed; serving on {} until killed", net.local_addr());
                loop {
                    std::thread::park();
                }
            }
            net.shutdown()
        }
    };
    if let Some(p) = &learned_path {
        // Merge what every shard coordinator learned beyond the shared
        // preloaded snapshot and persist it as v2 (a plain merge would
        // count the preload once per shard).
        let Some(first) = coords.first() else { return Ok(()) };
        let base = preload_snapshot
            .unwrap_or_else(|| LearnedTuning::new(first.learned().base.clone()));
        let shard_tables: Vec<&LearnedTuning> = coords.iter().map(|c| c.learned()).collect();
        let merged = base.merge_deltas(&shard_tables);
        merged.save(p)?;
        println!(
            "# learned table saved to {} ({} corrected bucket(s))",
            p.display(),
            merged.corrected_buckets()
        );
    }
    Ok(())
}

fn cmd_topology(args: &Args) -> Result<()> {
    use spmv_at::coordinator::shards::{configured_shards, shard_thread_counts};
    use spmv_at::machine::topology::{Topology, TopologySource};
    let topo = Topology::detect();
    let source = match topo.source() {
        TopologySource::Override => "SPMV_AT_TOPOLOGY override",
        TopologySource::Sysfs => "sysfs NUMA tree",
        TopologySource::Flat => "flat fallback (no NUMA info)",
    };
    println!("topology source: {source}");
    println!("sockets: {}  cpus: {}", topo.n_sockets(), topo.n_cpus());
    let mut t = Table::new(vec!["socket", "cpus"]);
    for i in 0..topo.n_sockets() {
        let cpus: Vec<String> = topo.cpus(i).iter().map(usize::to_string).collect();
        t.row(vec![i.to_string(), cpus.join(",")]);
    }
    print!("{}", t.render());
    let threads = args.parse_usize("threads", configured_threads())?;
    let shards = args.parse_usize("shards", configured_shards())?;
    let counts = shard_thread_counts(threads, shards);
    println!(
        "shard plan: {} shard(s) over {} thread(s) -> widths {:?}{}",
        counts.len(),
        threads,
        counts,
        if topo.n_sockets() > 1 {
            " (each pinned to socket i mod sockets)"
        } else {
            " (single socket: unpinned)"
        }
    );
    let split = SplitThreshold::from_env();
    println!(
        "auto-split threshold: {split}{}",
        if counts.len() > 1 {
            ""
        } else {
            " (inactive: single shard)"
        }
    );
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: spmv-at <suite|offline|decide|spmv|solve|serve|topology> [--flag value]...\n\
         flags (solve/serve):\n\
         \x20 --adaptive 0|1   adaptive runtime autotuner: online telemetry, budgeted\n\
         \x20                  exploration, hysteresis-guarded re-planning\n\
         \x20                  (overrides the SPMV_AT_ADAPTIVE environment variable)\n\
         \x20 --learned <path> (serve) start from a learned v2 tuning table and save\n\
         \x20                  the per-D_mat-bucket corrections back on quit\n\
         \x20 --shards <n>     pool shards (default: SPMV_AT_SHARDS, else the machine's\n\
         \x20                  socket count; each shard pins to one socket and plans\n\
         \x20                  first-touch their data there)\n\
         \x20 --split-rows <n> route matrices with >= n rows through a cached\n\
         \x20                  cross-shard SplitPlan whose row blocks execute\n\
         \x20                  concurrently, one per socket (0 = never, 'auto' = the\n\
         \x20                  nnz-per-socket heuristic; overrides SPMV_AT_SPLIT_ROWS)\n\
         \x20 --precond <kind> preconditioner for pcg solves: none, jacobi, or symgs\n\
         \x20                  (level-scheduled symmetric Gauss-Seidel); built once\n\
         \x20                  and cached per served entry (overrides SPMV_AT_PRECOND)\n\
         \x20 --partition <s>  intra-pool CRS work partition: even, nnz, merge, or\n\
         \x20                  auto (pick merge-path on row-length skew); also applies\n\
         \x20                  to spmv (overrides SPMV_AT_PARTITION)\n\
         \x20 --listen <spec>  (serve) also serve the framed binary protocol over\n\
         \x20                  unix:<path>, tcp:<host>:<port>, or <host>:<port>,\n\
         \x20                  coalescing concurrent single-vector requests into\n\
         \x20                  batches (overrides SPMV_AT_LISTEN; docs/PROTOCOL.md)\n\
         \x20 --decision-log <path> (serve) append every serving decision\n\
         \x20                  (register, transform, flip, replan, split, veto) as\n\
         \x20                  replayable JSONL; the DecisionLog wire request serves\n\
         \x20                  the in-memory tail either way\n\
         environment: SPMV_AT_THREADS, SPMV_AT_SHARDS, SPMV_AT_BATCH_TILE,\n\
         \x20 SPMV_AT_ADAPTIVE, SPMV_AT_SPLIT_ROWS, SPMV_AT_LISTEN,\n\
         \x20 SPMV_AT_PARTITION=even|nnz|merge|auto,\n\
         \x20 SPMV_AT_NET_QUEUE, SPMV_AT_COALESCE_WAIT_US, SPMV_AT_NET_AUTH,\n\
         \x20 SPMV_AT_NET_QUOTA_REQS, SPMV_AT_NET_QUOTA_BYTES, SPMV_AT_NET_PROTO,\n\
         \x20 SPMV_AT_PRECOND=none|jacobi|symgs, SPMV_AT_TRSV_PAR=auto|never|always|<width>,\n\
         \x20 SPMV_AT_TOPOLOGY=<sockets>:<cores> (see docs/TUNING.md)\n\
         examples:\n\
         \x20 spmv-at suite --scale 0.05\n\
         \x20 spmv-at offline --backend es2 --scale 0.05 --out tuning-es2.tsv\n\
         \x20 spmv-at decide --tuning tuning-es2.tsv --matrix memplus\n\
         \x20 spmv-at spmv --matrix chem_master1 --switch 0 --iters 100 --batch 16\n\
         \x20 spmv-at solve --matrix xenon1 --solver cg --adaptive 1\n\
         \x20 spmv-at solve --matrix torso1 --solver pcg --precond symgs\n\
         \x20 spmv-at serve --shards 4 --adaptive 1 --learned learned.tsv\n\
         \x20 spmv-at serve --listen tcp:0.0.0.0:7077\n\
         \x20 spmv-at topology"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "suite" => cmd_suite(&args),
        "offline" => cmd_offline(&args),
        "decide" => cmd_decide(&args),
        "spmv" => cmd_spmv(&args),
        "solve" => cmd_solve(&args),
        "serve" => cmd_serve(&args),
        "topology" => cmd_topology(&args),
        _ => usage(),
    }
}
