//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so the library carries its
//! own small, well-tested generator: SplitMix64 for seeding and
//! xoshiro256++ for the stream. Determinism matters here — the synthetic
//! Table-1 matrix suite must be bit-reproducible across runs so that
//! benchmark rows are comparable.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 stream from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the main PRNG. Fast, high-quality, tiny state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed; state is expanded with SplitMix64 per the
    /// xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift rejection.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let low = m as u64;
            if low >= bound || low >= low.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (the slower but branch-free pair
    /// variant; one draw per call, the mate is discarded for simplicity).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Bernoulli draw with probability `p`.
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        // For small k relative to n use a hash-free rejection over a sorted
        // vec; for large k shuffle a full index vector.
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut picked = Vec::with_capacity(k);
            while picked.len() < k {
                let c = self.range(0, n);
                if let Err(pos) = picked.binary_search(&c) {
                    picked.insert(pos, c);
                }
            }
            picked
        }
    }

    /// Draw from a (rounded, clamped-at-zero) normal with mean `mu` and
    /// standard deviation `sigma` — used for nonzeros-per-row distributions.
    pub fn next_rounded_normal(&mut self, mu: f64, sigma: f64) -> usize {
        let v = mu + sigma * self.next_gaussian();
        v.round().max(0.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the SplitMix64 paper code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x), "{x} out of [0,1)");
        }
    }

    #[test]
    fn next_below_is_in_bounds_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.next_below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c} far from 10k");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(99);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted_paths() {
        let mut r = Rng::new(11);
        for &(n, k) in &[(100usize, 3usize), (100, 80), (10, 10), (1, 1), (5, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut dedup = s.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn streams_differ_by_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
