//! The `D_mat` statistic (paper Eq. 4): `D_mat = σ / μ` over the
//! non-zeros-per-row distribution — the architecture-independent half of
//! the auto-tuning decision. "Computing `D_mat` requires a very low cost"
//! (§4.4): one pass over the row pointer array, no touching of values.

use crate::formats::Csr;

/// Row-length distribution statistics of a sparse matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowStats {
    /// Arithmetic mean μ of non-zeros per row.
    pub mean: f64,
    /// Population standard deviation σ of non-zeros per row.
    pub sigma: f64,
    /// Maximum row length (the ELL bandwidth `nz`).
    pub max_row: usize,
    /// Minimum row length.
    pub min_row: usize,
    /// Number of rows.
    pub n_rows: usize,
}

impl RowStats {
    /// Compute from a CSR matrix — O(n) over `row_ptr` only.
    pub fn of_csr(a: &Csr) -> Self {
        Self::of_row_ptr(&a.row_ptr)
    }

    /// Compute from a raw CSR row-pointer array.
    pub fn of_row_ptr(row_ptr: &[usize]) -> Self {
        let n = row_ptr.len().saturating_sub(1);
        if n == 0 {
            return Self { mean: 0.0, sigma: 0.0, max_row: 0, min_row: 0, n_rows: 0 };
        }
        let mut sum = 0usize;
        let mut sum2 = 0.0f64;
        let mut max_row = 0usize;
        let mut min_row = usize::MAX;
        for w in row_ptr.windows(2) {
            let l = w[1] - w[0];
            sum += l;
            sum2 += (l as f64) * (l as f64);
            max_row = max_row.max(l);
            min_row = min_row.min(l);
        }
        let mean = sum as f64 / n as f64;
        let var = (sum2 / n as f64 - mean * mean).max(0.0);
        Self { mean, sigma: var.sqrt(), max_row, min_row, n_rows: n }
    }

    /// `D_mat = σ / μ` (0 when the matrix is empty).
    pub fn d_mat(&self) -> f64 {
        if self.mean > 0.0 {
            self.sigma / self.mean
        } else {
            0.0
        }
    }

    /// ELL fill ratio `n·max_row / nnz` this distribution implies.
    pub fn fill_ratio(&self) -> f64 {
        let nnz = self.mean * self.n_rows as f64;
        if nnz > 0.0 {
            (self.n_rows * self.max_row) as f64 / nnz
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::{banded_circulant, generate, table1_specs};
    use crate::rng::Rng;

    #[test]
    fn perfect_band_has_zero_dmat() {
        let mut rng = Rng::new(1);
        let a = banded_circulant(&mut rng, 64, &[-1, 0, 1]);
        let s = RowStats::of_csr(&a);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.sigma, 0.0);
        assert_eq!(s.d_mat(), 0.0);
        assert_eq!(s.max_row, 3);
        assert_eq!(s.min_row, 3);
        assert!((s.fill_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dmat_matches_table1_for_generated_suite() {
        for spec in table1_specs() {
            let a = generate(&spec, 123, 0.04);
            let d = RowStats::of_csr(&a).d_mat();
            let err = (d - spec.d_mat).abs() / spec.d_mat.max(0.02);
            assert!(err < 0.8, "{}: D_mat {d} vs published {}", spec.name, spec.d_mat);
        }
    }

    #[test]
    fn empty_and_single_row() {
        let e = Csr::from_triplets(0, 0, &[]).unwrap();
        let s = RowStats::of_csr(&e);
        assert_eq!(s.d_mat(), 0.0);
        let one = Csr::from_triplets(1, 3, &[(0, 0, 1.0), (0, 2, 1.0)]).unwrap();
        let s = RowStats::of_csr(&one);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.sigma, 0.0);
    }

    #[test]
    fn hand_computed_sigma() {
        // Row lengths 1, 3: mean 2, var 1, sigma 1, D = 0.5.
        let a = Csr::from_triplets(2, 3, &[(0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0), (1, 2, 1.0)])
            .unwrap();
        let s = RowStats::of_csr(&a);
        assert_eq!(s.mean, 2.0);
        assert!((s.sigma - 1.0).abs() < 1e-12);
        assert!((s.d_mat() - 0.5).abs() < 1e-12);
    }
}
