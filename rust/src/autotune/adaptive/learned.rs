//! The `spmv-at-tuning v2` format: the factory table plus learned
//! per-`D_mat`-bucket corrections.
//!
//! The offline phase produces one global threshold `D*` from the install
//! suite. The adaptive loop observes *actual* cost ratios per served
//! matrix; [`LearnedTuning`] folds each observed flip into a small table
//! of `D_mat` buckets, so the correction generalises to the next matrix
//! with similar row-length dispersion — and persists it, so the next
//! process start begins from the learned table instead of the factory
//! one.
//!
//! On disk, v2 is the v1 key-value file under a `spmv-at-tuning v2`
//! header plus one `bucket` line per corrected bucket. The v2 loader
//! reads v1 files (empty corrections); the v1 loader
//! ([`TuningData::load`]) rejects v2 files with an error naming this
//! loader — forward compatibility is explicit, never silent.

use crate::autotune::online::{decide, OnlineDecision, TuningData};
use crate::formats::Csr;
use crate::spmv::Implementation;
use crate::Result;
use std::path::Path;

/// Upper edges of the `D_mat` buckets corrections are keyed by; the last
/// bucket is open-ended. Log-ish spacing over the Table-1 `D_mat` range
/// (0.02 … 3.10 in the paper, with headroom above).
pub const BUCKET_EDGES: [f64; 7] = [0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0];

/// Number of buckets (`BUCKET_EDGES.len() + 1`, for the open tail).
pub const N_BUCKETS: usize = BUCKET_EDGES.len() + 1;

/// The bucket index a `D_mat` value falls into.
pub fn bucket_of(d_mat: f64) -> usize {
    BUCKET_EDGES.iter().position(|&e| d_mat < e).unwrap_or(BUCKET_EDGES.len())
}

/// One bucket's learned state: running mean of the measured cost ratio
/// `R = t_crs / t_imp` over the flips recorded into it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BucketStat {
    /// Running mean of measured `R`.
    pub r_mean: f64,
    /// Flips folded in.
    pub samples: u64,
}

/// A v1 [`TuningData`] plus learned per-bucket corrections.
#[derive(Clone, Debug, PartialEq)]
pub struct LearnedTuning {
    /// The factory (offline-phase) table.
    pub base: TuningData,
    buckets: [Option<BucketStat>; N_BUCKETS],
}

impl LearnedTuning {
    /// A learned table with no corrections yet — decisions are exactly the
    /// factory table's until flips are recorded.
    pub fn new(base: TuningData) -> Self {
        Self { base, buckets: [None; N_BUCKETS] }
    }

    /// Fold one observed flip into the bucket of `d_mat`: `r_measured` is
    /// the live cost ratio `t_crs / t_imp` at the moment the controller
    /// re-decided. Non-finite or non-positive ratios are ignored.
    pub fn record(&mut self, d_mat: f64, r_measured: f64) {
        if !r_measured.is_finite() || r_measured <= 0.0 || !d_mat.is_finite() {
            return;
        }
        let b = &mut self.buckets[bucket_of(d_mat)];
        *b = Some(match *b {
            None => BucketStat { r_mean: r_measured, samples: 1 },
            Some(s) => {
                let n = s.samples + 1;
                BucketStat {
                    r_mean: s.r_mean + (r_measured - s.r_mean) / n as f64,
                    samples: n,
                }
            }
        });
    }

    /// The learned correction covering `d_mat`, if any.
    pub fn correction(&self, d_mat: f64) -> Option<BucketStat> {
        self.buckets[bucket_of(d_mat)]
    }

    /// Buckets carrying a correction.
    pub fn corrected_buckets(&self) -> usize {
        self.buckets.iter().flatten().count()
    }

    /// The online decision for `a` under the learned table: the factory
    /// §2.2 decision, overridden when the matrix's `D_mat` bucket has a
    /// learned ratio contradicting it (`R >= c` means the transformation
    /// pays at cost threshold `c`, per the paper's graph criterion).
    pub fn decide(&self, a: &Csr) -> OnlineDecision {
        let mut d = decide(a, &self.base);
        if let Some(b) = self.correction(d.d_mat) {
            let transform = b.r_mean >= self.base.c;
            if transform != d.transform {
                d.transform = transform;
                d.chosen = if transform { self.base.imp } else { Implementation::CsrSeq };
            }
        }
        d
    }

    /// Merge another learned table's corrections into this one (used for
    /// tables with *disjoint* observations): per-bucket sample-weighted
    /// mean. For per-shard tables that all started from one preloaded
    /// snapshot, use [`LearnedTuning::merge_deltas`] instead — plain
    /// merging would count the shared baseline once per shard.
    pub fn merge_from(&mut self, other: &LearnedTuning) {
        for (mine, theirs) in self.buckets.iter_mut().zip(&other.buckets) {
            let Some(t) = theirs else { continue };
            *mine = Some(match *mine {
                None => *t,
                Some(m) => {
                    let n = m.samples + t.samples;
                    BucketStat {
                        r_mean: (m.r_mean * m.samples as f64 + t.r_mean * t.samples as f64)
                            / n as f64,
                        samples: n,
                    }
                }
            });
        }
    }

    /// Merge per-shard tables that each started from `self` (the shared
    /// preloaded snapshot): every shard contributes only its observations
    /// *beyond* the baseline, so preloaded corrections are counted once —
    /// not once per shard, which would compound sample counts across
    /// restarts and freeze the running means.
    pub fn merge_deltas(&self, shards: &[&LearnedTuning]) -> LearnedTuning {
        let mut out = self.clone();
        for (i, mine) in out.buckets.iter_mut().enumerate() {
            let (base_n, base_sum) = match &self.buckets[i] {
                None => (0u64, 0.0),
                Some(b) => (b.samples, b.r_mean * b.samples as f64),
            };
            let mut n = base_n;
            let mut sum = base_sum;
            for shard in shards {
                let Some(s) = &shard.buckets[i] else { continue };
                n += s.samples.saturating_sub(base_n);
                sum += (s.r_mean * s.samples as f64 - base_sum).max(0.0);
            }
            *mine = (n > 0).then_some(BucketStat { r_mean: sum / n.max(1) as f64, samples: n });
        }
        out
    }

    /// Serialize as the v2 text format: the v1 body under a v2 header,
    /// plus one `bucket⇥idx⇥r_mean⇥samples` line per corrected bucket.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut s = String::from("spmv-at-tuning v2\n");
        s.push_str(&self.base.body_string());
        for (i, b) in self.buckets.iter().enumerate() {
            if let Some(b) = b {
                s.push_str(&format!("bucket\t{i}\t{}\t{}\n", b.r_mean, b.samples));
            }
        }
        std::fs::write(path, s).map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
    }

    /// Load a learned table. Reads both v2 files and plain v1 files (the
    /// factory table, no corrections) — the forward-compatible loader.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let mut lines = text.lines();
        let header = lines.next().unwrap_or_default();
        let is_v2 = match header {
            "spmv-at-tuning v2" => true,
            "spmv-at-tuning v1" => false,
            other => anyhow::bail!("unrecognised tuning file header: {other}"),
        };
        let mut buckets = [None; N_BUCKETS];
        let mut body = Vec::new();
        for line in lines {
            match line.strip_prefix("bucket\t") {
                Some(rest) if is_v2 => {
                    let mut f = rest.split('\t');
                    let idx: usize = f
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("bucket line missing index"))?
                        .parse()?;
                    anyhow::ensure!(idx < N_BUCKETS, "bucket index {idx} out of range");
                    let r_mean: f64 = f
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("bucket line missing r_mean"))?
                        .parse()?;
                    let samples: u64 = f
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("bucket line missing samples"))?
                        .parse()?;
                    buckets[idx] = Some(BucketStat { r_mean, samples });
                }
                _ => body.push(line),
            }
        }
        let base = TuningData::parse_body(body.into_iter())?;
        Ok(Self { base, buckets })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::banded_circulant;
    use crate::rng::Rng;

    fn base(d_star: Option<f64>) -> TuningData {
        TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowInner,
            threads: 1,
            c: 1.0,
            d_star,
        }
    }

    #[test]
    fn buckets_cover_the_line() {
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(0.07), 1);
        assert_eq!(bucket_of(3.1), 6);
        assert_eq!(bucket_of(1e9), N_BUCKETS - 1);
        // Edges are half-open: d < edge lands below.
        assert_eq!(bucket_of(0.05), 1);
    }

    #[test]
    fn corrections_override_the_factory_decision_both_ways() {
        let mut rng = Rng::new(4);
        let band = banded_circulant(&mut rng, 64, &[-1, 0, 1]); // D_mat = 0
        // Factory says never transform; a learned R >= c flips it on.
        let mut lt = LearnedTuning::new(base(None));
        assert!(!lt.decide(&band).transform);
        lt.record(0.0, 4.0);
        let d = lt.decide(&band);
        assert!(d.transform);
        assert_eq!(d.chosen, Implementation::EllRowInner);
        // Factory says transform; a learned R < c flips it off.
        let mut lt = LearnedTuning::new(base(Some(3.1)));
        assert!(lt.decide(&band).transform);
        lt.record(0.0, 0.5);
        let d = lt.decide(&band);
        assert!(!d.transform);
        assert_eq!(d.chosen, Implementation::CsrSeq);
    }

    #[test]
    fn record_keeps_running_mean_and_ignores_garbage() {
        let mut lt = LearnedTuning::new(base(None));
        lt.record(0.3, 2.0);
        lt.record(0.3, 4.0);
        let b = lt.correction(0.3).unwrap();
        assert_eq!(b.samples, 2);
        assert!((b.r_mean - 3.0).abs() < 1e-12);
        lt.record(0.3, f64::NAN);
        lt.record(0.3, -1.0);
        lt.record(f64::NAN, 2.0);
        assert_eq!(lt.correction(0.3).unwrap().samples, 2);
        assert_eq!(lt.corrected_buckets(), 1);
    }

    #[test]
    fn merge_deltas_counts_the_preload_once() {
        // Preloaded snapshot with one corrected bucket, cloned into three
        // "shards"; only one shard records a new flip. The merge must
        // yield preload + 1 observation, not 3x the preload.
        let mut pre = LearnedTuning::new(base(None));
        pre.record(0.3, 2.0);
        pre.record(0.3, 4.0); // bucket: mean 3.0, samples 2
        let mut shards = vec![pre.clone(), pre.clone(), pre.clone()];
        shards[1].record(0.3, 9.0); // one genuine new flip
        shards[2].record(7.0, 1.5); // new bucket on another shard
        let refs: Vec<&LearnedTuning> = shards.iter().collect();
        let merged = pre.merge_deltas(&refs);
        let b = merged.correction(0.3).unwrap();
        assert_eq!(b.samples, 3, "2 preloaded + 1 new, preload counted once");
        assert!((b.r_mean - 5.0).abs() < 1e-12, "(2 + 4 + 9) / 3");
        assert_eq!(merged.correction(7.0).unwrap().samples, 1);
        // No new flips anywhere: merge is the identity on the preload.
        let same = pre.merge_deltas(&[&pre.clone(), &pre.clone()]);
        assert_eq!(same, pre);
    }

    #[test]
    fn merge_is_sample_weighted() {
        let mut a = LearnedTuning::new(base(None));
        let mut b = LearnedTuning::new(base(None));
        a.record(0.3, 2.0);
        b.record(0.3, 5.0);
        b.record(0.3, 5.0);
        b.record(7.0, 1.5);
        a.merge_from(&b);
        let s = a.correction(0.3).unwrap();
        assert_eq!(s.samples, 3);
        assert!((s.r_mean - 4.0).abs() < 1e-12, "(2 + 5 + 5) / 3");
        assert_eq!(a.correction(7.0).unwrap().samples, 1);
    }

    #[test]
    fn v2_roundtrip_and_v1_compat() {
        let dir = std::env::temp_dir().join("spmv_at_learned_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t2.tsv");
        for d_star in [Some(0.25), None] {
            let mut lt = LearnedTuning::new(base(d_star));
            lt.record(0.3, 2.5);
            lt.record(9.0, 0.4);
            lt.save(&p).unwrap();
            assert_eq!(LearnedTuning::load(&p).unwrap(), lt);
        }
        // The v2 loader reads a v1 file as a correction-free table.
        let v1 = dir.join("t1.tsv");
        base(Some(1.25)).save(&v1).unwrap();
        let lt = LearnedTuning::load(&v1).unwrap();
        assert_eq!(lt.base, base(Some(1.25)));
        assert_eq!(lt.corrected_buckets(), 0);
        // The v1 loader rejects the v2 file with a clear error.
        let mut lt2 = LearnedTuning::new(base(None));
        lt2.record(0.3, 2.0);
        lt2.save(&p).unwrap();
        let err = TuningData::load(&p).unwrap_err().to_string();
        assert!(err.contains("v2"), "error must name the version: {err}");
        assert!(err.contains("LearnedTuning"), "error must point at the v2 loader: {err}");
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&v1).ok();
    }

    #[test]
    fn v2_loader_rejects_garbage() {
        let dir = std::env::temp_dir().join("spmv_at_learned_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad2.tsv");
        std::fs::write(&p, "not a tuning file\n").unwrap();
        assert!(LearnedTuning::load(&p).is_err());
        std::fs::write(&p, "spmv-at-tuning v2\nbucket\t999\t1.0\t1\n").unwrap();
        assert!(LearnedTuning::load(&p).is_err(), "out-of-range bucket");
        std::fs::remove_file(&p).ok();
    }
}
