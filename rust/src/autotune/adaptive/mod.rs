//! The adaptive runtime autotuner: measure, explore, re-decide.
//!
//! The paper's pipeline decides CRS→ELL **once**, at registration, from
//! the offline table's `D*`. That table can be wrong for a matrix the
//! install suite never saw — and the registry already measures per-call
//! timings without acting on them. This subsystem closes the loop:
//!
//! ```text
//!   offline (install)      online (register)        adaptive (serve)
//!   suite → D_mat–R_ell →  D_mat < D*? → plan  →  telemetry (EWMA per imp)
//!   graph → D*                                  →  explore (ε shadow calls)
//!        ▲                                      →  controller (dead-band +
//!        │                                         K-window hysteresis)
//!        └── learned per-D_mat-bucket corrections ← re-plan + record flip
//! ```
//!
//! * [`telemetry`] — per-(matrix, implementation) EWMA mean/variance and
//!   sample counts, fed by `MatrixEntry::record_batch` for served traffic
//!   and by exploration for the rival arm.
//! * [`explore`] — epsilon-greedy shadow measurement: occasionally run the
//!   rival implementation on a served input (output discarded), budgeted
//!   so exploration overhead stays under a configured fraction of serving
//!   time. Served results are never taken from a shadow execution.
//! * [`controller`] — the hysteresis guard: flip only after K consecutive
//!   evaluation windows in which the rival's measured mean beats the
//!   serving mean by more than a dead-band.
//! * [`learned`] — the `spmv-at-tuning v2` table: the factory [`TuningData`]
//!   plus per-`D_mat`-bucket measured-ratio corrections, persisted so the
//!   next process start begins from the learned table.
//!
//! The coordinator wires these together per registered matrix (one
//! [`AdaptiveState`] per entry, so every shard runs its own controllers)
//! and performs the actual plan swap — promoting the cached shadow plan in
//! O(1), or parking the transformed plan when flipping back to CRS, so a
//! re-decision never tears down the worker pool. Every serve keeps
//! executing through a cached [`SpmvPlan`]; the adaptive layer only
//! changes *which* plan that is, never how a result is produced.
//!
//! # Example
//!
//! Serve a tiny matrix through a coordinator with the adaptive loop on —
//! results are identical to the decide-once pipeline, the loop only adds
//! measurement:
//!
//! ```
//! use spmv_at::coordinator::{Coordinator, CoordinatorConfig};
//! use spmv_at::autotune::online::TuningData;
//! use spmv_at::spmv::Implementation;
//! use spmv_at::formats::Csr;
//!
//! let mut cfg = CoordinatorConfig::new(TuningData {
//!     backend: "sim:ES2".into(),
//!     imp: Implementation::EllRowInner,
//!     threads: 1,
//!     c: 1.0,
//!     d_star: Some(3.1),
//! });
//! cfg.threads = 1;
//! cfg.shards = 1;
//! cfg.adaptive.enabled = true;
//! cfg.adaptive.epsilon = 0.0; // keep the doc example deterministic
//! let mut coord = Coordinator::new(cfg);
//! coord.register("m", Csr::identity(3)).unwrap();
//! let y = coord.spmv("m", &[1.0, 2.0, 3.0]).unwrap();
//! assert_eq!(y, vec![1.0, 2.0, 3.0]);
//! assert!(coord.adaptive_enabled());
//! // Telemetry measured the serving arm on the way through.
//! assert_eq!(coord.stats()[0].calls, 1);
//! ```

pub mod controller;
pub mod explore;
pub mod learned;
pub mod telemetry;

pub use controller::{FlipEvidence, HysteresisController};
pub use explore::ExplorePolicy;
pub use learned::{bucket_of, BucketStat, LearnedTuning};
pub use telemetry::{ArmTelemetry, EwmaStats, Telemetry};

use crate::autotune::online::TuningData;
use crate::spmv::SpmvPlan;
use crate::Value;

/// Truth for the adaptive on/off switch: the `SPMV_AT_ADAPTIVE`
/// environment variable, on for `1`/`true`/`on`/`yes` (case-insensitive),
/// off otherwise (the PR 2 decide-once pipeline).
pub fn configured_adaptive() -> bool {
    match std::env::var("SPMV_AT_ADAPTIVE") {
        Ok(v) => matches!(v.trim().to_ascii_lowercase().as_str(), "1" | "true" | "on" | "yes"),
        Err(_) => false,
    }
}

/// Tunables for the adaptive loop (one config shared by every matrix a
/// coordinator registers).
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveConfig {
    /// Master switch; when false the coordinator behaves byte-for-byte
    /// like the decide-once pipeline.
    pub enabled: bool,
    /// EWMA decay per telemetry sample.
    pub ewma_alpha: f64,
    /// Probability a served call also shadow-measures the rival.
    pub epsilon: f64,
    /// Exploration time budget as a fraction of serving time.
    pub budget_fraction: f64,
    /// Served steps before the first shadow call may fire (one-shot and
    /// short-lived matrices never pay a shadow transformation; defaults
    /// to one controller window).
    pub explore_warmup: u64,
    /// Relative margin the rival must beat the serving mean by.
    pub deadband: f64,
    /// Served calls per controller evaluation window.
    pub window: u64,
    /// Consecutive contradicting windows required to flip (the K).
    pub flip_windows: u32,
    /// Telemetry samples the rival arm needs before its mean counts.
    pub min_rival_samples: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            ewma_alpha: 0.2,
            epsilon: 0.05,
            budget_fraction: 0.10,
            explore_warmup: 16,
            deadband: 0.15,
            window: 16,
            flip_windows: 3,
            min_rival_samples: 4,
        }
    }
}

impl AdaptiveConfig {
    /// Defaults, with `enabled` taken from [`configured_adaptive`]
    /// (`SPMV_AT_ADAPTIVE`).
    pub fn from_env() -> Self {
        Self { enabled: configured_adaptive(), ..Self::default() }
    }
}

/// Per-matrix adaptive state the coordinator attaches to a registry
/// entry: the measured arms, the exploration policy, the flip guard, and
/// the cached rival ("shadow") plan that makes a flip O(1).
#[derive(Debug)]
pub struct AdaptiveState {
    /// Per-implementation EWMA timings.
    pub telemetry: Telemetry,
    /// Epsilon-greedy shadow-measurement policy.
    pub explore: ExplorePolicy,
    /// Dead-band + K-window flip guard.
    pub controller: HysteresisController,
    /// The rival plan kept warm while not serving: the transformed plan
    /// before its first promotion (built during exploration) or after a
    /// flip back to CRS (parked, so flipping forward again is free).
    pub shadow: Option<SpmvPlan>,
    /// Set when the rival plan cannot exist on this matrix (transform
    /// failure or memory-policy veto) — exploration stops retrying.
    pub rival_dead: bool,
    /// Discarded-output buffer for single-call shadow executions.
    pub scratch: Vec<Value>,
    /// Discarded-output buffers for batched shadow executions (reused
    /// across explorations so the request path never allocates a fresh
    /// `k × n_rows` block per shadow SpMM).
    pub scratch_many: Vec<Vec<Value>>,
}

impl AdaptiveState {
    /// Fresh state for one matrix; `seed` keys the deterministic
    /// exploration draw sequence (the coordinator uses the registry-key
    /// hash, so a matrix explores identically across runs).
    pub fn new(cfg: &AdaptiveConfig, seed: u64) -> Self {
        Self {
            telemetry: Telemetry::new(cfg.ewma_alpha),
            explore: ExplorePolicy::new(
                cfg.epsilon,
                cfg.budget_fraction,
                cfg.explore_warmup,
                seed,
            ),
            controller: HysteresisController::new(
                cfg.deadband,
                cfg.window,
                cfg.flip_windows,
                cfg.min_rival_samples,
            ),
            shadow: None,
            rival_dead: false,
            scratch: Vec::new(),
            scratch_many: Vec::new(),
        }
    }
}

/// Convenience: a learned table seeded from a factory [`TuningData`].
pub fn learned_from(tuning: &TuningData) -> LearnedTuning {
    LearnedTuning::new(tuning.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_switch_default_off() {
        if std::env::var("SPMV_AT_ADAPTIVE").is_err() {
            assert!(!configured_adaptive());
            assert!(!AdaptiveConfig::from_env().enabled);
        }
    }

    #[test]
    fn defaults_are_sane() {
        let c = AdaptiveConfig::default();
        assert!(!c.enabled);
        assert!(c.epsilon > 0.0 && c.epsilon < 1.0);
        assert!(c.budget_fraction > 0.0 && c.budget_fraction < 1.0);
        assert!(c.deadband > 0.0 && c.deadband < 1.0);
        assert!(c.window >= 1 && c.flip_windows >= 1);
        let s = AdaptiveState::new(&c, 7);
        assert!(s.shadow.is_none());
        assert!(!s.rival_dead);
        assert_eq!(s.telemetry.samples(crate::spmv::Implementation::CsrSeq), 0);
    }
}
