//! Per-(matrix, implementation) runtime statistics for the adaptive loop.
//!
//! The offline table predicts `R_ell` from `D_mat`; the telemetry layer
//! measures it. Every served call (and every exploration shadow call)
//! feeds one per-call timing sample into an exponentially-weighted mean
//! and variance per implementation, keyed by the kernel that actually
//! executed. [`crate::coordinator::MatrixEntry::record_batch`] is the
//! feeding site for served traffic; the coordinator's exploration policy
//! ([`super::explore`]) keeps the rival arm's estimate fresh, and the
//! hysteresis controller ([`super::controller`]) compares the two arms'
//! means to re-decide.
//!
//! EWMA (rather than the registry's running mean) is deliberate: the
//! adaptive loop must notice *drift* — a matrix whose effective timings
//! change under load (cache pressure, co-located shards) — so old samples
//! must decay. Sample counts gate confidence: the controller never acts
//! on an arm with fewer than its configured minimum of samples.

use crate::spmv::Implementation;

/// Exponentially-weighted mean/variance over per-call seconds.
#[derive(Clone, Debug)]
pub struct EwmaStats {
    alpha: f64,
    mean: f64,
    var: f64,
    count: u64,
}

impl EwmaStats {
    /// Empty stats decaying with weight `alpha` per sample
    /// (`0 < alpha <= 1`; higher = faster forgetting).
    pub fn new(alpha: f64) -> Self {
        Self { alpha: alpha.clamp(1e-6, 1.0), mean: 0.0, var: 0.0, count: 0 }
    }

    /// Absorb one per-call timing sample.
    pub fn record(&mut self, seconds: f64) {
        self.count += 1;
        if self.count == 1 {
            self.mean = seconds;
            self.var = 0.0;
            return;
        }
        // Standard EW mean/variance update (West-style).
        let d = seconds - self.mean;
        self.mean += self.alpha * d;
        self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d);
    }

    /// Absorb `k` calls that each took `seconds_per_call` (one tiled SpMM
    /// dispatch reports the batch as `k` equal per-call samples).
    pub fn record_n(&mut self, seconds_per_call: f64, k: u64) {
        for _ in 0..k {
            self.record(seconds_per_call);
        }
    }

    /// EW mean seconds per call (`None` until the first sample).
    pub fn mean(&self) -> Option<f64> {
        (self.count > 0).then_some(self.mean)
    }

    /// EW standard deviation (0 until two samples arrive).
    pub fn std(&self) -> f64 {
        self.var.max(0.0).sqrt()
    }

    /// Samples absorbed so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Whether the estimate has absorbed at least `min_samples` samples —
    /// the controller's confidence gate.
    pub fn confident(&self, min_samples: u64) -> bool {
        self.count >= min_samples
    }
}

/// Per-arm timing stats for one registered matrix, keyed by any
/// copyable arm identifier.
///
/// The SpMV loop keys arms by [`Implementation`] (the [`Telemetry`]
/// alias); the preconditioner subsystem reuses the identical machinery
/// keyed by its serial-vs-level-scheduled triangular-solve mode
/// ([`crate::precond::TrsvMode`]). Keeping one generic implementation
/// means both decisions share the same EWMA semantics, degenerate-sample
/// guards, and confidence gates the hysteresis controller assumes.
#[derive(Clone, Debug)]
pub struct ArmTelemetry<K: Copy + PartialEq> {
    alpha: f64,
    arms: Vec<(K, EwmaStats)>,
}

/// Per-implementation timing stats for one registered matrix (the SpMV
/// instantiation of [`ArmTelemetry`]).
pub type Telemetry = ArmTelemetry<Implementation>;

impl<K: Copy + PartialEq> ArmTelemetry<K> {
    /// Empty telemetry; every arm decays with `alpha`.
    pub fn new(alpha: f64) -> Self {
        Self { alpha, arms: Vec::new() }
    }

    /// Record `k` calls of `imp` at `seconds_per_call` each.
    pub fn record(&mut self, imp: K, seconds_per_call: f64, k: u64) {
        if k == 0 || !seconds_per_call.is_finite() || seconds_per_call < 0.0 {
            return;
        }
        if let Some((_, s)) = self.arms.iter_mut().find(|(i, _)| *i == imp) {
            s.record_n(seconds_per_call, k);
            return;
        }
        let mut s = EwmaStats::new(self.alpha);
        s.record_n(seconds_per_call, k);
        self.arms.push((imp, s));
    }

    /// Stats for `imp`, if any sample has arrived.
    pub fn stats(&self, imp: K) -> Option<&EwmaStats> {
        self.arms.iter().find(|(i, _)| *i == imp).map(|(_, s)| s)
    }

    /// EW mean seconds per call of `imp` (`None` when unmeasured).
    pub fn mean(&self, imp: K) -> Option<f64> {
        self.stats(imp).and_then(|s| s.mean())
    }

    /// Samples absorbed for `imp`.
    pub fn samples(&self, imp: K) -> u64 {
        self.stats(imp).map_or(0, |s| s.count())
    }

    /// The measured cost ratio `t_a / t_b` when both arms are measured
    /// (the live analogue of the offline `R_ell = t_crs / t_imp`).
    pub fn ratio(&self, a: K, b: K) -> Option<f64> {
        match (self.mean(a), self.mean(b)) {
            (Some(ta), Some(tb)) if tb > 0.0 => Some(ta / tb),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_tracks_mean_and_decays_old_samples() {
        let mut s = EwmaStats::new(0.5);
        assert_eq!(s.mean(), None);
        s.record(1.0);
        assert_eq!(s.mean(), Some(1.0));
        assert!(s.confident(1));
        assert!(!s.confident(2));
        // Shift the level: EWMA must converge toward the new value.
        for _ in 0..30 {
            s.record(3.0);
        }
        let m = s.mean().unwrap();
        assert!((m - 3.0).abs() < 1e-6, "mean {m} must forget the old level");
        assert_eq!(s.count(), 31);
    }

    #[test]
    fn batch_record_matches_repeated_singles() {
        let mut a = EwmaStats::new(0.2);
        let mut b = EwmaStats::new(0.2);
        a.record_n(2e-3, 5);
        for _ in 0..5 {
            b.record(2e-3);
        }
        assert_eq!(a.mean(), b.mean());
        assert_eq!(a.count(), b.count());
    }

    #[test]
    fn variance_is_zero_for_constant_series() {
        let mut s = EwmaStats::new(0.3);
        for _ in 0..10 {
            s.record(1e-4);
        }
        assert!(s.std() < 1e-12);
        let mut noisy = EwmaStats::new(0.3);
        for i in 0..10 {
            noisy.record(if i % 2 == 0 { 1e-4 } else { 3e-4 });
        }
        assert!(noisy.std() > 0.0);
    }

    #[test]
    fn telemetry_keys_arms_independently() {
        let mut t = Telemetry::new(0.2);
        t.record(Implementation::CsrRowPar, 2e-3, 4);
        t.record(Implementation::EllRowInner, 1e-3, 2);
        assert_eq!(t.samples(Implementation::CsrRowPar), 4);
        assert_eq!(t.samples(Implementation::EllRowInner), 2);
        assert_eq!(t.samples(Implementation::CsrSeq), 0);
        assert_eq!(t.mean(Implementation::CsrSeq), None);
        let r = t
            .ratio(Implementation::CsrRowPar, Implementation::EllRowInner)
            .unwrap();
        assert!((r - 2.0).abs() < 1e-12, "R = t_crs/t_imp = {r}");
    }

    #[test]
    fn degenerate_samples_are_ignored() {
        let mut t = Telemetry::new(0.2);
        t.record(Implementation::CsrSeq, f64::NAN, 1);
        t.record(Implementation::CsrSeq, -1.0, 1);
        t.record(Implementation::CsrSeq, 1.0, 0);
        assert_eq!(t.samples(Implementation::CsrSeq), 0);
    }
}
