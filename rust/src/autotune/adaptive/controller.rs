//! Hysteresis-guarded re-decision: flip only on a sustained, significant
//! contradiction.
//!
//! The naive adaptive loop — "switch whenever the rival's last sample was
//! faster" — flip-flaps on timing noise and pays a re-plan (or at least a
//! serving-path change) per oscillation. [`HysteresisController`] guards
//! the flip twice:
//!
//! * a **dead-band**: the rival must be faster by more than a configured
//!   relative margin (`deadband`), not merely faster;
//! * **K consecutive windows**: serving samples are grouped into windows
//!   of `window` calls, the dead-band comparison is evaluated once per
//!   window, and only `flip_windows` *consecutive* contradicting windows
//!   trigger a flip. Any window that fails the test (rival too slow,
//!   within the dead-band, or not confidently measured) resets the vote
//!   count to zero.
//!
//! The controller is pure decision logic over the EW means that
//! [`super::telemetry`] maintains; the coordinator owns the actual plan
//! swap and calls [`HysteresisController::note_serve`] after every served
//! call or batch.

/// The telemetry snapshot that justified the most recent flip — captured
/// at the instant [`HysteresisController::note_serve`] fires, before the
/// caller mutates the entry, so the decision log records the evidence the
/// controller actually voted on rather than post-swap state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlipEvidence {
    /// EW mean of the serving arm at the flip, seconds per call.
    pub serving_mean: f64,
    /// EW mean of the rival arm at the flip, seconds per call.
    pub rival_mean: f64,
    /// Telemetry samples behind the rival mean.
    pub rival_samples: u64,
    /// Windows evaluated up to and including the flipping one.
    pub windows: u64,
    /// Consecutive contradicting windows that fired the flip.
    pub votes: u32,
}

/// One registered matrix's flip guard.
#[derive(Clone, Debug)]
pub struct HysteresisController {
    deadband: f64,
    window: u64,
    flip_windows: u32,
    min_rival_samples: u64,
    fill: u64,
    votes: u32,
    windows: u64,
    flips: u64,
    last_evidence: Option<FlipEvidence>,
}

impl HysteresisController {
    /// Controller evaluating every `window` served calls, flipping after
    /// `flip_windows` consecutive windows in which the rival mean beats
    /// the serving mean by more than `deadband` (relative), provided the
    /// rival has at least `min_rival_samples` telemetry samples.
    pub fn new(deadband: f64, window: u64, flip_windows: u32, min_rival_samples: u64) -> Self {
        Self {
            deadband: deadband.max(0.0),
            window: window.max(1),
            flip_windows: flip_windows.max(1),
            min_rival_samples,
            fill: 0,
            votes: 0,
            windows: 0,
            flips: 0,
            last_evidence: None,
        }
    }

    /// Account `k` served calls; when they complete a window, evaluate the
    /// dead-band comparison. Returns `true` when the flip fires (the
    /// caller swaps the serving plan); the vote state resets either way at
    /// a flip, and resets to zero on any non-contradicting window. One
    /// dispatch evaluates at most one window — a mega-batch carries one
    /// unit of independent evidence, not `k / window` votes — but the
    /// remainder of its calls carries into the next window rather than
    /// being dropped.
    pub fn note_serve(
        &mut self,
        k: u64,
        serving_mean: Option<f64>,
        rival: Option<(f64, u64)>,
    ) -> bool {
        self.fill += k;
        if self.fill < self.window {
            return false;
        }
        self.fill %= self.window;
        self.windows += 1;
        let evidence = match (serving_mean, rival) {
            (Some(s), Some((r, n))) if n >= self.min_rival_samples && s > 0.0 => Some((s, r, n)),
            _ => None,
        };
        let contradiction =
            matches!(evidence, Some((s, r, _)) if r < s * (1.0 - self.deadband));
        if !contradiction {
            self.votes = 0;
            return false;
        }
        self.votes += 1;
        if self.votes >= self.flip_windows {
            if let Some((s, r, n)) = evidence {
                self.last_evidence = Some(FlipEvidence {
                    serving_mean: s,
                    rival_mean: r,
                    rival_samples: n,
                    windows: self.windows,
                    votes: self.votes,
                });
            }
            self.votes = 0;
            self.flips += 1;
            return true;
        }
        false
    }

    /// Clear window fill and votes (after a forced re-plan, so the new
    /// serving choice gets a full K windows before the next flip).
    pub fn reset(&mut self) {
        self.fill = 0;
        self.votes = 0;
    }

    /// Contradicting windows currently accumulated toward a flip.
    pub fn votes(&self) -> u32 {
        self.votes
    }

    /// Windows evaluated so far.
    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Flips fired so far.
    pub fn flips(&self) -> u64 {
        self.flips
    }

    /// The evidence snapshot behind the most recent flip (`None` before
    /// any flip fired). Read by the decision log immediately after
    /// [`HysteresisController::note_serve`] returns `true`.
    pub fn flip_evidence(&self) -> Option<FlipEvidence> {
        self.last_evidence
    }

    /// Serve calls per evaluation window.
    pub fn window(&self) -> u64 {
        self.window
    }

    /// Consecutive contradicting windows required to flip.
    pub fn flip_windows(&self) -> u32 {
        self.flip_windows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_windows(c: &mut HysteresisController, samples: &[(f64, f64)]) -> Vec<bool> {
        // One full window per (serving_mean, rival_mean) pair.
        samples
            .iter()
            .map(|&(s, r)| c.note_serve(c.window(), Some(s), Some((r, 100))))
            .collect()
    }

    #[test]
    fn flips_after_k_consecutive_contradictions() {
        let mut c = HysteresisController::new(0.15, 4, 3, 1);
        // Rival 10x faster, well past the dead-band, three windows in a row.
        let fired = run_windows(&mut c, &[(1e-3, 1e-4), (1e-3, 1e-4), (1e-3, 1e-4)]);
        assert_eq!(fired, vec![false, false, true]);
        assert_eq!(c.flips(), 1);
        assert_eq!(c.votes(), 0, "votes reset after the flip");
    }

    #[test]
    fn alternating_timings_never_flip() {
        // Synthetic flip-flap: rival faster one window, slower the next.
        let mut c = HysteresisController::new(0.1, 2, 2, 1);
        let pattern: Vec<(f64, f64)> =
            (0..20).map(|i| if i % 2 == 0 { (1e-3, 1e-4) } else { (1e-3, 1e-2) }).collect();
        let fired = run_windows(&mut c, &pattern);
        assert!(fired.iter().all(|f| !f), "hysteresis must suppress flip-flap");
        assert_eq!(c.flips(), 0);
        assert_eq!(c.windows(), 20);
    }

    #[test]
    fn deadband_suppresses_marginal_wins() {
        let mut c = HysteresisController::new(0.2, 1, 1, 1);
        // Rival 10% faster — inside the 20% dead-band.
        assert!(!c.note_serve(1, Some(1.0e-3), Some((0.9e-3, 10))));
        // Rival 30% faster — outside it.
        assert!(c.note_serve(1, Some(1.0e-3), Some((0.7e-3, 10))));
    }

    #[test]
    fn unmeasured_or_thin_rival_never_votes() {
        let mut c = HysteresisController::new(0.1, 1, 1, 5);
        assert!(!c.note_serve(1, Some(1e-3), None));
        assert!(!c.note_serve(1, None, Some((1e-9, 100))));
        // Rival hugely faster but only 2 of the required 5 samples.
        assert!(!c.note_serve(1, Some(1e-3), Some((1e-9, 2))));
        assert!(c.note_serve(1, Some(1e-3), Some((1e-9, 5))));
    }

    #[test]
    fn oversized_batches_carry_their_remainder() {
        // window 4, flips after 2 contradicting windows. A 6-call batch
        // completes one window (one vote) and carries 2 calls forward, so
        // 2 more calls complete the second window — not 4.
        let mut c = HysteresisController::new(0.1, 4, 2, 1);
        assert!(!c.note_serve(6, Some(1e-3), Some((1e-5, 10))));
        assert_eq!(c.votes(), 1);
        assert!(c.note_serve(2, Some(1e-3), Some((1e-5, 10))), "remainder counted");
        // A mega-batch is still at most one evaluation per dispatch.
        let mut c = HysteresisController::new(0.1, 4, 3, 1);
        assert!(!c.note_serve(400, Some(1e-3), Some((1e-5, 10))));
        assert_eq!(c.votes(), 1, "one vote per dispatch, however large");
    }

    #[test]
    fn flip_evidence_snapshots_the_firing_window() {
        let mut c = HysteresisController::new(0.15, 4, 2, 3);
        assert_eq!(c.flip_evidence(), None, "no flip yet");
        assert!(!c.note_serve(4, Some(1e-3), Some((1e-4, 7))));
        assert!(c.note_serve(4, Some(2e-3), Some((1.5e-4, 9))));
        let ev = c.flip_evidence().expect("flip fired");
        assert_eq!(ev.serving_mean, 2e-3, "evidence is from the firing window");
        assert_eq!(ev.rival_mean, 1.5e-4);
        assert_eq!(ev.rival_samples, 9);
        assert_eq!(ev.windows, 2);
        assert_eq!(ev.votes, 2);
        // The snapshot survives the post-flip vote reset.
        c.reset();
        assert_eq!(c.flip_evidence(), Some(ev));
    }

    #[test]
    fn partial_windows_accumulate_and_reset_clears() {
        let mut c = HysteresisController::new(0.1, 8, 1, 1);
        assert!(!c.note_serve(5, Some(1e-3), Some((1e-5, 10))), "window not full");
        c.reset();
        // After reset the 5 buffered calls are gone: 5 more still no window.
        assert!(!c.note_serve(5, Some(1e-3), Some((1e-5, 10))));
        assert!(c.note_serve(3, Some(1e-3), Some((1e-5, 10))), "8th call closes it");
    }
}
