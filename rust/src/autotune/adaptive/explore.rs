//! Epsilon-greedy shadow measurement with an overhead budget.
//!
//! The hysteresis controller can only correct a wrong decision if the
//! *rival* implementation's timing estimate stays fresh — but the rival,
//! by definition, is not serving. [`ExplorePolicy`] decides when a served
//! call should additionally shadow-execute the rival (same input, output
//! discarded, timing recorded into [`super::telemetry::Telemetry`]):
//! an epsilon-greedy draw from the crate's deterministic
//! [`crate::rng::Rng`], gated by a budget so cumulative exploration time
//! never exceeds a configured fraction of cumulative serving time. The
//! served result is never taken from the shadow execution, so
//! exploration cannot change what a client observes.

use crate::rng::Rng;

/// The exploration decision policy for one registered matrix.
#[derive(Debug)]
pub struct ExplorePolicy {
    epsilon: f64,
    budget_fraction: f64,
    warmup: u64,
    rng: Rng,
    steps: u64,
    serve_seconds: f64,
    explore_seconds: f64,
    explored: u64,
    budget_skips: u64,
}

impl ExplorePolicy {
    /// Policy exploring with probability `epsilon` per served call, capped
    /// so exploration time stays under `budget_fraction` of serving time,
    /// and silent for the first `warmup` served steps (a one-shot or
    /// short-lived matrix never pays a shadow transformation). `seed`
    /// makes the draw sequence deterministic per matrix.
    pub fn new(epsilon: f64, budget_fraction: f64, warmup: u64, seed: u64) -> Self {
        Self {
            epsilon: epsilon.clamp(0.0, 1.0),
            budget_fraction: budget_fraction.max(0.0),
            warmup,
            rng: Rng::new(seed ^ 0x5eed_ad47),
            steps: 0,
            serve_seconds: 0.0,
            explore_seconds: 0.0,
            explored: 0,
            budget_skips: 0,
        }
    }

    /// Whether this served call should also shadow-measure the rival.
    /// Draws epsilon first (so the sequence is deterministic regardless of
    /// budget or warmup state), then applies the warmup and budget gates.
    /// The first post-warmup exploration is always admitted — without one
    /// sample the rival estimate can never exist.
    pub fn should_explore(&mut self) -> bool {
        if self.epsilon <= 0.0 || !self.rng.next_bool(self.epsilon) {
            return false;
        }
        if self.steps <= self.warmup {
            return false;
        }
        if self.within_budget() {
            true
        } else {
            self.budget_skips += 1;
            false
        }
    }

    /// Whether cumulative exploration time is within budget. An infinite
    /// `budget_fraction` means "no budget" unconditionally — the naive
    /// product `INFINITY * 0.0` would be NaN when no serve time has
    /// accrued yet (coarse clocks report 0.0), and a NaN comparison would
    /// silently read as over-budget.
    pub fn within_budget(&self) -> bool {
        self.explored == 0
            || self.budget_fraction.is_infinite()
            || self.explore_seconds <= self.budget_fraction * self.serve_seconds
    }

    /// Account one served step (call or batch) of `seconds`.
    pub fn note_serve(&mut self, seconds: f64) {
        self.steps += 1;
        if seconds.is_finite() && seconds > 0.0 {
            self.serve_seconds += seconds;
        }
    }

    /// Account seconds spent exploring (shadow build + shadow execute).
    pub fn note_explore(&mut self, seconds: f64) {
        self.explored += 1;
        if seconds.is_finite() && seconds > 0.0 {
            self.explore_seconds += seconds;
        }
    }

    /// Shadow calls taken so far.
    pub fn explored(&self) -> u64 {
        self.explored
    }

    /// Shadow calls suppressed by the budget gate.
    pub fn budget_skips(&self) -> u64 {
        self.budget_skips
    }

    /// Exploration overhead as a fraction of serving time (0 when nothing
    /// has been served yet).
    pub fn overhead_fraction(&self) -> f64 {
        if self.serve_seconds <= 0.0 {
            0.0
        } else {
            self.explore_seconds / self.serve_seconds
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_zero_never_explores() {
        let mut p = ExplorePolicy::new(0.0, 0.1, 0, 1);
        for _ in 0..100 {
            p.note_serve(1e-6);
            assert!(!p.should_explore());
        }
        assert_eq!(p.explored(), 0);
    }

    #[test]
    fn warmup_gates_the_first_explorations() {
        let mut p = ExplorePolicy::new(1.0, f64::INFINITY, 5, 2);
        for step in 1..=10u64 {
            p.note_serve(1e-6);
            let explored = p.should_explore();
            assert_eq!(explored, step > 5, "step {step}");
            if explored {
                p.note_explore(1e-7);
            }
        }
        assert_eq!(p.explored(), 5);
    }

    #[test]
    fn epsilon_one_explores_until_budget_binds() {
        let mut p = ExplorePolicy::new(1.0, 0.5, 0, 2);
        p.note_serve(0.0);
        // Bootstrap: first shadow is always admitted.
        assert!(p.should_explore());
        p.note_explore(1.0);
        // Over budget (1.0 explore vs 0 serve) — must skip now.
        assert!(!p.should_explore());
        assert!(p.budget_skips() > 0);
        // Enough serving time re-opens the budget.
        p.note_serve(10.0);
        assert!(p.should_explore());
        assert!((p.overhead_fraction() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn infinite_budget_never_binds_even_with_zero_serve_time() {
        // Regression: INFINITY * 0.0 = NaN used to read as over-budget,
        // disabling exploration after the bootstrap on coarse-clock
        // platforms where serves report 0.0 seconds.
        let mut p = ExplorePolicy::new(1.0, f64::INFINITY, 0, 4);
        p.note_serve(0.0);
        assert!(p.should_explore());
        p.note_explore(1.0);
        p.note_serve(0.0);
        assert!(p.within_budget(), "an infinite budget must never bind");
        assert!(p.should_explore());
        assert_eq!(p.budget_skips(), 0);
    }

    #[test]
    fn draw_sequence_is_deterministic_per_seed() {
        let draws = |seed| {
            let mut p = ExplorePolicy::new(0.3, f64::INFINITY, 0, seed);
            (0..64)
                .map(|_| {
                    p.note_serve(1e-6);
                    p.should_explore()
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(draws(7), draws(7));
        assert_ne!(draws(7), draws(8), "different matrices draw differently");
        // Roughly epsilon of the calls explore (loose bound, deterministic).
        let n = draws(7).iter().filter(|b| **b).count();
        assert!((5..=30).contains(&n), "{n} explorations of 64 at eps=0.3");
    }

    #[test]
    fn overhead_fraction_tracks_accounting() {
        let mut p = ExplorePolicy::new(0.5, 0.1, 0, 3);
        assert_eq!(p.overhead_fraction(), 0.0);
        p.note_serve(2.0);
        p.note_explore(0.1);
        assert!((p.overhead_fraction() - 0.05).abs() < 1e-12);
        assert!(p.within_budget());
        p.note_explore(0.2);
        assert!(!p.within_budget());
    }
}
