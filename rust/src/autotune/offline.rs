//! The offline AT phase (paper §2.2): run at library-install time on each
//! new machine.
//!
//! For every benchmark matrix, measure `t_crs`, `t_imp`, `t_trans` on the
//! given [`Backend`], form [`Ratios`], compute `D_mat`, build the
//! [`DrGraph`], and extract `D*`. The result is persisted as the
//! machine's *tuning table* and consumed by the online phase at every
//! subsequent library call.

use super::dmat::RowStats;
use super::graph::DrGraph;
use super::online::TuningData;
use super::ratios::Ratios;
use crate::formats::Csr;
use crate::machine::Backend;
use crate::metrics::Json;
use crate::spmv::Implementation;
use crate::Result;

/// Offline-phase configuration.
#[derive(Clone, Debug)]
pub struct OfflineConfig {
    /// The candidate implementation being characterised (the paper's
    /// Fig. 8 uses ELL-Row outer at 1 thread).
    pub imp: Implementation,
    /// Thread count for both baseline and candidate timings.
    pub threads: usize,
    /// The cost threshold `c` (paper default 1.0).
    pub c: f64,
}

impl Default for OfflineConfig {
    fn default() -> Self {
        Self { imp: Implementation::EllRowOuter, threads: 1, c: 1.0 }
    }
}

/// One offline measurement row.
#[derive(Clone, Debug)]
pub struct OfflineSample {
    /// Matrix label.
    pub name: String,
    /// `D_mat` of the matrix.
    pub d_mat: f64,
    /// Baseline CRS SpMV seconds.
    pub t_crs: f64,
    /// Candidate SpMV seconds (None when the transformation failed, e.g.
    /// ELL memory overflow — the paper's torso1 case).
    pub t_imp: Option<f64>,
    /// Transformation seconds.
    pub t_trans: Option<f64>,
    /// Derived ratios (None when excluded).
    pub ratios: Option<Ratios>,
}

/// The offline phase output: samples + graph + threshold.
#[derive(Clone, Debug)]
pub struct OfflineResult {
    /// Backend the table was tuned on.
    pub backend: String,
    /// Configuration used.
    pub imp: Implementation,
    /// Threads used.
    pub threads: usize,
    /// Cost threshold `c`.
    pub c: f64,
    /// Per-matrix rows.
    pub samples: Vec<OfflineSample>,
    /// The `D_mat`–`R_ell` graph.
    pub graph: DrGraph,
    /// Extracted `D*` (None = never transform on this machine).
    pub d_star: Option<f64>,
}

impl OfflineResult {
    /// Convert to the compact [`TuningData`] the online phase loads.
    pub fn tuning_data(&self) -> TuningData {
        TuningData {
            backend: self.backend.clone(),
            imp: self.imp,
            threads: self.threads,
            c: self.c,
            d_star: self.d_star,
        }
    }

    /// JSON dump (samples + graph + threshold).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("backend".into(), Json::Str(self.backend.clone())),
            ("imp".into(), Json::Str(self.imp.name().into())),
            ("threads".into(), Json::Num(self.threads as f64)),
            ("c".into(), Json::Num(self.c)),
            ("d_star".into(), self.d_star.map_or(Json::Null, Json::Num)),
            (
                "samples".into(),
                Json::Arr(
                    self.samples
                        .iter()
                        .map(|s| {
                            Json::Obj(vec![
                                ("name".into(), Json::Str(s.name.clone())),
                                ("d_mat".into(), Json::Num(s.d_mat)),
                                ("t_crs".into(), Json::Num(s.t_crs)),
                                ("t_imp".into(), s.t_imp.map_or(Json::Null, Json::Num)),
                                ("t_trans".into(), s.t_trans.map_or(Json::Null, Json::Num)),
                                (
                                    "sp".into(),
                                    s.ratios.map_or(Json::Null, |r| Json::Num(r.sp)),
                                ),
                                (
                                    "tt".into(),
                                    s.ratios.map_or(Json::Null, |r| Json::Num(r.tt)),
                                ),
                                (
                                    "r_ell".into(),
                                    s.ratios.map_or(Json::Null, |r| Json::Num(r.r)),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Run the offline phase over `(name, matrix)` pairs on `backend`.
///
/// Matrices whose transformation fails (e.g. the ELL memory budget — the
/// paper removed torso1 for exactly this) stay in the sample list with
/// `t_imp = None` and are excluded from the graph, mirroring §4.2.
pub fn run_offline<B: Backend + ?Sized>(
    backend: &B,
    matrices: &[(String, Csr)],
    cfg: &OfflineConfig,
) -> Result<OfflineResult> {
    anyhow::ensure!(!matrices.is_empty(), "offline phase needs at least one matrix");
    let mut samples = Vec::with_capacity(matrices.len());
    let mut graph = DrGraph::new();
    for (name, a) in matrices {
        let d_mat = RowStats::of_csr(a).d_mat();
        let t_crs = backend.spmv_seconds(a, Implementation::CsrSeq, cfg.threads)?;
        // Candidate timing can fail (memory overflow) — record exclusion.
        let timing = backend
            .spmv_seconds(a, cfg.imp, cfg.threads)
            .and_then(|t_imp| Ok((t_imp, backend.transform_seconds(a, cfg.imp)?)));
        match timing {
            Ok((t_imp, t_trans)) => {
                let ratios = Ratios::from_times(t_crs, t_imp, t_trans);
                graph.push(name.clone(), d_mat, ratios.r);
                samples.push(OfflineSample {
                    name: name.clone(),
                    d_mat,
                    t_crs,
                    t_imp: Some(t_imp),
                    t_trans: Some(t_trans),
                    ratios: Some(ratios),
                });
            }
            Err(_) => samples.push(OfflineSample {
                name: name.clone(),
                d_mat,
                t_crs,
                t_imp: None,
                t_trans: None,
                ratios: None,
            }),
        }
    }
    let d_star = graph.d_star(cfg.c);
    Ok(OfflineResult {
        backend: backend.name(),
        imp: cfg.imp,
        threads: cfg.threads,
        c: cfg.c,
        samples,
        graph,
        d_star,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::scalar::ScalarMachine;
    use crate::machine::vector::VectorMachine;
    use crate::machine::SimulatedBackend;
    use crate::matrixgen::{generate, table1_specs};

    fn small_suite() -> Vec<(String, Csr)> {
        table1_specs()
            .into_iter()
            .filter(|s| s.no != 3) // keep runtime small; torso1 handled elsewhere
            .map(|s| (s.name.to_string(), generate(&s, 9, 0.02)))
            .collect()
    }

    #[test]
    fn vector_machine_accepts_everything_scalar_is_picky() {
        let suite = small_suite();
        let cfg = OfflineConfig::default();
        let es2 = SimulatedBackend::new(VectorMachine::default());
        let sr = SimulatedBackend::new(ScalarMachine::default());
        let r_es2 = run_offline(&es2, &suite, &cfg).unwrap();
        let r_sr = run_offline(&sr, &suite, &cfg).unwrap();
        // Paper Fig. 8: ES2 D* covers the full 0.02–3.10 range; SR16000
        // only D_mat < ~0.1.
        let d_es2 = r_es2.d_star.expect("ES2 must accept some matrices");
        let d_sr = r_sr.d_star.expect("SR16000 accepts the near-band matrices");
        assert!(d_es2 > 1.0, "ES2 D* = {d_es2}");
        assert!(d_sr < d_es2, "SR D* {d_sr} should be below ES2 D* {d_es2}");
    }

    #[test]
    fn excluded_matrices_stay_in_samples() {
        struct FailingEll;
        impl Backend for FailingEll {
            fn name(&self) -> String {
                "failing".into()
            }
            fn max_threads(&self) -> usize {
                1
            }
            fn spmv_seconds(&self, _a: &Csr, imp: Implementation, _t: usize) -> Result<f64> {
                if imp == Implementation::CsrSeq {
                    Ok(1.0)
                } else {
                    anyhow::bail!("ELL overflow")
                }
            }
            fn transform_seconds(&self, _a: &Csr, _imp: Implementation) -> Result<f64> {
                Ok(0.1)
            }
        }
        let suite = vec![("m".to_string(), Csr::identity(4))];
        let r = run_offline(&FailingEll, &suite, &OfflineConfig::default()).unwrap();
        assert_eq!(r.samples.len(), 1);
        assert!(r.samples[0].t_imp.is_none());
        assert!(r.graph.points.is_empty());
        assert!(r.d_star.is_none());
    }

    #[test]
    fn empty_suite_rejected() {
        let es2 = SimulatedBackend::new(VectorMachine::default());
        assert!(run_offline(&es2, &[], &OfflineConfig::default()).is_err());
    }

    #[test]
    fn json_roundtrip_contains_rows() {
        let suite = vec![
            ("a".to_string(), Csr::identity(64)),
            ("b".to_string(), Csr::identity(32)),
        ];
        let es2 = SimulatedBackend::new(VectorMachine::default());
        let r = run_offline(&es2, &suite, &OfflineConfig::default()).unwrap();
        let s = r.to_json().render();
        assert!(s.contains("\"samples\""));
        assert!(s.contains("\"d_star\""));
    }
}
