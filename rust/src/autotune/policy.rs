//! The memory auto-tuning policy (paper §2.2 drawback discussion):
//! run-time transformation needs "approximately 2x or more of memory
//! space" — the paper defers to OpenATLib's user-requirement "auto-tuning
//! policy". This module implements that policy: a byte budget that
//! admits or rejects candidate formats *before* allocation, and an
//! eviction preference when several transformed copies are held.

use crate::formats::FormatKind;
use crate::machine::MatrixShape;
use crate::{Index, Value};

/// User-specified memory policy for run-time transformation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemoryPolicy {
    /// Maximum extra bytes a transformed copy may occupy (None = unlimited).
    pub budget_bytes: Option<usize>,
    /// Whether the CRS original must be kept alongside the transformed
    /// copy (the paper keeps it: the AT may fall back at any call).
    pub keep_crs: bool,
}

impl Default for MemoryPolicy {
    fn default() -> Self {
        Self { budget_bytes: None, keep_crs: true }
    }
}

impl MemoryPolicy {
    /// Unlimited policy.
    pub fn unlimited() -> Self {
        Self::default()
    }

    /// Policy with a byte budget.
    pub fn with_budget(bytes: usize) -> Self {
        Self { budget_bytes: Some(bytes), keep_crs: true }
    }

    /// Predicted storage bytes of a matrix of shape `m` in `kind`
    /// (without materialising it).
    pub fn predicted_bytes(m: &MatrixShape, kind: FormatKind) -> usize {
        let vb = std::mem::size_of::<Value>();
        let ib = std::mem::size_of::<Index>();
        let ub = std::mem::size_of::<usize>();
        match kind {
            FormatKind::Csr => m.nnz * (vb + ib) + (m.n + 1) * ub,
            FormatKind::Csc => m.nnz * (vb + ib) + (m.n_cols + 1) * ub,
            FormatKind::CooRow | FormatKind::CooCol => m.nnz * (vb + 2 * ib),
            FormatKind::Ell => m.n.saturating_mul(m.bandwidth) * (vb + ib),
            // 2×2 blocks, fill capped at 4 (worst case all singleton blocks).
            FormatKind::Bcsr => {
                let blocks = (m.nnz as f64 * m.fill_ratio.min(4.0) / 4.0).ceil() as usize;
                blocks * (4 * vb + ib) + (m.n / 2 + 1) * ub
            }
            // JDS: nnz payload + perm + diagonal pointers (no fill).
            FormatKind::Jds => {
                m.nnz * (vb + ib) + m.n * ib + (m.bandwidth + 1) * ub
            }
            // HYB: body slots at ~1.5μ bandwidth + spilled tail (~10%).
            FormatKind::Hyb => {
                let body_bw = ((m.mu * 1.5).ceil() as usize).min(m.bandwidth).max(1);
                m.n * body_bw * (vb + ib) + m.nnz / 10 * (vb + 2 * ib)
            }
            // SELL-C-σ: the σ-window sort removes most of ELL's padding —
            // keep 15% of the waste as the estimate (same retention factor
            // as the cost models) plus the perm/row_len side arrays.
            FormatKind::Sell => {
                let waste = m.n.saturating_mul(m.bandwidth).saturating_sub(m.nnz);
                let slots = m.nnz + (waste as f64 * 0.15).ceil() as usize;
                slots * (vb + ib) + m.n * 2 * ib
            }
        }
    }

    /// Does `kind` fit the budget for shape `m`?
    pub fn admits(&self, m: &MatrixShape, kind: FormatKind) -> bool {
        match self.budget_bytes {
            None => true,
            Some(cap) => Self::predicted_bytes(m, kind) <= cap,
        }
    }

    /// All formats admitted for shape `m`, cheapest-first.
    pub fn admissible(&self, m: &MatrixShape) -> Vec<FormatKind> {
        let mut kinds: Vec<(usize, FormatKind)> = FormatKind::ALL
            .iter()
            .copied()
            .filter(|&k| k != FormatKind::Csr && self.admits(m, k))
            .map(|k| (Self::predicted_bytes(m, k), k))
            .collect();
        kinds.sort_by_key(|&(b, _)| b);
        kinds.into_iter().map(|(_, k)| k).collect()
    }

    /// The ELL budget to pass to
    /// [`crate::transform::crs_to_ell_bounded`].
    pub fn ell_budget(&self) -> Option<usize> {
        self.budget_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(n: usize, nnz: usize, bw: usize) -> MatrixShape {
        MatrixShape {
            n,
            n_cols: n,
            nnz,
            mu: nnz as f64 / n as f64,
            sigma: 0.0,
            bandwidth: bw,
            fill_ratio: (n * bw) as f64 / nnz as f64,
        }
    }

    #[test]
    fn unlimited_admits_all() {
        let p = MemoryPolicy::unlimited();
        let m = shape(1000, 5000, 5);
        for k in FormatKind::ALL {
            assert!(p.admits(&m, k), "{k}");
        }
    }

    #[test]
    fn torso1_style_ell_rejected() {
        // Huge bandwidth: ELL blows up, COO stays linear in nnz.
        let m = shape(100_000, 1_000_000, 5_000);
        let coo_bytes = MemoryPolicy::predicted_bytes(&m, FormatKind::CooRow);
        let p = MemoryPolicy::with_budget(2 * coo_bytes);
        assert!(!p.admits(&m, FormatKind::Ell), "ELL must exceed budget");
        assert!(p.admits(&m, FormatKind::CooRow));
        let adm = p.admissible(&m);
        assert!(!adm.contains(&FormatKind::Ell));
        assert!(adm.contains(&FormatKind::CooRow));
    }

    #[test]
    fn admissible_sorted_cheapest_first() {
        let p = MemoryPolicy::unlimited();
        let m = shape(1000, 5000, 5);
        let adm = p.admissible(&m);
        let bytes: Vec<usize> =
            adm.iter().map(|&k| MemoryPolicy::predicted_bytes(&m, k)).collect();
        let mut sorted = bytes.clone();
        sorted.sort_unstable();
        assert_eq!(bytes, sorted);
        assert!(!adm.contains(&FormatKind::Csr), "CSR is the original, not a target");
    }

    #[test]
    fn predicted_ell_matches_reality() {
        use crate::formats::SparseMatrix as _;
        use crate::rng::Rng;
        let mut rng = Rng::new(8);
        let a = crate::matrixgen::random_csr(&mut rng, 50, 50, 0.1);
        let m = MatrixShape::of(&a);
        let e = crate::transform::crs_to_ell(&a).unwrap();
        assert_eq!(MemoryPolicy::predicted_bytes(&m, FormatKind::Ell), e.memory_bytes());
    }
}
