//! OpenATLib-style numbered-switch interface (the paper's substrate).
//!
//! The paper runs its baseline through OpenATLib's `OpenATI_DURMV` with
//! "switch no. 11, which is the normal CRS implementation". This module
//! reproduces that calling convention: a matrix handle plus an integer
//! switch selecting the SpMV implementation, with switch 0 meaning
//! **AUTO** — the run-time AT decision of §2.2.
//!
//! Execution goes through the plan engine: the handle owns a
//! [`Planner`] (tuning table + memory policy + a persistent worker pool)
//! and caches one [`SpmvPlan`] per implementation it has served, so the
//! transformation *and* the work partition are paid once and replayed on
//! every subsequent call — no per-call thread spawns, no per-call
//! partitioning.

use super::online::TuningData;
use super::policy::MemoryPolicy;
use crate::formats::Csr;
use crate::spmv::pool::ParPool;
use crate::spmv::{Implementation, Planner, SpmvPlan};
use crate::{Result, Value};
use std::sync::Arc;

/// Switch numbers (OpenATLib style).
pub mod switches {
    /// Run-time auto-tuning (§2.2 online phase).
    pub const AUTO: u32 = 0;
    /// Normal CRS (the paper's baseline switch).
    pub const CRS: u32 = 11;
    /// Row-parallel CRS.
    pub const CRS_PAR: u32 = 12;
    /// Merge-path CRS (nonzero-balanced 2-D partition, chunks may cut rows).
    pub const CRS_MERGE: u32 = 13;
    /// COO-Column outer (Fig. 1).
    pub const COO_COL_OUTER: u32 = 21;
    /// COO-Row outer (Fig. 2).
    pub const COO_ROW_OUTER: u32 = 22;
    /// ELL-Row inner (Fig. 3).
    pub const ELL_ROW_INNER: u32 = 31;
    /// ELL-Row outer (Fig. 4).
    pub const ELL_ROW_OUTER: u32 = 32;
    /// BCSR 2×2 (extension).
    pub const BCSR: u32 = 41;
    /// JDS (extension).
    pub const JDS: u32 = 51;
    /// HYB ELL+COO (extension).
    pub const HYB: u32 = 61;
    /// SELL-C-σ chunk-parallel (extension).
    pub const SELL_ROW_INNER: u32 = 71;
}

/// Map a switch number to an implementation (`None` for AUTO).
pub fn switch_to_impl(switch: u32) -> Result<Option<Implementation>> {
    use switches::*;
    Ok(match switch {
        AUTO => None,
        CRS => Some(Implementation::CsrSeq),
        CRS_PAR => Some(Implementation::CsrRowPar),
        CRS_MERGE => Some(Implementation::CsrMergePar),
        COO_COL_OUTER => Some(Implementation::CooColOuter),
        COO_ROW_OUTER => Some(Implementation::CooRowOuter),
        ELL_ROW_INNER => Some(Implementation::EllRowInner),
        ELL_ROW_OUTER => Some(Implementation::EllRowOuter),
        BCSR => Some(Implementation::BcsrSeq),
        JDS => Some(Implementation::JdsSeq),
        HYB => Some(Implementation::HybSeq),
        SELL_ROW_INNER => Some(Implementation::SellRowInner),
        other => anyhow::bail!("unknown OpenATI_DURMV switch {other}"),
    })
}

/// A matrix handle with cached execution plans — the `OpenATI_DURMV`
/// equivalent. Holds the CRS original (shared by `Arc`, so the cached
/// CRS plans are zero-copy views of it) plus a [`Planner`]; each
/// implementation that gets exercised materialises one [`SpmvPlan`]
/// (kept across calls — the run-time transformation happens once and
/// amortises over iterations).
pub struct Durmv {
    crs: Arc<Csr>,
    planner: Planner,
    plans: Vec<SpmvPlan>,
    /// Cumulative SpMV calls served (amortisation accounting).
    pub calls: u64,
    /// Seconds spent transforming (accounted once per implementation).
    pub transform_seconds: f64,
}

impl Durmv {
    /// New handle with the given tuning table and policy, executing on a
    /// dedicated pool of `threads` workers.
    pub fn new(crs: Csr, tuning: TuningData, policy: MemoryPolicy, threads: usize) -> Self {
        let pool = Arc::new(ParPool::new(threads.max(1)));
        Self {
            crs: Arc::new(crs),
            planner: Planner::new(tuning, policy, pool),
            plans: Vec::new(),
            calls: 0,
            transform_seconds: 0.0,
        }
    }

    /// The CRS original.
    pub fn csr(&self) -> &Csr {
        &self.crs
    }

    /// The implementation AUTO would choose for this matrix right now
    /// (tuning-table decision + memory-policy veto).
    pub fn auto_choice(&self) -> Implementation {
        self.planner.auto_choice(&self.crs)
    }

    /// `y = A·x` through the numbered switch. Switch 0 (AUTO) runs the
    /// online AT phase; the plan (transformation + partition) is built on
    /// first use of an implementation and cached for subsequent calls.
    pub fn durmv(&mut self, switch: u32, x: &[Value], y: &mut [Value]) -> Result<()> {
        let imp = match switch_to_impl(switch)? {
            Some(imp) => imp,
            None => self.auto_choice(),
        };
        self.calls += 1;
        self.plan_mut(imp)?.execute(x, y)
    }

    /// Batched `Y = A·X` through the numbered switch: the whole batch is
    /// served by one cached plan as a tiled SpMM
    /// ([`SpmvPlan::execute_many`]), streaming the matrix once per column
    /// tile instead of once per vector.
    pub fn durmv_many(
        &mut self,
        switch: u32,
        xs: &[Vec<Value>],
        ys: &mut [Vec<Value>],
    ) -> Result<()> {
        let imp = match switch_to_impl(switch)? {
            Some(imp) => imp,
            None => self.auto_choice(),
        };
        self.calls += xs.len() as u64;
        self.plan_mut(imp)?.execute_many(xs, ys)
    }

    /// The cached plan for `imp`, built (and its transformation
    /// accounted) on first use.
    fn plan_mut(&mut self, imp: Implementation) -> Result<&mut SpmvPlan> {
        if let Some(pos) = self.plans.iter().position(|p| p.implementation() == imp) {
            return Ok(&mut self.plans[pos]);
        }
        let plan = self.planner.plan_for(&self.crs, imp)?;
        self.transform_seconds += plan.transform_seconds();
        self.plans.push(plan);
        Ok(self.plans.last_mut().expect("pushed above"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::SparseMatrix;
    use crate::matrixgen::{banded_circulant, generate, spec_by_name};
    use crate::rng::Rng;

    fn tuning(d_star: Option<f64>) -> TuningData {
        TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star,
        }
    }

    #[test]
    fn switch_11_is_crs() {
        assert_eq!(
            switch_to_impl(switches::CRS).unwrap(),
            Some(Implementation::CsrSeq)
        );
        assert_eq!(switch_to_impl(switches::AUTO).unwrap(), None);
        assert!(switch_to_impl(99).is_err());
    }

    #[test]
    fn all_switches_compute_correctly() {
        let mut rng = Rng::new(9);
        let a = crate::matrixgen::random_csr(&mut rng, 30, 30, 0.15);
        let x: Vec<Value> = (0..30).map(|i| (i as f64).sin()).collect();
        let mut want = vec![0.0; 30];
        a.spmv(&x, &mut want);
        for sw in [11u32, 12, 13, 21, 22, 31, 32, 41, 51, 61, 71, 0] {
            let mut h = Durmv::new(a.clone(), tuning(Some(3.0)), MemoryPolicy::unlimited(), 2);
            let mut y = vec![0.0; 30];
            h.durmv(sw, &x, &mut y).unwrap();
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "switch {sw}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn auto_transforms_banded_and_caches() {
        let mut rng = Rng::new(10);
        let a = banded_circulant(&mut rng, 200, &[-1, 0, 1, 2]);
        let mut h = Durmv::new(a, tuning(Some(3.1)), MemoryPolicy::unlimited(), 1);
        assert_eq!(h.auto_choice(), Implementation::EllRowOuter);
        let x = vec![1.0; 200];
        let mut y = vec![0.0; 200];
        h.durmv(switches::AUTO, &x, &mut y).unwrap();
        let t1 = h.transform_seconds;
        assert!(t1 > 0.0, "transformation must be accounted");
        h.durmv(switches::AUTO, &x, &mut y).unwrap();
        assert_eq!(h.transform_seconds, t1, "second call must reuse the cached plan");
        assert_eq!(h.calls, 2);
    }

    #[test]
    fn interleaved_switches_keep_their_plans() {
        // AUTO (ELL) → explicit CRS → AUTO again: the ELL plan must not be
        // rebuilt (the per-implementation plan cache, not a single slot).
        let mut rng = Rng::new(12);
        let a = banded_circulant(&mut rng, 150, &[-1, 0, 1]);
        let mut h = Durmv::new(a, tuning(Some(3.1)), MemoryPolicy::unlimited(), 2);
        let x = vec![1.0; 150];
        let mut y = vec![0.0; 150];
        h.durmv(switches::AUTO, &x, &mut y).unwrap();
        let t1 = h.transform_seconds;
        h.durmv(switches::CRS, &x, &mut y).unwrap();
        h.durmv(switches::AUTO, &x, &mut y).unwrap();
        assert_eq!(h.transform_seconds, t1, "ELL transformation must be paid once");
        assert_eq!(h.calls, 3);
    }

    #[test]
    fn durmv_many_matches_looped_durmv_bitwise() {
        let mut rng = Rng::new(11);
        let a = banded_circulant(&mut rng, 120, &[-1, 0, 1]);
        let xs: Vec<Vec<Value>> = (0..5)
            .map(|k| (0..120).map(|i| ((i + k) as f64 * 0.21).cos()).collect())
            .collect();
        let mut looped = Durmv::new(a.clone(), tuning(Some(3.1)), MemoryPolicy::unlimited(), 2);
        let mut batched = Durmv::new(a, tuning(Some(3.1)), MemoryPolicy::unlimited(), 2);
        let mut want = vec![vec![0.0; 120]; 5];
        for (x, y) in xs.iter().zip(want.iter_mut()) {
            looped.durmv(switches::AUTO, x, y).unwrap();
        }
        let mut got = vec![vec![0.0; 120]; 5];
        batched.durmv_many(switches::AUTO, &xs, &mut got).unwrap();
        assert_eq!(got, want, "tiled batch must match looped calls bitwise");
        assert_eq!(batched.calls, 5);
        assert!(batched.transform_seconds > 0.0, "one transformation for the batch");
    }

    #[test]
    fn auto_respects_memory_policy() {
        // Tail-heavy matrix: ELL would explode; a tight budget forces CRS.
        let spec = spec_by_name("memplus").unwrap();
        let a = generate(&spec, 3, 0.03);
        let mut h = Durmv::new(
            a,
            tuning(Some(10.0)), // threshold that would otherwise transform
            MemoryPolicy::with_budget(64 * 1024),
            1,
        );
        assert_eq!(h.auto_choice(), Implementation::CsrSeq);
        let n = h.csr().n_rows();
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        h.durmv(switches::AUTO, &x, &mut y).unwrap();
        assert!(h.transform_seconds == 0.0);
    }

    #[test]
    fn auto_keeps_crs_for_high_dmat() {
        let spec = spec_by_name("memplus").unwrap();
        let a = generate(&spec, 3, 0.03);
        let h = Durmv::new(a, tuning(Some(0.1)), MemoryPolicy::unlimited(), 1);
        assert_eq!(h.auto_choice(), Implementation::CsrSeq);
    }
}
