//! The paper's auto-tuning method (§2.2), plus the adaptive runtime loop
//! that closes it.
//!
//! Three phases:
//!
//! * **Offline** ([`offline`]) — run once per machine install: benchmark a
//!   suite of matrices, computing for each the statistic
//!   `D_mat = σ/μ` ([`dmat`]) and the cost ratio `R_ell` ([`ratios`]),
//!   plot the `D_mat`–`R_ell` graph ([`graph`]) and extract the threshold
//!   `D*` (the largest `D_mat` still worth transforming at cost threshold
//!   `c`, default 1.0).
//! * **Online** ([`online`]) — run at every library call: compute `D_mat`
//!   of the input matrix (one cheap O(n) pass) and transform to ELL iff
//!   `D_mat < D*`.
//! * **Adaptive** ([`adaptive`]) — run *while serving*: per-implementation
//!   EWMA telemetry ([`adaptive::telemetry`]) measures the actual cost
//!   ratio, epsilon-greedy shadow calls ([`adaptive::explore`]) keep the
//!   rival arm's estimate fresh inside an overhead budget, a dead-band +
//!   K-window hysteresis controller ([`adaptive::controller`]) re-decides
//!   when the measurements contradict the offline table, and the flips
//!   are persisted as per-`D_mat`-bucket corrections in the
//!   `spmv-at-tuning v2` format ([`adaptive::learned`]) so the next
//!   process start begins from the learned table.
//!
//! [`atlib`] wraps the decision in an OpenATLib-style numbered-switch
//! interface (the paper's `OpenATI_DURMV`), and [`policy`] implements the
//! memory-budget auto-tuning policy the paper cites for the 2×-memory
//! drawback.

pub mod adaptive;
pub mod atlib;
pub mod dmat;
pub mod graph;
pub mod offline;
pub mod online;
pub mod policy;
pub mod ratios;

pub use adaptive::{AdaptiveConfig, LearnedTuning};
pub use dmat::RowStats;
pub use graph::{DrGraph, DrPoint};
pub use offline::{run_offline, OfflineConfig, OfflineResult, OfflineSample};
pub use online::{decide, OnlineDecision, TuningData};
pub use policy::MemoryPolicy;
pub use ratios::Ratios;
