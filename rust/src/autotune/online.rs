//! The online AT phase (paper §2.2): executed inside every library call.
//!
//! 1. Compute `D_mat` for the input matrix (one O(n) pass over `IRP`).
//! 2. If `D_mat < D*`, transform to ELL and use the ELL SpMV; otherwise
//!    stay on CRS.
//!
//! [`TuningData`] is the machine's installed tuning table (the offline
//! phase's output), with text-file persistence so the rust coordinator
//! can load what an earlier install run produced.

use super::dmat::RowStats;
use crate::formats::Csr;
use crate::spmv::Implementation;
use crate::Result;
use std::path::Path;

/// The persisted offline-phase output the online phase consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct TuningData {
    /// Backend name the table was tuned on (informational).
    pub backend: String,
    /// Candidate implementation the offline phase characterised.
    pub imp: Implementation,
    /// Thread count the table was tuned at.
    pub threads: usize,
    /// Cost threshold `c`.
    pub c: f64,
    /// The threshold `D*`; `None` = the candidate never won offline.
    pub d_star: Option<f64>,
}

impl TuningData {
    /// The key-value body shared by the v1 format and the v2 format's
    /// base-table section (everything but the header line).
    pub(crate) fn body_string(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("backend\t{}\n", self.backend));
        s.push_str(&format!("imp\t{}\n", self.imp.name()));
        s.push_str(&format!("threads\t{}\n", self.threads));
        s.push_str(&format!("c\t{}\n", self.c));
        match self.d_star {
            Some(d) => s.push_str(&format!("d_star\t{d}\n")),
            None => s.push_str("d_star\tnone\n"),
        }
        s
    }

    /// Serialize as a small key-value text file (the environment carries
    /// no serde; the format is stable and human-inspectable).
    pub fn save(&self, path: &Path) -> Result<()> {
        let s = format!("spmv-at-tuning v1\n{}", self.body_string());
        std::fs::write(path, s).map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
    }

    /// Load a tuning table saved by [`TuningData::save`]. This is the v1
    /// loader: it rejects v2 files (learned corrections) explicitly —
    /// load those with [`crate::autotune::adaptive::LearnedTuning::load`],
    /// which also reads v1 files.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let mut lines = text.lines();
        match lines.next().unwrap_or_default() {
            "spmv-at-tuning v1" => Self::parse_body(lines),
            "spmv-at-tuning v2" => anyhow::bail!(
                "{} is a v2 tuning file (learned adaptive corrections); \
                 load it with autotune::adaptive::LearnedTuning::load",
                path.display()
            ),
            header => anyhow::bail!("unrecognised tuning file header: {header}"),
        }
    }

    /// Parse the key-value body lines (shared by the v1 loader and the v2
    /// loader in [`crate::autotune::adaptive::learned`]).
    pub(crate) fn parse_body<'a>(lines: impl Iterator<Item = &'a str>) -> Result<Self> {
        let mut backend = None;
        let mut imp = None;
        let mut threads = None;
        let mut c = None;
        let mut d_star: Option<Option<f64>> = None;
        for line in lines {
            let (k, v) = line
                .split_once('\t')
                .ok_or_else(|| anyhow::anyhow!("bad tuning line: {line}"))?;
            match k {
                "backend" => backend = Some(v.to_string()),
                "imp" => {
                    imp = Some(
                        Implementation::parse(v)
                            .ok_or_else(|| anyhow::anyhow!("unknown implementation {v}"))?,
                    )
                }
                "threads" => threads = Some(v.parse()?),
                "c" => c = Some(v.parse()?),
                "d_star" => {
                    d_star = Some(if v == "none" { None } else { Some(v.parse()?) })
                }
                other => anyhow::bail!("unknown tuning key {other}"),
            }
        }
        Ok(Self {
            backend: backend.ok_or_else(|| anyhow::anyhow!("missing backend"))?,
            imp: imp.ok_or_else(|| anyhow::anyhow!("missing imp"))?,
            threads: threads.ok_or_else(|| anyhow::anyhow!("missing threads"))?,
            c: c.ok_or_else(|| anyhow::anyhow!("missing c"))?,
            d_star: d_star.ok_or_else(|| anyhow::anyhow!("missing d_star"))?,
        })
    }
}

/// The online decision for one input matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OnlineDecision {
    /// The input's `D_mat`.
    pub d_mat: f64,
    /// The threshold compared against (NaN if the table had none).
    pub d_star: f64,
    /// Whether to transform.
    pub transform: bool,
    /// The implementation to run.
    pub chosen: Implementation,
}

/// §2.2 online phase: compute `D_mat`, compare against `D*`.
pub fn decide(a: &Csr, tuning: &TuningData) -> OnlineDecision {
    let d_mat = RowStats::of_csr(a).d_mat();
    match tuning.d_star {
        Some(d_star) if d_mat < d_star => OnlineDecision {
            d_mat,
            d_star,
            transform: true,
            chosen: tuning.imp,
        },
        Some(d_star) => OnlineDecision {
            d_mat,
            d_star,
            transform: false,
            chosen: Implementation::CsrSeq,
        },
        None => OnlineDecision {
            d_mat,
            d_star: f64::NAN,
            transform: false,
            chosen: Implementation::CsrSeq,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::{banded_circulant, generate, spec_by_name};
    use crate::rng::Rng;

    fn tuning(d_star: Option<f64>) -> TuningData {
        TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star,
        }
    }

    #[test]
    fn banded_matrix_transforms_under_es2_table() {
        let mut rng = Rng::new(1);
        let a = banded_circulant(&mut rng, 100, &[-1, 0, 1]);
        let d = decide(&a, &tuning(Some(3.1)));
        assert!(d.transform);
        assert_eq!(d.chosen, Implementation::EllRowOuter);
        assert_eq!(d.d_mat, 0.0);
    }

    #[test]
    fn memplus_stays_on_crs_under_scalar_table() {
        let spec = spec_by_name("memplus").unwrap();
        let a = generate(&spec, 2, 0.05);
        let d = decide(&a, &tuning(Some(0.1)));
        assert!(!d.transform);
        assert_eq!(d.chosen, Implementation::CsrSeq);
        assert!(d.d_mat > 0.1);
    }

    #[test]
    fn no_threshold_never_transforms() {
        let mut rng = Rng::new(2);
        let a = banded_circulant(&mut rng, 50, &[0, 1]);
        let d = decide(&a, &tuning(None));
        assert!(!d.transform);
        assert!(d.d_star.is_nan());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("spmv_at_tuning_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.tsv");
        for t in [tuning(Some(0.25)), tuning(None)] {
            t.save(&p).unwrap();
            let back = TuningData::load(&p).unwrap();
            assert_eq!(t, back);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("spmv_at_tuning_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.tsv");
        std::fs::write(&p, "not a tuning file\n").unwrap();
        assert!(TuningData::load(&p).is_err());
        std::fs::write(&p, "spmv-at-tuning v1\nbackend\tx\n").unwrap();
        assert!(TuningData::load(&p).is_err(), "missing keys must fail");
        std::fs::remove_file(&p).ok();
    }
}
