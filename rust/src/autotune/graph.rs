//! The `D_mat`–`R_ell` graph (paper §2.2 step 3–4 and Fig. 8) and the
//! `D*` threshold extraction.
//!
//! Offline phase step (4): *"Find the largest point of the X-axis such
//! that `R_ell^i ≥ c` for i = 1,…,m. This point of the X-axis is denoted
//! `D*`."* Two readings are implemented:
//!
//! * [`DrGraph::d_star`] — the paper-literal rule: the largest `D_mat`
//!   among points with `R ≥ c`.
//! * [`DrGraph::d_star_conservative`] — the largest `D` such that *every*
//!   point with `D_mat ≤ D` has `R ≥ c` (no failing point inside the
//!   accepted region). The `ablation` bench compares the two.
//!
//! §4.5's "the graph can be well modeled" is realised by
//! [`DrGraph::fit_power_law`]: an `R ≈ a·D^b` least-squares fit in
//! log-log space, from which a model-based threshold `(c/a)^(1/b)` falls
//! out.

use crate::metrics::Json;

/// One matrix's point on the graph.
#[derive(Clone, Debug, PartialEq)]
pub struct DrPoint {
    /// Matrix label (Table-1 name).
    pub name: String,
    /// X: `D_mat = σ/μ`.
    pub d_mat: f64,
    /// Y: `R_ell = SP / TT`.
    pub r_ell: f64,
}

/// The `D_mat`–`R_ell` scatter for one machine × implementation.
#[derive(Clone, Debug, Default)]
pub struct DrGraph {
    /// Points, in insertion order.
    pub points: Vec<DrPoint>,
}

/// Power-law fit `R ≈ a·D^b` (log-log least squares).
#[derive(Clone, Copy, Debug)]
pub struct PowerLawFit {
    /// Coefficient `a`.
    pub a: f64,
    /// Exponent `b` (negative when transformation value decays with `D`).
    pub b: f64,
    /// Coefficient of determination in log space.
    pub r2: f64,
}

impl PowerLawFit {
    /// The `D` at which the fitted model crosses `R = c`.
    pub fn threshold(&self, c: f64) -> f64 {
        if self.b.abs() < 1e-12 {
            return if self.a >= c { f64::INFINITY } else { 0.0 };
        }
        (c / self.a).powf(1.0 / self.b)
    }

    /// Model prediction at `d`.
    pub fn predict(&self, d: f64) -> f64 {
        self.a * d.powf(self.b)
    }
}

impl DrGraph {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a point.
    pub fn push(&mut self, name: impl Into<String>, d_mat: f64, r_ell: f64) {
        self.points.push(DrPoint { name: name.into(), d_mat, r_ell });
    }

    /// Points with finite coordinates (ELL may be excluded for a matrix —
    /// the paper dropped torso1 — yielding NaN/∞ entries to skip).
    fn finite(&self) -> impl Iterator<Item = &DrPoint> {
        self.points.iter().filter(|p| p.d_mat.is_finite() && p.r_ell.is_finite())
    }

    /// Paper-literal `D*`: the largest `D_mat` whose point has `R ≥ c`.
    /// `None` when no point qualifies (never transform).
    pub fn d_star(&self, c: f64) -> Option<f64> {
        self.finite()
            .filter(|p| p.r_ell >= c)
            .map(|p| p.d_mat)
            .fold(None, |acc, d| Some(acc.map_or(d, |a: f64| a.max(d))))
    }

    /// Conservative `D*`: the largest `D` such that every point with
    /// `d_mat ≤ D` satisfies `R ≥ c`.
    pub fn d_star_conservative(&self, c: f64) -> Option<f64> {
        let mut pts: Vec<&DrPoint> = self.finite().collect();
        pts.sort_by(|a, b| a.d_mat.partial_cmp(&b.d_mat).unwrap());
        let mut best: Option<f64> = None;
        for p in pts {
            if p.r_ell >= c {
                best = Some(p.d_mat);
            } else {
                break;
            }
        }
        best
    }

    /// Least-squares power-law fit in log-log space over points with
    /// strictly positive coordinates. `None` with fewer than 2 usable
    /// points.
    pub fn fit_power_law(&self) -> Option<PowerLawFit> {
        let pts: Vec<(f64, f64)> = self
            .finite()
            .filter(|p| p.d_mat > 0.0 && p.r_ell > 0.0)
            .map(|p| (p.d_mat.ln(), p.r_ell.ln()))
            .collect();
        if pts.len() < 2 {
            return None;
        }
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let denom = n * sxx - sx * sx;
        if denom.abs() < 1e-12 {
            return None;
        }
        let b = (n * sxy - sx * sy) / denom;
        let ln_a = (sy - b * sx) / n;
        // R² in log space.
        let mean_y = sy / n;
        let ss_tot: f64 = pts.iter().map(|p| (p.1 - mean_y).powi(2)).sum();
        let ss_res: f64 = pts
            .iter()
            .map(|p| (p.1 - (ln_a + b * p.0)).powi(2))
            .sum();
        let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
        Some(PowerLawFit { a: ln_a.exp(), b, r2 })
    }

    /// Render as an aligned text table sorted by `D_mat` (the repo's
    /// stand-in for the paper's Fig. 8 scatter plot).
    pub fn render(&self, c: f64) -> String {
        let mut pts = self.points.clone();
        pts.sort_by(|a, b| a.d_mat.partial_cmp(&b.d_mat).unwrap());
        let mut t = crate::metrics::Table::new(vec![
            "matrix".to_string(),
            "D_mat".to_string(),
            "R_ell".to_string(),
            format!("R>={c}"),
        ]);
        for p in &pts {
            t.row(vec![
                p.name.clone(),
                format!("{:.3}", p.d_mat),
                format!("{:.3}", p.r_ell),
                if p.r_ell >= c { "yes".into() } else { "no".to_string() },
            ]);
        }
        let mut out = t.render();
        match self.d_star(c) {
            Some(d) => out.push_str(&format!("D* = {d:.3} (c = {c})\n")),
            None => out.push_str(&format!("D* = none (no point with R >= {c})\n")),
        }
        out
    }

    /// JSON dump for machine-readable bench output.
    pub fn to_json(&self, c: f64) -> Json {
        Json::Obj(vec![
            ("c".into(), Json::Num(c)),
            (
                "d_star".into(),
                self.d_star(c).map_or(Json::Null, Json::Num),
            ),
            (
                "points".into(),
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::Obj(vec![
                                ("name".into(), Json::Str(p.name.clone())),
                                ("d_mat".into(), Json::Num(p.d_mat)),
                                ("r_ell".into(), Json::Num(p.r_ell)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(points: &[(f64, f64)]) -> DrGraph {
        let mut g = DrGraph::new();
        for (i, &(d, r)) in points.iter().enumerate() {
            g.push(format!("m{i}"), d, r);
        }
        g
    }

    #[test]
    fn d_star_literal_takes_max_qualifying() {
        let g = graph(&[(0.02, 50.0), (0.5, 2.0), (1.2, 0.5), (3.1, 1.5)]);
        // Literal: the 3.1 point qualifies even though 1.2 fails.
        assert_eq!(g.d_star(1.0), Some(3.1));
        // Conservative stops at the first failure.
        assert_eq!(g.d_star_conservative(1.0), Some(0.5));
    }

    #[test]
    fn d_star_none_when_all_fail() {
        let g = graph(&[(0.1, 0.2), (0.5, 0.9)]);
        assert_eq!(g.d_star(1.0), None);
        assert_eq!(g.d_star_conservative(1.0), None);
    }

    #[test]
    fn non_finite_points_ignored() {
        let mut g = graph(&[(0.1, 5.0)]);
        g.push("torso1-excluded", 5.72, f64::NAN);
        g.push("free", 0.2, f64::INFINITY);
        assert_eq!(g.d_star(1.0), Some(0.1));
    }

    #[test]
    fn power_law_fit_recovers_exact_relation() {
        // R = 2 * D^-1.5 exactly.
        let pts: Vec<(f64, f64)> =
            [0.02f64, 0.1, 0.5, 1.0, 3.0].iter().map(|&d| (d, 2.0 * d.powf(-1.5))).collect();
        let g = graph(&pts);
        let f = g.fit_power_law().unwrap();
        assert!((f.a - 2.0).abs() < 1e-9, "a = {}", f.a);
        assert!((f.b + 1.5).abs() < 1e-9, "b = {}", f.b);
        assert!(f.r2 > 0.999);
        // Threshold where 2 D^-1.5 = 1 -> D = 2^(2/3).
        let th = f.threshold(1.0);
        assert!((th - 2f64.powf(2.0 / 3.0)).abs() < 1e-9, "threshold {th}");
        assert!((f.predict(1.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fit_requires_two_points() {
        assert!(graph(&[(0.5, 2.0)]).fit_power_law().is_none());
        assert!(graph(&[]).fit_power_law().is_none());
    }

    #[test]
    fn render_contains_threshold_line() {
        let g = graph(&[(0.1, 5.0), (2.0, 0.1)]);
        let s = g.render(1.0);
        assert!(s.contains("D* = 0.100"), "{s}");
        assert!(s.contains("yes"));
        assert!(s.contains("no"));
    }

    #[test]
    fn json_dump_shape() {
        let g = graph(&[(0.1, 5.0)]);
        let s = g.to_json(1.0).render();
        assert!(s.contains("\"d_star\":0.1"));
        assert!(s.contains("\"points\""));
    }
}
