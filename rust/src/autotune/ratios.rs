//! The paper's cost ratios (Eqs. 1–3).
//!
//! * `SP_crs/ell = t_crs / t_ell` — the SpMV speedup (Eq. 1).
//! * `TT_ell` — the transformation overhead in units of one CRS SpMV.
//!   **Note on the paper's Eq. (2):** the equation as printed reads
//!   `TT = t_crs / t_trans`, but the paper's own Fig. 7 ("TT_ell indicates
//!   the data transformation overheads based on one time of SpMV with
//!   CRS", with values of 20×–50× for expensive transforms) and the
//!   `c = 1.0` calibration example ("10× speedup … if and only if the
//!   transformation time to SpMV in CRS is 10") both require the
//!   *reciprocal*, `TT = t_trans / t_crs`. We implement the
//!   figure-consistent semantics.
//! * `R_ell = SP / TT` (Eq. 3) — speedup per unit of transformation
//!   overhead. `R ≥ c = 1.0` means the transformation pays for itself
//!   within `SP` iterations (§2.2's discussion: a 10× speedup amortises a
//!   10-SpMV transformation).

use crate::Value;

/// The (SP, TT, R) triple for one matrix × implementation × machine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ratios {
    /// `SP = t_crs / t_imp` — SpMV speedup over the CRS baseline (Eq. 1).
    pub sp: f64,
    /// `TT = t_trans / t_crs` — transformation overhead in CRS-SpMV units
    /// (Fig. 7 semantics; see module docs).
    pub tt: f64,
    /// `R = SP / TT` (Eq. 3).
    pub r: f64,
}

impl Ratios {
    /// Build from raw seconds. `t_trans == 0` (no transformation) yields
    /// `TT = 0`, `R = +inf` — "free" optimisation always amortises.
    pub fn from_times(t_crs: f64, t_imp: f64, t_trans: f64) -> Self {
        assert!(t_crs > 0.0, "t_crs must be positive, got {t_crs}");
        assert!(t_imp > 0.0, "t_imp must be positive, got {t_imp}");
        assert!(t_trans >= 0.0, "t_trans must be non-negative, got {t_trans}");
        let sp = t_crs / t_imp;
        let tt = t_trans / t_crs;
        let r = if tt > 0.0 { sp / tt } else { f64::INFINITY };
        Self { sp, tt, r }
    }

    /// Break-even iteration count: how many SpMVs must run before the
    /// transformed format has repaid `t_trans` (∞ if there is no speedup).
    /// This is the §2.2 "iteration time needed to take advantage of the
    /// transformation effect".
    pub fn break_even_iterations(&self) -> f64 {
        if self.sp <= 1.0 {
            f64::INFINITY
        } else {
            // Each iteration saves t_crs·(1 − 1/SP); transform costs t_crs·TT.
            self.tt / (1.0 - 1.0 / self.sp)
        }
    }

    /// Total time (in units of `t_crs`) for `iters` SpMVs including the
    /// transformation — the quantity an iterative solver actually pays.
    pub fn total_cost(&self, iters: usize) -> f64 {
        self.tt + iters as Value / self.sp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_example() {
        // "a 10x speedup … if and only if the transformation time to SpMV
        // in CRS is 10" defines R = 1.0.
        let r = Ratios::from_times(1.0, 0.1, 10.0);
        assert!((r.sp - 10.0).abs() < 1e-12);
        assert!((r.tt - 10.0).abs() < 1e-12);
        assert!((r.r - 1.0).abs() < 1e-12);
    }

    #[test]
    fn free_transform_has_infinite_r() {
        let r = Ratios::from_times(1.0, 0.5, 0.0);
        assert_eq!(r.tt, 0.0);
        assert!(r.r.is_infinite());
        assert_eq!(r.break_even_iterations(), 0.0);
    }

    #[test]
    fn break_even_matches_discussion() {
        // 1000x speedup, R = 1 -> TT = 1000 -> ~1000 iterations needed
        // (the §2.2 "enormous iteration time" example).
        let r = Ratios::from_times(1.0, 1e-3, 1000.0);
        assert!((r.r - 1.0).abs() < 1e-9);
        let be = r.break_even_iterations();
        assert!((be - 1001.0).abs() < 1.0, "break-even {be}");
    }

    #[test]
    fn slowdown_never_breaks_even() {
        let r = Ratios::from_times(1.0, 2.0, 0.5);
        assert!(r.sp < 1.0);
        assert!(r.break_even_iterations().is_infinite());
    }

    #[test]
    fn total_cost_crossover() {
        // SP=2, TT=4: transformed path wins once iters/1 > iters/2 + 4,
        // i.e. after 8 iterations.
        let r = Ratios::from_times(1.0, 0.5, 4.0);
        let baseline = |iters: usize| iters as f64; // CRS cost in t_crs units
        assert!(r.total_cost(7) > baseline(7));
        assert!(r.total_cost(9) < baseline(9));
    }

    #[test]
    #[should_panic(expected = "t_crs must be positive")]
    fn rejects_zero_tcrs() {
        let _ = Ratios::from_times(0.0, 1.0, 1.0);
    }
}
