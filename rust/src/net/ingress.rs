//! Ingress queues and the cross-request batch coalescer.
//!
//! Network sessions do not call the serving loops directly for
//! single-vector SpMV. They submit to a bounded per-shard ingress queue;
//! a coalescer thread per shard drains whatever has accumulated, groups
//! the pending requests by matrix key (arrival order preserved), and
//! issues one [`Client::spmv_batch`] per group — so `k` concurrent
//! requests against the same matrix become one tiled SpMM that streams
//! the matrix ⌈k/tile⌉ times instead of `k`. The batched result is
//! scattered back to the per-request response channels; because the
//! batch path and the single path run the same kernels over the same
//! plan, the scattered vectors are bitwise identical to serving each
//! request alone.
//!
//! Batching needs no timer to happen: while the shard executes one
//! batch, new arrivals accumulate in the queue and the next drain picks
//! them all up. [`NetConfig::coalesce_wait`](super::NetConfig) can add a
//! deliberate post-first-arrival wait for latency-tolerant, throughput-
//! hungry deployments (default 0). The wait is interruptible: it is a
//! `recv_timeout` loop on the ingress channel, so arrivals mid-wait join
//! the batch immediately and dropping the [`Ingress`] (shutdown) cuts
//! the wait short instead of stalling a full wait per shard.
//!
//! Backpressure is explicit and non-blocking: `submit` uses `try_send`,
//! and a full queue is an admission reject — the session answers the
//! client with `Busy` instead of parking the socket reader on a queue
//! that may stay full. Deadlines are enforced at drain time: a request
//! whose deadline has already expired when the coalescer assembles the
//! batch is shed ([`ServeOutcome::Shed`], counted in
//! [`NetCounters::deadline_sheds`]) rather than burning a batch slot on
//! a result the client has stopped waiting for.

use crate::coordinator::shards::route_key;
use crate::coordinator::Client;
use crate::{Result, Value};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// `SPMV_AT_NET_QUEUE` — ingress queue depth per shard (default 256,
/// floor 1). Requests beyond this bound are refused with `Busy`.
pub fn configured_queue_depth() -> usize {
    std::env::var("SPMV_AT_NET_QUEUE")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(256)
        .max(1)
}

/// `SPMV_AT_COALESCE_WAIT_US` — microseconds the coalescer waits after
/// the first arrival before draining, to let more requests land in the
/// same batch (default 0: drain immediately; batching still happens
/// whenever the shard is busy, because arrivals queue behind the
/// in-flight batch).
pub fn configured_coalesce_wait() -> Duration {
    Duration::from_micros(
        std::env::var("SPMV_AT_COALESCE_WAIT_US")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(0),
    )
}

/// Shared serving-front counters (sessions, batches, admission rejects,
/// deadline sheds). All loads/stores are relaxed: these are monotonic
/// telemetry, not synchronization.
#[derive(Debug, Default)]
pub struct NetCounters {
    /// Sessions currently open.
    pub sessions_open: AtomicU64,
    /// Sessions accepted over the listener's lifetime.
    pub sessions_total: AtomicU64,
    /// Coalescer dispatches (one per matrix-key group, singletons included).
    pub batches: AtomicU64,
    /// Requests served through the coalescer.
    pub requests: AtomicU64,
    /// Dispatches that coalesced ≥ 2 requests into one batch call.
    pub coalesced_batches: AtomicU64,
    /// Requests served inside those coalesced dispatches.
    pub coalesced_requests: AtomicU64,
    /// Requests refused with `Busy` because the ingress queue was full.
    pub admission_rejects: AtomicU64,
    /// Largest single dispatch so far.
    pub max_batch: AtomicU64,
    /// Requests shed at drain time because their deadline had expired.
    pub deadline_sheds: AtomicU64,
    /// Fresh per-session key interns (not on the wire). Sessions intern
    /// each matrix name into an `Arc<str>` once; the coalescer hot path
    /// then clones the `Arc` instead of allocating a `String` per
    /// request, and the loadgen bench asserts this stays O(sessions ×
    /// keys), not O(requests).
    pub key_interns: AtomicU64,
}

impl NetCounters {
    /// Mean requests per coalescer dispatch — the measured coalescing
    /// factor. 1.0 means no cross-request batching happened; `k` means
    /// the matrix-streaming cost of serving was cut by about `k` (up to
    /// tile granularity).
    pub fn coalescing_factor(&self) -> f64 {
        let batches = self.batches.load(Ordering::Relaxed);
        if batches == 0 {
            return 1.0;
        }
        self.requests.load(Ordering::Relaxed) as f64 / batches as f64
    }

    /// Snapshot for the wire (`NetStats` reply).
    pub fn snapshot(&self) -> super::proto::WireNetStats {
        super::proto::WireNetStats {
            sessions_open: self.sessions_open.load(Ordering::Relaxed),
            sessions_total: self.sessions_total.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            coalesced_batches: self.coalesced_batches.load(Ordering::Relaxed),
            coalesced_requests: self.coalesced_requests.load(Ordering::Relaxed),
            admission_rejects: self.admission_rejects.load(Ordering::Relaxed),
            max_batch: self.max_batch.load(Ordering::Relaxed),
            deadline_sheds: self.deadline_sheds.load(Ordering::Relaxed),
        }
    }
}

/// What happened to one queued request.
#[derive(Debug)]
pub enum ServeOutcome {
    /// The batch ran; this is the request's slice of the result (or the
    /// serving error).
    Done(Result<Vec<Value>>),
    /// The request's deadline expired before the coalescer drained it;
    /// no kernel ran for it. The session answers
    /// [`super::proto::ERR_DEADLINE_EXCEEDED`].
    Shed,
}

/// One queued single-vector request waiting to be coalesced. The key is
/// a session-interned `Arc<str>` so admission never allocates.
struct Pending {
    key: Arc<str>,
    x: Vec<Value>,
    resp: mpsc::Sender<ServeOutcome>,
    deadline: Option<Instant>,
}

/// Cheap, cloneable submission front over the per-shard ingress queues.
/// Sessions hold one each; requests are routed by the same
/// [`route_key`] hash the serving client uses, so a shard's coalescer
/// only ever batches work that shard serves.
#[derive(Clone)]
pub struct Ingress {
    txs: Vec<mpsc::SyncSender<Pending>>,
    counters: Arc<NetCounters>,
}

impl Ingress {
    /// Queue a single-vector request. `deadline` is the instant after
    /// which the coalescer sheds instead of serving it (`None` = no
    /// deadline). Returns the channel the outcome will arrive on, or
    /// `None` if the shard's queue is full (an admission reject — reply
    /// `Busy`, do not block). The key is cloned by `Arc`, never
    /// reallocated, on this hot path.
    pub fn submit(
        &self,
        key: &Arc<str>,
        x: Vec<Value>,
        deadline: Option<Instant>,
    ) -> Option<mpsc::Receiver<ServeOutcome>> {
        let (resp, rx) = mpsc::channel();
        let shard = route_key(key, self.txs.len()) as usize;
        match self.txs[shard].try_send(Pending { key: Arc::clone(key), x, resp, deadline }) {
            Ok(()) => Some(rx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.counters.admission_rejects.fetch_add(1, Ordering::Relaxed);
                None
            }
            Err(mpsc::TrySendError::Disconnected(p)) => {
                // Coalescer gone (server shutting down): fail the request
                // through its own channel rather than lying with `Busy`.
                let _ = p.resp.send(ServeOutcome::Done(Err(anyhow::anyhow!("server stopped"))));
                Some(rx)
            }
        }
    }

    /// The shared counters (for sessions to bump and report).
    pub fn counters(&self) -> &Arc<NetCounters> {
        &self.counters
    }
}

/// Owner of the coalescer threads; joining it is bounded even while
/// detached sessions still hold [`Ingress`] clones, because the drain
/// loop re-checks the stop flag every 50 ms and the coalesce wait itself
/// is interruptible.
pub struct CoalescerSet {
    stop: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl CoalescerSet {
    /// Signal and join all coalescer threads.
    pub fn join(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Spawn one coalescer thread per serving shard, each owning the
/// receiving end of that shard's bounded ingress queue.
pub fn spawn_coalescers(
    client: &Client,
    queue_depth: usize,
    coalesce_wait: Duration,
    counters: Arc<NetCounters>,
) -> (Ingress, CoalescerSet) {
    let stop = Arc::new(AtomicBool::new(false));
    let mut txs = Vec::new();
    let mut handles = Vec::new();
    for shard in 0..client.shards() {
        let (tx, rx) = mpsc::sync_channel::<Pending>(queue_depth.max(1));
        txs.push(tx);
        let client = client.clone();
        let counters = Arc::clone(&counters);
        let stop = Arc::clone(&stop);
        handles.push(
            std::thread::Builder::new()
                .name(format!("spmv-coalesce-{shard}"))
                .spawn(move || loop {
                    match rx.recv_timeout(Duration::from_millis(50)) {
                        Ok(first) => {
                            let mut batch = vec![first];
                            if !coalesce_wait.is_zero() {
                                // Interruptible wait: arrivals join the
                                // batch as they land, and a dropped
                                // ingress (shutdown) ends the wait at
                                // once — a plain `thread::sleep` here
                                // would do neither.
                                let wait_until = Instant::now() + coalesce_wait;
                                loop {
                                    let left =
                                        wait_until.saturating_duration_since(Instant::now());
                                    if left.is_zero() || stop.load(Ordering::Relaxed) {
                                        break;
                                    }
                                    match rx.recv_timeout(left) {
                                        Ok(p) => batch.push(p),
                                        Err(_) => break, // timeout or disconnected
                                    }
                                }
                            }
                            while let Ok(p) = rx.try_recv() {
                                batch.push(p);
                            }
                            dispatch(&client, batch, &counters);
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if stop.load(Ordering::Relaxed) {
                                break;
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                })
                .expect("spawn coalescer thread"),
        );
    }
    (Ingress { txs, counters }, CoalescerSet { stop, handles })
}

/// Shed expired requests, group the rest of one drain by matrix key
/// (arrival order preserved), and serve each group with a single batch
/// call, scattering results to waiters. The deadline check happens here,
/// at drain time: a shed request consumes no batch slot and no kernel
/// time, and a drain whose every request expired issues no batch call at
/// all.
fn dispatch(client: &Client, batch: Vec<Pending>, counters: &NetCounters) {
    let now = Instant::now();
    let mut groups: Vec<(Arc<str>, Vec<Pending>)> = Vec::new();
    for p in batch {
        if p.deadline.is_some_and(|d| now >= d) {
            counters.deadline_sheds.fetch_add(1, Ordering::Relaxed);
            let _ = p.resp.send(ServeOutcome::Shed);
            continue;
        }
        match groups.iter_mut().find(|(k, _)| **k == *p.key) {
            Some((_, g)) => g.push(p),
            None => {
                let key = Arc::clone(&p.key);
                groups.push((key, vec![p]));
            }
        }
    }
    for (key, group) in groups {
        let k = group.len() as u64;
        counters.batches.fetch_add(1, Ordering::Relaxed);
        counters.requests.fetch_add(k, Ordering::Relaxed);
        if k >= 2 {
            counters.coalesced_batches.fetch_add(1, Ordering::Relaxed);
            counters.coalesced_requests.fetch_add(k, Ordering::Relaxed);
        }
        counters.max_batch.fetch_max(k, Ordering::Relaxed);
        let (xs, resps): (Vec<_>, Vec<_>) = group.into_iter().map(|p| (p.x, p.resp)).unzip();
        match client.spmv_batch(&key, xs) {
            Ok(ys) => {
                for (y, resp) in ys.into_iter().zip(resps) {
                    let _ = resp.send(ServeOutcome::Done(Ok(y)));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for resp in resps {
                    let _ = resp.send(ServeOutcome::Done(Err(anyhow::anyhow!("{msg}"))));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, CoordinatorConfig, Server};
    use crate::formats::Csr;

    fn serving_client() -> (Server, Client) {
        let tuning = crate::autotune::online::TuningData {
            backend: "sim:ES2".into(),
            imp: crate::spmv::Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        };
        let mut cfg = CoordinatorConfig::new(tuning);
        cfg.threads = 2;
        cfg.adaptive.enabled = false;
        Server::spawn(Coordinator::new(cfg), 32)
    }

    fn done(out: ServeOutcome) -> Result<Vec<Value>> {
        match out {
            ServeOutcome::Done(r) => r,
            ServeOutcome::Shed => panic!("unexpected shed"),
        }
    }

    #[test]
    fn coalesced_results_match_direct_serving() {
        let (server, client) = serving_client();
        client.register("i", Csr::identity(6)).unwrap();
        let counters = Arc::new(NetCounters::default());
        let (ingress, set) =
            spawn_coalescers(&client, 16, Duration::from_millis(0), Arc::clone(&counters));

        let x: Vec<Value> = (0..6).map(|i| i as Value + 0.5).collect();
        let key: Arc<str> = Arc::from("i");
        let rx = ingress.submit(&key, x.clone(), None).expect("queue not full");
        let y = done(rx.recv().unwrap()).unwrap();
        assert_eq!(y, client.spmv("i", x).unwrap());
        assert_eq!(counters.requests.load(Ordering::Relaxed), 1);
        assert!(counters.coalescing_factor() >= 1.0);

        set.join();
        server.shutdown();
    }

    #[test]
    fn unknown_matrix_fails_each_waiter_not_the_coalescer() {
        let (server, client) = serving_client();
        let counters = Arc::new(NetCounters::default());
        let (ingress, set) =
            spawn_coalescers(&client, 16, Duration::from_millis(0), Arc::clone(&counters));

        let nope: Arc<str> = Arc::from("nope");
        let rx = ingress.submit(&nope, vec![1.0], None).expect("queue not full");
        assert!(done(rx.recv().unwrap()).is_err());

        // The coalescer survives a failed dispatch and serves the next one.
        client.register("i", Csr::identity(3)).unwrap();
        let key: Arc<str> = Arc::from("i");
        let rx = ingress.submit(&key, vec![1.0, 2.0, 3.0], None).expect("queue not full");
        assert_eq!(done(rx.recv().unwrap()).unwrap(), vec![1.0, 2.0, 3.0]);

        set.join();
        server.shutdown();
    }

    #[test]
    fn expired_deadlines_are_shed_at_drain_time_without_serving() {
        let (server, client) = serving_client();
        client.register("i", Csr::identity(3)).unwrap();
        let counters = Arc::new(NetCounters::default());
        // A 50 ms coalesce wait guarantees the drain happens well after
        // an already-expired deadline, deterministically.
        let (ingress, set) =
            spawn_coalescers(&client, 16, Duration::from_millis(50), Arc::clone(&counters));

        let key: Arc<str> = Arc::from("i");
        let expired = Some(Instant::now() - Duration::from_millis(1));
        let rx = ingress.submit(&key, vec![1.0, 2.0, 3.0], expired).expect("queue not full");
        assert!(matches!(rx.recv().unwrap(), ServeOutcome::Shed));
        assert_eq!(counters.deadline_sheds.load(Ordering::Relaxed), 1);
        // The shed request burned no batch slot: nothing was served.
        assert_eq!(counters.batches.load(Ordering::Relaxed), 0);
        assert_eq!(counters.requests.load(Ordering::Relaxed), 0);

        // A live request on the same channel still serves.
        let rx = ingress.submit(&key, vec![1.0, 2.0, 3.0], None).expect("queue not full");
        assert_eq!(done(rx.recv().unwrap()).unwrap(), vec![1.0, 2.0, 3.0]);

        set.join();
        server.shutdown();
    }

    #[test]
    fn dropping_the_ingress_interrupts_the_coalesce_wait() {
        let (server, client) = serving_client();
        client.register("i", Csr::identity(2)).unwrap();
        let counters = Arc::new(NetCounters::default());
        // A wait long enough that a non-interruptible sleep would be
        // caught by the elapsed-time assertion below.
        let (ingress, set) =
            spawn_coalescers(&client, 16, Duration::from_secs(5), Arc::clone(&counters));

        let key: Arc<str> = Arc::from("i");
        let rx = ingress.submit(&key, vec![1.0, 2.0], None).expect("queue not full");
        let t0 = Instant::now();
        drop(ingress); // all senders gone → the wait's recv_timeout disconnects
        set.join();
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "shutdown stalled on the coalesce wait: {:?}",
            t0.elapsed()
        );
        // The pending request was still dispatched on the way out.
        assert_eq!(done(rx.recv().unwrap()).unwrap(), vec![1.0, 2.0]);

        server.shutdown();
    }
}
