//! Per-connection session: a hello-first state machine over framed
//! protocol messages.
//!
//! Each accepted connection gets one session thread running
//! [`run_session`] over any `Read + Write` stream (TCP, Unix socket, or
//! an in-memory pipe in tests). The state machine is strict about the
//! handshake — the first frame must be a `Hello` whose version falls in
//! the server's `[MIN_VERSION, VERSION]` window (and, when the server
//! requires one, whose auth token matches), anything else closes the
//! connection — and lenient after it: a frame that *decodes* badly gets
//! an `Error` reply and the session keeps serving, because the length
//! prefix already delimited the bad frame and stream framing is intact.
//! Only transport-level damage (EOF inside a frame, an oversized length
//! prefix) ends the session — after such damage the remaining bytes on
//! the wire are unframed, so no reply could be delivered intelligibly
//! and any attempt to resync would parse garbage; the connection is
//! hard-closed without a reply.
//!
//! The session serves at the *client's* version: a v1 client gets v1
//! frame layouts byte-for-byte (no deadline field, no `deadline_sheds`
//! counter, no decision-log opcodes), a v2 client gets the full
//! protocol. Matrix names are interned once per session into `Arc<str>`
//! keys so the coalescer admission path never allocates per request.
//!
//! Single-vector `Spmv` requests go through the ingress coalescer; every
//! other request calls the serving [`Client`] directly. A full ingress
//! queue — or a spent per-session request/byte quota — is answered with
//! `Busy`; the reader thread never blocks on admission.

use super::ingress::{Ingress, ServeOutcome};
use super::proto::{self, Message, WireStatsRow};
use crate::coordinator::decision_log::DecisionLog;
use crate::coordinator::{Client, EntryStats};
use crate::formats::Csr;
use crate::Result;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How many decision-log records a `DecisionLog` wire request returns at
/// most (the tail of the log).
pub const DECISION_LOG_WIRE_LIMIT: usize = 256;

/// `SPMV_AT_NET_QUOTA_REQS` — requests one session may issue before
/// every further request is refused with `Busy` (default 0 = unlimited).
pub fn configured_quota_requests() -> u64 {
    std::env::var("SPMV_AT_NET_QUOTA_REQS").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// `SPMV_AT_NET_QUOTA_BYTES` — request-payload bytes one session may
/// send before every further request is refused with `Busy` (default 0
/// = unlimited).
pub fn configured_quota_bytes() -> u64 {
    std::env::var("SPMV_AT_NET_QUOTA_BYTES").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// `SPMV_AT_NET_AUTH` — when set and non-empty, the auth token every v2
/// `Hello` must present; v1 clients (which cannot carry a token) are
/// refused outright (default unset = open server).
pub fn configured_auth_token() -> Option<String> {
    std::env::var("SPMV_AT_NET_AUTH").ok().filter(|t| !t.is_empty())
}

/// Per-session serving policy, built from
/// [`NetConfig`](super::NetConfig) by the accept loop and cloned into
/// each session thread.
#[derive(Clone, Default)]
pub struct SessionPolicy {
    /// Required auth token (None = open server).
    pub auth_token: Option<String>,
    /// Per-session request budget (0 = unlimited).
    pub quota_requests: u64,
    /// Per-session request-payload byte budget (0 = unlimited).
    pub quota_bytes: u64,
    /// Decision log served to `DecisionLog` wire requests (None = the
    /// request answers with an empty tail).
    pub decision_log: Option<DecisionLog>,
}

/// Mutable per-session state: the negotiated version, the key intern
/// table, and the quota spend.
struct SessionState {
    version: u16,
    interned: HashMap<String, Arc<str>>,
    spent_requests: u64,
    spent_bytes: u64,
}

/// Serve one connection until the peer disconnects or the transport
/// fails. Returns `Ok` for clean closes (including a rejected
/// handshake); `Err` only for transport-level failures.
pub fn run_session<S: Read + Write>(
    mut stream: S,
    client: Client,
    ingress: Ingress,
    policy: SessionPolicy,
) -> Result<()> {
    // Handshake: the first frame must be a Hello inside the version
    // window. Hello is self-describing (its body carries its own version
    // field), so decoding at the current version handles every client.
    let payload = match proto::read_frame(&mut stream)? {
        Some(p) => p,
        None => return Ok(()),
    };
    let version = match proto::decode(&payload) {
        Ok((id, Message::Hello { version, auth })) => {
            if !(proto::MIN_VERSION..=proto::VERSION).contains(&version) {
                send(
                    &mut stream,
                    id,
                    &Message::Error {
                        code: proto::ERR_UNSUPPORTED_VERSION,
                        message: format!(
                            "client speaks protocol version {version}, this server serves {}..={}",
                            proto::MIN_VERSION,
                            proto::VERSION
                        ),
                    },
                    // Error bodies are layout-identical in every version.
                    proto::VERSION,
                )?;
                return Ok(());
            }
            if let Some(required) = &policy.auth_token {
                if version < 2 || auth != *required {
                    send(
                        &mut stream,
                        id,
                        &Message::Error {
                            code: proto::ERR_UNAUTHORIZED,
                            message: if version < 2 {
                                "this server requires an auth token; protocol v1 cannot carry one"
                                    .into()
                            } else {
                                "auth token missing or not recognised".into()
                            },
                        },
                        version,
                    )?;
                    return Ok(());
                }
            }
            // Negotiation: serve at the client's version (the minimum of
            // the two sides' maxima) and advertise the full window. The
            // ack is self-describing, so a v1 client receives exactly
            // the 2-byte v1 body.
            send(
                &mut stream,
                id,
                &Message::HelloAck {
                    version,
                    min: proto::MIN_VERSION,
                    max: proto::VERSION,
                },
                version,
            )?;
            version
        }
        Ok((id, _)) => {
            send(
                &mut stream,
                id,
                &Message::Error {
                    code: proto::ERR_MALFORMED,
                    message: "the first frame on a connection must be Hello".into(),
                },
                proto::VERSION,
            )?;
            return Ok(());
        }
        Err(e) => {
            send(&mut stream, 0, &decode_error(&payload, &e, proto::VERSION), proto::VERSION)?;
            return Ok(());
        }
    };

    let mut state = SessionState {
        version,
        interned: HashMap::new(),
        spent_requests: 0,
        spent_bytes: 0,
    };

    // Request loop: decode errors reply and continue; transport errors
    // (including an oversized length prefix, which leaves unframed bytes
    // on the wire) hard-close without a reply — see the module docs.
    loop {
        let payload = match proto::read_frame(&mut stream) {
            Ok(Some(p)) => p,
            Ok(None) => break,
            Err(e) => return Err(e),
        };
        // Quota spend is charged per frame, decodable or not, before any
        // serving work: identity is the session, budgets are session-
        // scoped, and a reconnect starts fresh.
        state.spent_requests += 1;
        state.spent_bytes += payload.len() as u64;
        let over_quota = (policy.quota_requests > 0 && state.spent_requests > policy.quota_requests)
            || (policy.quota_bytes > 0 && state.spent_bytes > policy.quota_bytes);
        match proto::decode_versioned(&payload, state.version) {
            Ok((id, msg)) => {
                let reply = if over_quota {
                    Message::Busy
                } else {
                    handle(&client, &ingress, &policy, &mut state, msg)
                };
                send(&mut stream, id, &reply, state.version)?;
            }
            Err(e) => {
                // Best-effort request-id echo so a pipelining client can
                // still match the error to its request.
                let id = payload
                    .get(1..5)
                    .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                    .unwrap_or(0);
                let reply = if over_quota {
                    Message::Busy
                } else {
                    decode_error(&payload, &e, state.version)
                };
                send(&mut stream, id, &reply, state.version)?;
            }
        }
    }
    Ok(())
}

/// Map a decode failure to the right error code: unknown opcode if the
/// opcode byte itself is unrecognised at this session's version,
/// malformed otherwise.
fn decode_error(payload: &[u8], e: &anyhow::Error, version: u16) -> Message {
    let code = match payload.first() {
        Some(&op) if !proto::known_opcode(op, version) => proto::ERR_UNKNOWN_OPCODE,
        _ => proto::ERR_MALFORMED,
    };
    Message::Error { code, message: e.to_string() }
}

fn send<S: Write>(stream: &mut S, id: u32, msg: &Message, version: u16) -> Result<()> {
    proto::write_frame(stream, &proto::encode_versioned(id, msg, version))
}

fn server_error(e: anyhow::Error) -> Message {
    Message::Error { code: proto::ERR_SERVER, message: e.to_string() }
}

/// Render a registry stats row for the wire.
pub fn wire_row(s: &EntryStats) -> WireStatsRow {
    WireStatsRow {
        name: s.name.clone(),
        n: s.n as u64,
        nnz: s.nnz as u64,
        d_mat: s.d_mat,
        shard: s.shard as u32,
        serving: s.serving.to_string(),
        calls: s.calls,
        transformed_calls: s.transformed_calls,
        replans: s.replans,
        split_parts: s.split_parts as u32,
        split_calls: s.split_calls,
        matrix_passes: s.matrix_passes,
        extra_bytes: s.extra_bytes as u64,
        amortized: s.amortized,
    }
}

/// Serve one decoded request. Always produces a reply message — server-
/// side failures become `Error` replies, never session terminations.
fn handle(
    client: &Client,
    ingress: &Ingress,
    policy: &SessionPolicy,
    state: &mut SessionState,
    msg: Message,
) -> Message {
    match msg {
        Message::Register { name, n_rows, n_cols, row_ptr, col_idx, values } => {
            let built = Csr::new(
                n_rows as usize,
                n_cols as usize,
                row_ptr.into_iter().map(|v| v as usize).collect(),
                col_idx,
                values,
            );
            match built.and_then(|csr| client.register(&name, csr)) {
                Ok(stats) => Message::Registered { row: wire_row(&stats) },
                Err(e) => server_error(e),
            }
        }
        Message::Spmv { name, x, deadline_us } => {
            // Intern once per session; afterwards admission clones the
            // Arc instead of allocating a String per request.
            let key = match state.interned.get(&name) {
                Some(k) => Arc::clone(k),
                None => {
                    let k: Arc<str> = Arc::from(name.as_str());
                    ingress.counters().key_interns.fetch_add(1, Ordering::Relaxed);
                    state.interned.insert(name, Arc::clone(&k));
                    k
                }
            };
            // The deadline is a relative budget from receipt; stamp it
            // here so queueing and coalescing time count against it.
            let deadline =
                (deadline_us > 0).then(|| Instant::now() + Duration::from_micros(deadline_us));
            match ingress.submit(&key, x, deadline) {
                None => Message::Busy,
                Some(rx) => match rx.recv() {
                    Ok(ServeOutcome::Done(Ok(y))) => Message::Vector { y },
                    Ok(ServeOutcome::Done(Err(e))) => server_error(e),
                    Ok(ServeOutcome::Shed) => Message::Error {
                        code: proto::ERR_DEADLINE_EXCEEDED,
                        message: format!(
                            "deadline of {deadline_us}µs expired before the batch drained"
                        ),
                    },
                    Err(_) => server_error(anyhow::anyhow!("server dropped response")),
                },
            }
        }
        Message::SpmvBatch { name, xs } => match client.spmv_batch(&name, xs) {
            Ok(ys) => Message::Vectors { ys },
            Err(e) => server_error(e),
        },
        Message::Stats => match client.stats() {
            Ok(rows) => Message::StatsRows { rows: rows.iter().map(wire_row).collect() },
            Err(e) => server_error(e),
        },
        Message::Replan { name } => match client.replan(&name) {
            Ok(stats) => Message::Registered { row: wire_row(&stats) },
            Err(e) => server_error(e),
        },
        Message::Evict { name } => match client.evict(&name) {
            Ok(existed) => Message::Evicted { existed },
            Err(e) => server_error(e),
        },
        Message::NetStats => Message::NetStatsReply { stats: ingress.counters().snapshot() },
        Message::DecisionLog => Message::DecisionLogReply {
            lines: policy
                .decision_log
                .as_ref()
                .map(|log| log.tail(DECISION_LOG_WIRE_LIMIT))
                .unwrap_or_default(),
        },
        Message::Hello { .. } => Message::Error {
            code: proto::ERR_MALFORMED,
            message: "handshake already complete".into(),
        },
        // A client sending response opcodes is confused but harmless.
        Message::HelloAck { .. }
        | Message::Registered { .. }
        | Message::Vector { .. }
        | Message::Vectors { .. }
        | Message::StatsRows { .. }
        | Message::Evicted { .. }
        | Message::NetStatsReply { .. }
        | Message::DecisionLogReply { .. }
        | Message::Busy
        | Message::Error { .. } => Message::Error {
            code: proto::ERR_MALFORMED,
            message: "response opcode sent as a request".into(),
        },
    }
}
