//! Per-connection session: a hello-first state machine over framed
//! protocol messages.
//!
//! Each accepted connection gets one session thread running
//! [`run_session`] over any `Read + Write` stream (TCP, Unix socket, or
//! an in-memory pipe in tests). The state machine is strict about the
//! handshake — the first frame must be a version-matching `Hello`,
//! anything else closes the connection — and lenient after it: a frame
//! that *decodes* badly gets an `Error` reply and the session keeps
//! serving, because the length prefix already delimited the bad frame
//! and stream framing is intact. Only transport-level damage (EOF inside
//! a frame, an oversized length prefix) ends the session.
//!
//! Single-vector `Spmv` requests go through the ingress coalescer; every
//! other request calls the serving [`Client`] directly. A full ingress
//! queue is answered with `Busy` — the reader thread never blocks on
//! admission.

use super::ingress::Ingress;
use super::proto::{self, Message, WireStatsRow};
use crate::coordinator::{Client, EntryStats};
use crate::formats::Csr;
use crate::Result;
use std::io::{Read, Write};

/// Serve one connection until the peer disconnects or the transport
/// fails. Returns `Ok` for clean closes (including a rejected
/// handshake); `Err` only for transport-level failures.
pub fn run_session<S: Read + Write>(mut stream: S, client: Client, ingress: Ingress) -> Result<()> {
    // Handshake: the first frame must be a version-matching Hello.
    let payload = match proto::read_frame(&mut stream)? {
        Some(p) => p,
        None => return Ok(()),
    };
    match proto::decode(&payload) {
        Ok((id, Message::Hello { version })) if version == proto::VERSION => {
            send(&mut stream, id, &Message::HelloAck { version: proto::VERSION })?;
        }
        Ok((id, Message::Hello { version })) => {
            send(
                &mut stream,
                id,
                &Message::Error {
                    code: proto::ERR_UNSUPPORTED_VERSION,
                    message: format!(
                        "client speaks protocol version {version}, this server speaks {}",
                        proto::VERSION
                    ),
                },
            )?;
            return Ok(());
        }
        Ok((id, _)) => {
            send(
                &mut stream,
                id,
                &Message::Error {
                    code: proto::ERR_MALFORMED,
                    message: "the first frame on a connection must be Hello".into(),
                },
            )?;
            return Ok(());
        }
        Err(e) => {
            send(&mut stream, 0, &decode_error(&payload, &e))?;
            return Ok(());
        }
    }

    // Request loop: decode errors reply and continue; transport errors end.
    while let Some(payload) = proto::read_frame(&mut stream)? {
        match proto::decode(&payload) {
            Ok((id, msg)) => {
                let reply = handle(&client, &ingress, msg);
                send(&mut stream, id, &reply)?;
            }
            Err(e) => {
                // Best-effort request-id echo so a pipelining client can
                // still match the error to its request.
                let id = payload
                    .get(1..5)
                    .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                    .unwrap_or(0);
                send(&mut stream, id, &decode_error(&payload, &e))?;
            }
        }
    }
    Ok(())
}

/// Map a decode failure to the right error code: unknown opcode if the
/// opcode byte itself is unrecognised, malformed otherwise.
fn decode_error(payload: &[u8], e: &anyhow::Error) -> Message {
    let code = match payload.first() {
        Some(&op) if !proto::known_opcode(op) => proto::ERR_UNKNOWN_OPCODE,
        _ => proto::ERR_MALFORMED,
    };
    Message::Error { code, message: e.to_string() }
}

fn send<S: Write>(stream: &mut S, id: u32, msg: &Message) -> Result<()> {
    proto::write_frame(stream, &proto::encode(id, msg))
}

fn server_error(e: anyhow::Error) -> Message {
    Message::Error { code: proto::ERR_SERVER, message: e.to_string() }
}

/// Render a registry stats row for the wire.
pub fn wire_row(s: &EntryStats) -> WireStatsRow {
    WireStatsRow {
        name: s.name.clone(),
        n: s.n as u64,
        nnz: s.nnz as u64,
        d_mat: s.d_mat,
        shard: s.shard as u32,
        serving: s.serving.to_string(),
        calls: s.calls,
        transformed_calls: s.transformed_calls,
        replans: s.replans,
        split_parts: s.split_parts as u32,
        split_calls: s.split_calls,
        matrix_passes: s.matrix_passes,
        extra_bytes: s.extra_bytes as u64,
        amortized: s.amortized,
    }
}

/// Serve one decoded request. Always produces a reply message — server-
/// side failures become `Error` replies, never session terminations.
fn handle(client: &Client, ingress: &Ingress, msg: Message) -> Message {
    match msg {
        Message::Register { name, n_rows, n_cols, row_ptr, col_idx, values } => {
            let built = Csr::new(
                n_rows as usize,
                n_cols as usize,
                row_ptr.into_iter().map(|v| v as usize).collect(),
                col_idx,
                values,
            );
            match built.and_then(|csr| client.register(&name, csr)) {
                Ok(stats) => Message::Registered { row: wire_row(&stats) },
                Err(e) => server_error(e),
            }
        }
        Message::Spmv { name, x } => match ingress.submit(&name, x) {
            None => Message::Busy,
            Some(rx) => match rx.recv() {
                Ok(Ok(y)) => Message::Vector { y },
                Ok(Err(e)) => server_error(e),
                Err(_) => server_error(anyhow::anyhow!("server dropped response")),
            },
        },
        Message::SpmvBatch { name, xs } => match client.spmv_batch(&name, xs) {
            Ok(ys) => Message::Vectors { ys },
            Err(e) => server_error(e),
        },
        Message::Stats => match client.stats() {
            Ok(rows) => Message::StatsRows { rows: rows.iter().map(wire_row).collect() },
            Err(e) => server_error(e),
        },
        Message::Replan { name } => match client.replan(&name) {
            Ok(stats) => Message::Registered { row: wire_row(&stats) },
            Err(e) => server_error(e),
        },
        Message::Evict { name } => match client.evict(&name) {
            Ok(existed) => Message::Evicted { existed },
            Err(e) => server_error(e),
        },
        Message::NetStats => Message::NetStatsReply { stats: ingress.counters().snapshot() },
        Message::Hello { .. } => Message::Error {
            code: proto::ERR_MALFORMED,
            message: "handshake already complete".into(),
        },
        // A client sending response opcodes is confused but harmless.
        Message::HelloAck { .. }
        | Message::Registered { .. }
        | Message::Vector { .. }
        | Message::Vectors { .. }
        | Message::StatsRows { .. }
        | Message::Evicted { .. }
        | Message::NetStatsReply { .. }
        | Message::Busy
        | Message::Error { .. } => Message::Error {
            code: proto::ERR_MALFORMED,
            message: "response opcode sent as a request".into(),
        },
    }
}
