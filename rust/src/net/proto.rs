//! Wire protocol: length-prefixed compact binary frames with a versioned
//! handshake.
//!
//! Every frame on the wire is `[payload length: u32 LE][payload]`; the
//! payload is `[opcode: u8][request id: u32 LE][body]`. All integers are
//! little-endian, floats are IEEE-754 binary64 little-endian, strings are
//! `u16` byte length + UTF-8 bytes, vectors are a `u32` element count
//! followed by the elements. The request id is opaque to the server and
//! echoed verbatim on the response, so a pipelining client can match
//! replies to requests. `docs/PROTOCOL.md` is the worked-example,
//! byte-level reference for everything in this module; the doctests here
//! pin the same bytes so the document cannot drift from the code.
//!
//! A connection starts with [`Message::Hello`] (magic `"SPAT"` + the
//! protocol version, plus an optional auth token from v2 on) and is good
//! for requests only after the server's [`Message::HelloAck`].
//! Backpressure is explicit: a server whose ingress queue is full — or
//! whose per-session quota is spent — answers [`Message::Busy`] instead
//! of queueing, and errors travel as [`Message::Error`] with a stable
//! numeric code plus a human-readable message.
//!
//! # Versioning
//!
//! The server accepts any client version in `[MIN_VERSION, VERSION]` and
//! serves the session at the client's version (the minimum of the two
//! sides' maxima). The two handshake messages are *self-describing*:
//! their bodies carry their own version field first, and the remainder of
//! the body is laid out per that embedded version — so a handshake frame
//! decodes without knowing the session version in advance. Every other
//! message is *session-versioned*: [`encode_versioned`]/
//! [`decode_versioned`] lay its body out per the negotiated version
//! (v1 `Spmv` has no deadline field, v1 `NetStatsReply` has no
//! `deadline_sheds`, and the decision-log opcodes do not exist in v1).
//! [`encode`]/[`decode`] are the current-version shorthands.
//!
//! # Frame round-trip
//!
//! ```
//! use spmv_at::net::proto::{self, Message};
//! use std::io::Cursor;
//!
//! let hello = Message::Hello { version: proto::VERSION, auth: String::new() };
//! let payload = proto::encode(1, &hello);
//! let mut wire = Vec::new();
//! proto::write_frame(&mut wire, &payload).unwrap();
//! // 4-byte LE length prefix, then the payload bytes.
//! assert_eq!(wire[..4], (payload.len() as u32).to_le_bytes());
//! assert_eq!(&wire[4..], &payload[..]);
//!
//! let mut r = Cursor::new(wire);
//! let got = proto::read_frame(&mut r).unwrap().expect("one frame");
//! let (id, msg) = proto::decode(&got).unwrap();
//! assert_eq!(id, 1);
//! assert_eq!(msg, hello);
//! // Clean EOF at a frame boundary reads as None, not an error.
//! assert!(proto::read_frame(&mut r).unwrap().is_none());
//! ```

use crate::Result;
use std::io::{Read, Write};

/// Handshake magic, the first four bytes of every [`Message::Hello`] body.
pub const MAGIC: [u8; 4] = *b"SPAT";

/// Highest protocol version this build speaks (the handshake negotiates
/// down to the client's version inside the window).
pub const VERSION: u16 = 2;

/// Oldest protocol version this build still serves (v1-compat mode: no
/// deadline field, no auth token, no decision-log opcodes).
pub const MIN_VERSION: u16 = 1;

/// Hard cap on a frame's payload length; a larger length prefix is
/// rejected before any allocation (a malformed or hostile prefix must
/// not OOM the server).
pub const MAX_FRAME: usize = 1 << 26; // 64 MiB

/// Error code: the client's protocol version is outside the server's
/// `[MIN_VERSION, VERSION]` window.
pub const ERR_UNSUPPORTED_VERSION: u16 = 1;
/// Error code: the opcode byte is not one this session's version knows.
pub const ERR_UNKNOWN_OPCODE: u16 = 2;
/// Error code: the frame body could not be decoded.
pub const ERR_MALFORMED: u16 = 3;
/// Error code: the request was understood but serving it failed (the
/// message carries the server-side error text).
pub const ERR_SERVER: u16 = 4;
/// Error code: the request's deadline expired before the coalescer
/// drained it; the batch slot was shed, not served (v2+).
pub const ERR_DEADLINE_EXCEEDED: u16 = 5;
/// Error code: the server requires an auth token and the handshake did
/// not present a matching one (v2+; v1 cannot carry a token, so a
/// token-requiring server refuses v1 clients with this code too).
pub const ERR_UNAUTHORIZED: u16 = 6;

/// Opcode: client hello (handshake).
pub const OP_HELLO: u8 = 0x01;
/// Opcode: register a matrix (CSR arrays).
pub const OP_REGISTER: u8 = 0x10;
/// Opcode: single-vector SpMV (the coalescable request).
pub const OP_SPMV: u8 = 0x11;
/// Opcode: batched SpMM (pre-batched by the client).
pub const OP_SPMV_BATCH: u8 = 0x12;
/// Opcode: fetch all stats rows.
pub const OP_STATS: u8 = 0x13;
/// Opcode: force a re-decision for one matrix.
pub const OP_REPLAN: u8 = 0x14;
/// Opcode: evict a matrix.
pub const OP_EVICT: u8 = 0x15;
/// Opcode: fetch the ingress/coalescer counters.
pub const OP_NET_STATS: u8 = 0x16;
/// Opcode: fetch the tail of the serving decision log (v2+).
pub const OP_DECISION_LOG: u8 = 0x17;
/// Opcode: server is over admission capacity for this request (reply).
pub const OP_BUSY: u8 = 0x7E;
/// Opcode: error reply.
pub const OP_ERROR: u8 = 0x7F;
/// Opcode: handshake accepted (reply).
pub const OP_HELLO_ACK: u8 = 0x81;
/// Opcode: stats-row reply (to `Register` and `Replan`).
pub const OP_REGISTERED: u8 = 0x82;
/// Opcode: single-vector result (reply to `Spmv`).
pub const OP_VECTOR: u8 = 0x83;
/// Opcode: batched result (reply to `SpmvBatch`).
pub const OP_VECTORS: u8 = 0x84;
/// Opcode: all stats rows (reply to `Stats`).
pub const OP_STATS_ROWS: u8 = 0x85;
/// Opcode: eviction result (reply to `Evict`).
pub const OP_EVICTED: u8 = 0x86;
/// Opcode: ingress/coalescer counters (reply to `NetStats`).
pub const OP_NET_STATS_REPLY: u8 = 0x87;
/// Opcode: decision-log tail (reply to `DecisionLog`, v2+).
pub const OP_DECISION_LOG_REPLY: u8 = 0x88;

/// Whether `op` is an opcode the given protocol version knows how to
/// decode. The decision-log pair exists only from v2 on.
pub fn known_opcode(op: u8, version: u16) -> bool {
    matches!(
        op,
        OP_HELLO
            | OP_REGISTER
            | OP_SPMV
            | OP_SPMV_BATCH
            | OP_STATS
            | OP_REPLAN
            | OP_EVICT
            | OP_NET_STATS
            | OP_BUSY
            | OP_ERROR
            | OP_HELLO_ACK
            | OP_REGISTERED
            | OP_VECTOR
            | OP_VECTORS
            | OP_STATS_ROWS
            | OP_EVICTED
            | OP_NET_STATS_REPLY
    ) || (version >= 2 && matches!(op, OP_DECISION_LOG | OP_DECISION_LOG_REPLY))
}

/// One stats row as serialised on the wire — the subset of
/// [`crate::coordinator::EntryStats`] a remote operator needs, with the
/// serving implementation rendered as text so the wire format does not
/// depend on the enum's layout.
#[derive(Clone, Debug, PartialEq)]
pub struct WireStatsRow {
    /// Registry key.
    pub name: String,
    /// Matrix rows.
    pub n: u64,
    /// Matrix non-zeros.
    pub nnz: u64,
    /// `D_mat` (row-length variation coefficient).
    pub d_mat: f64,
    /// Serving shard.
    pub shard: u32,
    /// Serving implementation, rendered as text.
    pub serving: String,
    /// Total calls served.
    pub calls: u64,
    /// Calls served by the transformed plan.
    pub transformed_calls: u64,
    /// Serving-plan flips applied.
    pub replans: u64,
    /// Row blocks of the cached split plan (0 = unsplit).
    pub split_parts: u32,
    /// Calls served through the split plan.
    pub split_calls: u64,
    /// Matrix streaming passes (see `EntryStats::matrix_passes`).
    pub matrix_passes: u64,
    /// Extra bytes held beyond the CRS original.
    pub extra_bytes: u64,
    /// Whether the transformation cost has amortised.
    pub amortized: bool,
}

/// Ingress/coalescer counter snapshot as serialised on the wire.
/// `deadline_sheds` is v2-only on the wire; a v1 session receives the
/// first eight counters exactly as the v1 spec laid them out.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireNetStats {
    /// Sessions currently open.
    pub sessions_open: u64,
    /// Sessions accepted over the listener's lifetime.
    pub sessions_total: u64,
    /// Coalescer dispatches (every batch, including singletons).
    pub batches: u64,
    /// Requests served through the coalescer.
    pub requests: u64,
    /// Dispatches that coalesced ≥ 2 requests.
    pub coalesced_batches: u64,
    /// Requests served inside those coalesced dispatches.
    pub coalesced_requests: u64,
    /// Requests refused with `Busy` because the ingress queue was full.
    pub admission_rejects: u64,
    /// Largest single coalesced dispatch.
    pub max_batch: u64,
    /// Requests shed at drain time because their deadline had expired
    /// (v2+ on the wire; always decodes as 0 on a v1 session).
    pub deadline_sheds: u64,
}

/// A decoded protocol message (request or response).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Handshake: magic + version (+ auth token from v2 on). Must be the
    /// first frame on a connection. Self-describing: the body is laid
    /// out per its own `version` field, not the session version.
    Hello {
        /// Protocol version the client speaks.
        version: u16,
        /// Auth token; empty = none presented. v1 bodies cannot carry
        /// one, so a v1 `Hello` always decodes with an empty token.
        auth: String,
    },
    /// Handshake accepted; the session speaks `version`. From v2 on the
    /// server also advertises its full `[min, max]` version window.
    /// Self-describing like [`Message::Hello`].
    HelloAck {
        /// Negotiated session version.
        version: u16,
        /// Oldest version the server serves (v2+ body; mirrored as
        /// `version` when decoding a v1 body).
        min: u16,
        /// Newest version the server serves (v2+ body; mirrored as
        /// `version` when decoding a v1 body).
        max: u16,
    },
    /// Register a matrix under a name (validated CSR arrays).
    Register {
        /// Registry key.
        name: String,
        /// Number of matrix rows.
        n_rows: u64,
        /// Number of matrix columns.
        n_cols: u64,
        /// CSR row offsets (`n_rows + 1` entries).
        row_ptr: Vec<u64>,
        /// CSR column indices (one per stored entry).
        col_idx: Vec<u32>,
        /// CSR values (one per stored entry).
        values: Vec<f64>,
    },
    /// `y = A·x` — the request the ingress coalescer batches.
    Spmv {
        /// Registry key.
        name: String,
        /// Input vector.
        x: Vec<f64>,
        /// Relative deadline in microseconds from server receipt; 0 = no
        /// deadline. The coalescer sheds the request with
        /// [`ERR_DEADLINE_EXCEEDED`] if it is still queued when the
        /// budget runs out. v2-only field (a v1 body omits it and
        /// decodes as 0).
        deadline_us: u64,
    },
    /// Batched `Y = A·X`, already grouped by the client.
    SpmvBatch {
        /// Registry key.
        name: String,
        /// Input vectors.
        xs: Vec<Vec<f64>>,
    },
    /// Fetch all stats rows.
    Stats,
    /// Force a re-decision for one matrix.
    Replan {
        /// Registry key.
        name: String,
    },
    /// Evict a matrix.
    Evict {
        /// Registry key.
        name: String,
    },
    /// Fetch the ingress/coalescer counters.
    NetStats,
    /// Fetch the tail of the serving decision log (v2+).
    DecisionLog,
    /// Stats-row reply (to `Register` and `Replan`).
    Registered {
        /// The entry's stats row after the operation.
        row: WireStatsRow,
    },
    /// Reply to `Spmv`.
    Vector {
        /// The result vector.
        y: Vec<f64>,
    },
    /// Reply to `SpmvBatch`.
    Vectors {
        /// One result vector per input.
        ys: Vec<Vec<f64>>,
    },
    /// Reply to `Stats`.
    StatsRows {
        /// All rows, merged across shards.
        rows: Vec<WireStatsRow>,
    },
    /// Reply to `Evict`.
    Evicted {
        /// Whether the matrix existed.
        existed: bool,
    },
    /// Reply to `NetStats`.
    NetStatsReply {
        /// The counter snapshot.
        stats: WireNetStats,
    },
    /// Reply to `DecisionLog` (v2+): the most recent JSONL records, one
    /// string per line, oldest first.
    DecisionLogReply {
        /// Rendered JSONL decision records.
        lines: Vec<String>,
    },
    /// The ingress queue for this request's shard is full — or the
    /// session's request/byte quota is spent; retry later (or
    /// reconnect, for quotas). Explicit backpressure — the server never
    /// blocks the socket reader on a full queue.
    Busy,
    /// The request failed; `code` is one of the `ERR_*` constants.
    Error {
        /// Stable numeric error code.
        code: u16,
        /// Human-readable detail.
        message: String,
    },
}

// ---------------------------------------------------------------------------
// Encoding

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= u16::MAX as usize, "string too long for the wire");
    put_u16(buf, s.len() as u16);
    buf.extend_from_slice(s.as_bytes());
}

fn put_vec_f64(buf: &mut Vec<u8>, v: &[f64]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_f64(buf, x);
    }
}

fn put_vec_u64(buf: &mut Vec<u8>, v: &[u64]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_u64(buf, x);
    }
}

fn put_vec_u32(buf: &mut Vec<u8>, v: &[u32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_u32(buf, x);
    }
}

fn put_row(buf: &mut Vec<u8>, row: &WireStatsRow) {
    put_str(buf, &row.name);
    put_u64(buf, row.n);
    put_u64(buf, row.nnz);
    put_f64(buf, row.d_mat);
    put_u32(buf, row.shard);
    put_str(buf, &row.serving);
    put_u64(buf, row.calls);
    put_u64(buf, row.transformed_calls);
    put_u64(buf, row.replans);
    put_u32(buf, row.split_parts);
    put_u64(buf, row.split_calls);
    put_u64(buf, row.matrix_passes);
    put_u64(buf, row.extra_bytes);
    buf.push(row.amortized as u8);
}

/// Serialise a message into a frame payload at the current protocol
/// version ([`VERSION`]). Shorthand for [`encode_versioned`].
///
/// ```
/// use spmv_at::net::proto::{self, Message};
/// // Spmv "m" with x = [1.0], no deadline, request id 7 (v2 layout):
/// let msg = Message::Spmv { name: "m".into(), x: vec![1.0], deadline_us: 0 };
/// let payload = proto::encode(7, &msg);
/// assert_eq!(
///     payload,
///     [
///         0x11, // opcode OP_SPMV
///         7, 0, 0, 0, // request id (u32 LE)
///         1, 0, // name byte length (u16 LE)
///         b'm', // name bytes (UTF-8)
///         1, 0, 0, 0, // vector element count (u32 LE)
///         0, 0, 0, 0, 0, 0, 0xF0, 0x3F, // 1.0 (f64 LE)
///         0, 0, 0, 0, 0, 0, 0, 0, // deadline_us = 0 (u64 LE, v2+)
///     ]
/// );
/// let (id, msg2) = proto::decode(&payload).unwrap();
/// assert_eq!(id, 7);
/// assert_eq!(msg2, msg);
/// // The same message in a v1 session omits the deadline field — the
/// // payload is byte-for-byte the v1 spec.
/// let v1 = proto::encode_versioned(7, &msg, 1);
/// assert_eq!(v1, payload[..payload.len() - 8]);
/// ```
pub fn encode(id: u32, msg: &Message) -> Vec<u8> {
    encode_versioned(id, msg, VERSION)
}

/// Serialise a message into a frame payload (`opcode + request id +
/// body`, no length prefix — [`write_frame`] adds that) laid out per
/// `version`. The handshake messages ignore `version` and lay themselves
/// out per their own embedded version field (see the module docs).
pub fn encode_versioned(id: u32, msg: &Message, version: u16) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.push(opcode(msg));
    put_u32(&mut buf, id);
    match msg {
        Message::Hello { version: v, auth } => {
            buf.extend_from_slice(&MAGIC);
            put_u16(&mut buf, *v);
            if *v >= 2 {
                put_str(&mut buf, auth);
            }
        }
        Message::HelloAck { version: v, min, max } => {
            put_u16(&mut buf, *v);
            if *v >= 2 {
                put_u16(&mut buf, *min);
                put_u16(&mut buf, *max);
            }
        }
        Message::Register { name, n_rows, n_cols, row_ptr, col_idx, values } => {
            put_str(&mut buf, name);
            put_u64(&mut buf, *n_rows);
            put_u64(&mut buf, *n_cols);
            put_vec_u64(&mut buf, row_ptr);
            put_vec_u32(&mut buf, col_idx);
            put_vec_f64(&mut buf, values);
        }
        Message::Spmv { name, x, deadline_us } => {
            put_str(&mut buf, name);
            put_vec_f64(&mut buf, x);
            if version >= 2 {
                put_u64(&mut buf, *deadline_us);
            }
        }
        Message::SpmvBatch { name, xs } => {
            put_str(&mut buf, name);
            put_u32(&mut buf, xs.len() as u32);
            for x in xs {
                put_vec_f64(&mut buf, x);
            }
        }
        Message::Stats | Message::NetStats | Message::DecisionLog | Message::Busy => {}
        Message::Replan { name } | Message::Evict { name } => put_str(&mut buf, name),
        Message::Registered { row } => put_row(&mut buf, row),
        Message::Vector { y } => put_vec_f64(&mut buf, y),
        Message::Vectors { ys } => {
            put_u32(&mut buf, ys.len() as u32);
            for y in ys {
                put_vec_f64(&mut buf, y);
            }
        }
        Message::StatsRows { rows } => {
            put_u32(&mut buf, rows.len() as u32);
            for row in rows {
                put_row(&mut buf, row);
            }
        }
        Message::Evicted { existed } => buf.push(*existed as u8),
        Message::NetStatsReply { stats } => {
            put_u64(&mut buf, stats.sessions_open);
            put_u64(&mut buf, stats.sessions_total);
            put_u64(&mut buf, stats.batches);
            put_u64(&mut buf, stats.requests);
            put_u64(&mut buf, stats.coalesced_batches);
            put_u64(&mut buf, stats.coalesced_requests);
            put_u64(&mut buf, stats.admission_rejects);
            put_u64(&mut buf, stats.max_batch);
            if version >= 2 {
                put_u64(&mut buf, stats.deadline_sheds);
            }
        }
        Message::DecisionLogReply { lines } => {
            put_u32(&mut buf, lines.len() as u32);
            for line in lines {
                put_str(&mut buf, line);
            }
        }
        Message::Error { code, message } => {
            put_u16(&mut buf, *code);
            put_str(&mut buf, message);
        }
    }
    buf
}

fn opcode(msg: &Message) -> u8 {
    match msg {
        Message::Hello { .. } => OP_HELLO,
        Message::HelloAck { .. } => OP_HELLO_ACK,
        Message::Register { .. } => OP_REGISTER,
        Message::Spmv { .. } => OP_SPMV,
        Message::SpmvBatch { .. } => OP_SPMV_BATCH,
        Message::Stats => OP_STATS,
        Message::Replan { .. } => OP_REPLAN,
        Message::Evict { .. } => OP_EVICT,
        Message::NetStats => OP_NET_STATS,
        Message::DecisionLog => OP_DECISION_LOG,
        Message::Registered { .. } => OP_REGISTERED,
        Message::Vector { .. } => OP_VECTOR,
        Message::Vectors { .. } => OP_VECTORS,
        Message::StatsRows { .. } => OP_STATS_ROWS,
        Message::Evicted { .. } => OP_EVICTED,
        Message::NetStatsReply { .. } => OP_NET_STATS_REPLY,
        Message::DecisionLogReply { .. } => OP_DECISION_LOG_REPLY,
        Message::Busy => OP_BUSY,
        Message::Error { .. } => OP_ERROR,
    }
}

// ---------------------------------------------------------------------------
// Decoding

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow::anyhow!("truncated payload: need {n} more bytes"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|_| anyhow::anyhow!("string is not UTF-8"))?
            .to_string())
    }

    fn vec_f64(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n.min(self.buf.len() / 8 + 1));
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }

    fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n.min(self.buf.len() / 8 + 1));
        for _ in 0..n {
            v.push(self.u64()?);
        }
        Ok(v)
    }

    fn vec_u32(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n.min(self.buf.len() / 4 + 1));
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn row(&mut self) -> Result<WireStatsRow> {
        Ok(WireStatsRow {
            name: self.string()?,
            n: self.u64()?,
            nnz: self.u64()?,
            d_mat: self.f64()?,
            shard: self.u32()?,
            serving: self.string()?,
            calls: self.u64()?,
            transformed_calls: self.u64()?,
            replans: self.u64()?,
            split_parts: self.u32()?,
            split_calls: self.u64()?,
            matrix_passes: self.u64()?,
            extra_bytes: self.u64()?,
            amortized: self.u8()? != 0,
        })
    }

    fn finish(&self) -> Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "{} trailing bytes after the message body",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

/// Decode a frame payload at the current protocol version ([`VERSION`]).
/// Shorthand for [`decode_versioned`].
pub fn decode(payload: &[u8]) -> Result<(u32, Message)> {
    decode_versioned(payload, VERSION)
}

/// Decode a frame payload into `(request id, message)` laid out per
/// `version`. Fails on opcodes unknown to that version, truncated
/// bodies, bad magic, non-UTF-8 strings, and trailing bytes — a decode
/// error means the frame was malformed, not that the stream framing is
/// lost (the length prefix already delimited it). The handshake messages
/// ignore `version` and decode per their own embedded version field.
pub fn decode_versioned(payload: &[u8], version: u16) -> Result<(u32, Message)> {
    let mut r = Reader { buf: payload, pos: 0 };
    let op = r.u8()?;
    let id = r.u32()?;
    let msg = match op {
        OP_HELLO => {
            let magic = r.take(4)?;
            anyhow::ensure!(magic == MAGIC, "bad handshake magic {magic:02x?}");
            let v = r.u16()?;
            let auth = if v >= 2 { r.string()? } else { String::new() };
            Message::Hello { version: v, auth }
        }
        OP_HELLO_ACK => {
            let v = r.u16()?;
            let (min, max) = if v >= 2 { (r.u16()?, r.u16()?) } else { (v, v) };
            Message::HelloAck { version: v, min, max }
        }
        OP_REGISTER => Message::Register {
            name: r.string()?,
            n_rows: r.u64()?,
            n_cols: r.u64()?,
            row_ptr: r.vec_u64()?,
            col_idx: r.vec_u32()?,
            values: r.vec_f64()?,
        },
        OP_SPMV => {
            let name = r.string()?;
            let x = r.vec_f64()?;
            let deadline_us = if version >= 2 { r.u64()? } else { 0 };
            Message::Spmv { name, x, deadline_us }
        }
        OP_SPMV_BATCH => {
            let name = r.string()?;
            let k = r.u32()? as usize;
            let mut xs = Vec::with_capacity(k.min(payload.len() / 4 + 1));
            for _ in 0..k {
                xs.push(r.vec_f64()?);
            }
            Message::SpmvBatch { name, xs }
        }
        OP_STATS => Message::Stats,
        OP_REPLAN => Message::Replan { name: r.string()? },
        OP_EVICT => Message::Evict { name: r.string()? },
        OP_NET_STATS => Message::NetStats,
        OP_DECISION_LOG if version >= 2 => Message::DecisionLog,
        OP_REGISTERED => Message::Registered { row: r.row()? },
        OP_VECTOR => Message::Vector { y: r.vec_f64()? },
        OP_VECTORS => {
            let k = r.u32()? as usize;
            let mut ys = Vec::with_capacity(k.min(payload.len() / 4 + 1));
            for _ in 0..k {
                ys.push(r.vec_f64()?);
            }
            Message::Vectors { ys }
        }
        OP_STATS_ROWS => {
            let k = r.u32()? as usize;
            let mut rows = Vec::with_capacity(k.min(payload.len() / 8 + 1));
            for _ in 0..k {
                rows.push(r.row()?);
            }
            Message::StatsRows { rows }
        }
        OP_EVICTED => Message::Evicted { existed: r.u8()? != 0 },
        OP_NET_STATS_REPLY => Message::NetStatsReply {
            stats: WireNetStats {
                sessions_open: r.u64()?,
                sessions_total: r.u64()?,
                batches: r.u64()?,
                requests: r.u64()?,
                coalesced_batches: r.u64()?,
                coalesced_requests: r.u64()?,
                admission_rejects: r.u64()?,
                max_batch: r.u64()?,
                deadline_sheds: if version >= 2 { r.u64()? } else { 0 },
            },
        },
        OP_DECISION_LOG_REPLY if version >= 2 => {
            let k = r.u32()? as usize;
            let mut lines = Vec::with_capacity(k.min(payload.len() / 2 + 1));
            for _ in 0..k {
                lines.push(r.string()?);
            }
            Message::DecisionLogReply { lines }
        }
        OP_BUSY => Message::Busy,
        OP_ERROR => Message::Error { code: r.u16()?, message: r.string()? },
        other => anyhow::bail!("unknown opcode 0x{other:02x} for protocol version {version}"),
    };
    r.finish()?;
    Ok((id, msg))
}

// ---------------------------------------------------------------------------
// Framing

/// Write one frame: `u32` LE payload length, then the payload.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    anyhow::ensure!(payload.len() <= MAX_FRAME, "frame payload {} too large", payload.len());
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's payload. `Ok(None)` is a clean EOF at a frame
/// boundary (the peer closed between frames); truncation *inside* a
/// frame, or a length prefix past [`MAX_FRAME`], is an error. After an
/// error the stream is unframed — any unread payload bytes are still on
/// the wire — so callers must hard-close the connection rather than try
/// to resync (see `net::session`).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut prefix = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut prefix[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => anyhow::bail!("connection closed inside a frame header"),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(prefix) as usize;
    anyhow::ensure!(len <= MAX_FRAME, "frame length {len} exceeds the {MAX_FRAME}-byte cap");
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow::anyhow!("connection closed inside a frame body: {e}"))?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let payload = encode(42, &msg);
        let (id, got) = decode(&payload).unwrap();
        assert_eq!(id, 42);
        assert_eq!(got, msg);
    }

    fn roundtrip_v1(msg: Message) {
        let payload = encode_versioned(42, &msg, 1);
        let (id, got) = decode_versioned(&payload, 1).unwrap();
        assert_eq!(id, 42);
        assert_eq!(got, msg);
    }

    fn row() -> WireStatsRow {
        WireStatsRow {
            name: "m".into(),
            n: 64,
            nnz: 400,
            d_mat: 0.25,
            shard: 1,
            serving: "ell_row_inner".into(),
            calls: 17,
            transformed_calls: 16,
            replans: 2,
            split_parts: 0,
            split_calls: 0,
            matrix_passes: 5,
            extra_bytes: 4096,
            amortized: true,
        }
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip(Message::Hello { version: VERSION, auth: "tok".into() });
        roundtrip(Message::HelloAck { version: VERSION, min: MIN_VERSION, max: VERSION });
        roundtrip(Message::Register {
            name: "a".into(),
            n_rows: 2,
            n_cols: 2,
            row_ptr: vec![0, 1, 2],
            col_idx: vec![0, 1],
            values: vec![1.5, -2.5],
        });
        roundtrip(Message::Spmv { name: "a".into(), x: vec![1.0, 2.0], deadline_us: 1500 });
        roundtrip(Message::SpmvBatch {
            name: "a".into(),
            xs: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
        });
        roundtrip(Message::Stats);
        roundtrip(Message::Replan { name: "a".into() });
        roundtrip(Message::Evict { name: "a".into() });
        roundtrip(Message::NetStats);
        roundtrip(Message::DecisionLog);
        roundtrip(Message::Registered { row: row() });
        roundtrip(Message::Vector { y: vec![0.5; 3] });
        roundtrip(Message::Vectors { ys: vec![vec![0.5; 3], vec![]] });
        roundtrip(Message::StatsRows { rows: vec![row(), row()] });
        roundtrip(Message::Evicted { existed: false });
        roundtrip(Message::NetStatsReply {
            stats: WireNetStats {
                sessions_open: 1,
                sessions_total: 9,
                batches: 4,
                requests: 12,
                coalesced_batches: 2,
                coalesced_requests: 10,
                admission_rejects: 3,
                max_batch: 8,
                deadline_sheds: 6,
            },
        });
        roundtrip(Message::DecisionLogReply {
            lines: vec!["{\"event\":\"register\"}".into(), "{\"event\":\"flip\"}".into()],
        });
        roundtrip(Message::Busy);
        roundtrip(Message::Error { code: ERR_SERVER, message: "boom".into() });
    }

    #[test]
    fn v1_layout_roundtrips_and_omits_v2_fields() {
        // v1 sessions still speak every v1 message, in the v1 layout.
        roundtrip_v1(Message::Hello { version: 1, auth: String::new() });
        roundtrip_v1(Message::HelloAck { version: 1, min: 1, max: 1 });
        roundtrip_v1(Message::Spmv { name: "a".into(), x: vec![1.0], deadline_us: 0 });
        roundtrip_v1(Message::NetStatsReply { stats: WireNetStats::default() });
        roundtrip_v1(Message::Busy);

        // The v1 Spmv body is exactly the v2 body minus the trailing
        // deadline u64; a nonzero deadline simply does not travel.
        let msg = Message::Spmv { name: "a".into(), x: vec![1.0], deadline_us: 77 };
        let v1 = encode_versioned(9, &msg, 1);
        let v2 = encode_versioned(9, &msg, 2);
        assert_eq!(v1[..], v2[..v2.len() - 8]);
        assert_eq!(&v2[v2.len() - 8..], &77u64.to_le_bytes());
        let (_, got) = decode_versioned(&v1, 1).unwrap();
        assert_eq!(got, Message::Spmv { name: "a".into(), x: vec![1.0], deadline_us: 0 });

        // A v1 NetStatsReply body is the eight v1 counters, 69 bytes of
        // payload total; deadline_sheds decodes as 0.
        let stats = WireNetStats { deadline_sheds: 5, requests: 2, ..Default::default() };
        let v1 = encode_versioned(3, &Message::NetStatsReply { stats }, 1);
        assert_eq!(v1.len(), 5 + 8 * 8);
        let (_, got) = decode_versioned(&v1, 1).unwrap();
        let Message::NetStatsReply { stats: got } = got else { panic!("wrong variant") };
        assert_eq!(got.deadline_sheds, 0);
        assert_eq!(got.requests, 2);

        // The decision-log opcodes do not exist in v1.
        let pv = encode_versioned(1, &Message::DecisionLog, 2);
        assert!(decode_versioned(&pv, 1).is_err());
        let pv = encode_versioned(1, &Message::DecisionLogReply { lines: vec![] }, 2);
        assert!(decode_versioned(&pv, 1).is_err());
        assert!(known_opcode(OP_DECISION_LOG, 2));
        assert!(!known_opcode(OP_DECISION_LOG, 1));
    }

    #[test]
    fn handshake_frames_are_self_describing() {
        // A v1 Hello/HelloAck body decodes identically at either session
        // version — the embedded version field governs the layout, so
        // the server can read the first frame before it knows the
        // client's version.
        let h1 = encode_versioned(1, &Message::Hello { version: 1, auth: String::new() }, 1);
        assert_eq!(decode_versioned(&h1, 1).unwrap(), decode_versioned(&h1, 2).unwrap());
        // v1 Hello body: magic + u16 version, nothing else.
        assert_eq!(h1.len(), 5 + 4 + 2);

        let a1 = encode_versioned(1, &Message::HelloAck { version: 1, min: 1, max: 1 }, 2);
        assert_eq!(a1.len(), 5 + 2, "a v1 HelloAck body is exactly the u16 version");
        assert_eq!(decode_versioned(&a1, 1).unwrap(), decode_versioned(&a1, 2).unwrap());

        let h2 = encode_versioned(1, &Message::Hello { version: 2, auth: "tok".into() }, 1);
        let (_, got) = decode_versioned(&h2, 1).unwrap();
        assert_eq!(got, Message::Hello { version: 2, auth: "tok".into() });

        let a2 = encode_versioned(1, &Message::HelloAck { version: 2, min: 1, max: 2 }, 1);
        let (_, got) = decode_versioned(&a2, 2).unwrap();
        assert_eq!(got, Message::HelloAck { version: 2, min: 1, max: 2 });
    }

    #[test]
    fn malformed_payloads_are_rejected_not_panicked() {
        // Empty payload.
        assert!(decode(&[]).is_err());
        // Unknown opcode.
        assert!(decode(&[0x55, 0, 0, 0, 0]).is_err());
        // Bad magic.
        let mut bad = encode(1, &Message::Hello { version: VERSION, auth: String::new() });
        bad[5] = b'X';
        assert!(decode(&bad).is_err());
        // Truncated body: chop every prefix of a real message.
        let full =
            encode(7, &Message::Spmv { name: "mat".into(), x: vec![1.0, 2.0], deadline_us: 9 });
        for cut in 0..full.len() {
            assert!(decode(&full[..cut]).is_err(), "prefix of {cut} bytes must not decode");
        }
        // Trailing garbage.
        let mut long = full.clone();
        long.push(0);
        assert!(decode(&long).is_err());
        // A vector length promising more elements than the payload holds.
        let mut lying = encode(7, &Message::Vector { y: vec![1.0] });
        let body_at = lying.len() - 12; // u32 count before one f64
        lying[body_at..body_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&lying).is_err());
    }

    #[test]
    fn random_byte_soup_never_panics_the_codec() {
        // Deterministic fuzz: feed pseudo-random payloads and streams to
        // the decoder and the frame reader at both protocol versions.
        // The property under test is error-not-panic (and, for the frame
        // reader, no unbounded allocation) — not any particular error.
        let mut rng = crate::rng::Rng::new(0xC0DEC_5EED);
        for _ in 0..4000 {
            let len = rng.next_below(96) as usize;
            let mut buf = vec![0u8; len];
            for b in buf.iter_mut() {
                *b = (rng.next_u64() & 0xFF) as u8;
            }
            let _ = decode_versioned(&buf, 1);
            let _ = decode_versioned(&buf, 2);
            let mut c = std::io::Cursor::new(&buf);
            // Interpreting the soup as a frame stream must terminate
            // with EOF or an error, never a panic.
            while let Ok(Some(_)) = read_frame(&mut c) {}
        }

        // Bit-flip fuzz: every single-bit corruption of a valid frame
        // must decode to the original, another message, or an error —
        // never a panic.
        let valid = encode(
            5,
            &Message::Register {
                name: "fz".into(),
                n_rows: 2,
                n_cols: 2,
                row_ptr: vec![0, 1, 2],
                col_idx: vec![0, 1],
                values: vec![1.0, 2.0],
            },
        );
        for bit in 0..valid.len() * 8 {
            let mut mutated = valid.clone();
            mutated[bit / 8] ^= 1 << (bit % 8);
            let _ = decode_versioned(&mutated, 1);
            let _ = decode_versioned(&mutated, 2);
        }
    }

    #[test]
    fn frame_reader_distinguishes_clean_eof_from_truncation() {
        use std::io::Cursor;
        let payload = encode(3, &Message::Stats);
        let mut wire = Vec::new();
        write_frame(&mut wire, &payload).unwrap();

        // Whole frame, then clean EOF.
        let mut c = Cursor::new(wire.clone());
        assert_eq!(read_frame(&mut c).unwrap(), Some(payload.clone()));
        assert_eq!(read_frame(&mut c).unwrap(), None);

        // Truncated header and truncated body are errors, not EOF.
        let mut c = Cursor::new(wire[..2].to_vec());
        assert!(read_frame(&mut c).is_err());
        let mut c = Cursor::new(wire[..wire.len() - 1].to_vec());
        assert!(read_frame(&mut c).is_err());

        // An oversized length prefix is rejected before allocation.
        let mut c = Cursor::new(u32::MAX.to_le_bytes().to_vec());
        assert!(read_frame(&mut c).is_err());
    }
}
