//! Network serving front end: Unix-socket and TCP transports over the
//! sharded serving loops, with cross-request batch coalescing.
//!
//! The layering, outside-in:
//!
//! ```text
//!   TcpListener / UnixListener        accept loop ([`NetServer`])
//!        │  one thread per connection
//!        ▼
//!   session ([`session::run_session`])   framed protocol state machine
//!        │  Spmv → bounded ingress queue; everything else → Client
//!        ▼
//!   coalescer ([`ingress`])           one thread per shard: drain,
//!        │                            group by matrix key, batch
//!        ▼
//!   Client → serving loops            the same sharded loops the
//!                                     in-process API uses
//! ```
//!
//! The front end adds no serving semantics: every request lands on the
//! same [`Client`] the in-process embedding uses, so results are
//! bitwise identical to local serving. What it adds is *admission* —
//! bounded queues with explicit `Busy` backpressure, optional
//! per-session auth and request/byte quotas ([`session::SessionPolicy`]),
//! and drain-time deadline shedding ([`ingress`]) — and *coalescing*:
//! concurrent single-vector requests against the same matrix are folded
//! into one tiled batch call, cutting matrix-streaming passes from `k`
//! to ⌈k/tile⌉ (see [`ingress`]).
//!
//! The wire format lives in [`proto`]; `docs/PROTOCOL.md` is its
//! byte-level reference.

pub mod ingress;
pub mod proto;
pub mod session;

use crate::coordinator::{Client, Coordinator, DecisionLog, Server};
use crate::formats::{Csr, SparseMatrix};
use crate::{Result, Value};
use self::ingress::{CoalescerSet, Ingress, NetCounters};
use self::proto::{Message, WireNetStats, WireStatsRow};
use self::session::SessionPolicy;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Where to listen (or connect): TCP `host:port` or a Unix socket path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ListenAddr {
    /// TCP, `host:port` form.
    Tcp(String),
    /// Unix domain socket path.
    Unix(PathBuf),
}

impl std::fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ListenAddr::Tcp(a) => write!(f, "tcp:{a}"),
            ListenAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// Parse a listen spec: `unix:/path/to.sock`, `tcp:host:port`, or bare
/// `host:port` (treated as TCP).
///
/// ```
/// use spmv_at::net::{parse_listen, ListenAddr};
/// assert_eq!(
///     parse_listen("unix:/tmp/spmv.sock").unwrap(),
///     ListenAddr::Unix("/tmp/spmv.sock".into())
/// );
/// assert_eq!(
///     parse_listen("tcp:0.0.0.0:7077").unwrap(),
///     ListenAddr::Tcp("0.0.0.0:7077".into())
/// );
/// assert_eq!(
///     parse_listen("127.0.0.1:7077").unwrap(),
///     ListenAddr::Tcp("127.0.0.1:7077".into())
/// );
/// assert!(parse_listen("").is_err());
/// ```
pub fn parse_listen(spec: &str) -> Result<ListenAddr> {
    if let Some(path) = spec.strip_prefix("unix:") {
        anyhow::ensure!(!path.is_empty(), "empty unix socket path in {spec:?}");
        return Ok(ListenAddr::Unix(PathBuf::from(path)));
    }
    let addr = spec.strip_prefix("tcp:").unwrap_or(spec);
    anyhow::ensure!(
        addr.contains(':') && !addr.starts_with(':') && !addr.ends_with(':'),
        "listen spec {spec:?} is not unix:<path>, tcp:<host>:<port>, or <host>:<port>"
    );
    Ok(ListenAddr::Tcp(addr.to_string()))
}

/// Front-end tuning knobs. `Default` reads the environment
/// ([`ingress::configured_queue_depth`],
/// [`ingress::configured_coalesce_wait`],
/// [`session::configured_auth_token`],
/// [`session::configured_quota_requests`],
/// [`session::configured_quota_bytes`]); tests construct explicit
/// values instead of mutating the environment.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Per-shard ingress queue bound; a full queue answers `Busy`.
    pub queue_depth: usize,
    /// Post-first-arrival wait before the coalescer drains its queue.
    pub coalesce_wait: Duration,
    /// Auth token every v2 `Hello` must present (`SPMV_AT_NET_AUTH`);
    /// `None` = open server. When set, v1 clients are refused (their
    /// `Hello` cannot carry a token).
    pub auth_token: Option<String>,
    /// Per-session request budget (`SPMV_AT_NET_QUOTA_REQS`, 0 =
    /// unlimited); a session over budget gets `Busy` on every request.
    pub quota_requests: u64,
    /// Per-session request-payload byte budget
    /// (`SPMV_AT_NET_QUOTA_BYTES`, 0 = unlimited).
    pub quota_bytes: u64,
    /// Serving-decision log served to `DecisionLog` wire requests;
    /// `None` answers with an empty tail. Pass the same handle to
    /// [`crate::coordinator::CoordinatorConfig`] so records flow in.
    pub decision_log: Option<DecisionLog>,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            queue_depth: ingress::configured_queue_depth(),
            coalesce_wait: ingress::configured_coalesce_wait(),
            auth_token: session::configured_auth_token(),
            quota_requests: session::configured_quota_requests(),
            quota_bytes: session::configured_quota_bytes(),
            decision_log: None,
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

/// A connected stream over either transport.
enum Conn {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// The running network front end: listener + accept loop + coalescers,
/// wrapped around a [`Server`] and its [`Client`].
pub struct NetServer {
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    coalescers: Option<CoalescerSet>,
    ingress: Option<Ingress>,
    counters: Arc<NetCounters>,
    local: ListenAddr,
    unix_path: Option<PathBuf>,
    server: Option<Server>,
}

impl NetServer {
    /// Bind the listener and start serving connections. Binding failures
    /// surface here synchronously; after `Ok`, [`Self::local_addr`]
    /// carries the resolved address (useful with TCP port 0).
    pub fn start(server: Server, client: Client, addr: &ListenAddr, cfg: NetConfig) -> Result<Self> {
        let counters = Arc::new(NetCounters::default());
        let policy = SessionPolicy {
            auth_token: cfg.auth_token.clone(),
            quota_requests: cfg.quota_requests,
            quota_bytes: cfg.quota_bytes,
            decision_log: cfg.decision_log.clone(),
        };
        let (ing, coalescers) = ingress::spawn_coalescers(
            &client,
            cfg.queue_depth,
            cfg.coalesce_wait,
            Arc::clone(&counters),
        );
        let (listener, local, unix_path) = match addr {
            ListenAddr::Tcp(a) => {
                let l = TcpListener::bind(a)
                    .map_err(|e| anyhow::anyhow!("cannot listen on tcp:{a}: {e}"))?;
                let local = ListenAddr::Tcp(l.local_addr()?.to_string());
                l.set_nonblocking(true)?;
                (Listener::Tcp(l), local, None)
            }
            ListenAddr::Unix(p) => {
                // A leftover socket file from an unclean shutdown refuses
                // rebinding; reclaim it only if nothing answers on it.
                if p.exists() && UnixStream::connect(p).is_err() {
                    let _ = std::fs::remove_file(p);
                }
                let l = UnixListener::bind(p)
                    .map_err(|e| anyhow::anyhow!("cannot listen on unix:{}: {e}", p.display()))?;
                l.set_nonblocking(true)?;
                (Listener::Unix(l), ListenAddr::Unix(p.clone()), Some(p.clone()))
            }
        };

        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            let counters = Arc::clone(&counters);
            let ing = ing.clone();
            std::thread::Builder::new()
                .name("spmv-accept".into())
                .spawn(move || accept_loop(listener, stop, client, ing, counters, policy))
                .expect("spawn accept thread")
        };

        Ok(Self {
            stop,
            accept: Some(accept),
            coalescers: Some(coalescers),
            ingress: Some(ing),
            counters,
            local,
            unix_path,
            server: Some(server),
        })
    }

    /// The resolved listen address (with the OS-assigned port for TCP
    /// binds to port 0).
    pub fn local_addr(&self) -> &ListenAddr {
        &self.local
    }

    /// The serving-front counters (shared with sessions and coalescers).
    pub fn counters(&self) -> &Arc<NetCounters> {
        &self.counters
    }

    /// Stop accepting, join the coalescers, and shut the serving loops
    /// down, returning their coordinators (joins are bounded even while
    /// detached session threads linger — see [`ingress::CoalescerSet`]).
    pub fn shutdown(mut self) -> Vec<Coordinator> {
        self.stop_front();
        self.server.take().expect("server present until shutdown").shutdown_all()
    }

    fn stop_front(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        drop(self.ingress.take());
        if let Some(c) = self.coalescers.take() {
            c.join();
        }
        if let Some(p) = self.unix_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_front();
        if let Some(server) = self.server.take() {
            let _ = server.shutdown_all();
        }
    }
}

fn accept_loop(
    listener: Listener,
    stop: Arc<AtomicBool>,
    client: Client,
    ing: Ingress,
    counters: Arc<NetCounters>,
    policy: SessionPolicy,
) {
    while !stop.load(Ordering::Relaxed) {
        let conn = match &listener {
            Listener::Tcp(l) => match l.accept() {
                Ok((s, _)) => {
                    let _ = s.set_nonblocking(false);
                    let _ = s.set_nodelay(true);
                    Some(Conn::Tcp(s))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                Err(_) => None,
            },
            Listener::Unix(l) => match l.accept() {
                Ok((s, _)) => {
                    let _ = s.set_nonblocking(false);
                    Some(Conn::Unix(s))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                Err(_) => None,
            },
        };
        match conn {
            Some(conn) => {
                counters.sessions_total.fetch_add(1, Ordering::Relaxed);
                counters.sessions_open.fetch_add(1, Ordering::Relaxed);
                let client = client.clone();
                let ing = ing.clone();
                let counters = Arc::clone(&counters);
                let policy = policy.clone();
                // Detached on purpose: a session lives exactly as long as
                // its connection, and an abrupt disconnect must never take
                // anything down with it.
                let _ = std::thread::Builder::new().name("spmv-session".into()).spawn(move || {
                    let _ = session::run_session(conn, client, ing, policy);
                    counters.sessions_open.fetch_sub(1, Ordering::Relaxed);
                });
            }
            None => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// A blocking protocol client over either transport. One request in
/// flight at a time; the request-id echo is verified on every reply.
/// Every frame after the handshake is encoded and decoded at the
/// negotiated session version, so the same client type drives a v2
/// server in v1-compat mode byte-for-byte per the v1 spec.
pub struct NetClient {
    conn: Conn,
    next_id: u32,
    version: u16,
    window: (u16, u16),
}

impl NetClient {
    /// Connect and complete the version handshake at the protocol
    /// version `SPMV_AT_NET_PROTO` names (unset or empty: the current
    /// [`proto::VERSION`]), presenting the `SPMV_AT_NET_AUTH` token when
    /// set.
    pub fn connect(addr: &ListenAddr) -> Result<Self> {
        let version = match std::env::var("SPMV_AT_NET_PROTO") {
            Ok(v) if !v.trim().is_empty() => v
                .trim()
                .parse::<u16>()
                .map_err(|_| anyhow::anyhow!("SPMV_AT_NET_PROTO={v:?} is not a version number"))?,
            _ => proto::VERSION,
        };
        Self::connect_with(addr, version, session::configured_auth_token())
    }

    /// Connect and handshake at an explicit protocol `version`,
    /// presenting `auth` (ignored below v2 — a v1 `Hello` cannot carry a
    /// token).
    pub fn connect_with(addr: &ListenAddr, version: u16, auth: Option<String>) -> Result<Self> {
        let conn = match addr {
            ListenAddr::Tcp(a) => {
                let s = TcpStream::connect(a)?;
                s.set_nodelay(true)?;
                Conn::Tcp(s)
            }
            ListenAddr::Unix(p) => Conn::Unix(UnixStream::connect(p)?),
        };
        let mut c = Self { conn, next_id: 0, version, window: (version, version) };
        let hello = Message::Hello { version, auth: auth.unwrap_or_default() };
        // Hello/HelloAck are self-describing (laid out per their embedded
        // version field), so the pre-negotiation exchange works at any
        // requested version.
        match c.call(&hello)? {
            Message::HelloAck { version: v, min, max } => {
                anyhow::ensure!(
                    v == version,
                    "server acknowledged version {v}, client asked for {version}"
                );
                c.window = (min, max);
                Ok(c)
            }
            Message::Error { code, message } => {
                anyhow::bail!("handshake rejected (error {code}): {message}")
            }
            other => anyhow::bail!("unexpected handshake reply: {other:?}"),
        }
    }

    /// The negotiated session version.
    pub fn version(&self) -> u16 {
        self.version
    }

    /// The server's advertised `[min, max]` version window (the
    /// requested version mirrored back when serving a v1 handshake,
    /// which cannot carry the window).
    pub fn server_window(&self) -> (u16, u16) {
        self.window
    }

    fn call(&mut self, msg: &Message) -> Result<Message> {
        self.next_id = self.next_id.wrapping_add(1);
        let id = self.next_id;
        proto::write_frame(&mut self.conn, &proto::encode_versioned(id, msg, self.version))?;
        let payload = proto::read_frame(&mut self.conn)?
            .ok_or_else(|| anyhow::anyhow!("server closed the connection"))?;
        let (got, reply) = proto::decode_versioned(&payload, self.version)?;
        anyhow::ensure!(got == id, "response id {got} does not match request id {id}");
        Ok(reply)
    }

    /// Register a matrix under `name`.
    pub fn register(&mut self, name: &str, csr: &Csr) -> Result<WireStatsRow> {
        let msg = Message::Register {
            name: name.into(),
            n_rows: csr.n_rows() as u64,
            n_cols: csr.n_cols() as u64,
            row_ptr: csr.row_ptr.iter().map(|&v| v as u64).collect(),
            col_idx: csr.col_idx.clone(),
            values: csr.values.clone(),
        };
        match self.call(&msg)? {
            Message::Registered { row } => Ok(row),
            other => Err(reply_err(other)),
        }
    }

    /// `y = A·x` (single vector — the server may coalesce it with
    /// concurrent requests from other connections). No deadline.
    pub fn spmv(&mut self, name: &str, x: Vec<Value>) -> Result<Vec<Value>> {
        match self.call(&Message::Spmv { name: name.into(), x, deadline_us: 0 })? {
            Message::Vector { y } => Ok(y),
            other => Err(reply_err(other)),
        }
    }

    /// `y = A·x` with a relative deadline in microseconds from server
    /// receipt: if the request is still queued in the coalescer when the
    /// budget expires, the server sheds it with
    /// [`proto::ERR_DEADLINE_EXCEEDED`] instead of serving stale work.
    /// Needs a v2 session (`deadline_us` does not exist on the v1 wire).
    pub fn spmv_deadline(
        &mut self,
        name: &str,
        x: Vec<Value>,
        deadline_us: u64,
    ) -> Result<Vec<Value>> {
        anyhow::ensure!(
            self.version >= 2,
            "deadlines need protocol v2; this session negotiated v{}",
            self.version
        );
        match self.call(&Message::Spmv { name: name.into(), x, deadline_us })? {
            Message::Vector { y } => Ok(y),
            other => Err(reply_err(other)),
        }
    }

    /// Batched `Y = A·X`, pre-grouped by the caller.
    pub fn spmv_batch(&mut self, name: &str, xs: Vec<Vec<Value>>) -> Result<Vec<Vec<Value>>> {
        match self.call(&Message::SpmvBatch { name: name.into(), xs })? {
            Message::Vectors { ys } => Ok(ys),
            other => Err(reply_err(other)),
        }
    }

    /// All stats rows, merged across shards.
    pub fn stats(&mut self) -> Result<Vec<WireStatsRow>> {
        match self.call(&Message::Stats)? {
            Message::StatsRows { rows } => Ok(rows),
            other => Err(reply_err(other)),
        }
    }

    /// Force a re-decision for `name`.
    pub fn replan(&mut self, name: &str) -> Result<WireStatsRow> {
        match self.call(&Message::Replan { name: name.into() })? {
            Message::Registered { row } => Ok(row),
            other => Err(reply_err(other)),
        }
    }

    /// Evict `name`; `Ok(true)` if it existed.
    pub fn evict(&mut self, name: &str) -> Result<bool> {
        match self.call(&Message::Evict { name: name.into() })? {
            Message::Evicted { existed } => Ok(existed),
            other => Err(reply_err(other)),
        }
    }

    /// The server's ingress/coalescer counter snapshot.
    pub fn net_stats(&mut self) -> Result<WireNetStats> {
        match self.call(&Message::NetStats)? {
            Message::NetStatsReply { stats } => Ok(stats),
            other => Err(reply_err(other)),
        }
    }

    /// The tail of the server's serving-decision log (most recent JSONL
    /// records, oldest first; empty when the server runs without a
    /// log). Needs a v2 session — the opcode does not exist on the v1
    /// wire.
    pub fn decision_log(&mut self) -> Result<Vec<String>> {
        anyhow::ensure!(
            self.version >= 2,
            "the decision log needs protocol v2; this session negotiated v{}",
            self.version
        );
        match self.call(&Message::DecisionLog)? {
            Message::DecisionLogReply { lines } => Ok(lines),
            other => Err(reply_err(other)),
        }
    }
}

fn reply_err(msg: Message) -> anyhow::Error {
    match msg {
        Message::Busy => anyhow::anyhow!("server busy: queue full or session quota spent"),
        Message::Error { code, message } if code == proto::ERR_DEADLINE_EXCEEDED => {
            anyhow::anyhow!("deadline exceeded: {message}")
        }
        Message::Error { code, message } => anyhow::anyhow!("server error {code}: {message}"),
        other => anyhow::anyhow!("unexpected reply: {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;

    fn test_cfg() -> CoordinatorConfig {
        let tuning = crate::autotune::online::TuningData {
            backend: "sim:ES2".into(),
            imp: crate::spmv::Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        };
        let mut cfg = CoordinatorConfig::new(tuning);
        cfg.threads = 2;
        cfg.adaptive.enabled = false;
        cfg
    }

    fn net_cfg(queue_depth: usize) -> NetConfig {
        NetConfig {
            queue_depth,
            coalesce_wait: Duration::ZERO,
            auth_token: None,
            quota_requests: 0,
            quota_bytes: 0,
            decision_log: None,
        }
    }

    fn start_tcp(cfg: NetConfig) -> NetServer {
        let (server, client) = Server::spawn_sharded(test_cfg(), 32);
        NetServer::start(server, client, &ListenAddr::Tcp("127.0.0.1:0".into()), cfg)
            .expect("bind an ephemeral port")
    }

    #[test]
    fn parse_listen_accepts_all_three_forms() {
        assert_eq!(parse_listen("unix:/tmp/x.sock").unwrap(), ListenAddr::Unix("/tmp/x.sock".into()));
        assert_eq!(parse_listen("tcp:127.0.0.1:9").unwrap(), ListenAddr::Tcp("127.0.0.1:9".into()));
        assert_eq!(parse_listen("127.0.0.1:9").unwrap(), ListenAddr::Tcp("127.0.0.1:9".into()));
        assert!(parse_listen("").is_err());
        assert!(parse_listen("unix:").is_err());
        assert!(parse_listen("justahost").is_err());
    }

    #[test]
    fn tcp_roundtrip_register_spmv_stats_evict() {
        let net = start_tcp(net_cfg(64));
        let addr = net.local_addr().clone();
        // connect() honours SPMV_AT_NET_PROTO (the CI v1-compat leg sets
        // it), so this roundtrip exercises whichever version the
        // environment picked; the serving results are identical.
        let mut c = NetClient::connect(&addr).unwrap();

        let csr = Csr::identity(5);
        let row = c.register("id", &csr).unwrap();
        assert_eq!(row.n, 5);
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(c.spmv("id", x.clone()).unwrap(), x);
        assert_eq!(c.spmv_batch("id", vec![x.clone(), x.clone()]).unwrap(), vec![x.clone(), x]);
        assert_eq!(c.stats().unwrap().len(), 1);
        let ns = c.net_stats().unwrap();
        assert_eq!(ns.requests, 1);
        assert!(ns.sessions_total >= 1);
        assert!(c.evict("id").unwrap());
        assert!(!c.evict("id").unwrap());
        drop(c);
        net.shutdown();
    }

    #[test]
    fn unix_socket_roundtrip_and_socket_file_cleanup() {
        let path = std::env::temp_dir().join(format!("spmv-at-test-{}.sock", std::process::id()));
        let (server, client) = Server::spawn_sharded(test_cfg(), 32);
        let net = NetServer::start(server, client, &ListenAddr::Unix(path.clone()), net_cfg(64))
            .unwrap();
        let mut c = NetClient::connect(&ListenAddr::Unix(path.clone())).unwrap();
        c.register("id", &Csr::identity(3)).unwrap();
        assert_eq!(c.spmv("id", vec![1.0, 2.0, 3.0]).unwrap(), vec![1.0, 2.0, 3.0]);
        drop(c);
        net.shutdown();
        assert!(!path.exists(), "shutdown removes the socket file");
    }

    #[test]
    fn version_mismatch_is_rejected_with_the_right_code() {
        let net = start_tcp(net_cfg(4));
        let ListenAddr::Tcp(addr) = net.local_addr().clone() else { unreachable!() };
        let mut s = TcpStream::connect(&addr).unwrap();
        let hello = Message::Hello { version: 999, auth: String::new() };
        proto::write_frame(&mut s, &proto::encode(1, &hello)).unwrap();
        let payload = proto::read_frame(&mut s).unwrap().unwrap();
        let (_, reply) = proto::decode(&payload).unwrap();
        match reply {
            Message::Error { code, .. } => assert_eq!(code, proto::ERR_UNSUPPORTED_VERSION),
            other => panic!("expected Error, got {other:?}"),
        }
        // The server then closes: next read is clean EOF.
        assert!(proto::read_frame(&mut s).unwrap().is_none());
        net.shutdown();
    }

    #[test]
    fn explicit_version_negotiation_reports_the_window() {
        let net = start_tcp(net_cfg(16));
        let addr = net.local_addr().clone();
        let mut v2 = NetClient::connect_with(&addr, proto::VERSION, None).unwrap();
        assert_eq!(v2.version(), proto::VERSION);
        assert_eq!(v2.server_window(), (proto::MIN_VERSION, proto::VERSION));
        let mut v1 = NetClient::connect_with(&addr, 1, None).unwrap();
        assert_eq!(v1.version(), 1);
        // A v1 HelloAck cannot carry the window; the requested version is
        // mirrored back.
        assert_eq!(v1.server_window(), (1, 1));
        // Both sessions serve, against the same registry.
        v2.register("id", &Csr::identity(3)).unwrap();
        assert_eq!(v1.spmv("id", vec![1.0, 2.0, 3.0]).unwrap(), vec![1.0, 2.0, 3.0]);
        // v2-only calls refuse cleanly on the v1 session.
        assert!(v1.spmv_deadline("id", vec![0.0; 3], 1_000_000).is_err());
        assert!(v1.decision_log().is_err());
        // …and work on the v2 session (ample deadline, no log configured).
        assert_eq!(
            v2.spmv_deadline("id", vec![1.0, 1.0, 1.0], 60_000_000).unwrap(),
            vec![1.0, 1.0, 1.0]
        );
        assert_eq!(v2.decision_log().unwrap(), Vec::<String>::new());
        drop((v1, v2));
        net.shutdown();
    }
}
