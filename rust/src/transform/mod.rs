//! Run-time sparse data transformations from CRS (paper §2.1).
//!
//! These are the routines whose cost `t_trans` enters the `R_ell` ratio: the
//! auto-tuner only transforms when the SpMV speedup amortises this cost.
//!
//! * [`crs_to_coo_row`] — trivial: expand `IRP` into `IROW`.
//! * [`crs_to_ccs`] — the paper's Phase-I counting algorithm, reproduced
//!   loop-for-loop from the §2.1 listing.
//! * [`crs_to_coo_col`] — Phase I + Phase II (CCS → column-major COO).
//! * [`crs_to_ell`] — row-wise gather with zero padding into band-major
//!   storage.
//! * [`crs_to_bcsr`] — the future-work extension (block discovery + fill).
//!
//! [`par`] holds the parallel variants (the paper's declared future work,
//! "we do not show the parallel implementations of the data transformation
//! processes"), used by the `ablation` bench to quantify what parallel
//! transformation would buy.

pub mod par;

mod roundtrip;

pub use roundtrip::{coo_to_crs, csc_to_crs, ell_to_crs};

use crate::formats::{Coo, CooOrder, Csc, Csr, Ell, SparseMatrix};
use crate::{Index, Result, Value};

/// CRS → COO-Row: copy `VAL`/`ICOL`, expand the row pointers into `IROW`.
/// "Transformation from the CRS to the COO … is easy if the COO … requires
/// row-wise storage" (§2.1).
pub fn crs_to_coo_row(a: &Csr) -> Coo {
    let nnz = a.nnz();
    let mut row_idx = Vec::with_capacity(nnz);
    for i in 0..a.n_rows() {
        let len = a.row_len(i);
        row_idx.extend(std::iter::repeat(i as Index).take(len));
    }
    // Sorted/in-bounds by construction: skip the validation passes.
    Coo::from_parts_unchecked(
        a.n_rows(),
        a.n_cols(),
        row_idx,
        a.col_idx.clone(),
        a.values.clone(),
        CooOrder::RowMajor,
    )
}

/// CRS → CCS, the paper's Phase-I algorithm (§2.1 listing), kept
/// structurally identical to the Fortran original:
///
/// 1. count non-zeros per column into `NC_IRP`;
/// 2. prefix-sum into the new pointers `IRP_T`;
/// 3. second sweep scatters values/row-indices into their column segments
///    using `NC_IRP` as a moving cursor.
pub fn crs_to_ccs(a: &Csr) -> Csc {
    let n_cols = a.n_cols();
    let nnz = a.nnz();
    // === Count the number of non-zero columns.
    let mut nc_irp = vec![0usize; n_cols];
    for &c in &a.col_idx {
        nc_irp[c as usize] += 1;
    }
    // === Set IRP (prefix sums -> column pointers).
    let mut col_ptr = vec![0usize; n_cols + 1];
    for j in 0..n_cols {
        col_ptr[j + 1] = col_ptr[j] + nc_irp[j];
    }
    // Reset the cursor array to the segment starts.
    nc_irp.copy_from_slice(&col_ptr[..n_cols]);
    // === Set column numbers (scatter pass).
    let mut row_idx = vec![0 as Index; nnz];
    let mut values = vec![0.0 as Value; nnz];
    for i in 0..a.n_rows() {
        for (c, v) in a.row(i) {
            let k = nc_irp[c as usize];
            nc_irp[c as usize] += 1;
            values[k] = v;
            row_idx[k] = i as Index;
        }
    }
    Csc::new(a.n_rows(), n_cols, col_ptr, row_idx, values)
        .expect("counting transform produces valid CSC")
}

/// CRS → COO-Column via the paper's two phases: Phase I builds CCS
/// ([`crs_to_ccs`]), Phase II expands the column pointers into explicit
/// column indices ("the transformation is easy since we know the first row
/// index in each column via the pointer arrays").
pub fn crs_to_coo_col(a: &Csr) -> Coo {
    let ccs = crs_to_ccs(a);
    let mut col_idx = Vec::with_capacity(ccs.nnz());
    for j in 0..ccs.n_cols() {
        col_idx.extend(std::iter::repeat(j as Index).take(ccs.col_len(j)));
    }
    // Move the CCS buffers out instead of cloning them (perf pass), and
    // skip re-validation — column-major order holds by construction.
    Coo::from_parts_unchecked(
        a.n_rows(),
        a.n_cols(),
        ccs.row_idx,
        col_idx,
        ccs.values,
        CooOrder::ColMajor,
    )
}

/// Checked ELL slot count `n·nz`, enforcing the optional byte budget
/// (the §2.2 memory auto-tuning policy hook; the paper had to drop
/// `torso1` for exactly this reason). Shared by the sequential and
/// parallel ELL builders so both paths enforce the same policy.
pub(crate) fn ell_checked_slots(a: &Csr, max_bytes: Option<usize>) -> Result<usize> {
    let n = a.n_rows();
    let nz = a.max_row_len();
    let slots = n.checked_mul(nz).ok_or_else(|| anyhow::anyhow!("ELL size overflow"))?;
    let bytes = slots * (std::mem::size_of::<Value>() + std::mem::size_of::<Index>());
    if let Some(cap) = max_bytes {
        anyhow::ensure!(
            bytes <= cap,
            "ELL storage {bytes} B exceeds memory budget {cap} B (n={n}, nz={nz})"
        );
    }
    Ok(slots)
}

/// CRS → ELL with band-major padded storage. Rows shorter than the
/// bandwidth get explicit `0.0` values with column index 0. Fails if the
/// padded storage would exceed `max_bytes` (see [`ell_checked_slots`]).
pub fn crs_to_ell_bounded(a: &Csr, max_bytes: Option<usize>) -> Result<Ell> {
    let n = a.n_rows();
    let nz = a.max_row_len();
    let slots = ell_checked_slots(a, max_bytes)?;
    let mut values = vec![0.0 as Value; slots];
    let mut col_idx = vec![0 as Index; slots];
    for i in 0..n {
        for (k, (c, v)) in a.row(i).enumerate() {
            // Band-major: J_PTR = N*(K-1) + I.
            values[k * n + i] = v;
            col_idx[k * n + i] = c;
        }
    }
    Ell::new(n, a.n_cols(), nz, values, col_idx, a.nnz())
}

/// CRS → ELL without a memory budget.
pub fn crs_to_ell(a: &Csr) -> Result<Ell> {
    crs_to_ell_bounded(a, None)
}

/// CRS → BCSR with `br × bc` blocks (paper §5 future work).
pub fn crs_to_bcsr(a: &Csr, br: usize, bc: usize) -> Result<crate::formats::Bcsr> {
    crate::formats::Bcsr::from_csr(a, br, bc)
}

/// CRS → JDS (extension: fill-free vector format).
pub fn crs_to_jds(a: &Csr) -> crate::formats::Jds {
    crate::formats::Jds::from_csr(a)
}

/// CRS → HYB with auto-chosen threshold (extension: capped-bandwidth ELL
/// with a COO spill tail).
pub fn crs_to_hyb(a: &Csr) -> Result<crate::formats::Hyb> {
    crate::formats::Hyb::from_csr(a)
}

/// Which transformation a [`crate::formats::FormatKind`] target requires,
/// with a uniform entry point used by the timing harness and coordinator.
pub fn transform_to(
    a: &Csr,
    target: crate::formats::FormatKind,
    max_bytes: Option<usize>,
) -> Result<Box<dyn SparseMatrix + Send + Sync>> {
    use crate::formats::FormatKind::*;
    Ok(match target {
        Csr => Box::new(a.clone()),
        Csc => Box::new(crs_to_ccs(a)),
        CooRow => Box::new(crs_to_coo_row(a)),
        CooCol => Box::new(crs_to_coo_col(a)),
        Ell => Box::new(crs_to_ell_bounded(a, max_bytes)?),
        Bcsr => Box::new(crs_to_bcsr(a, 2, 2)?),
        Jds => Box::new(crs_to_jds(a)),
        Hyb => Box::new(crs_to_hyb(a)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;

    fn sample() -> Csr {
        Csr::from_triplets(
            4,
            4,
            &[
                (0, 0, 1.0),
                (0, 3, 2.0),
                (1, 1, 3.0),
                (2, 0, 4.0),
                (2, 2, 5.0),
                (2, 3, 5.5),
                (3, 3, 6.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn coo_row_preserves_entries() {
        let a = sample();
        let c = crs_to_coo_row(&a);
        assert_eq!(c.nnz(), a.nnz());
        let mut t = c
            .row_idx
            .iter()
            .zip(&c.col_idx)
            .zip(&c.values)
            .map(|((&r, &cc), &v)| (r as usize, cc as usize, v))
            .collect::<Vec<_>>();
        t.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(t, a.to_triplets());
    }

    #[test]
    fn ccs_is_column_sorted_and_complete() {
        let a = sample();
        let c = crs_to_ccs(&a);
        assert_eq!(c.nnz(), a.nnz());
        let mut t = c.to_triplets_col_major();
        t.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut want = a.to_triplets();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(t, want);
        // Rows within each column are ascending (CRS sweep is row-ordered).
        for j in 0..4 {
            let rows: Vec<_> = c.col(j).map(|(r, _)| r).collect();
            let mut s = rows.clone();
            s.sort_unstable();
            assert_eq!(rows, s, "column {j} not row-sorted");
        }
    }

    #[test]
    fn coo_col_matches_two_phase_semantics() {
        let a = sample();
        let c = crs_to_coo_col(&a);
        assert_eq!(c.order(), CooOrder::ColMajor);
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y1 = vec![0.0; 4];
        let mut y2 = vec![0.0; 4];
        a.spmv(&x, &mut y1);
        c.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn ell_bounded_rejects_oversized() {
        // A torso1-like pathological row: 1 row with 100 entries, 99 rows with 1.
        let mut t: Vec<(usize, usize, Value)> = (0..100).map(|j| (0, j, 1.0)).collect();
        t.extend((1..100).map(|i| (i, i, 1.0)));
        let a = Csr::from_triplets(100, 100, &t).unwrap();
        // nz = 100, slots = 10_000 -> 120 KB; budget of 1 KB must fail.
        assert!(crs_to_ell_bounded(&a, Some(1024)).is_err());
        assert!(crs_to_ell_bounded(&a, None).is_ok());
    }

    #[test]
    fn transform_to_all_targets_agree_on_spmv() {
        let mut rng = Rng::new(2024);
        let a = random_csr(&mut rng, 50, 40, 0.08);
        let x: Vec<Value> = (0..40).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut want = vec![0.0; 50];
        a.spmv(&x, &mut want);
        for kind in crate::formats::FormatKind::ALL {
            let m = transform_to(&a, kind, None).unwrap();
            let mut got = vec![0.0; 50];
            m.spmv(&x, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12, "{kind}: {g} != {w}");
            }
        }
    }
}
