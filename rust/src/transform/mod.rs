//! Run-time sparse data transformations from CRS (paper §2.1).
//!
//! These are the routines whose cost `t_trans` enters the `R_ell` ratio: the
//! auto-tuner only transforms when the SpMV speedup amortises this cost.
//!
//! * [`crs_to_coo_row`] — trivial: expand `IRP` into `IROW`.
//! * [`crs_to_ccs`] — the paper's Phase-I counting algorithm, reproduced
//!   loop-for-loop from the §2.1 listing.
//! * [`crs_to_coo_col`] — Phase I + Phase II (CCS → column-major COO).
//! * [`crs_to_ell`] — row-wise gather with zero padding into band-major
//!   storage.
//! * [`crs_to_bcsr`] — the future-work extension (block discovery + fill).
//!
//! [`par`] holds the parallel variants (the paper's declared future work,
//! "we do not show the parallel implementations of the data transformation
//! processes"), used by the `ablation` bench to quantify what parallel
//! transformation would buy.

pub mod par;

mod roundtrip;

pub use roundtrip::{coo_to_crs, csc_to_crs, ell_to_crs, sell_to_crs};

use crate::formats::{Coo, CooOrder, Csc, Csr, Ell, SellCSigma, SparseMatrix, MAX_C};
use crate::{Index, Result, Value};

/// CRS → COO-Row: copy `VAL`/`ICOL`, expand the row pointers into `IROW`.
/// "Transformation from the CRS to the COO … is easy if the COO … requires
/// row-wise storage" (§2.1).
pub fn crs_to_coo_row(a: &Csr) -> Coo {
    let nnz = a.nnz();
    let mut row_idx = Vec::with_capacity(nnz);
    for i in 0..a.n_rows() {
        let len = a.row_len(i);
        row_idx.extend(std::iter::repeat(i as Index).take(len));
    }
    // Sorted/in-bounds by construction: skip the validation passes.
    Coo::from_parts_unchecked(
        a.n_rows(),
        a.n_cols(),
        row_idx,
        a.col_idx.clone(),
        a.values.clone(),
        CooOrder::RowMajor,
    )
}

/// CRS → CCS, the paper's Phase-I algorithm (§2.1 listing), kept
/// structurally identical to the Fortran original:
///
/// 1. count non-zeros per column into `NC_IRP`;
/// 2. prefix-sum into the new pointers `IRP_T`;
/// 3. second sweep scatters values/row-indices into their column segments
///    using `NC_IRP` as a moving cursor.
pub fn crs_to_ccs(a: &Csr) -> Csc {
    let n_cols = a.n_cols();
    let nnz = a.nnz();
    // === Count the number of non-zero columns.
    let mut nc_irp = vec![0usize; n_cols];
    for &c in &a.col_idx {
        nc_irp[c as usize] += 1;
    }
    // === Set IRP (prefix sums -> column pointers).
    let mut col_ptr = vec![0usize; n_cols + 1];
    for j in 0..n_cols {
        col_ptr[j + 1] = col_ptr[j] + nc_irp[j];
    }
    // Reset the cursor array to the segment starts.
    nc_irp.copy_from_slice(&col_ptr[..n_cols]);
    // === Set column numbers (scatter pass).
    let mut row_idx = vec![0 as Index; nnz];
    let mut values = vec![0.0 as Value; nnz];
    for i in 0..a.n_rows() {
        for (c, v) in a.row(i) {
            let k = nc_irp[c as usize];
            nc_irp[c as usize] += 1;
            values[k] = v;
            row_idx[k] = i as Index;
        }
    }
    Csc::new(a.n_rows(), n_cols, col_ptr, row_idx, values)
        .expect("counting transform produces valid CSC")
}

/// CRS → COO-Column via the paper's two phases: Phase I builds CCS
/// ([`crs_to_ccs`]), Phase II expands the column pointers into explicit
/// column indices ("the transformation is easy since we know the first row
/// index in each column via the pointer arrays").
pub fn crs_to_coo_col(a: &Csr) -> Coo {
    let ccs = crs_to_ccs(a);
    let mut col_idx = Vec::with_capacity(ccs.nnz());
    for j in 0..ccs.n_cols() {
        col_idx.extend(std::iter::repeat(j as Index).take(ccs.col_len(j)));
    }
    // Move the CCS buffers out instead of cloning them (perf pass), and
    // skip re-validation — column-major order holds by construction.
    Coo::from_parts_unchecked(
        a.n_rows(),
        a.n_cols(),
        ccs.row_idx,
        col_idx,
        ccs.values,
        CooOrder::ColMajor,
    )
}

/// Checked ELL slot count `n·nz`, enforcing the optional byte budget
/// (the §2.2 memory auto-tuning policy hook; the paper had to drop
/// `torso1` for exactly this reason). Shared by the sequential and
/// parallel ELL builders so both paths enforce the same policy.
pub(crate) fn ell_checked_slots(a: &Csr, max_bytes: Option<usize>) -> Result<usize> {
    let n = a.n_rows();
    let nz = a.max_row_len();
    let slots = n.checked_mul(nz).ok_or_else(|| anyhow::anyhow!("ELL size overflow"))?;
    let bytes = slots * (std::mem::size_of::<Value>() + std::mem::size_of::<Index>());
    if let Some(cap) = max_bytes {
        anyhow::ensure!(
            bytes <= cap,
            "ELL storage {bytes} B exceeds memory budget {cap} B (n={n}, nz={nz})"
        );
    }
    Ok(slots)
}

/// CRS → ELL with band-major padded storage. Rows shorter than the
/// bandwidth get explicit `0.0` values with column index 0. Fails if the
/// padded storage would exceed `max_bytes` (see [`ell_checked_slots`]).
pub fn crs_to_ell_bounded(a: &Csr, max_bytes: Option<usize>) -> Result<Ell> {
    let n = a.n_rows();
    let nz = a.max_row_len();
    let slots = ell_checked_slots(a, max_bytes)?;
    let mut values = vec![0.0 as Value; slots];
    let mut col_idx = vec![0 as Index; slots];
    for i in 0..n {
        for (k, (c, v)) in a.row(i).enumerate() {
            // Band-major: J_PTR = N*(K-1) + I.
            values[k * n + i] = v;
            col_idx[k * n + i] = c;
        }
    }
    Ell::new(n, a.n_cols(), nz, values, col_idx, a.nnz())
}

/// CRS → ELL without a memory budget.
pub fn crs_to_ell(a: &Csr) -> Result<Ell> {
    crs_to_ell_bounded(a, None)
}

/// Default SELL chunk height `C` when `SPMV_AT_SELL_C` is unset: two
/// AVX-512 / four AVX2 double lanes — wide enough to feed any current
/// host vector unit, short enough that the ragged tail stays small.
pub const DEFAULT_SELL_C: usize = 8;

/// SELL chunk height: `SPMV_AT_SELL_C` (clamped to `1..=MAX_C`), else
/// [`DEFAULT_SELL_C`]. The single truth function for the env knob.
pub fn configured_sell_c() -> usize {
    std::env::var("SPMV_AT_SELL_C")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|c| c.clamp(1, MAX_C))
        .unwrap_or(DEFAULT_SELL_C)
}

/// SELL sort window: `SPMV_AT_SELL_SIGMA` (≥ 1), else `4·C` — large
/// enough to group similar-length rows across a few chunks, small enough
/// that the permutation stays cache-local. The single truth function for
/// the env knob.
pub fn configured_sell_sigma(c: usize) -> usize {
    std::env::var("SPMV_AT_SELL_SIGMA")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&s| s >= 1)
        .unwrap_or(4 * c.max(1))
}

/// The σ-sorted SELL-C-σ layout (permutation, per-chunk widths/offsets)
/// plus the byte-budget check, shared by the sequential and parallel
/// builders so both enforce the same policy (mirrors [`ell_checked_slots`]).
pub(crate) struct SellLayout {
    pub c: usize,
    pub sigma: usize,
    pub perm: Vec<Index>,
    pub row_len: Vec<Index>,
    pub chunk_width: Vec<usize>,
    pub chunk_off: Vec<usize>,
    /// Total padded slots (Σ width·rows).
    pub slots: usize,
}

pub(crate) fn sell_layout(
    a: &Csr,
    c: usize,
    sigma: usize,
    max_bytes: Option<usize>,
) -> Result<SellLayout> {
    anyhow::ensure!((1..=MAX_C).contains(&c), "SELL chunk height C={c} outside 1..={MAX_C}");
    anyhow::ensure!(sigma >= 1, "SELL sort window sigma must be >= 1");
    let n = a.n_rows();
    // σ-window descending length sort; stable, so equal-length rows keep
    // their original order (deterministic layout).
    let mut perm: Vec<Index> = (0..n as Index).collect();
    for w in perm.chunks_mut(sigma) {
        w.sort_by_key(|&r| std::cmp::Reverse(a.row_len(r as usize)));
    }
    let row_len: Vec<Index> = perm.iter().map(|&r| a.row_len(r as usize) as Index).collect();
    let n_chunks = n.div_ceil(c);
    let mut chunk_width = vec![0usize; n_chunks];
    let mut chunk_off = vec![0usize; n_chunks];
    let mut slots = 0usize;
    for q in 0..n_chunks {
        let rows = c.min(n - q * c);
        let width =
            row_len[q * c..q * c + rows].iter().map(|&l| l as usize).max().unwrap_or(0);
        chunk_width[q] = width;
        chunk_off[q] = slots;
        slots = width
            .checked_mul(rows)
            .and_then(|s| slots.checked_add(s))
            .ok_or_else(|| anyhow::anyhow!("SELL size overflow"))?;
    }
    let bytes = slots * (std::mem::size_of::<Value>() + std::mem::size_of::<Index>())
        + n * 2 * std::mem::size_of::<Index>();
    if let Some(cap) = max_bytes {
        anyhow::ensure!(
            bytes <= cap,
            "SELL storage {bytes} B exceeds memory budget {cap} B (n={n}, C={c}, sigma={sigma})"
        );
    }
    Ok(SellLayout { c, sigma, perm, row_len, chunk_width, chunk_off, slots })
}

/// CRS → SELL-C-σ with explicit parameters (no byte budget). The
/// parameterised entry point property tests use so they never touch
/// process environment.
pub fn crs_to_sell_with(a: &Csr, c: usize, sigma: usize) -> Result<SellCSigma> {
    crs_to_sell_impl(a, c, sigma, None)
}

/// CRS → SELL-C-σ with `C`/`σ` from `SPMV_AT_SELL_C`/`SPMV_AT_SELL_SIGMA`
/// (see [`configured_sell_c`]/[`configured_sell_sigma`]), enforcing the
/// optional byte budget like the ELL builder.
pub fn crs_to_sell_bounded(a: &Csr, max_bytes: Option<usize>) -> Result<SellCSigma> {
    let c = configured_sell_c();
    crs_to_sell_impl(a, c, configured_sell_sigma(c), max_bytes)
}

/// CRS → SELL-C-σ without a memory budget (env-configured `C`/`σ`).
pub fn crs_to_sell(a: &Csr) -> Result<SellCSigma> {
    crs_to_sell_bounded(a, None)
}

fn crs_to_sell_impl(a: &Csr, c: usize, sigma: usize, max_bytes: Option<usize>) -> Result<SellCSigma> {
    let l = sell_layout(a, c, sigma, max_bytes)?;
    let n = a.n_rows();
    let mut values = vec![0.0 as Value; l.slots];
    let mut col_idx = vec![0 as Index; l.slots];
    for q in 0..l.chunk_width.len() {
        let rows = c.min(n - q * c);
        let off = l.chunk_off[q];
        for i in 0..rows {
            let r = l.perm[q * c + i] as usize;
            for (k, (col, v)) in a.row(r).enumerate() {
                // Chunk-band-major: lane-contiguous within each band.
                values[off + k * rows + i] = v;
                col_idx[off + k * rows + i] = col;
            }
        }
    }
    SellCSigma::new(
        n,
        a.n_cols(),
        l.c,
        l.sigma,
        l.chunk_width,
        l.chunk_off,
        l.perm,
        l.row_len,
        values,
        col_idx,
    )
}

/// CRS → BCSR with `br × bc` blocks (paper §5 future work).
pub fn crs_to_bcsr(a: &Csr, br: usize, bc: usize) -> Result<crate::formats::Bcsr> {
    crate::formats::Bcsr::from_csr(a, br, bc)
}

/// CRS → JDS (extension: fill-free vector format).
pub fn crs_to_jds(a: &Csr) -> crate::formats::Jds {
    crate::formats::Jds::from_csr(a)
}

/// CRS → HYB with auto-chosen threshold (extension: capped-bandwidth ELL
/// with a COO spill tail).
pub fn crs_to_hyb(a: &Csr) -> Result<crate::formats::Hyb> {
    crate::formats::Hyb::from_csr(a)
}

/// Which transformation a [`crate::formats::FormatKind`] target requires,
/// with a uniform entry point used by the timing harness and coordinator.
pub fn transform_to(
    a: &Csr,
    target: crate::formats::FormatKind,
    max_bytes: Option<usize>,
) -> Result<Box<dyn SparseMatrix + Send + Sync>> {
    use crate::formats::FormatKind::*;
    Ok(match target {
        Csr => Box::new(a.clone()),
        Csc => Box::new(crs_to_ccs(a)),
        CooRow => Box::new(crs_to_coo_row(a)),
        CooCol => Box::new(crs_to_coo_col(a)),
        Ell => Box::new(crs_to_ell_bounded(a, max_bytes)?),
        Bcsr => Box::new(crs_to_bcsr(a, 2, 2)?),
        Jds => Box::new(crs_to_jds(a)),
        Hyb => Box::new(crs_to_hyb(a)?),
        Sell => Box::new(crs_to_sell_bounded(a, max_bytes)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;

    fn sample() -> Csr {
        Csr::from_triplets(
            4,
            4,
            &[
                (0, 0, 1.0),
                (0, 3, 2.0),
                (1, 1, 3.0),
                (2, 0, 4.0),
                (2, 2, 5.0),
                (2, 3, 5.5),
                (3, 3, 6.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn coo_row_preserves_entries() {
        let a = sample();
        let c = crs_to_coo_row(&a);
        assert_eq!(c.nnz(), a.nnz());
        let mut t = c
            .row_idx
            .iter()
            .zip(&c.col_idx)
            .zip(&c.values)
            .map(|((&r, &cc), &v)| (r as usize, cc as usize, v))
            .collect::<Vec<_>>();
        t.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(t, a.to_triplets());
    }

    #[test]
    fn ccs_is_column_sorted_and_complete() {
        let a = sample();
        let c = crs_to_ccs(&a);
        assert_eq!(c.nnz(), a.nnz());
        let mut t = c.to_triplets_col_major();
        t.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut want = a.to_triplets();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(t, want);
        // Rows within each column are ascending (CRS sweep is row-ordered).
        for j in 0..4 {
            let rows: Vec<_> = c.col(j).map(|(r, _)| r).collect();
            let mut s = rows.clone();
            s.sort_unstable();
            assert_eq!(rows, s, "column {j} not row-sorted");
        }
    }

    #[test]
    fn coo_col_matches_two_phase_semantics() {
        let a = sample();
        let c = crs_to_coo_col(&a);
        assert_eq!(c.order(), CooOrder::ColMajor);
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y1 = vec![0.0; 4];
        let mut y2 = vec![0.0; 4];
        a.spmv(&x, &mut y1);
        c.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn ell_bounded_rejects_oversized() {
        // A torso1-like pathological row: 1 row with 100 entries, 99 rows with 1.
        let mut t: Vec<(usize, usize, Value)> = (0..100).map(|j| (0, j, 1.0)).collect();
        t.extend((1..100).map(|i| (i, i, 1.0)));
        let a = Csr::from_triplets(100, 100, &t).unwrap();
        // nz = 100, slots = 10_000 -> 120 KB; budget of 1 KB must fail.
        assert!(crs_to_ell_bounded(&a, Some(1024)).is_err());
        assert!(crs_to_ell_bounded(&a, None).is_ok());
    }

    #[test]
    fn sell_bounded_rejects_oversized() {
        // The same pathological shape the ELL budget test uses; SELL's
        // per-chunk padding shrinks the span but a 100-entry row still
        // blows a 1 KB budget.
        let mut t: Vec<(usize, usize, Value)> = (0..100).map(|j| (0, j, 1.0)).collect();
        t.extend((1..100).map(|i| (i, i, 1.0)));
        let a = Csr::from_triplets(100, 100, &t).unwrap();
        assert!(sell_layout(&a, 8, 32, Some(1024)).is_err());
        assert!(crs_to_sell_bounded(&a, None).is_ok());
        // SELL pads each chunk only to its own widest row, so the padded
        // span must be strictly below ELL's n*nz for this shape.
        let s = crs_to_sell_with(&a, 8, 32).unwrap();
        assert!(s.padded_slots() < ell_checked_slots(&a, None).unwrap());
    }

    #[test]
    fn transform_to_all_targets_agree_on_spmv() {
        let mut rng = Rng::new(2024);
        let a = random_csr(&mut rng, 50, 40, 0.08);
        let x: Vec<Value> = (0..40).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut want = vec![0.0; 50];
        a.spmv(&x, &mut want);
        for kind in crate::formats::FormatKind::ALL {
            let m = transform_to(&a, kind, None).unwrap();
            let mut got = vec![0.0; 50];
            m.spmv(&x, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12, "{kind}: {g} != {w}");
            }
        }
    }
}
