//! Inverse transformations back to CRS.
//!
//! The paper only transforms *away* from CRS; the inverses exist here so
//! that (a) property tests can assert lossless round-trips and (b) the
//! coordinator can evict a transformed copy and rebuild CRS if the memory
//! policy demands it.

use crate::formats::{Coo, Csc, Csr, Ell, SellCSigma, SparseMatrix};
use crate::Index;

/// COO (either order) → CRS.
pub fn coo_to_crs(c: &Coo) -> Csr {
    let nnz = c.nnz();
    let n_rows = c.n_rows();
    // Counting sort by row, preserving the (already sorted) column order
    // within rows for RowMajor input; ColMajor input gets columns in
    // ascending row order per column which after the scatter is also
    // column-sorted within each row (stable counting scatter over a
    // col-major stream yields col-sorted rows).
    let mut cnt = vec![0usize; n_rows + 1];
    for &r in &c.row_idx {
        cnt[r as usize + 1] += 1;
    }
    for i in 0..n_rows {
        cnt[i + 1] += cnt[i];
    }
    let row_ptr = cnt.clone();
    let mut col_idx = vec![0 as Index; nnz];
    let mut values = vec![0.0; nnz];
    for k in 0..nnz {
        let r = c.row_idx[k] as usize;
        let slot = cnt[r];
        cnt[r] += 1;
        col_idx[slot] = c.col_idx[k];
        values[slot] = c.values[k];
    }
    Csr::new(n_rows, c.n_cols(), row_ptr, col_idx, values)
        .expect("COO scatter produces valid CSR")
}

/// CCS → CRS (the reverse counting transform).
pub fn csc_to_crs(c: &Csc) -> Csr {
    let nnz = c.nnz();
    let n_rows = c.n_rows();
    let mut cnt = vec![0usize; n_rows + 1];
    for &r in &c.row_idx {
        cnt[r as usize + 1] += 1;
    }
    for i in 0..n_rows {
        cnt[i + 1] += cnt[i];
    }
    let row_ptr = cnt.clone();
    let mut col_idx = vec![0 as Index; nnz];
    let mut values = vec![0.0; nnz];
    for j in 0..c.n_cols() {
        for (r, v) in c.col(j) {
            let slot = cnt[r as usize];
            cnt[r as usize] += 1;
            col_idx[slot] = j as Index;
            values[slot] = v;
        }
    }
    Csr::new(n_rows, c.n_cols(), row_ptr, col_idx, values)
        .expect("CSC scatter produces valid CSR")
}

/// ELL → CRS, dropping padding slots (zero value **and** column 0 beyond the
/// row's logical population cannot be distinguished from a stored exact
/// zero at column 0, so this uses the stored-value-count convention: slots
/// are dropped only if they are padding, i.e. trailing `(0.0, col 0)`
/// entries; stored exact zeros inside the band survive).
pub fn ell_to_crs(e: &Ell) -> Csr {
    let n = e.n_rows();
    let mut triplets: Vec<(usize, usize, f64)> = Vec::with_capacity(e.nnz());
    for i in 0..n {
        for k in 0..e.bandwidth {
            let off = e.offset(i, k);
            let v = e.values[off];
            let c = e.col_idx[off] as usize;
            if v != 0.0 || c != 0 {
                triplets.push((i, c, v));
            }
        }
    }
    Csr::from_triplets(n, e.n_cols(), &triplets).expect("ELL entries are in bounds")
}

/// SELL-C-σ → CRS. Unlike [`ell_to_crs`], no padding convention is
/// needed: the format stores each sorted slot's logical row length, so
/// the walk visits exactly the stored entries (through the row
/// permutation) and the round-trip is exact — stored zeros at column 0
/// included.
pub fn sell_to_crs(s: &SellCSigma) -> Csr {
    let mut triplets: Vec<(usize, usize, f64)> = Vec::with_capacity(s.nnz());
    for q in 0..s.n_chunks() {
        let rows = s.chunk_rows(q);
        let base = q * s.c;
        let off = s.chunk_off[q];
        for i in 0..rows {
            let r = s.perm[base + i] as usize;
            for k in 0..s.row_len[base + i] as usize {
                let p = off + k * rows + i;
                triplets.push((r, s.col_idx[p] as usize, s.values[p]));
            }
        }
    }
    Csr::from_triplets(s.n_rows(), s.n_cols(), &triplets).expect("SELL entries are in bounds")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::CooOrder;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;
    use crate::transform::{crs_to_ccs, crs_to_coo_col, crs_to_coo_row, crs_to_ell};

    fn random_matrix(seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        random_csr(&mut rng, 64, 48, 0.07)
    }

    #[test]
    fn coo_row_roundtrip_exact() {
        let a = random_matrix(1);
        let back = coo_to_crs(&crs_to_coo_row(&a));
        assert_eq!(a, back);
    }

    #[test]
    fn coo_col_roundtrip_exact() {
        let a = random_matrix(2);
        let back = coo_to_crs(&crs_to_coo_col(&a));
        assert_eq!(a, back);
    }

    #[test]
    fn ccs_roundtrip_exact() {
        let a = random_matrix(3);
        let back = csc_to_crs(&crs_to_ccs(&a));
        assert_eq!(a, back);
    }

    #[test]
    fn ell_roundtrip_preserves_nonzeros() {
        let a = random_matrix(4);
        let back = ell_to_crs(&crs_to_ell(&a).unwrap());
        assert_eq!(a, back);
    }

    #[test]
    fn ell_roundtrip_keeps_explicit_zero_off_column_zero() {
        use crate::Value;
        // A stored 0.0 at column 2 must survive; padding must not.
        let a = Csr::from_triplets(2, 3, &[(0, 2, 0.0), (0, 1, 5.0), (1, 0, 1.0)]).unwrap();
        let e = crs_to_ell(&a).unwrap();
        let back = ell_to_crs(&e);
        let t: Vec<(usize, usize, Value)> = back.to_triplets();
        assert!(t.contains(&(0, 2, 0.0)), "explicit zero dropped: {t:?}");
        assert_eq!(back.nnz(), 3);
    }

    #[test]
    fn order_marker_used() {
        // Exercise the pub use to keep the import meaningful.
        let _ = CooOrder::RowMajor;
    }

    /// The ISSUE-6 property matrix: CSR→SELL-C-σ→CSR is exact across
    /// C ∈ {1, 4, 32} × σ ∈ {1, C, 4C, n} over shapes including empty
    /// rows and a single giant row.
    #[test]
    fn sell_roundtrip_property_matrix() {
        use crate::transform::crs_to_sell_with;
        let mut giant: Vec<(usize, usize, f64)> = (0..40).map(|j| (3, j, (j + 1) as f64)).collect();
        giant.extend([(0, 0, 1.0), (17, 5, -2.0)]);
        let shapes: Vec<(&str, Csr)> = vec![
            ("random", random_matrix(11)),
            // Empty rows throughout (row 1 of 3 populated), plus all-empty.
            ("sparse-rows", Csr::from_triplets(9, 9, &[(1, 1, 2.0), (7, 0, 3.0)]).unwrap()),
            ("all-empty", Csr::from_triplets(6, 6, &[]).unwrap()),
            ("giant-row", Csr::from_triplets(18, 40, &giant).unwrap()),
        ];
        for (tag, a) in &shapes {
            let n = a.n_rows().max(1);
            for c in [1usize, 4, 32] {
                for sigma in [1usize, c, 4 * c, n] {
                    let s = crs_to_sell_with(a, c, sigma).unwrap();
                    let back = sell_to_crs(&s);
                    assert_eq!(a, &back, "{tag}: C={c} sigma={sigma}");
                    assert_eq!(s.nnz(), a.nnz(), "{tag}: C={c} sigma={sigma}");
                }
            }
        }
    }

    #[test]
    fn sell_roundtrip_keeps_explicit_zero_at_column_zero() {
        use crate::transform::crs_to_sell_with;
        // The case the ELL padding convention cannot represent: a stored
        // exact zero AT column 0. SELL's per-row lengths keep it.
        let a = Csr::from_triplets(2, 3, &[(0, 0, 0.0), (0, 1, 5.0), (1, 0, 1.0)]).unwrap();
        let s = crs_to_sell_with(&a, 2, 2).unwrap();
        let back = sell_to_crs(&s);
        assert_eq!(a, back);
        assert!(back.to_triplets().contains(&(0, 0, 0.0)));
    }
}
