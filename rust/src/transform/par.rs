//! Parallel data transformations (the paper's §5 future work:
//! "we do not show the parallel implementations of the data transformation
//! processes … Evaluation with parallelized transformations … are future
//! work").
//!
//! Strategy: every transform splits its scatter pass over row (or entry)
//! ranges with per-chunk write cursors derived from a shared counting
//! pass, mirroring how the SpMV kernels split work with `ISTART/IEND`.
//! All chunks execute on a persistent [`ParPool`] — the `*_on` entry
//! points take an explicit pool (this is what plan construction uses);
//! the `*_par(a, n_threads)` wrappers keep the historical signature and
//! run `n_threads` chunks on the global pool.
//!
//! Every fan-out here goes through [`ParPool::run_init`], not plain
//! `run_chunks`: a transform *is* array initialization, so on a
//! socket-pinned shard pool the freshly written COO/ELL/CCS pages are
//! first-touched — physically placed — on the socket that will stream
//! them (the NUMA layer's core mechanism; see
//! [`crate::machine::topology`]).

use crate::formats::{Coo, CooOrder, Csc, Csr, Ell, SellCSigma, SparseMatrix};
use crate::spmv::partition::split_even;
use crate::spmv::pool::{self, ParPool, SendPtr};
use crate::{Index, Result, Value};

/// Parallel CRS → SELL-C-σ on `pool` with a storage bound and the env
/// `C`/`σ` knobs (the same [`super::sell_layout`] policy the sequential
/// builder enforces). The σ-sorted layout (permutation, widths, offsets)
/// is computed serially — an O(n) pass plus window sorts — then the
/// padded scatter fans out over *chunk* ranges via `run_init`: each range
/// owns the disjoint storage span `chunk_off[lo]..chunk_off[hi]`, so the
/// freshly written pages are first-touched on the pinned pool's socket.
pub fn crs_to_sell_bounded_on(
    a: &Csr,
    max_bytes: Option<usize>,
    pool: &ParPool,
) -> Result<SellCSigma> {
    let c = super::configured_sell_c();
    crs_to_sell_chunked(a, c, super::configured_sell_sigma(c), max_bytes, pool, pool.size())
}

/// Parallel CRS → SELL-C-σ with explicit parameters (no byte budget).
pub fn crs_to_sell_with_on(a: &Csr, c: usize, sigma: usize, pool: &ParPool) -> Result<SellCSigma> {
    crs_to_sell_chunked(a, c, sigma, None, pool, pool.size())
}

fn crs_to_sell_chunked(
    a: &Csr,
    c: usize,
    sigma: usize,
    max_bytes: Option<usize>,
    pool: &ParPool,
    n_splits: usize,
) -> Result<SellCSigma> {
    let l = super::sell_layout(a, c, sigma, max_bytes)?;
    let n = a.n_rows();
    let n_chunks = l.chunk_width.len();
    let mut values = vec![0.0 as Value; l.slots];
    let mut col_idx = vec![0 as Index; l.slots];
    let ranges = split_even(n_chunks, n_splits);
    let vp = SendPtr(values.as_mut_ptr());
    let cp = SendPtr(col_idx.as_mut_ptr());
    let lr = &l;
    pool.run_init(&ranges, |_tid, r| {
        for q in r {
            let rows = c.min(n - q * c);
            let off = lr.chunk_off[q];
            let width = lr.chunk_width[q];
            for i in 0..rows {
                let row = lr.perm[q * c + i] as usize;
                let mut k = 0usize;
                for (col, v) in a.row(row) {
                    unsafe {
                        *vp.get().add(off + k * rows + i) = v;
                        *cp.get().add(off + k * rows + i) = col;
                    }
                    k += 1;
                }
                // Write the padding slots too so every page of the chunk
                // span is first-touched on this pool.
                while k < width {
                    unsafe {
                        *vp.get().add(off + k * rows + i) = 0.0;
                        *cp.get().add(off + k * rows + i) = 0;
                    }
                    k += 1;
                }
            }
        }
    });
    SellCSigma::new(
        n,
        a.n_cols(),
        l.c,
        l.sigma,
        l.chunk_width,
        l.chunk_off,
        l.perm,
        l.row_len,
        values,
        col_idx,
    )
}

/// Parallel CRS → ELL on `pool` with a storage bound (the same
/// [`super::ell_checked_slots`] policy the sequential builder enforces):
/// each chunk owns a contiguous row range and fills its band-major slots
/// independently (no write conflicts: slot `k*n+i` belongs to exactly one
/// row `i`).
pub fn crs_to_ell_bounded_on(a: &Csr, max_bytes: Option<usize>, pool: &ParPool) -> Result<Ell> {
    super::ell_checked_slots(a, max_bytes)?;
    crs_to_ell_chunked(a, pool, pool.size())
}

/// Parallel CRS → ELL on `pool` without a storage bound.
pub fn crs_to_ell_on(a: &Csr, pool: &ParPool) -> Result<Ell> {
    crs_to_ell_bounded_on(a, None, pool)
}

/// Parallel CRS → ELL at `n_threads` chunks on the global pool.
pub fn crs_to_ell_par(a: &Csr, n_threads: usize) -> Result<Ell> {
    crs_to_ell_chunked(a, &pool::global(), n_threads)
}

fn crs_to_ell_chunked(a: &Csr, pool: &ParPool, n_chunks: usize) -> Result<Ell> {
    let n = a.n_rows();
    let nz = a.max_row_len();
    let slots = n.checked_mul(nz).ok_or_else(|| anyhow::anyhow!("ELL size overflow"))?;
    let mut values = vec![0.0 as Value; slots];
    let mut col_idx = vec![0 as Index; slots];
    let ranges = split_even(n, n_chunks);
    let vp = SendPtr(values.as_mut_ptr());
    let cp = SendPtr(col_idx.as_mut_ptr());
    pool.run_init(&ranges, |_tid, r| {
        for i in r {
            for (k, (c, v)) in a.row(i).enumerate() {
                unsafe {
                    *vp.get().add(k * n + i) = v;
                    *cp.get().add(k * n + i) = c;
                }
            }
        }
    });
    Ell::new(n, a.n_cols(), nz, values, col_idx, a.nnz())
}

/// Parallel CRS → COO-Row: the `IROW` expansion is embarrassingly parallel
/// over row ranges (each chunk writes the disjoint `row_ptr[lo]..row_ptr[hi]`
/// span of `IROW`).
pub fn crs_to_coo_row_on(a: &Csr, pool: &ParPool) -> Coo {
    crs_to_coo_row_chunked(a, pool, pool.size())
}

/// Parallel CRS → COO-Row at `n_threads` chunks on the global pool.
pub fn crs_to_coo_row_par(a: &Csr, n_threads: usize) -> Coo {
    crs_to_coo_row_chunked(a, &pool::global(), n_threads)
}

fn crs_to_coo_row_chunked(a: &Csr, pool: &ParPool, n_chunks: usize) -> Coo {
    let nnz = a.nnz();
    let n = a.n_rows();
    let mut row_idx = vec![0 as Index; nnz];
    let ranges = split_even(n, n_chunks);
    let rp = SendPtr(row_idx.as_mut_ptr());
    pool.run_init(&ranges, |_tid, r| {
        let mut w = a.row_ptr[r.start];
        for i in r {
            for _ in 0..(a.row_ptr[i + 1] - a.row_ptr[i]) {
                // Chunks own disjoint row_ptr spans of IROW.
                unsafe { *rp.get().add(w) = i as Index };
                w += 1;
            }
        }
    });
    Coo::new(n, a.n_cols(), row_idx, a.col_idx.clone(), a.values.clone(), CooOrder::RowMajor)
        .expect("parallel IROW expansion preserves ordering")
}

/// Parallel CRS → CCS. The counting pass is parallelised with per-chunk
/// count arrays that are then reduced; the scatter pass is parallel over
/// row ranges with per-chunk cursor arrays offset by the counts of all
/// preceding chunks (a two-level prefix sum) — each (column, chunk) pair
/// owns a disjoint slot range, so scatters never conflict.
pub fn crs_to_ccs_on(a: &Csr, pool: &ParPool) -> Csc {
    crs_to_ccs_chunked(a, pool, pool.size())
}

/// Parallel CRS → CCS at `n_threads` chunks on the global pool.
pub fn crs_to_ccs_par(a: &Csr, n_threads: usize) -> Csc {
    crs_to_ccs_chunked(a, &pool::global(), n_threads)
}

fn crs_to_ccs_chunked(a: &Csr, pool: &ParPool, n_chunks: usize) -> Csc {
    let n_cols = a.n_cols();
    let n = a.n_rows();
    let nnz = a.nnz();
    let ranges = split_even(n, n_chunks);
    let t = ranges.len().max(1);

    // Phase 1: per-chunk column counts.
    let mut counts = vec![vec![0usize; n_cols]; t];
    let countp = SendPtr(counts.as_mut_ptr());
    pool.run_init(&ranges, |tid, r| {
        // Chunk `tid` owns counts[tid] exclusively.
        let cnt = unsafe { &mut *countp.get().add(tid) };
        for k in a.row_ptr[r.start]..a.row_ptr[r.end] {
            cnt[a.col_idx[k] as usize] += 1;
        }
    });

    // Phase 2: two-level exclusive prefix sum -> col_ptr and per-chunk
    // starting cursors (chunk-major within each column to preserve the
    // row-sorted-within-column invariant).
    let mut col_ptr = vec![0usize; n_cols + 1];
    let mut cursors = vec![vec![0usize; n_cols]; t];
    let mut running = 0usize;
    for j in 0..n_cols {
        col_ptr[j] = running;
        for ti in 0..t {
            cursors[ti][j] = running;
            running += counts[ti][j];
        }
    }
    col_ptr[n_cols] = running;
    debug_assert_eq!(running, nnz);

    // Phase 3: parallel scatter.
    let mut row_idx = vec![0 as Index; nnz];
    let mut values = vec![0.0 as Value; nnz];
    let rp = SendPtr(row_idx.as_mut_ptr());
    let vp = SendPtr(values.as_mut_ptr());
    let curp = SendPtr(cursors.as_mut_ptr());
    pool.run_init(&ranges, |tid, r| {
        let cur = unsafe { &mut *curp.get().add(tid) };
        for i in r {
            for (c, v) in a.row(i) {
                let slot = cur[c as usize];
                cur[c as usize] += 1;
                // (column, chunk) slot ranges are disjoint by the
                // two-level prefix sum above.
                unsafe {
                    *rp.get().add(slot) = i as Index;
                    *vp.get().add(slot) = v;
                }
            }
        }
    });
    Csc::new(n, n_cols, col_ptr, row_idx, values).expect("parallel counting transform valid")
}

/// Parallel CRS → COO-Column (parallel Phase I + parallel Phase II).
pub fn crs_to_coo_col_on(a: &Csr, pool: &ParPool) -> Coo {
    crs_to_coo_col_chunked(a, pool, pool.size())
}

/// Parallel CRS → COO-Column at `n_threads` chunks on the global pool.
pub fn crs_to_coo_col_par(a: &Csr, n_threads: usize) -> Coo {
    crs_to_coo_col_chunked(a, &pool::global(), n_threads)
}

fn crs_to_coo_col_chunked(a: &Csr, pool: &ParPool, n_chunks: usize) -> Coo {
    let ccs = crs_to_ccs_chunked(a, pool, n_chunks);
    let n_cols = ccs.n_cols();
    let nnz = ccs.nnz();
    let mut col_idx = vec![0 as Index; nnz];
    let ranges = split_even(n_cols, n_chunks);
    let cp = SendPtr(col_idx.as_mut_ptr());
    let ccs_ref = &ccs;
    pool.run_init(&ranges, |_tid, r| {
        let mut w = ccs_ref.col_ptr[r.start];
        for j in r {
            for _ in 0..ccs_ref.col_len(j) {
                // Chunks own disjoint col_ptr spans of ICOL.
                unsafe { *cp.get().add(w) = j as Index };
                w += 1;
            }
        }
    });
    Coo::new(
        a.n_rows(),
        a.n_cols(),
        ccs.row_idx.clone(),
        col_idx,
        ccs.values.clone(),
        CooOrder::ColMajor,
    )
    .expect("parallel phase II preserves ordering")
}

/// Pool-parallel counterpart of [`crate::transform::transform_to`]: the
/// uniform entry point dispatching to the parallel pipelines where they
/// exist (sequential builders otherwise) — exactly what plan construction
/// pays, so timing harnesses measure the cost actually incurred at
/// `SpmvPlan` build time.
pub fn transform_to_on(
    a: &Csr,
    target: crate::formats::FormatKind,
    max_bytes: Option<usize>,
    pool: &ParPool,
) -> Result<Box<dyn SparseMatrix + Send + Sync>> {
    use crate::formats::FormatKind::*;
    Ok(match target {
        Csr => Box::new(a.clone()),
        Csc => Box::new(crs_to_ccs_on(a, pool)),
        CooRow => Box::new(crs_to_coo_row_on(a, pool)),
        CooCol => Box::new(crs_to_coo_col_on(a, pool)),
        Ell => Box::new(crs_to_ell_bounded_on(a, max_bytes, pool)?),
        Bcsr => Box::new(crate::transform::crs_to_bcsr(a, 2, 2)?),
        Jds => Box::new(crate::transform::crs_to_jds(a)),
        Hyb => Box::new(crate::transform::crs_to_hyb(a)?),
        Sell => Box::new(crs_to_sell_bounded_on(a, max_bytes, pool)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;
    use crate::transform::{crs_to_ccs, crs_to_coo_col, crs_to_coo_row, crs_to_ell};

    fn cases() -> Vec<Csr> {
        let mut rng = Rng::new(77);
        vec![
            random_csr(&mut rng, 1, 1, 1.0),
            random_csr(&mut rng, 7, 5, 0.4),
            random_csr(&mut rng, 100, 100, 0.05),
            random_csr(&mut rng, 33, 61, 0.11),
            Csr::from_triplets(5, 5, &[]).unwrap(),
        ]
    }

    #[test]
    fn par_ell_matches_sequential() {
        for a in cases() {
            for t in [1, 2, 3, 8] {
                let seq = crs_to_ell(&a).unwrap();
                let par = crs_to_ell_par(&a, t).unwrap();
                assert_eq!(seq, par, "t={t}");
            }
        }
    }

    #[test]
    fn par_coo_row_matches_sequential() {
        for a in cases() {
            for t in [1, 2, 5] {
                assert_eq!(crs_to_coo_row(&a), crs_to_coo_row_par(&a, t), "t={t}");
            }
        }
    }

    #[test]
    fn par_ccs_matches_sequential() {
        for a in cases() {
            for t in [1, 2, 3, 8] {
                assert_eq!(crs_to_ccs(&a), crs_to_ccs_par(&a, t), "t={t}");
            }
        }
    }

    #[test]
    fn par_coo_col_matches_sequential() {
        for a in cases() {
            for t in [1, 2, 4] {
                assert_eq!(crs_to_coo_col(&a), crs_to_coo_col_par(&a, t), "t={t}");
            }
        }
    }

    #[test]
    fn pool_entry_points_match_sequential() {
        let pool = ParPool::new(3);
        for a in cases() {
            assert_eq!(crs_to_ell(&a).unwrap(), crs_to_ell_on(&a, &pool).unwrap());
            assert_eq!(crs_to_coo_row(&a), crs_to_coo_row_on(&a, &pool));
            assert_eq!(crs_to_ccs(&a), crs_to_ccs_on(&a, &pool));
            assert_eq!(crs_to_coo_col(&a), crs_to_coo_col_on(&a, &pool));
        }
    }

    #[test]
    fn par_sell_matches_sequential() {
        use crate::transform::crs_to_sell_with;
        for a in cases() {
            let n = a.n_rows().max(1);
            for (c, sigma) in [(1, 1), (4, 4), (4, 16), (32, n)] {
                for t in [1usize, 2, 3, 8] {
                    let pool = ParPool::new(t);
                    let seq = crs_to_sell_with(&a, c, sigma).unwrap();
                    let par = crs_to_sell_with_on(&a, c, sigma, &pool).unwrap();
                    assert_eq!(seq, par, "C={c} sigma={sigma} t={t}");
                }
            }
        }
    }

    #[test]
    fn transform_to_on_agrees_on_spmv() {
        let pool = ParPool::new(3);
        let mut rng = Rng::new(91);
        let a = random_csr(&mut rng, 40, 35, 0.12);
        let x: Vec<Value> = (0..35).map(|i| (i as f64 * 0.23).sin()).collect();
        let mut want = vec![0.0; 40];
        a.spmv(&x, &mut want);
        for kind in crate::formats::FormatKind::ALL {
            let m = transform_to_on(&a, kind, None, &pool).unwrap();
            let mut got = vec![0.0; 40];
            m.spmv(&x, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12, "{kind}: {g} != {w}");
            }
        }
    }

    #[test]
    fn bounded_ell_respects_budget() {
        let pool = ParPool::new(2);
        let mut t: Vec<(usize, usize, Value)> = (0..100).map(|j| (0, j, 1.0)).collect();
        t.extend((1..100).map(|i| (i, i, 1.0)));
        let a = Csr::from_triplets(100, 100, &t).unwrap();
        assert!(crs_to_ell_bounded_on(&a, Some(1024), &pool).is_err());
        assert!(crs_to_ell_bounded_on(&a, None, &pool).is_ok());
    }
}
