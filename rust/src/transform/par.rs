//! Parallel data transformations (the paper's §5 future work:
//! "we do not show the parallel implementations of the data transformation
//! processes … Evaluation with parallelized transformations … are future
//! work").
//!
//! Strategy: every transform splits its scatter pass over row (or entry)
//! ranges with per-thread write cursors derived from a shared counting
//! pass, mirroring how the SpMV kernels split work with `ISTART/IEND`.

use crate::formats::{Coo, CooOrder, Csc, Csr, Ell, SparseMatrix};
use crate::spmv::partition::split_even;
use crate::{Index, Result, Value};

/// Parallel CRS → ELL: each thread owns a contiguous row range and fills
/// its band-major slots independently (no write conflicts: slot `k*n+i`
/// belongs to exactly one row `i`).
pub fn crs_to_ell_par(a: &Csr, n_threads: usize) -> Result<Ell> {
    let n = a.n_rows();
    let nz = a.max_row_len();
    let slots = n.checked_mul(nz).ok_or_else(|| anyhow::anyhow!("ELL size overflow"))?;
    let mut values = vec![0.0 as Value; slots];
    let mut col_idx = vec![0 as Index; slots];
    let ranges = split_even(n, n_threads);

    // SAFETY-free sharing: give each thread disjoint &mut views per band is
    // awkward (rows interleave in band-major layout), so use raw pointers
    // wrapped in a Sync newtype; disjointness is by row index.
    struct Shared(*mut Value, *mut Index);
    unsafe impl Sync for Shared {}
    let shared = Shared(values.as_mut_ptr(), col_idx.as_mut_ptr());

    std::thread::scope(|s| {
        for r in &ranges {
            let (lo, hi) = (r.start, r.end);
            let shared = &shared;
            s.spawn(move || {
                for i in lo..hi {
                    for (k, (c, v)) in a.row(i).enumerate() {
                        // Each (i, k) slot is written by exactly one thread
                        // because row ranges are disjoint.
                        unsafe {
                            *shared.0.add(k * n + i) = v;
                            *shared.1.add(k * n + i) = c;
                        }
                    }
                }
            });
        }
    });
    Ell::new(n, a.n_cols(), nz, values, col_idx, a.nnz())
}

/// Parallel CRS → COO-Row: the `IROW` expansion is embarrassingly parallel
/// over row ranges.
pub fn crs_to_coo_row_par(a: &Csr, n_threads: usize) -> Coo {
    let nnz = a.nnz();
    let n = a.n_rows();
    let mut row_idx = vec![0 as Index; nnz];
    let ranges = split_even(n, n_threads);
    std::thread::scope(|s| {
        let mut rest: &mut [Index] = &mut row_idx;
        for r in &ranges {
            let lo_off = a.row_ptr[r.start];
            let hi_off = a.row_ptr[r.end];
            let (chunk, tail) = rest.split_at_mut(hi_off - lo_off);
            rest = tail;
            let (lo, hi) = (r.start, r.end);
            s.spawn(move || {
                let mut w = 0;
                for i in lo..hi {
                    for _ in 0..(a.row_ptr[i + 1] - a.row_ptr[i]) {
                        chunk[w] = i as Index;
                        w += 1;
                    }
                }
            });
        }
    });
    Coo::new(n, a.n_cols(), row_idx, a.col_idx.clone(), a.values.clone(), CooOrder::RowMajor)
        .expect("parallel IROW expansion preserves ordering")
}

/// Parallel CRS → CCS. The counting pass is parallelised with per-thread
/// count arrays that are then reduced; the scatter pass is parallel over
/// row ranges with per-thread cursor arrays offset by the counts of all
/// preceding threads (a two-level prefix sum) — each (column, thread) pair
/// owns a disjoint slot range, so scatters never conflict.
pub fn crs_to_ccs_par(a: &Csr, n_threads: usize) -> Csc {
    let n_cols = a.n_cols();
    let n = a.n_rows();
    let nnz = a.nnz();
    let ranges = split_even(n, n_threads);
    let t = ranges.len().max(1);

    // Phase 1: per-thread column counts.
    let mut counts = vec![vec![0usize; n_cols]; t];
    std::thread::scope(|s| {
        for (cnt, r) in counts.iter_mut().zip(&ranges) {
            let (lo, hi) = (r.start, r.end);
            s.spawn(move || {
                for k in a.row_ptr[lo]..a.row_ptr[hi] {
                    cnt[a.col_idx[k] as usize] += 1;
                }
            });
        }
    });

    // Phase 2: two-level exclusive prefix sum -> col_ptr and per-thread
    // starting cursors (thread-major within each column to preserve the
    // row-sorted-within-column invariant).
    let mut col_ptr = vec![0usize; n_cols + 1];
    let mut cursors = vec![vec![0usize; n_cols]; t];
    let mut running = 0usize;
    for j in 0..n_cols {
        col_ptr[j] = running;
        for ti in 0..t {
            cursors[ti][j] = running;
            running += counts[ti][j];
        }
    }
    col_ptr[n_cols] = running;
    debug_assert_eq!(running, nnz);

    // Phase 3: parallel scatter.
    let mut row_idx = vec![0 as Index; nnz];
    let mut values = vec![0.0 as Value; nnz];
    struct Shared(*mut Index, *mut Value);
    unsafe impl Sync for Shared {}
    let shared = Shared(row_idx.as_mut_ptr(), values.as_mut_ptr());
    std::thread::scope(|s| {
        for (cur, r) in cursors.iter_mut().zip(&ranges) {
            let (lo, hi) = (r.start, r.end);
            let shared = &shared;
            s.spawn(move || {
                for i in lo..hi {
                    for (c, v) in a.row(i) {
                        let slot = cur[c as usize];
                        cur[c as usize] += 1;
                        // (column, thread) slot ranges are disjoint by the
                        // two-level prefix sum above.
                        unsafe {
                            *shared.0.add(slot) = i as Index;
                            *shared.1.add(slot) = v;
                        }
                    }
                }
            });
        }
    });
    Csc::new(n, n_cols, col_ptr, row_idx, values).expect("parallel counting transform valid")
}

/// Parallel CRS → COO-Column (parallel Phase I + parallel Phase II).
pub fn crs_to_coo_col_par(a: &Csr, n_threads: usize) -> Coo {
    let ccs = crs_to_ccs_par(a, n_threads);
    let n_cols = ccs.n_cols();
    let nnz = ccs.nnz();
    let mut col_idx = vec![0 as Index; nnz];
    let ranges = split_even(n_cols, n_threads);
    std::thread::scope(|s| {
        let mut rest: &mut [Index] = &mut col_idx;
        for r in &ranges {
            let lo_off = ccs.col_ptr[r.start];
            let hi_off = ccs.col_ptr[r.end];
            let (chunk, tail) = rest.split_at_mut(hi_off - lo_off);
            rest = tail;
            let (lo, hi) = (r.start, r.end);
            let ccs = &ccs;
            s.spawn(move || {
                let mut w = 0;
                for j in lo..hi {
                    for _ in 0..ccs.col_len(j) {
                        chunk[w] = j as Index;
                        w += 1;
                    }
                }
            });
        }
    });
    Coo::new(a.n_rows(), a.n_cols(), ccs.row_idx.clone(), col_idx, ccs.values.clone(), CooOrder::ColMajor)
        .expect("parallel phase II preserves ordering")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;
    use crate::transform::{crs_to_ccs, crs_to_coo_col, crs_to_coo_row, crs_to_ell};

    fn cases() -> Vec<Csr> {
        let mut rng = Rng::new(77);
        vec![
            random_csr(&mut rng, 1, 1, 1.0),
            random_csr(&mut rng, 7, 5, 0.4),
            random_csr(&mut rng, 100, 100, 0.05),
            random_csr(&mut rng, 33, 61, 0.11),
            Csr::from_triplets(5, 5, &[]).unwrap(),
        ]
    }

    #[test]
    fn par_ell_matches_sequential() {
        for a in cases() {
            for t in [1, 2, 3, 8] {
                let seq = crs_to_ell(&a).unwrap();
                let par = crs_to_ell_par(&a, t).unwrap();
                assert_eq!(seq, par, "t={t}");
            }
        }
    }

    #[test]
    fn par_coo_row_matches_sequential() {
        for a in cases() {
            for t in [1, 2, 5] {
                assert_eq!(crs_to_coo_row(&a), crs_to_coo_row_par(&a, t), "t={t}");
            }
        }
    }

    #[test]
    fn par_ccs_matches_sequential() {
        for a in cases() {
            for t in [1, 2, 3, 8] {
                assert_eq!(crs_to_ccs(&a), crs_to_ccs_par(&a, t), "t={t}");
            }
        }
    }

    #[test]
    fn par_coo_col_matches_sequential() {
        for a in cases() {
            for t in [1, 2, 4] {
                assert_eq!(crs_to_coo_col(&a), crs_to_coo_col_par(&a, t), "t={t}");
            }
        }
    }
}
