//! Level-set analysis for sparse triangular solves.
//!
//! Substitution on a triangular matrix is sequential row-to-row only
//! where rows actually depend on each other. Grouping rows into
//! *levels* — row `i` sits one level above the deepest row it reads —
//! yields a schedule where every row inside a level is independent, so
//! a level can execute as one parallel dispatch and the per-level
//! barrier provides the cross-level happens-before.
//!
//! The analysis is itself a run-time data transformation in the paper's
//! sense: it costs one O(nnz) pass up front ([`LevelSchedule::analysis_seconds`])
//! and pays back per solve only when levels are wide enough to feed the
//! pool. The level-population statistics ([`LevelStats`] — level count,
//! average/maximum width) are the subsystem's analogue of the `D_mat`
//! density statistic: the serial-vs-parallel decision
//! ([`super::sptrsv::TrsvPar`]) thresholds on average width per thread
//! exactly as the SpMV decision thresholds on `D_mat`, and the schedule
//! is cached per matrix alongside the transformed plan so repeated
//! solves amortise it.
//!
//! Within a level, row lengths are as skewed as the matrix itself, so
//! chunks balance *nonzeros* rather than rows: each level builds a
//! work prefix over its row list and feeds it to the same
//! [`crate::spmv::partition::split_by_nnz`] splitter the SpMV row
//! partitions use.

use crate::formats::{Csr, SparseMatrix};
use crate::matrixgen::rowlen;
use std::ops::Range;
use std::time::Instant;

/// Level-population statistics — the triangular-solve analogue of the
/// `D_mat` statistic: the decision input for serial vs level-scheduled
/// execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LevelStats {
    /// Number of levels (the critical-path length of the dependency DAG).
    pub levels: usize,
    /// Total rows scheduled (= matrix order).
    pub rows: usize,
    /// Average rows per level — the parallelism actually on offer.
    pub avg_width: f64,
    /// Largest level population.
    pub max_width: usize,
}

/// A cached dependency-DAG schedule for one strict triangle: rows
/// grouped by level, with nnz-balanced chunk ranges per level sized for
/// a given pool width.
///
/// Built once per (matrix, pool) by [`LevelSchedule::build_lower`] /
/// [`LevelSchedule::build_upper`] and cached like a transformed plan;
/// the SpTRSV kernels in [`super::sptrsv`] replay it on every solve.
#[derive(Clone, Debug)]
pub struct LevelSchedule {
    /// All rows, grouped by level; within a level, ascending row index
    /// (the grouping is what buys parallelism — per-row arithmetic
    /// order is untouched, which is why level-scheduled execution is
    /// bitwise-identical to serial substitution).
    rows: Vec<usize>,
    /// Level `l` occupies `rows[level_ptr[l]..level_ptr[l + 1]]`.
    level_ptr: Vec<usize>,
    /// Per level: nnz-balanced ranges into `rows`, at most `threads`
    /// of them.
    chunks: Vec<Vec<Range<usize>>>,
    stats: LevelStats,
    analysis_seconds: f64,
}

impl LevelSchedule {
    /// Schedule a strictly-lower triangle for forward substitution.
    /// Row `i` depends on exactly its stored columns (all `< i`), so a
    /// single ascending pass computes every level in O(nnz).
    pub fn build_lower(lower: &Csr, threads: usize) -> Self {
        let t0 = Instant::now();
        let n = lower.n_rows();
        let mut level = vec![0usize; n];
        let mut n_levels = 0usize;
        for i in 0..n {
            let mut l = 0usize;
            for (c, _) in lower.row(i) {
                l = l.max(level[c as usize] + 1);
            }
            level[i] = l;
            n_levels = n_levels.max(l + 1);
        }
        Self::assemble(lower, &level, n_levels, threads, t0)
    }

    /// Schedule a strictly-upper triangle for backward substitution.
    /// Row `i` depends on its stored columns (all `> i`), so the pass
    /// runs descending; levels still number 0.. in execution order.
    pub fn build_upper(upper: &Csr, threads: usize) -> Self {
        let t0 = Instant::now();
        let n = upper.n_rows();
        let mut level = vec![0usize; n];
        let mut n_levels = 0usize;
        for i in (0..n).rev() {
            let mut l = 0usize;
            for (c, _) in upper.row(i) {
                l = l.max(level[c as usize] + 1);
            }
            level[i] = l;
            n_levels = n_levels.max(l + 1);
        }
        Self::assemble(upper, &level, n_levels, threads, t0)
    }

    /// Bucket rows by level (counting sort keeps ascending row order
    /// inside each level), then cut each level into nnz-balanced chunks.
    fn assemble(
        tri: &Csr,
        level: &[usize],
        n_levels: usize,
        threads: usize,
        t0: Instant,
    ) -> Self {
        let n = level.len();
        let mut counts = vec![0usize; n_levels];
        for &l in level {
            counts[l] += 1;
        }
        let mut level_ptr = Vec::with_capacity(n_levels + 1);
        level_ptr.push(0usize);
        for &c in &counts {
            level_ptr.push(level_ptr.last().unwrap() + c);
        }
        let mut cursor = level_ptr[..n_levels].to_vec();
        let mut rows = vec![0usize; n];
        for (i, &l) in level.iter().enumerate() {
            rows[cursor[l]] = i;
            cursor[l] += 1;
        }

        let threads = threads.max(1);
        let mut chunks = Vec::with_capacity(n_levels);
        for l in 0..n_levels {
            let span = level_ptr[l]..level_ptr[l + 1];
            // Work prefix over this level's row list: row length + 1 so
            // empty rows still cost their dispatch/store.
            let mut prefix = Vec::with_capacity(span.len() + 1);
            prefix.push(0usize);
            for &i in &rows[span.clone()] {
                let len = tri.row_ptr[i + 1] - tri.row_ptr[i];
                prefix.push(prefix.last().unwrap() + len + 1);
            }
            let local = crate::spmv::partition::split_by_nnz(&prefix, threads);
            chunks.push(
                local
                    .into_iter()
                    .map(|r| span.start + r.start..span.start + r.end)
                    .collect(),
            );
        }

        let stats = {
            let widths: Vec<usize> = counts;
            let s = rowlen::stats(&widths);
            LevelStats {
                levels: n_levels,
                rows: n,
                avg_width: s.mean,
                max_width: s.max,
            }
        };
        Self {
            rows,
            level_ptr,
            chunks,
            stats,
            analysis_seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// Number of levels (0 for an empty matrix).
    pub fn n_levels(&self) -> usize {
        self.level_ptr.len().saturating_sub(1)
    }

    /// Level-population statistics — the decision input.
    pub fn stats(&self) -> &LevelStats {
        &self.stats
    }

    /// Wall seconds the analysis pass cost (the transformation cost the
    /// amortisation accounting charges against the schedule).
    pub fn analysis_seconds(&self) -> f64 {
        self.analysis_seconds
    }

    /// The scheduled row order (grouped by level).
    pub fn rows(&self) -> &[usize] {
        &self.rows
    }

    /// The nnz-balanced chunk ranges (into [`Self::rows`]) for level `l`.
    pub fn chunks(&self, l: usize) -> &[Range<usize>] {
        &self.chunks[l]
    }

    /// Rows of level `l`, in ascending row order.
    pub fn level_rows(&self, l: usize) -> &[usize] {
        &self.rows[self.level_ptr[l]..self.level_ptr[l + 1]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Csr;

    fn lower_chain() -> Csr {
        // Bidiagonal chain: row i depends on row i-1 → n levels of 1.
        Csr::from_triplets(4, 4, &[(1, 0, 1.0), (2, 1, 1.0), (3, 2, 1.0)]).unwrap()
    }

    #[test]
    fn chain_is_fully_sequential() {
        let s = LevelSchedule::build_lower(&lower_chain(), 4);
        assert_eq!(s.n_levels(), 4);
        assert_eq!(s.stats().max_width, 1);
        for l in 0..4 {
            assert_eq!(s.level_rows(l), &[l]);
        }
    }

    #[test]
    fn diagonal_matrix_is_one_level() {
        // No off-diagonal entries → every row independent → one level.
        let empty = Csr::from_triplets(5, 5, &[]).unwrap();
        let s = LevelSchedule::build_lower(&empty, 2);
        assert_eq!(s.n_levels(), 1);
        assert_eq!(s.stats().avg_width, 5.0);
        assert_eq!(s.level_rows(0), &[0, 1, 2, 3, 4]);
        // Chunks cover the level exactly, in order.
        let covered: usize = s.chunks(0).iter().map(|r| r.len()).sum();
        assert_eq!(covered, 5);
    }

    #[test]
    fn upper_levels_mirror_lower() {
        // Strictly-upper chain: row i depends on i+1 → execution starts
        // at the last row; level 0 must be the bottom row.
        let u = Csr::from_triplets(3, 3, &[(0, 1, 1.0), (1, 2, 1.0)]).unwrap();
        let s = LevelSchedule::build_upper(&u, 2);
        assert_eq!(s.n_levels(), 3);
        assert_eq!(s.level_rows(0), &[2]);
        assert_eq!(s.level_rows(1), &[1]);
        assert_eq!(s.level_rows(2), &[0]);
    }

    #[test]
    fn forked_dag_levels() {
        // Rows 1 and 2 both depend only on row 0; row 3 on both.
        let l = Csr::from_triplets(
            4,
            4,
            &[(1, 0, 1.0), (2, 0, 1.0), (3, 1, 1.0), (3, 2, 1.0)],
        )
        .unwrap();
        let s = LevelSchedule::build_lower(&l, 2);
        assert_eq!(s.n_levels(), 3);
        assert_eq!(s.level_rows(0), &[0]);
        assert_eq!(s.level_rows(1), &[1, 2]);
        assert_eq!(s.level_rows(2), &[3]);
        assert_eq!(s.stats().max_width, 2);
        assert!(s.analysis_seconds() >= 0.0);
    }
}
