//! Symmetric Gauss-Seidel preconditioner with an autotuned
//! triangle-solve decision.
//!
//! One SymGS application approximates `M⁻¹r` for
//! `M = (D + L)·D⁻¹·(D + U)`: a forward sparse triangular solve on
//! `(D + L)`, a D-scaling of the intermediate, and a backward solve on
//! `(D + U)` — the HPCG smoother shape. Setup splits the matrix once
//! ([`Csr::split_triangular`]), validates the diagonal, and builds the
//! two level schedules; all of it is cached alongside the entry's
//! `SpmvPlan`, so repeated solves pay only the two substitutions.
//!
//! **The autotuned decision.** Each triangular solve can run serially
//! or replay the cached level schedule on the pool
//! ([`TrsvMode`]); the static choice comes from the level-width
//! threshold ([`TrsvPar`], env `SPMV_AT_TRSV_PAR`). Because the two
//! variants are bitwise-identical, the adaptive loop can *serve* the
//! rival arm directly — no shadow execution, no result risk: every
//! `rival_every`-th apply runs the other mode, its wall time feeds the
//! same EWMA telemetry the SpMV arms use
//! ([`ArmTelemetry<TrsvMode>`](ArmTelemetry)), and the hysteresis
//! controller flips the static mode when measurements contradict the
//! width heuristic — exactly the SpMV re-planning loop, keyed by
//! triangle-solve mode instead of kernel implementation.

use super::levels::{LevelSchedule, LevelStats};
use super::sptrsv::{
    solve_lower_levels, solve_lower_seq, solve_upper_levels, solve_upper_seq, TrsvMode, TrsvPar,
};
use super::Preconditioner;
use crate::autotune::adaptive::{AdaptiveConfig, ArmTelemetry, HysteresisController};
use crate::formats::{Csr, Triangular};
use crate::spmv::ParPool;
use crate::{Result, Value};
use std::sync::Arc;
use std::time::Instant;

/// Serve-the-rival cadence: with adaptive mode on, every Nth apply runs
/// the non-serving SpTRSV mode (safe because both modes are
/// bitwise-identical) so its telemetry stays fresh without shadow work.
const RIVAL_EVERY: u64 = 16;

/// Symmetric Gauss-Seidel preconditioner (`M = (D+L)·D⁻¹·(D+U)`) with
/// cached triangles, cached level schedules, and a measurement-driven
/// serial-vs-parallel triangle-solve arm.
pub struct SymGs {
    tri: Triangular,
    lower_sched: LevelSchedule,
    upper_sched: LevelSchedule,
    pool: Arc<ParPool>,
    /// Currently-serving SpTRSV mode (starts at the policy's static
    /// choice; the controller may flip it).
    mode: TrsvMode,
    adaptive: bool,
    telemetry: ArmTelemetry<TrsvMode>,
    controller: HysteresisController,
    applies: u64,
    setup_seconds: f64,
    /// Intermediate `y`/`w` buffer, reused across applies so the hot
    /// path stays allocation-free.
    scratch: Vec<Value>,
}

impl SymGs {
    /// Split, validate, and level-schedule `a`; decide the initial
    /// SpTRSV mode from `policy` and the schedules' width statistics.
    ///
    /// `adaptive` wires the mode into the runtime loop: telemetry is
    /// always recorded, but rival serving and mode flips only happen
    /// when `adaptive.enabled` (matching the SpMV loop's contract that
    /// the flag off means decide-once).
    pub fn build(
        a: &Csr,
        pool: Arc<ParPool>,
        policy: TrsvPar,
        adaptive: &AdaptiveConfig,
    ) -> Result<Self> {
        let t0 = Instant::now();
        let tri = a.split_triangular()?;
        anyhow::ensure!(
            tri.diag_nonzero(),
            "SymGS needs a non-zero diagonal in every row"
        );
        let threads = pool.size();
        let lower_sched = LevelSchedule::build_lower(&tri.lower, threads);
        let upper_sched = LevelSchedule::build_upper(&tri.upper, threads);
        // One decision for both sweeps: the narrower triangle bounds the
        // benefit, so threshold on the smaller average width.
        let narrower = if lower_sched.stats().avg_width <= upper_sched.stats().avg_width {
            *lower_sched.stats()
        } else {
            *upper_sched.stats()
        };
        let mode = policy.choose(&narrower, threads);
        let controller = HysteresisController::new(
            adaptive.deadband,
            adaptive.window,
            adaptive.flip_windows,
            adaptive.min_rival_samples,
        );
        let n = tri.n();
        Ok(Self {
            tri,
            lower_sched,
            upper_sched,
            pool,
            mode,
            adaptive: adaptive.enabled,
            telemetry: ArmTelemetry::new(adaptive.ewma_alpha),
            controller,
            applies: 0,
            setup_seconds: t0.elapsed().as_secs_f64(),
            scratch: vec![0.0; n],
        })
    }

    /// The SpTRSV mode the next apply will serve with (rival applies
    /// excepted).
    pub fn mode(&self) -> TrsvMode {
        self.mode
    }

    /// Level statistics of the forward (lower) schedule.
    pub fn lower_stats(&self) -> &LevelStats {
        self.lower_sched.stats()
    }

    /// Level statistics of the backward (upper) schedule.
    pub fn upper_stats(&self) -> &LevelStats {
        self.upper_sched.stats()
    }

    /// Wall seconds of level-set analysis (both schedules) — the
    /// transformation-cost half of the amortisation ledger.
    pub fn analysis_seconds(&self) -> f64 {
        self.lower_sched.analysis_seconds() + self.upper_sched.analysis_seconds()
    }

    /// EW mean seconds per apply of `mode`, when measured.
    pub fn mean_apply_seconds(&self, mode: TrsvMode) -> Option<f64> {
        self.telemetry.mean(mode)
    }

    /// Applications served so far.
    pub fn applies(&self) -> u64 {
        self.applies
    }

    /// Mode flips the controller has made.
    pub fn flips(&self) -> u64 {
        self.controller.flips()
    }

    fn rival(mode: TrsvMode) -> TrsvMode {
        match mode {
            TrsvMode::Serial => TrsvMode::LevelPar,
            TrsvMode::LevelPar => TrsvMode::Serial,
        }
    }

    /// One SymGS sweep in `run` mode, writing `z ← M⁻¹ r` via `scratch`.
    fn sweep(&self, run: TrsvMode, r: &[Value], scratch: &mut [Value], z: &mut [Value]) {
        let d = Some(self.tri.diag.as_slice());
        match run {
            TrsvMode::Serial => {
                solve_lower_seq(&self.tri.lower, d, r, scratch);
                for (w, &di) in scratch.iter_mut().zip(&self.tri.diag) {
                    *w *= di;
                }
                solve_upper_seq(&self.tri.upper, d, scratch, z);
            }
            TrsvMode::LevelPar => {
                solve_lower_levels(&self.tri.lower, d, &self.lower_sched, &self.pool, r, scratch);
                for (w, &di) in scratch.iter_mut().zip(&self.tri.diag) {
                    *w *= di;
                }
                solve_upper_levels(&self.tri.upper, d, &self.upper_sched, &self.pool, scratch, z);
            }
        }
    }
}

impl Preconditioner for SymGs {
    fn name(&self) -> &'static str {
        "symgs"
    }

    fn setup_seconds(&self) -> f64 {
        self.setup_seconds
    }

    fn apply(&mut self, r: &[Value], z: &mut [Value]) {
        self.applies += 1;
        // Serve the rival on a deterministic cadence (bitwise-safe).
        let run = if self.adaptive && self.applies % RIVAL_EVERY == 0 {
            Self::rival(self.mode)
        } else {
            self.mode
        };
        let mut scratch = std::mem::take(&mut self.scratch);
        let t0 = Instant::now();
        self.sweep(run, r, &mut scratch, z);
        let dt = t0.elapsed().as_secs_f64();
        self.scratch = scratch;
        self.telemetry.record(run, dt, 1);

        if self.adaptive {
            let rival = Self::rival(self.mode);
            let rival_obs = self
                .telemetry
                .stats(rival)
                .map(|s| (s.mean().unwrap_or(f64::INFINITY), s.count()));
            let flip =
                self.controller
                    .note_serve(1, self.telemetry.mean(self.mode), rival_obs);
            if flip {
                self.mode = rival;
                self.controller.reset();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::{make_spd, random_csr};
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        make_spd(&random_csr(&mut rng, n, n, 0.05))
    }

    #[test]
    fn symgs_apply_matches_direct_triangular_arithmetic() {
        // 2×2: A = [[4, 1], [1, 3]] → L = [[0,0],[1,0]], D = (4,3),
        // U = [[0,1],[0,0]]. M z = r via the three-step recipe by hand.
        let a = Csr::from_triplets(2, 2, &[(0, 0, 4.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 3.0)])
            .unwrap();
        let pool = Arc::new(ParPool::new(1));
        let mut m =
            SymGs::build(&a, pool, TrsvPar::Never, &AdaptiveConfig::default()).unwrap();
        let r = [8.0, 10.0];
        let mut z = [0.0; 2];
        m.apply(&r, &mut z);
        // Forward: y0 = 8/4 = 2; y1 = (10 − 1·2)/3 = 8/3.
        // Scale:   w = (8, 8).
        // Backward: z1 = 8/3; z0 = (8 − 1·(8/3))/4 = 4/3.
        assert!((z[0] - 4.0 / 3.0).abs() < 1e-15);
        assert!((z[1] - 8.0 / 3.0).abs() < 1e-15);
        assert_eq!(m.applies(), 1);
        assert_eq!(m.name(), "symgs");
        assert!(m.setup_seconds() >= 0.0);
    }

    #[test]
    fn serial_and_levelpar_modes_are_bitwise_identical() {
        let a = spd(120, 9);
        let pool = Arc::new(ParPool::new(3));
        let cfg = AdaptiveConfig::default();
        let mut serial = SymGs::build(&a, pool.clone(), TrsvPar::Never, &cfg).unwrap();
        let mut par = SymGs::build(&a, pool, TrsvPar::Always, &cfg).unwrap();
        assert_eq!(serial.mode(), TrsvMode::Serial);
        assert_eq!(par.mode(), TrsvMode::LevelPar);
        let r: Vec<f64> = (0..120).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut z_s = vec![0.0; 120];
        let mut z_p = vec![0.0; 120];
        serial.apply(&r, &mut z_s);
        par.apply(&r, &mut z_p);
        assert_eq!(z_s, z_p, "level-scheduled SymGS must be bitwise-identical");
    }

    #[test]
    fn symgs_rejects_zero_diagonal() {
        let a = Csr::from_triplets(2, 2, &[(0, 1, 1.0), (1, 0, 1.0)]).unwrap();
        let pool = Arc::new(ParPool::new(1));
        assert!(SymGs::build(&a, pool, TrsvPar::Auto, &AdaptiveConfig::default()).is_err());
    }

    #[test]
    fn adaptive_arm_measures_both_modes_and_can_flip() {
        let a = spd(200, 11);
        let pool = Arc::new(ParPool::new(2));
        let cfg = AdaptiveConfig {
            enabled: true,
            // Tight loop so both arms accumulate samples fast.
            window: 4,
            flip_windows: 1,
            min_rival_samples: 1,
            ..AdaptiveConfig::default()
        };
        // Force the static choice to LevelPar on a random matrix whose
        // levels are narrow — the measured serial arm should win
        // eventually, and at minimum both arms must be sampled.
        let mut m = SymGs::build(&a, pool, TrsvPar::Always, &cfg).unwrap();
        let r: Vec<f64> = (0..200).map(|i| 1.0 + (i % 7) as f64).collect();
        let mut z = vec![0.0; 200];
        let mut reference: Option<Vec<f64>> = None;
        for _ in 0..(RIVAL_EVERY * 4) {
            m.apply(&r, &mut z);
            // Every apply — serving or rival — produces the same bits.
            match &reference {
                Some(want) => assert_eq!(&z, want),
                None => reference = Some(z.clone()),
            }
        }
        assert!(m.mean_apply_seconds(TrsvMode::Serial).is_some());
        assert!(m.mean_apply_seconds(TrsvMode::LevelPar).is_some());
        assert!(m.applies() == RIVAL_EVERY * 4);
    }
}
