//! Sparse triangular solve (SpTRSV) kernels: serial substitution and
//! level-scheduled parallel variants.
//!
//! All four kernels solve against a *strict* triangle plus an optional
//! dense diagonal: `diag: Some(d)` solves `(D + L)·x = b` (or `(D + U)`),
//! `diag: None` solves the unit-diagonal system `(I + L)·x = b` — the
//! unit view is a kernel argument, not a matrix copy.
//!
//! **Bitwise identity.** The level-scheduled variants assign whole rows
//! to pool chunks; each row's accumulation loop is byte-for-byte the
//! serial one (same CSR entry order, same single `acc` register, same
//! final divide), and every value a row reads was finalised by an
//! earlier level whose [`ParPool::run_chunks`] dispatch completed — the
//! per-level barrier is the happens-before edge. Reordering happens only
//! *between* independent rows, never within a row's sum, so parallel
//! output is bitwise-identical to serial at any thread count. The test
//! suite asserts this across pools of 1, 2 and 7 threads.
//!
//! Callers guarantee a non-zero diagonal when passing `Some(d)`
//! (validated once at preconditioner build, not per-solve — see
//! [`super::SymGs::build`]).

use super::levels::{LevelSchedule, LevelStats};
use crate::formats::{Csr, SparseMatrix};
use crate::spmv::pool::{ParPool, SendPtr};
use crate::Value;

/// Forward substitution on a strictly-lower triangle, serial.
pub fn solve_lower_seq(lower: &Csr, diag: Option<&[Value]>, b: &[Value], x: &mut [Value]) {
    let n = lower.n_rows();
    for i in 0..n {
        let mut acc = b[i];
        for (c, v) in lower.row(i) {
            acc -= v * x[c as usize];
        }
        x[i] = match diag {
            Some(d) => acc / d[i],
            None => acc,
        };
    }
}

/// Backward substitution on a strictly-upper triangle, serial.
pub fn solve_upper_seq(upper: &Csr, diag: Option<&[Value]>, b: &[Value], x: &mut [Value]) {
    let n = upper.n_rows();
    for i in (0..n).rev() {
        let mut acc = b[i];
        for (c, v) in upper.row(i) {
            acc -= v * x[c as usize];
        }
        x[i] = match diag {
            Some(d) => acc / d[i],
            None => acc,
        };
    }
}

/// Forward substitution replaying a cached level schedule on the pool.
/// Bitwise-identical to [`solve_lower_seq`] (see module docs).
pub fn solve_lower_levels(
    lower: &Csr,
    diag: Option<&[Value]>,
    sched: &LevelSchedule,
    pool: &ParPool,
    b: &[Value],
    x: &mut [Value],
) {
    solve_levels(lower, diag, sched, pool, b, x);
}

/// Backward substitution replaying a cached level schedule on the pool.
/// Bitwise-identical to [`solve_upper_seq`]: the schedule built by
/// [`LevelSchedule::build_upper`] already orders levels bottom-row
/// first, so the kernel body is direction-agnostic.
pub fn solve_upper_levels(
    upper: &Csr,
    diag: Option<&[Value]>,
    sched: &LevelSchedule,
    pool: &ParPool,
    b: &[Value],
    x: &mut [Value],
) {
    solve_levels(upper, diag, sched, pool, b, x);
}

/// Shared level-replay body. Writes go through [`SendPtr`] at provably
/// disjoint rows (chunks partition the level's row list); reads hit
/// rows finalised before the previous level's barrier.
fn solve_levels(
    tri: &Csr,
    diag: Option<&[Value]>,
    sched: &LevelSchedule,
    pool: &ParPool,
    b: &[Value],
    x: &mut [Value],
) {
    let xp = SendPtr(x.as_mut_ptr());
    for l in 0..sched.n_levels() {
        pool.run_chunks(sched.chunks(l), |_, range| {
            let xp = xp;
            for t in range {
                let i = sched.rows()[t];
                let mut acc = b[i];
                for (c, v) in tri.row(i) {
                    acc -= v * unsafe { *xp.get().add(c as usize) };
                }
                let out = match diag {
                    Some(d) => acc / d[i],
                    None => acc,
                };
                unsafe { *xp.get().add(i) = out };
            }
        });
    }
}

/// Which SpTRSV kernel a solve actually runs — the two arms of the
/// subsystem's autotuned decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrsvMode {
    /// Plain substitution on the calling thread.
    Serial,
    /// Level-scheduled parallel substitution on the pool.
    LevelPar,
}

impl TrsvMode {
    /// Stable lowercase name (stats rows, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            TrsvMode::Serial => "serial",
            TrsvMode::LevelPar => "levelpar",
        }
    }
}

/// The static serial-vs-parallel SpTRSV policy, from `SPMV_AT_TRSV_PAR`.
///
/// Level-scheduled execution only pays when levels are wide enough to
/// feed the pool: each level costs one `run_chunks` dispatch, so narrow
/// levels (the bidiagonal chain's width-1 extreme) make the parallel
/// variant strictly slower. The decision thresholds on *average level
/// width per pool thread* — the subsystem's `D* `-style cut — and the
/// adaptive layer can overrule a wrong static choice from measured
/// per-apply times exactly as it re-plans SpMV formats.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TrsvPar {
    /// Threshold at the default width factor (4.0 rows per thread).
    Auto,
    /// Always substitute serially.
    Never,
    /// Always replay the level schedule on the pool.
    Always,
    /// Threshold at a custom width factor: go parallel when
    /// `avg_width >= factor × threads`.
    MinWidthPerThread(f64),
}

/// Default rows-per-thread factor for [`TrsvPar::Auto`].
pub const AUTO_WIDTH_FACTOR: f64 = 4.0;

impl TrsvPar {
    /// Parse a policy string: `auto`, `never`/`0`, `always`/`1`, or a
    /// numeric width factor. Empty/whitespace means unset (`None`).
    pub fn parse(s: &str) -> Option<Self> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "" => None,
            "auto" => Some(TrsvPar::Auto),
            "never" | "0" | "off" | "serial" => Some(TrsvPar::Never),
            "always" | "1" | "on" => Some(TrsvPar::Always),
            _ => t
                .parse::<f64>()
                .ok()
                .filter(|f| f.is_finite() && *f > 0.0)
                .map(TrsvPar::MinWidthPerThread),
        }
    }

    /// Truth function for `SPMV_AT_TRSV_PAR`: unset, empty, or
    /// unparseable → [`TrsvPar::Auto`].
    pub fn from_env() -> Self {
        match std::env::var("SPMV_AT_TRSV_PAR") {
            Ok(v) => Self::parse(&v).unwrap_or(TrsvPar::Auto),
            Err(_) => TrsvPar::Auto,
        }
    }

    /// Decide the mode for a schedule's statistics on a pool of
    /// `threads` workers. A 1-thread pool always substitutes serially
    /// (level replay would add dispatch cost for zero parallelism)
    /// unless the policy is `Always`.
    pub fn choose(&self, stats: &LevelStats, threads: usize) -> TrsvMode {
        match *self {
            TrsvPar::Never => TrsvMode::Serial,
            TrsvPar::Always => TrsvMode::LevelPar,
            TrsvPar::Auto => Self::threshold(stats, threads, AUTO_WIDTH_FACTOR),
            TrsvPar::MinWidthPerThread(f) => Self::threshold(stats, threads, f),
        }
    }

    fn threshold(stats: &LevelStats, threads: usize, factor: f64) -> TrsvMode {
        if threads > 1 && stats.avg_width >= factor * threads as f64 {
            TrsvMode::LevelPar
        } else {
            TrsvMode::Serial
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Csr;

    #[test]
    fn serial_forward_solves_a_hand_system() {
        // (D + L) x = b with D = diag(2, 4), L = [[0,0],[1,0]], b = (2, 9)
        // → x0 = 1, x1 = (9 − 1·1)/4 = 2.
        let l = Csr::from_triplets(2, 2, &[(1, 0, 1.0)]).unwrap();
        let mut x = vec![0.0; 2];
        solve_lower_seq(&l, Some(&[2.0, 4.0]), &[2.0, 9.0], &mut x);
        assert_eq!(x, vec![1.0, 2.0]);
    }

    #[test]
    fn serial_backward_solves_a_hand_system() {
        // (D + U) x = b with D = diag(2, 4), U = [[0,3],[0,0]], b = (10, 8)
        // → x1 = 2, x0 = (10 − 3·2)/2 = 2.
        let u = Csr::from_triplets(2, 2, &[(0, 1, 3.0)]).unwrap();
        let mut x = vec![0.0; 2];
        solve_upper_seq(&u, Some(&[2.0, 4.0]), &[10.0, 8.0], &mut x);
        assert_eq!(x, vec![2.0, 2.0]);
    }

    #[test]
    fn unit_diagonal_view_skips_the_divide() {
        let l = Csr::from_triplets(2, 2, &[(1, 0, 0.5)]).unwrap();
        let mut x = vec![0.0; 2];
        solve_lower_seq(&l, None, &[3.0, 4.0], &mut x);
        assert_eq!(x, vec![3.0, 2.5]);
    }

    #[test]
    fn policy_parsing_and_truth_function() {
        assert_eq!(TrsvPar::parse("auto"), Some(TrsvPar::Auto));
        assert_eq!(TrsvPar::parse("never"), Some(TrsvPar::Never));
        assert_eq!(TrsvPar::parse("0"), Some(TrsvPar::Never));
        assert_eq!(TrsvPar::parse("ALWAYS"), Some(TrsvPar::Always));
        assert_eq!(TrsvPar::parse("1"), Some(TrsvPar::Always));
        assert_eq!(TrsvPar::parse(" 2.5 "), Some(TrsvPar::MinWidthPerThread(2.5)));
        assert_eq!(TrsvPar::parse(""), None);
        assert_eq!(TrsvPar::parse("bogus"), None);
        assert_eq!(TrsvPar::parse("-3"), None);
    }

    #[test]
    fn auto_thresholds_on_avg_width_per_thread() {
        let narrow = LevelStats { levels: 100, rows: 100, avg_width: 1.0, max_width: 1 };
        let wide = LevelStats { levels: 4, rows: 1000, avg_width: 250.0, max_width: 400 };
        assert_eq!(TrsvPar::Auto.choose(&narrow, 4), TrsvMode::Serial);
        assert_eq!(TrsvPar::Auto.choose(&wide, 4), TrsvMode::LevelPar);
        // Exactly at the cut (avg = 4.0 × threads) goes parallel.
        let at = LevelStats { levels: 10, rows: 160, avg_width: 16.0, max_width: 20 };
        assert_eq!(TrsvPar::Auto.choose(&at, 4), TrsvMode::LevelPar);
        // A 1-thread pool never goes parallel under a threshold policy…
        assert_eq!(TrsvPar::Auto.choose(&wide, 1), TrsvMode::Serial);
        // …but Always is honoured verbatim (test hook).
        assert_eq!(TrsvPar::Always.choose(&narrow, 1), TrsvMode::LevelPar);
        assert_eq!(TrsvPar::Never.choose(&wide, 8), TrsvMode::Serial);
        assert_eq!(
            TrsvPar::MinWidthPerThread(100.0).choose(&wide, 4),
            TrsvMode::Serial
        );
    }
}
