//! Preconditioner kernels: level-scheduled sparse triangular solves
//! (SpTRSV), symmetric Gauss-Seidel (SymGS), and the trait the solver
//! layer applies them through.
//!
//! The subsystem extends the paper's central question — *does a
//! run-time data transformation pay for itself?* — to the triangular
//! workload behind preconditioned solvers. Here the "transformation" is
//! level-set analysis ([`levels::LevelSchedule`]): an O(nnz) pass that
//! groups rows of a triangle into dependency levels so each level can
//! run in parallel. Its cost, its cached reuse, and the
//! serial-vs-parallel decision it feeds ([`sptrsv::TrsvPar`], measured
//! and correctable at run time via the adaptive telemetry/hysteresis
//! machinery) mirror the SpMV pipeline's transform/decide/serve loop
//! one-for-one:
//!
//! ```text
//!   SpMV loop                      SpTRSV / SymGS loop
//!   ─────────                      ───────────────────
//!   CRS → ELL/SELL transform       Csr::split_triangular + level sets
//!   D_mat density statistic        LevelStats avg/max level width
//!   D* threshold (offline table)   SPMV_AT_TRSV_PAR width threshold
//!   cached SpmvPlan                cached Triangular + LevelSchedule
//!   Telemetry per Implementation   ArmTelemetry<TrsvMode>
//!   hysteresis re-plan             hysteresis mode flip (bitwise-safe)
//! ```
//!
//! [`Preconditioner`] is the application-facing seam:
//! [`crate::solver::pcg_with`] takes any implementation, the
//! coordinator caches one per served entry next to its `SpmvPlan`, and
//! the CLI selects one via `--precond` / `SPMV_AT_PRECOND`
//! ([`configured_precond`]). [`Jacobi`] reproduces what `pcg` always
//! did (diagonal scaling) with the setup hoisted out of the solve loop;
//! [`SymGs`] is the HPCG-smoother shape built on the SpTRSV kernels.

pub mod levels;
pub mod sptrsv;
mod symgs;

pub use levels::{LevelSchedule, LevelStats};
pub use sptrsv::{TrsvMode, TrsvPar};
pub use symgs::SymGs;

use crate::formats::{Csr, SparseMatrix};
use crate::spmv::ParPool;
use crate::{Result, Value};
use std::sync::Arc;
use std::time::Instant;

/// An operator `z ← M⁻¹ r` applied once per solver iteration.
///
/// Implementations own whatever setup artifacts they need (inverted
/// diagonal, triangles, level schedules) so repeated solves on a cached
/// entry never redo setup — the bug this trait fixes: `pcg` used to
/// rescan the full matrix for its diagonal on *every* solve call.
/// `apply` is infallible by contract: all validation (squareness,
/// non-zero diagonal) happens at build time.
pub trait Preconditioner: Send {
    /// Stable lowercase name (`stats` rows, solve reports, bench JSON).
    fn name(&self) -> &'static str;

    /// Wall seconds the one-time setup cost (0 for [`Identity`]).
    /// Reported per solve in
    /// [`crate::solver::SolveStats::precond_setup_seconds`] whether the
    /// setup was paid in that call or amortised from cache.
    fn setup_seconds(&self) -> f64;

    /// Apply `z ← M⁻¹ r`. `r` and `z` have the operator's dimension.
    fn apply(&mut self, r: &[Value], z: &mut [Value]);
}

/// The do-nothing preconditioner: `z ← r` (PCG degenerates to CG).
pub struct Identity;

impl Preconditioner for Identity {
    fn name(&self) -> &'static str {
        "none"
    }

    fn setup_seconds(&self) -> f64 {
        0.0
    }

    fn apply(&mut self, r: &[Value], z: &mut [Value]) {
        z.copy_from_slice(r);
    }
}

/// Diagonal (Jacobi) scaling: `z ← D⁻¹ r`, with `1/dᵢ` precomputed once
/// at build — the preconditioner `pcg` has always used, minus the
/// per-solve full-matrix diagonal scan.
pub struct Jacobi {
    minv: Vec<Value>,
    setup_seconds: f64,
}

impl Jacobi {
    /// Extract and invert the diagonal of `a`. Fails on rectangular
    /// matrices or any zero diagonal entry (same contract `pcg`
    /// enforced inline).
    pub fn build(a: &Csr) -> Result<Self> {
        let t0 = Instant::now();
        anyhow::ensure!(
            a.n_rows() == a.n_cols(),
            "jacobi preconditioner needs a square matrix, got {}x{}",
            a.n_rows(),
            a.n_cols()
        );
        let n = a.n_rows();
        let mut minv = vec![0.0; n];
        for i in 0..n {
            let mut d = 0.0;
            for (c, v) in a.row(i) {
                if c as usize == i {
                    d = v;
                }
            }
            anyhow::ensure!(d != 0.0, "jacobi preconditioner needs a non-zero diagonal (row {i})");
            minv[i] = 1.0 / d;
        }
        Ok(Self { minv, setup_seconds: t0.elapsed().as_secs_f64() })
    }

    /// Build from an already-extracted diagonal (the
    /// [`crate::solver::SpmvOp::diagonal`] path — lets [`crate::solver::pcg`]
    /// instantiate Jacobi for operators that are not plain `Csr`).
    pub fn from_diagonal(d: Vec<Value>) -> Result<Self> {
        let t0 = Instant::now();
        anyhow::ensure!(
            d.iter().all(|&v| v != 0.0),
            "Jacobi preconditioner needs a zero-free diagonal"
        );
        let minv = d.into_iter().map(|v| 1.0 / v).collect();
        Ok(Self { minv, setup_seconds: t0.elapsed().as_secs_f64() })
    }
}

impl Preconditioner for Jacobi {
    fn name(&self) -> &'static str {
        "jacobi"
    }

    fn setup_seconds(&self) -> f64 {
        self.setup_seconds
    }

    fn apply(&mut self, r: &[Value], z: &mut [Value]) {
        for ((zi, &ri), &mi) in z.iter_mut().zip(r).zip(&self.minv) {
            *zi = ri * mi;
        }
    }
}

/// Which preconditioner the CLI / env / coordinator selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecondKind {
    /// [`Identity`] — no preconditioning.
    None,
    /// [`Jacobi`] — diagonal scaling (the historical `pcg` behaviour,
    /// and the default).
    Jacobi,
    /// [`SymGs`] — symmetric Gauss-Seidel on level-scheduled SpTRSV.
    SymGs,
}

impl PrecondKind {
    /// Stable lowercase name (flag values, stats rows).
    pub fn name(self) -> &'static str {
        match self {
            PrecondKind::None => "none",
            PrecondKind::Jacobi => "jacobi",
            PrecondKind::SymGs => "symgs",
        }
    }

    /// Parse a kind string (`none`/`identity`, `jacobi`/`diag`,
    /// `symgs`/`gs`). Empty/whitespace means unset (`None`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "none" | "identity" | "off" => Some(PrecondKind::None),
            "jacobi" | "diag" | "diagonal" => Some(PrecondKind::Jacobi),
            "symgs" | "gs" | "gauss-seidel" => Some(PrecondKind::SymGs),
            _ => None,
        }
    }

    /// Build the preconditioner for `a`, running level-scheduled
    /// kernels (SymGS) on `pool` under the given policies.
    pub fn build(
        self,
        a: &Csr,
        pool: &Arc<ParPool>,
        trsv: TrsvPar,
        adaptive: &crate::autotune::adaptive::AdaptiveConfig,
    ) -> Result<Box<dyn Preconditioner>> {
        Ok(match self {
            PrecondKind::None => Box::new(Identity),
            PrecondKind::Jacobi => Box::new(Jacobi::build(a)?),
            PrecondKind::SymGs => Box::new(SymGs::build(a, pool.clone(), trsv, adaptive)?),
        })
    }
}

impl std::fmt::Display for PrecondKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Truth function for `SPMV_AT_PRECOND`: unset, empty, or unparseable
/// means [`PrecondKind::Jacobi`] — the preconditioner `pcg` has always
/// applied, so existing deployments see byte-identical behaviour.
pub fn configured_precond() -> PrecondKind {
    match std::env::var("SPMV_AT_PRECOND") {
        Ok(v) => PrecondKind::parse(&v).unwrap_or(PrecondKind::Jacobi),
        Err(_) => PrecondKind::Jacobi,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_copies() {
        let mut m = Identity;
        let mut z = [0.0; 3];
        m.apply(&[1.0, -2.0, 3.5], &mut z);
        assert_eq!(z, [1.0, -2.0, 3.5]);
        assert_eq!(m.name(), "none");
        assert_eq!(m.setup_seconds(), 0.0);
    }

    #[test]
    fn jacobi_scales_by_inverse_diagonal() {
        let a = Csr::from_triplets(2, 2, &[(0, 0, 2.0), (0, 1, 7.0), (1, 1, 4.0)]).unwrap();
        let mut m = Jacobi::build(&a).unwrap();
        let mut z = [0.0; 2];
        m.apply(&[2.0, 2.0], &mut z);
        assert_eq!(z, [1.0, 0.5]);
        assert!(m.setup_seconds() >= 0.0);
    }

    #[test]
    fn jacobi_rejects_zero_or_missing_diagonal() {
        let zero = Csr::from_triplets(2, 2, &[(0, 0, 0.0), (1, 1, 1.0)]).unwrap();
        assert!(Jacobi::build(&zero).is_err());
        let missing = Csr::from_triplets(2, 2, &[(0, 0, 1.0)]).unwrap();
        assert!(Jacobi::build(&missing).is_err());
        let rect = Csr::from_triplets(2, 3, &[(0, 0, 1.0)]).unwrap();
        assert!(Jacobi::build(&rect).is_err());
    }

    #[test]
    fn kind_parse_and_names() {
        assert_eq!(PrecondKind::parse("none"), Some(PrecondKind::None));
        assert_eq!(PrecondKind::parse(" JACOBI "), Some(PrecondKind::Jacobi));
        assert_eq!(PrecondKind::parse("symgs"), Some(PrecondKind::SymGs));
        assert_eq!(PrecondKind::parse("gs"), Some(PrecondKind::SymGs));
        assert_eq!(PrecondKind::parse(""), None);
        assert_eq!(PrecondKind::parse("bogus"), None);
        assert_eq!(PrecondKind::SymGs.to_string(), "symgs");
    }
}
