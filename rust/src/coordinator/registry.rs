//! Matrix registry: per-matrix auto-tuning lifecycle state.
//!
//! Every registered matrix walks the state machine
//!
//! ```text
//! Registered --(online AT decision at register time)--> decision recorded
//!    |                                                        |
//!    |  first SpMV, decision = keep CRS                       | first SpMV, decision = transform
//!    v                                                        v
//! Baseline (cached CRS plan)                    Transformed { plan, t_trans }
//! ```
//!
//! Both states execute through a cached [`SpmvPlan`]: the baseline plan
//! (row-parallel CRS on the coordinator's pool) is built at registration,
//! and the transformed plan replaces it as the serving path on the first
//! SpMV after a transform decision. Amortisation accounting — how many
//! calls the transformed copy has served and whether the transformation
//! cost has been repaid — makes the §2.2 break-even analysis observable.

use super::shards::SplitPlan;
use crate::autotune::adaptive::AdaptiveState;
use crate::autotune::online::OnlineDecision;
use crate::formats::Csr;
use crate::spmv::{Implementation, SpmvPlan};
use std::sync::Arc;

/// Execution state of one registered matrix.
pub enum AtState {
    /// Serving the CRS baseline plan (either the decision said so, or the
    /// transformation has not been triggered yet).
    Baseline,
    /// A transformed plan is live.
    Transformed {
        /// The executable plan owning the transformed data.
        plan: SpmvPlan,
        /// Seconds the transformation took (amortisation numerator).
        t_trans: f64,
    },
}

/// One registered matrix with its AT lifecycle.
pub struct MatrixEntry {
    /// Registry key.
    pub name: String,
    /// The CRS original (always kept — the §2.2 memory-policy default),
    /// shared by `Arc` with the baseline plan so CRS serving is zero-copy.
    pub csr: Arc<Csr>,
    /// The online decision taken at registration.
    pub decision: OnlineDecision,
    /// The cached CRS baseline plan serving the [`AtState::Baseline`] state.
    pub baseline: SpmvPlan,
    /// The rival (transform-target) implementation the adaptive loop
    /// measures against — the tuning table's candidate, regardless of
    /// what the online decision chose.
    pub candidate: Implementation,
    /// The pool shard this matrix's plans build and execute on.
    pub shard: usize,
    /// Current execution state.
    pub state: AtState,
    /// Total SpMV calls served.
    pub calls: u64,
    /// Calls served by the transformed copy.
    pub transformed_calls: u64,
    /// Measured seconds of CRS SpMV (running mean), for amortisation.
    pub t_crs_mean: f64,
    /// Measured seconds of transformed SpMV (running mean).
    pub t_imp_mean: f64,
    /// Per-matrix adaptive loop state (`None` when the coordinator runs
    /// the decide-once pipeline).
    pub adaptive: Option<AdaptiveState>,
    /// Serving-plan flips applied (controller-initiated or forced).
    pub replans: u64,
    /// Cached cross-shard split plan serving an oversized matrix
    /// (`None` = unsplit serving). Built lazily on the first call past
    /// the split threshold; invalidated by flips/replans so it always
    /// follows the current decision.
    pub split: Option<SplitPlan>,
    /// Calls served through the split plan.
    pub split_calls: u64,
    /// Set when an automatic split build failed: the entry is pinned to
    /// unsplit serving so the hot path never re-pays the failed build on
    /// every call. Reset by flips and forced replans (the decision the
    /// split would serve has changed, so it gets one fresh chance).
    pub split_vetoed: bool,
    /// Cached preconditioner serving this entry's `solve` requests
    /// (`None` until the first preconditioned solve). Built once —
    /// triangles, level schedules, inverted diagonal — and reused by
    /// every later solve, exactly as the `SpmvPlan` caches the
    /// transformed matrix.
    pub precond: Option<Box<dyn crate::precond::Preconditioner>>,
    /// Preconditioner applications served through the cached instance.
    pub precond_calls: u64,
    /// Wall seconds the cached preconditioner's one-time setup cost
    /// (0.0 until one is built) — kept here so stats survive the
    /// take/put-back dance around a solve.
    pub precond_setup_seconds: f64,
}

impl MatrixEntry {
    /// New entry in the baseline state, serving through `baseline` on
    /// pool shard `shard`, with `candidate` as the transform-target arm.
    pub fn new(
        name: String,
        csr: Arc<Csr>,
        decision: OnlineDecision,
        baseline: SpmvPlan,
        candidate: Implementation,
        shard: usize,
    ) -> Self {
        Self {
            name,
            csr,
            decision,
            baseline,
            candidate,
            shard,
            state: AtState::Baseline,
            calls: 0,
            transformed_calls: 0,
            t_crs_mean: 0.0,
            t_imp_mean: 0.0,
            adaptive: None,
            replans: 0,
            split: None,
            split_calls: 0,
            split_vetoed: false,
            precond: None,
            precond_calls: 0,
            precond_setup_seconds: 0.0,
        }
    }

    /// The implementation currently serving this entry — the split
    /// plan's when one is cached, the serving state's otherwise.
    pub fn serving_imp(&self) -> Implementation {
        if let Some(split) = &self.split {
            return split.implementation();
        }
        match &self.state {
            AtState::Baseline => self.baseline.implementation(),
            AtState::Transformed { plan, .. } => plan.implementation(),
        }
    }

    /// Transformation seconds paid so far (0 while baseline; a
    /// transformed split reports its blocks' summed build cost).
    pub fn t_trans(&self) -> f64 {
        if let Some(split) = &self.split {
            return split.transform_seconds();
        }
        match &self.state {
            AtState::Baseline => 0.0,
            AtState::Transformed { t_trans, .. } => *t_trans,
        }
    }

    /// The measured per-call saving of the transformed kernel over CRS,
    /// clamped at zero — the single definition both the amortisation test
    /// and the break-even estimate use (an unclamped negative saving
    /// would let `calls · saving` go *backwards* past `t_trans`).
    pub fn per_call_saving(&self) -> f64 {
        (self.t_crs_mean - self.t_imp_mean).max(0.0)
    }

    /// Whether the transformation cost has been repaid by the measured
    /// per-call saving: `transformed_calls · saving ≥ t_trans` (trivially
    /// true when nothing was transformed — baseline and CRS-split
    /// serving both owe zero).
    pub fn amortized(&self) -> bool {
        let t_trans = self.t_trans();
        if t_trans <= 0.0 {
            return true;
        }
        self.transformed_calls as f64 * self.per_call_saving() >= t_trans
    }

    /// Estimated calls until break-even (0 when already amortised; ∞ when
    /// the transformed kernel is not actually faster).
    pub fn calls_to_break_even(&self) -> f64 {
        let t_trans = self.t_trans();
        if t_trans <= 0.0 {
            return 0.0;
        }
        let saving = self.per_call_saving();
        if saving <= 0.0 {
            // Zero (clamped) saving with a real debt: never breaks even —
            // consistent with `amortized`.
            return f64::INFINITY;
        }
        (t_trans / saving - self.transformed_calls as f64).max(0.0)
    }

    /// Record a served call.
    pub fn record_call(&mut self, transformed: bool, seconds: f64) {
        self.record_batch(transformed, 1, seconds);
    }

    /// Record a batch of `k` calls served in `seconds_total` (one tiled
    /// SpMM dispatch): the running means absorb `k` samples at the
    /// per-call average, and — when the adaptive loop is on — the same
    /// samples feed the per-implementation EWMA telemetry, keyed by the
    /// kernel that actually executed.
    pub fn record_batch(&mut self, transformed: bool, k: u64, seconds_total: f64) {
        if k == 0 {
            return;
        }
        let per_call = seconds_total / k as f64;
        self.calls += k;
        if transformed {
            self.transformed_calls += k;
            let n = self.transformed_calls as f64;
            self.t_imp_mean += (per_call - self.t_imp_mean) * (k as f64 / n);
        } else {
            let n = (self.calls - self.transformed_calls) as f64;
            self.t_crs_mean += (per_call - self.t_crs_mean) * (k as f64 / n);
        }
        let imp = self.serving_imp();
        if let Some(ad) = &mut self.adaptive {
            ad.telemetry.record(imp, per_call, k);
        }
    }

    /// Extra memory held beyond the CRS original: the transformed copy
    /// when serving it, the cached cross-shard split's blocks, plus the
    /// parked shadow plan the adaptive loop keeps warm for O(1) flips.
    pub fn extra_bytes(&self) -> usize {
        let serving = match &self.state {
            AtState::Baseline => 0,
            AtState::Transformed { plan, .. } => plan.extra_bytes(),
        };
        let split = self.split.as_ref().map_or(0, SplitPlan::extra_bytes);
        let shadow = self
            .adaptive
            .as_ref()
            .and_then(|ad| ad.shadow.as_ref())
            .map_or(0, |p| p.extra_bytes());
        serving + split + shadow
    }
}

/// Summary row for reporting (`stats` requests).
#[derive(Clone, Debug)]
pub struct EntryStats {
    /// Registry key.
    pub name: String,
    /// Matrix rows.
    pub n: usize,
    /// Matrix non-zeros.
    pub nnz: usize,
    /// `D_mat`.
    pub d_mat: f64,
    /// The pool shard (= socket, under NUMA routing) serving this matrix.
    pub shard: usize,
    /// The implementation currently serving.
    pub serving: Implementation,
    /// The serving plan's intra-pool partition strategy (`"even"`,
    /// `"nnz"`, `"merge"`; `"-"` for unpartitioned or split-served
    /// entries — a cross-shard split partitions per block).
    pub partition: &'static str,
    /// Total calls.
    pub calls: u64,
    /// Transformed calls.
    pub transformed_calls: u64,
    /// Transformation seconds paid.
    pub t_trans: f64,
    /// Amortised yet?
    pub amortized: bool,
    /// Extra bytes held.
    pub extra_bytes: usize,
    /// Serving-plan flips applied so far (adaptive re-decisions + forced
    /// replans).
    pub replans: u64,
    /// Exploration shadow calls taken (0 when adaptive is off).
    pub explored: u64,
    /// Telemetry samples on the CRS baseline arm.
    pub samples_crs: u64,
    /// Telemetry samples on the candidate (transform-target) arm.
    pub samples_imp: u64,
    /// Row blocks of the cached cross-shard split plan serving this
    /// entry (0 = unsplit serving).
    pub split_parts: usize,
    /// Calls served through the split plan.
    pub split_calls: u64,
    /// Times the matrix data was streamed by this entry's plans, summed
    /// over baseline + transformed + cached split. With the adaptive
    /// loop off this is exactly the serving pass count (exploration can
    /// add shadow streams): a coalesced batch of `k` requests grows it
    /// by ⌈k/tile⌉ instead of `k` — the counter the network ingress
    /// tests read to prove coalescing paid.
    pub matrix_passes: u64,
    /// Name of the cached preconditioner (`None` until a preconditioned
    /// solve built one).
    pub precond: Option<&'static str>,
    /// Preconditioner applications served through the cached instance
    /// (with `calls`, the full amortisation denominator for solver
    /// traffic).
    pub precond_calls: u64,
    /// One-time setup seconds of the cached preconditioner (0.0 when
    /// none) — the cost the caching amortises across solves.
    pub precond_setup_seconds: f64,
}

impl MatrixEntry {
    /// The serving implementation by the stats-row convention.
    ///
    /// Deliberately NOT `serving_imp()`: the unsplit baseline state
    /// reports as the paper's CRS switch (`CsrSeq`) whichever CRS kernel
    /// the baseline plan runs, while the telemetry keys by the kernel
    /// that actually executed. Both [`MatrixEntry::stats`] and the
    /// decision log render this convention, so replaying the log
    /// reproduces the stats row exactly.
    pub fn reported_serving(&self) -> Implementation {
        match (&self.split, &self.state) {
            (Some(split), _) => split.implementation(),
            (None, AtState::Baseline) => Implementation::CsrSeq,
            (None, AtState::Transformed { plan, .. }) => plan.implementation(),
        }
    }

    /// The intra-pool partition strategy by the stats-row convention
    /// (`"-"` for split-served entries, whose row blocks partition the
    /// work instead).
    pub fn reported_partition(&self) -> &'static str {
        match (&self.split, &self.state) {
            (Some(_), _) => "-",
            (None, AtState::Baseline) => self.baseline.partition_strategy(),
            (None, AtState::Transformed { plan, .. }) => plan.partition_strategy(),
        }
    }

    /// Produce the report row. The baseline state reports as the paper's
    /// CRS switch regardless of which CRS kernel the baseline plan runs.
    pub fn stats(&self) -> EntryStats {
        use crate::formats::SparseMatrix as _;
        let (explored, samples_crs, samples_imp) = match &self.adaptive {
            None => (0, 0, 0),
            Some(ad) => (
                ad.explore.explored(),
                ad.telemetry.samples(self.baseline.implementation()),
                ad.telemetry.samples(self.candidate),
            ),
        };
        EntryStats {
            name: self.name.clone(),
            n: self.csr.n_rows(),
            nnz: self.csr.nnz(),
            d_mat: self.decision.d_mat,
            shard: self.shard,
            serving: self.reported_serving(),
            partition: self.reported_partition(),
            calls: self.calls,
            transformed_calls: self.transformed_calls,
            t_trans: self.t_trans(),
            amortized: self.amortized(),
            extra_bytes: self.extra_bytes(),
            replans: self.replans,
            explored,
            samples_crs,
            samples_imp,
            split_parts: self.split.as_ref().map_or(0, SplitPlan::parts),
            split_calls: self.split_calls,
            matrix_passes: self.baseline.matrix_passes()
                + match &self.state {
                    AtState::Baseline => 0,
                    AtState::Transformed { plan, .. } => plan.matrix_passes(),
                }
                + self.split.as_ref().map_or(0, SplitPlan::matrix_passes),
            precond: self.precond.as_ref().map(|p| p.name()),
            precond_calls: self.precond_calls,
            precond_setup_seconds: self.precond_setup_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::pool::ParPool;
    use crate::spmv::Implementation;
    use std::sync::Arc;

    fn decision(transform: bool) -> OnlineDecision {
        OnlineDecision {
            d_mat: 0.1,
            d_star: 1.0,
            transform,
            chosen: if transform {
                Implementation::EllRowOuter
            } else {
                Implementation::CsrSeq
            },
        }
    }

    fn crs_plan(n: usize) -> SpmvPlan {
        SpmvPlan::build(
            &Arc::new(Csr::identity(n)),
            Implementation::CsrSeq,
            None,
            Arc::new(ParPool::new(1)),
        )
        .unwrap()
    }

    fn ell_plan(n: usize, t_trans: f64) -> AtState {
        let plan = SpmvPlan::build(
            &Arc::new(Csr::identity(n)),
            Implementation::EllRowOuter,
            None,
            Arc::new(ParPool::new(1)),
        )
        .unwrap();
        AtState::Transformed { plan, t_trans }
    }

    fn entry(transform: bool) -> MatrixEntry {
        MatrixEntry::new(
            "m".into(),
            Arc::new(Csr::identity(4)),
            decision(transform),
            crs_plan(4),
            Implementation::EllRowOuter,
            0,
        )
    }

    #[test]
    fn baseline_plan_shares_the_registered_matrix() {
        let csr = Arc::new(Csr::identity(6));
        let pool = Arc::new(ParPool::new(1));
        let baseline = SpmvPlan::build(&csr, Implementation::CsrRowPar, None, pool).unwrap();
        let e = MatrixEntry::new(
            "m".into(),
            csr.clone(),
            decision(false),
            baseline,
            Implementation::EllRowOuter,
            0,
        );
        match e.baseline.matrix() {
            crate::spmv::AnyMatrix::Csr(shared) => {
                assert!(Arc::ptr_eq(shared, &csr), "baseline must not clone the CRS");
            }
            _ => panic!("baseline must be CRS"),
        }
        // A partitioned CRS baseline reports its strategy in the stats row.
        assert!(
            ["even", "nnz", "merge"].contains(&e.stats().partition),
            "row-parallel baseline must report a real partition strategy"
        );
    }

    #[test]
    fn record_batch_matches_equivalent_single_calls() {
        let mut a = entry(true);
        let mut b = entry(true);
        a.record_call(false, 2e-3);
        b.record_call(false, 2e-3);
        // One batch of 4 at 1ms/call vs 4 singles of 1ms.
        a.record_batch(true, 4, 4e-3);
        for _ in 0..4 {
            b.record_call(true, 1e-3);
        }
        assert_eq!(a.calls, b.calls);
        assert_eq!(a.transformed_calls, b.transformed_calls);
        assert!((a.t_imp_mean - b.t_imp_mean).abs() < 1e-15);
        // Zero-width batches are ignored.
        a.record_batch(true, 0, 1.0);
        assert_eq!(a.calls, b.calls);
    }

    #[test]
    fn baseline_is_trivially_amortized() {
        let e = entry(false);
        assert!(e.amortized());
        assert_eq!(e.t_trans(), 0.0);
        assert_eq!(e.extra_bytes(), 0);
        assert_eq!(e.calls_to_break_even(), 0.0);
    }

    #[test]
    fn amortization_crossover() {
        let mut e = entry(true);
        // Pretend: CRS costs 1ms/call, transformed 0.1ms, transform 5ms.
        e.record_call(false, 1e-3);
        e.state = ell_plan(4, 5e-3);
        for _ in 0..5 {
            e.record_call(true, 1e-4);
            assert!(!e.amortized(), "not yet at {} calls", e.transformed_calls);
        }
        let before = e.calls_to_break_even();
        assert!(before > 0.0 && before.is_finite());
        e.record_call(true, 1e-4); // 6 * 0.9ms = 5.4ms >= 5ms
        assert!(e.amortized());
        assert_eq!(e.calls_to_break_even(), 0.0);
    }

    #[test]
    fn never_amortizes_when_not_faster() {
        let mut e = entry(true);
        e.record_call(false, 1e-4);
        e.state = ell_plan(4, 1e-3);
        e.record_call(true, 2e-4); // slower than CRS
        assert!(!e.amortized());
        assert!(e.calls_to_break_even().is_infinite());
    }

    #[test]
    fn negative_saving_is_clamped_consistently() {
        // Regression: amortized() clamped the saving while
        // calls_to_break_even() did not — both now share per_call_saving().
        let mut e = entry(true);
        e.record_call(false, 1e-4);
        e.state = ell_plan(4, 5e-3);
        // Transformed kernel measures *slower*: negative raw saving.
        for _ in 0..1_000 {
            e.record_call(true, 2e-4);
        }
        assert_eq!(e.per_call_saving(), 0.0, "saving clamps at zero");
        assert!(
            !e.amortized(),
            "a slower kernel must never report amortised, however many calls"
        );
        assert!(e.calls_to_break_even().is_infinite());
        // Zero-cost transformation with zero saving: nothing owed.
        e.state = ell_plan(4, 0.0);
        assert!(e.amortized());
        assert_eq!(e.calls_to_break_even(), 0.0);
    }

    #[test]
    fn record_batch_feeds_adaptive_telemetry_by_serving_kernel() {
        use crate::autotune::adaptive::{AdaptiveConfig, AdaptiveState};
        let mut e = entry(true);
        e.adaptive = Some(AdaptiveState::new(&AdaptiveConfig::default(), 1));
        e.record_batch(false, 3, 3e-3); // baseline serves: CsrSeq plan here
        e.state = ell_plan(4, 1e-3);
        e.record_batch(true, 2, 2e-4);
        let ad = e.adaptive.as_ref().unwrap();
        assert_eq!(ad.telemetry.samples(e.baseline.implementation()), 3);
        assert_eq!(ad.telemetry.samples(Implementation::EllRowOuter), 2);
        let s = e.stats();
        assert_eq!(s.samples_crs, 3);
        assert_eq!(s.samples_imp, 2);
        assert_eq!(s.replans, 0);
        assert_eq!(s.explored, 0);
    }

    #[test]
    fn split_served_entry_reports_split_fields() {
        use crate::autotune::online::TuningData;
        use crate::autotune::MemoryPolicy;
        use crate::coordinator::{PlanShards, ShardedPlanner};
        let sp = ShardedPlanner::new(
            TuningData {
                backend: "sim:ES2".into(),
                imp: Implementation::EllRowOuter,
                threads: 1,
                c: 1.0,
                d_star: Some(3.1),
            },
            MemoryPolicy::unlimited(),
            PlanShards::new(2, 1),
        );
        let csr = Arc::new(Csr::identity(64));
        let split = sp.plan_split(&csr, Implementation::CsrRowPar, 2).unwrap();
        let mut e = MatrixEntry::new(
            "m".into(),
            csr.clone(),
            decision(false),
            crs_plan(64),
            Implementation::EllRowOuter,
            0,
        );
        assert_eq!(e.stats().split_parts, 0, "unsplit entries report zero parts");
        e.split = Some(split);
        e.split_calls = 5;
        let s = e.stats();
        assert_eq!(s.split_parts, 2);
        assert_eq!(s.split_calls, 5);
        assert_eq!(s.serving, Implementation::CsrRowPar, "the split's kernel serves");
        assert_eq!(e.serving_imp(), Implementation::CsrRowPar);
        assert!(e.extra_bytes() > 0, "sliced CRS blocks are real copies");
        assert_eq!(e.t_trans(), 0.0, "a CRS split owes no transformation");
        assert!(e.amortized());
        assert_eq!(e.calls_to_break_even(), 0.0);
    }

    #[test]
    fn stats_row_reflects_state() {
        let mut e = entry(true);
        e.record_call(false, 1e-3);
        let s = e.stats();
        assert_eq!(s.serving, Implementation::CsrSeq);
        assert_eq!(s.partition, "-", "a sequential baseline plan is unpartitioned");
        assert_eq!(s.calls, 1);
        e.state = ell_plan(4, 1e-3);
        assert_eq!(e.stats().serving, Implementation::EllRowOuter);
        assert!(e.stats().extra_bytes > 0);
    }
}
