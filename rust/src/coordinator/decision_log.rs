//! Append-only, replayable log of serving decisions (JSONL).
//!
//! Every decision that changes — or deliberately keeps — how a
//! registered matrix is served emits one [`DecisionRecord`]: the
//! register-time online decision, the deferred transform build, adaptive
//! flips, forced replans, split builds, and split vetoes. Each record
//! carries two things:
//!
//! * the **resulting serving state** (kernel, partition, split parts,
//!   split veto), rendered by the same convention as the stats row
//!   ([`crate::coordinator::MatrixEntry::reported_serving`]), and
//! * the **telemetry that justified the decision** — `D_mat`, `D*`, the
//!   serving/rival arm means and sample counts, and the controller's
//!   vote/window state at the moment it fired.
//!
//! Because every record carries the *post-state*, the log is replayable
//! by a trivial fold: the last record per matrix **is** the final
//! serving decision ([`replay`]), with no need to re-run any planner
//! logic. That makes the log an audit trail ("why did this matrix flip
//! at 03:14?") and a reproducibility artifact (the acceptance test
//! replays it against the live registry) at once.
//!
//! The log is a cheap cloneable handle over one shared sink: an
//! in-memory ring of the most recent rendered lines (always on, bounded)
//! plus an optional append-only JSONL file (`--decision-log <path>`).
//! Rendering uses [`crate::metrics::Json`], one compact object per line.

use crate::metrics::Json;
use crate::Result;
use std::collections::{HashMap, VecDeque};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// How many rendered lines the in-memory ring retains (the file, when
/// configured, keeps everything).
const RING_CAPACITY: usize = 1024;

/// What kind of serving decision a record describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecisionEvent {
    /// The register-time online decision (§2.2 `D_mat` vs `D*`).
    Register,
    /// The deferred transformation was built and took over serving.
    Transform,
    /// The hysteresis controller (or a forced replan) flipped the
    /// serving plan between the baseline and the candidate.
    Flip,
    /// A forced replan re-ran the online phase.
    Replan,
    /// A cross-shard split plan was built and took over serving.
    Split,
    /// A split build failed; the entry is pinned to unsplit serving.
    SplitVeto,
}

impl DecisionEvent {
    /// The event's stable wire/JSONL name.
    pub fn name(self) -> &'static str {
        match self {
            DecisionEvent::Register => "register",
            DecisionEvent::Transform => "transform",
            DecisionEvent::Flip => "flip",
            DecisionEvent::Replan => "replan",
            DecisionEvent::Split => "split",
            DecisionEvent::SplitVeto => "split_veto",
        }
    }
}

/// One serving decision: the event, the resulting state, and the
/// telemetry that justified it.
#[derive(Clone, Debug)]
pub struct DecisionRecord {
    /// What happened.
    pub event: DecisionEvent,
    /// Registry key of the matrix.
    pub matrix: String,
    /// Serving implementation after the event, rendered by the stats-row
    /// convention (unsplit baseline serving reports the paper's CRS
    /// switch).
    pub kernel: String,
    /// Intra-pool partition strategy after the event (`"-"` for
    /// unpartitioned or split-served entries).
    pub partition: &'static str,
    /// Row blocks of the cached split plan after the event (0 = unsplit).
    pub split_parts: u64,
    /// Whether split serving is vetoed after the event.
    pub split_vetoed: bool,
    /// Whether the decision transforms (serves a non-CRS plan).
    pub transform: bool,
    /// The matrix's `D_mat` (row-length variation coefficient).
    pub d_mat: f64,
    /// The `D*` threshold compared against (NaN renders as null).
    pub d_star: f64,
    /// Measured per-call mean of the serving arm, seconds (None until
    /// telemetry exists).
    pub serving_mean: Option<f64>,
    /// Measured per-call mean of the rival arm, seconds.
    pub rival_mean: Option<f64>,
    /// Telemetry samples behind `rival_mean`.
    pub rival_samples: u64,
    /// Controller contradiction votes at the moment of the event.
    pub votes: u64,
    /// Controller windows evaluated at the moment of the event.
    pub windows: u64,
    /// Free-text justification (e.g. the threshold comparison, or a
    /// build-failure message).
    pub detail: String,
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

impl DecisionRecord {
    /// Render as one compact JSONL line (no trailing newline).
    fn render(&self, seq: u64) -> String {
        Json::Obj(vec![
            ("seq".into(), Json::Num(seq as f64)),
            ("event".into(), Json::Str(self.event.name().into())),
            ("matrix".into(), Json::Str(self.matrix.clone())),
            ("kernel".into(), Json::Str(self.kernel.clone())),
            ("partition".into(), Json::Str(self.partition.into())),
            ("split_parts".into(), Json::Num(self.split_parts as f64)),
            ("split_vetoed".into(), Json::Bool(self.split_vetoed)),
            ("transform".into(), Json::Bool(self.transform)),
            ("d_mat".into(), Json::Num(self.d_mat)),
            ("d_star".into(), Json::Num(self.d_star)),
            ("serving_mean".into(), opt_num(self.serving_mean)),
            ("rival_mean".into(), opt_num(self.rival_mean)),
            ("rival_samples".into(), Json::Num(self.rival_samples as f64)),
            ("votes".into(), Json::Num(self.votes as f64)),
            ("windows".into(), Json::Num(self.windows as f64)),
            ("detail".into(), Json::Str(self.detail.clone())),
        ])
        .render()
    }
}

struct Inner {
    file: Option<std::io::BufWriter<std::fs::File>>,
    path: Option<PathBuf>,
    ring: VecDeque<String>,
    seq: u64,
}

/// Cheap cloneable handle over one shared decision-log sink. Cloning
/// shares the sink (the sharded server clones its config per shard; all
/// shards append to the same log).
#[derive(Clone)]
pub struct DecisionLog {
    inner: Arc<Mutex<Inner>>,
}

impl std::fmt::Debug for DecisionLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("DecisionLog")
            .field("path", &inner.path)
            .field("records", &inner.seq)
            .finish()
    }
}

impl DecisionLog {
    /// Ring-only log: the most recent [`RING_CAPACITY`] rendered lines
    /// are retained for the `DecisionLog` wire request; nothing is
    /// written to disk.
    pub fn in_memory() -> Self {
        Self {
            inner: Arc::new(Mutex::new(Inner { file: None, path: None, ring: VecDeque::new(), seq: 0 })),
        }
    }

    /// Ring + append-only JSONL file at `path` (created if missing,
    /// appended to if present — the log is append-only across restarts).
    pub fn to_path(path: &Path) -> Result<Self> {
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Self {
            inner: Arc::new(Mutex::new(Inner {
                file: Some(std::io::BufWriter::new(file)),
                path: Some(path.to_path_buf()),
                ring: VecDeque::new(),
                seq: 0,
            })),
        })
    }

    /// Append one record: rendered once, pushed into the ring, and —
    /// when a file is configured — written and flushed as one JSONL
    /// line. File write errors are swallowed (the log is telemetry;
    /// serving must not fail on a full disk), but the ring always keeps
    /// the line.
    pub fn record(&self, rec: &DecisionRecord) {
        let mut inner = self.inner.lock().unwrap();
        let line = rec.render(inner.seq);
        inner.seq += 1;
        if inner.ring.len() == RING_CAPACITY {
            inner.ring.pop_front();
        }
        inner.ring.push_back(line.clone());
        if let Some(f) = inner.file.as_mut() {
            let _ = writeln!(f, "{line}");
            let _ = f.flush();
        }
    }

    /// The most recent `n` rendered lines, oldest first.
    pub fn tail(&self, n: usize) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        inner.ring.iter().rev().take(n).rev().cloned().collect()
    }

    /// Total records appended over this handle's lifetime.
    pub fn len(&self) -> u64 {
        self.inner.lock().unwrap().seq
    }

    /// Whether no record has been appended yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The configured file path, if any.
    pub fn path(&self) -> Option<PathBuf> {
        self.inner.lock().unwrap().path.clone()
    }
}

/// The serving decision a replayed log arrives at for one matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplayedDecision {
    /// Serving implementation (stats-row convention), rendered as text.
    pub kernel: String,
    /// Intra-pool partition strategy.
    pub partition: String,
    /// Split row blocks (0 = unsplit).
    pub split_parts: u64,
    /// Whether split serving is vetoed.
    pub split_vetoed: bool,
}

/// Extract `"key":"string"` from one rendered line. Only used on lines
/// this module rendered itself, so the minimal scan (no escape handling
/// beyond what registry keys can contain) is sound.
fn str_field(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":\"");
    let start = line.find(&pat)? + pat.len();
    let end = line[start..].find('"')?;
    Some(line[start..start + end].to_string())
}

/// Extract `"key":<number>` from one rendered line.
fn num_field(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find([',', '}'])?;
    rest[..end].parse().ok()
}

/// Extract `"key":true|false` from one rendered line.
fn bool_field(line: &str, key: &str) -> Option<bool> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    Some(line[start..].starts_with("true"))
}

/// Replay rendered JSONL lines into the final serving decision per
/// matrix: because every record carries its post-state, the fold is
/// "last record per matrix wins". Lines that are not decision records
/// (blank, or hand-edited) are skipped.
pub fn replay<'a>(lines: impl IntoIterator<Item = &'a str>) -> HashMap<String, ReplayedDecision> {
    let mut out = HashMap::new();
    for line in lines {
        let Some(matrix) = str_field(line, "matrix") else { continue };
        let Some(kernel) = str_field(line, "kernel") else { continue };
        let Some(partition) = str_field(line, "partition") else { continue };
        let decision = ReplayedDecision {
            kernel,
            partition,
            split_parts: num_field(line, "split_parts").unwrap_or(0.0) as u64,
            split_vetoed: bool_field(line, "split_vetoed").unwrap_or(false),
        };
        out.insert(matrix, decision);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(event: DecisionEvent, matrix: &str, kernel: &str) -> DecisionRecord {
        DecisionRecord {
            event,
            matrix: matrix.into(),
            kernel: kernel.into(),
            partition: "even",
            split_parts: 0,
            split_vetoed: false,
            transform: kernel != "csr_seq",
            d_mat: 0.25,
            d_star: 3.1,
            serving_mean: Some(1.5e-6),
            rival_mean: None,
            rival_samples: 0,
            votes: 0,
            windows: 0,
            detail: "D_mat 0.250 < D* 3.100".into(),
        }
    }

    #[test]
    fn records_render_and_replay_to_the_last_state_per_matrix() {
        let log = DecisionLog::in_memory();
        log.record(&rec(DecisionEvent::Register, "a", "csr_seq"));
        log.record(&rec(DecisionEvent::Register, "b", "csr_seq"));
        log.record(&rec(DecisionEvent::Transform, "a", "ell_row_outer"));
        assert_eq!(log.len(), 3);
        let lines = log.tail(100);
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"event\":\"register\""));
        assert!(lines[2].contains("\"kernel\":\"ell_row_outer\""));

        let replayed = replay(lines.iter().map(String::as_str));
        assert_eq!(replayed["a"].kernel, "ell_row_outer");
        assert_eq!(replayed["b"].kernel, "csr_seq");
        assert_eq!(replayed["a"].partition, "even");
        assert!(!replayed["a"].split_vetoed);
        assert_eq!(replayed["a"].split_parts, 0);
    }

    #[test]
    fn tail_is_bounded_and_ordered() {
        let log = DecisionLog::in_memory();
        for i in 0..(RING_CAPACITY + 10) {
            log.record(&rec(DecisionEvent::Flip, &format!("m{i}"), "csr_seq"));
        }
        let lines = log.tail(5);
        assert_eq!(lines.len(), 5);
        // Oldest-first within the tail; the newest record is last.
        assert!(lines[4].contains(&format!("\"matrix\":\"m{}\"", RING_CAPACITY + 9)));
        assert_eq!(log.len(), (RING_CAPACITY + 10) as u64);
        assert_eq!(log.tail(usize::MAX).len(), RING_CAPACITY, "ring is bounded");
    }

    #[test]
    fn file_sink_appends_jsonl_that_replays_identically() {
        let path = std::env::temp_dir()
            .join(format!("spmv-at-decision-log-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let log = DecisionLog::to_path(&path).unwrap();
            log.record(&rec(DecisionEvent::Register, "a", "csr_seq"));
            log.record(&rec(DecisionEvent::Flip, "a", "ell_row_inner"));
            assert_eq!(log.path().as_deref(), Some(path.as_path()));
        }
        // Reopening appends rather than truncating.
        {
            let log = DecisionLog::to_path(&path).unwrap();
            log.record(&rec(DecisionEvent::Replan, "a", "csr_seq"));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        let replayed = replay(text.lines());
        assert_eq!(replayed["a"].kernel, "csr_seq", "the last record wins");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replay_skips_foreign_lines_and_handles_nulls() {
        let mut r = rec(DecisionEvent::Register, "x", "csr_seq");
        r.serving_mean = None;
        r.d_star = f64::NAN; // renders as null
        let log = DecisionLog::in_memory();
        log.record(&r);
        let mut lines = log.tail(10);
        lines.insert(0, "not json".to_string());
        lines.push(String::new());
        let replayed = replay(lines.iter().map(String::as_str));
        assert_eq!(replayed.len(), 1);
        assert_eq!(replayed["x"].kernel, "csr_seq");
        assert!(log.tail(10)[0].contains("\"d_star\":null"));
    }
}
