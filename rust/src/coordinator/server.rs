//! Channel-served request loop around [`Coordinator`].
//!
//! The environment has no async runtime, so the serving layer is a plain
//! worker thread draining an MPSC queue — the same request/response
//! contract a tokio service would expose, without the dependency.
//! [`Client`] is cheap to clone; every request carries its own response
//! channel (rendezvous style), so concurrent clients interleave safely
//! and back-pressure falls out of the bounded queue.

use super::{Coordinator, EntryStats};
use crate::formats::Csr;
use crate::solver::{SolveStats, SolverOptions};
use crate::{Result, Value};
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Solver selection for [`Request::Solve`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Conjugate Gradient (SPD systems).
    Cg,
    /// BiCGStab (general systems).
    BiCgStab,
    /// GMRES(30).
    Gmres,
    /// Damped Jacobi (ω = 1).
    Jacobi,
    /// Jacobi-preconditioned CG.
    Pcg,
}

impl SolverKind {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cg" => Some(Self::Cg),
            "bicgstab" | "bicg" => Some(Self::BiCgStab),
            "gmres" => Some(Self::Gmres),
            "jacobi" => Some(Self::Jacobi),
            "pcg" => Some(Self::Pcg),
            _ => None,
        }
    }
}

/// Requests the server accepts.
pub enum Request {
    /// Register a matrix under a name.
    Register {
        /// Registry key.
        name: String,
        /// The matrix (CRS).
        csr: Csr,
        /// Response: stats row at registration.
        resp: mpsc::Sender<Result<EntryStats>>,
    },
    /// `y = A·x`.
    Spmv {
        /// Registry key.
        name: String,
        /// Input vector.
        x: Vec<Value>,
        /// Response: y.
        resp: mpsc::Sender<Result<Vec<Value>>>,
    },
    /// Solve `A·x = b` with the AT-routed operator.
    Solve {
        /// Registry key.
        name: String,
        /// Right-hand side.
        b: Vec<Value>,
        /// Solver to use.
        solver: SolverKind,
        /// Options.
        opts: SolverOptions,
        /// Response: (solution, stats).
        resp: mpsc::Sender<Result<(Vec<Value>, SolveStats)>>,
    },
    /// Batched `Y = A·X` (multiple right-hand sides, one decision).
    SpmvBatch {
        /// Registry key.
        name: String,
        /// Input vectors.
        xs: Vec<Vec<Value>>,
        /// Response: one output per input.
        resp: mpsc::Sender<Result<Vec<Vec<Value>>>>,
    },
    /// All stats rows.
    Stats {
        /// Response channel.
        resp: mpsc::Sender<Vec<EntryStats>>,
    },
    /// Drop a matrix.
    Evict {
        /// Registry key.
        name: String,
        /// Response: whether it existed.
        resp: mpsc::Sender<bool>,
    },
    /// Stop the server loop.
    Shutdown,
}

/// Cloneable handle to a running [`Server`].
#[derive(Clone)]
pub struct Client {
    tx: mpsc::SyncSender<Request>,
}

impl Client {
    /// Register a matrix.
    pub fn register(&self, name: &str, csr: Csr) -> Result<EntryStats> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::Register { name: name.into(), csr, resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?
    }

    /// `y = A·x`.
    pub fn spmv(&self, name: &str, x: Vec<Value>) -> Result<Vec<Value>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::Spmv { name: name.into(), x, resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?
    }

    /// Solve `A·x = b`.
    pub fn solve(
        &self,
        name: &str,
        b: Vec<Value>,
        solver: SolverKind,
        opts: SolverOptions,
    ) -> Result<(Vec<Value>, SolveStats)> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::Solve { name: name.into(), b, solver, opts, resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?
    }

    /// Batched `Y = A·X`.
    pub fn spmv_batch(&self, name: &str, xs: Vec<Vec<Value>>) -> Result<Vec<Vec<Value>>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::SpmvBatch { name: name.into(), xs, resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?
    }

    /// Fetch all stats rows.
    pub fn stats(&self) -> Result<Vec<EntryStats>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::Stats { resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))
    }

    /// Evict a matrix.
    pub fn evict(&self, name: &str) -> Result<bool> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::Evict { name: name.into(), resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))
    }
}

/// The worker-thread server owning a [`Coordinator`].
pub struct Server {
    tx: mpsc::SyncSender<Request>,
    handle: Option<JoinHandle<Coordinator>>,
}

/// An adapter letting the solvers run against a coordinator-registered
/// matrix (each `apply` is a routed SpMV).
struct CoordOp<'c> {
    coord: &'c mut Coordinator,
    name: String,
    n: usize,
}

impl crate::solver::SpmvOp for CoordOp<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn apply(&mut self, x: &[Value], y: &mut [Value]) -> Result<()> {
        let out = self.coord.spmv(&self.name, x)?;
        y.copy_from_slice(&out);
        Ok(())
    }

    fn diagonal(&self) -> Result<Vec<Value>> {
        let csr = &self
            .coord
            .entries
            .get(&self.name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix"))?
            .csr;
        crate::solver::SpmvOp::diagonal(csr)
    }
}

impl Server {
    /// Spawn the server with a bounded queue of `depth` requests.
    pub fn spawn(coord: Coordinator, depth: usize) -> (Self, Client) {
        let (tx, rx) = mpsc::sync_channel::<Request>(depth.max(1));
        let handle = std::thread::spawn(move || {
            let mut coord = coord;
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Register { name, csr, resp } => {
                        let _ = resp.send(coord.register(&name, csr));
                    }
                    Request::Spmv { name, x, resp } => {
                        let _ = resp.send(coord.spmv(&name, &x));
                    }
                    Request::Solve { name, b, solver, opts, resp } => {
                        let _ = resp.send(Self::do_solve(&mut coord, &name, &b, solver, &opts));
                    }
                    Request::SpmvBatch { name, xs, resp } => {
                        let _ = resp.send(coord.spmv_batch(&name, &xs));
                    }
                    Request::Stats { resp } => {
                        let _ = resp.send(coord.stats());
                    }
                    Request::Evict { name, resp } => {
                        let _ = resp.send(coord.evict(&name));
                    }
                    Request::Shutdown => break,
                }
            }
            coord
        });
        let client = Client { tx: tx.clone() };
        (Self { tx, handle: Some(handle) }, client)
    }

    fn do_solve(
        coord: &mut Coordinator,
        name: &str,
        b: &[Value],
        solver: SolverKind,
        opts: &SolverOptions,
    ) -> Result<(Vec<Value>, SolveStats)> {
        use crate::formats::SparseMatrix as _;
        let n = coord
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix '{name}'"))?
            .csr
            .n_rows();
        anyhow::ensure!(b.len() == n, "b length {} != n {}", b.len(), n);
        let mut op = CoordOp { coord, name: name.to_string(), n };
        let mut x = vec![0.0; n];
        let stats = match solver {
            SolverKind::Cg => crate::solver::cg(&mut op, b, &mut x, opts)?,
            SolverKind::BiCgStab => crate::solver::bicgstab(&mut op, b, &mut x, opts)?,
            SolverKind::Gmres => crate::solver::gmres(&mut op, b, &mut x, 30, opts)?,
            SolverKind::Jacobi => crate::solver::jacobi(&mut op, b, &mut x, 1.0, opts)?,
            SolverKind::Pcg => crate::solver::pcg(&mut op, b, &mut x, opts)?,
        };
        Ok((x, stats))
    }

    /// Stop the loop and recover the coordinator (with all its state).
    pub fn shutdown(mut self) -> Coordinator {
        let _ = self.tx.send(Request::Shutdown);
        self.handle
            .take()
            .expect("shutdown called once")
            .join()
            .expect("server thread panicked")
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = self.tx.send(Request::Shutdown);
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::online::TuningData;
    use crate::coordinator::CoordinatorConfig;
    use crate::matrixgen::make_spd;
    use crate::rng::Rng;
    use crate::spmv::Implementation;

    fn server() -> (Server, Client) {
        let tuning = TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        };
        let mut cfg = CoordinatorConfig::new(tuning);
        cfg.threads = 2;
        Server::spawn(Coordinator::new(cfg), 16)
    }

    #[test]
    fn request_response_roundtrip() {
        let (srv, client) = server();
        let mut rng = Rng::new(1);
        let a = crate::matrixgen::random_csr(&mut rng, 30, 30, 0.2);
        let mut want = vec![0.0; 30];
        use crate::formats::SparseMatrix as _;
        let x: Vec<Value> = (0..30).map(|i| (i as f64).sin()).collect();
        a.spmv(&x, &mut want);

        client.register("m", a).unwrap();
        let y = client.spmv("m", x).unwrap();
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
        let stats = client.stats().unwrap();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].calls, 1);
        let coord = srv.shutdown();
        assert_eq!(coord.names(), vec!["m"]);
    }

    #[test]
    fn solve_through_server() {
        let (_srv, client) = server();
        let mut rng = Rng::new(2);
        let a = make_spd(&crate::matrixgen::random_csr(&mut rng, 60, 60, 0.08));
        let x_true: Vec<Value> = (0..60).map(|i| ((i + 1) as f64 * 0.17).sin()).collect();
        let mut b = vec![0.0; 60];
        use crate::formats::SparseMatrix as _;
        a.spmv(&x_true, &mut b);
        client.register("sys", a).unwrap();
        let (x, stats) = client
            .solve("sys", b, SolverKind::Cg, SolverOptions::default())
            .unwrap();
        assert!(stats.converged);
        let err: f64 = x.iter().zip(&x_true).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(err < 1e-5, "error {err}");
        // The coordinator served every solver SpMV.
        let rows = client.stats().unwrap();
        assert_eq!(rows[0].calls as usize, stats.spmv_calls);
    }

    #[test]
    fn concurrent_clients_interleave() {
        let (_srv, client) = server();
        client.register("id", crate::formats::Csr::identity(16)).unwrap();
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..25 {
                    let x = vec![(t * 100 + k) as f64; 16];
                    let y = c.spmv("id", x.clone()).unwrap();
                    assert_eq!(y, x, "identity must echo");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(client.stats().unwrap()[0].calls, 100);
    }

    #[test]
    fn errors_propagate_to_clients() {
        let (_srv, client) = server();
        assert!(client.spmv("ghost", vec![1.0]).is_err());
        assert!(client
            .solve("ghost", vec![1.0], SolverKind::Cg, SolverOptions::default())
            .is_err());
        assert!(!client.evict("ghost").unwrap());
    }

    #[test]
    fn solver_kind_parse() {
        assert_eq!(SolverKind::parse("cg"), Some(SolverKind::Cg));
        assert_eq!(SolverKind::parse("BICGSTAB"), Some(SolverKind::BiCgStab));
        assert_eq!(SolverKind::parse("gmres"), Some(SolverKind::Gmres));
        assert_eq!(SolverKind::parse("jacobi"), Some(SolverKind::Jacobi));
        assert_eq!(SolverKind::parse("nope"), None);
    }
}
