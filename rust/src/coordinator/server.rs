//! Channel-served request loop around [`Coordinator`] — optionally one
//! loop **per pool shard**.
//!
//! The environment has no async runtime, so the serving layer is a plain
//! worker thread draining an MPSC queue — the same request/response
//! contract a tokio service would expose, without the dependency.
//! [`Client`] is cheap to clone; every request carries its own response
//! channel (rendezvous style), so concurrent clients interleave safely
//! and back-pressure falls out of the bounded queue.
//!
//! [`Server::spawn`] runs a single loop over one coordinator.
//! [`Server::spawn_sharded`] spawns one loop per configured shard, every
//! loop sharing ONE set of socket-pinned [`crate::spmv::ParPool`]s
//! through its own [`Coordinator`] over the full multi-shard planner.
//! The [`Client`] routes every keyed request with the same
//! [`shards::route_key`] hash the coordinators use internally, so loop
//! `i`'s matrices plan on pool `i` (placement is per-socket exactly as
//! before) while batched SpMM against matrices on different shards
//! executes concurrently instead of serialising on one pool's job slot.
//! Because every loop sees all the shards, automatic cross-shard
//! splitting ([`super::SplitThreshold`]) engages behind the sharded
//! client too — there is exactly one serving shape; `Server::spawn` is
//! just its one-loop special case. `Stats` broadcasts and merges —
//! split-served entries report their `split_parts`/`split_calls` like
//! any other row, and `shutdown` / `shutdown_all` hand back the
//! coordinators with their cached [`super::SplitPlan`]s intact.
//!
//! [`spawn_dispatch`] is the one dispatch primitive every service thread
//! in the crate goes through (these loops, and the XLA artifact service
//! in [`crate::runtime`]): it constructs the service state *inside* the
//! thread via an `init` closure — required for non-`Send` state like the
//! XLA runtime — reports the init result synchronously, then drains the
//! bounded queue until the step function signals shutdown.

use super::shards::{self, PlanShards, ShardedPlanner};
use super::{Coordinator, CoordinatorConfig, EntryStats};
use crate::formats::Csr;
use crate::solver::{SolveStats, SolverOptions};
use crate::{Result, Value};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

/// Spawn one service thread over a bounded queue — the crate's single
/// dispatch primitive, shared by the request loops here and by
/// [`crate::runtime::XlaService`].
///
/// The service state is constructed *inside* the thread by `init` (so
/// non-`Send` state — the XLA runtime holds `Rc` internals — never
/// crosses a thread boundary), and the init result is reported back
/// synchronously: a failing `init` makes this function return its error
/// with the thread already joined. After init, the thread drains the
/// queue, handing each message to `step` until it returns `false` (the
/// service's shutdown message) or every sender is dropped. `finish`
/// consumes the state in-thread and produces the join value (the
/// request loops hand their [`Coordinator`] back this way; services with
/// non-`Send` state return `()`).
pub fn spawn_dispatch<M, S, R>(
    name: &str,
    depth: usize,
    init: impl FnOnce() -> Result<S> + Send + 'static,
    mut step: impl FnMut(&mut S, M) -> bool + Send + 'static,
    finish: impl FnOnce(S) -> R + Send + 'static,
) -> Result<(mpsc::SyncSender<M>, JoinHandle<Option<R>>)>
where
    M: Send + 'static,
    S: 'static,
    R: Send + 'static,
{
    let (tx, rx) = mpsc::sync_channel::<M>(depth.max(1));
    let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
    let handle = std::thread::Builder::new()
        .name(name.to_string())
        .spawn(move || {
            let mut state = match init() {
                Ok(s) => {
                    let _ = init_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return None;
                }
            };
            while let Ok(msg) = rx.recv() {
                if !step(&mut state, msg) {
                    break;
                }
            }
            Some(finish(state))
        })
        .expect("spawn service thread");
    match init_rx.recv() {
        Ok(Ok(())) => Ok((tx, handle)),
        Ok(Err(e)) => {
            let _ = handle.join();
            Err(e)
        }
        Err(_) => {
            let _ = handle.join();
            Err(anyhow::anyhow!("service thread died during initialization"))
        }
    }
}

/// Solver selection for [`Request::Solve`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Conjugate Gradient (SPD systems).
    Cg,
    /// BiCGStab (general systems).
    BiCgStab,
    /// GMRES(30).
    Gmres,
    /// Damped Jacobi (ω = 1).
    Jacobi,
    /// Jacobi-preconditioned CG.
    Pcg,
}

impl SolverKind {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cg" => Some(Self::Cg),
            "bicgstab" | "bicg" => Some(Self::BiCgStab),
            "gmres" => Some(Self::Gmres),
            "jacobi" => Some(Self::Jacobi),
            "pcg" => Some(Self::Pcg),
            _ => None,
        }
    }
}

/// Requests the server accepts.
pub enum Request {
    /// Register a matrix under a name.
    Register {
        /// Registry key.
        name: String,
        /// The matrix (CRS).
        csr: Csr,
        /// Response: stats row at registration.
        resp: mpsc::Sender<Result<EntryStats>>,
    },
    /// `y = A·x`.
    Spmv {
        /// Registry key.
        name: String,
        /// Input vector.
        x: Vec<Value>,
        /// Response: y.
        resp: mpsc::Sender<Result<Vec<Value>>>,
    },
    /// Solve `A·x = b` with the AT-routed operator.
    Solve {
        /// Registry key.
        name: String,
        /// Right-hand side.
        b: Vec<Value>,
        /// Solver to use.
        solver: SolverKind,
        /// Options.
        opts: SolverOptions,
        /// Response: (solution, stats).
        resp: mpsc::Sender<Result<(Vec<Value>, SolveStats)>>,
    },
    /// Batched `Y = A·X` (multiple right-hand sides, one decision).
    SpmvBatch {
        /// Registry key.
        name: String,
        /// Input vectors.
        xs: Vec<Vec<Value>>,
        /// Response: one output per input.
        resp: mpsc::Sender<Result<Vec<Vec<Value>>>>,
    },
    /// Force a re-decision for one matrix (the adaptive loop's manual
    /// override; also rebuilds/swaps the serving plan when appropriate).
    Replan {
        /// Registry key.
        name: String,
        /// Response: stats row after the re-decision.
        resp: mpsc::Sender<Result<EntryStats>>,
    },
    /// All stats rows.
    Stats {
        /// Response channel.
        resp: mpsc::Sender<Vec<EntryStats>>,
    },
    /// Drop a matrix.
    Evict {
        /// Registry key.
        name: String,
        /// Response: whether it existed.
        resp: mpsc::Sender<bool>,
    },
    /// Stop the server loop.
    Shutdown,
}

/// Cloneable handle to a running [`Server`]: one request queue per shard
/// loop, keyed requests routed by [`shards::route_key`].
#[derive(Clone)]
pub struct Client {
    txs: Vec<mpsc::SyncSender<Request>>,
}

impl Client {
    /// The shard loop serving `name`.
    fn tx_for(&self, name: &str) -> &mpsc::SyncSender<Request> {
        &self.txs[shards::route_key(name, self.txs.len()) as usize]
    }

    /// Number of shard loops behind this client.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Register a matrix (routed to its shard).
    pub fn register(&self, name: &str, csr: Csr) -> Result<EntryStats> {
        let (resp, rx) = mpsc::channel();
        self.tx_for(name)
            .send(Request::Register { name: name.into(), csr, resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?
    }

    /// `y = A·x`.
    pub fn spmv(&self, name: &str, x: Vec<Value>) -> Result<Vec<Value>> {
        let (resp, rx) = mpsc::channel();
        self.tx_for(name)
            .send(Request::Spmv { name: name.into(), x, resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?
    }

    /// Solve `A·x = b`.
    pub fn solve(
        &self,
        name: &str,
        b: Vec<Value>,
        solver: SolverKind,
        opts: SolverOptions,
    ) -> Result<(Vec<Value>, SolveStats)> {
        let (resp, rx) = mpsc::channel();
        self.tx_for(name)
            .send(Request::Solve { name: name.into(), b, solver, opts, resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?
    }

    /// Batched `Y = A·X` (tiled SpMM on the matrix's shard).
    pub fn spmv_batch(&self, name: &str, xs: Vec<Vec<Value>>) -> Result<Vec<Vec<Value>>> {
        let (resp, rx) = mpsc::channel();
        self.tx_for(name)
            .send(Request::SpmvBatch { name: name.into(), xs, resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?
    }

    /// Force a re-decision for a matrix (routed to its shard).
    pub fn replan(&self, name: &str) -> Result<EntryStats> {
        let (resp, rx) = mpsc::channel();
        self.tx_for(name)
            .send(Request::Replan { name: name.into(), resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?
    }

    /// Fetch all stats rows (broadcast to every shard, merged and sorted
    /// by name).
    pub fn stats(&self) -> Result<Vec<EntryStats>> {
        let mut rows = Vec::new();
        for tx in &self.txs {
            let (resp, rx) = mpsc::channel();
            tx.send(Request::Stats { resp })
                .map_err(|_| anyhow::anyhow!("server stopped"))?;
            rows.extend(rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))?);
        }
        rows.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(rows)
    }

    /// Evict a matrix (routed to its shard).
    pub fn evict(&self, name: &str) -> Result<bool> {
        let (resp, rx) = mpsc::channel();
        self.tx_for(name)
            .send(Request::Evict { name: name.into(), resp })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped response"))
    }
}

/// The worker-thread server: one loop per shard, each owning a
/// [`Coordinator`].
pub struct Server {
    txs: Vec<mpsc::SyncSender<Request>>,
    handles: Vec<JoinHandle<Option<Coordinator>>>,
}

/// An adapter letting the solvers run against a coordinator-registered
/// matrix (each `apply` is a routed SpMV).
struct CoordOp<'c> {
    coord: &'c mut Coordinator,
    name: String,
    n: usize,
}

impl crate::solver::SpmvOp for CoordOp<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn apply(&mut self, x: &[Value], y: &mut [Value]) -> Result<()> {
        let out = self.coord.spmv(&self.name, x)?;
        y.copy_from_slice(&out);
        Ok(())
    }

    fn diagonal(&self) -> Result<Vec<Value>> {
        let csr = &self
            .coord
            .entries
            .get(&self.name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix"))?
            .csr;
        crate::solver::SpmvOp::diagonal(csr.as_ref())
    }
}

impl Server {
    /// Spawn a single request loop over one coordinator with a bounded
    /// queue of `depth` requests.
    pub fn spawn(coord: Coordinator, depth: usize) -> (Self, Client) {
        Self::spawn_loops(vec![coord], depth)
    }

    /// Spawn one request loop per configured shard: the socket-pinned
    /// pools (one per shard, clamped to the thread budget — see
    /// [`shards::shard_thread_counts`], pool `i` pinned to socket
    /// `i mod sockets` of the detected [`crate::machine::Topology`]) are
    /// built **once** and shared by every loop, each loop owning a
    /// [`Coordinator`] over the full multi-shard planner. The client's
    /// [`shards::route_key`] hash and the coordinator's internal
    /// `shard_of` agree (same hash, same shard count), so loop `i`'s
    /// matrices plan — and adaptively re-plan — on pool `i`, first-
    /// touching their arrays on that socket, while oversized matrices
    /// past the [`super::SplitThreshold`] split across ALL the shared
    /// pools from whichever loop serves them. Requests for matrices on
    /// different shards execute concurrently. The request-loop thread
    /// itself pins to its home shard's socket, so the `Vec`s a request
    /// materialises (inputs, outputs) are local too.
    pub fn spawn_sharded(cfg: CoordinatorConfig, depth: usize) -> (Self, Client) {
        let topo = crate::machine::Topology::detect();
        let counts = shards::shard_thread_counts(cfg.threads, cfg.shards);
        shards::warn_if_clamped(cfg.threads, cfg.shards, counts.len());
        let pools: Vec<Arc<crate::spmv::pool::ParPool>> = counts
            .into_iter()
            .enumerate()
            .map(|(i, threads)| {
                Arc::new(crate::spmv::pool::ParPool::new_pinned(threads, topo.shard_cpus(i)))
            })
            .collect();
        let coords: Vec<Coordinator> = (0..pools.len())
            .map(|_| {
                let planner = ShardedPlanner::new(
                    cfg.tuning.clone(),
                    cfg.policy,
                    PlanShards::from_pools(pools.clone()),
                );
                Coordinator::with_planner(cfg.clone(), planner)
            })
            .collect();
        Self::spawn_loops(coords, depth)
    }

    fn spawn_loops(coords: Vec<Coordinator>, depth: usize) -> (Self, Client) {
        let n_loops = coords.len();
        let mut txs = Vec::with_capacity(n_loops);
        let mut handles = Vec::with_capacity(n_loops);
        for (i, coord) in coords.into_iter().enumerate() {
            // Join the home shard's socket so request-side allocations
            // (the response vectors every SpMV materialises) first-touch
            // locally — meaningful only when loop count == shard count,
            // i.e. the client's hash sends shard i's keys to loop i. A
            // single loop over a multi-shard planner serves every socket
            // from one thread; pinning it to shard 0's socket would
            // mislocate all the others.
            let affinity: Option<Vec<usize>> = if coord.planner().len() == n_loops {
                coord.planner().shards().pool(i).affinity().map(<[usize]>::to_vec)
            } else {
                None
            };
            let (tx, handle) = spawn_dispatch(
                &format!("spmv-serve-{i}"),
                depth,
                move || {
                    if let Some(cpus) = &affinity {
                        crate::machine::topology::pin_current_thread(cpus);
                    }
                    Ok(coord)
                },
                |coord, req| Self::dispatch(coord, req),
                |coord| coord,
            )
            .expect("serve-loop init is infallible");
            txs.push(tx);
            handles.push(handle);
        }
        let client = Client { txs: txs.clone() };
        (Self { txs, handles }, client)
    }

    /// Handle one request against the loop's coordinator; `false` stops
    /// the loop ([`Request::Shutdown`]).
    fn dispatch(coord: &mut Coordinator, req: Request) -> bool {
        match req {
            Request::Register { name, csr, resp } => {
                let _ = resp.send(coord.register(&name, csr));
            }
            Request::Spmv { name, x, resp } => {
                let _ = resp.send(coord.spmv(&name, &x));
            }
            Request::Solve { name, b, solver, opts, resp } => {
                let _ = resp.send(Self::do_solve(coord, &name, &b, solver, &opts));
            }
            Request::SpmvBatch { name, xs, resp } => {
                let _ = resp.send(coord.spmv_batch(&name, &xs));
            }
            Request::Replan { name, resp } => {
                let _ = resp.send(coord.replan(&name));
            }
            Request::Stats { resp } => {
                let _ = resp.send(coord.stats());
            }
            Request::Evict { name, resp } => {
                let _ = resp.send(coord.evict(&name));
            }
            Request::Shutdown => return false,
        }
        true
    }

    fn do_solve(
        coord: &mut Coordinator,
        name: &str,
        b: &[Value],
        solver: SolverKind,
        opts: &SolverOptions,
    ) -> Result<(Vec<Value>, SolveStats)> {
        use crate::formats::SparseMatrix as _;
        let n = coord
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix '{name}'"))?
            .csr
            .n_rows();
        anyhow::ensure!(b.len() == n, "b length {} != n {}", b.len(), n);
        let mut x = vec![0.0; n];
        let stats = match solver {
            SolverKind::Cg => {
                let mut op = CoordOp { coord, name: name.to_string(), n };
                crate::solver::cg(&mut op, b, &mut x, opts)?
            }
            SolverKind::BiCgStab => {
                let mut op = CoordOp { coord, name: name.to_string(), n };
                crate::solver::bicgstab(&mut op, b, &mut x, opts)?
            }
            SolverKind::Gmres => {
                let mut op = CoordOp { coord, name: name.to_string(), n };
                crate::solver::gmres(&mut op, b, &mut x, 30, opts)?
            }
            SolverKind::Jacobi => {
                let mut op = CoordOp { coord, name: name.to_string(), n };
                crate::solver::jacobi(&mut op, b, &mut x, 1.0, opts)?
            }
            SolverKind::Pcg => {
                // Take the cached preconditioner out of the entry (built
                // on first use from `--precond`/`SPMV_AT_PRECOND`), so
                // the solve can drive SpMV through `&mut Coordinator`
                // while applying it; put it back with the call credit
                // whether the solve converged or errored.
                let mut m = coord.take_preconditioner(name)?;
                let mut op = CoordOp { coord: &mut *coord, name: name.to_string(), n };
                let solved = crate::solver::pcg_with(&mut op, m.as_mut(), b, &mut x, opts);
                drop(op);
                let calls = solved.as_ref().map_or(0, |s| s.precond_calls as u64);
                coord.put_preconditioner(name, m, calls);
                solved?
            }
        };
        Ok((x, stats))
    }

    /// Stop a single-loop server and recover its coordinator (with all
    /// its state). Sharded servers use [`Server::shutdown_all`].
    ///
    /// # Panics
    /// Panics if this server runs more than one shard loop.
    pub fn shutdown(self) -> Coordinator {
        let mut coords = self.shutdown_all();
        assert_eq!(coords.len(), 1, "sharded server: use shutdown_all");
        coords.pop().expect("one coordinator")
    }

    /// Stop every shard loop and recover the coordinators, in shard order.
    pub fn shutdown_all(mut self) -> Vec<Coordinator> {
        for tx in &self.txs {
            let _ = tx.send(Request::Shutdown);
        }
        self.handles
            .drain(..)
            .map(|h| h.join().expect("server thread panicked").expect("serve loop initialised"))
            .collect()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::online::TuningData;
    use crate::coordinator::CoordinatorConfig;
    use crate::matrixgen::make_spd;
    use crate::rng::Rng;
    use crate::spmv::Implementation;

    fn server() -> (Server, Client) {
        let tuning = TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        };
        let mut cfg = CoordinatorConfig::new(tuning);
        cfg.threads = 2;
        Server::spawn(Coordinator::new(cfg), 16)
    }

    #[test]
    fn request_response_roundtrip() {
        let (srv, client) = server();
        let mut rng = Rng::new(1);
        let a = crate::matrixgen::random_csr(&mut rng, 30, 30, 0.2);
        let mut want = vec![0.0; 30];
        use crate::formats::SparseMatrix as _;
        let x: Vec<Value> = (0..30).map(|i| (i as f64).sin()).collect();
        a.spmv(&x, &mut want);

        client.register("m", a).unwrap();
        let y = client.spmv("m", x).unwrap();
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
        let stats = client.stats().unwrap();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].calls, 1);
        let coord = srv.shutdown();
        assert_eq!(coord.names(), vec!["m"]);
    }

    #[test]
    fn solve_through_server() {
        let (_srv, client) = server();
        let mut rng = Rng::new(2);
        let a = make_spd(&crate::matrixgen::random_csr(&mut rng, 60, 60, 0.08));
        let x_true: Vec<Value> = (0..60).map(|i| ((i + 1) as f64 * 0.17).sin()).collect();
        let mut b = vec![0.0; 60];
        use crate::formats::SparseMatrix as _;
        a.spmv(&x_true, &mut b);
        client.register("sys", a).unwrap();
        let (x, stats) = client
            .solve("sys", b, SolverKind::Cg, SolverOptions::default())
            .unwrap();
        assert!(stats.converged);
        let err: f64 = x.iter().zip(&x_true).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(err < 1e-5, "error {err}");
        // The coordinator served every solver SpMV.
        let rows = client.stats().unwrap();
        assert_eq!(rows[0].calls as usize, stats.spmv_calls);
    }

    #[test]
    fn pcg_solve_caches_the_preconditioner_across_solves() {
        let (srv, client) = server();
        let mut rng = Rng::new(7);
        let a = make_spd(&crate::matrixgen::random_csr(&mut rng, 50, 50, 0.1));
        let x_true: Vec<Value> = (0..50).map(|i| ((i + 1) as f64 * 0.11).cos()).collect();
        let mut b = vec![0.0; 50];
        use crate::formats::SparseMatrix as _;
        a.spmv(&x_true, &mut b);
        client.register("sys", a).unwrap();
        let (_, s1) = client
            .solve("sys", b.clone(), SolverKind::Pcg, SolverOptions::default())
            .unwrap();
        assert!(s1.converged);
        assert!(s1.precond_calls > 0);
        let (_, s2) = client
            .solve("sys", b, SolverKind::Pcg, SolverOptions::default())
            .unwrap();
        let rows = client.stats().unwrap();
        // Both solves' applications were credited to the cached instance.
        assert_eq!(rows[0].precond_calls as usize, s1.precond_calls + s2.precond_calls);
        // The kind follows the env truth (`SPMV_AT_PRECOND`, default
        // Jacobi) — CI's symgs leg runs this very test under symgs.
        assert_eq!(
            rows[0].precond,
            Some(crate::precond::configured_precond().name())
        );
        let coord = srv.shutdown();
        let entry = &coord.entries["sys"];
        assert!(entry.precond.is_some(), "preconditioner stays cached");
    }

    #[test]
    fn concurrent_clients_interleave() {
        let (_srv, client) = server();
        client.register("id", crate::formats::Csr::identity(16)).unwrap();
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..25 {
                    let x = vec![(t * 100 + k) as f64; 16];
                    let y = c.spmv("id", x.clone()).unwrap();
                    assert_eq!(y, x, "identity must echo");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(client.stats().unwrap()[0].calls, 100);
    }

    #[test]
    fn errors_propagate_to_clients() {
        let (_srv, client) = server();
        assert!(client.spmv("ghost", vec![1.0]).is_err());
        assert!(client.replan("ghost").is_err());
        assert!(client
            .solve("ghost", vec![1.0], SolverKind::Cg, SolverOptions::default())
            .is_err());
        assert!(!client.evict("ghost").unwrap());
    }

    #[test]
    fn sharded_server_routes_and_serves_concurrently() {
        let tuning = TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        };
        let mut cfg = CoordinatorConfig::new(tuning);
        cfg.threads = 2;
        cfg.shards = 2;
        let (srv, client) = Server::spawn_sharded(cfg, 16);
        assert_eq!(client.shards(), 2);
        // Find two names on different shards.
        let names: Vec<String> = (0..16).map(|i| format!("m-{i}")).collect();
        let a = names
            .iter()
            .find(|n| crate::coordinator::shards::route_key(n, 2) == 0)
            .unwrap()
            .clone();
        let b = names
            .iter()
            .find(|n| crate::coordinator::shards::route_key(n, 2) == 1)
            .unwrap()
            .clone();
        let mut rng = Rng::new(5);
        let ma = crate::matrixgen::random_csr(&mut rng, 24, 24, 0.2);
        let mb = crate::matrixgen::random_csr(&mut rng, 24, 24, 0.2);
        client.register(&a, ma.clone()).unwrap();
        client.register(&b, mb.clone()).unwrap();

        // Concurrent batched SpMM on both matrices from two client threads.
        let mut handles = Vec::new();
        for (name, m) in [(a.clone(), ma), (b.clone(), mb)] {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                use crate::formats::SparseMatrix as _;
                let xs: Vec<Vec<Value>> = (0..8)
                    .map(|k| (0..24).map(|i| ((i + k) as f64 * 0.3).sin()).collect())
                    .collect();
                for _ in 0..10 {
                    let ys = c.spmv_batch(&name, xs.clone()).unwrap();
                    for (x, y) in xs.iter().zip(&ys) {
                        let mut want = vec![0.0; 24];
                        m.spmv(x, &mut want);
                        for (g, w) in y.iter().zip(&want) {
                            assert!((g - w).abs() < 1e-9, "{name}");
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Stats merge across shards, sorted by name.
        let rows = client.stats().unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.windows(2).all(|w| w[0].name <= w[1].name));
        assert!(rows.iter().all(|r| r.calls == 80));
        // Both shard coordinators come back, each holding its matrix.
        let coords = srv.shutdown_all();
        assert_eq!(coords.len(), 2);
        let total: usize = coords.iter().map(|c| c.names().len()).sum();
        assert_eq!(total, 2);
        assert!(coords[0].names() != coords[1].names());
    }

    #[test]
    fn sharded_loops_auto_split_oversized_entries() {
        // The unified serving loop: every `spawn_sharded` loop shares
        // the full N-shard planner, so `SplitThreshold` engages behind
        // the sharded client too — the PR-5 trade-off (splits only in
        // the single-loop shape) is gone.
        use crate::formats::SparseMatrix as _;
        let tuning = TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowInner,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        };
        let mut cfg = CoordinatorConfig::new(tuning);
        cfg.threads = 2;
        cfg.shards = 2;
        cfg.adaptive.enabled = false;
        cfg.split = crate::coordinator::SplitThreshold::Rows(32);
        let (srv, client) = Server::spawn_sharded(cfg, 16);
        assert_eq!(client.shards(), 2);
        let mut rng = Rng::new(11);
        let a = crate::matrixgen::random_csr(&mut rng, 64, 64, 0.1);
        client.register("big", a.clone()).unwrap();
        let xs: Vec<Vec<Value>> = (0..4)
            .map(|j| (0..64).map(|i| ((i + j) as f64 * 0.2).sin()).collect())
            .collect();
        let ys = client.spmv_batch("big", xs.clone()).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let mut want = vec![0.0; 64];
            a.spmv(x, &mut want);
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9);
            }
        }
        // A small matrix keeps serving unsplit alongside it, wherever
        // its key routes.
        client.register("small", crate::formats::Csr::identity(8)).unwrap();
        assert_eq!(client.spmv("small", vec![3.0; 8]).unwrap(), vec![3.0; 8]);
        let rows = client.stats().unwrap();
        let big = rows.iter().find(|r| r.name == "big").unwrap();
        assert_eq!(big.split_parts, 2, "splits engage behind the sharded client");
        assert_eq!(big.split_calls, 1);
        let small = rows.iter().find(|r| r.name == "small").unwrap();
        assert_eq!((small.split_parts, small.split_calls), (0, 0));
        srv.shutdown_all();
    }

    #[test]
    fn single_loop_server_serves_split_entries_and_reports_them() {
        use crate::formats::SparseMatrix as _;
        let tuning = TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowInner,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        };
        let mut cfg = CoordinatorConfig::new(tuning);
        cfg.threads = 2;
        cfg.shards = 2;
        cfg.split = crate::coordinator::SplitThreshold::Rows(32);
        // One loop over a multi-shard coordinator (the degenerate
        // `Server::spawn` case of the unified serving shape): splitting
        // engages here exactly as it does behind the sharded client.
        let (srv, client) = Server::spawn(Coordinator::new(cfg), 16);
        let mut rng = Rng::new(9);
        let a = crate::matrixgen::random_csr(&mut rng, 64, 64, 0.1);
        client.register("big", a.clone()).unwrap();
        let xs: Vec<Vec<Value>> = (0..4)
            .map(|j| (0..64).map(|i| ((i + j) as f64 * 0.2).sin()).collect())
            .collect();
        let ys = client.spmv_batch("big", xs.clone()).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let mut want = vec![0.0; 64];
            a.spmv(x, &mut want);
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9);
            }
        }
        let y1 = client.spmv("big", xs[0].clone()).unwrap();
        assert_eq!(y1, ys[0], "single-RHS split serving agrees with the batch");
        let rows = client.stats().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].split_parts, 2, "stats must expose the split");
        assert_eq!(rows[0].split_calls, 5);
        assert_eq!(rows[0].calls, 5);
        // Shutdown hands back the coordinator with the split intact.
        let coord = srv.shutdown();
        let row = &coord.stats()[0];
        assert_eq!((row.split_parts, row.split_calls), (2, 5));
    }

    #[test]
    fn solver_kind_parse() {
        assert_eq!(SolverKind::parse("cg"), Some(SolverKind::Cg));
        assert_eq!(SolverKind::parse("BICGSTAB"), Some(SolverKind::BiCgStab));
        assert_eq!(SolverKind::parse("gmres"), Some(SolverKind::Gmres));
        assert_eq!(SolverKind::parse("jacobi"), Some(SolverKind::Jacobi));
        assert_eq!(SolverKind::parse("nope"), None);
    }
}
