//! The L3 coordinator: matrix registry + online AT routing + serving loop.
//!
//! This is the long-lived process a numerical application talks to. It
//! owns:
//!
//! * the machine's installed **tuning table** (offline-phase output),
//! * the **memory policy** bounding transformed copies,
//! * one persistent **worker pool** ([`crate::spmv::pool::ParPool`]) and a
//!   [`Planner`] that turns registered matrices into cached, reusable
//!   [`SpmvPlan`]s — every served SpMV executes through a plan, never
//!   through per-call thread spawns or per-call partitioning,
//! * a **matrix registry** with per-matrix AT lifecycle state
//!   ([`registry`]),
//! * the optional **XLA runtime** so ELL SpMV can execute through the
//!   AOT-compiled Pallas artifact instead of the native kernel,
//! * and a channel-served **request loop** ([`server`]) so concurrent
//!   clients (solvers, benches, the CLI) share one coordinator.
//!
//! Python never appears here: the tuning table is a text file, the XLA
//! artifacts are pre-compiled HLO.

pub mod registry;
pub mod server;

pub use registry::{AtState, EntryStats, MatrixEntry};
pub use server::{Client, Request, Server, SolverKind};

use crate::autotune::online::{decide, TuningData};
use crate::autotune::MemoryPolicy;
use crate::formats::{Csr, FormatKind, SparseMatrix};
use crate::machine::MatrixShape;
use crate::runtime::XlaHandle;
use crate::spmv::pool::{self, ParPool};
use crate::spmv::{Implementation, Planner};
use crate::{Result, Value};
use std::collections::HashMap;
use std::sync::Arc;

/// How the coordinator executes ELL SpMV.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EllExec {
    /// Native rust kernels (Figs. 3–4).
    Native,
    /// Through the AOT XLA artifact when a shape bucket fits, falling back
    /// to native otherwise.
    XlaPreferred,
}

/// Coordinator configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    /// The installed tuning table.
    pub tuning: TuningData,
    /// Memory policy for transformed copies.
    pub policy: MemoryPolicy,
    /// Size of the coordinator's worker pool (native parallel kernels and
    /// parallel transformations).
    pub threads: usize,
    /// ELL execution preference.
    pub ell_exec: EllExec,
}

impl CoordinatorConfig {
    /// Config with an explicit tuning table and defaults elsewhere. The
    /// thread count comes from [`pool::configured_threads`] — the
    /// `SPMV_AT_THREADS` environment variable when set, hardware
    /// parallelism otherwise.
    pub fn new(tuning: TuningData) -> Self {
        Self {
            tuning,
            policy: MemoryPolicy::default(),
            threads: pool::configured_threads(),
            ell_exec: EllExec::Native,
        }
    }
}

/// The coordinator. Single-threaded state; wrap in [`Server`] for
/// concurrent access.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    planner: Planner,
    xla: Option<XlaHandle>,
    entries: HashMap<String, MatrixEntry>,
}

impl Coordinator {
    /// New coordinator without an XLA runtime. Spawns the worker pool
    /// (`cfg.threads` wide) that every plan built here executes on.
    pub fn new(cfg: CoordinatorConfig) -> Self {
        let pool = Arc::new(ParPool::new(cfg.threads));
        let planner = Planner::new(cfg.tuning.clone(), cfg.policy, pool);
        Self { cfg, planner, xla: None, entries: HashMap::new() }
    }

    /// Attach a handle to the XLA artifact service
    /// ([`crate::runtime::XlaService`]).
    pub fn with_xla(mut self, rt: XlaHandle) -> Self {
        self.xla = Some(rt);
        self
    }

    /// The active tuning table.
    pub fn tuning(&self) -> &TuningData {
        &self.cfg.tuning
    }

    /// Register a matrix under `name`, running the §2.2 online phase
    /// (compute `D_mat`, compare to `D*`, record the decision) and caching
    /// the baseline CRS plan. The transformation itself is deferred to the
    /// first SpMV so registration stays cheap.
    pub fn register(&mut self, name: &str, csr: Csr) -> Result<EntryStats> {
        anyhow::ensure!(
            !self.entries.contains_key(name),
            "matrix '{name}' already registered"
        );
        let mut decision = decide(&csr, &self.cfg.tuning);
        // Memory policy veto (the OpenATLib policy hook).
        if decision.transform {
            let shape = MatrixShape::of(&csr);
            if !self
                .cfg
                .policy
                .admits(&shape, decision.chosen.required_format())
            {
                decision.transform = false;
                decision.chosen = Implementation::CsrSeq;
            }
        }
        let baseline = self.planner.plan_for(&csr, Implementation::CsrRowPar)?;
        let entry = MatrixEntry::new(name.to_string(), csr, decision, baseline);
        let stats = entry.stats();
        self.entries.insert(name.to_string(), entry);
        Ok(stats)
    }

    /// Remove a matrix, returning whether it existed.
    pub fn evict(&mut self, name: &str) -> bool {
        self.entries.remove(name).is_some()
    }

    /// Names of all registered matrices.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// `y = A·x` for a registered matrix, routed through the AT decision.
    /// The transformed plan is built (and cached) on the first call that
    /// needs it; every call executes through a cached plan.
    pub fn spmv(&mut self, name: &str, x: &[Value]) -> Result<Vec<Value>> {
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix '{name}'"))?;
        anyhow::ensure!(
            x.len() == entry.csr.n_cols(),
            "x length {} != n_cols {}",
            x.len(),
            entry.csr.n_cols()
        );
        let mut y = vec![0.0; entry.csr.n_rows()];

        // Trigger the deferred transformation if decided and not yet done.
        if entry.decision.transform && matches!(entry.state, AtState::Baseline) {
            match self.planner.plan_for(&entry.csr, entry.decision.chosen) {
                Ok(plan) => {
                    let t_trans = plan.transform_seconds();
                    entry.state = AtState::Transformed { plan, t_trans };
                }
                Err(_) => {
                    // Transformation failed (e.g. ELL overflow): pin to CRS.
                    entry.decision.transform = false;
                    entry.decision.chosen = Implementation::CsrSeq;
                }
            }
        }

        let t0 = std::time::Instant::now();
        let transformed = match &mut entry.state {
            AtState::Baseline => {
                entry.baseline.execute(x, &mut y)?;
                false
            }
            AtState::Transformed { plan, .. } => {
                // Prefer the XLA artifact path for ELL when configured.
                let mut served = false;
                if self.cfg.ell_exec == EllExec::XlaPreferred {
                    if let (Some(rt), Some(e)) = (&self.xla, plan.ell()) {
                        if rt.has_bucket(e.n_rows(), e.bandwidth) {
                            let cols: Vec<i32> =
                                e.col_idx.iter().map(|&c| c as i32).collect();
                            let out =
                                rt.ell_spmv(e.n_rows(), e.bandwidth, &e.values, &cols, x)?;
                            y.copy_from_slice(&out);
                            served = true;
                        }
                    }
                }
                if !served {
                    plan.execute(x, &mut y)?;
                }
                true
            }
        };
        entry.record_call(transformed, t0.elapsed().as_secs_f64());
        Ok(y)
    }

    /// Batched `Y = A·X` for a registered matrix: `xs` are multiple
    /// right-hand vectors served under a single routing decision and a
    /// single transformation trigger — the SpMM-style request shape a
    /// serving deployment batches into. Returns one output per input.
    pub fn spmv_batch(&mut self, name: &str, xs: &[Vec<Value>]) -> Result<Vec<Vec<Value>>> {
        let mut out = Vec::with_capacity(xs.len());
        for x in xs {
            out.push(self.spmv(name, x)?);
        }
        Ok(out)
    }

    /// Per-matrix stats rows, sorted by name.
    pub fn stats(&self) -> Vec<EntryStats> {
        let mut rows: Vec<EntryStats> = self.entries.values().map(|e| e.stats()).collect();
        rows.sort_by(|a, b| a.name.cmp(&b.name));
        rows
    }

    /// Total extra bytes held by transformed copies (memory-policy
    /// observability).
    pub fn extra_bytes(&self) -> usize {
        self.entries.values().map(|e| e.extra_bytes()).sum()
    }

    /// The format a registered matrix is currently served from.
    pub fn serving_format(&self, name: &str) -> Option<FormatKind> {
        self.entries.get(name).map(|e| match &e.state {
            AtState::Baseline => FormatKind::Csr,
            AtState::Transformed { plan, .. } => plan.kind(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::{banded_circulant, generate, spec_by_name};
    use crate::rng::Rng;

    fn tuning(d_star: Option<f64>) -> TuningData {
        TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star,
        }
    }

    fn coord(d_star: Option<f64>) -> Coordinator {
        let mut cfg = CoordinatorConfig::new(tuning(d_star));
        cfg.threads = 2;
        Coordinator::new(cfg)
    }

    #[test]
    fn register_spmv_roundtrip_matches_reference() {
        let mut rng = Rng::new(1);
        let a = crate::matrixgen::random_csr(&mut rng, 50, 50, 0.1);
        let x: Vec<Value> = (0..50).map(|i| (i as f64 * 0.31).cos()).collect();
        let mut want = vec![0.0; 50];
        a.spmv(&x, &mut want);
        let mut c = coord(Some(3.1));
        c.register("m", a).unwrap();
        let y = c.spmv("m", &x).unwrap();
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn banded_matrix_gets_transformed_lazily() {
        let mut rng = Rng::new(2);
        let a = banded_circulant(&mut rng, 128, &[-1, 0, 1]);
        let mut c = coord(Some(3.1));
        c.register("band", a).unwrap();
        assert_eq!(c.serving_format("band"), Some(FormatKind::Csr), "lazy until first call");
        let x = vec![1.0; 128];
        c.spmv("band", &x).unwrap();
        assert_eq!(c.serving_format("band"), Some(FormatKind::Ell));
        assert!(c.extra_bytes() > 0);
        let s = &c.stats()[0];
        assert_eq!(s.transformed_calls, 1);
        assert!(s.t_trans > 0.0);
    }

    #[test]
    fn high_dmat_matrix_stays_on_crs() {
        let spec = spec_by_name("memplus").unwrap();
        let a = generate(&spec, 5, 0.02);
        let n = a.n_rows();
        let mut c = coord(Some(0.1)); // SR16000-style threshold
        c.register("memplus", a).unwrap();
        let x = vec![1.0; n];
        c.spmv("memplus", &x).unwrap();
        assert_eq!(c.serving_format("memplus"), Some(FormatKind::Csr));
        assert_eq!(c.extra_bytes(), 0);
    }

    #[test]
    fn memory_policy_vetoes_transformation() {
        let mut rng = Rng::new(3);
        let a = banded_circulant(&mut rng, 256, &[-1, 0, 1]);
        let mut cfg = CoordinatorConfig::new(tuning(Some(3.1)));
        cfg.policy = MemoryPolicy::with_budget(16); // absurdly tight
        let mut c = Coordinator::new(cfg);
        c.register("band", a).unwrap();
        let x = vec![1.0; 256];
        c.spmv("band", &x).unwrap();
        assert_eq!(c.serving_format("band"), Some(FormatKind::Csr));
    }

    #[test]
    fn duplicate_and_unknown_names_rejected() {
        let mut c = coord(None);
        c.register("a", Csr::identity(4)).unwrap();
        assert!(c.register("a", Csr::identity(4)).is_err());
        assert!(c.spmv("nope", &[1.0; 4]).is_err());
        assert!(c.spmv("a", &[1.0; 3]).is_err(), "dimension mismatch");
        assert!(c.evict("a"));
        assert!(!c.evict("a"));
    }

    #[test]
    fn stats_sorted_and_complete() {
        let mut c = coord(Some(3.1));
        c.register("zz", Csr::identity(8)).unwrap();
        c.register("aa", Csr::identity(8)).unwrap();
        let names: Vec<String> = c.stats().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names, vec!["aa", "zz"]);
        assert_eq!(c.names(), vec!["aa", "zz"]);
    }

    #[test]
    fn repeated_calls_reuse_one_plan_and_pool() {
        // Many calls through one coordinator: results stay bitwise stable
        // (same plan, same partition, same reduction order every call).
        let mut rng = Rng::new(8);
        let a = banded_circulant(&mut rng, 300, &[-1, 0, 1, 2]);
        let mut c = coord(Some(3.1));
        c.register("m", a).unwrap();
        let x: Vec<Value> = (0..300).map(|i| (i as f64 * 0.17).sin()).collect();
        let first = c.spmv("m", &x).unwrap();
        for _ in 0..5 {
            let again = c.spmv("m", &x).unwrap();
            assert_eq!(first, again, "repeated execution must be bitwise stable");
        }
        let s = &c.stats()[0];
        assert_eq!(s.calls, 6);
        assert!(s.t_trans > 0.0, "transformed exactly once");
    }
}
