//! The L3 coordinator: matrix registry + online AT routing + serving loop.
//!
//! This is the long-lived process a numerical application talks to. It
//! owns:
//!
//! * the machine's installed **tuning table** (offline-phase output),
//! * the **memory policy** bounding transformed copies,
//! * **sharded, socket-pinned worker pools** ([`shards::PlanShards`]): N
//!   independent [`crate::spmv::pool::ParPool`]s (N from `SPMV_AT_SHARDS`
//!   when set, else the detected socket count —
//!   [`crate::machine::Topology`]), shard `i` pinned to socket
//!   `i mod sockets`, with a [`shards::ShardedPlanner`] routing each
//!   registered matrix to one shard by registry key — so key-routing is
//!   socket-routing, and every plan build or adaptive re-plan
//!   first-touches its arrays on the owning socket through
//!   [`crate::spmv::pool::ParPool::run_init`]. Batches against different
//!   matrices run on disjoint workers; a single huge matrix can
//!   row-split *across* shards ([`shards::SplitPlan`]) with the blocks
//!   **concurrently in flight** (cross-pool join —
//!   [`crate::spmv::pool::PoolGroup`]), and `spmv`/`spmv_batch` route
//!   matrices past the split threshold ([`shards::SplitThreshold`],
//!   `SPMV_AT_SPLIT_ROWS`) through a *cached* split automatically.
//!   Every served SpMV/SpMM executes through a cached, reusable
//!   [`crate::spmv::SpmvPlan`] — never through per-call thread spawns or
//!   per-call partitioning,
//! * a **matrix registry** with per-matrix AT lifecycle state
//!   ([`registry`]),
//! * the **adaptive loop** (`SPMV_AT_ADAPTIVE`,
//!   [`crate::autotune::adaptive`]): per-matrix telemetry, budgeted
//!   exploration shadow calls, and a hysteresis-guarded controller that
//!   flips the serving plan — promoting the cached shadow plan or parking
//!   the transformed one, always on the matrix's own shard, never
//!   touching the result a client sees — and folds each flip into the
//!   learned v2 tuning table,
//! * the optional **XLA runtime** so ELL SpMV can execute through the
//!   AOT-compiled Pallas artifact instead of the native kernel,
//! * and a channel-served **request loop** ([`server`]) so concurrent
//!   clients (solvers, benches, the CLI) share one coordinator —
//!   [`Server::spawn_sharded`] runs one loop per shard so requests for
//!   matrices on different shards execute concurrently.
//!
//! Python never appears here: the tuning table is a text file, the XLA
//! artifacts are pre-compiled HLO.

pub mod decision_log;
pub mod registry;
pub mod server;
pub mod shards;

pub use decision_log::{DecisionEvent, DecisionLog, DecisionRecord};
pub use registry::{AtState, EntryStats, MatrixEntry};
pub use server::{Client, Request, Server, SolverKind};
pub use shards::{PlanShards, ShardedPlanner, SplitPlan, SplitThreshold};

use crate::autotune::adaptive::{AdaptiveConfig, AdaptiveState, LearnedTuning};
use crate::autotune::online::{decide, OnlineDecision, TuningData};
use crate::autotune::MemoryPolicy;
use crate::formats::{Csr, FormatKind, SparseMatrix};
use crate::machine::MatrixShape;
use crate::runtime::XlaHandle;
use crate::spmv::pool;
use crate::spmv::Implementation;
use crate::{Result, Value};
use std::collections::HashMap;
use std::sync::Arc;

/// How the coordinator executes ELL SpMV.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EllExec {
    /// Native rust kernels (Figs. 3–4).
    Native,
    /// Through the AOT XLA artifact when a shape bucket fits, falling back
    /// to native otherwise.
    XlaPreferred,
}

/// Coordinator configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    /// The installed tuning table.
    pub tuning: TuningData,
    /// Memory policy for transformed copies.
    pub policy: MemoryPolicy,
    /// Total worker threads, divided between the shards (native parallel
    /// kernels and parallel transformations).
    pub threads: usize,
    /// Independent pool shards matrices are routed across.
    pub shards: usize,
    /// ELL execution preference.
    pub ell_exec: EllExec,
    /// When to route a matrix through a cached cross-shard
    /// [`shards::SplitPlan`] instead of a single-shard plan
    /// (`SPMV_AT_SPLIT_ROWS` / `--split-rows`; never engages on
    /// single-shard planners, so single-socket serving is untouched).
    pub split: shards::SplitThreshold,
    /// The adaptive loop's tunables; `adaptive.enabled = false` is the
    /// decide-once pipeline, byte for byte.
    pub adaptive: AdaptiveConfig,
    /// A pre-learned table (v2 corrections) to start from; `None` seeds a
    /// correction-free table from `tuning`.
    pub learned: Option<LearnedTuning>,
    /// Which preconditioner `solve` requests build (and cache) for a
    /// served entry (`SPMV_AT_PRECOND` / `--precond`; default Jacobi —
    /// the historical `pcg` behaviour).
    pub precond: crate::precond::PrecondKind,
    /// Serial-vs-level-scheduled SpTRSV policy for SymGS triangular
    /// sweeps (`SPMV_AT_TRSV_PAR`, default: the level-width auto
    /// threshold).
    pub trsv_par: crate::precond::TrsvPar,
    /// Append-only, replayable serving-decision log
    /// ([`decision_log::DecisionLog`], `--decision-log`). `None` disables
    /// recording; the handle is `Arc`-backed, so the sharded server's
    /// per-shard config clones all append to one log.
    pub decision_log: Option<DecisionLog>,
}

impl CoordinatorConfig {
    /// Config with an explicit tuning table and defaults elsewhere. The
    /// thread count comes from [`pool::configured_threads`] — the
    /// `SPMV_AT_THREADS` environment variable when set, hardware
    /// parallelism otherwise — the shard count from
    /// [`shards::configured_shards`] (`SPMV_AT_SHARDS` when set, else the
    /// detected socket count — override with `SPMV_AT_TOPOLOGY`), the
    /// split-routing threshold from
    /// [`shards::SplitThreshold::from_env`] (`SPMV_AT_SPLIT_ROWS`,
    /// default: the nnz × shard-count heuristic), the adaptive
    /// switch from [`crate::autotune::adaptive::configured_adaptive`]
    /// (`SPMV_AT_ADAPTIVE`, default off), the preconditioner kind from
    /// [`crate::precond::configured_precond`] (`SPMV_AT_PRECOND`,
    /// default Jacobi) and the SpTRSV policy from
    /// [`crate::precond::TrsvPar::from_env`] (`SPMV_AT_TRSV_PAR`,
    /// default auto).
    pub fn new(tuning: TuningData) -> Self {
        Self {
            tuning,
            policy: MemoryPolicy::default(),
            threads: pool::configured_threads(),
            shards: shards::configured_shards(),
            ell_exec: EllExec::Native,
            split: shards::SplitThreshold::from_env(),
            adaptive: AdaptiveConfig::from_env(),
            learned: None,
            precond: crate::precond::configured_precond(),
            trsv_par: crate::precond::TrsvPar::from_env(),
            decision_log: None,
        }
    }
}

/// The coordinator. Single-threaded state; wrap in [`Server`] for
/// concurrent access ([`Server::spawn_sharded`] for one request loop per
/// shard).
pub struct Coordinator {
    cfg: CoordinatorConfig,
    planner: ShardedPlanner,
    xla: Option<XlaHandle>,
    entries: HashMap<String, MatrixEntry>,
    learned: LearnedTuning,
}

impl Coordinator {
    /// New coordinator without an XLA runtime. Spawns `cfg.shards`
    /// independent worker pools (`cfg.threads` workers divided between
    /// them) that every plan built here executes on.
    pub fn new(cfg: CoordinatorConfig) -> Self {
        let pools = PlanShards::spread(cfg.shards, cfg.threads);
        let planner = ShardedPlanner::new(cfg.tuning.clone(), cfg.policy, pools);
        Self::with_planner(cfg, planner)
    }

    /// New coordinator over an explicitly built [`ShardedPlanner`] (the
    /// sharded server hands each per-shard coordinator its own slice).
    pub fn with_planner(cfg: CoordinatorConfig, planner: ShardedPlanner) -> Self {
        let learned = cfg
            .learned
            .clone()
            .unwrap_or_else(|| LearnedTuning::new(cfg.tuning.clone()));
        Self { cfg, planner, xla: None, entries: HashMap::new(), learned }
    }

    /// Attach a handle to the XLA artifact service
    /// ([`crate::runtime::XlaService`]).
    pub fn with_xla(mut self, rt: XlaHandle) -> Self {
        self.xla = Some(rt);
        self
    }

    /// The active tuning table.
    pub fn tuning(&self) -> &TuningData {
        &self.cfg.tuning
    }

    /// Register a matrix under `name`, running the §2.2 online phase
    /// (compute `D_mat`, compare to `D*` — through the learned per-bucket
    /// corrections when the adaptive loop is on — and record the
    /// decision), routing the matrix to its pool shard, and caching the
    /// baseline CRS plan (a zero-copy `Arc` view of the registered
    /// matrix). The transformation itself is deferred to the first SpMV
    /// so registration stays cheap.
    pub fn register(&mut self, name: &str, csr: Csr) -> Result<EntryStats> {
        anyhow::ensure!(
            !self.entries.contains_key(name),
            "matrix '{name}' already registered"
        );
        let csr = Arc::new(csr);
        let mut decision = self.decide_for(&csr);
        let shard = self.planner.shard_of(name);
        // The baseline CRS kernel follows the partition-strategy pick:
        // merge-path CRS when the row-length skew (or SPMV_AT_PARTITION)
        // calls for it, row-parallel CRS otherwise.
        let base_imp = self.planner.planner(shard).baseline_impl(&csr);
        // The adaptive rival arm: normally the tuning table's transform
        // target. When the *skew heuristic* put merge-path CRS in the
        // baseline slot and the online phase keeps CRS anyway, the
        // interesting rival is the conventional row partitioning — so
        // the controller can flip CsrMergePar ↔ CsrRowPar from live
        // telemetry rather than trusting the heuristic forever. An
        // SPMV_AT_PARTITION override is the user's explicit pick, not a
        // heuristic to second-guess: the rival stays the tuning table's.
        let candidate = if base_imp == Implementation::CsrMergePar
            && !decision.transform
            && crate::spmv::partition::configured_partition().is_none()
        {
            Implementation::CsrRowPar
        } else {
            self.cfg.tuning.imp
        };
        // Memory policy veto (the OpenATLib policy hook). Both CRS
        // partitioning arms are zero-copy views, so only a transform
        // target can be vetoed here.
        let candidate_admitted = {
            let shape = MatrixShape::of(&csr);
            self.cfg.policy.admits(&shape, candidate.required_format())
        };
        if decision.transform && !(decision.chosen == candidate && candidate_admitted) {
            decision.transform = false;
            decision.chosen = Implementation::CsrSeq;
        }
        let baseline = self.planner.planner(shard).plan_for(&csr, base_imp)?;
        let mut entry =
            MatrixEntry::new(name.to_string(), csr, decision, baseline, candidate, shard);
        if self.cfg.adaptive.enabled {
            let mut ad = AdaptiveState::new(&self.cfg.adaptive, shards::fnv1a(name));
            // A vetoed candidate can never serve: don't shadow-measure it.
            ad.rival_dead = !candidate_admitted;
            entry.adaptive = Some(ad);
        }
        Self::log_decision(
            self.cfg.decision_log.as_ref(),
            &entry,
            DecisionEvent::Register,
            format!(
                "D_mat {:.4} vs D* {:.4}: transform={} chosen={} (candidate {}, admitted={})",
                entry.decision.d_mat,
                entry.decision.d_star,
                entry.decision.transform,
                entry.decision.chosen,
                candidate,
                candidate_admitted,
            ),
        );
        let stats = entry.stats();
        self.entries.insert(name.to_string(), entry);
        Ok(stats)
    }

    /// Append one record to the decision log (no-op without one): the
    /// entry's **post-event** serving state by the stats-row convention —
    /// so replaying the log reproduces [`MatrixEntry::stats`] exactly —
    /// plus the telemetry that justified the event. Flip events carry the
    /// controller's [`crate::autotune::adaptive::FlipEvidence`] snapshot
    /// (the means the vote actually fired on); every other event carries
    /// the live telemetry at the moment it was recorded.
    fn log_decision(
        log: Option<&DecisionLog>,
        entry: &MatrixEntry,
        event: DecisionEvent,
        detail: String,
    ) {
        let Some(log) = log else { return };
        let flip_ev = if event == DecisionEvent::Flip {
            entry.adaptive.as_ref().and_then(|ad| ad.controller.flip_evidence())
        } else {
            None
        };
        let (serving_mean, rival_mean, rival_samples, votes, windows) =
            match (entry.adaptive.as_ref(), flip_ev) {
                (_, Some(ev)) => (
                    Some(ev.serving_mean),
                    Some(ev.rival_mean),
                    ev.rival_samples,
                    u64::from(ev.votes),
                    ev.windows,
                ),
                (Some(ad), None) => {
                    let serving_imp = match &entry.state {
                        AtState::Baseline => entry.baseline.implementation(),
                        AtState::Transformed { plan, .. } => plan.implementation(),
                    };
                    let rival_imp = if matches!(entry.state, AtState::Baseline) {
                        entry.candidate
                    } else {
                        entry.baseline.implementation()
                    };
                    (
                        ad.telemetry.mean(serving_imp),
                        ad.telemetry.mean(rival_imp),
                        ad.telemetry.samples(rival_imp),
                        u64::from(ad.controller.votes()),
                        ad.controller.windows(),
                    )
                }
                (None, None) => (None, None, 0, 0, 0),
            };
        log.record(&DecisionRecord {
            event,
            matrix: entry.name.clone(),
            kernel: entry.reported_serving().name().to_string(),
            partition: entry.reported_partition(),
            split_parts: entry.split.as_ref().map_or(0, SplitPlan::parts) as u64,
            split_vetoed: entry.split_vetoed,
            transform: entry.decision.transform,
            d_mat: entry.decision.d_mat,
            d_star: entry.decision.d_star,
            serving_mean,
            rival_mean,
            rival_samples,
            votes,
            windows,
            detail,
        });
    }

    /// The online decision for a matrix: the factory table's §2.2
    /// comparison, overridden by learned `D_mat`-bucket corrections when
    /// the adaptive loop is on.
    fn decide_for(&self, csr: &Csr) -> OnlineDecision {
        if self.cfg.adaptive.enabled {
            self.learned.decide(csr)
        } else {
            decide(csr, &self.cfg.tuning)
        }
    }

    /// The pool shard a registry key routes to.
    pub fn shard_of(&self, name: &str) -> usize {
        self.planner.shard_of(name)
    }

    /// The sharded planner (observability / tests).
    pub fn planner(&self) -> &ShardedPlanner {
        &self.planner
    }

    /// Remove a matrix, returning whether it existed.
    pub fn evict(&mut self, name: &str) -> bool {
        self.entries.remove(name).is_some()
    }

    /// Names of all registered matrices.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// `y = A·x` for a registered matrix, routed through the AT decision.
    /// The transformed plan is built (and cached) on the first call that
    /// needs it; every call executes through a cached plan.
    pub fn spmv(&mut self, name: &str, x: &[Value]) -> Result<Vec<Value>> {
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix '{name}'"))?;
        anyhow::ensure!(
            x.len() == entry.csr.n_cols(),
            "x length {} != n_cols {}",
            x.len(),
            entry.csr.n_cols()
        );
        let mut y = vec![0.0; entry.csr.n_rows()];
        // Oversized matrices route through a cached cross-shard split;
        // the split replaces the transformed full-matrix plan (never both
        // — that would double the memory and the build cost). The
        // XLA-preferred serving shape keeps its artifact path: split
        // routing stays out of the way there.
        let xla_preferred = self.cfg.ell_exec == EllExec::XlaPreferred && self.xla.is_some();
        if !xla_preferred {
            Self::trigger_split(
                self.cfg.split,
                &self.planner,
                entry,
                self.cfg.decision_log.as_ref(),
            );
            if let Some(split) = entry.split.as_mut() {
                let t0 = std::time::Instant::now();
                split.execute(x, &mut y)?;
                let dt = t0.elapsed().as_secs_f64();
                let transformed = split.implementation().needs_transform();
                entry.split_calls += 1;
                entry.record_call(transformed, dt);
                // The adaptive controller's arms are full-matrix plans; a
                // split-served entry skips exploration/flipping (a forced
                // `replan` still re-decides and rebuilds the split).
                return Ok(y);
            }
        }
        Self::trigger_transform(&self.planner, entry, self.cfg.decision_log.as_ref());

        let t0 = std::time::Instant::now();
        let transformed = match &mut entry.state {
            AtState::Baseline => {
                entry.baseline.execute(x, &mut y)?;
                false
            }
            AtState::Transformed { plan, .. } => {
                // Prefer the XLA artifact path for ELL when configured.
                let mut served = false;
                if self.cfg.ell_exec == EllExec::XlaPreferred {
                    if let (Some(rt), Some(e)) = (&self.xla, plan.ell()) {
                        if rt.has_bucket(e.n_rows(), e.bandwidth) {
                            let cols: Vec<i32> =
                                e.col_idx.iter().map(|&c| c as i32).collect();
                            let out =
                                rt.ell_spmv(e.n_rows(), e.bandwidth, &e.values, &cols, x)?;
                            y.copy_from_slice(&out);
                            served = true;
                        }
                    }
                }
                if !served {
                    plan.execute(x, &mut y)?;
                }
                true
            }
        };
        let dt = t0.elapsed().as_secs_f64();
        entry.record_call(transformed, dt);
        if self.cfg.adaptive.enabled {
            Self::adaptive_step(
                &self.planner,
                &mut self.learned,
                entry,
                x,
                None,
                1,
                dt,
                self.cfg.decision_log.as_ref(),
            );
        }
        Ok(y)
    }

    /// Build (once, lazily — like the deferred transformation) the cached
    /// cross-shard [`SplitPlan`] for a matrix past the split threshold.
    /// The split serves the online decision's chosen kernel when that
    /// kernel is split-stable (row-oriented — see
    /// [`Implementation::split_stable`]), the row-parallel CRS baseline
    /// otherwise; `splits` = the planner's shard count, so each socket
    /// streams one nnz-balanced block. A build failure (e.g. an ELL
    /// budget overflow on one block) **pins the entry to the unsplit
    /// path** (`split_vetoed`) so the failed build is never re-paid per
    /// call; a successful build drops any full-size transformed plan —
    /// an entry never holds both.
    fn trigger_split(
        threshold: shards::SplitThreshold,
        planner: &ShardedPlanner,
        entry: &mut MatrixEntry,
        log: Option<&DecisionLog>,
    ) {
        if entry.split.is_some()
            || entry.split_vetoed
            || !threshold.should_split(entry.csr.n_rows(), entry.csr.nnz(), planner.len())
        {
            return;
        }
        let imp = if entry.decision.transform && entry.decision.chosen.split_stable() {
            entry.decision.chosen
        } else {
            // Fall back to the entry's baseline CRS kernel (row-parallel
            // or merge-path, per the register-time partition pick) — both
            // are split-stable.
            entry.baseline.implementation()
        };
        match planner.plan_split(&entry.csr, imp, planner.len()) {
            Ok(split) => {
                // The split replaces a full-size transformed plan (a
                // veto-then-replan sequence can reach here with one
                // serving); holding both would double the memory.
                if matches!(entry.state, AtState::Transformed { .. }) {
                    entry.state = AtState::Baseline;
                }
                let parts = split.parts();
                entry.split = Some(split);
                Self::log_decision(
                    log,
                    entry,
                    DecisionEvent::Split,
                    format!("cross-shard split built: {parts} blocks serving {imp}"),
                );
            }
            Err(e) => {
                entry.split_vetoed = true;
                Self::log_decision(
                    log,
                    entry,
                    DecisionEvent::SplitVeto,
                    format!("split build for {imp} failed ({e}); pinned to unsplit serving"),
                );
            }
        }
    }

    /// Trigger the deferred transformation for `entry` if decided and not
    /// yet done, building the plan on the entry's shard. On failure
    /// (e.g. an ELL overflow the predictor missed) the entry is pinned to
    /// CRS.
    fn trigger_transform(
        planner: &ShardedPlanner,
        entry: &mut MatrixEntry,
        log: Option<&DecisionLog>,
    ) {
        if entry.decision.transform && matches!(entry.state, AtState::Baseline) {
            let target = entry.decision.chosen;
            match planner.planner(entry.shard).plan_for(&entry.csr, target) {
                Ok(plan) => {
                    let t_trans = plan.transform_seconds();
                    entry.state = AtState::Transformed { plan, t_trans };
                    Self::log_decision(
                        log,
                        entry,
                        DecisionEvent::Transform,
                        format!("deferred transform built: {target} in {t_trans:.3e}s"),
                    );
                }
                Err(e) => {
                    entry.decision.transform = false;
                    entry.decision.chosen = Implementation::CsrSeq;
                    Self::log_decision(
                        log,
                        entry,
                        DecisionEvent::Transform,
                        format!("transform to {target} failed ({e}); pinned to CRS"),
                    );
                }
            }
        }
    }

    /// One adaptive-loop step after a served call (`batch = None`) or
    /// batch (`batch = Some(xs)`) of `k` calls that took `serve_seconds`:
    /// budget accounting, an epsilon-greedy shadow measurement of the
    /// rival arm (output discarded — the served result is never touched),
    /// and the hysteresis evaluation that may flip the serving plan. A
    /// batched serve shadow-measures the rival as the same tiled SpMM, so
    /// the two arms' per-call means stay comparable (a single-RHS shadow
    /// against a per-RHS-amortised serving mean would make the rival look
    /// `k`× slower than it is).
    fn adaptive_step(
        planner: &ShardedPlanner,
        learned: &mut LearnedTuning,
        entry: &mut MatrixEntry,
        x: &[Value],
        batch: Option<&[Vec<Value>]>,
        k: u64,
        serve_seconds: f64,
        log: Option<&DecisionLog>,
    ) {
        let Some(ad) = entry.adaptive.as_mut() else { return };
        ad.explore.note_serve(serve_seconds);
        let serving_is_baseline = matches!(entry.state, AtState::Baseline);
        let serving_imp = match &entry.state {
            AtState::Baseline => entry.baseline.implementation(),
            AtState::Transformed { plan, .. } => plan.implementation(),
        };
        let rival_imp = if serving_is_baseline {
            entry.candidate
        } else {
            entry.baseline.implementation()
        };

        // Shadow-measure the rival occasionally to keep its estimate fresh.
        if !ad.rival_dead && ad.explore.should_explore() {
            let t0 = std::time::Instant::now();
            if serving_is_baseline && ad.shadow.is_none() {
                // The rival plan does not exist yet: build it now (its
                // build cost is exploration overhead, and it is kept, so
                // a later flip promotes it in O(1)).
                match planner.planner(entry.shard).plan_for(&entry.csr, entry.candidate) {
                    Ok(p) => ad.shadow = Some(p),
                    Err(_) => ad.rival_dead = true,
                }
            }
            let rival_plan = if serving_is_baseline {
                ad.shadow.as_mut()
            } else {
                Some(&mut entry.baseline)
            };
            if let Some(plan) = rival_plan {
                match batch {
                    Some(xs) => {
                        // Shadow the whole batch through the rival's tiled
                        // SpMM: same work shape as the serve it mirrors.
                        // Output buffers are reused across explorations.
                        let n = plan.n_rows();
                        if ad.scratch_many.len() < xs.len() {
                            ad.scratch_many.resize(xs.len(), Vec::new());
                        }
                        for y in ad.scratch_many.iter_mut().take(xs.len()) {
                            y.resize(n, 0.0);
                        }
                        let ys = &mut ad.scratch_many[..xs.len()];
                        let te = std::time::Instant::now();
                        if plan.execute_many(xs, ys).is_ok() {
                            let per_call =
                                te.elapsed().as_secs_f64() / xs.len().max(1) as f64;
                            ad.telemetry.record(rival_imp, per_call, xs.len() as u64);
                        }
                    }
                    None => {
                        ad.scratch.resize(plan.n_rows(), 0.0);
                        let te = std::time::Instant::now();
                        if plan.execute(x, &mut ad.scratch).is_ok() {
                            ad.telemetry.record(rival_imp, te.elapsed().as_secs_f64(), 1);
                        }
                    }
                }
                ad.explore.note_explore(t0.elapsed().as_secs_f64());
            }
        }

        // Hysteresis evaluation over the measured arms.
        let serving_mean = ad.telemetry.mean(serving_imp);
        let rival =
            ad.telemetry.mean(rival_imp).map(|m| (m, ad.telemetry.samples(rival_imp)));
        if ad.controller.note_serve(k, serving_mean, rival) {
            // Flip failures (transform blow-up) mark the rival dead inside
            // apply_flip; the serving path is unaffected either way. Both
            // outcomes are logged — a rejected flip is a decision too, and
            // its record's (unchanged) post-state keeps the replay exact.
            match Self::apply_flip(planner, learned, entry) {
                Ok(()) => Self::log_decision(
                    log,
                    entry,
                    DecisionEvent::Flip,
                    "hysteresis controller fired; serving plan swapped".to_string(),
                ),
                Err(e) => Self::log_decision(
                    log,
                    entry,
                    DecisionEvent::Flip,
                    format!("hysteresis controller fired but the flip was rejected: {e}"),
                ),
            }
        }
    }

    /// Swap which plan serves `entry` — the adaptive re-decision. From
    /// baseline, the cached shadow plan is promoted (or built now on the
    /// entry's own shard); from transformed, the plan is parked as the
    /// shadow so flipping back is O(1). The flip is recorded in the
    /// entry's replan counter and folded into the learned per-`D_mat`
    /// bucket corrections as the live measured ratio `t_crs / t_imp`.
    fn apply_flip(
        planner: &ShardedPlanner,
        learned: &mut LearnedTuning,
        entry: &mut MatrixEntry,
    ) -> Result<()> {
        // Measured ratio *before* mutating state, from the live telemetry.
        let measured_r = entry.adaptive.as_ref().and_then(|ad| {
            ad.telemetry.ratio(entry.baseline.implementation(), entry.candidate)
        });
        if matches!(entry.state, AtState::Baseline) {
            // The register-time memory-policy veto (and any failed build)
            // marks the rival dead; a flip must honour it even when rival
            // telemetry was injected from outside.
            if entry.adaptive.as_ref().is_some_and(|ad| ad.rival_dead) {
                anyhow::bail!(
                    "candidate implementation unavailable for '{}' (vetoed or failed)",
                    entry.name
                );
            }
            let shadow = entry.adaptive.as_mut().and_then(|ad| ad.shadow.take());
            let plan = match shadow {
                Some(p) => p,
                None => match planner.planner(entry.shard).plan_for(&entry.csr, entry.candidate) {
                    Ok(p) => p,
                    Err(e) => {
                        if let Some(ad) = entry.adaptive.as_mut() {
                            ad.rival_dead = true;
                        }
                        return Err(e);
                    }
                },
            };
            let t_trans = plan.transform_seconds();
            entry.state = AtState::Transformed { plan, t_trans };
            entry.decision.transform = true;
            entry.decision.chosen = entry.candidate;
        } else {
            let old = std::mem::replace(&mut entry.state, AtState::Baseline);
            if let (AtState::Transformed { plan, .. }, Some(ad)) = (old, entry.adaptive.as_mut()) {
                ad.shadow = Some(plan);
            }
            entry.decision.transform = false;
            entry.decision.chosen = Implementation::CsrSeq;
        }
        // The cached split (if any) was built for the old decision; drop
        // it — and clear any split veto — so the next serve rebuilds for
        // the new one.
        entry.split = None;
        entry.split_vetoed = false;
        entry.replans += 1;
        if let Some(r) = measured_r {
            learned.record(entry.decision.d_mat, r);
        }
        Ok(())
    }

    /// Force an immediate re-decision for `name`: re-run the online phase
    /// (through the learned corrections when adaptive), flip the serving
    /// plan if the decision changed, or — when it did not change but a
    /// transformed plan is serving — rebuild it and
    /// [`crate::spmv::SpmvPlan::swap_executable`] the fresh plan into the
    /// serving slot
    /// (fresh partition and batch tile, no pool teardown). Resets the
    /// hysteresis state so the new choice gets its full K windows.
    pub fn replan(&mut self, name: &str) -> Result<EntryStats> {
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix '{name}'"))?;
        let decision = if self.cfg.adaptive.enabled {
            self.learned.decide(&entry.csr)
        } else {
            decide(&entry.csr, &self.cfg.tuning)
        };
        let shape = MatrixShape::of(&entry.csr);
        if entry.split.is_some() {
            // Split-served: record the fresh decision and rebuild the
            // split on its shards — never materialise a full-size plan
            // for a matrix that will keep serving split.
            entry.decision = decision;
            if entry.decision.transform
                && !self.cfg.policy.admits(&shape, entry.candidate.required_format())
            {
                entry.decision.transform = false;
                entry.decision.chosen = Implementation::CsrSeq;
            }
            entry.split = None;
            Self::trigger_split(
                self.cfg.split,
                &self.planner,
                entry,
                self.cfg.decision_log.as_ref(),
            );
            entry.replans += 1;
            if let Some(ad) = entry.adaptive.as_mut() {
                ad.controller.reset();
            }
            Self::log_decision(
                self.cfg.decision_log.as_ref(),
                entry,
                DecisionEvent::Replan,
                format!(
                    "forced replan rebuilt the split: transform={} chosen={}",
                    entry.decision.transform, entry.decision.chosen
                ),
            );
            return Ok(entry.stats());
        }
        // A forced replan re-decides, so a previously failed split build
        // gets one fresh chance on the next serve.
        entry.split_vetoed = false;
        let want_transform = decision.transform
            && self.cfg.policy.admits(&shape, entry.candidate.required_format());
        let is_transformed = matches!(entry.state, AtState::Transformed { .. });
        if want_transform != is_transformed {
            Self::apply_flip(&self.planner, &mut self.learned, entry)?;
        } else if is_transformed {
            let fresh =
                self.planner.planner(entry.shard).plan_for(&entry.csr, entry.candidate)?;
            if let AtState::Transformed { plan, t_trans } = &mut entry.state {
                *t_trans = fresh.transform_seconds();
                plan.swap_executable(fresh);
            }
            entry.replans += 1;
        }
        if let Some(ad) = entry.adaptive.as_mut() {
            ad.controller.reset();
        }
        Self::log_decision(
            self.cfg.decision_log.as_ref(),
            entry,
            DecisionEvent::Replan,
            format!(
                "forced replan: transform={} chosen={}",
                entry.decision.transform, entry.decision.chosen
            ),
        );
        Ok(entry.stats())
    }

    /// Inject a measured per-call timing sample for `(name, imp)` straight
    /// into the adaptive telemetry — the hook benches and tests use to
    /// drive the controller from [`crate::machine::MeasuredBackend`]
    /// timings (or synthetic ones) without waiting for wall-clock serving
    /// traffic to accumulate.
    ///
    /// # Errors
    /// Fails for unknown matrices or when the adaptive loop is off.
    pub fn inject_sample(
        &mut self,
        name: &str,
        imp: Implementation,
        seconds_per_call: f64,
        k: u64,
    ) -> Result<()> {
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix '{name}'"))?;
        let ad = entry
            .adaptive
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("adaptive loop is off (SPMV_AT_ADAPTIVE)"))?;
        ad.telemetry.record(imp, seconds_per_call, k);
        Ok(())
    }

    /// The learned tuning table (factory base + per-`D_mat`-bucket
    /// corrections recorded by flips on this coordinator).
    pub fn learned(&self) -> &LearnedTuning {
        &self.learned
    }

    /// Whether the adaptive loop is on.
    pub fn adaptive_enabled(&self) -> bool {
        self.cfg.adaptive.enabled
    }

    /// Batched `Y = A·X` for a registered matrix: `xs` are multiple
    /// right-hand vectors served under a single routing decision, a
    /// single transformation trigger, and — the SpMM win — a single
    /// [`crate::spmv::SpmvPlan::execute_many`] that streams the matrix
    /// once per column tile instead of once per vector. Returns one
    /// output per input.
    ///
    /// The XLA-preferred ELL path stays single-RHS (the AOT artifact's
    /// contract is one vector per call) and falls back to looped
    /// [`Coordinator::spmv`].
    pub fn spmv_batch(&mut self, name: &str, xs: &[Vec<Value>]) -> Result<Vec<Vec<Value>>> {
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        if self.cfg.ell_exec == EllExec::XlaPreferred && self.xla.is_some() {
            let mut out = Vec::with_capacity(xs.len());
            for x in xs {
                out.push(self.spmv(name, x)?);
            }
            return Ok(out);
        }
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix '{name}'"))?;
        for x in xs {
            anyhow::ensure!(
                x.len() == entry.csr.n_cols(),
                "x length {} != n_cols {}",
                x.len(),
                entry.csr.n_cols()
            );
        }
        Self::trigger_split(self.cfg.split, &self.planner, entry, self.cfg.decision_log.as_ref());
        let mut ys = vec![vec![0.0; entry.csr.n_rows()]; xs.len()];
        if let Some(split) = entry.split.as_mut() {
            let t0 = std::time::Instant::now();
            split.execute_many(xs, &mut ys)?;
            let dt = t0.elapsed().as_secs_f64();
            let transformed = split.implementation().needs_transform();
            let k = xs.len() as u64;
            entry.split_calls += k;
            entry.record_batch(transformed, k, dt);
            // Split-served entries skip the adaptive step (see `spmv`).
            return Ok(ys);
        }
        Self::trigger_transform(&self.planner, entry, self.cfg.decision_log.as_ref());
        let t0 = std::time::Instant::now();
        let transformed = match &mut entry.state {
            AtState::Baseline => {
                entry.baseline.execute_many(xs, &mut ys)?;
                false
            }
            AtState::Transformed { plan, .. } => {
                plan.execute_many(xs, &mut ys)?;
                true
            }
        };
        let dt = t0.elapsed().as_secs_f64();
        entry.record_batch(transformed, xs.len() as u64, dt);
        if self.cfg.adaptive.enabled {
            // One batch counts as k served calls toward the controller's
            // window; exploration shadows the same batch through the
            // rival's tiled SpMM.
            let k = xs.len() as u64;
            Self::adaptive_step(
                &self.planner,
                &mut self.learned,
                entry,
                &xs[0],
                Some(xs),
                k,
                dt,
                self.cfg.decision_log.as_ref(),
            );
        }
        Ok(ys)
    }

    /// Take the entry's cached preconditioner for a solve, building it
    /// on first use from the configured kind (`cfg.precond`), the
    /// entry's CRS original, and the entry's shard pool (SymGS level
    /// sweeps run where the matrix's SpMV plans run). Taking (rather
    /// than borrowing) lets the solve drive SpMV through the
    /// coordinator (`&mut self`) while the preconditioner is applied —
    /// pair with [`Self::put_preconditioner`].
    pub fn take_preconditioner(
        &mut self,
        name: &str,
    ) -> Result<Box<dyn crate::precond::Preconditioner>> {
        let kind = self.cfg.precond;
        let trsv = self.cfg.trsv_par;
        let adaptive = self.cfg.adaptive.clone();
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown matrix '{name}'"))?;
        let pool = self.planner.planner(entry.shard).pool().clone();
        if let Some(p) = entry.precond.take() {
            return Ok(p);
        }
        let built = kind.build(&entry.csr, &pool, trsv, &adaptive)?;
        entry.precond_setup_seconds = built.setup_seconds();
        Ok(built)
    }

    /// Return a taken preconditioner to its entry's cache, crediting the
    /// applications the solve performed through it. A quietly dropped
    /// box (entry evicted mid-solve) is fine — the next solve rebuilds.
    pub fn put_preconditioner(
        &mut self,
        name: &str,
        p: Box<dyn crate::precond::Preconditioner>,
        calls: u64,
    ) {
        if let Some(entry) = self.entries.get_mut(name) {
            entry.precond_calls += calls;
            entry.precond = Some(p);
        }
    }

    /// Per-matrix stats rows, sorted by name.
    pub fn stats(&self) -> Vec<EntryStats> {
        let mut rows: Vec<EntryStats> = self.entries.values().map(|e| e.stats()).collect();
        rows.sort_by(|a, b| a.name.cmp(&b.name));
        rows
    }

    /// Total extra bytes held by transformed copies (memory-policy
    /// observability).
    pub fn extra_bytes(&self) -> usize {
        self.entries.values().map(|e| e.extra_bytes()).sum()
    }

    /// The format a registered matrix is currently served from (the
    /// split plan's block format when a cross-shard split serves it).
    pub fn serving_format(&self, name: &str) -> Option<FormatKind> {
        self.entries.get(name).map(|e| match (&e.split, &e.state) {
            (Some(split), _) => split.implementation().required_format(),
            (None, AtState::Baseline) => FormatKind::Csr,
            (None, AtState::Transformed { plan, .. }) => plan.kind(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::{banded_circulant, generate, spec_by_name};
    use crate::rng::Rng;

    fn tuning(d_star: Option<f64>) -> TuningData {
        TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star,
        }
    }

    fn coord(d_star: Option<f64>) -> Coordinator {
        let mut cfg = CoordinatorConfig::new(tuning(d_star));
        cfg.threads = 2;
        Coordinator::new(cfg)
    }

    #[test]
    fn register_spmv_roundtrip_matches_reference() {
        let mut rng = Rng::new(1);
        let a = crate::matrixgen::random_csr(&mut rng, 50, 50, 0.1);
        let x: Vec<Value> = (0..50).map(|i| (i as f64 * 0.31).cos()).collect();
        let mut want = vec![0.0; 50];
        a.spmv(&x, &mut want);
        let mut c = coord(Some(3.1));
        c.register("m", a).unwrap();
        let y = c.spmv("m", &x).unwrap();
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn banded_matrix_gets_transformed_lazily() {
        let mut rng = Rng::new(2);
        let a = banded_circulant(&mut rng, 128, &[-1, 0, 1]);
        let mut c = coord(Some(3.1));
        c.register("band", a).unwrap();
        assert_eq!(c.serving_format("band"), Some(FormatKind::Csr), "lazy until first call");
        let x = vec![1.0; 128];
        c.spmv("band", &x).unwrap();
        assert_eq!(c.serving_format("band"), Some(FormatKind::Ell));
        assert!(c.extra_bytes() > 0);
        let s = &c.stats()[0];
        assert_eq!(s.transformed_calls, 1);
        assert!(s.t_trans > 0.0);
    }

    #[test]
    fn high_dmat_matrix_stays_on_crs() {
        let spec = spec_by_name("memplus").unwrap();
        let a = generate(&spec, 5, 0.02);
        let n = a.n_rows();
        let mut c = coord(Some(0.1)); // SR16000-style threshold
        c.register("memplus", a).unwrap();
        let x = vec![1.0; n];
        c.spmv("memplus", &x).unwrap();
        assert_eq!(c.serving_format("memplus"), Some(FormatKind::Csr));
        assert_eq!(c.extra_bytes(), 0);
    }

    #[test]
    fn memory_policy_vetoes_transformation() {
        let mut rng = Rng::new(3);
        let a = banded_circulant(&mut rng, 256, &[-1, 0, 1]);
        let mut cfg = CoordinatorConfig::new(tuning(Some(3.1)));
        cfg.policy = MemoryPolicy::with_budget(16); // absurdly tight
        let mut c = Coordinator::new(cfg);
        c.register("band", a).unwrap();
        let x = vec![1.0; 256];
        c.spmv("band", &x).unwrap();
        assert_eq!(c.serving_format("band"), Some(FormatKind::Csr));
    }

    #[test]
    fn duplicate_and_unknown_names_rejected() {
        let mut c = coord(None);
        c.register("a", Csr::identity(4)).unwrap();
        assert!(c.register("a", Csr::identity(4)).is_err());
        assert!(c.spmv("nope", &[1.0; 4]).is_err());
        assert!(c.spmv("a", &[1.0; 3]).is_err(), "dimension mismatch");
        assert!(c.evict("a"));
        assert!(!c.evict("a"));
    }

    #[test]
    fn stats_sorted_and_complete() {
        let mut c = coord(Some(3.1));
        c.register("zz", Csr::identity(8)).unwrap();
        c.register("aa", Csr::identity(8)).unwrap();
        let names: Vec<String> = c.stats().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names, vec!["aa", "zz"]);
        assert_eq!(c.names(), vec!["aa", "zz"]);
    }

    #[test]
    fn spmv_batch_is_tiled_and_matches_reference() {
        let mut rng = Rng::new(9);
        let a = banded_circulant(&mut rng, 96, &[-1, 0, 1]);
        let mut c = coord(Some(3.1));
        c.register("band", a.clone()).unwrap();
        let xs: Vec<Vec<Value>> = (0..5)
            .map(|k| (0..96).map(|i| ((i + k) as f64 * 0.23).sin()).collect())
            .collect();
        let ys = c.spmv_batch("band", &xs).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let mut want = vec![0.0; 96];
            a.spmv(x, &mut want);
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9);
            }
        }
        let s = &c.stats()[0];
        assert_eq!(s.calls, 5);
        assert_eq!(s.transformed_calls, 5, "one trigger served the whole batch");
        // Batched and looped serving agree bitwise.
        let mut c2 = coord(Some(3.1));
        c2.register("band", a).unwrap();
        let looped: Vec<Vec<Value>> = xs.iter().map(|x| c2.spmv("band", x).unwrap()).collect();
        assert_eq!(ys, looped);
        // Empty batches are a no-op, not an error.
        assert!(c.spmv_batch("band", &[]).unwrap().is_empty());
        // Bad widths are rejected.
        assert!(c.spmv_batch("band", &[vec![0.0; 7]]).is_err());
    }

    #[test]
    fn matrices_route_to_distinct_shard_pools() {
        let mut cfg = CoordinatorConfig::new(tuning(None));
        cfg.threads = 2;
        cfg.shards = 2;
        let mut c = Coordinator::new(cfg);
        // Find two names on different shards (16 candidates must cover 2).
        let names: Vec<String> = (0..16).map(|i| format!("m-{i}")).collect();
        let a = names.iter().find(|n| c.shard_of(n) == 0).unwrap().clone();
        let b = names.iter().find(|n| c.shard_of(n) == 1).unwrap().clone();
        c.register(&a, Csr::identity(8)).unwrap();
        c.register(&b, Csr::identity(8)).unwrap();
        assert!(!Arc::ptr_eq(
            c.planner().planner_for(&a).pool(),
            c.planner().planner_for(&b).pool(),
        ));
        let x = vec![1.0; 8];
        assert_eq!(c.spmv(&a, &x).unwrap(), x, "shard 0 serves correctly");
        assert_eq!(c.spmv(&b, &x).unwrap(), x, "shard 1 serves correctly");
    }

    #[test]
    fn inject_sample_requires_adaptive() {
        // Pin the loop off explicitly — the CI adaptive leg sets
        // SPMV_AT_ADAPTIVE=1, which CoordinatorConfig::new would inherit.
        let mut cfg = CoordinatorConfig::new(tuning(None));
        cfg.threads = 2;
        cfg.adaptive.enabled = false;
        let mut c = Coordinator::new(cfg);
        c.register("m", Csr::identity(4)).unwrap();
        assert!(c.inject_sample("m", Implementation::EllRowInner, 1e-6, 4).is_err());
        assert!(c.inject_sample("ghost", Implementation::EllRowInner, 1e-6, 4).is_err());
        assert!(!c.adaptive_enabled());
    }

    #[test]
    fn forced_replan_flips_and_swaps() {
        // Adaptive on with exploration disabled: decisions only move when
        // told to (injected telemetry / forced replan). EllRowInner keeps
        // per-row accumulation order identical to CRS, so flips are
        // bitwise-invisible.
        let mut cfg = CoordinatorConfig::new(TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowInner,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        });
        cfg.threads = 2;
        cfg.adaptive.enabled = true;
        cfg.adaptive.epsilon = 0.0;
        let mut c = Coordinator::new(cfg);
        let mut rng = Rng::new(17);
        let a = banded_circulant(&mut rng, 96, &[-1, 0, 1]);
        c.register("band", a).unwrap();
        let x = vec![1.0; 96];
        let first = c.spmv("band", &x).unwrap();
        assert_eq!(c.serving_format("band"), Some(FormatKind::Ell));

        // Learned correction says the transformation does NOT pay for this
        // D_mat bucket: the forced replan must flip back to CRS.
        c.learned.record(0.0, 0.25);
        let s = c.replan("band").unwrap();
        assert_eq!(c.serving_format("band"), Some(FormatKind::Csr));
        assert_eq!(s.replans, 1);
        assert_eq!(c.spmv("band", &x).unwrap(), first, "flip must not change results");
        // The transformed plan is parked, not dropped: still accounted.
        assert!(c.extra_bytes() > 0, "shadow plan keeps its bytes");

        // Correction now says it pays again: flip forward, promoting the
        // parked shadow in O(1); replans counts both flips.
        c.learned.record(0.0, 100.0); // running mean pulls >= c
        let s = c.replan("band").unwrap();
        assert_eq!(c.serving_format("band"), Some(FormatKind::Ell));
        assert_eq!(s.replans, 2);
        assert_eq!(c.spmv("band", &x).unwrap(), first);
    }

    #[test]
    fn decision_log_replays_to_the_live_serving_state() {
        let mut cfg = CoordinatorConfig::new(tuning(Some(3.1)));
        cfg.threads = 2;
        let log = DecisionLog::in_memory();
        cfg.decision_log = Some(log.clone());
        let mut c = Coordinator::new(cfg);
        let mut rng = Rng::new(21);
        c.register("band", banded_circulant(&mut rng, 96, &[-1, 0, 1])).unwrap();
        c.register("id", Csr::identity(16)).unwrap();
        c.spmv("band", &vec![1.0; 96]).unwrap(); // fires the deferred transform
        c.spmv("id", &vec![1.0; 16]).unwrap();
        c.replan("id").unwrap();
        let lines = log.tail(usize::MAX);
        assert!(lines.iter().any(|l| l.contains("\"event\":\"register\"")));
        assert!(lines.iter().any(|l| l.contains("\"event\":\"transform\"")));
        assert!(lines.iter().any(|l| l.contains("\"event\":\"replan\"")));
        // Folding the log reproduces the stats row for every matrix.
        let replayed = decision_log::replay(lines.iter().map(String::as_str));
        for s in c.stats() {
            let r = &replayed[&s.name];
            assert_eq!(r.kernel, s.serving.name(), "kernel for '{}'", s.name);
            assert_eq!(r.partition, s.partition, "partition for '{}'", s.name);
            assert_eq!(r.split_parts as usize, s.split_parts, "split for '{}'", s.name);
            assert!(!r.split_vetoed);
        }
    }

    #[test]
    fn skewed_matrix_serves_merge_baseline_and_flips_to_rowpar() {
        // The skew pick routes a giant-row matrix to the merge-path CRS
        // baseline; with the format decision keeping CRS, the adaptive
        // rival arm is the conventional row partitioning, and injected
        // telemetry favouring it flips the serving plan — bitwise
        // invisibly, since both arms match csr_seq exactly.
        if std::env::var_os("SPMV_AT_PARTITION").is_some() {
            return; // the pick is forced; the skew heuristic is not in play
        }
        let mut t: Vec<(usize, usize, Value)> = (0..100).map(|r| (r, r, 2.0)).collect();
        for col in 0..100 {
            t.push((50, col, 1.0 + (col % 7) as Value * 0.0625));
        }
        let a = Csr::from_triplets(100, 100, &t).unwrap();
        let mut cfg = CoordinatorConfig::new(tuning(None)); // keep CRS
        cfg.threads = 2;
        cfg.adaptive.enabled = true;
        cfg.adaptive.epsilon = 0.0;
        let mut c = Coordinator::new(cfg);
        c.register("skew", a.clone()).unwrap();
        let e = &c.entries["skew"];
        assert_eq!(e.baseline.implementation(), Implementation::CsrMergePar);
        assert_eq!(e.candidate, Implementation::CsrRowPar);
        assert_eq!(c.stats()[0].partition, "merge");

        c.inject_sample("skew", Implementation::CsrRowPar, 1e-12, 16).unwrap();
        let x: Vec<Value> = (0..100).map(|i| 1.0 + (i % 9) as Value * 0.125).collect();
        let mut want = vec![0.0; 100];
        a.spmv(&x, &mut want);
        let ad = crate::autotune::adaptive::AdaptiveConfig::default();
        for _ in 0..ad.window * ad.flip_windows as u64 {
            assert_eq!(c.spmv("skew", &x).unwrap(), want, "bitwise across the flip");
        }
        let s = &c.stats()[0];
        assert_eq!(s.replans, 1, "the controller promoted the row-parallel rival");
        assert_eq!(s.serving, Implementation::CsrRowPar);
        assert_eq!(c.serving_format("skew"), Some(FormatKind::Csr), "still zero-copy CRS");
        assert_eq!(c.spmv("skew", &x).unwrap(), want, "bitwise-stable after the flip");
    }

    #[test]
    fn repeated_calls_reuse_one_plan_and_pool() {
        // Many calls through one coordinator: results stay bitwise stable
        // (same plan, same partition, same reduction order every call).
        let mut rng = Rng::new(8);
        let a = banded_circulant(&mut rng, 300, &[-1, 0, 1, 2]);
        let mut c = coord(Some(3.1));
        c.register("m", a).unwrap();
        let x: Vec<Value> = (0..300).map(|i| (i as f64 * 0.17).sin()).collect();
        let first = c.spmv("m", &x).unwrap();
        for _ in 0..5 {
            let again = c.spmv("m", &x).unwrap();
            assert_eq!(first, again, "repeated execution must be bitwise stable");
        }
        let s = &c.stats()[0];
        assert_eq!(s.calls, 6);
        assert!(s.t_trans > 0.0, "transformed exactly once");
    }
}
