//! Sharded plan serving: N independent worker pools with deterministic
//! matrix→shard routing.
//!
//! A single crate-wide [`ParPool`] serialises every `execute_many` in the
//! process on one job slot: two clients batching SpMM against *different*
//! matrices still take turns on the same workers. [`PlanShards`] owns N
//! independent pools (N from the `SPMV_AT_SHARDS` environment variable,
//! or explicit configuration) and routes each registry key to one shard
//! by a stable FNV-1a hash, so plans for different matrices land on
//! disjoint worker sets and proceed concurrently. [`ShardedPlanner`] puts
//! one [`Planner`] (same tuning table, same memory policy) over each
//! shard's pool; the coordinator registers every matrix through
//! `planner_for(key)` and the sharded server runs one request loop per
//! shard on top.
//!
//! This is also the hook the NUMA roadmap item builds on: pinning each
//! shard's pool to one socket turns key-routing into locality-routing.
//! The adaptive layer rides the same partitioning: every shard's
//! coordinator owns the [`crate::autotune::adaptive`] controllers for the
//! matrices routed to it, so re-planning happens on the matrix's own
//! shard — rebuilds never cross worker sets, and a flip on one shard
//! cannot stall serving on another.

use crate::autotune::online::TuningData;
use crate::autotune::MemoryPolicy;
use crate::spmv::pool::ParPool;
use crate::spmv::Planner;
use std::sync::Arc;

/// The configured shard count: `SPMV_AT_SHARDS` when set to a positive
/// integer, else 1 (single-pool serving, the pre-sharding behaviour).
pub fn configured_shards() -> usize {
    match std::env::var("SPMV_AT_SHARDS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => 1,
        },
        Err(_) => 1,
    }
}

/// Split `total_threads` workers across `shards` pools: every shard gets
/// the floor share, the first `total % shards` shards absorb the
/// remainder, and no shard drops below one thread (so a shard count
/// above the thread budget oversubscribes by design rather than
/// spawning dead pools — pick `SPMV_AT_SHARDS ≤ SPMV_AT_THREADS`).
pub fn shard_thread_counts(total_threads: usize, shards: usize) -> Vec<usize> {
    let n = shards.max(1);
    let base = total_threads / n;
    let rem = total_threads % n;
    (0..n).map(|i| (base + usize::from(i < rem)).max(1)).collect()
}

/// Stable FNV-1a over the registry key — deterministic across processes
/// (unlike `DefaultHasher`), so a key always lands on the same shard (and
/// the adaptive layer can seed per-matrix exploration deterministically).
pub(crate) fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The shard index a registry key routes to among `shards` shards — the
/// one routing function shared by [`PlanShards`], the sharded server's
/// client, and anything else that must agree on placement.
pub fn route_key(key: &str, shards: usize) -> u64 {
    fnv1a(key) % shards.max(1) as u64
}

/// N independent worker pools plus the key→shard route.
pub struct PlanShards {
    pools: Vec<Arc<ParPool>>,
}

impl PlanShards {
    /// `n_shards` pools of `threads_each` workers.
    pub fn new(n_shards: usize, threads_each: usize) -> Self {
        let n = n_shards.max(1);
        let pools = (0..n).map(|_| Arc::new(ParPool::new(threads_each))).collect();
        Self { pools }
    }

    /// `n_shards` pools dividing `total_threads` workers between them,
    /// remainder spread over the leading shards
    /// (see [`shard_thread_counts`]).
    pub fn spread(n_shards: usize, total_threads: usize) -> Self {
        let pools = shard_thread_counts(total_threads, n_shards)
            .into_iter()
            .map(|t| Arc::new(ParPool::new(t)))
            .collect();
        Self { pools }
    }

    /// Shards sized from the environment: `SPMV_AT_SHARDS` pools dividing
    /// `total_threads` workers between them.
    pub fn from_env(total_threads: usize) -> Self {
        Self::spread(configured_shards(), total_threads)
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.pools.len()
    }

    /// Always false (there is at least one shard).
    pub fn is_empty(&self) -> bool {
        self.pools.is_empty()
    }

    /// The shard a registry key routes to.
    pub fn route(&self, key: &str) -> usize {
        route_key(key, self.pools.len()) as usize
    }

    /// Pool of shard `i`.
    ///
    /// # Panics
    /// Panics if `i >= len()`.
    pub fn pool(&self, i: usize) -> &Arc<ParPool> {
        &self.pools[i]
    }

    /// Pool the key's shard owns.
    pub fn pool_for(&self, key: &str) -> &Arc<ParPool> {
        self.pool(self.route(key))
    }
}

impl std::fmt::Debug for PlanShards {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanShards")
            .field("shards", &self.pools.len())
            .field("threads_each", &self.pools.first().map(|p| p.size()))
            .finish()
    }
}

/// One [`Planner`] per shard, all sharing one tuning table and memory
/// policy; plans for a key build on (and execute on) the key's shard pool.
pub struct ShardedPlanner {
    shards: PlanShards,
    planners: Vec<Planner>,
}

impl ShardedPlanner {
    /// A planner per shard over `shards`.
    pub fn new(tuning: TuningData, policy: MemoryPolicy, shards: PlanShards) -> Self {
        let planners = (0..shards.len())
            .map(|i| Planner::new(tuning.clone(), policy, shards.pool(i).clone()))
            .collect();
        Self { shards, planners }
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.planners.len()
    }

    /// Always false (there is at least one shard).
    pub fn is_empty(&self) -> bool {
        self.planners.is_empty()
    }

    /// The shard a registry key routes to.
    pub fn shard_of(&self, key: &str) -> usize {
        self.shards.route(key)
    }

    /// Planner of shard `i`.
    ///
    /// # Panics
    /// Panics if `i >= len()`.
    pub fn planner(&self, i: usize) -> &Planner {
        &self.planners[i]
    }

    /// The planner serving a registry key.
    pub fn planner_for(&self, key: &str) -> &Planner {
        self.planner(self.shard_of(key))
    }

    /// The underlying pools + route.
    pub fn shards(&self) -> &PlanShards {
        &self.shards
    }
}

impl std::fmt::Debug for ShardedPlanner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedPlanner").field("shards", &self.shards).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::Implementation;

    fn tuning() -> TuningData {
        TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        }
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let s = PlanShards::new(4, 1);
        for key in ["a", "b", "xenon1", "memplus", "m-0", "m-1", "m-2"] {
            let r = s.route(key);
            assert!(r < 4);
            assert_eq!(r, s.route(key), "route must be stable");
            assert!(Arc::ptr_eq(s.pool_for(key), s.pool(r)));
        }
    }

    #[test]
    fn distinct_keys_spread_over_shards() {
        let s = PlanShards::new(2, 1);
        // Some pair among a small key set must land on each shard.
        let hit: std::collections::HashSet<usize> =
            (0..16).map(|i| s.route(&format!("m-{i}"))).collect();
        assert_eq!(hit.len(), 2, "16 keys over 2 shards must hit both");
    }

    #[test]
    fn sharded_planner_builds_on_the_routed_pool() {
        let sp = ShardedPlanner::new(tuning(), MemoryPolicy::unlimited(), PlanShards::new(3, 2));
        assert_eq!(sp.len(), 3);
        for key in ["p", "q", "r", "s"] {
            let shard = sp.shard_of(key);
            assert!(Arc::ptr_eq(sp.planner_for(key).pool(), sp.shards().pool(shard)));
        }
    }

    #[test]
    fn thread_split_spreads_remainder_and_keeps_every_shard_alive() {
        assert_eq!(shard_thread_counts(8, 2), vec![4, 4]);
        // Remainder workers go to the leading shards, none stranded.
        assert_eq!(shard_thread_counts(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(shard_thread_counts(10, 4).iter().sum::<usize>(), 10);
        // More shards than threads: every shard stays alive at width 1.
        assert_eq!(shard_thread_counts(1, 4), vec![1, 1, 1, 1]);
        assert_eq!(shard_thread_counts(0, 3), vec![1, 1, 1]);
        assert_eq!(shard_thread_counts(5, 0), vec![5]);
        let s = PlanShards::spread(4, 10);
        assert_eq!(s.len(), 4);
        assert_eq!(s.pool(0).size(), 3);
        assert_eq!(s.pool(3).size(), 2);
    }

    #[test]
    fn env_default_is_single_shard() {
        // SPMV_AT_SHARDS unset in the test environment → 1 shard.
        if std::env::var("SPMV_AT_SHARDS").is_err() {
            assert_eq!(configured_shards(), 1);
            assert_eq!(PlanShards::from_env(4).len(), 1);
        }
    }
}
