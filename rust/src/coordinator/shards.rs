//! Sharded plan serving: N independent worker pools with deterministic
//! matrix→shard routing.
//!
//! A single crate-wide [`ParPool`] serialises every `execute_many` in the
//! process on one job slot: two clients batching SpMM against *different*
//! matrices still take turns on the same workers. [`PlanShards`] owns N
//! independent pools (N from the `SPMV_AT_SHARDS` environment variable,
//! or explicit configuration) and routes each registry key to one shard
//! by a stable FNV-1a hash, so plans for different matrices land on
//! disjoint worker sets and proceed concurrently. [`ShardedPlanner`] puts
//! one [`Planner`] (same tuning table, same memory policy) over each
//! shard's pool; the coordinator registers every matrix through
//! `planner_for(key)` and the sharded server runs one request loop per
//! shard on top.
//!
//! **This is the crate's NUMA locality layer.** Shard counts default to
//! the machine's socket count ([`crate::machine::Topology`]), shard `i`'s
//! pool is pinned to socket `i mod sockets`
//! ([`crate::spmv::pool::ParPool::new_pinned`]), and — because every plan
//! build and adaptive re-plan materialises its arrays through the owning
//! pool's [`ParPool::run_init`] fan-out — key-routing *is*
//! socket-routing: a matrix's transformed copies are first-touched on,
//! and forever streamed from, the socket its registry key hashes to. The
//! adaptive layer rides the same partitioning: every shard's coordinator
//! owns the [`crate::autotune::adaptive`] controllers for the matrices
//! routed to it, so re-planning happens on the matrix's own shard —
//! rebuilds never cross worker sets (a NUMA re-plan is exactly a
//! first-touch rebuild on the right socket), and a flip on one shard
//! cannot stall serving on another.
//!
//! For a single matrix too large for one socket, [`SplitPlan`] splits the
//! row range across shards ([`ShardedPlanner::plan_split`] /
//! [`ShardedPlanner::execute_split_many`] /
//! [`ShardedPlanner::execute_split`]): each shard holds and streams only
//! its row block, all blocks run **concurrently** through the plan's
//! cross-pool join ([`crate::spmv::pool::PoolGroup`], overlap observable
//! via [`SplitPlan::max_concurrent_blocks`]), and the per-row results are
//! merged — bitwise identical to the unsplit
//! [`crate::spmv::SpmvPlan::execute_many`] for the row-oriented kernels.
//! The coordinator routes oversized matrices through a *cached* split
//! automatically ([`SplitThreshold`], `SPMV_AT_SPLIT_ROWS` /
//! `--split-rows`; off on single-shard planners).
//!
//! # Example
//!
//! Build a tiny matrix, plan it on its routed shard, execute, assert:
//!
//! ```
//! use spmv_at::coordinator::{PlanShards, ShardedPlanner};
//! use spmv_at::autotune::online::TuningData;
//! use spmv_at::autotune::MemoryPolicy;
//! use spmv_at::spmv::Implementation;
//! use spmv_at::formats::Csr;
//! use std::sync::Arc;
//!
//! let tuning = TuningData {
//!     backend: "sim:ES2".into(),
//!     imp: Implementation::EllRowInner,
//!     threads: 1,
//!     c: 1.0,
//!     d_star: Some(3.1),
//! };
//! let sp = ShardedPlanner::new(tuning, MemoryPolicy::unlimited(), PlanShards::new(2, 1));
//! let a = Arc::new(Csr::identity(3));
//! let mut plan = sp.planner_for("m").plan_for(&a, Implementation::CsrRowPar).unwrap();
//! let mut y = vec![0.0; 3];
//! plan.execute(&[1.0, 2.0, 3.0], &mut y).unwrap();
//! assert_eq!(y, vec![1.0, 2.0, 3.0]);
//!
//! // A cross-shard row split of the same operator agrees bitwise.
//! let mut split = sp.plan_split(&a, Implementation::CsrRowPar, 2).unwrap();
//! let xs = vec![vec![1.0, 2.0, 3.0]];
//! let mut ys = vec![vec![0.0; 3]];
//! sp.execute_split_many(&mut split, &xs, &mut ys).unwrap();
//! assert_eq!(ys[0], y);
//! ```

use crate::autotune::online::TuningData;
use crate::autotune::MemoryPolicy;
use crate::formats::{Csr, SparseMatrix};
use crate::machine::Topology;
use crate::spmv::partition::split_by_nnz;
use crate::spmv::pool::{ParPool, PoolGroup};
use crate::spmv::{Implementation, Planner, SpmvPlan};
use crate::{Result, Value};
use std::ops::Range;
use std::sync::Arc;

/// Non-zeros per shard below which an automatic split is not worth its
/// merge overhead: with the default heuristic
/// ([`SplitThreshold::Auto`]) a matrix splits only when every socket
/// would stream at least this many entries (~48 MiB of CRS data — well
/// past any LLC, so the stream is memory-bound and locality pays).
pub const SPLIT_AUTO_NNZ_PER_SHARD: usize = 1 << 22;

/// When the coordinator routes a matrix through a cached cross-shard
/// [`SplitPlan`] instead of a single-shard plan. Never splits on
/// single-shard planners (single-socket machines), whatever the
/// threshold says.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitThreshold {
    /// Never auto-split (`SPMV_AT_SPLIT_ROWS=0` / `--split-rows 0`): the
    /// pre-split serving path, byte for byte.
    Off,
    /// Split matrices with at least this many rows.
    Rows(usize),
    /// The default heuristic: split when
    /// `nnz >= SPLIT_AUTO_NNZ_PER_SHARD × shards`, i.e. when the matrix
    /// is big enough that each socket still streams a memory-bound block.
    Auto,
}

impl SplitThreshold {
    /// The configured threshold: `SPMV_AT_SPLIT_ROWS` when set (`0`
    /// disables, a positive integer is an explicit row threshold),
    /// [`SplitThreshold::Auto`] otherwise. An unparseable value falls
    /// back to `Auto` with a stderr warning — silently dropping an
    /// explicitly requested threshold would also silently change the
    /// CLI's serving shape (see `--split-rows` in `main.rs`).
    pub fn from_env() -> Self {
        match std::env::var("SPMV_AT_SPLIT_ROWS") {
            Ok(s) if !s.trim().is_empty() => Self::parse(&s).unwrap_or_else(|| {
                eprintln!(
                    "spmv-at: ignoring invalid SPMV_AT_SPLIT_ROWS='{}' \
                     (expected 0, a positive integer, or 'auto'); using auto",
                    s.trim()
                );
                Self::Auto
            }),
            _ => Self::Auto,
        }
    }

    /// Parse a CLI/env value: `0` → [`SplitThreshold::Off`], a positive
    /// integer → [`SplitThreshold::Rows`], `auto` →
    /// [`SplitThreshold::Auto`]; `None` for anything else.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("auto") {
            return Some(Self::Auto);
        }
        match s.parse::<usize>() {
            Ok(0) => Some(Self::Off),
            Ok(n) => Some(Self::Rows(n)),
            Err(_) => None,
        }
    }

    /// The truth function: should a matrix of `n_rows` rows and `nnz`
    /// non-zeros serve through a cross-shard split on a planner of
    /// `shards` pools?
    pub fn should_split(self, n_rows: usize, nnz: usize, shards: usize) -> bool {
        if shards <= 1 || n_rows < 2 {
            return false;
        }
        match self {
            Self::Off => false,
            Self::Rows(r) => n_rows >= r,
            Self::Auto => nnz >= SPLIT_AUTO_NNZ_PER_SHARD.saturating_mul(shards),
        }
    }
}

impl std::fmt::Display for SplitThreshold {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Off => f.write_str("off"),
            Self::Rows(r) => write!(f, ">={r} rows"),
            Self::Auto => write!(f, "auto (nnz >= {SPLIT_AUTO_NNZ_PER_SHARD} per shard)"),
        }
    }
}

/// The configured shard count: `SPMV_AT_SHARDS` when set to a positive
/// integer, else the detected **socket count**
/// ([`Topology::detect`] — 1 on single-node machines, which is the
/// pre-NUMA behaviour; `SPMV_AT_TOPOLOGY=2:4` makes it 2 anywhere).
pub fn configured_shards() -> usize {
    match std::env::var("SPMV_AT_SHARDS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => Topology::detect().n_sockets(),
        },
        Err(_) => Topology::detect().n_sockets(),
    }
}

/// Split `total_threads` workers across `shards` pools: every shard gets
/// the floor share and the leading shards absorb the remainder. A shard
/// count above the thread budget is **clamped** to it — the returned
/// length is the effective shard count — so no shard is ever a
/// zero-worker pool and no worker is oversubscribed across pools.
/// Degenerate inputs clamp to one shard / one thread. Pure (display-only
/// callers use it freely); the pool-spawning sites log the clamp through
/// [`warn_if_clamped`].
pub fn shard_thread_counts(total_threads: usize, shards: usize) -> Vec<usize> {
    let total = total_threads.max(1);
    let n = shards.max(1).min(total);
    let base = total / n;
    let rem = total % n;
    (0..n).map(|i| base + usize::from(i < rem)).collect()
}

/// Log (once, from the site that actually spawns pools) that a requested
/// shard count was clamped to the thread budget.
pub(crate) fn warn_if_clamped(total_threads: usize, requested: usize, effective: usize) {
    let want = requested.max(1);
    if effective < want {
        eprintln!(
            "spmv-at: clamping {want} shard(s) to {effective} — only {} worker thread(s) \
             configured (raise SPMV_AT_THREADS or lower SPMV_AT_SHARDS)",
            total_threads.max(1)
        );
    }
}

/// Stable FNV-1a over the registry key — deterministic across processes
/// (unlike `DefaultHasher`), so a key always lands on the same shard (and
/// the adaptive layer can seed per-matrix exploration deterministically).
pub(crate) fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The shard index a registry key routes to among `shards` shards — the
/// one routing function shared by [`PlanShards`], the sharded server's
/// client, and anything else that must agree on placement.
pub fn route_key(key: &str, shards: usize) -> u64 {
    fnv1a(key) % shards.max(1) as u64
}

/// N independent worker pools plus the key→shard route.
pub struct PlanShards {
    pools: Vec<Arc<ParPool>>,
}

impl PlanShards {
    /// `n_shards` unpinned pools of `threads_each` workers (tests and
    /// single-node setups; production serving goes through
    /// [`PlanShards::spread`], which pins).
    pub fn new(n_shards: usize, threads_each: usize) -> Self {
        let n = n_shards.max(1);
        let pools = (0..n).map(|_| Arc::new(ParPool::new(threads_each))).collect();
        Self { pools }
    }

    /// Wrap explicitly built pools (the sharded server hands each
    /// per-shard coordinator its own pre-pinned pool).
    ///
    /// # Panics
    /// Panics if `pools` is empty.
    pub fn from_pools(pools: Vec<Arc<ParPool>>) -> Self {
        assert!(!pools.is_empty(), "PlanShards needs at least one pool");
        Self { pools }
    }

    /// `n_shards` pools dividing `total_threads` workers between them
    /// (clamped + remainder spread, see [`shard_thread_counts`]), each
    /// pinned to socket `i mod sockets` of the detected
    /// [`Topology`] (no pinning on single-socket machines).
    pub fn spread(n_shards: usize, total_threads: usize) -> Self {
        Self::spread_on(n_shards, total_threads, &Topology::detect())
    }

    /// [`PlanShards::spread`] against an explicit topology (tests,
    /// benches, and anything that already detected one).
    pub fn spread_on(n_shards: usize, total_threads: usize, topo: &Topology) -> Self {
        let counts = shard_thread_counts(total_threads, n_shards);
        warn_if_clamped(total_threads, n_shards, counts.len());
        let pools = counts
            .into_iter()
            .enumerate()
            .map(|(i, t)| Arc::new(ParPool::new_pinned(t, topo.shard_cpus(i))))
            .collect();
        Self { pools }
    }

    /// Shards sized from the environment: [`configured_shards`] pools
    /// (socket count unless `SPMV_AT_SHARDS` overrides) dividing
    /// `total_threads` workers between them, socket-pinned.
    pub fn from_env(total_threads: usize) -> Self {
        Self::spread(configured_shards(), total_threads)
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.pools.len()
    }

    /// Always false (there is at least one shard).
    pub fn is_empty(&self) -> bool {
        self.pools.is_empty()
    }

    /// The shard a registry key routes to.
    pub fn route(&self, key: &str) -> usize {
        route_key(key, self.pools.len()) as usize
    }

    /// Pool of shard `i`.
    ///
    /// # Panics
    /// Panics if `i >= len()`.
    pub fn pool(&self, i: usize) -> &Arc<ParPool> {
        &self.pools[i]
    }

    /// Pool the key's shard owns.
    pub fn pool_for(&self, key: &str) -> &Arc<ParPool> {
        self.pool(self.route(key))
    }
}

impl std::fmt::Debug for PlanShards {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanShards")
            .field("shards", &self.pools.len())
            .field("threads_each", &self.pools.first().map(|p| p.size()))
            .finish()
    }
}

/// One [`Planner`] per shard, all sharing one tuning table and memory
/// policy; plans for a key build on (and execute on) the key's shard pool.
pub struct ShardedPlanner {
    shards: PlanShards,
    planners: Vec<Planner>,
}

impl ShardedPlanner {
    /// A planner per shard over `shards`.
    pub fn new(tuning: TuningData, policy: MemoryPolicy, shards: PlanShards) -> Self {
        let planners = (0..shards.len())
            .map(|i| Planner::new(tuning.clone(), policy, shards.pool(i).clone()))
            .collect();
        Self { shards, planners }
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.planners.len()
    }

    /// Always false (there is at least one shard).
    pub fn is_empty(&self) -> bool {
        self.planners.is_empty()
    }

    /// The shard a registry key routes to.
    pub fn shard_of(&self, key: &str) -> usize {
        self.shards.route(key)
    }

    /// Planner of shard `i`.
    ///
    /// # Panics
    /// Panics if `i >= len()`.
    pub fn planner(&self, i: usize) -> &Planner {
        &self.planners[i]
    }

    /// The planner serving a registry key.
    pub fn planner_for(&self, key: &str) -> &Planner {
        self.planner(self.shard_of(key))
    }

    /// The underlying pools + route.
    pub fn shards(&self) -> &PlanShards {
        &self.shards
    }

    /// Build a cross-shard row split of one matrix: the row range is cut
    /// into `splits` nnz-balanced blocks, block `i` is sliced out
    /// ([`Csr::slice_rows`]) and planned **on shard `i mod shards`** —
    /// so on socket-pinned pools each socket holds (first-touched, via
    /// the build's [`crate::spmv::pool::ParPool::run_init`] fan-outs)
    /// exactly the row block it will stream. `splits == 1` degenerates to
    /// an ordinary single-shard plan sharing the CRS original zero-copy.
    ///
    /// Use the row-oriented kernels (`CsrSeq`/`CsrRowPar`/`EllRowInner`/
    /// `EllRowOuter`): every output row is produced by exactly one block
    /// with unchanged per-row accumulation order, so results are
    /// bitwise-identical to the unsplit plan. (The COO column-major
    /// kernels reorder entries *across* rows of the whole matrix and are
    /// not split-stable.)
    ///
    /// # Errors
    /// Fails if any block's transformation fails (e.g. an ELL budget
    /// overflow).
    pub fn plan_split(
        &self,
        csr: &Arc<Csr>,
        imp: Implementation,
        splits: usize,
    ) -> Result<SplitPlan> {
        let n = csr.n_rows();
        let mut parts = Vec::new();
        let mut pools = Vec::new();
        for (i, rows) in split_by_nnz(&csr.row_ptr, splits.max(1)).into_iter().enumerate() {
            let shard = i % self.len();
            let block = if rows.start == 0 && rows.end == n {
                Arc::clone(csr)
            } else {
                Arc::new(csr.slice_rows(rows.clone()))
            };
            let plan = self.planner(shard).plan_for(&block, imp)?;
            pools.push(plan.pool().clone());
            parts.push(SplitPart {
                rows,
                shard,
                plan,
                scratch: Vec::new(),
                scratch1: Vec::new(),
                error: None,
            });
        }
        // One uniform batch tile across the blocks (the most conservative
        // of their defaults), so the split's ⌈k/tile⌉ pass accounting
        // matches an unsplit plan forced to the same tile.
        let tile = parts.iter().map(|p| p.plan.batch_tile()).min().unwrap_or(1).max(1);
        for p in &mut parts {
            p.plan.set_batch_tile(tile);
        }
        Ok(SplitPlan {
            parts,
            pools,
            group: PoolGroup::new(),
            imp,
            batch_tile: tile,
            passes: 0,
            n_rows: n,
            n_cols: csr.n_cols(),
        })
    }

    /// Batched `Y = A·X` through a [`SplitPlan`]: the row blocks run
    /// their blocked SpMM tiles **concurrently**, each on its own shard
    /// pool, joined through the plan's [`PoolGroup`], and the per-block
    /// rows are merged into `ys` after the join. Bitwise-identical to
    /// [`crate::spmv::SpmvPlan::execute_many`] on the unsplit plan for
    /// the row-oriented kernels (see [`ShardedPlanner::plan_split`]).
    ///
    /// # Errors
    /// Fails on dimension mismatches.
    pub fn execute_split_many(
        &self,
        split: &mut SplitPlan,
        xs: &[Vec<Value>],
        ys: &mut [Vec<Value>],
    ) -> Result<()> {
        split.execute_many(xs, ys)
    }

    /// Single-vector `y = A·x` through a [`SplitPlan`] — the same
    /// concurrent fan-out as [`ShardedPlanner::execute_split_many`] for
    /// one right-hand side.
    ///
    /// # Errors
    /// Fails on dimension mismatches.
    pub fn execute_split(
        &self,
        split: &mut SplitPlan,
        x: &[Value],
        y: &mut [Value],
    ) -> Result<()> {
        split.execute(x, y)
    }
}

/// A single matrix row-split across shards: one [`SpmvPlan`] per
/// nnz-balanced row block, each on its own shard pool (= its own socket
/// when pinned). Built by [`ShardedPlanner::plan_split`]; executed by
/// [`ShardedPlanner::execute_split_many`] /
/// [`ShardedPlanner::execute_split`], which run the blocks
/// **concurrently** through the plan's [`PoolGroup`] — the cross-socket
/// wall-clock win, not just cross-socket placement. Observability:
/// [`SplitPlan::matrix_passes`] follows the unsplit ⌈k/tile⌉ semantics,
/// [`SplitPlan::max_concurrent_blocks`] proves ≥2 blocks were in flight
/// simultaneously, and each shard pool's `dispatch_count` still shows
/// which pool served which block.
pub struct SplitPlan {
    parts: Vec<SplitPart>,
    /// Part `i`'s pool handle (cached so the fan-out does not re-clone
    /// per call).
    pools: Vec<Arc<ParPool>>,
    /// The cross-pool join primitive + its overlap counters.
    group: PoolGroup,
    imp: Implementation,
    /// Uniform batch-tile width across the blocks.
    batch_tile: usize,
    /// Passes over the matrix data, unsplit semantics: 1 per `execute`,
    /// ⌈k/tile⌉ per `execute_many` — **not** summed per block (a split
    /// call streams each row exactly once per tile, same as unsplit).
    passes: u64,
    n_rows: usize,
    n_cols: usize,
}

struct SplitPart {
    rows: Range<usize>,
    shard: usize,
    plan: SpmvPlan,
    /// Per-part batched-output staging, reused across calls so the hot
    /// path does not allocate `k × block_rows` per execution.
    scratch: Vec<Vec<Value>>,
    /// Per-part single-RHS staging for [`SplitPlan::execute`].
    scratch1: Vec<Value>,
    /// Error a concurrent block execution reported (drained by the
    /// caller after the join).
    error: Option<anyhow::Error>,
}

impl SplitPlan {
    /// Number of row blocks (≤ requested splits when the matrix has
    /// fewer rows).
    pub fn parts(&self) -> usize {
        self.parts.len()
    }

    /// The shard serving block `i`.
    ///
    /// # Panics
    /// Panics if `i >= parts()`.
    pub fn part_shard(&self, i: usize) -> usize {
        self.parts[i].shard
    }

    /// The row range of block `i`.
    ///
    /// # Panics
    /// Panics if `i >= parts()`.
    pub fn part_rows(&self, i: usize) -> Range<usize> {
        self.parts[i].rows.clone()
    }

    /// Rows of the full operator.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Columns of the full operator.
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Matrix passes so far, with the **unsplit** ⌈k/tile⌉ semantics of
    /// [`SpmvPlan::matrix_passes`]: one `execute_many` of `k` right-hand
    /// sides adds ⌈k/tile⌉ once for the whole split call — every output
    /// row is streamed once per tile, exactly like the unsplit plan —
    /// not once per block. (Summing the per-block counters, as this
    /// method once did, over-counted by a factor of `parts`.) Per-block
    /// activity stays observable through each shard pool's
    /// `dispatch_count`.
    pub fn matrix_passes(&self) -> u64 {
        self.passes
    }

    /// The implementation every block executes.
    pub fn implementation(&self) -> Implementation {
        self.imp
    }

    /// The uniform batch-tile width the blocks execute with.
    pub fn batch_tile(&self) -> usize {
        self.batch_tile
    }

    /// Force one batch-tile width on every block (tests and sweeps).
    pub fn set_batch_tile(&mut self, tile: usize) {
        self.batch_tile = tile.max(1);
        for p in &mut self.parts {
            p.plan.set_batch_tile(tile);
        }
    }

    /// Seconds the blocks' transformations took at build time, summed
    /// (0 for CRS splits — same contract as
    /// [`SpmvPlan::transform_seconds`]).
    pub fn transform_seconds(&self) -> f64 {
        self.parts.iter().map(|p| p.plan.transform_seconds()).sum()
    }

    /// Extra bytes the blocks hold beyond the shared CRS original,
    /// summed. Transformed blocks report their converted copies; CRS
    /// blocks report their row *slices* (real copies, unlike the
    /// zero-copy unsplit CRS plan) — except the degenerate 1-block split,
    /// which shares the original by `Arc`.
    pub fn extra_bytes(&self) -> usize {
        if self.parts.len() <= 1 {
            return self.parts.iter().map(|p| p.plan.extra_bytes()).sum();
        }
        self.parts
            .iter()
            .map(|p| {
                if p.plan.kind() == crate::formats::FormatKind::Csr {
                    p.plan.memory_bytes()
                } else {
                    p.plan.extra_bytes()
                }
            })
            .sum()
    }

    /// High-water mark of row blocks simultaneously in flight across
    /// this plan's executions — ≥ 2 proves the blocks really ran
    /// concurrently rather than one after another. See
    /// [`PoolGroup::max_in_flight`].
    pub fn max_concurrent_blocks(&self) -> u64 {
        self.group.max_in_flight()
    }

    /// Concurrent fan-out executions so far ([`PoolGroup::join_count`]).
    pub fn join_count(&self) -> u64 {
        self.group.join_count()
    }

    /// The implementation behind [`ShardedPlanner::execute_split_many`]:
    /// dimension checks up front, then every block's tiled SpMM in
    /// flight at once through the [`PoolGroup`], then a deterministic
    /// caller-side merge of the disjoint row ranges.
    ///
    /// # Errors
    /// Fails on dimension mismatches, or if any block's execution failed
    /// (first block error wins; the join always completes).
    pub(crate) fn execute_many(&mut self, xs: &[Vec<Value>], ys: &mut [Vec<Value>]) -> Result<()> {
        anyhow::ensure!(
            xs.len() == ys.len(),
            "batch mismatch: {} inputs vs {} outputs",
            xs.len(),
            ys.len()
        );
        for x in xs {
            anyhow::ensure!(
                x.len() == self.n_cols,
                "x length {} != n_cols {}",
                x.len(),
                self.n_cols
            );
        }
        for y in ys.iter() {
            anyhow::ensure!(
                y.len() == self.n_rows,
                "y length {} != n_rows {}",
                y.len(),
                self.n_rows
            );
        }
        if xs.is_empty() {
            return Ok(());
        }
        self.group.join_all(&self.pools, &mut self.parts, |_i, part| {
            let block_rows = part.rows.end - part.rows.start;
            // Scratch (re)sizing happens on the block's own fan-out
            // thread, so growth is first-touched on the block's socket.
            if part.scratch.len() < xs.len() {
                part.scratch.resize_with(xs.len(), Vec::new);
            }
            for s in part.scratch.iter_mut().take(xs.len()) {
                s.resize(block_rows, 0.0);
            }
            if let Err(e) = part.plan.execute_many(xs, &mut part.scratch[..xs.len()]) {
                part.error = Some(e);
            }
        });
        self.drain_errors()?;
        for part in &self.parts {
            for (y, s) in ys.iter_mut().zip(&part.scratch) {
                y[part.rows.clone()].copy_from_slice(s);
            }
        }
        self.passes += (xs.len() as u64).div_ceil(self.batch_tile as u64);
        Ok(())
    }

    /// Single-vector split execution behind
    /// [`ShardedPlanner::execute_split`] — the same concurrent fan-out
    /// and merge for one right-hand side.
    ///
    /// # Errors
    /// Fails on dimension mismatches, or if any block's execution failed.
    pub(crate) fn execute(&mut self, x: &[Value], y: &mut [Value]) -> Result<()> {
        anyhow::ensure!(
            x.len() == self.n_cols,
            "x length {} != n_cols {}",
            x.len(),
            self.n_cols
        );
        anyhow::ensure!(
            y.len() == self.n_rows,
            "y length {} != n_rows {}",
            y.len(),
            self.n_rows
        );
        self.group.join_all(&self.pools, &mut self.parts, |_i, part| {
            let block_rows = part.rows.end - part.rows.start;
            part.scratch1.resize(block_rows, 0.0);
            if let Err(e) = part.plan.execute(x, &mut part.scratch1) {
                part.error = Some(e);
            }
        });
        self.drain_errors()?;
        for part in &self.parts {
            y[part.rows.clone()].copy_from_slice(&part.scratch1);
        }
        self.passes += 1;
        Ok(())
    }

    /// Surface the first error any block reported during the last join,
    /// clearing **every** slot — a stale error left behind must not fail
    /// the next (successful) call.
    fn drain_errors(&mut self) -> Result<()> {
        let mut first = None;
        for part in &mut self.parts {
            if let Some(e) = part.error.take() {
                first.get_or_insert(e);
            }
        }
        match first {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl std::fmt::Debug for SplitPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SplitPlan")
            .field("parts", &self.parts.len())
            .field("n_rows", &self.n_rows)
            .field(
                "shards",
                &self.parts.iter().map(|p| p.shard).collect::<Vec<_>>(),
            )
            .finish()
    }
}

impl std::fmt::Debug for ShardedPlanner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedPlanner").field("shards", &self.shards).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::Implementation;

    fn tuning() -> TuningData {
        TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        }
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let s = PlanShards::new(4, 1);
        for key in ["a", "b", "xenon1", "memplus", "m-0", "m-1", "m-2"] {
            let r = s.route(key);
            assert!(r < 4);
            assert_eq!(r, s.route(key), "route must be stable");
            assert!(Arc::ptr_eq(s.pool_for(key), s.pool(r)));
        }
    }

    #[test]
    fn distinct_keys_spread_over_shards() {
        let s = PlanShards::new(2, 1);
        // Some pair among a small key set must land on each shard.
        let hit: std::collections::HashSet<usize> =
            (0..16).map(|i| s.route(&format!("m-{i}"))).collect();
        assert_eq!(hit.len(), 2, "16 keys over 2 shards must hit both");
    }

    #[test]
    fn sharded_planner_builds_on_the_routed_pool() {
        let sp = ShardedPlanner::new(tuning(), MemoryPolicy::unlimited(), PlanShards::new(3, 2));
        assert_eq!(sp.len(), 3);
        for key in ["p", "q", "r", "s"] {
            let shard = sp.shard_of(key);
            assert!(Arc::ptr_eq(sp.planner_for(key).pool(), sp.shards().pool(shard)));
        }
    }

    #[test]
    fn thread_split_spreads_remainder_and_clamps_to_the_budget() {
        assert_eq!(shard_thread_counts(8, 2), vec![4, 4]);
        // Remainder workers go to the leading shards, none stranded.
        assert_eq!(shard_thread_counts(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(shard_thread_counts(10, 4).iter().sum::<usize>(), 10);
        // Regression: more shards than threads used to oversubscribe with
        // width-1 pools; the shard count now clamps to the thread budget
        // so no shard is ever thread-starved.
        assert_eq!(shard_thread_counts(1, 4), vec![1]);
        assert_eq!(shard_thread_counts(3, 7), vec![1, 1, 1]);
        assert_eq!(shard_thread_counts(0, 3), vec![1]);
        assert_eq!(shard_thread_counts(5, 0), vec![5]);
        for (total, shards) in [(1, 4), (2, 7), (16, 3), (0, 0), (7, 7)] {
            let counts = shard_thread_counts(total, shards);
            assert!(counts.iter().all(|&c| c >= 1), "({total},{shards}): no dead pools");
            assert_eq!(counts.iter().sum::<usize>(), total.max(1), "({total},{shards})");
        }
        let s = PlanShards::spread(4, 10);
        assert_eq!(s.len(), 4);
        assert_eq!(s.pool(0).size(), 3);
        assert_eq!(s.pool(3).size(), 2);
    }

    #[test]
    fn env_default_tracks_the_socket_count() {
        // SPMV_AT_SHARDS unset → the shard count is the detected socket
        // count (1 on single-node machines: the pre-NUMA behaviour).
        if std::env::var("SPMV_AT_SHARDS").is_err() {
            let sockets = crate::machine::Topology::detect().n_sockets();
            assert_eq!(configured_shards(), sockets);
            assert_eq!(PlanShards::from_env(4).len(), sockets.min(4));
        }
    }

    #[test]
    fn spread_on_pins_pools_per_socket() {
        let topo = crate::machine::Topology::parse_override("2:2").unwrap();
        let s = PlanShards::spread_on(4, 4, &topo);
        assert_eq!(s.len(), 4);
        assert_eq!(s.pool(0).affinity(), Some(&[0usize, 1][..]));
        assert_eq!(s.pool(1).affinity(), Some(&[2usize, 3][..]));
        assert_eq!(s.pool(2).affinity(), Some(&[0usize, 1][..]), "wraps past the sockets");
        // Single-socket topologies never pin.
        let flat = crate::machine::Topology::single_node(4);
        assert!(PlanShards::spread_on(2, 4, &flat).pool(0).affinity().is_none());
    }

    #[test]
    fn split_plan_matches_unsplit_and_lands_on_every_shard() {
        use crate::matrixgen::random_csr;
        use crate::rng::Rng;
        let mut rng = Rng::new(23);
        let a = Arc::new(random_csr(&mut rng, 120, 120, 0.08));
        let sp = ShardedPlanner::new(tuning(), MemoryPolicy::unlimited(), PlanShards::new(3, 2));
        let xs: Vec<Vec<Value>> = (0..5)
            .map(|j| (0..120).map(|i| 1.0 + ((i * 3 + j) % 7) as f64 * 0.125).collect())
            .collect();
        let mut want = vec![vec![0.0; 120]; 5];
        let mut full = sp.planner(0).plan_for(&a, Implementation::CsrRowPar).unwrap();
        full.execute_many(&xs, &mut want).unwrap();

        let mut split = sp.plan_split(&a, Implementation::CsrRowPar, 3).unwrap();
        assert_eq!(split.parts(), 3);
        assert_eq!(split.n_rows(), 120);
        let dispatch_before: Vec<u64> =
            (0..3).map(|i| sp.shards().pool(i).dispatch_count()).collect();
        let passes_before = split.matrix_passes();
        let mut got = vec![vec![0.0; 120]; 5];
        sp.execute_split_many(&mut split, &xs, &mut got).unwrap();
        assert_eq!(got, want, "row split must be bitwise-identical");
        // Every block really ran on its own shard pool.
        for i in 0..split.parts() {
            let shard = split.part_shard(i);
            assert!(
                sp.shards().pool(shard).dispatch_count() > dispatch_before[shard],
                "block {i} must dispatch on shard {shard}"
            );
        }
        assert!(split.matrix_passes() > passes_before);
        // The blocks were dispatched concurrently, not one after another.
        assert_eq!(split.max_concurrent_blocks(), 3);
        assert_eq!(split.join_count(), 1);
        // The single-vector path agrees with the batched one.
        let mut y1 = vec![0.0; 120];
        sp.execute_split(&mut split, &xs[0], &mut y1).unwrap();
        assert_eq!(y1, want[0], "execute_split must match the batched rows");
        // Dimension mismatches are rejected.
        assert!(split.execute_many(&xs, &mut vec![vec![0.0; 119]; 5]).is_err());
        assert!(split.execute_many(&xs[..2], &mut got).is_err());
        assert!(split.execute(&xs[0][..119], &mut y1).is_err());
        assert!(split.execute(&xs[0], &mut vec![0.0; 119]).is_err());
    }

    #[test]
    fn split_threshold_truth_function() {
        use SplitThreshold::{Auto, Off, Rows};
        assert_eq!(SplitThreshold::parse("0"), Some(Off));
        assert_eq!(SplitThreshold::parse(" 4096 "), Some(Rows(4096)));
        assert_eq!(SplitThreshold::parse("auto"), Some(Auto));
        assert_eq!(SplitThreshold::parse("AUTO"), Some(Auto));
        assert_eq!(SplitThreshold::parse("-3"), None);
        assert_eq!(SplitThreshold::parse("rows"), None);
        // Single-shard planners never split, whatever the threshold says.
        assert!(!Rows(1).should_split(1 << 20, usize::MAX, 1));
        assert!(!Auto.should_split(usize::MAX, usize::MAX, 1));
        assert!(!Off.should_split(usize::MAX, usize::MAX, 8));
        // Explicit row threshold is inclusive.
        assert!(Rows(100).should_split(100, 1, 2));
        assert!(!Rows(100).should_split(99, usize::MAX, 2));
        // The nnz heuristic scales with the shard count.
        assert!(Auto.should_split(1 << 20, SPLIT_AUTO_NNZ_PER_SHARD * 2, 2));
        assert!(!Auto.should_split(1 << 20, SPLIT_AUTO_NNZ_PER_SHARD * 2 - 1, 2));
        assert!(!Auto.should_split(1 << 20, SPLIT_AUTO_NNZ_PER_SHARD * 2, 3));
        // One-row matrices cannot split.
        assert!(!Rows(1).should_split(1, usize::MAX, 2));
        // Unset environment = the Auto heuristic.
        if std::env::var("SPMV_AT_SPLIT_ROWS").is_err() {
            assert_eq!(SplitThreshold::from_env(), Auto);
        }
    }

    #[test]
    fn drain_errors_clears_every_slot() {
        // Regression: two blocks failing in one join used to leave the
        // second error in place, spuriously failing the NEXT call.
        let sp = ShardedPlanner::new(tuning(), MemoryPolicy::unlimited(), PlanShards::new(2, 1));
        let a = Arc::new(Csr::identity(8));
        let mut split = sp.plan_split(&a, Implementation::CsrRowPar, 2).unwrap();
        for p in &mut split.parts {
            p.error = Some(anyhow::anyhow!("injected"));
        }
        assert!(split.drain_errors().is_err(), "the first error surfaces");
        let xs = vec![vec![1.0; 8]];
        let mut ys = vec![vec![0.0; 8]];
        split.execute_many(&xs, &mut ys).unwrap();
        assert_eq!(ys[0], vec![1.0; 8], "no stale error may fail a successful call");
    }

    #[test]
    fn split_passes_follow_unsplit_tile_semantics() {
        // Regression: matrix_passes once summed the per-block counters,
        // over-counting by a factor of `parts` vs the unsplit plan.
        use crate::matrixgen::random_csr;
        use crate::rng::Rng;
        let mut rng = Rng::new(29);
        let a = Arc::new(random_csr(&mut rng, 80, 80, 0.1));
        let sp = ShardedPlanner::new(tuning(), MemoryPolicy::unlimited(), PlanShards::new(2, 1));
        let mut full = sp.planner(0).plan_for(&a, Implementation::CsrRowPar).unwrap();
        let mut split = sp.plan_split(&a, Implementation::CsrRowPar, 2).unwrap();
        full.set_batch_tile(3);
        split.set_batch_tile(3);
        assert_eq!(split.batch_tile(), 3);
        let k = 7usize;
        let xs: Vec<Vec<Value>> = (0..k)
            .map(|j| (0..80).map(|i| ((i + j) as f64 * 0.19).sin()).collect())
            .collect();
        let mut ys = vec![vec![0.0; 80]; k];
        full.execute_many(&xs, &mut ys).unwrap();
        split.execute_many(&xs, &mut ys).unwrap();
        assert_eq!(
            split.matrix_passes(),
            full.matrix_passes(),
            "split passes must pin to the unsplit ceil(k/tile) count"
        );
        split.execute(&xs[0], &mut ys[0]).unwrap();
        full.execute(&xs[0], &mut ys[1]).unwrap();
        assert_eq!(split.matrix_passes(), full.matrix_passes(), "execute adds one pass each");
    }
}
