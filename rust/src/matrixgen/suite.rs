//! The Table-1 benchmark suite, regenerated synthetically.
//!
//! Each [`MatrixSpec`] carries the published `(N, NNZ, μ, σ)` of one UF
//! collection matrix plus a structure class; [`generate`] synthesizes a
//! matrix matching those moments (and therefore the published `D_mat`).
//! A `scale` factor shrinks `N`/`NNZ` proportionally (keeping `μ`, `σ`,
//! `D_mat`) so tests can run the whole suite quickly.

use super::rowlen;
use super::{assemble_from_row_lens, Placement};
use crate::formats::Csr;
use crate::rng::Rng;

/// Qualitative structure class driving column placement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GenClass {
    /// FEM / device stencil: near-diagonal banded locality.
    BandedFem,
    /// Circuit / graph: uniform scatter, heavy-tailed rows.
    Circuit,
    /// Bio-mechanical power-tail (torso1): extreme outlier rows.
    PowerTail,
}

/// One Table-1 row.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    /// Paper's matrix number (1–22).
    pub no: u32,
    /// UF collection name.
    pub name: &'static str,
    /// Dimension `N` (all Table-1 matrices are square).
    pub n: usize,
    /// Non-zero count `NNZ`.
    pub nnz: usize,
    /// Published mean non-zeros per row `μ`.
    pub mu: f64,
    /// Published standard deviation `σ`.
    pub sigma: f64,
    /// Published `D_mat = σ/μ`.
    pub d_mat: f64,
    /// Application field (Table-1 "Field" column).
    pub field: &'static str,
    /// Table-1 set (I or II).
    pub set: u8,
    /// Structure class used for synthesis.
    pub class: GenClass,
    /// Published max non-zeros per row of the original UF matrix, where
    /// known — pins the synthetic ELL bandwidth (hence fill ratio).
    pub max_row: Option<usize>,
}

impl MatrixSpec {
    const fn new(
        no: u32,
        name: &'static str,
        n: usize,
        nnz: usize,
        mu: f64,
        sigma: f64,
        d_mat: f64,
        field: &'static str,
        set: u8,
        class: GenClass,
    ) -> Self {
        Self { no, name, n, nnz, mu, sigma, d_mat, field, set, class, max_row: None }
    }

    const fn with_max_row(mut self, max_row: usize) -> Self {
        self.max_row = Some(max_row);
        self
    }
}

/// The 22 Table-1 matrices (sets I and II).
pub fn table1_specs() -> Vec<MatrixSpec> {
    use GenClass::*;
    vec![
        MatrixSpec::new(1, "chipcool0", 20_082, 281_150, 14.00, 2.69, 0.19, "2D/3D", 1, BandedFem),
        MatrixSpec::new(2, "chem_master1", 40_401, 201_201, 4.98, 0.14, 0.02, "2D/3D", 1, BandedFem),
        MatrixSpec::new(3, "torso1", 116_158, 8_516_500, 73.31, 419.58, 5.72, "2D/3D", 1, PowerTail)
            .with_max_row(3_263),
        MatrixSpec::new(4, "torso2", 115_067, 1_033_473, 8.91, 0.58, 0.06, "2D/3D", 1, BandedFem),
        MatrixSpec::new(5, "torso3", 259_156, 4_429_042, 17.09, 4.39, 0.25, "2D/3D", 1, BandedFem),
        MatrixSpec::new(6, "memplus", 17_758, 126_150, 7.10, 22.03, 3.10, "Electric circuit", 1, Circuit)
            .with_max_row(574),
        MatrixSpec::new(7, "ex19", 12_005, 259_879, 21.64, 12.28, 0.56, "Fluid dynamics", 1, BandedFem),
        MatrixSpec::new(8, "poisson3Da", 13_514, 352_762, 26.10, 13.76, 0.52, "Fluid dynamics", 1, BandedFem),
        MatrixSpec::new(9, "poisson3Db", 85_623, 2_374_949, 27.73, 14.71, 0.53, "Fluid dynamics", 1, BandedFem),
        MatrixSpec::new(10, "airfoil_2d", 14_214, 259_688, 18.26, 3.94, 0.21, "Fluid dynamics", 1, BandedFem),
        MatrixSpec::new(11, "viscoplastic2", 32_769, 381_326, 11.63, 13.95, 1.19, "Materials", 1, Circuit),
        MatrixSpec::new(12, "xenon1", 48_600, 1_181_120, 24.30, 4.25, 0.17, "Materials", 2, BandedFem),
        MatrixSpec::new(13, "xenon2", 157_464, 3_866_688, 24.55, 4.06, 0.16, "Materials", 2, BandedFem),
        MatrixSpec::new(14, "wang3", 26_064, 177_168, 6.79, 0.43, 0.06, "Semiconductor device", 2, BandedFem),
        MatrixSpec::new(15, "wang4", 26_068, 177_196, 6.79, 0.43, 0.06, "Semiconductor device", 2, BandedFem),
        MatrixSpec::new(16, "ec132", 51_993, 380_415, 7.31, 3.35, 0.45, "Semiconductor device", 2, BandedFem),
        MatrixSpec::new(17, "sme3Da", 12_504, 874_887, 69.96, 34.92, 0.49, "Structural", 2, BandedFem),
        MatrixSpec::new(18, "sme3Db", 29_067, 2_081_063, 71.59, 37.06, 0.51, "Structural", 2, BandedFem),
        MatrixSpec::new(19, "sme3Dc", 42_930, 3_148_656, 73.34, 36.98, 0.50, "Structural", 2, BandedFem),
        MatrixSpec::new(20, "epb1", 14_734, 95_053, 6.45, 0.57, 0.08, "Thermal", 2, BandedFem),
        MatrixSpec::new(21, "epb2", 25_228, 175_027, 6.93, 6.38, 0.92, "Thermal", 2, Circuit),
        MatrixSpec::new(22, "epb3", 84_617, 463_625, 5.47, 0.54, 0.10, "Thermal", 2, BandedFem),
    ]
}

/// Look up a spec by its Table-1 name.
pub fn spec_by_name(name: &str) -> Option<MatrixSpec> {
    table1_specs().into_iter().find(|s| s.name.eq_ignore_ascii_case(name))
}

/// Generate the matrix for `spec` at `scale` ∈ (0, 1]. `scale` shrinks
/// `N` and `NNZ` together so `μ`, `σ` and `D_mat` are preserved; 1.0
/// reproduces the published size. The generator is deterministic in
/// `(spec.no, seed, scale)`.
pub fn generate(spec: &MatrixSpec, seed: u64, scale: f64) -> Csr {
    assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0,1], got {scale}");
    let n = ((spec.n as f64 * scale).round() as usize).max(8);
    // Keep μ: nnz scales with n.
    let nnz = ((spec.mu * n as f64).round() as usize).min(n * n);
    let mut rng = Rng::new(seed ^ (spec.no as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let lens = rowlen::synthesize_with_max(&mut rng, n, nnz, spec.sigma, n, spec.max_row);
    let placement = match spec.class {
        GenClass::BandedFem => Placement::Banded,
        GenClass::Circuit | GenClass::PowerTail => Placement::Uniform,
    };
    assemble_from_row_lens(&mut rng, n, &lens, placement)
}

/// Measured moments of a generated matrix, for Table-1 reporting.
#[derive(Clone, Copy, Debug)]
pub struct MeasuredStats {
    /// Rows.
    pub n: usize,
    /// Non-zeros.
    pub nnz: usize,
    /// Mean non-zeros/row.
    pub mu: f64,
    /// Std non-zeros/row.
    pub sigma: f64,
    /// σ/μ.
    pub d_mat: f64,
    /// Max row length (ELL bandwidth).
    pub max_row: usize,
}

/// Measure the Table-1 statistics of any CSR matrix.
pub fn measure(a: &Csr) -> MeasuredStats {
    use crate::formats::SparseMatrix as _;
    let lens: Vec<usize> = (0..a.n_rows()).map(|i| a.row_len(i)).collect();
    let s = rowlen::stats(&lens);
    MeasuredStats {
        n: a.n_rows(),
        nnz: a.nnz(),
        mu: s.mean,
        sigma: s.std,
        d_mat: if s.mean > 0.0 { s.std / s.mean } else { 0.0 },
        max_row: s.max,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_22_rows_with_published_dmat() {
        let specs = table1_specs();
        assert_eq!(specs.len(), 22);
        for s in &specs {
            let computed = s.sigma / s.mu;
            assert!(
                (computed - s.d_mat).abs() < 0.02,
                "{}: published D_mat {} vs σ/μ {computed}",
                s.name,
                s.d_mat
            );
        }
        // Set split: 11 + 11.
        assert_eq!(specs.iter().filter(|s| s.set == 1).count(), 11);
        assert_eq!(specs.iter().filter(|s| s.set == 2).count(), 11);
    }

    #[test]
    fn generated_moments_match_spec_at_small_scale() {
        for spec in table1_specs() {
            let a = generate(&spec, 42, 0.05);
            let m = measure(&a);
            assert!(
                (m.mu - spec.mu).abs() / spec.mu < 0.05,
                "{}: μ {} vs {}",
                spec.name,
                m.mu,
                spec.mu
            );
            let d_err = (m.d_mat - spec.d_mat).abs() / spec.d_mat.max(0.02);
            assert!(
                d_err < 0.75,
                "{}: D_mat {} vs {} (rel err {d_err})",
                spec.name,
                m.d_mat,
                spec.d_mat
            );
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = spec_by_name("memplus").unwrap();
        let a = generate(&spec, 7, 0.05);
        let b = generate(&spec, 7, 0.05);
        assert_eq!(a, b);
        let c = generate(&spec, 8, 0.05);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn spec_lookup() {
        assert!(spec_by_name("torso1").is_some());
        assert!(spec_by_name("TORSO1").is_some());
        assert!(spec_by_name("nope").is_none());
    }

    #[test]
    #[should_panic(expected = "scale must be in (0,1]")]
    fn rejects_bad_scale() {
        let spec = table1_specs().remove(0);
        let _ = generate(&spec, 1, 0.0);
    }
}
